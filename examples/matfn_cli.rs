//! Matrix-function playground: sweep spectra and watch PRISM adapt.
//!
//! Reproduces the qualitative content of the paper's Figure 1 at example
//! scale: fix `sigma_max = 1`, sweep `sigma_min` over decades, and for each
//! matrix report iterations-to-tolerance for classic Newton–Schulz,
//! PolarExpress (optimized for sigma_min = 1e-3), and PRISM — for both the
//! polar factor and the square root. PolarExpress degrades away from its
//! design interval; PRISM stays flat. Also prints the alpha_k traces, the
//! paper's "fingerprint" of spectrum adaptivity (Figs. 3-4 right panels).
//!
//! Every algorithm is a `matfn` registry name, and each solver is planned
//! once and reused across the whole sweep — the persistent-workspace path.
//!
//! ```sh
//! cargo run --release --example matfn_cli -- [--n 128] [--decades 10]
//! ```

use prism::cli::Args;
use prism::linalg::gemm::syrk_at_a;
use prism::matfn::registry;
use prism::prism::StopRule;
use prism::randmat;
use prism::rng::Rng;

fn main() {
    let args = Args::from_env(false);
    let n = args.get_usize("n", 128).unwrap();
    let m = n / 2;
    let decades = args.get_usize("decades", 10).unwrap();
    let seed = args.get_u64("seed", 42).unwrap();
    let tol = 1e-6;
    let stop = StopRule::default().with_max_iters(400).with_tol(tol);

    // Plan each solver once; the sweep below reuses their workspaces.
    let plan = |name: &str| {
        let mut s = registry::resolve(name).expect("registry name");
        s.set_stop(stop);
        s
    };
    let mut classic_polar = plan("ns-polar");
    let mut pe_polar = plan("pe-polar");
    let mut prism_polar = plan("prism5-polar");
    let mut classic_sqrt = plan("ns-sqrt");
    let mut prism_sqrt = plan("prism5-sqrt");

    println!("matfn_cli (Fig. 1 analog): {n}x{m}, sigma_min sweep, tol {tol:.0e}\n");
    println!("POLAR  — iterations to ‖I − XᵀX‖_F < tol");
    println!(
        "{:>10} {:>12} {:>14} {:>10} {:>18}",
        "sigma_min", "ns-polar", "pe-polar", "prism5", "PRISM speedup(it)"
    );

    let mut rng = Rng::seed_from(seed);
    let mut last_alphas: Vec<f64> = Vec::new();
    for dec in 0..decades {
        let smin = 10f64.powi(-(dec as i32 + 1));
        let s = randmat::logspace(smin, 1.0, m);
        let a = randmat::with_spectrum(&mut rng, n, m, &s);

        let classic = classic_polar.solve(&a, &mut rng);
        let pe = pe_polar.solve(&a, &mut rng);
        let fast = prism_polar.solve(&a, &mut rng);
        let it = |l: &prism::prism::IterationLog| {
            l.iters_to_tol(tol).map(|k| k.to_string()).unwrap_or_else(|| "—".into())
        };
        let speedup = match (classic.log.iters_to_tol(tol), fast.log.iters_to_tol(tol)) {
            (Some(c), Some(p)) if p > 0 => format!("{:.2}x", c as f64 / p as f64),
            _ => "—".into(),
        };
        println!(
            "{:>10.0e} {:>12} {:>14} {:>10} {:>18}",
            smin,
            it(&classic.log),
            it(&pe.log),
            it(&fast.log),
            speedup
        );
        last_alphas = fast.log.alphas.clone();
    }

    println!("\nSQRT   — iterations to coupled residual < tol (A = GᵀG)");
    println!("{:>10} {:>12} {:>10}", "sigma_min", "ns-sqrt", "prism5");
    for dec in 0..decades / 2 {
        // sqrt squares the condition number: sweep fewer decades.
        let smin = 10f64.powi(-(dec as i32 + 1));
        let s = randmat::logspace(smin, 1.0, m);
        let g = randmat::with_spectrum(&mut rng, n, m, &s);
        let a = syrk_at_a(&g);
        let classic = classic_sqrt.solve(&a, &mut rng);
        let fast = prism_sqrt.solve(&a, &mut rng);
        let it = |l: &prism::prism::IterationLog| {
            l.iters_to_tol(tol).map(|k| k.to_string()).unwrap_or_else(|| "—".into())
        };
        println!("{:>10.0e} {:>12} {:>10}", smin, it(&classic.log), it(&fast.log));
    }

    println!(
        "\nworkspace: prism5-polar ran {} decades with {} buffer allocations total",
        decades,
        prism_polar.workspace_allocations()
    );
    println!("\nPRISM-5 alpha_k trace for the hardest polar instance (adapts, then");
    println!("relaxes to the Taylor coefficient 0.375 as the spectrum contracts):");
    let pts: Vec<String> = last_alphas.iter().map(|a| format!("{a:.3}")).collect();
    println!("  [{}]", pts.join(", "));
}
