//! Quickstart: the 60-second tour of the PRISM public API.
//!
//! Computes each matrix function from the paper's Table 1 on a small
//! ill-conditioned test matrix and shows the PRISM speedup over the classic
//! iteration — no artifacts or configuration required.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use prism::linalg::gemm::{matmul, syrk_at_a};
use prism::linalg::Mat;
use prism::prism::chebyshev::{chebyshev_inverse, ChebyshevOpts};
use prism::prism::db_newton::{db_newton_prism, DbNewtonOpts};
use prism::prism::inverse_newton::{inv_root_prism, InvRootOpts};
use prism::prism::polar::{orthogonality_error, polar_prism, PolarOpts};
use prism::prism::sqrt::{sqrt_prism, SqrtOpts};
use prism::prism::StopRule;
use prism::randmat;
use prism::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from(42);

    // An ill-conditioned 96x48 test matrix: singular values log-spaced in
    // [1e-6, 1]. Classic Newton–Schulz stalls early on this spectrum; PRISM
    // adapts α_k to it (the paper's Figure 1 setting).
    let s = randmat::logspace(1e-6, 1.0, 48);
    let a = randmat::with_spectrum(&mut rng, 96, 48, &s);
    let stop = StopRule::default().with_max_iters(200).with_tol(1e-8);

    println!("PRISM quickstart — A in R^(96x48), sigma in [1e-6, 1]\n");

    // ── 1. Orthogonalization (polar factor, the Muon primitive) ───────────
    let classic = polar_prism(&a, &PolarOpts::classic(2).with_stop(stop), &mut rng);
    let fast = polar_prism(&a, &PolarOpts::degree5().with_stop(stop), &mut rng);
    println!("polar factor U Vᵀ (5th-order Newton–Schulz):");
    println!(
        "  classic : {:>3} iters   PRISM-5 : {:>3} iters   ({:.2}x fewer)",
        classic.log.iters(),
        fast.log.iters(),
        classic.log.iters() as f64 / fast.log.iters() as f64
    );
    println!("  orthogonality error ‖I − QᵀQ‖_F = {:.2e}\n", orthogonality_error(&fast.q));

    // ── 2. Square root + inverse square root (the Shampoo primitive) ──────
    let spd = syrk_at_a(&a); // SPD 48x48 with squared spectrum
    let c_sqrt = sqrt_prism(&spd, &SqrtOpts::classic(2).with_stop(stop), &mut rng);
    let p_sqrt = sqrt_prism(&spd, &SqrtOpts::degree5().with_stop(stop), &mut rng);
    let check = matmul(&p_sqrt.sqrt, &p_sqrt.sqrt).sub(&spd).max_abs();
    println!("square root A^(1/2), inverse root A^(-1/2) (coupled NS):");
    println!(
        "  classic : {:>3} iters   PRISM-5 : {:>3} iters   ‖X² − A‖_max = {:.2e}\n",
        c_sqrt.log.iters(),
        p_sqrt.log.iters(),
        check
    );

    // ── 3. Inverse p-th root (general Shampoo p) ───────────────────────────
    let c_ir = inv_root_prism(&spd, &InvRootOpts::classic(2).with_stop(stop), &mut rng);
    let p_ir = inv_root_prism(&spd, &InvRootOpts::prism(2).with_stop(stop), &mut rng);
    println!("inverse root A^(-1/2) via coupled inverse Newton:");
    println!("  classic : {:>3} iters   PRISM   : {:>3} iters\n", c_ir.log.iters(), p_ir.log.iters());

    // ── 4. DB Newton (globally convergent sqrt, O(n²) α fit) ──────────────
    let c_db = db_newton_prism(&spd, &DbNewtonOpts::classic().with_stop(stop), &mut rng);
    let p_db = db_newton_prism(&spd, &DbNewtonOpts::prism().with_stop(stop), &mut rng);
    println!("DB Newton square root (product form):");
    println!("  classic : {:>3} iters   PRISM   : {:>3} iters\n", c_db.log.iters(), p_db.log.iters());

    // ── 5. Matrix inverse via Chebyshev ────────────────────────────────────
    let sq = randmat::sym_with_spectrum(&mut rng, 48, &randmat::logspace(1e-3, 1.0, 48));
    let c_inv = chebyshev_inverse(&sq, &ChebyshevOpts::classic().with_stop(stop), &mut rng);
    let p_inv = chebyshev_inverse(&sq, &ChebyshevOpts::prism().with_stop(stop), &mut rng);
    let id_err = matmul(&sq, &p_inv.inverse).sub(&Mat::eye(48)).max_abs();
    println!("matrix inverse A⁻¹ via Chebyshev iteration:");
    println!(
        "  classic : {:>3} iters   PRISM   : {:>3} iters   ‖AX − I‖_max = {:.2e}\n",
        c_inv.log.iters(),
        p_inv.log.iters(),
        id_err
    );

    // ── 6. The adaptive α_k trace — PRISM's fingerprint ────────────────────
    println!("PRISM-5 polar α_k trace (adapts to the spectrum, no σ_min input):");
    let trace: Vec<String> = fast.log.alphas.iter().map(|x| format!("{x:.3}")).collect();
    println!("  [{}]", trace.join(", "));
    println!("\nAll engines share one knob set: degree d, sketch size p, stop rule.");
    println!("See `prism --help` (the binary) and examples/ for the full system.");
}
