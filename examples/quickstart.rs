//! Quickstart: the 60-second tour of the unified `matfn` solver API.
//!
//! Every matrix function from the paper's Table 1 goes through the same
//! three steps — pick a registry name, plan a `Solver`, call `solve` — and a
//! planned solver is *persistent*: repeated same-shape calls reuse its
//! workspace and perform zero heap allocations in the hot loop.
//!
//! Under the hood every solve runs on the packed cache-blocked GEMM engine
//! (`prism::linalg::gemm` — tune with `--gemm-block MCxKCxNC` on the CLI),
//! and general-degree updates evaluate their polynomials by
//! Paterson–Stockmeyer in ≈ 2√d GEMMs instead of d − 1 explicit powers.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use prism::linalg::gemm::{matmul, syrk_at_a};
use prism::linalg::Mat;
use prism::matfn::registry;
use prism::prism::polar::orthogonality_error;
use prism::prism::StopRule;
use prism::randmat;
use prism::rng::Rng;

fn main() {
    let mut rng = Rng::seed_from(42);

    // An ill-conditioned 96x48 test matrix: singular values log-spaced in
    // [1e-6, 1]. Classic Newton–Schulz stalls early on this spectrum; PRISM
    // adapts α_k to it (the paper's Figure 1 setting).
    let s = randmat::logspace(1e-6, 1.0, 48);
    let a = randmat::with_spectrum(&mut rng, 96, 48, &s);
    let stop = StopRule::default().with_max_iters(200).with_tol(1e-8);
    // One helper: resolve a registry name, apply the stop rule, solve.
    let run = |name: &str, input: &Mat, rng: &mut Rng| {
        let mut solver = registry::resolve(name).expect("registry name");
        solver.set_stop(stop);
        solver.solve(input, rng)
    };

    println!("matfn quickstart — A in R^(96x48), sigma in [1e-6, 1]");
    println!("registry exposes {} named solvers\n", registry::names().len());

    // ── 1. Orthogonalization (polar factor, the Muon primitive) ───────────
    let classic = run("ns-polar", &a, &mut rng);
    let fast = run("prism5-polar", &a, &mut rng);
    println!("polar factor U Vᵀ  (ns-polar vs prism5-polar):");
    println!(
        "  classic : {:>3} iters   PRISM-5 : {:>3} iters   ({:.2}x fewer)",
        classic.log.iters(),
        fast.log.iters(),
        classic.log.iters() as f64 / fast.log.iters() as f64
    );
    println!(
        "  orthogonality error ‖I − QᵀQ‖_F = {:.2e}\n",
        orthogonality_error(&fast.primary)
    );

    // ── 2. Square root + inverse square root (the Shampoo primitive) ──────
    let spd = syrk_at_a(&a); // SPD 48x48 with squared spectrum
    let c_sqrt = run("ns-sqrt", &spd, &mut rng);
    let p_sqrt = run("prism5-sqrt", &spd, &mut rng);
    let check = matmul(&p_sqrt.primary, &p_sqrt.primary).sub(&spd).max_abs();
    println!("square root A^(1/2)  (ns-sqrt vs prism5-sqrt, coupled NS):");
    println!(
        "  classic : {:>3} iters   PRISM-5 : {:>3} iters   ‖X² − A‖_max = {:.2e}",
        c_sqrt.log.iters(),
        p_sqrt.log.iters(),
        check
    );
    println!("  (secondary output is the coupled A^(-1/2) for free)\n");

    // ── 3. Inverse p-th root (general Shampoo p) ───────────────────────────
    let c_ir = run("invnewton-classic-invroot2", &spd, &mut rng);
    let p_ir = run("invnewton-invroot2", &spd, &mut rng);
    println!("inverse root A^(-1/2) via coupled inverse Newton:");
    println!(
        "  classic : {:>3} iters   PRISM   : {:>3} iters\n",
        c_ir.log.iters(),
        p_ir.log.iters()
    );

    // ── 4. DB Newton (globally convergent sqrt, O(n²) α fit) ──────────────
    let c_db = run("newton-classic-sqrt", &spd, &mut rng);
    let p_db = run("newton-sqrt", &spd, &mut rng);
    println!("DB Newton square root (product form):");
    println!(
        "  classic : {:>3} iters   PRISM   : {:>3} iters\n",
        c_db.log.iters(),
        p_db.log.iters()
    );

    // ── 5. Matrix inverse via Chebyshev ────────────────────────────────────
    let sq = randmat::sym_with_spectrum(&mut rng, 48, &randmat::logspace(1e-3, 1.0, 48));
    let c_inv = run("cheb-classic-inverse", &sq, &mut rng);
    let p_inv = run("cheb-inverse", &sq, &mut rng);
    let id_err = matmul(&sq, &p_inv.primary).sub(&Mat::eye(48)).max_abs();
    println!("matrix inverse A⁻¹ via Chebyshev iteration:");
    println!(
        "  classic : {:>3} iters   PRISM   : {:>3} iters   ‖AX − I‖_max = {:.2e}\n",
        c_inv.log.iters(),
        p_inv.log.iters(),
        id_err
    );

    // ── 6. Persistent solvers: reuse + warm start + observer ───────────────
    let mut solver = registry::resolve("prism5-polar").unwrap();
    solver.set_stop(stop);
    let cold = solver.solve(&a, &mut rng);
    let allocs_after_cold = solver.workspace_allocations();
    let _ = solver.solve(&a, &mut rng);
    println!("persistent solver (prism5-polar):");
    println!(
        "  cold call: {} workspace allocations; warm call: {} new",
        allocs_after_cold,
        solver.workspace_allocations() - allocs_after_cold
    );
    // Warm start (paper §C): hand the previous polar factor back as x0.
    let warm = solver.solve_from(&a, &cold.primary, &mut rng);
    println!(
        "  warm-started from previous result: {} iters (vs {} cold)",
        warm.log.iters(),
        cold.log.iters()
    );
    // Observer: stream per-iteration residuals instead of waiting for the log.
    use std::sync::{Arc, Mutex};
    let trace = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&trace);
    solver.set_observer(Some(Box::new(move |ev| {
        sink.lock().unwrap().push((ev.iter, ev.residual));
    })));
    let _ = solver.solve(&a, &mut rng);
    solver.set_observer(None);
    let trace = trace.lock().unwrap();
    let head: Vec<String> =
        trace.iter().take(4).map(|(k, r)| format!("({k}, {r:.1e})")).collect();
    println!("  streamed {} residual events: [{}, …]\n", trace.len(), head.join(", "));

    // ── 7. The adaptive α_k trace — PRISM's fingerprint ────────────────────
    println!("PRISM-5 polar α_k trace (adapts to the spectrum, no σ_min input):");
    let pts: Vec<String> = fast.log.alphas.iter().map(|x| format!("{x:.3}")).collect();
    println!("  [{}]", pts.join(", "));
    println!("\nEverything above is one API: registry::resolve(name) → Solver::solve.");
    println!("See `prism --help` (the binary) and examples/ for the full system.");
}
