//! The L3 coordinator under load: route, batch, and execute matrix-function
//! jobs on a worker pool, the way a distributed Shampoo/DION deployment
//! refreshes preconditioners while training continues.
//!
//! A synthetic gradient stream (HTMP heavy-tailed spectra, mixed shapes)
//! feeds the service; we sweep worker counts and batching limits and report
//! throughput plus latency percentiles per configuration — demonstrating the
//! amortization PRISM's cheap `O(n²p)` fit enables inside a batched service.
//!
//! ```sh
//! cargo run --release --example precond_service -- [--jobs 96] [--n 96]
//! ```

use prism::cli::Args;
use prism::config::{Admission, Backend, ServiceConfig};
use prism::coordinator::async_shampoo::AsyncShampoo;
use prism::coordinator::service::{JobKind, Service};
use prism::linalg::gemm::syrk_at_a;
use prism::nn::mlp::Mlp;
use prism::optim::Optimizer;
use prism::rng::Rng;
use prism::util::Stopwatch;
use prism::workload::{BlobsDataset, GradientStream};

struct LoadResult {
    workers: usize,
    max_batch: usize,
    backend: &'static str,
    jobs_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn run_load(
    workers: usize,
    max_batch: usize,
    backend: Backend,
    bname: &'static str,
    jobs: usize,
    n: usize,
    kappa: f64,
    seed: u64,
) -> LoadResult {
    let cfg = ServiceConfig {
        workers,
        queue_cap: 256,
        admission: Admission::Block,
        max_batch,
        sketch_p: 8,
        max_iters: 60,
        // None keeps the per-task defaults (1e-7 polar, 1e-9 inverse-root).
        tol: None,
        precision: prism::matfn::Precision::F64,
        solver_cache_cap: 32,
        gemm_threads: 1,
        stream_residuals: false,
        gemm_block: None,
        gemm_kernel: None,
        faults: None,
    };
    // Mixed shapes: square covariance blocks (InvSqrt) and tall gradient
    // panels (Polar) — same-shape jobs batch together, mixed shapes don't.
    let shapes = vec![(n, n), (n, n / 2), (n + n / 4, n)];
    let mut stream = GradientStream::new(seed, shapes, kappa);
    let svc = Service::start(cfg, backend, seed).expect("valid service config");
    let sw = Stopwatch::start();
    for _ in 0..jobs {
        let (layer, g) = stream.next_grad();
        let (r, c) = g.shape();
        if r == c {
            svc.submit(layer, JobKind::InvSqrt { eps: 1e-8 }, syrk_at_a(&g)).unwrap();
        } else {
            svc.submit(layer, JobKind::Polar, g).unwrap();
        }
    }
    let results = svc.drain().unwrap();
    let wall = sw.elapsed_s();
    assert_eq!(results.len(), jobs, "every submitted job must complete");

    let mut lat: Vec<f64> = results.iter().map(|r| r.latency_s * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize];
    LoadResult {
        workers,
        max_batch,
        backend: bname,
        jobs_per_s: jobs as f64 / wall,
        p50_ms: pct(0.5),
        p99_ms: pct(0.99),
    }
}

fn main() {
    let args = Args::from_env(false);
    let jobs = args.get_usize("jobs", 96).unwrap();
    let n = args.get_usize("n", 96).unwrap();
    let kappa = args.get_f64("kappa", 0.5).unwrap();
    let seed = args.get_u64("seed", 42).unwrap();

    println!("precond_service: {jobs} jobs, base shape {n}x{n}, HTMP(kappa={kappa})\n");

    let mut rows = Vec::new();
    // Sweep 1: worker scaling at fixed batch.
    for workers in [1, 2, 4] {
        rows.push(run_load(workers, 4, Backend::Prism5, "prism5", jobs, n, kappa, seed));
    }
    // Sweep 2: batching policy at fixed workers.
    for max_batch in [1, 8] {
        rows.push(run_load(4, max_batch, Backend::Prism5, "prism5", jobs, n, kappa, seed));
    }
    // Sweep 3: backend comparison at the best config.
    for (b, name) in [
        (Backend::Eigen, "eigen"),
        (Backend::PolarExpress, "polar-express"),
        (Backend::Prism3, "prism3"),
    ] {
        rows.push(run_load(4, 4, b, name, jobs, n, kappa, seed));
    }

    println!(
        "{:>7} {:>9} {:<14} {:>10} {:>9} {:>9}",
        "workers", "max_batch", "backend", "jobs/s", "p50 ms", "p99 ms"
    );
    for r in &rows {
        println!(
            "{:>7} {:>9} {:<14} {:>10.1} {:>9.1} {:>9.1}",
            r.workers, r.max_batch, r.backend, r.jobs_per_s, r.p50_ms, r.p99_ms
        );
    }
    println!("\nNotes: throughput should scale with workers until GEMM saturates cores;");
    println!("batching trades p50 latency for throughput; PRISM backends avoid the O(n³)");
    println!("eigendecomposition so they dominate at larger n.");

    // ── Phase 2: staleness-tolerant training through the service ─────────
    // AsyncShampoo trains while its inverse-root refreshes run on the
    // worker pool — the Distributed-Shampoo/DION deployment pattern.
    println!("\n── async Shampoo through the service (staleness-tolerant) ──");
    let cfg = ServiceConfig {
        workers: 2,
        queue_cap: 64,
        admission: Admission::Block,
        max_batch: 1,
        sketch_p: 8,
        max_iters: 40,
        tol: None,
        precision: prism::matfn::Precision::F64,
        solver_cache_cap: 32,
        gemm_threads: 1,
        // Stream per-iteration residuals from the workers (matfn Observer
        // hook) so convergence is visible while refreshes are in flight.
        stream_residuals: true,
        gemm_block: None,
        gemm_kernel: None,
        faults: None,
    };
    let svc = Service::start(cfg, Backend::Prism5, seed).expect("valid service config");
    let mut opt = AsyncShampoo::new(0.05, 1e-6, 5, &svc);
    let mut rng = Rng::seed_from(seed);
    let data = BlobsDataset::generate(&mut rng, 800, 64, 8, 1.8);
    let mut model = Mlp::new(&mut rng, &[64, 48, 8]);
    let (train_idx, val_idx) = data.split(0.2);
    let (val_x, val_y) = data.batch(&val_idx);
    let sw = Stopwatch::start();
    let steps = 60;
    for step in 0..steps {
        let idx: Vec<usize> =
            train_idx.iter().cycle().skip(step * 48).take(48).copied().collect();
        let (x, y) = data.batch(&idx);
        let (loss, _) = model.forward_backward(&x, &y);
        {
            let mut params = model.params_mut();
            opt.step(&mut params);
        }
        model.zero_grads();
        if step % 15 == 0 || step + 1 == steps {
            println!(
                "  step {step:>3}  loss {loss:.4}  val acc {:.3}  in-flight {}  mean staleness {:.1}",
                model.accuracy(&val_x, &val_y),
                opt.pending_jobs(),
                opt.mean_staleness()
            );
        }
    }
    opt.sync();
    let mut streamed = 0usize;
    let mut last_res = f64::NAN;
    while let Some(ev) = svc.try_recv_progress() {
        streamed += 1;
        last_res = ev.residual;
    }
    println!(
        "  streamed {streamed} per-iteration residuals from the workers (last {last_res:.1e})"
    );
    println!(
        "  done in {:.2}s — train loop never blocked after warmup (staleness ≤ interval + service lag)",
        sw.elapsed_s()
    );
}
