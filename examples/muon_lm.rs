//! Figure 6 analog, and the repo's end-to-end driver: train the transformer
//! language model through the full three-layer stack.
//!
//! * **L1/L2** — the model fwd/bwd (with the Pallas Newton–Schulz kernels in
//!   its orbit) was lowered once by `make artifacts` into
//!   `artifacts/train_step.hlo.txt`.
//! * **Runtime** — Rust loads + compiles it with PJRT; Python never runs.
//! * **L3** — this driver samples token batches from a synthetic Markov/Zipf
//!   corpus, executes the artifact, and applies Muon (PolarExpress / PRISM-3 /
//!   PRISM-5 polar backends) or AdamW in Rust.
//!
//! The paper's Fig. 6 ordering is: AdamW ≫ PolarExpress > PRISM-5 > PRISM-3
//! in final validation loss (lower better). We print the loss curves and the
//! final train/val losses per optimizer.
//!
//! ```sh
//! make artifacts && cargo run --release --example muon_lm -- --steps 200
//! ```

use prism::cli::Args;
use prism::config::Backend;
use prism::coordinator::train::TrainDriver;
use prism::optim::adamw::AdamW;
use prism::optim::muon::Muon;
use prism::optim::Optimizer;
use prism::rng::Rng;
use prism::runtime::Runtime;
use prism::workload::MarkovCorpus;

struct RunOut {
    name: String,
    losses: Vec<f64>,
    val_loss: f64,
    ms_per_step: f64,
}

fn run_one(
    rt: &Runtime,
    corpus: &MarkovCorpus,
    mut opt: Box<dyn Optimizer>,
    steps: usize,
    seed: u64,
    log_every: usize,
) -> prism::util::Result<RunOut> {
    let mut driver = TrainDriver::new(rt, seed as f32)?;
    let mut rng = Rng::seed_from(seed ^ 0xBA7C4);
    let name = opt.name();
    println!("── {name}: {} params", driver.num_params());
    for step in 0..steps {
        let (xs, ys) = corpus.sample_batch(&mut rng, driver.batch, driver.seq_len);
        let loss = driver.step(&xs, &ys, opt.as_mut())?;
        if step % log_every == 0 || step + 1 == steps {
            println!("  step {step:>4}  train loss {loss:.4}");
        }
    }
    // Validation: average loss over held-out batches (fresh RNG stream).
    let mut vrng = Rng::seed_from(seed ^ 0x7E57);
    let mut val = 0.0;
    let vbatches = 8;
    for _ in 0..vbatches {
        let (xs, ys) = corpus.sample_batch(&mut vrng, driver.batch, driver.seq_len);
        val += driver.eval(&xs, &ys)?;
    }
    val /= vbatches as f64;
    let ms = driver.step_times_s.iter().sum::<f64>() / driver.step_times_s.len() as f64 * 1e3;
    println!("  val loss {val:.4}  ({ms:.0} ms/step)\n");
    Ok(RunOut { name, losses: driver.losses, val_loss: val, ms_per_step: ms })
}

fn main() -> prism::util::Result<()> {
    let args = Args::from_env(false);
    let steps = args.get_usize("steps", 200)?;
    let seed = args.get_u64("seed", 42)?;
    let log_every = args.get_usize("log-every", 25)?;
    let dir = args.get_string("artifacts", "artifacts");

    let rt = Runtime::open(&dir)?;
    println!("muon_lm (Fig. 6 analog) — PJRT platform: {}\n", rt.platform());

    // One shared corpus so every optimizer sees the same task.
    let probe = TrainDriver::new(&rt, seed as f32)?;
    let (vocab, batch, seq) = (probe.vocab, probe.batch, probe.seq_len);
    drop(probe);
    let mut crng = Rng::seed_from(seed);
    let corpus = MarkovCorpus::generate(&mut crng, vocab, 200_000);
    println!(
        "corpus: {} tokens, vocab {vocab}, unigram entropy {:.3} nats; batch {batch} x seq {seq}\n",
        corpus.tokens.len(),
        corpus.unigram_entropy()
    );

    let runs: Vec<(&str, Box<dyn Optimizer>)> = vec![
        ("adamw", Box::new(AdamW::paper_default())),
        ("muon+polar-express", Box::new(Muon::paper_default(Backend::PolarExpress, seed))),
        ("muon+prism3", Box::new(Muon::paper_default(Backend::Prism3, seed))),
        ("muon+prism5", Box::new(Muon::paper_default(Backend::Prism5, seed))),
    ];

    let mut outs = Vec::new();
    for (_tag, opt) in runs {
        outs.push(run_one(&rt, &corpus, opt, steps, seed, log_every)?);
    }

    println!("{:<24} {:>12} {:>12} {:>12}", "optimizer", "final train", "val loss", "ms/step");
    for o in &outs {
        println!(
            "{:<24} {:>12.4} {:>12.4} {:>12.0}",
            o.name,
            o.losses.last().copied().unwrap_or(f64::NAN),
            o.val_loss,
            o.ms_per_step
        );
    }
    println!("\nloss curves (every {log_every} steps):");
    for o in &outs {
        let pts: Vec<String> = o
            .losses
            .iter()
            .step_by(log_every.max(1))
            .map(|l| format!("{l:.3}"))
            .collect();
        println!("  {:<22} [{}]", o.name, pts.join(", "));
    }
    Ok(())
}
