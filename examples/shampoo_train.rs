//! Figure 5 analog: training speed of Shampoo with three inverse-root
//! backends — eigendecomposition, PolarExpress (coupled), and PRISM-5.
//!
//! The paper trains ResNet-20/32 on CIFAR-10/100; our offline substitute is
//! an MLP classifier on a synthetic blobs dataset with CIFAR-like input
//! width, which exercises exactly the same code path: matrix parameters →
//! Kronecker-factored preconditioners → `L^{-1/2} G R^{-1/2}`. The comparison
//! of interest (which backend gives better validation accuracy per
//! wall-second) is preserved.
//!
//! ```sh
//! cargo run --release --example shampoo_train -- [--steps 150] [--dim 512]
//! ```

use prism::cli::Args;
use prism::config::Backend;
use prism::nn::mlp::Mlp;
use prism::optim::shampoo::Shampoo;
use prism::optim::Optimizer;
use prism::rng::Rng;
use prism::util::Stopwatch;
use prism::workload::BlobsDataset;

struct Curve {
    name: &'static str,
    seconds: Vec<f64>,
    train_loss: Vec<f64>,
    val_acc: Vec<f64>,
}

fn train_one(
    backend: Backend,
    name: &'static str,
    data: &BlobsDataset,
    dims: &[usize],
    steps: usize,
    batch: usize,
    seed: u64,
) -> Curve {
    let mut rng = Rng::seed_from(seed);
    let mut model = Mlp::new(&mut rng, dims);
    let mut opt = Shampoo::paper_default(backend, seed);
    opt.precond_interval = 5;
    let (train_idx, val_idx) = data.split(0.2);
    let (val_x, val_y) = data.batch(&val_idx);

    let mut curve =
        Curve { name, seconds: Vec::new(), train_loss: Vec::new(), val_acc: Vec::new() };
    let sw = Stopwatch::start();
    for step in 0..steps {
        // Mini-batch by cycling a window over the (already shuffled) indices.
        let start = (step * batch) % train_idx.len().saturating_sub(batch).max(1);
        let idx: Vec<usize> = train_idx[start..(start + batch).min(train_idx.len())].to_vec();
        let (x, y) = data.batch(&idx);
        let (loss, _correct) = model.forward_backward(&x, &y);
        {
            let mut params = model.params_mut();
            opt.step(&mut params);
        }
        model.zero_grads();
        if step % 10 == 0 || step + 1 == steps {
            let acc = model.accuracy(&val_x, &val_y);
            curve.seconds.push(sw.elapsed_s());
            curve.train_loss.push(loss);
            curve.val_acc.push(acc);
        }
    }
    curve
}

fn main() {
    let args = Args::from_env(false);
    let steps = args.get_usize("steps", 150).unwrap();
    let dim = args.get_usize("dim", 512).unwrap();
    let batch = args.get_usize("batch", 64).unwrap();
    let seed = args.get_u64("seed", 42).unwrap();
    let classes = 10;

    let mut rng = Rng::seed_from(seed);
    let data = BlobsDataset::generate(&mut rng, 2000, dim, classes, 1.6);
    println!(
        "shampoo_train (Fig. 5 analog): {}x{dim} blobs, {classes} classes, {steps} steps",
        data.len()
    );
    let dims = [dim, 256, 128, classes];
    println!("model: MLP {dims:?}\n");

    let curves = [
        train_one(Backend::Eigen, "eigen", &data, &dims, steps, batch, seed),
        train_one(Backend::PolarExpress, "polar-express", &data, &dims, steps, batch, seed),
        train_one(Backend::Prism5, "PRISM-5", &data, &dims, steps, batch, seed),
    ];

    println!(
        "{:<16} {:>10} {:>12} {:>12} {:>14}",
        "backend", "wall (s)", "final loss", "val acc", "acc@half-time"
    );
    let min_wall =
        curves.iter().map(|c| *c.seconds.last().unwrap()).fold(f64::INFINITY, f64::min);
    for c in &curves {
        // Accuracy reached by half the fastest backend's wall time — the
        // "training speed" view, the paper's x-axis.
        let half = c
            .seconds
            .iter()
            .position(|&s| s >= min_wall / 2.0)
            .map(|i| c.val_acc[i])
            .unwrap_or(*c.val_acc.last().unwrap());
        println!(
            "{:<16} {:>10.2} {:>12.4} {:>12.3} {:>14.3}",
            c.name,
            c.seconds.last().unwrap(),
            c.train_loss.last().unwrap(),
            c.val_acc.last().unwrap(),
            half
        );
    }
    println!("\nval-accuracy trajectories (step, acc):");
    for c in &curves {
        let pts: Vec<String> = c
            .val_acc
            .iter()
            .enumerate()
            .step_by(3)
            .map(|(i, a)| format!("({},{a:.2})", i * 10))
            .collect();
        println!("  {:<14} {}", c.name, pts.join(" "));
    }
}
