"""L2 correctness: transformer shapes, loss/grads, and trainability."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

CFG = (64, 32, 2, 4, 64)  # vocab, dim, layers, heads, mlp_dim (tiny)


def make_params(seed=0):
    return model.init_params(float(seed), *CFG)


def test_param_spec_matches_init():
    spec = model.param_spec(*CFG)
    params = make_params()
    assert len(spec) == len(params)
    for (name, shape), p in zip(spec, params):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_all_params_rank_le_2():
    for (name, shape) in model.param_spec(*CFG):
        assert len(shape) <= 2, f"{name} has rank {len(shape)}"


def test_forward_shapes_and_causality():
    params = make_params()
    tokens = jnp.zeros((2, 8), jnp.int32)
    logits = model.forward(params, tokens, CFG)
    assert logits.shape == (2, 8, CFG[0])
    # Causality: changing a future token must not affect earlier logits.
    t2 = tokens.at[0, 7].set(5)
    l2 = model.forward(params, t2, CFG)
    np.testing.assert_allclose(logits[0, :7], l2[0, :7], rtol=1e-5, atol=1e-5)
    # ... but it does affect the last position's logits distribution via
    # embedding? No — position 7's own logits change only through its input.
    assert not np.allclose(logits[0, 7], l2[0, 7])


def test_initial_loss_near_uniform():
    params = make_params()
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (4, 16), 0, CFG[0])
    y = jax.random.randint(jax.random.fold_in(key, 1), (4, 16), 0, CFG[0])
    loss = model.loss_fn(params, x, y, CFG)
    assert abs(float(loss) - np.log(CFG[0])) < 0.5


def test_train_step_returns_finite_grads():
    params = make_params()
    x = jnp.ones((2, 8), jnp.float32)
    y = jnp.ones((2, 8), jnp.float32)
    out = model.train_step(params, x, y, CFG)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    assert len(grads) == len(params)
    nonzero = 0
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.all(np.isfinite(g))
        nonzero += int(np.linalg.norm(g) > 0)
    assert nonzero >= len(params) - 1  # everything but maybe one gain


def test_few_sgd_steps_reduce_loss():
    params = list(make_params())
    key = jax.random.PRNGKey(3)
    x = jax.random.randint(key, (8, 16), 0, CFG[0]).astype(jnp.float32)
    # Learnable structure: target = input shifted by +1 mod vocab.
    y = jnp.mod(x + 1, CFG[0])
    step = jax.jit(lambda ps: model.train_step(tuple(ps), x, y, CFG))
    loss0 = float(step(params)[0])
    for _ in range(20):
        out = step(params)
        grads = out[1:]
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    loss1 = float(step(params)[0])
    assert loss1 < loss0 - 0.3, f"{loss0} -> {loss1}"


def test_polar_residual_traces_shapes():
    x = jax.random.normal(jax.random.PRNGKey(1), (48, 24)) / 7.0
    s = jax.random.normal(jax.random.PRNGKey(2), (4, 24)) / 2.0
    t, fro = model.polar_residual_traces(x, s, q=6)
    assert t.shape == (6,)
    assert np.isfinite(np.asarray(t)).all()
    # fro must equal ‖I − XᵀX‖_F
    r = np.eye(24) - np.asarray(x).T @ np.asarray(x)
    np.testing.assert_allclose(float(fro), np.linalg.norm(r), rtol=1e-4)
