"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py,
swept over shapes and dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ns_update, residual, sketch_traces, ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(jax.random.PRNGKey(key), shape)).astype(dtype)


dims = st.sampled_from([4, 8, 16, 24, 32, 48, 64, 96, 128, 160])
small_dims = st.sampled_from([4, 8, 16, 32, 64])
alphas = st.floats(min_value=0.375, max_value=1.45)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=small_dims, a=alphas, seed=seeds)
def test_ns_update_d1_matches_ref(m, n, a, seed):
    x = rand(seed, (m, n))
    r = rand(seed + 1, (n, n), scale=0.3)
    got = ns_update.ns_update_d1(x, r, a)
    want = ref.ns_update_d1_ref(x, r, a)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=small_dims, a=alphas, seed=seeds)
def test_ns_update_d2_matches_ref(m, n, a, seed):
    x = rand(seed, (m, n))
    r = rand(seed + 2, (n, n), scale=0.3)
    got = ns_update.ns_update_d2(x, r, a)
    want = ref.ns_update_d2_ref(x, r, a)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(n=small_dims, a=alphas, seed=seeds)
def test_poly_d2_matches_ref(n, a, seed):
    r = rand(seed, (n, n), scale=0.5)
    got = ns_update.poly_d2(r, a)
    want = ref.poly_d2_ref(r, a)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=small_dims, seed=seeds)
def test_residual_polar_matches_ref(m, n, seed):
    x = rand(seed, (m, n), scale=1.0 / np.sqrt(m))
    got = residual.residual_polar(x)
    want = ref.residual_polar_ref(x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(n=small_dims, seed=seeds)
def test_residual_coupled_matches_ref(n, seed):
    y = rand(seed, (n, n), scale=0.3)
    x = rand(seed + 1, (n, n), scale=0.3)
    got = residual.residual_coupled(y, x)
    want = ref.residual_coupled_ref(y, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(m=small_dims, k=small_dims, n=small_dims, seed=seeds)
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k))
    y = rand(seed + 3, (k, n))
    got = ns_update.matmul(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(n=small_dims, p=st.sampled_from([4, 8]), q=st.sampled_from([6, 10]), seed=seeds)
def test_sketch_traces_match_ref(n, p, q, seed):
    r = rand(seed, (n, n), scale=0.2)
    r = 0.5 * (r + r.T)
    s = rand(seed + 4, (p, n), scale=1.0 / np.sqrt(p))
    got = sketch_traces.sketch_traces(s, r, q)
    want = ref.sketch_traces_ref(s, r, q)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_odd_tile_sizes():
    """Shapes that don't divide 128 exercise the tile-shrink path."""
    x = rand(0, (100, 36))
    r = rand(1, (36, 36), scale=0.3)
    got = ns_update.ns_update_d1(x, r, 0.7)
    want = ref.ns_update_d1_ref(x, r, 0.7)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_polar_step_composition_converges():
    """Iterating the full Pallas polar step orthogonalizes a random matrix —
    kernel-level end-to-end sanity."""
    from compile import model

    x = rand(7, (64, 32), scale=1.0)
    x = x / jnp.linalg.norm(x)
    for _ in range(30):
        x = model.polar_step_d2(x, 1.0)
    g = x.T @ x
    np.testing.assert_allclose(g, np.eye(32), rtol=0, atol=1e-3)


def test_bf16_inputs_accumulate_in_f32():
    """MXU-style mixed precision: bf16 operands, f32 accumulation."""
    x = rand(9, (32, 16)).astype(jnp.bfloat16)
    r = rand(10, (16, 16), scale=0.3).astype(jnp.bfloat16)
    got = ns_update.ns_update_d1(x, r, 0.5)
    assert got.dtype == jnp.bfloat16
    want = ref.ns_update_d1_ref(x.astype(jnp.float32), r.astype(jnp.float32), 0.5)
    np.testing.assert_allclose(got.astype(jnp.float32), want, rtol=5e-2, atol=5e-2)
