"""AOT pipeline: lowering produces valid HLO text + a coherent manifest."""

import json
import os

import jax
import pytest

from compile import aot, model


def test_to_hlo_text_roundtrippable():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), "float32")
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_build_artifacts(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build_artifacts(out)
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    names = {a["name"] for a in manifest["artifacts"]}
    assert {"init_params", "train_step", "polar_step_d2", "polar_step_d1",
            "polar_residual_traces"} <= names
    for a in manifest["artifacts"]:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            head = f.read(4096)
        assert "ENTRY" in head or "HloModule" in head
    # train_step signature: params + 2 token tensors in, loss + grads out.
    ts = next(a for a in manifest["artifacts"] if a["name"] == "train_step")
    nparams = len(model.param_spec(aot.VOCAB, aot.DIM, aot.LAYERS, aot.HEADS,
                                   aot.MLP_DIM))
    assert len(ts["inputs"]) == nparams + 2
    assert len(ts["outputs"]) == nparams + 1
    assert ts["meta"]["batch"] == aot.BATCH


@pytest.mark.slow
def test_artifact_numerics_vs_jit(tmp_path):
    """The lowered polar_step_d2 HLO computes the same thing as the jitted
    python function (executed through jax itself here; the Rust integration
    test re-executes through PJRT-rust)."""
    import numpy as np
    x = np.random.RandomState(0).randn(aot.POLAR_M, aot.POLAR_N).astype("float32")
    x /= np.linalg.norm(x)
    want = model.polar_step_d2(x, 1.0)
    got = jax.jit(model.polar_step_d2)(x, 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
