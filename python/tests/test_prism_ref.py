"""PRISM algorithm-level tests in Python: the α-fitting machinery and the
full reference iteration (the same formulas the Rust engines implement).

These pin down the *math* independently of any substrate:
  * the closed-form quartic coefficients of m(α) match direct evaluation,
  * the cubic-root minimiser matches a dense grid search,
  * the sketched fit matches the exact fit for small p (Theorem 2),
  * PRISM converges, and no slower than classic NS (Theorem 1),
  * the α trace starts at the upper bound and decays to the Taylor
    coefficient (the Figs. 3/4 fingerprint).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import prism_ref
from compile.kernels import ref

seeds = st.integers(min_value=0, max_value=2**31 - 1)


def spectrum_matrix(rng, m, n, smin):
    """m x n matrix with log-spaced singular values in [smin, 1]."""
    s = np.logspace(np.log10(smin), 0, n)
    u, _ = np.linalg.qr(rng.randn(m, n))
    v, _ = np.linalg.qr(rng.randn(n, n))
    return (u * s) @ v.T


@settings(max_examples=10, deadline=None)
@given(seed=seeds, d=st.sampled_from([1, 2]))
def test_quartic_coeffs_match_direct_evaluation(seed, d):
    """m(α) from the closed-form c's must equal ‖S(I − XᵀX g²)‖²_F − c₀."""
    rng = np.random.RandomState(seed)
    n, p = 12, 6
    x = jnp.asarray(rng.randn(2 * n, n) / (3 * n), jnp.float32)
    s = jnp.asarray(rng.randn(p, n).astype(np.float32))
    r = ref.residual_polar_ref(x)
    q = 4 * d + 2
    t = np.asarray(ref.sketch_traces_ref(s, r, q), dtype=np.float64)
    if d == 1:
        c1, c2, c3, c4 = prism_ref.quartic_coeffs_d1(t)
    else:
        c1, c2, c3, c4 = prism_ref.quartic_coeffs_d2(t)

    rn = np.asarray(r, np.float64)
    sn = np.asarray(s, np.float64)
    eye = np.eye(n)

    def m_direct(a):
        g = eye + a * rn if d == 1 else eye + 0.5 * rn + a * rn @ rn
        inner = eye - (eye - rn) @ g @ g  # I − XᵀX g² with XᵀX = I − R
        return np.linalg.norm(sn @ inner) ** 2

    m0 = m_direct(0.0)
    for a in [0.4, 0.7, 1.0, 1.3]:
        want = m_direct(a) - m0
        got = c1 * a + c2 * a * a + c3 * a**3 + c4 * a**4
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(seed=seeds)
def test_cubic_minimizer_matches_grid(seed):
    rng = np.random.RandomState(seed)
    c = rng.randn(4)
    lo, hi = 0.375, 1.45
    got = prism_ref.minimize_quartic(*c, lo, hi)
    grid = np.linspace(lo, hi, 20001)
    m = c[0] * grid + c[1] * grid**2 + c[2] * grid**3 + c[3] * grid**4
    want = grid[np.argmin(m)]
    mv = lambda a: c[0] * a + c[1] * a * a + c[2] * a**3 + c[3] * a**4
    # The analytic argmin must be at least as good as the grid argmin.
    assert mv(got) <= mv(want) + 1e-9


@pytest.mark.parametrize("d", [1, 2])
def test_sketched_alpha_close_to_exact(d):
    """Theorem 2 in action: p = 8 sketch ≈ exact fit."""
    rng = np.random.RandomState(0)
    a = spectrum_matrix(rng, 32, 16, 1e-3)
    x = jnp.asarray(a / np.linalg.norm(a), jnp.float32)
    exact = prism_ref.fit_alpha_exact(x, d)
    diffs = []
    for seed in range(5):
        s = jnp.asarray(
            np.random.RandomState(100 + seed).randn(8, 16) / np.sqrt(8), jnp.float32
        )
        diffs.append(abs(prism_ref.fit_alpha(x, s, d) - exact))
    lo, hi = prism_ref.ALPHA_INTERVAL[d]
    assert np.median(diffs) < 0.25 * (hi - lo), (exact, diffs)


@pytest.mark.parametrize("smin", [1e-2, 1e-4, 1e-6])
def test_prism_no_slower_than_classic(smin):
    """Theorem 1: PRISM needs no more iterations than classic NS."""
    rng = np.random.RandomState(1)
    a = spectrum_matrix(rng, 48, 24, smin)
    _, res_c = prism_ref.polar_classic_ref(a, d=2, iters=120, tol=1e-6)
    _, res_p, _ = prism_ref.polar_prism_ref(a, d=2, iters=120, tol=1e-6, seed=2)
    assert res_p[-1] < 1e-6
    assert len(res_p) <= len(res_c), (len(res_p), len(res_c))


def test_prism_converges_to_svd_polar_factor():
    rng = np.random.RandomState(3)
    a = spectrum_matrix(rng, 40, 20, 1e-4)
    x, res, _ = prism_ref.polar_prism_ref(a, d=2, iters=100, tol=1e-9, seed=4)
    u, _, vt = np.linalg.svd(a, full_matrices=False)
    np.testing.assert_allclose(np.asarray(x), u @ vt, rtol=0, atol=5e-4)


def test_alpha_trace_fingerprint():
    """α starts pinned at the upper bound, ends at the Taylor coefficient."""
    rng = np.random.RandomState(5)
    a = spectrum_matrix(rng, 64, 32, 1e-6)
    _, _, alphas = prism_ref.polar_prism_ref(a, d=2, iters=100, tol=1e-9, seed=6)
    lo, hi = prism_ref.ALPHA_INTERVAL[2]
    assert alphas[0] > hi - 0.05, alphas[:3]
    assert abs(alphas[-1] - lo) < 0.05, alphas[-3:]


def test_exact_and_sketched_iterations_agree():
    """Full runs with exact vs sketched α land within an iteration or two."""
    rng = np.random.RandomState(7)
    a = spectrum_matrix(rng, 36, 18, 1e-4)
    # tol well above the f32 noise floor (≈1e-7 at this size).
    _, res_e, _ = prism_ref.polar_prism_ref(a, d=2, iters=80, tol=1e-6, exact=True)
    _, res_s, _ = prism_ref.polar_prism_ref(a, d=2, iters=80, tol=1e-6, seed=8)
    assert abs(len(res_e) - len(res_s)) <= 2


def test_monotone_residual_decay():
    rng = np.random.RandomState(9)
    a = spectrum_matrix(rng, 48, 24, 1e-5)
    for d in (1, 2):
        _, res, _ = prism_ref.polar_prism_ref(a, d=d, iters=120, tol=1e-8, seed=10)
        for r0, r1 in zip(res, res[1:]):
            if r0 < 1e-5:
                break  # below this the f32 noise floor dominates
            assert r1 <= r0 * 1.05, (d, r0, r1)
