"""L1 Pallas kernels (build-time only; lowered into the AOT artifacts)."""
