"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact counterpart here; pytest +
hypothesis sweep shapes and dtypes asserting allclose between the two.
These references are also the L2 building blocks wherever a differentiable
path is required (pallas_call has no default VJP).
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain matmul in f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def residual_polar_ref(x):
    """R = I - XᵀX for a (possibly rectangular) iterate X: m x n."""
    n = x.shape[1]
    return jnp.eye(n, dtype=x.dtype) - matmul_ref(x.T, x)


def residual_coupled_ref(y, x):
    """R = I - Y X (coupled square-root residual, Higham-stable pairing)."""
    n = x.shape[1]
    return jnp.eye(n, dtype=x.dtype) - matmul_ref(y, x)


def ns_update_d1_ref(x, r, alpha):
    """X · (I + αR) = X + α (X @ R)."""
    return x + alpha * matmul_ref(x, r)


def poly_d2_ref(r, alpha):
    """W = R/2 + α R² (so the d=2 update is X + X @ W)."""
    return 0.5 * r + alpha * matmul_ref(r, r)


def ns_update_d2_ref(x, r, alpha):
    """X · (I + R/2 + αR²)."""
    return x + matmul_ref(x, poly_d2_ref(r, alpha))


def sketch_traces_ref(s, r, q):
    """[tr(S R^i Sᵀ) for i in 1..q] computed right-to-left in O(n²p)."""
    y = s.T
    out = []
    for _ in range(q):
        y = matmul_ref(r, y)
        out.append(jnp.sum(s.T * y))
    return jnp.stack(out)


def polar_step_d2_ref(x, alpha):
    """One full PRISM-5 polar iteration at a given α (the AOT artifact's
    semantics): R = I − XᵀX, X ← X(I + R/2 + αR²)."""
    r = residual_polar_ref(x)
    return ns_update_d2_ref(x, r, alpha)
