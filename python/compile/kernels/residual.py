"""Pallas kernels for the residual matrices.

`R = I − XᵀX` (polar) and `R = I − Y X` (coupled square root). The identity
subtraction is fused into the matmul tile epilogue: the diagonal test uses
the grid coordinates, so no identity matrix ever exists in HBM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ns_update import _tile


def residual_polar(x, bm=128, bn=128):
    """R = I − XᵀX. x: (m, n) → (n, n)."""
    m, n = x.shape
    bm_ = _tile(n, bm)
    bn_ = _tile(n, bn)

    def kernel(xi_ref, xj_ref, o_ref):
        # xi: (m, bm) panel of X columns i; xj: (m, bn) panel of columns j.
        acc = jnp.dot(
            xi_ref[...].T, xj_ref[...], preferred_element_type=jnp.float32
        )
        i = pl.program_id(0)
        j = pl.program_id(1)
        rows = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0) + i * acc.shape[0]
        cols = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1) + j * acc.shape[1]
        eye = (rows == cols).astype(acc.dtype)
        o_ref[...] = (eye - acc).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        grid=(n // bm_, n // bn_),
        in_specs=[
            pl.BlockSpec((m, bm_), lambda i, j: (0, i)),
            pl.BlockSpec((m, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        interpret=True,
    )(x, x)


def residual_coupled(y, x, bm=128, bn=128):
    """R = I − Y X. y: (n, n), x: (n, n) → (n, n)."""
    n = x.shape[0]
    bm_ = _tile(n, bm)
    bn_ = _tile(n, bn)

    def kernel(y_ref, x_ref, o_ref):
        acc = jnp.dot(y_ref[...], x_ref[...], preferred_element_type=jnp.float32)
        i = pl.program_id(0)
        j = pl.program_id(1)
        rows = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 0) + i * acc.shape[0]
        cols = jax.lax.broadcasted_iota(jnp.int32, acc.shape, 1) + j * acc.shape[1]
        eye = (rows == cols).astype(acc.dtype)
        o_ref[...] = (eye - acc).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        grid=(n // bm_, n // bn_),
        in_specs=[
            pl.BlockSpec((bm_, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        interpret=True,
    )(y, x)
