"""Sketched power traces `T_i = tr(S R^i Sᵀ)` assembled from the Pallas
matmul kernel.

The O(n²p) schedule is the paper's: carry `Y ← R @ Y` (n×p panel, p ≪ n —
the panel stays resident in VMEM across the whole sweep) and reduce
`tr(S Y) = Σ_{k,j} Sᵀ[k,j]·Y[k,j]` per power. The sequential i-loop is a
`lax.scan`-free Python loop — q is a small compile-time constant (6 for d=1,
10 for d=2) so unrolling into the HLO is the right call.
"""

import jax.numpy as jnp

from .ns_update import matmul


def sketch_traces(s, r, q):
    """s: (p, n) sketch, r: (n, n) symmetric residual → (q,) traces."""
    st = s.T  # n x p
    y = st
    out = []
    for _ in range(q):
        y = matmul(r, y)  # Pallas tiled matmul
        out.append(jnp.sum(st * y))
    return jnp.stack(out)
