"""Pallas kernels for the Newton–Schulz update — the paper's compute
hot-spot, tiled for TPU VMEM.

Hardware adaptation (paper targets A100 GPUs): instead of CUDA threadblocks
and shared memory we express the HBM↔VMEM schedule with a grid + BlockSpecs.
Each (i, j) grid cell streams K-panels of the operands into VMEM, accumulates
on the MXU (jnp.dot inside the kernel) in f32, and fuses the elementwise
epilogue (+X, ×α) into the same tile pass — one fewer HBM round-trip than an
unfused matmul+axpy, exactly the fusion the paper's GPU kernels get from
cuBLAS epilogues.

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that both the
python tests and the Rust runtime execute bit-identically.

VMEM budget at the default 128-tile: 3 f32 tiles (x, r, acc) = 3·128²·4 B ≈
196 KiB, far under the ~16 MiB/core budget; see DESIGN.md §Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile(dim, pref):
    """Largest tile ≤ pref that divides dim (shapes here are moderate; for
    production TPU use pad-to-128 instead)."""
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


def ns_update_d1(x, r, alpha, bm=128, bn=128):
    """X(I + αR) with a fused Pallas kernel. x: (m, n), r: (n, n), alpha: scalar."""
    m, n = x.shape
    assert r.shape == (n, n)
    bm = _tile(m, bm)
    bn = _tile(n, bn)
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    def kernel(x_ref, xrow_ref, r_ref, a_ref, o_ref):
        acc = jnp.dot(xrow_ref[...], r_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] = (x_ref[...] + a_ref[0, 0] * acc).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),   # x tile (epilogue add)
            pl.BlockSpec((bm, n), lambda i, j: (i, 0)),    # full x row panel
            pl.BlockSpec((n, bn), lambda i, j: (0, j)),    # r column panel
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),     # alpha
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, x, r, alpha_arr)


def poly_d2(r, alpha, bm=128, bn=128):
    """W = R/2 + α R² with a fused epilogue. r: (n, n)."""
    n = r.shape[0]
    bm_ = _tile(n, bm)
    bn_ = _tile(n, bn)
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    def kernel(rt_ref, rrow_ref, rcol_ref, a_ref, o_ref):
        acc = jnp.dot(rrow_ref[...], rcol_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] = (0.5 * rt_ref[...] + a_ref[0, 0] * acc).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), r.dtype),
        grid=(n // bm_, n // bn_),
        in_specs=[
            pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
            pl.BlockSpec((bm_, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, bn_), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        interpret=True,
    )(r, r, r, alpha_arr)


def ns_update_d2(x, r, alpha):
    """X(I + R/2 + αR²) = X + X @ (R/2 + αR²): two fused Pallas passes."""
    w = poly_d2(r, alpha)
    one = jnp.asarray(1.0, jnp.float32)
    return ns_update_d1(x, w, one)  # X + 1.0 · (X @ W)


def matmul(x, y, bm=128, bn=128):
    """Plain tiled Pallas matmul (used by the sketch-trace artifact)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm = _tile(m, bm)
    bn = _tile(n, bn)

    def kernel(x_ref, y_ref, o_ref):
        o_ref[...] = jnp.dot(
            x_ref[...], y_ref[...], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, y)
