"""L2: the JAX model and PRISM iteration steps that get AOT-lowered.

* A decoder-only transformer LM (the Fig. 6 model, scaled for CPU): all
  parameters are rank ≤ 2 so the Rust optimizer can treat each as one
  matrix/vector (heads are reshaped internally).
* The PRISM polar step + sketched-trace computation assembled from the
  Pallas kernels in `kernels/` — these lower into the same HLO artifacts
  the Rust hot path executes.

The train_step (fwd+bwd) uses pure-jnp ops (pallas_call has no default VJP);
the PRISM artifacts use the Pallas kernels directly (forward-only).
"""

import jax
import jax.numpy as jnp

from .kernels import ns_update, residual, sketch_traces


# ------------------------------------------------------------ transformer --

def param_spec(vocab, dim, layers, heads, mlp_dim):
    """Ordered (name, shape) list — the contract with the Rust TrainDriver."""
    del heads
    spec = [("embed", (vocab, dim))]
    for l in range(layers):
        spec += [
            (f"l{l}.ln1_g", (dim,)),
            (f"l{l}.wq", (dim, dim)),
            (f"l{l}.wk", (dim, dim)),
            (f"l{l}.wv", (dim, dim)),
            (f"l{l}.wo", (dim, dim)),
            (f"l{l}.ln2_g", (dim,)),
            (f"l{l}.w1", (dim, mlp_dim)),
            (f"l{l}.w2", (mlp_dim, dim)),
        ]
    spec += [("ln_f_g", (dim,))]
    return spec


def init_params(seed, vocab, dim, layers, heads, mlp_dim):
    """Initialise parameters from a scalar seed (f32, traced)."""
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.float32).astype(jnp.int32))
    spec = param_spec(vocab, dim, layers, heads, mlp_dim)
    params = []
    for i, (name, shape) in enumerate(spec):
        k = jax.random.fold_in(key, i)
        if name.endswith(("_g",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name == "embed":
            params.append(0.02 * jax.random.normal(k, shape, jnp.float32))
        else:
            fan_in = shape[0]
            params.append(
                jax.random.normal(k, shape, jnp.float32) * (1.0 / jnp.sqrt(fan_in))
            )
    return tuple(params)


def _rmsnorm(x, g):
    return x * g / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def forward(params, tokens, cfg):
    """tokens: int32 [B, T] → logits [B, T, V]."""
    vocab, dim, layers, heads, mlp_dim = cfg
    it = iter(params)
    embed = next(it)
    x = embed[tokens]  # [B, T, D]
    t = tokens.shape[1]
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    dh = dim // heads
    for _ in range(layers):
        ln1_g = next(it)
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        ln2_g = next(it)
        w1, w2 = next(it), next(it)
        h = _rmsnorm(x, ln1_g)
        q = (h @ wq).reshape(*h.shape[:-1], heads, dh)
        k = (h @ wk).reshape(*h.shape[:-1], heads, dh)
        v = (h @ wv).reshape(*h.shape[:-1], heads, dh)
        att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(dh)
        att = jnp.where(causal[None, None, :, :] > 0, att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", att, v).reshape(*h.shape[:-1], dim)
        x = x + o @ wo
        h2 = _rmsnorm(x, ln2_g)
        x = x + jax.nn.gelu(h2 @ w1) @ w2
    ln_f_g = next(it)
    x = _rmsnorm(x, ln_f_g)
    return x @ embed.T  # tied unembedding


def loss_fn(params, tokens_x, tokens_y, cfg):
    logits = forward(params, tokens_x, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tokens_y[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(params, tokens_x_f, tokens_y_f, cfg):
    """AOT entrypoint: f32 token buffers (the Rust side has one buffer type),
    cast to int32 inside. Returns (loss, *grads)."""
    tx = tokens_x_f.astype(jnp.int32)
    ty = tokens_y_f.astype(jnp.int32)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tx, ty, cfg))(params)
    return (loss, *grads)


# ----------------------------------------------------------- PRISM steps --

def polar_step_d2(x, alpha):
    """One PRISM-5 polar iteration (Pallas kernels): R = I − XᵀX,
    X ← X(I + R/2 + αR²). α comes from the Rust-side sketch fit."""
    r = residual.residual_polar(x)
    return ns_update.ns_update_d2(x, r, alpha)


def polar_step_d1(x, alpha):
    """One PRISM-3 polar iteration (Pallas kernels)."""
    r = residual.residual_polar(x)
    return ns_update.ns_update_d1(x, r, alpha)


def polar_residual_traces(x, s, q=10):
    """R = I − XᵀX plus its sketched power traces (Pallas): everything the
    Rust coordinator needs to pick α for the *next* step in one call."""
    r = residual.residual_polar(x)
    t = sketch_traces.sketch_traces(s, r, q)
    fro = jnp.sqrt(jnp.sum(r * r))
    return t, fro
