"""Reference PRISM polar iteration in pure JAX — the cross-language oracle.

Mirrors the Rust `prism::polar` engine coefficient-for-coefficient:

* residual `R = I − XᵀX` (Pallas kernel in the compiled path),
* sketched power traces `T_i = tr(S R^i Sᵀ)` for i ≤ 4d+2,
* closed-form quartic coefficients `c₁..c₄` of `m(α)` (paper §A.1),
* constrained minimisation of `m(α)` on `[ℓ, u]` by solving the cubic
  `m'(α) = 0` (numpy roots — build-time only, never in the hot path),
* the update `X ← X·g_d(R; α)`.

Used by pytest to validate both the Pallas kernels *and* the Rust
implementation (the Rust integration tests execute the AOT artifact built
from these same formulas and compare iteration-for-iteration).
"""

import jax.numpy as jnp
import numpy as np

from .kernels import ref

# α constraint intervals per degree (paper: [1/2,1] for d=1 from Thm 1;
# [3/8, 29/20] for d=2, found empirically).
ALPHA_INTERVAL = {1: (0.5, 1.0), 2: (0.375, 1.45)}


def quartic_coeffs_d1(t):
    """c₁..c₄ of m(α) for d=1 from traces t[i-1] = tr(S R^i Sᵀ) (§A.1)."""
    t1, t2, t3, t4, t5, t6 = t[:6]
    del t1
    c1 = 4.0 * t3 - 4.0 * t2
    c2 = 6.0 * t4 - 10.0 * t3 + 4.0 * t2
    c3 = 4.0 * t5 - 8.0 * t4 + 4.0 * t3
    c4 = t6 - 2.0 * t5 + t4
    return c1, c2, c3, c4


def quartic_coeffs_d2(t):
    """c₁..c₄ of m(α) for d=2; needs traces up to R¹⁰ (§A.1)."""
    (t4, t5, t6, t7, t8, t9, t10) = t[3:10]
    c1 = 0.5 * t7 + 2.0 * t6 + 0.5 * t5 - 3.0 * t4
    c2 = 1.5 * t8 + 3.0 * t7 - 4.5 * t6 - 4.0 * t5 + 4.0 * t4
    c3 = 2.0 * t9 - 6.0 * t7 + 4.0 * t6
    c4 = t10 - 2.0 * t9 + t8
    return c1, c2, c3, c4


def minimize_quartic(c1, c2, c3, c4, lo, hi):
    """argmin over [lo, hi] of c₁α + c₂α² + c₃α³ + c₄α⁴ via m'(α) = 0."""
    # m'(α) = c1 + 2 c2 α + 3 c3 α² + 4 c4 α³.
    roots = np.roots([4.0 * c4, 3.0 * c3, 2.0 * c2, c1])
    cands = [lo, hi] + [
        float(r.real) for r in roots if abs(r.imag) < 1e-9 and lo <= r.real <= hi
    ]
    m = lambda a: c1 * a + c2 * a * a + c3 * a**3 + c4 * a**4
    return min(cands, key=m)


def fit_alpha(x, s, d):
    """PRISM Step 5: fit α for iterate x using sketch s (p × n)."""
    r = ref.residual_polar_ref(x)
    q = 4 * d + 2
    t = np.asarray(ref.sketch_traces_ref(s, r, q), dtype=np.float64)
    lo, hi = ALPHA_INTERVAL[d]
    if d == 1:
        c = quartic_coeffs_d1(t)
    else:
        c = quartic_coeffs_d2(t)
    return minimize_quartic(*c, lo, hi)


def fit_alpha_exact(x, d, grid=2001):
    """PRISM Step 4 by brute force: dense grid over the exact objective
    m(α) = ‖I − Xᵀ X g(R;α)²‖²_F (test oracle — O(n³) per grid point
    avoided by eigenvalues)."""
    r = np.asarray(ref.residual_polar_ref(x), dtype=np.float64)
    lam = np.linalg.eigvalsh(r)
    lo, hi = ALPHA_INTERVAL[d]
    alphas = np.linspace(lo, hi, grid)
    best, best_v = lo, np.inf
    for a in alphas:
        if d == 1:
            g = 1.0 + a * lam
        else:
            g = 1.0 + 0.5 * lam + a * lam * lam
        v = np.sum((1.0 - (1.0 - lam) * g * g) ** 2)
        if v < best_v:
            best, best_v = a, v
    return best


def polar_prism_ref(a, d=2, iters=40, p=8, tol=1e-8, seed=0, exact=False):
    """Full PRISM polar iteration; returns (X, residual history, α history)."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(a, jnp.float32)
    x = x / jnp.linalg.norm(x)
    n = x.shape[1]
    res, alphas = [], []
    for _ in range(iters):
        r = ref.residual_polar_ref(x)
        rn = float(jnp.linalg.norm(r))
        res.append(rn)
        if rn < tol:
            break
        if exact:
            alpha = fit_alpha_exact(x, d)
        else:
            s = jnp.asarray(rng.randn(p, n) / np.sqrt(p), jnp.float32)
            alpha = fit_alpha(x, s, d)
        alphas.append(alpha)
        if d == 1:
            x = ref.ns_update_d1_ref(x, r, alpha)
        else:
            x = ref.ns_update_d2_ref(x, r, alpha)
    return x, res, alphas


def polar_classic_ref(a, d=2, iters=40, tol=1e-8):
    """Classical Newton–Schulz (Taylor α: 1/2 for d=1, 3/8 for d=2)."""
    x = jnp.asarray(a, jnp.float32)
    x = x / jnp.linalg.norm(x)
    taylor = {1: 0.5, 2: 0.375}[d]
    res = []
    for _ in range(iters):
        r = ref.residual_polar_ref(x)
        rn = float(jnp.linalg.norm(r))
        res.append(rn)
        if rn < tol:
            break
        if d == 1:
            x = ref.ns_update_d1_ref(x, r, taylor)
        else:
            x = ref.ns_update_d2_ref(x, r, taylor)
    return x, res
