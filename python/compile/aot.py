"""AOT lowering: jit → StableHLO → XlaComputation → **HLO text** + manifest.

HLO text (not `.serialize()`): jax ≥ 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the Rust `xla` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.

Run `python -m compile.aot --out-dir ../artifacts` from `python/` (this is
what `make artifacts` does). Python never runs after this point — the Rust
binary executes the artifacts via PJRT.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# The Fig. 6 model configuration (CPU-scaled; see DESIGN.md substitutions).
VOCAB = 256
DIM = 128
LAYERS = 2
HEADS = 4
MLP_DIM = 256
BATCH = 8
SEQ_LEN = 32
CFG = (VOCAB, DIM, LAYERS, HEADS, MLP_DIM)

# Default PRISM polar artifact shape (Muon-sized gradient matrix).
POLAR_M = 256
POLAR_N = 128
SKETCH_P = 8
TRACE_Q = 10


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def tensor_entries(named_shapes):
    return [
        {"name": n, "shape": list(s), "dtype": "f32"} for (n, s) in named_shapes
    ]


def build_artifacts(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}

    def emit(name, lowered, inputs, outputs, meta=None):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": tensor_entries(inputs),
                "outputs": tensor_entries(outputs),
                "meta": meta or {},
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    pspec = model.param_spec(VOCAB, DIM, LAYERS, HEADS, MLP_DIM)

    # ---- init_params(seed) -> params ------------------------------------
    init_fn = functools.partial(
        model.init_params, vocab=VOCAB, dim=DIM, layers=LAYERS, heads=HEADS,
        mlp_dim=MLP_DIM,
    )
    lowered = jax.jit(init_fn).lower(spec(()))
    emit(
        "init_params",
        lowered,
        inputs=[("seed", ())],
        outputs=[(f"param.{n}", s) for (n, s) in pspec],
        meta={"vocab": VOCAB, "dim": DIM, "layers": LAYERS, "heads": HEADS,
              "mlp_dim": MLP_DIM},
    )

    # ---- train_step(params..., x, y) -> (loss, grads...) ----------------
    def step_fn(*args):
        params = args[:-2]
        return model.train_step(params, args[-2], args[-1], CFG)

    arg_specs = [spec(s) for (_, s) in pspec] + [
        spec((BATCH, SEQ_LEN)),
        spec((BATCH, SEQ_LEN)),
    ]
    lowered = jax.jit(step_fn).lower(*arg_specs)
    emit(
        "train_step",
        lowered,
        inputs=[(f"param.{n}", s) for (n, s) in pspec]
        + [("tokens_x", (BATCH, SEQ_LEN)), ("tokens_y", (BATCH, SEQ_LEN))],
        outputs=[("loss", ())] + [(f"grad.{n}", s) for (n, s) in pspec],
        meta={"batch": BATCH, "seq_len": SEQ_LEN, "vocab": VOCAB},
    )

    # ---- PRISM polar steps (Pallas kernels) ------------------------------
    lowered = jax.jit(model.polar_step_d2).lower(
        spec((POLAR_M, POLAR_N)), spec(())
    )
    emit(
        "polar_step_d2",
        lowered,
        inputs=[("x", (POLAR_M, POLAR_N)), ("alpha", ())],
        outputs=[("x_next", (POLAR_M, POLAR_N))],
        meta={"d": 2, "alpha_lo": 0.375, "alpha_hi": 1.45},
    )

    lowered = jax.jit(model.polar_step_d1).lower(
        spec((POLAR_M, POLAR_N)), spec(())
    )
    emit(
        "polar_step_d1",
        lowered,
        inputs=[("x", (POLAR_M, POLAR_N)), ("alpha", ())],
        outputs=[("x_next", (POLAR_M, POLAR_N))],
        meta={"d": 1, "alpha_lo": 0.5, "alpha_hi": 1.0},
    )

    # ---- residual + sketched traces (Pallas) ------------------------------
    lowered = jax.jit(
        functools.partial(model.polar_residual_traces, q=TRACE_Q)
    ).lower(spec((POLAR_M, POLAR_N)), spec((SKETCH_P, POLAR_N)))
    emit(
        "polar_residual_traces",
        lowered,
        inputs=[("x", (POLAR_M, POLAR_N)), ("s", (SKETCH_P, POLAR_N))],
        outputs=[("traces", (TRACE_Q,)), ("fro", ())],
        meta={"q": TRACE_Q, "p": SKETCH_P},
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    # legacy single-file interface used by early Makefile revisions
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    print(f"AOT-lowering artifacts into {out_dir}")
    build_artifacts(out_dir or ".")


if __name__ == "__main__":
    main()
