//! `pallas-lint`: repo-specific static checks, run as `cargo xtask lint`.
//!
//! Four rules the stock clippy cannot express, each tied to a contract the
//! crate's docs promise (see CONTRIBUTING.md for the rationale and the
//! waiver syntax):
//!
//! * **R1** — no `.lock().unwrap()` outside `util::lock_or_recover` (and
//!   the `runtime/sync` layer itself). A panicking lock holder must degrade
//!   into typed error results, not cascade poison panics through the
//!   service.
//! * **R2** — every `unsafe` keyword carries a nearby `// SAFETY:` comment
//!   (a `# Safety` doc section also counts) stating the discharged
//!   obligations.
//! * **R3** — files tagged `#![doc = "hot-path"]` contain no allocating
//!   constructors (`Mat::zeros`, `Vec::with_capacity`, `vec![`) or
//!   allocating matmuls (`.matmul(`): the engine cores' allocation-free
//!   contract, checked at the source level instead of only by runtime
//!   workspace counters.
//! * **R4** — the migrated concurrency modules import sync primitives from
//!   `crate::runtime::sync`, never `std::sync` directly, so the
//!   `--cfg loom` build really models every lock they take.
//!
//! A finding on line N is waived by `pallas-lint: allow(R#)` in a comment
//! on line N or N-1. The linter is a hand-rolled comment/string-aware
//! scanner (the workspace is dependency-free by design — no `syn`); it
//! walks `rust/src/**/*.rs` only. Tests, benches and examples may use
//! plain `std::sync` freely.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files that must route every sync primitive through `crate::runtime::sync`
/// (rule R4). Matched as path suffixes against the walked file paths.
const MIGRATED: &[&str] = &[
    "coordinator/service.rs",
    "coordinator/schedule.rs",
    "coordinator/supervise.rs",
    "coordinator/gate.rs",
    "threads.rs",
    "metrics.rs",
    "runtime/faultinject.rs",
];

/// Tokens banned in hot-path-tagged files (rule R3).
const R3_BANNED: &[&str] = &[
    "Mat::zeros",
    "Mat32::zeros",
    "Vec::with_capacity",
    "vec![",
    ".matmul(",
];

/// The `#![doc = ...]` marker that opts a file into rule R3.
const HOT_PATH_TAG: &str = "#![doc = \"hot-path\"]";

/// How many lines above an `unsafe` keyword a SAFETY comment may sit
/// (covers a `# Safety` doc section followed by `cfg`/`target_feature`
/// attributes).
const R2_WINDOW: usize = 12;

// One-line messages; CONTRIBUTING.md carries the full story per rule.
const R1_MSG: &str = "`.lock().unwrap()` — use `util::lock_or_recover`";
const R2_MSG: &str = "`unsafe` without a nearby `// SAFETY:` comment";
const R4_MSG: &str = "`std::sync` in a migrated module — use `crate::runtime::sync`";

#[derive(Debug)]
struct Finding {
    path: String,
    /// 1-based line number.
    line: usize,
    rule: &'static str,
    msg: String,
}

impl Finding {
    fn new(path: &str, line: usize, rule: &'static str, msg: impl Into<String>) -> Self {
        Finding {
            path: path.to_string(),
            line,
            rule,
            msg: msg.into(),
        }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let Finding { path, line, rule, msg } = self;
        write!(f, "{path}:{line}: [{rule}] {msg}")
    }
}

/// One source line split into its code text (strings replaced by spaces,
/// comments removed) and its comment text (line + block + doc comments).
#[derive(Default)]
struct SourceLine {
    code: String,
    comment: String,
}

/// Comment/string-aware line splitter. Handles line comments, nested block
/// comments, string/raw-string literals, char literals and lifetimes. Not a
/// full lexer — just enough to keep the rules from firing on tokens inside
/// strings or prose.
fn strip(src: &str) -> Vec<SourceLine> {
    #[derive(Clone, Copy)]
    enum St {
        Code,
        LineComment,
        BlockComment(usize),
        Str,
        RawStr(usize),
    }
    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<SourceLine> = vec![SourceLine::default()];
    let mut st = St::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(SourceLine::default());
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            i += 1;
            continue;
        }
        let cur = lines.last_mut().expect("lines is never empty");
        match st {
            St::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push(' ');
                    st = St::Str;
                    i += 1;
                } else if c == 'r' && !prev_is_word(&chars, i) {
                    if let Some(hashes) = raw_str_hashes(&chars, i + 1) {
                        cur.code.push(' ');
                        st = St::RawStr(hashes);
                        i += 2 + hashes;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    i = skip_char_literal(&chars, i, cur);
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if depth == 1 {
                        St::Code
                    } else {
                        St::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += 2;
                } else if c == '"' {
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' && closes_raw_str(&chars, i + 1, hashes) {
                    st = St::Code;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines
}

fn prev_is_word(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// At `chars[i]`, does `#*"` start a raw-string body? Returns the hash count.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<usize> {
    let mut hashes = 0;
    while chars.get(i + hashes) == Some(&'#') {
        hashes += 1;
    }
    if chars.get(i + hashes) == Some(&'"') {
        Some(hashes)
    } else {
        None
    }
}

fn closes_raw_str(chars: &[char], i: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Skip over a char literal (`'a'`, `'\n'`, `'\u{7f}'`) starting at the
/// opening quote; a lifetime (`'static`) keeps the quote in the code text.
/// Returns the next index to scan.
fn skip_char_literal(chars: &[char], i: usize, cur: &mut SourceLine) -> usize {
    if chars.get(i + 1) == Some(&'\\') {
        // Escaped char literal: scan (bounded) for the closing quote.
        for j in (i + 3)..(i + 13).min(chars.len()) {
            if chars[j] == '\'' {
                cur.code.push(' ');
                return j + 1;
            }
        }
    } else if chars.get(i + 2) == Some(&'\'') {
        cur.code.push(' ');
        return i + 3;
    }
    // Lifetime or stray quote: keep it as code.
    cur.code.push('\'');
    i + 1
}

/// Is the comment on line `ln` (0-based) or the line above it a waiver for
/// `rule`?
fn waived(lines: &[SourceLine], ln: usize, rule: &str) -> bool {
    let tag = format!("pallas-lint: allow({rule})");
    let here = lines[ln].comment.contains(&tag);
    let above = ln > 0 && lines[ln - 1].comment.contains(&tag);
    here || above
}

/// Does `hay` contain `word` delimited by non-word characters? (Keeps R2
/// from firing on `unsafe_op_in_unsafe_fn` and the like.)
fn contains_word(hay: &str, word: &str) -> bool {
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(p) = hay[from..].find(word) {
        let at = from + p;
        let end = at + word.len();
        let before_ok = at == 0 || !is_word_byte(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_word_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lint one file's contents. `path` is the repo-relative path the rules key
/// on (R1's layer exemptions, R4's migrated list); fixtures pass synthetic
/// paths to aim a rule.
fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let lines = strip(src);
    let mut findings = Vec::new();

    // Joined code text with a byte → line map, for the cross-line R1 match.
    let mut code = String::new();
    let mut line_of: Vec<usize> = Vec::new();
    for (ln, l) in lines.iter().enumerate() {
        for ch in l.code.chars() {
            code.push(ch);
            line_of.resize(line_of.len() + ch.len_utf8(), ln);
        }
        code.push('\n');
        line_of.push(ln);
    }

    // R1: `.lock()` immediately followed (modulo whitespace) by `.unwrap()`.
    let r1_exempt = path.contains("runtime/sync/") || path.ends_with("util.rs");
    if !r1_exempt {
        let bytes = code.as_bytes();
        let mut from = 0;
        while let Some(p) = code[from..].find(".lock()") {
            let at = from + p;
            let mut rest = at + ".lock()".len();
            while bytes.get(rest).is_some_and(|b| b.is_ascii_whitespace()) {
                rest += 1;
            }
            if code[rest..].starts_with(".unwrap()") {
                let ln = line_of[at];
                if !waived(&lines, ln, "R1") {
                    findings.push(Finding::new(path, ln + 1, "R1", R1_MSG));
                }
            }
            from = at + ".lock()".len();
        }
    }

    // R2: every `unsafe` keyword needs a SAFETY comment within the window.
    for (ln, l) in lines.iter().enumerate() {
        if !contains_word(&l.code, "unsafe") {
            continue;
        }
        let lo = ln.saturating_sub(R2_WINDOW);
        let documented = lines[lo..=ln]
            .iter()
            .any(|w| w.comment.contains("SAFETY:") || w.comment.contains("# Safety"));
        if !documented && !waived(&lines, ln, "R2") {
            findings.push(Finding::new(path, ln + 1, "R2", R2_MSG));
        }
    }

    // R3: allocation-free contract of hot-path-tagged files (non-test code).
    if src.contains(HOT_PATH_TAG) {
        let first_test = lines
            .iter()
            .position(|l| l.code.contains("#[cfg(test)]"))
            .unwrap_or(lines.len());
        for (ln, l) in lines.iter().enumerate().take(first_test) {
            for token in R3_BANNED {
                if l.code.contains(token) && !waived(&lines, ln, "R3") {
                    findings.push(Finding::new(
                        path,
                        ln + 1,
                        "R3",
                        format!("`{token}` allocates in a `hot-path`-tagged file"),
                    ));
                }
            }
        }
    }

    // R4: migrated modules must not touch `std::sync` directly.
    if MIGRATED.iter().any(|m| path.ends_with(m)) {
        for (ln, l) in lines.iter().enumerate() {
            if l.code.contains("std::sync") && !waived(&lines, ln, "R4") {
                findings.push(Finding::new(path, ln + 1, "R4", R4_MSG));
            }
        }
    }

    findings
}

/// Recursively collect `.rs` files under `dir`, sorted for stable output.
fn rs_files(dir: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    let Ok(entries) = fs::read_dir(dir) else {
        return out;
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            out.extend(rs_files(&p));
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    out.sort();
    out
}

/// Lint every `.rs` file under `rust/src` relative to `repo_root`.
fn lint_tree(repo_root: &Path) -> Vec<Finding> {
    let src_root = repo_root.join("rust").join("src");
    let mut findings = Vec::new();
    for file in rs_files(&src_root) {
        let rel = file
            .strip_prefix(repo_root)
            .unwrap_or(&file)
            .to_string_lossy()
            .replace('\\', "/");
        match fs::read_to_string(&file) {
            Ok(src) => findings.extend(lint_source(&rel, &src)),
            Err(e) => findings.push(Finding::new(&rel, 0, "io", format!("unreadable: {e}"))),
        }
    }
    findings
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            // CARGO_MANIFEST_DIR is xtask/; the repo root is its parent.
            let root = Path::new(env!("CARGO_MANIFEST_DIR"))
                .parent()
                .expect("xtask lives one level under the repo root")
                .to_path_buf();
            let findings = lint_tree(&root);
            if findings.is_empty() {
                println!("pallas-lint: clean (rules R1-R4, rust/src)");
                ExitCode::SUCCESS
            } else {
                for f in &findings {
                    eprintln!("{f}");
                }
                eprintln!("pallas-lint: {} finding(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        _ => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const R1_FIXTURE: &str = include_str!("../fixtures/r1.rs");
    const R2_FIXTURE: &str = include_str!("../fixtures/r2.rs");
    const R3_FIXTURE: &str = include_str!("../fixtures/r3.rs");
    const R4_FIXTURE: &str = include_str!("../fixtures/r4.rs");
    const CLEAN_FIXTURE: &str = include_str!("../fixtures/clean.rs");

    fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn r1_fires_on_lock_unwrap() {
        let findings = lint_source("rust/src/fake.rs", R1_FIXTURE);
        assert!(rules_of(&findings).contains(&"R1"), "{findings:?}");
    }

    #[test]
    fn r1_fires_across_a_line_break() {
        let src = "fn f(m: &M) {\n    let _g = m.lock()\n        .unwrap();\n}\n";
        let findings = lint_source("rust/src/fake.rs", src);
        assert_eq!(rules_of(&findings), vec!["R1"], "{findings:?}");
        assert_eq!(findings[0].line, 2);
    }

    #[test]
    fn r1_exempts_the_sync_layer_and_util() {
        let model = lint_source("rust/src/runtime/sync/model.rs", R1_FIXTURE);
        assert!(model.is_empty(), "{model:?}");
        let util = lint_source("rust/src/util.rs", R1_FIXTURE);
        assert!(util.is_empty(), "{util:?}");
    }

    #[test]
    fn r2_fires_on_undocumented_unsafe() {
        let findings = lint_source("rust/src/fake.rs", R2_FIXTURE);
        assert!(rules_of(&findings).contains(&"R2"), "{findings:?}");
    }

    #[test]
    fn r2_accepts_a_safety_comment() {
        let src = "// SAFETY: p is valid per the caller contract.\nlet v = unsafe { *p };\n";
        assert!(lint_source("rust/src/fake.rs", src).is_empty());
    }

    #[test]
    fn r2_ignores_unsafe_in_strings_comments_and_identifiers() {
        let src = "// unsafe in prose\nlet s = \"unsafe\";\n#![deny(unsafe_op_in_unsafe_fn)]\n";
        assert!(lint_source("rust/src/fake.rs", src).is_empty());
    }

    #[test]
    fn r3_fires_only_in_tagged_files() {
        let findings = lint_source("rust/src/fake.rs", R3_FIXTURE);
        assert!(rules_of(&findings).contains(&"R3"), "{findings:?}");
        // The same source without the tag is not checked.
        let untagged = R3_FIXTURE.replace(HOT_PATH_TAG, "");
        assert!(lint_source("rust/src/fake.rs", &untagged).is_empty());
    }

    #[test]
    fn r3_exempts_test_modules() {
        let src = format!(
            "{HOT_PATH_TAG}\nfn hot() {{}}\n#[cfg(test)]\nmod tests {{\n    \
             fn t() {{ let v = Vec::with_capacity(4); let _ = v; }}\n}}\n"
        );
        assert!(lint_source("rust/src/fake.rs", &src).is_empty());
    }

    #[test]
    fn r4_fires_only_in_migrated_modules() {
        let findings = lint_source("rust/src/metrics.rs", R4_FIXTURE);
        assert!(rules_of(&findings).contains(&"R4"), "{findings:?}");
        let other = lint_source("rust/src/other.rs", R4_FIXTURE);
        assert!(other.is_empty(), "{other:?}");
    }

    #[test]
    fn waiver_comment_suppresses_a_finding() {
        let above = "// pallas-lint: allow(R1)\nlet _g = m.lock().unwrap();\n";
        assert!(lint_source("rust/src/fake.rs", above).is_empty());
        let same_line = "let _g = m.lock().unwrap(); // pallas-lint: allow(R1)\n";
        assert!(lint_source("rust/src/fake.rs", same_line).is_empty());
    }

    #[test]
    fn clean_fixture_is_clean() {
        let findings = lint_source("rust/src/metrics.rs", CLEAN_FIXTURE);
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn scanner_strips_strings_and_comments() {
        let lines = strip("let a = \"x.lock().unwrap()\"; // .lock().unwrap()\n");
        assert!(!lines[0].code.contains("lock"));
        assert!(lines[0].comment.contains(".lock().unwrap()"));
    }

    #[test]
    fn scanner_handles_raw_strings_and_char_literals() {
        let raw = strip("let r = r#\"unsafe \" x\"#;\n");
        assert!(!raw[0].code.contains("unsafe"), "{:?}", raw[0].code);
        let chr = strip("let c = '\\'';\n");
        assert!(chr[0].code.contains("let c ="));
        let lt = strip("let l: &'static str = \"\";\n");
        assert!(lt[0].code.contains("'static"));
    }

    #[test]
    fn scanner_handles_nested_block_comments() {
        let lines = strip("/* a /* inner unsafe */ still comment */ let x = 1;\n");
        assert!(!lines[0].code.contains("unsafe"));
        assert!(lines[0].code.contains("let x = 1;"));
    }

    /// The acceptance gate: the real tree is clean under all four rules.
    /// Runs in tier-1 (`cargo test` builds the workspace), so a violating
    /// commit fails even before CI's explicit `cargo xtask lint` step.
    #[test]
    fn real_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("xtask lives one level under the repo root");
        let findings = lint_tree(root);
        let report: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
        let report = report.join("\n");
        assert!(findings.is_empty(), "violations in rust/src:\n{report}");
    }
}
