//! Fixture: violates rule R1 (`.lock().unwrap()` outside
//! `util::lock_or_recover`). Pinned by the xtask self-tests — if the rule
//! stops firing here, the lint has regressed.

use std::sync::Mutex;

fn drain(queue: &Mutex<Vec<u64>>) -> Vec<u64> {
    // A panicking holder poisons `queue`; this unwrap then cascades the
    // panic into every later caller instead of degrading gracefully.
    let mut q = queue.lock().unwrap();
    std::mem::take(&mut *q)
}
