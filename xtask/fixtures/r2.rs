//! Fixture: violates rule R2 — an `unsafe` with no justification comment
//! anywhere nearby. Pinned by the xtask self-tests. (This header must not
//! spell out the required comment marker: it would land inside the rule's
//! lookback window and satisfy it.)

fn first_byte(bytes: &[u8]) -> u8 {
    assert!(!bytes.is_empty());

    unsafe { *bytes.as_ptr() }
}
