//! Fixture: violates rule R4 when linted under a migrated module path —
//! a direct `std::sync` import that the `--cfg loom` build would not model.
//! Pinned by the xtask self-tests (which lint this file as
//! `rust/src/metrics.rs` to aim the rule, and as a non-migrated path to
//! prove it stays silent elsewhere).

use std::sync::Mutex;

static REGISTRY: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());

fn register(name: &'static str) {
    if let Ok(mut reg) = REGISTRY.lock() {
        reg.push(name);
    }
}
