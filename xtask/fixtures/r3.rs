//! Fixture: violates rule R3 — an allocating constructor inside a file
//! tagged `hot-path`. Pinned by the xtask self-tests.

#![doc = "hot-path"]

fn scratch(n: usize) -> Vec<f64> {
    // Hot-path files must draw scratch from the Workspace pool, never
    // allocate per call.
    Vec::with_capacity(n)
}
