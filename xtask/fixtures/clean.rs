//! Fixture: clean under every rule, even when linted as a migrated module.
//! Demonstrates the sanctioned idioms — the sync shim, `lock_or_recover`,
//! a justified `unsafe`, and the explicit waiver escape hatch.

use crate::runtime::sync::{Arc, Mutex};
use crate::util::lock_or_recover;
// The waiver must name the rule it silences and sit on the offending line
// or the line above; reviewers grep for it.
use std::sync::atomic::AtomicU64; // pallas-lint: allow(R4)

fn drain(queue: &Arc<Mutex<Vec<u64>>>) -> Vec<u64> {
    let mut q = lock_or_recover(queue);
    std::mem::take(&mut *q)
}

fn first_byte(bytes: &[u8]) -> u8 {
    assert!(!bytes.is_empty());
    // SAFETY: the assert above guarantees at least one element, so the
    // pointer read is in bounds.
    unsafe { *bytes.as_ptr() }
}
