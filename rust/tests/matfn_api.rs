//! Integration suite for the unified `matfn` API surface: registry
//! round-trips (including from optimizer `Backend`/config strings), helpful
//! unknown-name errors, the zero-allocation persistent-workspace contract,
//! warm starts, and per-iteration observers.

use prism::config::Backend;
use prism::linalg::gemm::matmul_at_b;
use prism::linalg::Mat;
use prism::matfn::{registry, MatFnSolver, MatFnTask, Solver};
use prism::prism::driver::StopRule;
use prism::randmat;
use prism::rng::Rng;
use std::sync::{Arc, Mutex};

// ───────────────────────── registry round-trips ─────────────────────────

#[test]
fn every_registry_name_resolves_and_round_trips() {
    for &name in registry::names() {
        let solver = registry::resolve(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(solver.name(), name, "resolve(name).name() must equal the key");
    }
}

#[test]
fn backend_strings_round_trip_through_the_registry() {
    // Every optimizer/config Backend, for both service tasks: Backend →
    // Solver → name → registry → same name. This is the config-file path:
    // a TOML `backend = "prism5"` ends up at the same solver as the
    // registry key "prism5-polar"/"prism5-invsqrt".
    for b in [
        Backend::NewtonSchulz,
        Backend::PolarExpress,
        Backend::Prism3,
        Backend::Prism5,
        Backend::Eigen,
        Backend::PrismNewton,
    ] {
        for task in [MatFnTask::Polar, MatFnTask::InvSqrt] {
            let s = Solver::for_backend(b, task, 25).unwrap();
            let name = s.name();
            let re = registry::resolve(&name)
                .unwrap_or_else(|e| panic!("{:?}/{}: '{name}': {e}", b, task.name()));
            assert_eq!(re.name(), name);
            // The backend string itself parses back too (registry method
            // vocabulary ⊇ Backend::parse vocabulary). The one exception is
            // prism-newton×polar: DB-Newton has no polar form, which is
            // exactly why for_backend substitutes PRISM-5 there.
            if !(b == Backend::PrismNewton && task == MatFnTask::Polar) {
                let via_string =
                    registry::resolve(&format!("{}-{}", b.name(), task.name())).unwrap();
                assert_eq!(via_string.task(), task);
            }
        }
    }
}

#[test]
fn unknown_names_list_the_valid_options() {
    let err = registry::resolve("prism6-polar").unwrap_err().to_string();
    assert!(err.contains("prism6-polar"), "{err}");
    for expected in ["prism5-polar", "newton-sqrt", "cheb-inverse", "eigen-invroot2"] {
        assert!(err.contains(expected), "error must list '{expected}': {err}");
    }
}

// ───────────────── persistent workspace: zero allocations ─────────────────

#[test]
fn reused_solvers_run_allocation_free_for_every_engine() {
    // This covers the *whole* hot loop, including the sketched α fits: the
    // PRISM solvers below run AlphaMode::Sketched, whose sketch draw, 1×q
    // trace row, and power-trace ping-pong panels all come from the same
    // solver Workspace this test watches — so a steady-state solve performs
    // zero heap allocations end to end (the satellite contract for
    // `sketch::power_traces_into`).
    let mut rng = Rng::seed_from(1);
    let tall = randmat::gaussian(&mut rng, 20, 10);
    let w = randmat::logspace(1e-2, 1.0, 12);
    let spd = randmat::sym_with_spectrum(&mut rng, 12, &w);
    // (registry name, input) per engine family — PRISM engines and both
    // iterative baselines.
    let cases: &[(&str, &Mat)] = &[
        ("prism5-polar", &tall),
        ("prism3-sign", &spd),
        ("prism5-sqrt", &spd),
        ("prism5-invsqrt", &spd),
        ("invnewton-invroot2", &spd),
        ("newton-sqrt", &spd),
        ("cheb-inverse", &spd),
        ("pe-polar", &tall),
    ];
    for &(name, input) in cases {
        let mut s = registry::resolve(name).unwrap();
        s.set_stop(StopRule::default().with_max_iters(20));
        let _ = s.solve(input, &mut rng);
        let allocs = s.workspace_allocations();
        assert!(allocs > 0, "{name}: cold call should populate the pool");
        for _ in 0..2 {
            let _ = s.solve(input, &mut rng);
        }
        assert_eq!(
            s.workspace_allocations(),
            allocs,
            "{name}: same-shape reuse must be allocation-free"
        );
    }
}

#[test]
fn shape_change_grows_pool_then_stabilizes() {
    let mut rng = Rng::seed_from(2);
    let small = randmat::gaussian(&mut rng, 12, 6);
    let big = randmat::gaussian(&mut rng, 24, 12);
    let mut s = registry::resolve("prism5-polar").unwrap();
    let _ = s.solve(&small, &mut rng);
    let _ = s.solve(&big, &mut rng); // grows buffers (counted)
    let after_big = s.workspace_allocations();
    let _ = s.solve(&big, &mut rng);
    let _ = s.solve(&small, &mut rng); // big buffers serve small shapes
    assert_eq!(s.workspace_allocations(), after_big);
}

// ───────────────────────── warm start (§C) ─────────────────────────

#[test]
fn polar_warm_start_polishes_previous_factor() {
    // Polar warm starts are first-order (see MatFnSolver::solve_from docs):
    // the iteration polishes x0, which is exact for the same input and
    // O(‖ΔA‖)-accurate under drift — the Muon optimizer-step trade.
    let mut rng = Rng::seed_from(3);
    let spec = randmat::logspace(1e-2, 1.0, 16);
    let a = randmat::with_spectrum(&mut rng, 24, 16, &spec);
    let mut s = registry::resolve("prism5-polar").unwrap();
    s.set_stop(StopRule::default().with_max_iters(100).with_tol(1e-8));
    let cold = s.solve(&a, &mut rng);
    assert!(cold.log.converged);

    // Same input: the previous factor is already the answer — ~no work.
    let again = s.solve_from(&a, &cold.primary, &mut rng);
    assert!(again.log.converged);
    assert!(
        again.log.iters() <= 1,
        "re-solve from own factor took {} iters",
        again.log.iters()
    );

    // Drifted input: far fewer iterations than a cold solve, result still
    // orthogonal and within O(drift) of the drifted input's true factor.
    let mut a2 = a.clone();
    let noise = Mat::gaussian(&mut rng, 24, 16, 1e-8);
    a2.axpy(1.0, &noise);
    let warm = s.solve_from(&a2, &cold.primary, &mut rng);
    let cold2 = s.solve(&a2, &mut rng);
    assert!(warm.log.converged && cold2.log.converged);
    assert!(
        warm.log.iters() < cold2.log.iters(),
        "warm {} vs cold {}",
        warm.log.iters(),
        cold2.log.iters()
    );
    assert!(matmul_at_b(&warm.primary, &warm.primary).sub(&Mat::eye(16)).max_abs() < 1e-6);
    let exact2 = prism::baselines::eigen_fn::polar_eigen(&a2);
    assert!(
        warm.primary.sub(&exact2).max_abs() < 1e-3,
        "warm result must track the drifted factor to first order"
    );
}

#[test]
fn inverse_warm_start_polishes_previous_result() {
    let mut rng = Rng::seed_from(4);
    let w = randmat::logspace(1e-2, 1.0, 10);
    let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
    for name in ["cheb-inverse", "invnewton-invroot2"] {
        let mut s = registry::resolve(name).unwrap();
        s.set_stop(StopRule::default().with_max_iters(200).with_tol(1e-9));
        let cold = s.solve(&a, &mut rng);
        assert!(cold.log.converged, "{name}");
        let warm = s.solve_from(&a, &cold.primary, &mut rng);
        assert!(warm.log.converged, "{name}");
        assert!(
            warm.log.iters() <= 3,
            "{name}: restarting from the answer should be ~instant, took {}",
            warm.log.iters()
        );
    }
}

#[test]
fn sqrt_warm_start_falls_back_to_cold_solve() {
    // Coupled square-root methods cannot resume from X alone; solve_from is
    // documented to fall back to a full solve and must still be correct.
    let mut rng = Rng::seed_from(5);
    let w = randmat::logspace(1e-2, 1.0, 8);
    let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
    let mut s = registry::resolve("prism5-sqrt").unwrap();
    let cold = s.solve(&a, &mut rng);
    let warm = s.solve_from(&a, &cold.primary, &mut rng);
    assert!(warm.log.converged);
    let back = prism::linalg::gemm::matmul(&warm.primary, &warm.primary);
    assert!(back.sub(&a).max_abs() < 1e-6);
}

// ───────────────────────── observer streaming ─────────────────────────

#[test]
fn observer_streams_one_event_per_iteration() {
    let mut rng = Rng::seed_from(6);
    let a = randmat::gaussian(&mut rng, 20, 10);
    let mut s = registry::resolve("prism5-polar").unwrap();
    let events: Arc<Mutex<Vec<(usize, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&events);
    s.set_observer(Some(Box::new(move |ev| {
        sink.lock().unwrap().push((ev.iter, ev.residual));
    })));
    let out = s.solve(&a, &mut rng);
    s.set_observer(None);
    let n_events = {
        let seen = events.lock().unwrap();
        assert_eq!(seen.len(), out.log.iters());
        for (k, (iter, res)) in seen.iter().enumerate() {
            assert_eq!(*iter, k);
            assert_eq!(*res, out.log.residuals[k + 1], "stream must mirror the log");
        }
        seen.len()
    };
    // Removing the observer stops the stream but not the solver.
    let out2 = s.solve(&a, &mut rng);
    assert!(out2.log.converged);
    assert_eq!(events.lock().unwrap().len(), n_events, "no events after removal");
}

// ───────────────────── trait-object service pattern ─────────────────────

#[test]
fn solvers_compose_as_trait_objects() {
    let mut rng = Rng::seed_from(7);
    let w = randmat::logspace(1e-2, 1.0, 9);
    let spd = randmat::sym_with_spectrum(&mut rng, 9, &w);
    let mut bank: Vec<Box<dyn MatFnSolver>> = vec![
        Box::new(registry::resolve("prism5-invsqrt").unwrap()),
        Box::new(registry::resolve("newton-invsqrt").unwrap()),
        Box::new(registry::resolve("eigen-invsqrt").unwrap()),
    ];
    for s in bank.iter_mut() {
        let out = s.solve(&spd, &mut rng);
        assert!(out.log.converged, "{}", s.name());
        let prod = prism::linalg::gemm::matmul(
            &prism::linalg::gemm::matmul(&out.primary, &spd),
            &out.primary,
        );
        assert!(
            prod.sub(&Mat::eye(9)).max_abs() < 1e-4,
            "{}: not an inverse sqrt",
            s.name()
        );
    }
}
