//! Integration suite for the unified `matfn` API surface: registry
//! round-trips (including from optimizer `Backend`/config strings), helpful
//! unknown-name errors, the zero-allocation persistent-workspace contract,
//! warm starts, and per-iteration observers.

use prism::config::Backend;
use prism::linalg::gemm::matmul_at_b;
use prism::linalg::Mat;
use prism::matfn::{registry, MatFnSolver, MatFnTask, Solver};
use prism::prism::driver::StopRule;
use prism::randmat;
use prism::rng::Rng;
use std::sync::{Arc, Mutex};

// ───────────────────────── registry round-trips ─────────────────────────

#[test]
fn every_registry_name_resolves_and_round_trips() {
    for &name in registry::names() {
        let solver = registry::resolve(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(solver.name(), name, "resolve(name).name() must equal the key");
    }
}

#[test]
fn backend_strings_round_trip_through_the_registry() {
    // Every optimizer/config Backend, for both service tasks: Backend →
    // Solver → name → registry → same name. This is the config-file path:
    // a TOML `backend = "prism5"` ends up at the same solver as the
    // registry key "prism5-polar"/"prism5-invsqrt".
    for b in [
        Backend::NewtonSchulz,
        Backend::PolarExpress,
        Backend::Prism3,
        Backend::Prism5,
        Backend::Eigen,
        Backend::PrismNewton,
    ] {
        for task in [MatFnTask::Polar, MatFnTask::RectPolar, MatFnTask::InvSqrt] {
            let s = Solver::for_backend(b, task, 25).unwrap();
            let name = s.name();
            let re = registry::resolve(&name)
                .unwrap_or_else(|e| panic!("{:?}/{}: '{name}': {e}", b, task.name()));
            assert_eq!(re.name(), name);
            // The backend string itself parses back too (registry method
            // vocabulary ⊇ Backend::parse vocabulary). The exceptions are
            // the pairs for_backend substitutes PRISM for: DB-Newton has no
            // (rect)polar form, and PolarExpress's minimax schedule has no
            // rectangular form (no "pe-rectpolar" registry key).
            let substituted = (b == Backend::PrismNewton
                && matches!(task, MatFnTask::Polar | MatFnTask::RectPolar))
                || (b == Backend::PolarExpress && task == MatFnTask::RectPolar);
            if !substituted {
                let via_string =
                    registry::resolve(&format!("{}-{}", b.name(), task.name())).unwrap();
                assert_eq!(via_string.task(), task);
            }
        }
    }
}

#[test]
fn unknown_names_list_the_valid_options() {
    let err = registry::resolve("prism6-polar").unwrap_err().to_string();
    assert!(err.contains("prism6-polar"), "{err}");
    for expected in ["prism5-polar", "newton-sqrt", "cheb-inverse", "eigen-invroot2"] {
        assert!(err.contains(expected), "error must list '{expected}': {err}");
    }
}

// ───────────────── persistent workspace: zero allocations ─────────────────

#[test]
fn reused_solvers_run_allocation_free_for_every_engine() {
    // This covers the *whole* hot loop, including the sketched α fits: the
    // PRISM solvers below run AlphaMode::Sketched, whose sketch draw, 1×q
    // trace row, and power-trace ping-pong panels all come from the same
    // solver Workspace this test watches — so a steady-state solve performs
    // zero heap allocations end to end (the satellite contract for
    // `sketch::power_traces_into`).
    let mut rng = Rng::seed_from(1);
    let tall = randmat::gaussian(&mut rng, 20, 10);
    let w = randmat::logspace(1e-2, 1.0, 12);
    let spd = randmat::sym_with_spectrum(&mut rng, 12, &w);
    // (registry name, input) per engine family — PRISM engines and both
    // iterative baselines.
    let cases: &[(&str, &Mat)] = &[
        ("prism5-polar", &tall),
        ("prism5-rectpolar", &tall), // aspect 2 → Gram route (syrk + p×p core)
        ("prism3-sign", &spd),
        ("prism5-sqrt", &spd),
        ("prism5-invsqrt", &spd),
        ("invnewton-invroot2", &spd),
        ("newton-sqrt", &spd),
        ("cheb-inverse", &spd),
        ("pe-polar", &tall),
    ];
    for &(name, input) in cases {
        let mut s = registry::resolve(name).unwrap();
        s.set_stop(StopRule::default().with_max_iters(20));
        let _ = s.solve(input, &mut rng);
        let allocs = s.workspace_allocations();
        assert!(allocs > 0, "{name}: cold call should populate the pool");
        for _ in 0..2 {
            let _ = s.solve(input, &mut rng);
        }
        assert_eq!(
            s.workspace_allocations(),
            allocs,
            "{name}: same-shape reuse must be allocation-free"
        );
    }
}

#[test]
fn shape_change_grows_pool_then_stabilizes() {
    let mut rng = Rng::seed_from(2);
    let small = randmat::gaussian(&mut rng, 12, 6);
    let big = randmat::gaussian(&mut rng, 24, 12);
    let mut s = registry::resolve("prism5-polar").unwrap();
    let _ = s.solve(&small, &mut rng);
    let _ = s.solve(&big, &mut rng); // grows buffers (counted)
    let after_big = s.workspace_allocations();
    let _ = s.solve(&big, &mut rng);
    let _ = s.solve(&small, &mut rng); // big buffers serve small shapes
    assert_eq!(s.workspace_allocations(), after_big);
}

// ───────────────────── batched lockstep solves ─────────────────────

/// Batched vs sequential, the `solve_batch` contract: every member's
/// output must be bitwise identical to a sequential `solve` of the same
/// input from a clone of the batch's entry RNG state.
fn assert_batch_matches_sequential(name: &str, inputs: &[Mat], coupled: bool) {
    let refs: Vec<&Mat> = inputs.iter().collect();
    let entry = Rng::seed_from(77);
    let mut batch_solver = registry::resolve(name).unwrap();
    batch_solver.set_stop(StopRule::default().with_max_iters(30));
    let outs = batch_solver.solve_batch(&refs, &mut entry.clone());
    assert_eq!(outs.len(), inputs.len());
    let mut seq_solver = registry::resolve(name).unwrap();
    seq_solver.set_stop(StopRule::default().with_max_iters(30));
    for (j, (a, out)) in inputs.iter().zip(&outs).enumerate() {
        let want = seq_solver.solve(a, &mut entry.clone());
        assert_eq!(out.primary, want.primary, "{name} job {j}: primary differs");
        assert_eq!(out.log.alphas, want.log.alphas, "{name} job {j}: α sequence differs");
        assert_eq!(out.log.residuals, want.log.residuals, "{name} job {j}: residuals differ");
        assert_eq!(out.log.converged, want.log.converged, "{name} job {j}: converged flag");
        assert_eq!(out.log.diverged, want.log.diverged, "{name} job {j}: diverged flag");
        if coupled {
            assert_eq!(
                out.secondary.as_ref().unwrap(),
                want.secondary.as_ref().unwrap(),
                "{name} job {j}: coupled partner differs"
            );
        }
    }
}

#[test]
fn solve_batch_bitwise_matches_sequential_tall_polar() {
    // Mixed conditioning → members converge at different iterations, so
    // the lockstep liveness bookkeeping (and the shared-fill stream
    // alignment it relies on) is exercised, not just the happy path.
    let mut rng = Rng::seed_from(20);
    let inputs: Vec<Mat> = (0..5)
        .map(|k| {
            let s = randmat::logspace(10f64.powi(-(k as i32) - 2), 1.0, 12);
            randmat::with_spectrum(&mut rng, 18, 12, &s)
        })
        .collect();
    assert_batch_matches_sequential("prism5-polar", &inputs, false);
    assert_batch_matches_sequential("prism3-polar", &inputs, false);
    // Classical NS consumes no randomness but runs the same lockstep loop.
    assert_batch_matches_sequential("ns-polar", &inputs, false);
}

#[test]
fn solve_batch_bitwise_matches_sequential_wide_polar() {
    let mut rng = Rng::seed_from(21);
    let inputs: Vec<Mat> = (0..3).map(|_| randmat::gaussian(&mut rng, 10, 20)).collect();
    assert_batch_matches_sequential("prism5-polar", &inputs, false);
}

#[test]
fn solve_batch_bitwise_matches_sequential_invsqrt_and_sign() {
    let mut rng = Rng::seed_from(22);
    let spd: Vec<Mat> = (0..4)
        .map(|k| {
            let w = randmat::logspace(10f64.powi(-(k as i32) - 1), 1.0, 10);
            randmat::sym_with_spectrum(&mut rng, 10, &w)
        })
        .collect();
    assert_batch_matches_sequential("prism5-invsqrt", &spd, true);
    assert_batch_matches_sequential("prism5-sqrt", &spd, true);
    let indef: Vec<Mat> = (0..4)
        .map(|_| {
            let w: Vec<f64> = (0..8)
                .map(|i| if i % 2 == 0 { 0.9 - 0.1 * i as f64 } else { -0.8 + 0.1 * i as f64 })
                .collect();
            randmat::sym_with_spectrum(&mut rng, 8, &w)
        })
        .collect();
    assert_batch_matches_sequential("prism3-sign", &indef, false);
}

#[test]
fn solve_batch_falls_back_for_non_ns_methods() {
    // Direct/minimax methods run members back to back but must satisfy the
    // same per-job stream contract (trivially — they draw no randomness).
    let mut rng = Rng::seed_from(23);
    let tall: Vec<Mat> = (0..3).map(|_| randmat::gaussian(&mut rng, 16, 8)).collect();
    let refs: Vec<&Mat> = tall.iter().collect();
    for name in ["pe-polar", "eigen-polar"] {
        let mut batch_solver = registry::resolve(name).unwrap();
        let outs = batch_solver.solve_batch(&refs, &mut Rng::seed_from(3));
        let mut seq_solver = registry::resolve(name).unwrap();
        for (a, out) in tall.iter().zip(&outs) {
            let want = seq_solver.solve(a, &mut Rng::seed_from(3));
            assert_eq!(out.primary, want.primary, "{name}: batch != sequential");
        }
    }
}

#[test]
fn solve_batch_rectpolar_mixed_shapes_fall_back_sequential() {
    // RectPolar batches legitimately mix shapes (one job per layer) and are
    // never lockstepped — routes are chosen per shape and solved through
    // the Gram/direct cores. The mixed-shape batch must not panic, and
    // every member must be bitwise identical to a sequential solve from a
    // clone of the entry RNG state (the per-job stream contract).
    let mut rng = Rng::seed_from(27);
    let shapes = [(32usize, 8usize), (8, 32), (24, 6), (10, 10)];
    let inputs: Vec<Mat> = shapes
        .iter()
        .map(|&(m, n)| {
            let s = randmat::logspace(0.1, 1.0, m.min(n));
            if m >= n {
                randmat::with_spectrum(&mut rng, m, n, &s)
            } else {
                randmat::with_spectrum(&mut rng, n, m, &s).transpose()
            }
        })
        .collect();
    let refs: Vec<&Mat> = inputs.iter().collect();
    let entry = Rng::seed_from(55);
    let mut batch_solver = registry::resolve("prism5-rectpolar").unwrap();
    batch_solver.set_stop(StopRule::default().with_max_iters(60));
    let outs = batch_solver.solve_batch(&refs, &mut entry.clone());
    assert_eq!(outs.len(), inputs.len());
    let mut seq_solver = registry::resolve("prism5-rectpolar").unwrap();
    seq_solver.set_stop(StopRule::default().with_max_iters(60));
    for (j, (a, out)) in inputs.iter().zip(&outs).enumerate() {
        let want = seq_solver.solve(a, &mut entry.clone());
        assert_eq!(out.primary, want.primary, "rectpolar job {j}: batch != sequential");
        assert_eq!(out.log.residuals, want.log.residuals, "rectpolar job {j}: residual trail");
    }
}

#[test]
fn solve_batch_shares_one_sketch_fill_per_iteration() {
    // The amortisation claim itself: a lockstep batch draws one sketch per
    // iteration of its longest member — O(iters) — while sequential solves
    // draw one per member per iteration — O(batch · iters).
    let mut rng = Rng::seed_from(24);
    let w = randmat::logspace(1e-2, 1.0, 10);
    let inputs: Vec<Mat> = (0..6).map(|_| randmat::sym_with_spectrum(&mut rng, 10, &w)).collect();
    let refs: Vec<&Mat> = inputs.iter().collect();
    let entry = Rng::seed_from(99);
    let mut solver = registry::resolve("prism5-invsqrt").unwrap();

    let scope = prism::sketch::SketchScope::begin();
    let outs = solver.solve_batch(&refs, &mut entry.clone());
    let batched_fills = scope.fills();
    let longest = outs.iter().map(|o| o.log.iters()).max().unwrap() as u64;
    assert_eq!(batched_fills, longest, "one shared fill per lockstep iteration");

    let scope = prism::sketch::SketchScope::begin();
    for a in &inputs {
        let _ = solver.solve(a, &mut entry.clone());
    }
    let sequential_fills = scope.fills();
    let total: u64 = outs.iter().map(|o| o.log.iters() as u64).sum();
    assert_eq!(sequential_fills, total, "sequential fills scale with batch · iters");
    assert!(batched_fills < sequential_fills);
}

#[test]
fn warm_batched_solves_are_allocation_free() {
    let mut rng = Rng::seed_from(25);
    let w = randmat::logspace(1e-2, 1.0, 10);
    let inputs: Vec<Mat> = (0..4).map(|_| randmat::sym_with_spectrum(&mut rng, 10, &w)).collect();
    let refs: Vec<&Mat> = inputs.iter().collect();
    let mut solver = registry::resolve("prism5-invsqrt").unwrap();
    let mut r = Rng::seed_from(5);
    let _ = solver.solve_batch(&refs, &mut r);
    let allocs = solver.workspace_allocations();
    assert!(allocs > 0, "cold batch populates the pool");
    for _ in 0..2 {
        let _ = solver.solve_batch(&refs, &mut r);
    }
    assert_eq!(
        solver.workspace_allocations(),
        allocs,
        "warm batched solves must not allocate"
    );
}

#[test]
fn solve_batch_streams_job_tagged_events() {
    // One persistent observer serves the whole batch; events carry the
    // member index so a service can attribute interleaved trajectories.
    let mut rng = Rng::seed_from(26);
    let w = randmat::logspace(1e-2, 1.0, 8);
    let inputs: Vec<Mat> = (0..3).map(|_| randmat::sym_with_spectrum(&mut rng, 8, &w)).collect();
    let refs: Vec<&Mat> = inputs.iter().collect();
    let mut solver = registry::resolve("prism5-invsqrt").unwrap();
    let events: Arc<Mutex<Vec<(usize, usize, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&events);
    solver.set_observer(Some(Box::new(move |ev| {
        sink.lock().unwrap().push((ev.job, ev.iter, ev.residual));
    })));
    let outs = solver.solve_batch(&refs, &mut Rng::seed_from(7));
    solver.set_observer(None);
    let events = events.lock().unwrap();
    for (j, out) in outs.iter().enumerate() {
        let mine: Vec<&(usize, usize, f64)> =
            events.iter().filter(|(job, _, _)| *job == j).collect();
        assert_eq!(mine.len(), out.log.iters(), "job {j}: one event per iteration");
        for (k, (_, iter, res)) in mine.iter().enumerate() {
            assert_eq!(*iter, k, "job {j}: iteration order");
            assert_eq!(*res, out.log.residuals[k + 1], "job {j}: stream mirrors the log");
        }
    }
}

// ───────────────────────── warm start (§C) ─────────────────────────

#[test]
fn polar_warm_start_polishes_previous_factor() {
    // Polar warm starts are first-order (see MatFnSolver::solve_from docs):
    // the iteration polishes x0, which is exact for the same input and
    // O(‖ΔA‖)-accurate under drift — the Muon optimizer-step trade.
    let mut rng = Rng::seed_from(3);
    let spec = randmat::logspace(1e-2, 1.0, 16);
    let a = randmat::with_spectrum(&mut rng, 24, 16, &spec);
    let mut s = registry::resolve("prism5-polar").unwrap();
    s.set_stop(StopRule::default().with_max_iters(100).with_tol(1e-8));
    let cold = s.solve(&a, &mut rng);
    assert!(cold.log.converged);

    // Same input: the previous factor is already the answer — ~no work.
    let again = s.solve_from(&a, &cold.primary, &mut rng);
    assert!(again.log.converged);
    assert!(
        again.log.iters() <= 1,
        "re-solve from own factor took {} iters",
        again.log.iters()
    );

    // Drifted input: far fewer iterations than a cold solve, result still
    // orthogonal and within O(drift) of the drifted input's true factor.
    let mut a2 = a.clone();
    let noise = Mat::gaussian(&mut rng, 24, 16, 1e-8);
    a2.axpy(1.0, &noise);
    let warm = s.solve_from(&a2, &cold.primary, &mut rng);
    let cold2 = s.solve(&a2, &mut rng);
    assert!(warm.log.converged && cold2.log.converged);
    assert!(
        warm.log.iters() < cold2.log.iters(),
        "warm {} vs cold {}",
        warm.log.iters(),
        cold2.log.iters()
    );
    assert!(matmul_at_b(&warm.primary, &warm.primary).sub(&Mat::eye(16)).max_abs() < 1e-6);
    let exact2 = prism::baselines::eigen_fn::polar_eigen(&a2);
    assert!(
        warm.primary.sub(&exact2).max_abs() < 1e-3,
        "warm result must track the drifted factor to first order"
    );
}

#[test]
fn inverse_warm_start_polishes_previous_result() {
    let mut rng = Rng::seed_from(4);
    let w = randmat::logspace(1e-2, 1.0, 10);
    let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
    for name in ["cheb-inverse", "invnewton-invroot2"] {
        let mut s = registry::resolve(name).unwrap();
        s.set_stop(StopRule::default().with_max_iters(200).with_tol(1e-9));
        let cold = s.solve(&a, &mut rng);
        assert!(cold.log.converged, "{name}");
        let warm = s.solve_from(&a, &cold.primary, &mut rng);
        assert!(warm.log.converged, "{name}");
        assert!(
            warm.log.iters() <= 3,
            "{name}: restarting from the answer should be ~instant, took {}",
            warm.log.iters()
        );
    }
}

#[test]
fn sqrt_warm_start_falls_back_to_cold_solve() {
    // Coupled square-root methods cannot resume from X alone; solve_from is
    // documented to fall back to a full solve and must still be correct.
    let mut rng = Rng::seed_from(5);
    let w = randmat::logspace(1e-2, 1.0, 8);
    let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
    let mut s = registry::resolve("prism5-sqrt").unwrap();
    let cold = s.solve(&a, &mut rng);
    let warm = s.solve_from(&a, &cold.primary, &mut rng);
    assert!(warm.log.converged);
    let back = prism::linalg::gemm::matmul(&warm.primary, &warm.primary);
    assert!(back.sub(&a).max_abs() < 1e-6);
}

// ───────────────────────── observer streaming ─────────────────────────

#[test]
fn observer_streams_one_event_per_iteration() {
    let mut rng = Rng::seed_from(6);
    let a = randmat::gaussian(&mut rng, 20, 10);
    let mut s = registry::resolve("prism5-polar").unwrap();
    let events: Arc<Mutex<Vec<(usize, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = Arc::clone(&events);
    s.set_observer(Some(Box::new(move |ev| {
        sink.lock().unwrap().push((ev.iter, ev.residual));
    })));
    let out = s.solve(&a, &mut rng);
    s.set_observer(None);
    let n_events = {
        let seen = events.lock().unwrap();
        assert_eq!(seen.len(), out.log.iters());
        for (k, (iter, res)) in seen.iter().enumerate() {
            assert_eq!(*iter, k);
            assert_eq!(*res, out.log.residuals[k + 1], "stream must mirror the log");
        }
        seen.len()
    };
    // Removing the observer stops the stream but not the solver.
    let out2 = s.solve(&a, &mut rng);
    assert!(out2.log.converged);
    assert_eq!(events.lock().unwrap().len(), n_events, "no events after removal");
}

// ───────────────────── trait-object service pattern ─────────────────────

#[test]
fn solvers_compose_as_trait_objects() {
    let mut rng = Rng::seed_from(7);
    let w = randmat::logspace(1e-2, 1.0, 9);
    let spd = randmat::sym_with_spectrum(&mut rng, 9, &w);
    let mut bank: Vec<Box<dyn MatFnSolver>> = vec![
        Box::new(registry::resolve("prism5-invsqrt").unwrap()),
        Box::new(registry::resolve("newton-invsqrt").unwrap()),
        Box::new(registry::resolve("eigen-invsqrt").unwrap()),
    ];
    for s in bank.iter_mut() {
        let out = s.solve(&spd, &mut rng);
        assert!(out.log.converged, "{}", s.name());
        let prod = prism::linalg::gemm::matmul(
            &prism::linalg::gemm::matmul(&out.primary, &spd),
            &out.primary,
        );
        assert!(
            prod.sub(&Mat::eye(9)).max_abs() < 1e-4,
            "{}: not an inverse sqrt",
            s.name()
        );
    }
}
