//! Tier-chaos: the seeded fault-injection suite for the coordinator's
//! supervision layer (`coordinator::supervise` + `runtime::faultinject`).
//!
//! Every test here mutates process-global fault state (the engines consult
//! one installed [`FaultPlan`]), so the whole suite serializes on one lock
//! and every test clears the plan on exit — including panicking exits —
//! via a drop guard. CI additionally runs this binary with
//! `--test-threads=1` under a hard wall-clock timeout, because the failure
//! mode these tests exist to catch is a *hang* (a lost job that `drain`
//! waits on forever).
//!
//! The acceptance contract (ISSUE: robustness): a 16-job burst with a
//! scripted NaN iterate, one worker panic, and one expired deadline must
//! drain to exactly 16 results — each failure typed — and the unaffected
//! jobs must be bit-identical to a fault-free run at the same seed and
//! worker count.

use prism::config::{Admission, Backend, ServiceConfig};
use prism::coordinator::service::{JobKind, Service};
use prism::linalg::Mat;
use prism::randmat;
use prism::rng::Rng;
use prism::runtime::faultinject::{self, Fault, FaultPlan};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

static SUITE: Mutex<()> = Mutex::new(());

/// Suite lock + cleanup: holds the serialization guard and clears any
/// installed fault plan when dropped, so one failing test cannot leak a
/// plan into the next (or into a later run of the same process).
struct ChaosGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        faultinject::clear();
    }
}

fn chaos_lock() -> ChaosGuard {
    // A previous test panicking while holding the lock poisons it; the
    // global fault state is re-initialized per test, so just take it.
    ChaosGuard(SUITE.lock().unwrap_or_else(std::sync::PoisonError::into_inner))
}

fn cfg(workers: usize, max_batch: usize, faults: Option<&str>) -> ServiceConfig {
    ServiceConfig {
        workers,
        queue_cap: 32,
        admission: Admission::Block,
        max_batch,
        sketch_p: 8,
        max_iters: 40,
        tol: None,
        precision: prism::matfn::Precision::F64,
        solver_cache_cap: 32,
        gemm_threads: 1,
        stream_residuals: false,
        gemm_block: None,
        gemm_kernel: None,
        faults: faults.map(str::to_string),
        linger: None,
        cache_snapshot: None,
    }
}

/// Same-shape SPD burst inputs (one route, so batching/seeding is the
/// simple dense-id case the determinism argument needs).
fn burst_inputs(n: usize, count: usize) -> Vec<Mat> {
    let mut rng = Rng::seed_from(11);
    let w = randmat::logspace(0.05, 1.0, n);
    (0..count).map(|_| randmat::sym_with_spectrum(&mut rng, n, &w)).collect()
}

/// The headline acceptance test. Faults pin `workers = 1, max_batch = 1`:
/// each batch is one job seeded by its own id (`batch_stream_seed`), and
/// the single worker sees jobs in submission order — so the scripted event
/// indices name exact victims, and removing a victim's solve can never
/// perturb any other job's RNG stream.
///
/// Event audit (ids are dense, 1-based, in submission order; `nan` counts
/// engine runs from install, 0-based; `panic` counts worker-accepted jobs,
/// 1-based; job 13's zero TTL expires it before solving, so it advances
/// neither count):
///
/// ```text
/// jobs 1-4   → solves 0-3
/// job  5     → solve 4   ← nan:solve=4,iter=1 → diverges → damp rung
///              (rescue)  ← solve 5 (the escalation retry)
/// jobs 6-8   → solves 6-8, accepted #6-#8
/// job  9     → accepted #9 ← panic:worker=0,job=9 → no solve, restart
/// jobs 10-12 → solves 9-11
/// job  13    → expired (deadline), never accepted
/// jobs 14-16 → solves 12-14
/// ```
#[test]
fn chaos_burst_every_job_accounted_and_peers_bit_identical() {
    let _guard = chaos_lock();
    let inputs = burst_inputs(8, 16);

    let svc = Service::start(
        cfg(1, 1, Some("nan:solve=4,iter=1;panic:worker=0,job=9")),
        Backend::Prism5,
        42,
    )
    .expect("valid chaos config");
    for (i, a) in inputs.iter().enumerate() {
        if i == 12 {
            svc.submit_with_deadline(i, JobKind::InvSqrt { eps: 0.0 }, a.clone(), Duration::ZERO)
                .unwrap();
        } else {
            svc.submit(i, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
        }
    }
    let mut results =
        svc.drain_timeout(Duration::from_secs(60)).expect("faulted burst must still drain");
    assert_eq!(results.len(), 16, "exactly one result per submitted job");
    results.sort_by_key(|r| r.id);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.id, i as u64 + 1, "accepted ids are dense in submission order");
    }

    // Job 5: the poisoned solve diverged, the escalation ladder rescued it.
    let rescued = &results[4];
    assert!(
        rescued.error.is_none(),
        "escalation must rescue the NaN-poisoned solve, got error {:?}",
        rescued.error
    );
    let path = rescued.fallback.as_deref().expect("a rescued job records its escalation path");
    assert!(path.contains("damp"), "f64 InvSqrt escalates via the damping rung, got '{path}'");
    assert!(!rescued.result.has_non_finite());

    // Job 9: its worker panicked before solving; typed error, no result lost.
    let panicked = &results[8];
    let err = panicked.error.as_deref().expect("the panicked job must carry a typed error");
    assert!(err.contains("panic"), "got '{err}'");
    assert_eq!(panicked.iters, 0);
    assert!(panicked.final_residual.is_nan());

    // Job 13: expired in the queue; typed error, counted, never solved.
    let expired = &results[12];
    let err = expired.error.as_deref().expect("the expired job must carry a typed error");
    assert!(err.contains("deadline"), "got '{err}'");

    let counter = |name: &str| svc.metrics.counter(name).get();
    assert_eq!(counter("service.jobs_submitted"), 16);
    assert_eq!(counter("service.worker_panics"), 1);
    assert_eq!(counter("service.worker_restarts"), 1);
    assert_eq!(counter("service.jobs_escalated"), 1);
    assert_eq!(counter("service.jobs_expired"), 1);
    assert_eq!(counter("service.jobs_failed"), 1, "only the panicked job is lost");
    assert_eq!(counter("service.jobs_done"), 14, "13 clean solves + 1 rescue");
    drop(svc);

    // Fault-free run at the same seed and worker count: the 13 unaffected
    // jobs must be bit-identical — a fault never perturbs its burst peers.
    faultinject::clear();
    let svc = Service::start(cfg(1, 1, None), Backend::Prism5, 42).expect("valid clean config");
    for (i, a) in inputs.iter().enumerate() {
        svc.submit(i, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
    }
    let mut clean = svc.drain_timeout(Duration::from_secs(60)).expect("clean burst must drain");
    assert_eq!(clean.len(), 16);
    clean.sort_by_key(|r| r.id);
    for (f, c) in results.iter().zip(&clean) {
        assert!(c.error.is_none(), "clean run must not fail job {}", c.id);
        if matches!(f.id, 5 | 9 | 13) {
            continue; // the scripted victims
        }
        assert!(f.error.is_none());
        assert_eq!(
            f.result, c.result,
            "job {}: a fault elsewhere in the burst perturbed this job's result",
            f.id
        );
    }
}

/// Shutdown under load: drop the handle mid-burst — with a panic, an
/// expired deadline, and a cancellation in flight — and check through the
/// (shared) metrics registry that every admitted job was executed and
/// counted rather than silently discarded. `Drop` flushes the router and
/// joins the workers, so by the time `drop(svc)` returns the counters are
/// final even though no result was ever fetched.
#[test]
fn shutdown_under_load_accounts_for_every_submitted_job() {
    let _guard = chaos_lock();
    let inputs = burst_inputs(8, 12);
    let svc = Service::start(cfg(1, 1, Some("panic:worker=0,job=2;delay:ms=1")), Backend::Prism5, 7)
        .expect("valid chaos config");
    let metrics = Arc::clone(&svc.metrics);
    for (i, a) in inputs.iter().enumerate() {
        if i == 5 {
            svc.submit_with_deadline(i, JobKind::InvSqrt { eps: 0.0 }, a.clone(), Duration::ZERO)
                .unwrap();
        } else {
            svc.submit(i, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
        }
    }
    // Racing the worker on purpose: job 12 is either still pending (counted
    // cancelled) or already solved (counted done) — both keep the identity.
    assert!(svc.cancel(12), "id 12 was assigned, so the mark must be accepted");
    assert!(!svc.cancel(99), "an id the service never assigned is refused");
    drop(svc);

    let c = |name: &str| metrics.counter(name).get();
    assert_eq!(c("service.jobs_submitted"), 12);
    let accounted = c("service.jobs_done")
        + c("service.jobs_failed")
        + c("service.jobs_expired")
        + c("service.jobs_cancelled")
        + c("service.jobs_rejected");
    assert_eq!(accounted, 12, "every admitted job must be executed and counted across shutdown");
    assert_eq!(c("service.worker_panics"), 1, "worker 0's 2nd accepted job is scripted to panic");
    assert_eq!(c("service.worker_restarts"), 1);
}

/// The `delay` fault stalls dispatch (inside `submit`, since `max_batch=1`
/// dispatches eagerly) by a fixed, scripted amount — widening queue-time
/// race windows deterministically — without affecting any result.
#[test]
fn scripted_dispatch_delay_stalls_dispatch_measurably() {
    let _guard = chaos_lock();
    let inputs = burst_inputs(6, 3);
    let svc = Service::start(cfg(1, 1, Some("delay:ms=20")), Backend::Prism5, 3)
        .expect("valid chaos config");
    let sw = Instant::now();
    for (i, a) in inputs.iter().enumerate() {
        svc.submit(i, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
    }
    assert!(
        sw.elapsed() >= Duration::from_millis(60),
        "3 dispatches under delay:ms=20 must take ≥ 60 ms, took {:?}",
        sw.elapsed()
    );
    let results = svc.drain_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(results.len(), 3);
    assert!(results.iter().all(|r| r.error.is_none()), "a delay is not a failure");
}

/// Hook semantics deferred out of `faultinject`'s unit tests (they mutate
/// the process-global plan): event counting, exact victim addressing,
/// counter reset on re-install, and full inertness after [`clear`].
#[test]
fn install_hooks_count_events_and_clear_restores_inertness() {
    let _guard = chaos_lock();
    let plan = FaultPlan::parse("nan:solve=2,iter=3;panic:worker=1,job=4;delay:ms=7").unwrap();
    faultinject::install(plan);
    assert!(faultinject::active());
    // Engine runs count 0-based from install; only run 2 is a victim.
    assert_eq!(faultinject::begin_solve(), None);
    assert_eq!(faultinject::begin_solve(), None);
    assert_eq!(faultinject::begin_solve(), Some(3));
    assert_eq!(faultinject::begin_solve(), None);
    assert!(!faultinject::should_panic(0, 4), "wrong worker must not fire");
    assert!(!faultinject::should_panic(1, 3), "wrong job sequence must not fire");
    assert!(faultinject::should_panic(1, 4));
    // The hook itself is stateless (fires on every matching query); the
    // once-only behaviour lives in the worker's accepted-job counter, which
    // survives the restart and never repeats a sequence number.
    assert!(faultinject::should_panic(1, 4));
    assert_eq!(faultinject::dispatch_delay_ms(), Some(7));
    // Re-install resets the solve counter.
    faultinject::install(FaultPlan::parse("nan:solve=0,iter=1").unwrap());
    assert_eq!(faultinject::begin_solve(), Some(1));
    faultinject::clear();
    assert!(!faultinject::active());
    assert_eq!(faultinject::begin_solve(), None, "cleared hooks must be inert");
    assert!(!faultinject::should_panic(1, 4));
    assert_eq!(faultinject::dispatch_delay_ms(), None);
}

/// `PALLAS_FAULTS` is the env-var route into the same validated parser the
/// TOML/CLI specs use: absent/empty → no plan, well-formed → the parsed
/// plan, malformed → a typed config error (never a silently ignored spec).
#[test]
fn plan_from_env_validates_like_every_other_spec_source() {
    let _guard = chaos_lock();
    std::env::remove_var("PALLAS_FAULTS");
    assert_eq!(faultinject::plan_from_env().unwrap(), None);
    std::env::set_var("PALLAS_FAULTS", "  ");
    assert_eq!(faultinject::plan_from_env().unwrap(), None, "blank spec means no plan");
    std::env::set_var("PALLAS_FAULTS", "delay:ms=2");
    assert_eq!(
        faultinject::plan_from_env().unwrap(),
        Some(FaultPlan { faults: vec![Fault::DelayDispatch { ms: 2 }] })
    );
    std::env::set_var("PALLAS_FAULTS", "explode:now=1");
    assert!(
        matches!(faultinject::plan_from_env(), Err(prism::util::Error::Config(_))),
        "a malformed env spec must be a typed config error"
    );
    std::env::remove_var("PALLAS_FAULTS");
}
