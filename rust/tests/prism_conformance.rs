//! Conformance property suite: every Table-1 iteration engine — classic and
//! PRISM variants — plus the baselines, checked against eigendecomposition /
//! SVD ground truth (`baselines::eigen_fn`) on randomly drawn spectra, with
//! the `IterationLog` invariants asserted on every run.
//!
//! All engines are reached exclusively through the unified `matfn` API
//! (registry names / `Solver::new` specs), so this suite is also the
//! conformance check for the solver surface itself: per-variant the solver
//! is planned **once** and reused across every case, exercising the
//! cross-call workspace path on mixed shapes.
//!
//! Dimensions are kept small (n ≤ 14) and iteration budgets generous so the
//! 64-case-per-engine suite stays CI-sized while still sweeping condition
//! numbers across several orders of magnitude.

use prism::baselines::eigen_fn;
use prism::linalg::eigen::symmetric_eigen;
use prism::linalg::gemm::matmul;
use prism::linalg::Mat;
use prism::matfn::{registry, MatFnTask, Precision, Solver, SolverSpec};
use prism::prism::driver::{IterationLog, StopRule};
use prism::ptest::{gens, Prop};
use prism::randmat;
use prism::rng::Rng;
use std::sync::Mutex;

const CASES: usize = 64;

/// Plan solvers from registry names with a common stop rule; panics on a bad
/// name so conformance failures point at the registry, not the harness.
/// Behind a `Mutex` because `Prop::run` takes an `Fn` closure while a
/// reused `Solver` needs `&mut` for its workspace.
fn solvers(names: &[&str], stop: StopRule) -> Mutex<Vec<Solver>> {
    Mutex::new(
        names
            .iter()
            .map(|n| {
                let mut s = registry::resolve(n).unwrap_or_else(|e| panic!("{n}: {e}"));
                s.set_stop(stop);
                s
            })
            .collect(),
    )
}

/// Structural invariants every run must satisfy; when `monotone` is set (the
/// contraction-style engines) the residual trajectory of a *converged* run
/// must also be non-increasing up to a 10% numerical slack.
fn log_invariants(log: &IterationLog, monotone: bool, what: &str) {
    assert_eq!(log.alphas.len(), log.iters(), "{what}: alphas/iters");
    assert_eq!(log.residuals.len(), log.iters() + 1, "{what}: residual trail");
    assert_eq!(log.times_s.len(), log.iters(), "{what}: times trail");
    assert!(log.final_residual() >= 0.0, "{what}: negative residual");
    if monotone && log.converged {
        for w in log.residuals.windows(2) {
            assert!(
                w[1] <= w[0] * 1.1,
                "{what}: residual went up {} -> {}",
                w[0],
                w[1]
            );
        }
    }
}

fn spd(rng: &mut Rng, n: usize, wmin: f64) -> Mat {
    gens::spd(rng, n, wmin)
}

// ───────────────────── polar (Table 1 rows 3–4) ─────────────────────

#[test]
fn conformance_polar_vs_svd() {
    let stop = StopRule::default().with_max_iters(300).with_tol(1e-8);
    // "ns-polar" is classic degree-5; classic degree-3 needs an explicit spec.
    let variants = solvers(&["ns-polar", "prism3-polar", "prism5-polar"], stop);
    variants.lock().unwrap().push(
        Solver::new(MatFnTask::Polar, SolverSpec::ns_classic(1).with_stop(stop)).unwrap(),
    );
    Prop::new("polar vs svd").cases(CASES).run(|rng| {
        let mut variants = variants.lock().unwrap();
        let n = gens::usize_in(rng, 4, 12);
        let m = n + gens::usize_in(rng, 0, 6);
        let kappa = gens::f64_log(rng, 2.0, 1e2);
        let a = gens::ill_conditioned(rng, m, n, kappa);
        let exact = eigen_fn::polar_eigen(&a);
        for s in variants.iter_mut() {
            let name = s.name();
            let out = s.solve(&a, rng);
            assert!(out.log.converged, "{name}: κ={kappa} res={}", out.log.final_residual());
            let err = out.primary.sub(&exact).max_abs();
            assert!(err < 1e-4, "{name}: κ={kappa} polar err {err}");
            log_invariants(&out.log, true, &name);
        }
    });
}

// ───────────── rectangular polar (Gram / direct routes) ─────────────

/// Full-rank m × n operand with σ ∈ [0.1, 1] (κ(A) = 10 ⇒ κ(AᵀA) = 100 on
/// the Gram route) and its SVD polar factor U·Vᵀ.
fn rect_grid_case(rng: &mut Rng, m: usize, n: usize) -> (Mat, Mat) {
    let s = randmat::logspace(0.1, 1.0, m.min(n));
    let a = if m >= n {
        randmat::with_spectrum(rng, m, n, &s)
    } else {
        randmat::with_spectrum(rng, n, m, &s).transpose()
    };
    let exact = eigen_fn::polar_eigen(&a);
    (a, exact)
}

#[test]
fn conformance_rectpolar_vs_svd() {
    // Adversarial aspect grid: every (m, n) cross-combination of
    // {8, 63, 256}. Under `RectStrategy::Auto` the squares take the direct
    // route and every rectangular combination (aspect ≥ 2 throughout) the
    // Gram route, so both routes and both orientations are pinned against
    // U·Vᵀ at the f64 bar. One solver is reused across all nine shapes,
    // exercising the cross-call workspace path on mixed rect shapes.
    let stop = StopRule::default().with_max_iters(300).with_tol(1e-11);
    let mut rng = Rng::seed_from(41);
    let mut s = registry::resolve("prism5-rectpolar").unwrap();
    s.set_stop(stop);
    for &m in &[8usize, 63, 256] {
        for &n in &[8usize, 63, 256] {
            let (a, exact) = rect_grid_case(&mut rng, m, n);
            let out = s.solve(&a, &mut rng);
            let err = out.primary.sub(&exact).max_abs();
            assert!(err < 1e-8, "rectpolar {m}x{n}: err {err}");
            log_invariants(&out.log, false, &format!("rectpolar {m}x{n}"));
        }
    }
}

#[test]
fn conformance_rectpolar_mixed_vs_svd() {
    // Same grid at `Precision::Mixed` (f32 iterate under the f64 residual
    // guard + one f64 cleanup step): the contract bar is 1e-4.
    let stop = StopRule::default().with_max_iters(300).with_tol(1e-9);
    let mut rng = Rng::seed_from(43);
    let mut s = registry::resolve("prism5-rectpolar").unwrap();
    s.set_stop(stop);
    s.spec_mut().precision = Precision::Mixed;
    for &m in &[8usize, 63, 256] {
        for &n in &[8usize, 63, 256] {
            let (a, exact) = rect_grid_case(&mut rng, m, n);
            let out = s.solve(&a, &mut rng);
            let err = out.primary.sub(&exact).max_abs();
            assert!(err < 1e-4, "rectpolar mixed {m}x{n}: err {err}");
            log_invariants(&out.log, false, &format!("rectpolar mixed {m}x{n}"));
        }
    }
}

// ─────────────── coupled sqrt / inverse sqrt (rows 1–2) ───────────────

#[test]
fn conformance_sqrt_vs_eigen() {
    let stop = StopRule::default().with_max_iters(300).with_tol(1e-9);
    let variants = solvers(&["ns-sqrt", "prism3-sqrt", "prism5-sqrt"], stop);
    Prop::new("sqrt vs eigen").cases(CASES).run(|rng| {
        let mut variants = variants.lock().unwrap();
        let n = gens::usize_in(rng, 4, 12);
        let wmin = gens::f64_log(rng, 1e-3, 0.5);
        let a = spd(rng, n, wmin);
        let exact_sqrt = eigen_fn::sqrt_eigen(&a);
        let exact_inv = eigen_fn::inv_sqrt_eigen(&a, 0.0);
        for s in variants.iter_mut() {
            let name = s.name();
            let out = s.solve(&a, rng);
            assert!(out.log.converged, "{name}: wmin={wmin} res={}", out.log.final_residual());
            let es = out.primary.sub(&exact_sqrt).max_abs();
            assert!(es < 1e-4, "{name}: sqrt err {es} (wmin={wmin})");
            let inv = out.secondary.as_ref().expect("coupled inverse root");
            let ei = inv.sub(&exact_inv).max_abs();
            assert!(ei < 1e-3, "{name}: inv-sqrt err {ei} (wmin={wmin})");
            log_invariants(&out.log, true, &name);
        }
    });
}

// ───────────────────────── sign (§4) ─────────────────────────

#[test]
fn conformance_sign_vs_eigen() {
    let stop = StopRule::default().with_max_iters(300).with_tol(1e-8);
    let variants = solvers(&["ns-sign", "prism3-sign", "prism5-sign"], stop);
    variants
        .lock()
        .unwrap()
        .push(Solver::new(MatFnTask::Sign, SolverSpec::ns_classic(1).with_stop(stop)).unwrap());
    Prop::new("sign vs eigen").cases(CASES).run(|rng| {
        let mut variants = variants.lock().unwrap();
        let n = gens::usize_in(rng, 4, 12);
        let lmin = gens::f64_log(rng, 1e-2, 0.5);
        // Symmetric with eigenvalues of both signs, |λ| ∈ [lmin, 1].
        let w: Vec<f64> = randmat::logspace(lmin, 1.0, n)
            .iter()
            .enumerate()
            .map(|(i, &x)| if i % 2 == 0 { x } else { -x })
            .collect();
        let a = randmat::sym_with_spectrum(rng, n, &w);
        let exact = eigen_fn::sign_eigen(&a);
        for s in variants.iter_mut() {
            let name = s.name();
            let out = s.solve(&a, rng);
            assert!(
                out.log.converged,
                "sign {name}: lmin={lmin} res={}",
                out.log.final_residual()
            );
            let err = out.primary.sub(&exact).max_abs();
            assert!(err < 1e-4, "sign {name}: err {err} (lmin={lmin})");
            log_invariants(&out.log, true, &name);
        }
    });
}

// ───────────────── coupled inverse Newton (row 5) ─────────────────

#[test]
fn conformance_inv_root_vs_eigen() {
    let stop = StopRule::default().with_max_iters(500).with_tol(1e-9);
    Prop::new("inv root vs eigen").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let wmin = gens::f64_log(rng, 1e-2, 0.5);
        let p = *gens::choice(rng, &[1usize, 2, 4]);
        let a = spd(rng, n, wmin);
        let exact = eigen_fn::inv_root_eigen(&a, p, 0.0).unwrap();
        for method in ["invnewton-classic", "invnewton"] {
            let name = format!("{method}-invroot{p}");
            let mut s = registry::resolve(&name).unwrap();
            s.set_stop(stop);
            let out = s.solve(&a, rng);
            assert!(
                out.log.converged,
                "{name}: wmin={wmin} res={}",
                out.log.final_residual()
            );
            let err = out.primary.sub(&exact).max_abs();
            assert!(err < 1e-3, "{name}: err {err} (wmin={wmin})");
            log_invariants(&out.log, true, &name);
        }
    });
}

// ───────────────────── DB-Newton (row 6) ─────────────────────

#[test]
fn conformance_db_newton_vs_eigen() {
    let stop = StopRule::default().with_max_iters(150).with_tol(1e-10);
    let variants = solvers(&["newton-classic-sqrt", "newton-sqrt"], stop);
    Prop::new("db-newton vs eigen").cases(CASES).run(|rng| {
        let mut variants = variants.lock().unwrap();
        let n = gens::usize_in(rng, 4, 12);
        let wmin = gens::f64_log(rng, 1e-4, 0.5);
        let a = spd(rng, n, wmin);
        let exact_sqrt = eigen_fn::sqrt_eigen(&a);
        for s in variants.iter_mut() {
            let name = s.name();
            let out = s.solve(&a, rng);
            assert!(
                out.log.converged,
                "db-newton {name}: wmin={wmin} res={}",
                out.log.final_residual()
            );
            let err = out.primary.sub(&exact_sqrt).max_abs();
            assert!(err < 1e-5, "db-newton {name}: sqrt err {err} (wmin={wmin})");
            let inv = out.secondary.as_ref().expect("coupled inverse root");
            let prod = matmul(&out.primary, inv);
            assert!(
                prod.sub(&Mat::eye(n)).max_abs() < 1e-5,
                "db-newton {name}: X·Y ≠ I (wmin={wmin})"
            );
            log_invariants(&out.log, false, &name);
        }
    });
}

// ───────────────── Chebyshev inverse (row 7) ─────────────────

#[test]
fn conformance_chebyshev_vs_eigen() {
    let stop = StopRule::default().with_max_iters(500).with_tol(1e-8);
    let variants = solvers(&["cheb-classic-inverse", "cheb-inverse"], stop);
    Prop::new("chebyshev vs eigen").cases(CASES).run(|rng| {
        let mut variants = variants.lock().unwrap();
        let n = gens::usize_in(rng, 4, 12);
        let wmin = gens::f64_log(rng, 1e-2, 0.5);
        let a = spd(rng, n, wmin);
        let exact = symmetric_eigen(&a).apply_fn(|w| 1.0 / w);
        for s in variants.iter_mut() {
            let name = s.name();
            let out = s.solve(&a, rng);
            assert!(
                out.log.converged,
                "chebyshev {name}: wmin={wmin} res={}",
                out.log.final_residual()
            );
            let err = out.primary.sub(&exact).max_abs();
            // ‖A⁻¹‖ grows like 1/wmin, so bound the error relative to it.
            let tol = 1e-5 / wmin;
            assert!(err < tol, "chebyshev {name}: err {err} > {tol} (wmin={wmin})");
            log_invariants(&out.log, false, &name);
        }
    });
}

// ───────────── baselines: PolarExpress and CANS ─────────────

#[test]
fn conformance_polar_express_vs_svd() {
    // One solver for the whole suite: the Remez schedule is built once in
    // Solver::new and the workspace is reused across every case.
    let pe = solvers(&["pe-polar"], StopRule::default().with_max_iters(60).with_tol(1e-8));
    Prop::new("polar-express vs svd").cases(CASES).run(|rng| {
        let mut pe = pe.lock().unwrap();
        let pe = &mut pe[0];
        let n = gens::usize_in(rng, 4, 12);
        let m = n + gens::usize_in(rng, 0, 6);
        // Stay on the schedule's design interval σ_min ≥ 1e-3 (paper tuning);
        // off-design degradation is covered by the Fig. 1 unit tests.
        let smin = gens::f64_log(rng, 2e-3, 0.5);
        let s = randmat::logspace(smin, 1.0, n);
        let a = randmat::with_spectrum(rng, m, n, &s);
        let exact = eigen_fn::polar_eigen(&a);
        let out = pe.solve(&a, rng);
        assert!(out.log.converged, "pe: smin={smin} res={}", out.log.final_residual());
        let err = out.primary.sub(&exact).max_abs();
        assert!(err < 1e-4, "pe: err {err} (smin={smin})");
        log_invariants(&out.log, false, "polar-express");
    });
}

#[test]
fn conformance_cans_vs_svd() {
    let cans = solvers(&["cans-polar"], StopRule::default().with_max_iters(200).with_tol(1e-8));
    Prop::new("cans vs svd").cases(CASES).run(|rng| {
        let mut cans = cans.lock().unwrap();
        let cans = &mut cans[0];
        let n = gens::usize_in(rng, 4, 12);
        let m = n + gens::usize_in(rng, 0, 6);
        let kappa = gens::f64_log(rng, 2.0, 1e2);
        let a = gens::ill_conditioned(rng, m, n, kappa);
        let exact = eigen_fn::polar_eigen(&a);
        let out = cans.solve(&a, rng);
        assert!(out.log.converged, "cans: κ={kappa} res={}", out.log.final_residual());
        let err = out.primary.sub(&exact).max_abs();
        assert!(err < 1e-4, "cans: err {err} (κ={kappa})");
        // The early rescale phase may bump the residual, so no monotonicity.
        log_invariants(&out.log, false, "cans");
    });
}

// ───────────── eigen baseline through the same trait ─────────────

#[test]
fn conformance_eigen_solvers_are_exact() {
    let mut rng = Rng::seed_from(99);
    let w = randmat::logspace(1e-2, 1.0, 9);
    let a = randmat::sym_with_spectrum(&mut rng, 9, &w);
    for name in ["eigen-sqrt", "eigen-invsqrt", "eigen-inverse", "eigen-sign"] {
        let mut s = registry::resolve(name).unwrap();
        let out = s.solve(&a, &mut rng);
        assert!(out.log.converged, "{name}");
        assert!(!out.primary.has_non_finite(), "{name}");
    }
    let mut s = registry::resolve("eigen-polar").unwrap();
    let g = randmat::gaussian(&mut rng, 12, 7);
    let out = s.solve(&g, &mut rng);
    let exact = eigen_fn::polar_eigen(&g);
    assert!(out.primary.sub(&exact).max_abs() < 1e-10);
}
