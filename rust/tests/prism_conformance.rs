//! Conformance property suite: every Table-1 iteration engine — classic and
//! PRISM variants — plus the two matmul-only baselines, checked against
//! eigendecomposition/SVD ground truth (`baselines::eigen_fn`) on randomly
//! drawn spectra, with the `IterationLog` invariants asserted on every run.
//!
//! Dimensions are kept small (n ≤ 14) and iteration budgets generous so the
//! 64-case-per-engine suite stays CI-sized while still sweeping condition
//! numbers across several orders of magnitude.

use prism::baselines::cans::{polar_cans, CansOpts};
use prism::baselines::eigen_fn;
use prism::baselines::polar_express::PolarExpress;
use prism::linalg::eigen::symmetric_eigen;
use prism::linalg::gemm::matmul;
use prism::linalg::Mat;
use prism::prism::chebyshev::{chebyshev_inverse, ChebyshevOpts};
use prism::prism::db_newton::{db_newton_prism, DbNewtonOpts};
use prism::prism::driver::{AlphaMode, IterationLog, StopRule};
use prism::prism::inverse_newton::{inv_root_prism, InvRootOpts};
use prism::prism::polar::{polar_prism, PolarOpts};
use prism::prism::sign::{sign_prism, SignOpts};
use prism::prism::sqrt::{sqrt_prism, SqrtOpts};
use prism::ptest::{gens, Prop};
use prism::randmat;
use prism::rng::Rng;

const CASES: usize = 64;

/// Structural invariants every run must satisfy; when `monotone` is set (the
/// contraction-style engines) the residual trajectory of a *converged* run
/// must also be non-increasing up to a 10% numerical slack.
fn log_invariants(log: &IterationLog, monotone: bool, what: &str) {
    assert_eq!(log.alphas.len(), log.iters(), "{what}: alphas/iters");
    assert_eq!(log.residuals.len(), log.iters() + 1, "{what}: residual trail");
    assert_eq!(log.times_s.len(), log.iters(), "{what}: times trail");
    assert!(log.final_residual() >= 0.0, "{what}: negative residual");
    if monotone && log.converged {
        for w in log.residuals.windows(2) {
            assert!(
                w[1] <= w[0] * 1.1,
                "{what}: residual went up {} -> {}",
                w[0],
                w[1]
            );
        }
    }
}

fn spd(rng: &mut Rng, n: usize, wmin: f64) -> Mat {
    gens::spd(rng, n, wmin)
}

// ───────────────────── polar (Table 1 rows 3–4) ─────────────────────

#[test]
fn conformance_polar_vs_svd() {
    let variants: &[(&str, usize, AlphaMode)] = &[
        ("classic-d1", 1, AlphaMode::Classic),
        ("classic-d2", 2, AlphaMode::Classic),
        ("prism-3", 1, AlphaMode::Sketched { p: 8 }),
        ("prism-5", 2, AlphaMode::Sketched { p: 8 }),
    ];
    Prop::new("polar vs svd").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let m = n + gens::usize_in(rng, 0, 6);
        let kappa = gens::f64_log(rng, 2.0, 1e2);
        let a = gens::ill_conditioned(rng, m, n, kappa);
        let exact = eigen_fn::polar_eigen(&a);
        let stop = StopRule::default().with_max_iters(300).with_tol(1e-8);
        for &(name, d, alpha) in variants {
            let out = polar_prism(&a, &PolarOpts { d, alpha, stop }, rng);
            assert!(out.log.converged, "{name}: κ={kappa} res={}", out.log.final_residual());
            let err = out.q.sub(&exact).max_abs();
            assert!(err < 1e-4, "{name}: κ={kappa} polar err {err}");
            log_invariants(&out.log, true, name);
        }
    });
}

// ─────────────── coupled sqrt / inverse sqrt (rows 1–2) ───────────────

#[test]
fn conformance_sqrt_vs_eigen() {
    Prop::new("sqrt vs eigen").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let wmin = gens::f64_log(rng, 1e-3, 0.5);
        let a = spd(rng, n, wmin);
        let exact_sqrt = eigen_fn::sqrt_eigen(&a);
        let exact_inv = eigen_fn::inv_sqrt_eigen(&a, 0.0);
        let stop = StopRule::default().with_max_iters(300).with_tol(1e-9);
        for (name, opts) in [
            ("classic-ns", SqrtOpts::classic(2).with_stop(stop)),
            ("prism-3", SqrtOpts { d: 1, alpha: AlphaMode::Sketched { p: 8 }, stop }),
            ("prism-5", SqrtOpts { d: 2, alpha: AlphaMode::Sketched { p: 8 }, stop }),
        ] {
            let out = sqrt_prism(&a, &opts, rng);
            assert!(out.log.converged, "{name}: wmin={wmin} res={}", out.log.final_residual());
            let es = out.sqrt.sub(&exact_sqrt).max_abs();
            assert!(es < 1e-4, "{name}: sqrt err {es} (wmin={wmin})");
            let ei = out.inv_sqrt.sub(&exact_inv).max_abs();
            assert!(ei < 1e-3, "{name}: inv-sqrt err {ei} (wmin={wmin})");
            log_invariants(&out.log, true, name);
        }
    });
}

// ───────────────────────── sign (§4) ─────────────────────────

#[test]
fn conformance_sign_vs_eigen() {
    Prop::new("sign vs eigen").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let lmin = gens::f64_log(rng, 1e-2, 0.5);
        // Symmetric with eigenvalues of both signs, |λ| ∈ [lmin, 1].
        let w: Vec<f64> = randmat::logspace(lmin, 1.0, n)
            .iter()
            .enumerate()
            .map(|(i, &x)| if i % 2 == 0 { x } else { -x })
            .collect();
        let a = randmat::sym_with_spectrum(rng, n, &w);
        let exact = eigen_fn::sign_eigen(&a);
        let stop = StopRule::default().with_max_iters(300).with_tol(1e-8);
        for d in [1usize, 2] {
            for (name, alpha) in
                [("classic", AlphaMode::Classic), ("prism", AlphaMode::Sketched { p: 8 })]
            {
                let opts = SignOpts { d, alpha, stop, normalize: true };
                let out = sign_prism(&a, &opts, rng);
                assert!(
                    out.log.converged,
                    "sign {name} d={d}: lmin={lmin} res={}",
                    out.log.final_residual()
                );
                let err = out.s.sub(&exact).max_abs();
                assert!(err < 1e-4, "sign {name} d={d}: err {err} (lmin={lmin})");
                log_invariants(&out.log, true, name);
            }
        }
    });
}

// ───────────────── coupled inverse Newton (row 5) ─────────────────

#[test]
fn conformance_inv_root_vs_eigen() {
    Prop::new("inv root vs eigen").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let wmin = gens::f64_log(rng, 1e-2, 0.5);
        let p = *gens::choice(rng, &[1usize, 2, 4]);
        let a = spd(rng, n, wmin);
        let exact = eigen_fn::inv_root_eigen(&a, p, 0.0).unwrap();
        let stop = StopRule::default().with_max_iters(500).with_tol(1e-9);
        for (name, opts) in [
            ("classic", InvRootOpts::classic(p).with_stop(stop)),
            ("prism", InvRootOpts::prism(p).with_stop(stop)),
        ] {
            let out = inv_root_prism(&a, &opts, rng);
            assert!(
                out.log.converged,
                "invroot {name} p={p}: wmin={wmin} res={}",
                out.log.final_residual()
            );
            let err = out.inv_root.sub(&exact).max_abs();
            assert!(err < 1e-3, "invroot {name} p={p}: err {err} (wmin={wmin})");
            log_invariants(&out.log, true, name);
        }
    });
}

// ───────────────────── DB-Newton (row 6) ─────────────────────

#[test]
fn conformance_db_newton_vs_eigen() {
    Prop::new("db-newton vs eigen").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let wmin = gens::f64_log(rng, 1e-4, 0.5);
        let a = spd(rng, n, wmin);
        let exact_sqrt = eigen_fn::sqrt_eigen(&a);
        let stop = StopRule::default().with_max_iters(150).with_tol(1e-10);
        for (name, opts) in [
            ("classic", DbNewtonOpts::classic().with_stop(stop)),
            ("prism", DbNewtonOpts::prism().with_stop(stop)),
        ] {
            let out = db_newton_prism(&a, &opts, rng);
            assert!(
                out.log.converged,
                "db-newton {name}: wmin={wmin} res={}",
                out.log.final_residual()
            );
            let err = out.sqrt.sub(&exact_sqrt).max_abs();
            assert!(err < 1e-5, "db-newton {name}: sqrt err {err} (wmin={wmin})");
            let prod = matmul(&out.sqrt, &out.inv_sqrt);
            assert!(
                prod.sub(&Mat::eye(n)).max_abs() < 1e-5,
                "db-newton {name}: X·Y ≠ I (wmin={wmin})"
            );
            log_invariants(&out.log, false, name);
        }
    });
}

// ───────────────── Chebyshev inverse (row 7) ─────────────────

#[test]
fn conformance_chebyshev_vs_eigen() {
    Prop::new("chebyshev vs eigen").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let wmin = gens::f64_log(rng, 1e-2, 0.5);
        let a = spd(rng, n, wmin);
        let exact = symmetric_eigen(&a).apply_fn(|w| 1.0 / w);
        let stop = StopRule::default().with_max_iters(500).with_tol(1e-8);
        for (name, opts) in [
            ("classic", ChebyshevOpts::classic().with_stop(stop)),
            ("prism", ChebyshevOpts::prism().with_stop(stop)),
        ] {
            let out = chebyshev_inverse(&a, &opts, rng);
            assert!(
                out.log.converged,
                "chebyshev {name}: wmin={wmin} res={}",
                out.log.final_residual()
            );
            let err = out.inverse.sub(&exact).max_abs();
            // ‖A⁻¹‖ grows like 1/wmin, so bound the error relative to it.
            let tol = 1e-5 / wmin;
            assert!(err < tol, "chebyshev {name}: err {err} > {tol} (wmin={wmin})");
            log_invariants(&out.log, false, name);
        }
    });
}

// ───────────── baselines: PolarExpress and CANS ─────────────

#[test]
fn conformance_polar_express_vs_svd() {
    // Build the Remez schedule once; it is deterministic and reused across
    // cases (the per-case work is the iteration itself).
    let pe = PolarExpress::paper_default();
    Prop::new("polar-express vs svd").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let m = n + gens::usize_in(rng, 0, 6);
        // Stay on the schedule's design interval σ_min ≥ 1e-3 (paper tuning);
        // off-design degradation is covered by the Fig. 1 unit tests.
        let smin = gens::f64_log(rng, 2e-3, 0.5);
        let s = randmat::logspace(smin, 1.0, n);
        let a = randmat::with_spectrum(rng, m, n, &s);
        let exact = eigen_fn::polar_eigen(&a);
        let stop = StopRule::default().with_max_iters(60).with_tol(1e-8);
        let (q, log) = pe.polar(&a, &stop);
        assert!(log.converged, "pe: smin={smin} res={}", log.final_residual());
        let err = q.sub(&exact).max_abs();
        assert!(err < 1e-4, "pe: err {err} (smin={smin})");
        log_invariants(&log, false, "polar-express");
    });
}

#[test]
fn conformance_cans_vs_svd() {
    Prop::new("cans vs svd").cases(CASES).run(|rng| {
        let n = gens::usize_in(rng, 4, 12);
        let m = n + gens::usize_in(rng, 0, 6);
        let kappa = gens::f64_log(rng, 2.0, 1e2);
        let a = gens::ill_conditioned(rng, m, n, kappa);
        let exact = eigen_fn::polar_eigen(&a);
        let opts = CansOpts {
            stop: StopRule::default().with_max_iters(200).with_tol(1e-8),
            ..Default::default()
        };
        let (q, log) = polar_cans(&a, &opts, rng);
        assert!(log.converged, "cans: κ={kappa} res={}", log.final_residual());
        let err = q.sub(&exact).max_abs();
        assert!(err < 1e-4, "cans: err {err} (κ={kappa})");
        // The early rescale phase may bump the residual, so no monotonicity.
        log_invariants(&log, false, "cans");
    });
}
