//! End-to-end: TrainDriver + Muon over the AOT transformer artifacts.
//! Self-skips without `make artifacts`.

use prism::config::Backend;
use prism::coordinator::TrainDriver;
use prism::optim::adamw::AdamW;
use prism::optim::muon::Muon;
use prism::rng::Rng;
use prism::runtime::Runtime;
use prism::workload::MarkovCorpus;

fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

fn batches(
    corpus: &MarkovCorpus,
    rng: &mut Rng,
    batch: usize,
    seq: usize,
) -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
    corpus.sample_batch(rng, batch, seq)
}

#[test]
fn muon_training_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let mut driver = TrainDriver::new(&rt, 0.25).expect("driver");
    assert!(driver.num_params() > 50_000, "params: {}", driver.num_params());
    let mut rng = Rng::seed_from(11);
    let corpus = MarkovCorpus::generate(&mut rng, driver.vocab, 20_000);
    let mut opt = Muon::paper_default(Backend::Prism5, 1);
    opt.lr = 0.02;

    let (ex, ey) = batches(&corpus, &mut rng, driver.batch, driver.seq_len);
    let loss0 = driver.eval(&ex, &ey).expect("eval");
    for _ in 0..12 {
        let (xs, ys) = batches(&corpus, &mut rng, driver.batch, driver.seq_len);
        driver.step(&xs, &ys, &mut opt).expect("step");
    }
    let loss1 = driver.eval(&ex, &ey).expect("eval");
    assert!(
        loss1 < loss0 - 0.15,
        "muon-prism5 did not learn: {loss0} -> {loss1}"
    );
}

#[test]
fn adamw_training_also_works() {
    let Some(rt) = runtime() else { return };
    let mut driver = TrainDriver::new(&rt, 0.5).expect("driver");
    let mut rng = Rng::seed_from(12);
    let corpus = MarkovCorpus::generate(&mut rng, driver.vocab, 20_000);
    let mut opt = AdamW::new(3e-3, 0.0);
    let (ex, ey) = batches(&corpus, &mut rng, driver.batch, driver.seq_len);
    let loss0 = driver.eval(&ex, &ey).expect("eval");
    for _ in 0..12 {
        let (xs, ys) = batches(&corpus, &mut rng, driver.batch, driver.seq_len);
        driver.step(&xs, &ys, &mut opt).expect("step");
    }
    let loss1 = driver.eval(&ex, &ey).expect("eval");
    assert!(loss1 < loss0 - 0.1, "adamw did not learn: {loss0} -> {loss1}");
}

#[test]
fn step_rejects_wrong_batch_size() {
    let Some(rt) = runtime() else { return };
    let mut driver = TrainDriver::new(&rt, 0.1).expect("driver");
    let mut opt = AdamW::new(1e-3, 0.0);
    let xs = vec![vec![0u32; driver.seq_len]; driver.batch + 1];
    assert!(driver.step(&xs, &xs, &mut opt).is_err());
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let Some(rt) = runtime() else { return };
    let mut driver = TrainDriver::new(&rt, 0.5).expect("driver");
    let mut rng = Rng::seed_from(21);
    let corpus = MarkovCorpus::generate(&mut rng, driver.vocab, 20_000);
    let mut opt = AdamW::paper_default();

    // Train a few steps, checkpoint, train one more and note the loss.
    for _ in 0..3 {
        let (xs, ys) = batches(&corpus, &mut rng, driver.batch, driver.seq_len);
        driver.step(&xs, &ys, &mut opt).expect("step");
    }
    let path = std::env::temp_dir().join("prism_train_ckpt.bin");
    driver.save_checkpoint(&path).expect("save");
    let (ex, ey) = batches(&corpus, &mut rng, driver.batch, driver.seq_len);
    let loss_after_save = driver.eval(&ex, &ey).expect("eval");

    // Fresh driver (different init seed) must diverge from the trained one,
    // then match exactly after restoring the checkpoint.
    let mut fresh = TrainDriver::new(&rt, 0.9).expect("driver2");
    let loss_fresh = fresh.eval(&ex, &ey).expect("eval fresh");
    assert!((loss_fresh - loss_after_save).abs() > 1e-4, "fresh driver should differ");
    let step = fresh.load_checkpoint(&path).expect("load");
    assert_eq!(step, 3);
    let loss_restored = fresh.eval(&ex, &ey).expect("eval restored");
    assert!(
        (loss_restored - loss_after_save).abs() < 1e-6,
        "restored {loss_restored} vs saved {loss_after_save}"
    );
    let _ = std::fs::remove_file(&path);
}
