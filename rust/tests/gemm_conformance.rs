//! GEMM cross-check suite for the packed cache-blocked engine, run **once
//! per available microkernel** (scalar everywhere, plus AVX2/NEON where the
//! host supports them, forced via `GemmEngine::with_kernel`): every packed
//! path (plain, transposed forms, both SYRKs) against `matmul_naive` on an
//! adversarial shape grid straddling all blocking boundaries, the skinny
//! fast paths (thin-A / thin-B / dims-of-one GEMV), plus the determinism
//! contract — bit-identical output at pool sizes 1/2/4 (and 8) **per
//! kernel** — and a cross-check against the independent seed broadcast
//! kernel.
//!
//! Determinism is per-kernel: the SIMD kernels use fused multiply-add (one
//! rounding per step) where the scalar kernel rounds twice, so
//! cross-kernel **bit equality is NOT required or asserted** — kernels are
//! compared to the naive reference at tolerance instead.

use prism::linalg::gemm::{
    gemm_broadcast, matmul, matmul_a_bt, matmul_at_b, matmul_naive, matmul_naive32, syrk_a_at,
    syrk_at_a, GemmBlocking, GemmEngine, GemmScope, MicroKernel, Workspace,
};
use prism::linalg::{Mat, Mat32};
use prism::ptest::{gens, Prop};
use prism::rng::Rng;

/// `A·B` through the seed broadcast kernel — an independent implementation
/// (no packing, different tiling) retained for cross-checks.
fn broadcast_ref(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows());
    let (m, k) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    gemm_broadcast(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, n, k);
    c
}

fn assert_close(got: &Mat, want: &Mat, tol: f64, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    let err = got.sub(want).max_abs();
    assert!(err < tol, "{what}: err {err}");
}

/// Engines at pool sizes 1/2/4 pinned to one microkernel.
fn engines_for(kern: MicroKernel) -> [GemmEngine; 3] {
    [
        GemmEngine::with_threads(1).with_kernel(kern),
        GemmEngine::with_threads(2).with_kernel(kern),
        GemmEngine::with_threads(4).with_kernel(kern),
    ]
}

/// The satellite's adversarial grid: every m, n, k drawn from this set. The
/// values straddle the 8-row/4-col micro-tile (and with it the thin-A /
/// thin-B skinny routing thresholds), the MIN_PANEL_ROWS parallel threshold
/// (16), and force ragged edges on every packing path.
const ADVERSARIAL: &[usize] = &[1, 3, 7, 17, 63, 65, 100];

/// Full m×k×n cross product of the adversarial grid, once per available
/// kernel: the packed/skinny paths vs the naive reference within 1e-12, and
/// (where the parallel dispatch can engage) pool sizes 1/2/4 bit-identical.
#[test]
fn adversarial_shapes_match_naive_and_pools_agree() {
    for kern in MicroKernel::available() {
        let engines = engines_for(kern);
        let mut rng = Rng::seed_from(1);
        for &m in ADVERSARIAL {
            for &k in ADVERSARIAL {
                for &n in ADVERSARIAL {
                    let a = Mat::gaussian(&mut rng, m, k, 1.0);
                    let b = Mat::gaussian(&mut rng, k, n, 1.0);
                    let base = engines[0].matmul(&a, &b);
                    assert_close(
                        &base,
                        &matmul_naive(&a, &b),
                        1e-12,
                        &format!("{} {m}x{k}x{n}", kern.name()),
                    );
                    for e in &engines[1..] {
                        assert_eq!(
                            base.as_slice(),
                            e.matmul(&a, &b).as_slice(),
                            "{} matmul {m}x{k}x{n} differs at {} threads",
                            kern.name(),
                            e.threads()
                        );
                    }
                }
            }
        }
    }
}

/// Tolerance for f32-vs-f32-naive comparisons on the adversarial grid. Both
/// sides round in f32, so the gap is pure summation-order noise: with k ≤ 100
/// unit-Gaussian terms the worst case is ~k·ε_f32·‖row‖·‖col‖ ≈ 4e-5 — 1e-3
/// leaves a wide margin without masking a broken kernel (a wrong tile shows
/// up at O(1)).
const F32_TOL: f64 = 1e-3;

/// The dtype axis of the adversarial grid: the f32 instantiation of every
/// packed/skinny matmul route vs `matmul_naive32`, once per available kernel,
/// with pool sizes 1/2/4 bit-identical (the same partition-independence
/// contract the f64 engine pins).
#[test]
fn adversarial_shapes_f32_match_naive32_and_pools_agree() {
    for kern in MicroKernel::available() {
        let engines = engines_for(kern);
        let mut rng = Rng::seed_from(5);
        for &m in ADVERSARIAL {
            for &k in ADVERSARIAL {
                for &n in ADVERSARIAL {
                    let a = Mat32::from_f64(&Mat::gaussian(&mut rng, m, k, 1.0));
                    let b = Mat32::from_f64(&Mat::gaussian(&mut rng, k, n, 1.0));
                    let base = engines[0].matmul_f32(&a, &b);
                    assert_close(
                        &base.to_f64(),
                        &matmul_naive32(&a, &b).to_f64(),
                        F32_TOL,
                        &format!("{} f32 {m}x{k}x{n}", kern.name()),
                    );
                    for e in &engines[1..] {
                        assert_eq!(
                            base.as_slice(),
                            e.matmul_f32(&a, &b).as_slice(),
                            "{} f32 matmul {m}x{k}x{n} differs at {} threads",
                            kern.name(),
                            e.threads()
                        );
                    }
                }
            }
        }
    }
}

/// The f32 SYRK over the adversarial (k, n) grid, per kernel: value vs the
/// f64 naive reference at f32 tolerance, exact symmetry (the f32 mirror
/// copies the upper triangle bit-for-bit), and pool-size determinism.
#[test]
fn adversarial_syrk_f32_matches_reference() {
    for kern in MicroKernel::available() {
        let engines = engines_for(kern);
        let mut rng = Rng::seed_from(6);
        for &k in ADVERSARIAL {
            for &n in ADVERSARIAL {
                let a64 = Mat::gaussian(&mut rng, k, n, 1.0);
                let a = Mat32::from_f64(&a64);
                let base = engines[0].syrk_at_a_f32(&a);
                let up = base.to_f64();
                assert_close(
                    &up,
                    &matmul_naive(&a.to_f64().transpose(), &a.to_f64()),
                    F32_TOL,
                    &format!("{} f32 syrk_at_a {k}x{n}", kern.name()),
                );
                assert_eq!(up.symmetry_defect(), 0.0, "{} f32 syrk symmetry", kern.name());
                for e in &engines[1..] {
                    assert_eq!(
                        base.as_slice(),
                        e.syrk_at_a_f32(&a).as_slice(),
                        "{} f32 syrk {k}x{n} differs at {} threads",
                        kern.name(),
                        e.threads()
                    );
                }
            }
        }
    }
}

/// f32 `_into` entry points reuse caller buffers and match the allocating
/// APIs bit-for-bit; the f32 side of the workspace pools buffers exactly
/// like the f64 side.
#[test]
fn f32_into_apis_match_allocating_apis() {
    let mut rng = Rng::seed_from(7);
    let eng = GemmEngine::sequential();
    let a = Mat32::from_f64(&Mat::gaussian(&mut rng, 13, 7, 1.0));
    let b = Mat32::from_f64(&Mat::gaussian(&mut rng, 7, 11, 1.0));
    let mut c = Mat32::zeros(0, 0);

    eng.matmul_f32_into(&mut c, &a, &b);
    assert_eq!(c.as_slice(), eng.matmul_f32(&a, &b).as_slice());

    eng.syrk_at_a_f32_into(&mut c, &a);
    assert_eq!(c.as_slice(), eng.syrk_at_a_f32(&a).as_slice());

    let mut ws = Workspace::new();
    let buf = ws.take_f32(4, 4);
    ws.put_f32(buf);
    let buf = ws.take_f32(4, 4); // recycled, not a fresh allocation
    ws.put_f32(buf);
    assert_eq!(ws.allocations(), 1);
}

/// Transposed packing paths (`AᵀB`, `ABᵀ`) over the adversarial (m, n) grid
/// against naive-on-explicit-transpose, with pool-size determinism, per
/// kernel (the skinny rows exercise the strided streaming branches).
#[test]
fn adversarial_transposed_forms_match_naive() {
    for kern in MicroKernel::available() {
        let engines = engines_for(kern);
        let mut rng = Rng::seed_from(2);
        let k = 17; // one mid-grid shared dim keeps the suite O(seconds)
        for &m in ADVERSARIAL {
            for &n in ADVERSARIAL {
                // Aᵀ·B with A: k×m, B: k×n.
                let a = Mat::gaussian(&mut rng, k, m, 1.0);
                let b = Mat::gaussian(&mut rng, k, n, 1.0);
                let base_atb = engines[0].matmul_at_b(&a, &b);
                assert_close(
                    &base_atb,
                    &matmul_naive(&a.transpose(), &b),
                    1e-12,
                    &format!("{} at_b {m}x{k}x{n}", kern.name()),
                );
                // A·Bᵀ with A: m×k, B: n×k.
                let a2 = Mat::gaussian(&mut rng, m, k, 1.0);
                let b2 = Mat::gaussian(&mut rng, n, k, 1.0);
                let base_abt = engines[0].matmul_a_bt(&a2, &b2);
                assert_close(
                    &base_abt,
                    &matmul_naive(&a2, &b2.transpose()),
                    1e-12,
                    &format!("{} a_bt {m}x{k}x{n}", kern.name()),
                );
                for e in &engines[1..] {
                    assert_eq!(base_atb.as_slice(), e.matmul_at_b(&a, &b).as_slice());
                    assert_eq!(base_abt.as_slice(), e.matmul_a_bt(&a2, &b2).as_slice());
                }
            }
        }
    }
}

/// Both SYRK forms over the adversarial (k, n) grid, per kernel: exact
/// value vs naive, exact symmetry, and pool-size determinism for the
/// triangle-restricted packed path (the skipped-tile filter must be
/// partition-independent).
#[test]
fn adversarial_syrk_matches_naive() {
    for kern in MicroKernel::available() {
        let engines = engines_for(kern);
        let mut rng = Rng::seed_from(3);
        for &k in ADVERSARIAL {
            for &n in ADVERSARIAL {
                let a = Mat::gaussian(&mut rng, k, n, 1.0);
                let base_at = engines[0].syrk_at_a(&a);
                assert_close(
                    &base_at,
                    &matmul_naive(&a.transpose(), &a),
                    1e-12,
                    &format!("{} syrk_at_a {k}x{n}", kern.name()),
                );
                assert_eq!(base_at.symmetry_defect(), 0.0);
                let base_aat = engines[0].syrk_a_at(&a);
                assert_close(
                    &base_aat,
                    &matmul_naive(&a, &a.transpose()),
                    1e-12,
                    &format!("{} syrk_a_at {k}x{n}", kern.name()),
                );
                assert_eq!(base_aat.symmetry_defect(), 0.0);
                for e in &engines[1..] {
                    assert_eq!(
                        base_at.as_slice(),
                        e.syrk_at_a(&a).as_slice(),
                        "{} syrk_at_a {k}x{n} differs at {} threads",
                        kern.name(),
                        e.threads()
                    );
                    assert_eq!(
                        base_aat.as_slice(),
                        e.syrk_a_at(&a).as_slice(),
                        "{} syrk_a_at {k}x{n} differs at {} threads",
                        kern.name(),
                        e.threads()
                    );
                }
            }
        }
    }
}

/// Regression for the `GemmBlocking::clamped` / skinny-path interaction:
/// products with m, n, or k = 1 must stay correct on every kernel, at every
/// pool size, and under a blocking whose NC ≥ NR floor used to inflate a
/// 1-column GEMV with packed zero-padding — the skinny routing bypasses
/// the blocked path (and therefore the clamp) entirely, which the
/// bit-identity across wildly different blockings pins down.
#[test]
fn dims_of_one_conform_on_every_kernel() {
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 1, 9),
        (1, 9, 1),
        (9, 1, 1),
        (1, 33, 65),
        (65, 33, 1),
        (64, 1, 64),
        (1, 300, 1),
    ];
    for kern in MicroKernel::available() {
        for &(m, k, n) in shapes {
            let mut rng = Rng::seed_from(4);
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            let base = GemmEngine::sequential().with_kernel(kern).matmul(&a, &b);
            assert_close(
                &base,
                &matmul_naive(&a, &b),
                1e-12,
                &format!("{} {m}x{k}x{n}", kern.name()),
            );
            // Pool sizes agree bitwise (GEMV accumulation is pure k order).
            for threads in [2usize, 4] {
                let par = GemmEngine::with_threads(threads).with_kernel(kern);
                assert_eq!(
                    base.as_slice(),
                    par.matmul(&a, &b).as_slice(),
                    "{} {m}x{k}x{n} differs at {threads} threads",
                    kern.name()
                );
            }
            // Blockings agree bitwise for every shape in this table: the
            // skinny routes (m ≤ 8 or n ≤ 4) bypass the NC/KC grid
            // entirely, and the one blocked shape (64×1×64) has k = 1, so
            // each element is a single product no regrouping can change.
            // Either way the clamp cannot inflate the work or the result.
            for blk in [
                GemmBlocking { mc: 128, kc: 256, nc: 512 },
                GemmBlocking { mc: 1, kc: 1, nc: 1 },
                GemmBlocking { mc: 16, kc: 7, nc: 13 },
            ] {
                let eng = GemmEngine::sequential().with_kernel(kern).with_blocking(blk);
                assert_eq!(
                    base.as_slice(),
                    eng.matmul(&a, &b).as_slice(),
                    "{} {m}x{k}x{n} differs under blocking {}",
                    kern.name(),
                    blk.display()
                );
            }
            // And k = 1 / n = 1 SYRKs stay exact and symmetric.
            let g = Mat::gaussian(&mut rng, k, n, 1.0);
            let s = GemmEngine::sequential().with_kernel(kern).syrk_at_a(&g);
            assert_close(&s, &matmul_naive(&g.transpose(), &g), 1e-12, "syrk dims-of-one");
            assert_eq!(s.symmetry_defect(), 0.0);
        }
    }
}

/// Non-default blockings exercise every ragged-edge path in the packers and
/// stay correct; a parallel engine at the same blocking stays bit-identical.
#[test]
fn custom_blockings_conform() {
    for kern in MicroKernel::available() {
        let mut rng = Rng::seed_from(4);
        for blk in [
            GemmBlocking { mc: 8, kc: 4, nc: 4 },
            GemmBlocking { mc: 16, kc: 7, nc: 13 },
            GemmBlocking { mc: 24, kc: 32, nc: 20 },
        ] {
            let seq = GemmEngine::sequential().with_blocking(blk).with_kernel(kern);
            let par = GemmEngine::with_threads(4).with_blocking(blk).with_kernel(kern);
            for &(m, k, n) in &[(5, 9, 3), (33, 33, 33), (65, 40, 51)] {
                let a = Mat::gaussian(&mut rng, m, k, 1.0);
                let b = Mat::gaussian(&mut rng, k, n, 1.0);
                let got = seq.matmul(&a, &b);
                assert_close(
                    &got,
                    &matmul_naive(&a, &b),
                    1e-12,
                    &format!("{} blk {} {m}x{k}x{n}", kern.name(), blk.display()),
                );
                assert_eq!(got.as_slice(), par.matmul(&a, &b).as_slice());
                let s = seq.syrk_at_a(&a);
                assert_close(&s, &matmul_naive(&a.transpose(), &a), 1e-12, "blk syrk");
                assert_eq!(s.as_slice(), par.syrk_at_a(&a).as_slice());
            }
        }
    }
}

#[test]
fn property_matmul_matches_broadcast_ragged() {
    Prop::new("packed vs broadcast").cases(64).run(|rng| {
        let m = gens::usize_in(rng, 1, 70);
        let k = gens::usize_in(rng, 1, 70);
        let n = gens::usize_in(rng, 1, 70);
        let a = Mat::gaussian(rng, m, k, 1.0);
        let b = Mat::gaussian(rng, k, n, 1.0);
        assert_close(&matmul(&a, &b), &broadcast_ref(&a, &b), 1e-9, &format!("{m}x{k}x{n}"));
    });
}

#[test]
fn property_transposed_forms_match_broadcast() {
    Prop::new("at_b/a_bt vs broadcast").cases(64).run(|rng| {
        let m = gens::usize_in(rng, 1, 40);
        let k = gens::usize_in(rng, 1, 40);
        let n = gens::usize_in(rng, 1, 40);
        // Aᵀ·B with A: k×m, B: k×n.
        let a = Mat::gaussian(rng, k, m, 1.0);
        let b = Mat::gaussian(rng, k, n, 1.0);
        let want = broadcast_ref(&a.transpose(), &b);
        assert_close(&matmul_at_b(&a, &b), &want, 1e-9, "at_b");
        // A·Bᵀ with A: m×k, B: n×k.
        let a2 = Mat::gaussian(rng, m, k, 1.0);
        let b2 = Mat::gaussian(rng, n, k, 1.0);
        let want2 = broadcast_ref(&a2, &b2.transpose());
        assert_close(&matmul_a_bt(&a2, &b2), &want2, 1e-9, "a_bt");
    });
}

#[test]
fn property_syrk_matches_broadcast() {
    Prop::new("syrk vs broadcast").cases(64).run(|rng| {
        let k = gens::usize_in(rng, 1, 40);
        let n = gens::usize_in(rng, 1, 40);
        let a = Mat::gaussian(rng, k, n, 1.0);
        let got = syrk_at_a(&a);
        assert_close(&got, &broadcast_ref(&a.transpose(), &a), 1e-9, "syrk_at_a");
        assert_eq!(got.symmetry_defect(), 0.0);
        let got2 = syrk_a_at(&a);
        assert_close(&got2, &broadcast_ref(&a, &a.transpose()), 1e-9, "syrk_a_at");
        assert_eq!(got2.symmetry_defect(), 0.0);
    });
}

#[test]
fn pool_sizes_1_2_8_bit_identical() {
    for kern in MicroKernel::available() {
        let engines = [
            GemmEngine::with_threads(1).with_kernel(kern),
            GemmEngine::with_threads(2).with_kernel(kern),
            GemmEngine::with_threads(8).with_kernel(kern),
        ];
        assert_eq!(engines[0].threads(), 1);
        assert_eq!(engines[1].threads(), 2);
        assert_eq!(engines[2].threads(), 8);
        let mut rng = Rng::seed_from(2);
        // Shapes below, at, and well above the parallel dispatch threshold,
        // including panel splits that leave ragged remainders.
        for &(m, k, n) in &[(3, 5, 4), (16, 16, 16), (17, 33, 29), (70, 41, 67), (128, 64, 96)] {
            let a = Mat::gaussian(&mut rng, m, k, 1.0);
            let b = Mat::gaussian(&mut rng, k, n, 1.0);
            let base_mm = engines[0].matmul(&a, &b);
            let base_syrk = engines[0].syrk_at_a(&a);
            let base_syrk2 = engines[0].syrk_a_at(&a);
            let base_atb = engines[0].matmul_at_b(&a, &a);
            for e in &engines[1..] {
                assert_eq!(
                    base_mm.as_slice(),
                    e.matmul(&a, &b).as_slice(),
                    "{} matmul {m}x{k}x{n} differs at {} threads",
                    kern.name(),
                    e.threads()
                );
                assert_eq!(
                    base_syrk.as_slice(),
                    e.syrk_at_a(&a).as_slice(),
                    "{} syrk_at_a {m}x{k} differs at {} threads",
                    kern.name(),
                    e.threads()
                );
                assert_eq!(
                    base_syrk2.as_slice(),
                    e.syrk_a_at(&a).as_slice(),
                    "{} syrk_a_at {m}x{k} differs at {} threads",
                    kern.name(),
                    e.threads()
                );
                let mut c = Mat::zeros(0, 0);
                e.matmul_at_b_into(&mut c, &a, &a);
                assert_eq!(
                    base_atb.as_slice(),
                    c.as_slice(),
                    "{} matmul_at_b differs at {} threads",
                    kern.name(),
                    e.threads()
                );
            }
        }
    }
}

#[test]
fn into_apis_match_allocating_apis() {
    let mut rng = Rng::seed_from(3);
    let eng = GemmEngine::sequential();
    let a = Mat::gaussian(&mut rng, 13, 7, 1.0);
    let b = Mat::gaussian(&mut rng, 7, 11, 1.0);
    let mut c = Mat::zeros(0, 0);

    eng.matmul_into(&mut c, &a, &b);
    assert_eq!(c.as_slice(), matmul(&a, &b).as_slice());

    eng.syrk_at_a_into(&mut c, &a);
    assert_eq!(c.as_slice(), syrk_at_a(&a).as_slice());

    eng.syrk_a_at_into(&mut c, &a);
    assert_eq!(c.as_slice(), syrk_a_at(&a).as_slice());

    eng.matmul_a_bt_into(&mut c, &b.transpose(), &a);
    assert_eq!(c.as_slice(), matmul_a_bt(&b.transpose(), &a).as_slice());

    // The output Workspace type still pools iteration buffers for engines.
    let mut ws = Workspace::new();
    let buf = ws.take(4, 4);
    ws.put(buf);
    assert_eq!(ws.allocations(), 1);
}

#[test]
fn gemm_scope_is_thread_local() {
    let mut rng = Rng::seed_from(4);
    let a = Mat::gaussian(&mut rng, 8, 8, 1.0);
    // Concurrent GEMM traffic on other threads must not leak into this
    // thread's scope.
    let outer = GemmScope::begin();
    let handles: Vec<_> = (0..4u64)
        .map(|s| {
            let a = a.clone();
            std::thread::spawn(move || {
                let scope = GemmScope::begin();
                let mut rng = Rng::seed_from(s);
                let b = Mat::gaussian(&mut rng, 8, 8, 1.0);
                for _ in 0..5 {
                    let _ = matmul(&a, &b);
                }
                assert_eq!(scope.calls(), 5);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(outer.calls(), 0, "other threads' GEMMs leaked into this scope");
    let _ = matmul(&a, &a);
    assert_eq!(outer.calls(), 1);
    // And flop accounting distinguishes SYRK (n²k) from GEMM (2mnk), with
    // the SYRK sub-counter tracking the symmetric calls.
    let scope = GemmScope::begin();
    let g = Mat::gaussian(&mut rng, 7, 5, 1.0);
    let _ = syrk_at_a(&g); // n=5, k=7
    assert_eq!(scope.flops(), 5 * 5 * 7);
    assert_eq!(scope.syrk_calls(), 1);
    let _ = matmul(&g, &syrk_at_a(&g)); // 7x5 · 5x5 → 2·7·5·5 (+ the syrk)
    assert_eq!(scope.flops(), 5 * 5 * 7 + 5 * 5 * 7 + 2 * 7 * 5 * 5);
    assert_eq!(scope.syrk_calls(), 2);
    assert_eq!(scope.calls(), 3, "two syrks + one matmul since this scope began");
}
