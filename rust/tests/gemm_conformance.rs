//! GEMM cross-check property suite: the broadcast-FMA engine (sequential
//! and parallel) against the retained packed dot-product reference kernel
//! (`gemm_packed`) on ragged shapes, plus the determinism contract —
//! bit-identical output for pool sizes 1, 2 and 8.

use prism::linalg::gemm::{
    gemm_packed, matmul, matmul_a_bt, matmul_at_b, syrk_a_at, syrk_at_a, GemmEngine, GemmScope,
    Workspace,
};
use prism::linalg::Mat;
use prism::ptest::{gens, Prop};
use prism::rng::Rng;

/// `A·B` through the independent packed reference kernel.
fn packed_ref(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows());
    let (m, k) = a.shape();
    let n = b.cols();
    let bt = b.transpose();
    let mut c = Mat::zeros(m, n);
    gemm_packed(a.as_slice(), bt.as_slice(), c.as_mut_slice(), m, n, k);
    c
}

fn assert_close(got: &Mat, want: &Mat, tol: f64, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    let err = got.sub(want).max_abs();
    assert!(err < tol, "{what}: err {err}");
}

/// Shapes that straddle every blocking boundary: the 4-row micro-tile, the
/// packed kernel's MC=64/KC=256 blocks, and the broadcast kernel's NC=512
/// column panel.
const EDGE_SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 1),
    (1, 3, 9),
    (5, 1, 3),
    (2, 4, 2),
    (3, 4, 1),
    (63, 17, 5),
    (64, 256, 8),
    (65, 257, 9),
    (66, 130, 33),
    (3, 5, 513),
];

#[test]
fn matmul_matches_packed_on_edge_shapes() {
    let mut rng = Rng::seed_from(1);
    for &(m, k, n) in EDGE_SHAPES {
        let a = Mat::gaussian(&mut rng, m, k, 1.0);
        let b = Mat::gaussian(&mut rng, k, n, 1.0);
        assert_close(&matmul(&a, &b), &packed_ref(&a, &b), 1e-9, &format!("{m}x{k}x{n}"));
    }
}

#[test]
fn property_matmul_matches_packed_ragged() {
    Prop::new("broadcast vs packed").cases(64).run(|rng| {
        let m = gens::usize_in(rng, 1, 70);
        let k = gens::usize_in(rng, 1, 70);
        let n = gens::usize_in(rng, 1, 70);
        let a = Mat::gaussian(rng, m, k, 1.0);
        let b = Mat::gaussian(rng, k, n, 1.0);
        assert_close(&matmul(&a, &b), &packed_ref(&a, &b), 1e-9, &format!("{m}x{k}x{n}"));
    });
}

#[test]
fn property_transposed_forms_match_packed() {
    Prop::new("at_b/a_bt vs packed").cases(64).run(|rng| {
        let m = gens::usize_in(rng, 1, 40);
        let k = gens::usize_in(rng, 1, 40);
        let n = gens::usize_in(rng, 1, 40);
        // Aᵀ·B with A: k×m, B: k×n.
        let a = Mat::gaussian(rng, k, m, 1.0);
        let b = Mat::gaussian(rng, k, n, 1.0);
        let want = packed_ref(&a.transpose(), &b);
        assert_close(&matmul_at_b(&a, &b), &want, 1e-9, "at_b");
        // A·Bᵀ with A: m×k, B: n×k.
        let a2 = Mat::gaussian(rng, m, k, 1.0);
        let b2 = Mat::gaussian(rng, n, k, 1.0);
        let want2 = packed_ref(&a2, &b2.transpose());
        assert_close(&matmul_a_bt(&a2, &b2), &want2, 1e-9, "a_bt");
    });
}

#[test]
fn property_syrk_matches_packed() {
    Prop::new("syrk vs packed").cases(64).run(|rng| {
        let k = gens::usize_in(rng, 1, 40);
        let n = gens::usize_in(rng, 1, 40);
        let a = Mat::gaussian(rng, k, n, 1.0);
        let got = syrk_at_a(&a);
        assert_close(&got, &packed_ref(&a.transpose(), &a), 1e-9, "syrk_at_a");
        assert_eq!(got.symmetry_defect(), 0.0);
        let got2 = syrk_a_at(&a);
        assert_close(&got2, &packed_ref(&a, &a.transpose()), 1e-9, "syrk_a_at");
        assert_eq!(got2.symmetry_defect(), 0.0);
    });
}

#[test]
fn pool_sizes_1_2_8_bit_identical() {
    let engines = [
        GemmEngine::with_threads(1),
        GemmEngine::with_threads(2),
        GemmEngine::with_threads(8),
    ];
    assert_eq!(engines[0].threads(), 1);
    assert_eq!(engines[1].threads(), 2);
    assert_eq!(engines[2].threads(), 8);
    let mut rng = Rng::seed_from(2);
    // Shapes below, at, and well above the parallel dispatch threshold,
    // including panel splits that leave ragged remainders.
    for &(m, k, n) in &[(3, 5, 4), (16, 16, 16), (17, 33, 29), (70, 41, 67), (128, 64, 96)] {
        let a = Mat::gaussian(&mut rng, m, k, 1.0);
        let b = Mat::gaussian(&mut rng, k, n, 1.0);
        let mut ws = Workspace::new();
        let base_mm = engines[0].matmul(&a, &b);
        let base_syrk = engines[0].syrk_at_a(&a);
        let base_syrk2 = engines[0].syrk_a_at(&a);
        let base_atb = engines[0].matmul_at_b(&a, &a);
        for e in &engines[1..] {
            assert_eq!(
                base_mm.as_slice(),
                e.matmul(&a, &b).as_slice(),
                "matmul {m}x{k}x{n} differs at {} threads",
                e.threads()
            );
            assert_eq!(
                base_syrk.as_slice(),
                e.syrk_at_a(&a).as_slice(),
                "syrk_at_a {m}x{k} differs at {} threads",
                e.threads()
            );
            assert_eq!(
                base_syrk2.as_slice(),
                e.syrk_a_at(&a).as_slice(),
                "syrk_a_at {m}x{k} differs at {} threads",
                e.threads()
            );
            let mut c = Mat::zeros(0, 0);
            e.matmul_at_b_into(&mut c, &a, &a, &mut ws);
            assert_eq!(
                base_atb.as_slice(),
                c.as_slice(),
                "matmul_at_b differs at {} threads",
                e.threads()
            );
        }
    }
}

#[test]
fn into_apis_match_allocating_apis() {
    let mut rng = Rng::seed_from(3);
    let eng = GemmEngine::sequential();
    let mut ws = Workspace::new();
    let a = Mat::gaussian(&mut rng, 13, 7, 1.0);
    let b = Mat::gaussian(&mut rng, 7, 11, 1.0);
    let mut c = Mat::zeros(0, 0);

    eng.matmul_into(&mut c, &a, &b);
    assert_eq!(c.as_slice(), matmul(&a, &b).as_slice());

    eng.syrk_at_a_into(&mut c, &a);
    assert_eq!(c.as_slice(), syrk_at_a(&a).as_slice());

    eng.syrk_a_at_into(&mut c, &a, &mut ws);
    assert_eq!(c.as_slice(), syrk_a_at(&a).as_slice());

    eng.matmul_a_bt_into(&mut c, &b.transpose(), &a, &mut ws);
    assert_eq!(c.as_slice(), matmul_a_bt(&b.transpose(), &a).as_slice());
}

#[test]
fn gemm_scope_is_thread_local() {
    let mut rng = Rng::seed_from(4);
    let a = Mat::gaussian(&mut rng, 8, 8, 1.0);
    // Concurrent GEMM traffic on other threads must not leak into this
    // thread's scope.
    let outer = GemmScope::begin();
    let handles: Vec<_> = (0..4u64)
        .map(|s| {
            let a = a.clone();
            std::thread::spawn(move || {
                let scope = GemmScope::begin();
                let mut rng = Rng::seed_from(s);
                let b = Mat::gaussian(&mut rng, 8, 8, 1.0);
                for _ in 0..5 {
                    let _ = matmul(&a, &b);
                }
                assert_eq!(scope.calls(), 5);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(outer.calls(), 0, "other threads' GEMMs leaked into this scope");
    let _ = matmul(&a, &a);
    assert_eq!(outer.calls(), 1);
    // And flop accounting distinguishes SYRK (n²k) from GEMM (2mnk).
    let scope = GemmScope::begin();
    let g = Mat::gaussian(&mut rng, 7, 5, 1.0);
    let _ = syrk_at_a(&g); // n=5, k=7
    assert_eq!(scope.flops(), 5 * 5 * 7);
    let _ = matmul(&g, &syrk_at_a(&g)); // 7x5 · 5x5 → 2·7·5·5 (+ the syrk)
    assert_eq!(scope.flops(), 5 * 5 * 7 + 5 * 5 * 7 + 2 * 7 * 5 * 5);
}
