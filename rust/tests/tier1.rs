//! Tier-1 smoke target: one bounded, fast pass over the critical paths —
//! substrate (GEMM engine, determinism, counters), one engine per Table-1
//! family against ground truth, and a service round trip — so a plain
//! `cargo build --release && cargo test -q` always exercises the whole
//! stack even if the heavier property suites are filtered out.
//!
//! Budget: every test here is O(small-n³) with single-digit case counts.

use prism::baselines::eigen_fn;
use prism::config::{Backend, ServiceConfig};
use prism::coordinator::service::{JobKind, Service};
use prism::linalg::gemm::{matmul, matmul_naive, GemmEngine, GemmScope, MicroKernel};
use prism::linalg::Mat;
use prism::matfn::{registry, SolverSpec};
use prism::prism::driver::StopRule;
use prism::ptest::gens;
use prism::randmat;
use prism::rng::Rng;

#[test]
fn smoke_gemm_engine_correct_and_deterministic() {
    let mut rng = Rng::seed_from(1);
    let a = Mat::gaussian(&mut rng, 21, 13, 1.0);
    let b = Mat::gaussian(&mut rng, 13, 17, 1.0);
    let want = matmul_naive(&a, &b);
    assert!(matmul(&a, &b).sub(&want).max_abs() < 1e-10);
    let par = GemmEngine::with_threads(4);
    assert_eq!(par.matmul(&a, &b).as_slice(), GemmEngine::sequential().matmul(&a, &b).as_slice());
}

#[test]
fn smoke_every_kernel_and_skinny_path_correct() {
    // One pass over the microkernel dispatch (scalar + whatever SIMD the
    // host has) and the skinny routes: blocked shape, sketch shape (thin-A),
    // and a 1-column GEMV.
    let mut rng = Rng::seed_from(7);
    let a = Mat::gaussian(&mut rng, 24, 20, 1.0);
    let b = Mat::gaussian(&mut rng, 20, 18, 1.0);
    let s = Mat::gaussian(&mut rng, 8, 24, 1.0); // sketch panel
    let v = Mat::gaussian(&mut rng, 20, 1, 1.0); // GEMV column
    for kern in MicroKernel::available() {
        let eng = GemmEngine::sequential().with_kernel(kern);
        assert!(
            eng.matmul(&a, &b).sub(&matmul_naive(&a, &b)).max_abs() < 1e-10,
            "{} blocked",
            kern.name()
        );
        assert!(
            eng.matmul(&s, &a).sub(&matmul_naive(&s, &a)).max_abs() < 1e-10,
            "{} thin-A (sketch shape)",
            kern.name()
        );
        assert!(
            eng.matmul(&a, &v).sub(&matmul_naive(&a, &v)).max_abs() < 1e-10,
            "{} gemv",
            kern.name()
        );
    }
    // The default engine resolves to a host-runnable kernel (honouring the
    // PALLAS_GEMM_KERNEL override the CI scalar matrix job sets).
    assert!(GemmEngine::sequential().kernel().is_available());
}

#[test]
fn smoke_f32_kernel_and_skinny_path_correct() {
    // The f32 instantiation of the same dispatch: blocked, thin-A (sketch
    // shape), and GEMV routes per available kernel, against the f32 naive
    // reference at single-precision tolerance.
    use prism::linalg::gemm::matmul_naive32;
    use prism::linalg::Mat32;
    let mut rng = Rng::seed_from(7);
    let a = Mat32::from_f64(&Mat::gaussian(&mut rng, 24, 20, 1.0));
    let b = Mat32::from_f64(&Mat::gaussian(&mut rng, 20, 18, 1.0));
    let s = Mat32::from_f64(&Mat::gaussian(&mut rng, 8, 24, 1.0));
    let v = Mat32::from_f64(&Mat::gaussian(&mut rng, 20, 1, 1.0));
    for kern in MicroKernel::available() {
        let eng = GemmEngine::sequential().with_kernel(kern);
        for (lhs, rhs, route) in
            [(&a, &b, "blocked"), (&s, &a, "thin-A (sketch shape)"), (&a, &v, "gemv")]
        {
            let got = eng.matmul_f32(lhs, rhs).to_f64();
            let want = matmul_naive32(lhs, rhs).to_f64();
            assert!(got.sub(&want).max_abs() < 1e-4, "{} {} f32", kern.name(), route);
        }
    }
}

#[test]
fn smoke_mixed_precision_invsqrt_vs_eigen() {
    // The f32-iterate / f64-guard path through the public Solver API: the
    // f64 guard must still certify the tight inverse-root tolerance, and
    // the iterate must match the eigendecomposition ground truth.
    let mut rng = Rng::seed_from(8);
    let a = gens::spd(&mut rng, 10, 1e-2);
    let exact = eigen_fn::inv_sqrt_eigen(&a, 0.0);
    let stop = StopRule::default().with_max_iters(200).with_tol(1e-9);
    let spec = SolverSpec::prism(2).with_stop(stop).with_precision(prism::matfn::Precision::Mixed);
    let mut solver = prism::matfn::Solver::new(prism::matfn::MatFnTask::InvSqrt, spec).unwrap();
    let out = solver.solve(&a, &mut rng);
    assert!(out.log.converged, "res={}", out.log.final_residual());
    assert!(out.log.final_residual() < 1e-9);
    assert!(out.primary.sub(&exact).max_abs() < 1e-4);
}

#[test]
fn smoke_gemm_counter_scoped() {
    let mut rng = Rng::seed_from(2);
    let a = Mat::gaussian(&mut rng, 6, 6, 1.0);
    let scope = GemmScope::begin();
    let _ = matmul(&a, &a);
    assert_eq!(scope.calls(), 1);
    assert_eq!(scope.flops(), 2 * 6 * 6 * 6);
}

#[test]
fn smoke_polar_prism_vs_svd() {
    let mut rng = Rng::seed_from(3);
    let a = gens::ill_conditioned(&mut rng, 16, 10, 50.0);
    let exact = eigen_fn::polar_eigen(&a);
    let mut solver = registry::resolve("prism5-polar").unwrap();
    solver.set_stop(StopRule::default().with_max_iters(200).with_tol(1e-8));
    let out = solver.solve(&a, &mut rng);
    assert!(out.log.converged, "res={}", out.log.final_residual());
    assert!(out.primary.sub(&exact).max_abs() < 1e-5);
    assert_eq!(out.log.alphas.len(), out.log.iters());
}

#[test]
fn smoke_sqrt_prism_vs_eigen() {
    let mut rng = Rng::seed_from(4);
    let a = gens::spd(&mut rng, 10, 1e-2);
    let exact = eigen_fn::sqrt_eigen(&a);
    let stop = StopRule::default().with_max_iters(200).with_tol(1e-9);
    let mut solver =
        prism::matfn::Solver::new(prism::matfn::MatFnTask::Sqrt, SolverSpec::prism(2).with_stop(stop))
            .unwrap();
    let out = solver.solve(&a, &mut rng);
    assert!(out.log.converged);
    assert!(out.primary.sub(&exact).max_abs() < 1e-5);
}

#[test]
fn smoke_rectpolar_gram_flop_budget() {
    // Acceptance gate for the Gram route: O(p²m) + O(p³)-class work must
    // stay strictly below the identity-padded square embedding's O(m³) at
    // every aspect ≥ 2. Both routes run the same fixed iteration budget
    // with Classic α ("ns-*"), so no sketch draws muddy the accounting.
    let stop = StopRule::default().with_max_iters(6).with_tol(1e-30);
    for aspect in [2usize, 4] {
        let p = 16;
        let m = p * aspect;
        let mut rng = Rng::seed_from(12);
        let s = randmat::logspace(0.1, 1.0, p);
        let a = randmat::with_spectrum(&mut rng, m, p, &s);
        // Identity-padded square embedding: B[:, :p] = A, B[j, j] = 1 else.
        let mut b = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..p {
                b[(i, j)] = a[(i, j)];
            }
        }
        for j in p..m {
            b[(j, j)] = 1.0;
        }

        let mut rect = registry::resolve("ns-rectpolar").unwrap();
        rect.set_stop(stop);
        let scope = GemmScope::begin();
        let _ = rect.solve(&a, &mut rng);
        let rect_flops = scope.flops();

        let mut square = registry::resolve("ns-polar").unwrap();
        square.set_stop(stop);
        let scope = GemmScope::begin();
        let _ = square.solve(&b, &mut rng);
        let square_flops = scope.flops();

        assert!(rect_flops > 0 && square_flops > 0, "flop accounting must see both solves");
        assert!(
            rect_flops < square_flops,
            "aspect {aspect}: Gram route must spend strictly fewer flops \
             ({rect_flops} vs {square_flops})"
        );
    }
}

#[test]
fn smoke_reused_solver_is_allocation_free() {
    // The persistent-solver contract: from the second same-shape call
    // onward, the workspace pool serves every iteration buffer.
    let mut rng = Rng::seed_from(6);
    let a = gens::ill_conditioned(&mut rng, 24, 12, 30.0);
    let mut solver = registry::resolve("prism5-polar").unwrap();
    let _ = solver.solve(&a, &mut rng);
    let allocs = solver.workspace_allocations();
    assert!(allocs > 0);
    let out = solver.solve(&a, &mut rng);
    assert!(out.log.converged);
    assert_eq!(solver.workspace_allocations(), allocs);
}

#[test]
fn smoke_service_round_trip() {
    let mut rng = Rng::seed_from(5);
    let cfg = ServiceConfig {
        workers: 2,
        queue_cap: 16,
        admission: prism::config::Admission::Block,
        max_batch: 2,
        sketch_p: 8,
        max_iters: 40,
        tol: None, // per-task defaults (1e-9 for this InvSqrt traffic)
        precision: prism::matfn::Precision::F64,
        solver_cache_cap: 32,
        gemm_threads: 1,
        stream_residuals: false,
        gemm_block: None,
        gemm_kernel: None,
        faults: None,
        linger: None,
        cache_snapshot: None,
    };
    let svc = Service::start(cfg, Backend::Prism5, 7).expect("valid service config");
    let w = randmat::logspace(0.05, 1.0, 6);
    for layer in 0..2 {
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
    }
    let results = svc.drain().unwrap();
    assert_eq!(results.len(), 2);
    for r in &results {
        assert!(!r.result.has_non_finite());
        assert_eq!(r.result.shape(), (6, 6));
    }
}

#[test]
fn smoke_batched_solve_matches_sequential() {
    // The service's amortised path: a lockstep batch must be bit-identical
    // to sequential solves from a clone of the entry RNG state.
    let mut rng = Rng::seed_from(9);
    let w = randmat::logspace(0.05, 1.0, 8);
    let inputs: Vec<Mat> = (0..4).map(|_| randmat::sym_with_spectrum(&mut rng, 8, &w)).collect();
    let refs: Vec<&Mat> = inputs.iter().collect();
    let entry = Rng::seed_from(31);
    let mut batch_solver = registry::resolve("prism5-invsqrt").unwrap();
    let outs = batch_solver.solve_batch(&refs, &mut entry.clone());
    let mut seq_solver = registry::resolve("prism5-invsqrt").unwrap();
    for (a, out) in inputs.iter().zip(&outs) {
        let want = seq_solver.solve(a, &mut entry.clone());
        assert_eq!(out.primary, want.primary, "batched result must match sequential");
        assert!(out.log.converged);
    }
}
