//! Model-checked coordinator races (`RUSTFLAGS="--cfg loom" cargo test
//! --release --test loom_coordinator`).
//!
//! Each test drives the *production* admission/scheduling types —
//! [`prism::coordinator::gate`]'s `InflightLedger` + `AdmissionGate` and
//! [`prism::coordinator::schedule`]'s `BucketScheduler` — through the
//! in-tree bounded model checker ([`prism::runtime::sync::model`]), which
//! explores every thread interleaving (up to the preemption bound) and
//! fails with the offending schedule on the first assert violation or
//! deadlock. The four scenarios are the four coordinator races the service
//! docs promise are closed:
//!
//! 1. **Bounded admission** — a blocking submitter racing a result fetch at
//!    the queue cap. A lost condvar wakeup would park the submitter forever,
//!    which the checker reports as a modeled deadlock ([`Condvar::
//!    wait_timeout`] is deliberately untimed under the model, so the 5 ms
//!    production backstop cannot mask the bug).
//! 2. **Linger flush vs. synchronous cut** — the flusher's `take_over_linger`
//!    racing `push`'s full-bucket cut: every job is dispatched at most once
//!    and never dropped.
//! 3. **Cancel vs. dispatch** — `remove` racing `take_over_linger` for the
//!    same pending job: exactly one result per job, and the ledger's
//!    inflight accounting returns to zero after the fetch.
//! 4. **Panic-respawn vs. in-flight fetch** — a worker panicking mid-batch
//!    while holding its reported-set mutex, racing a condvar-monitored
//!    fetcher: the supervisor's poison recovery synthesizes exactly the
//!    missing results.

#![cfg(loom)]

use prism::coordinator::gate::{AdmissionGate, InflightLedger};
use prism::coordinator::schedule::BucketScheduler;
use prism::coordinator::{Job, JobKind};
use prism::linalg::Mat;
use prism::matfn::Precision;
use prism::runtime::sync::model::{model, thread, Quiet};
use prism::runtime::sync::{Arc, Condvar, Mutex};
use prism::util::lock_or_recover;
use std::collections::BTreeSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

fn job(id: u64) -> Job {
    Job {
        id,
        layer: id as usize,
        kind: JobKind::InvSqrt { eps: 0.0 },
        matrix: Mat::eye(2),
        submitted: Instant::now(),
        deadline: None,
    }
}

/// Race 1: blocking admission at the cap vs. a concurrent result fetch.
///
/// Mirrors `Service::admit` + `Service::note_received`: the capacity check
/// and the park both happen under the pending mutex, and the capacity-freeing
/// path notifies while holding that same mutex. Any interleaving in which the
/// notify could land between the submitter's check and its park would strand
/// the submitter — and surface here as a modeled deadlock.
#[test]
fn blocking_submit_never_misses_the_capacity_wakeup() {
    model(|| {
        const CAP: usize = 1;
        let pending: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(vec![1]));
        let gate = Arc::new(AdmissionGate::new());
        let ledger = Arc::new(InflightLedger::new());

        // Blocking submitter: admit job 2 once capacity frees.
        let submitter = {
            let (pending, gate, ledger) =
                (Arc::clone(&pending), Arc::clone(&gate), Arc::clone(&ledger));
            thread::spawn(move || loop {
                let mut pend = lock_or_recover(&pending);
                if pend.len() + ledger.inflight() < CAP {
                    pend.push(2);
                    return;
                }
                let _pend = gate.park(pend, Duration::from_millis(5));
            })
        };

        // Fetcher: dispatch job 1, receive its result, notify under the
        // pending lock (the note_received path).
        let fetcher = {
            let (pending, gate, ledger) =
                (Arc::clone(&pending), Arc::clone(&gate), Arc::clone(&ledger));
            thread::spawn(move || {
                {
                    let mut pend = lock_or_recover(&pending);
                    let got = pend.pop();
                    assert_eq!(got, Some(1), "job 1 was pending at the start");
                }
                ledger.note_dispatched(1);
                ledger.note_received();
                let _pend = lock_or_recover(&pending);
                gate.notify();
            })
        };

        submitter.join().expect("submitter must terminate");
        fetcher.join().expect("fetcher must terminate");
        assert_eq!(*lock_or_recover(&pending), vec![2]);
        assert_eq!(ledger.inflight(), 0);
    });
}

/// Race 2: the linger flusher's cut racing a submitter's full-bucket cut on
/// the same bucket. Whatever the interleaving, each job is dispatched at
/// most once (no double dispatch) and every job is either dispatched or
/// still pending (no drop).
#[test]
fn linger_flush_and_full_cut_never_double_dispatch_or_drop() {
    model(|| {
        let sched = Arc::new(Mutex::new(BucketScheduler::new(2, Precision::F64)));
        let dispatched: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));

        // Submitter: two same-bucket pushes; the second can trigger the
        // synchronous full-bucket cut if the flusher has not already swept.
        let submitter = {
            let (sched, dispatched) = (Arc::clone(&sched), Arc::clone(&dispatched));
            thread::spawn(move || {
                for id in [1u64, 2] {
                    let batch = lock_or_recover(&sched).push(job(id));
                    if let Some(b) = batch {
                        lock_or_recover(&dispatched).extend(b.iter().map(|j| j.id));
                    }
                }
            })
        };

        // Flusher: one linger sweep with a zero linger — everything pending
        // at the instant of the sweep is ripe.
        let flusher = {
            let (sched, dispatched) = (Arc::clone(&sched), Arc::clone(&dispatched));
            thread::spawn(move || {
                let ripe = lock_or_recover(&sched)
                    .take_over_linger(Instant::now(), Duration::ZERO);
                for b in ripe {
                    lock_or_recover(&dispatched).extend(b.iter().map(|j| j.id));
                }
            })
        };

        submitter.join().expect("submitter must terminate");
        flusher.join().expect("flusher must terminate");

        let mut seen: Vec<u64> = lock_or_recover(&dispatched).clone();
        for b in lock_or_recover(&sched).take_all() {
            seen.extend(b.iter().map(|j| j.id));
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![1, 2], "each job exactly once, dispatched or pending");
    });
}

/// Race 3: `Service::cancel`'s surgical removal racing the flusher's
/// dispatch of the same pending job. Exactly one of them claims the job —
/// one result is produced either way — and the ledger drains to zero.
#[test]
fn cancel_racing_dispatch_keeps_inflight_accounting_exact() {
    model(|| {
        let sched = Arc::new(Mutex::new(BucketScheduler::new(2, Precision::F64)));
        lock_or_recover(&sched).push(job(1));
        let ledger = Arc::new(InflightLedger::new());
        let cancelled: Arc<Mutex<BTreeSet<u64>>> = Arc::new(Mutex::new(BTreeSet::new()));
        let results: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));

        // Canceller: mirrors Service::cancel — pull the job out of its
        // bucket if it is still pending (counting the synthesized result as
        // a dispatch), otherwise leave a marker for the worker.
        let canceller = {
            let (sched, ledger, cancelled, results) = (
                Arc::clone(&sched),
                Arc::clone(&ledger),
                Arc::clone(&cancelled),
                Arc::clone(&results),
            );
            thread::spawn(move || {
                let removed = lock_or_recover(&sched).remove(1).is_some();
                if removed {
                    ledger.note_dispatched(1);
                    lock_or_recover(&results).push(1);
                } else {
                    lock_or_recover(&cancelled).insert(1);
                }
            })
        };

        // Flusher + worker: sweep ripe buckets, count the dispatch, then
        // solve (or short-circuit on the cancel marker) and send the result.
        let dispatcher = {
            let (sched, ledger, cancelled, results) = (
                Arc::clone(&sched),
                Arc::clone(&ledger),
                Arc::clone(&cancelled),
                Arc::clone(&results),
            );
            thread::spawn(move || {
                let ripe = lock_or_recover(&sched)
                    .take_over_linger(Instant::now(), Duration::ZERO);
                for b in ripe {
                    ledger.note_dispatched(b.len() as u64);
                    for j in b {
                        let _ = lock_or_recover(&cancelled).remove(&j.id);
                        lock_or_recover(&results).push(j.id);
                    }
                }
            })
        };

        canceller.join().expect("canceller must terminate");
        dispatcher.join().expect("dispatcher must terminate");

        // Fetch loop: every result is received exactly once.
        let got = lock_or_recover(&results).clone();
        assert_eq!(got, vec![1], "exactly one result for job 1, whoever claimed it");
        for _ in &got {
            ledger.note_received();
        }
        assert_eq!(ledger.inflight(), 0, "the ledger drains exactly");
    });
}

/// Race 4: a worker panicking mid-batch — with the reported-set mutex held —
/// while a fetcher monitors the result channel. The supervisor recovers the
/// poisoned pre-panic reported set and synthesizes results for exactly the
/// members that had not reported; the fetcher sees one result per member in
/// every interleaving of the unwind and the fetch.
#[test]
fn panic_respawn_racing_a_fetch_loses_no_result() {
    model(|| {
        // (results, result-arrival condvar) — the res_rx stand-in.
        let results: Arc<(Mutex<Vec<u64>>, Condvar)> =
            Arc::new((Mutex::new(Vec::new()), Condvar::new()));

        // Worker + supervisor for the 2-member batch [1, 2].
        let worker = {
            let results = Arc::clone(&results);
            thread::spawn(move || {
                let reported: Mutex<BTreeSet<u64>> = Mutex::new(BTreeSet::new());
                let panicked = catch_unwind(AssertUnwindSafe(|| {
                    // Member 1 reports, then the solve panics with the
                    // reported-set guard alive — poisoning the mutex exactly
                    // the way a mid-insert unwind would.
                    {
                        let (res, cv) = &*results;
                        lock_or_recover(res).push(1);
                        cv.notify_all();
                    }
                    let mut rep = lock_or_recover(&reported);
                    rep.insert(1);
                    std::panic::panic_any(Quiet("scripted mid-batch panic"));
                }))
                .is_err();
                assert!(panicked, "the scripted panic must unwind");
                // Supervisor: recover the pre-panic reported set and
                // synthesize one error result per unreported member.
                let rep = lock_or_recover(&reported);
                for id in [1u64, 2] {
                    if !rep.contains(&id) {
                        let (res, cv) = &*results;
                        lock_or_recover(res).push(id);
                        cv.notify_all();
                    }
                }
            })
        };

        // Fetcher: block until both results have arrived (a lost notify
        // here would be a modeled deadlock).
        let fetcher = {
            let results = Arc::clone(&results);
            thread::spawn(move || {
                let (res, cv) = &*results;
                let mut got = lock_or_recover(res);
                while got.len() < 2 {
                    got = cv.wait(got).unwrap_or_else(|p| p.into_inner());
                }
                let mut ids = got.clone();
                ids.sort_unstable();
                assert_eq!(ids, vec![1, 2], "one result per batch member");
            })
        };

        worker.join().expect("worker must terminate");
        fetcher.join().expect("fetcher must terminate");
    });
}
