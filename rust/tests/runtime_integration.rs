//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have run; they self-skip (with a loud
//! message) when `artifacts/manifest.json` is absent so `cargo test` stays
//! usable in a fresh checkout.

use prism::linalg::Mat;
use prism::prism::polar::orthogonality_error;
use prism::rng::Rng;
use prism::runtime::{f32_to_mat, mat_to_f32, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in [
        "init_params",
        "train_step",
        "polar_step_d1",
        "polar_step_d2",
        "polar_residual_traces",
    ] {
        assert!(rt.manifest.get(name).is_some(), "missing {name}");
    }
}

#[test]
fn polar_step_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("polar_step_d2").expect("load");
    let (m, n) = {
        let s = &exe.entry.inputs[0].shape;
        (s[0] as usize, s[1] as usize)
    };
    let mut rng = Rng::seed_from(42);
    let mut a = Mat::gaussian(&mut rng, m, n, 1.0);
    let fro = a.fro_norm();
    a.scale(1.0 / fro);
    let alpha = 1.2_f32;

    let out = exe
        .run_f32(&[&mat_to_f32(&a), &[alpha]])
        .expect("execute polar_step_d2");
    let got = f32_to_mat(m, n, &out[0]).unwrap();

    // Rust-native reference of the same update: R = I − XᵀX; X(I + R/2 + αR²).
    let r = {
        let mut r = prism::linalg::gemm::syrk_at_a(&a).scaled(-1.0);
        r.add_diag(1.0);
        r
    };
    let r2 = prism::linalg::gemm::matmul(&r, &r);
    let mut g = r.scaled(0.5);
    g.axpy(alpha as f64, &r2);
    g.add_diag(1.0);
    let want = prism::linalg::gemm::matmul(&a, &g);

    let err = got.sub(&want).max_abs();
    assert!(err < 1e-4, "pallas-HLO vs rust mismatch: {err}");
}

#[test]
fn iterated_polar_step_orthogonalizes() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("polar_step_d2").expect("load");
    let (m, n) = {
        let s = &exe.entry.inputs[0].shape;
        (s[0] as usize, s[1] as usize)
    };
    let mut rng = Rng::seed_from(7);
    let mut a = Mat::gaussian(&mut rng, m, n, 1.0);
    let fro = a.fro_norm();
    a.scale(1.0 / fro);
    let mut x = mat_to_f32(&a);
    for k in 0..30 {
        // α schedule: aggressive early, Taylor-like later (what the Rust
        // coordinator does via the sketch fit).
        let alpha: f32 = if k < 10 { 1.45 } else { 0.375 };
        let out = exe.run_f32(&[&x, &[alpha]]).expect("step");
        x = out.into_iter().next().unwrap();
    }
    let q = f32_to_mat(m, n, &x).unwrap();
    let err = orthogonality_error(&q);
    assert!(err < 1e-2, "orthogonality after 30 pallas steps: {err}");
}

#[test]
fn residual_traces_artifact_matches_rust_sketch() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("polar_residual_traces").expect("load");
    let (m, n) = {
        let s = &exe.entry.inputs[0].shape;
        (s[0] as usize, s[1] as usize)
    };
    let p = exe.entry.inputs[1].shape[0] as usize;
    let q = exe.entry.outputs[0].shape[0] as usize;
    let mut rng = Rng::seed_from(9);
    let mut a = Mat::gaussian(&mut rng, m, n, 1.0);
    let fro = a.fro_norm();
    a.scale(1.0 / fro);
    let s = Mat::gaussian(&mut rng, p, n, 1.0 / (p as f64).sqrt());

    let out = exe
        .run_f32(&[&mat_to_f32(&a), &mat_to_f32(&s)])
        .expect("execute traces");
    let traces_pallas = &out[0];
    let fro_pallas = out[1][0] as f64;

    // Rust-native computation.
    let r = {
        let mut r = prism::linalg::gemm::syrk_at_a(&a).scaled(-1.0);
        r.add_diag(1.0);
        r
    };
    let sk = prism::sketch::GaussianSketch { s };
    let traces_rust = sk.power_traces(&r, q);
    for i in 0..q {
        let rel = (traces_pallas[i] as f64 - traces_rust[i]).abs()
            / traces_rust[i].abs().max(1e-6);
        assert!(rel < 1e-3, "trace {i}: pallas={} rust={}", traces_pallas[i], traces_rust[i]);
    }
    assert!((fro_pallas - r.fro_norm()).abs() / r.fro_norm() < 1e-4);
}

#[test]
fn train_step_loss_reasonable_and_finite_grads() {
    let Some(rt) = runtime() else { return };
    let step = rt.load("train_step").expect("load step");
    let init = rt.load("init_params").expect("load init");
    let params = init.run_f32(&[&[0.5f32]]).expect("init params");
    let nparams = step.entry.inputs.len() - 2;
    assert_eq!(params.len(), nparams);

    let batch = step.entry.meta.get("batch").unwrap().as_int().unwrap() as usize;
    let seq = step.entry.meta.get("seq_len").unwrap().as_int().unwrap() as usize;
    let vocab = step.entry.meta.get("vocab").unwrap().as_int().unwrap() as f64;

    let mut rng = Rng::seed_from(3);
    let tokens: Vec<f32> = (0..batch * seq)
        .map(|_| rng.below(vocab as usize) as f32)
        .collect();
    let mut inputs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    inputs.push(&tokens);
    inputs.push(&tokens);
    let out = step.run_f32(&inputs).expect("train step");
    let loss = out[0][0] as f64;
    assert!(loss.is_finite());
    assert!((loss - vocab.ln()).abs() < 1.0, "init loss {loss} vs ln V {}", vocab.ln());
    // All grads finite, most non-zero.
    let mut nonzero = 0;
    for g in &out[1..] {
        assert!(g.iter().all(|x| x.is_finite()));
        if g.iter().any(|&x| x != 0.0) {
            nonzero += 1;
        }
    }
    assert!(nonzero >= nparams - 1);
}
