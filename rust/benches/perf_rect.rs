//! Rectangular polar: Gram-route speedup over the square-padded baseline
//! (the Fig. 6-style table for the rect subsystem).
//!
//! A tall m × p operand (aspect = m/p ∈ {2, 4, 8}) is orthogonalized two
//! ways under the same fixed iteration budget:
//!
//! * **rect** — `<method>-rectpolar`: the Gram route forms G = AᵀA by SYRK
//!   (p²m flops), iterates G^{-1/2} on the p × p Gram matrix (O(p³) per
//!   step), and finishes with one skinny GEMM A·G^{-1/2} (2mp²).
//! * **square** — `<method>-polar` on the identity-padded m × m embedding
//!   (B[:, :p] = A, B[j, j] = 1 for j ≥ p): the pre-subsystem way to push a
//!   rectangular param through a square-only solver, O(m³) per step.
//!
//! Besides wall time the table reports per-call GEMM flops from
//! [`GemmScope`] — the acceptance gate: the Gram route must spend strictly
//! fewer flops than the padded route at every aspect ≥ 2. Rows land in
//! `bench_out/BENCH_rect.json` with an `aspect` key (CI greps `"aspect":8`).
//!
//! Run: `cargo bench --bench perf_rect [-- --full | -- --smoke]`
//! (`--smoke` shrinks p, not the aspect sweep — the CI grep needs all rows).

use prism::benchkit::{banner, Bench, JsonReport, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::GemmScope;
use prism::linalg::Mat;
use prism::matfn::registry;
use prism::prism::StopRule;
use prism::randmat;
use prism::rng::Rng;

/// Identity-padded m × m embedding of a tall m × p operand.
fn pad_square(a: &Mat) -> Mat {
    let (m, p) = a.shape();
    let mut b = Mat::zeros(m, m);
    for i in 0..m {
        for j in 0..p {
            b[(i, j)] = a[(i, j)];
        }
    }
    for j in p..m {
        b[(j, j)] = 1.0;
    }
    b
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let smoke = std::env::args().any(|a| a == "--smoke");
    banner(
        "perf_rect — Gram-route rectangular polar vs square-padded baseline",
        "aspect sweep at a fixed iteration budget; flops from GemmScope",
    );
    let bench = if full { Bench::default() } else { Bench::quick() };
    // Fixed budget: the point is per-iteration cost vs shape, not
    // convergence (both routes run the identical iteration count).
    let stop = StopRule::default().with_max_iters(8).with_tol(1e-30);
    let p: usize = if smoke {
        8
    } else if full {
        64
    } else {
        32
    };
    let aspects: &[usize] = &[2, 4, 8];
    let mut report = JsonReport::create("bench_out/BENCH_rect.json", "perf_rect");

    let mut t = Table::new(&[
        "solver",
        "aspect",
        "shape",
        "route",
        "rect ms",
        "square ms",
        "speedup",
        "rect Mflop",
        "square Mflop",
    ]);
    for method in ["ns", "prism5"] {
        for &aspect in aspects {
            let m = p * aspect;
            let mut rng = Rng::seed_from(23);
            let s = randmat::logspace(0.1, 1.0, p);
            let a = randmat::with_spectrum(&mut rng, m, p, &s);
            let b = pad_square(&a);

            let rect_key = format!("{method}-rectpolar");
            let square_key = format!("{method}-polar");

            let mut rect = registry::resolve(&rect_key).unwrap();
            rect.set_stop(stop);
            let _ = rect.solve(&a, &mut rng); // warm the workspace
            let scope = GemmScope::begin();
            let _ = rect.solve(&a, &mut rng);
            let rect_flops = scope.flops();
            let rt = bench.run(&format!("{rect_key}_{m}x{p}"), || {
                std::hint::black_box(rect.solve(&a, &mut rng).log.iters());
            });

            let mut square = registry::resolve(&square_key).unwrap();
            square.set_stop(stop);
            let _ = square.solve(&b, &mut rng);
            let scope = GemmScope::begin();
            let _ = square.solve(&b, &mut rng);
            let square_flops = scope.flops();
            let st = bench.run(&format!("{square_key}_pad_{m}"), || {
                std::hint::black_box(square.solve(&b, &mut rng).log.iters());
            });

            // The acceptance gate: Gram-route work is O(p²m) + O(p³)-class,
            // strictly below the padded route's O(m³) at aspect ≥ 2.
            assert!(
                rect_flops < square_flops,
                "{rect_key} {m}x{p}: Gram route must spend fewer flops \
                 ({rect_flops} vs {square_flops})"
            );

            t.row(&[
                rect_key.clone(),
                aspect.to_string(),
                format!("{m}x{p}"),
                "gram".into(), // aspect ≥ 2 always resolves to Gram
                format!("{:.2}", rt.median_s() * 1e3),
                format!("{:.2}", st.median_s() * 1e3),
                format!("{:.2}x", st.median_s() / rt.median_s()),
                format!("{:.1}", rect_flops as f64 / 1e6),
                format!("{:.1}", square_flops as f64 / 1e6),
            ]);
            report.entry(&[
                ("solver", Value::Str(rect_key.clone())),
                ("aspect", Value::Int(aspect as i64)),
                ("m", Value::Int(m as i64)),
                ("p", Value::Int(p as i64)),
                ("route", Value::Str("gram".into())),
                ("rect_ms", Value::Float(rt.median_s() * 1e3)),
                ("square_ms", Value::Float(st.median_s() * 1e3)),
                ("speedup_vs_square", Value::Float(st.median_s() / rt.median_s())),
                ("rect_flops", Value::Int(rect_flops as i64)),
                ("square_flops", Value::Int(square_flops as i64)),
            ]);
        }
    }
    t.print();
    println!("\nNotes: both routes run the same fixed iteration budget; 'square' solves");
    println!("the identity-padded m×m embedding. Flops are per warm call (GemmScope,");
    println!("this thread only) — the rect column must stay strictly below square at");
    println!("every aspect ≥ 2, which the bench asserts.");
    match report.finish() {
        Some(path) => println!("report → {path}"),
        None => println!("report → (unwritable bench_out/, skipped)"),
    }
}
