//! Figure 4: convergence of degree-5 polynomial methods for orthogonalizing
//! heavy-tailed HTMP random matrices (Hodgkinson et al. 2025) with tail
//! parameter κ ∈ {0.1, 0.5, 100}; right panel — the α_k traces.
//!
//! Small κ ⇒ heavier right tail in the singular-value distribution (the
//! spectra of gradient matrices in well-trained networks). The paper's point:
//! PRISM's α_k trace *differs qualitatively* between heavy-tailed and
//! bulk-only spectra — adaptation the fixed schedules can't do.

use prism::baselines::polar_express::PolarExpress;
use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::prism::polar::{polar_prism, PolarOpts};
use prism::prism::{IterationLog, StopRule};
use prism::randmat;
use prism::rng::Rng;

const TOL: f64 = 1e-8;

fn row_series(series: &mut SeriesWriter, kappa: f64, method: &str, log: &IterationLog) {
    for (k, &r) in log.residuals.iter().enumerate() {
        series.point(&[
            ("kappa", Value::Float(kappa)),
            ("method", Value::Str(method.into())),
            ("iter", Value::Int(k as i64)),
            (
                "time_s",
                Value::Float(if k == 0 { 0.0 } else { log.times_s[k - 1] }),
            ),
            ("residual", Value::Float(r)),
        ]);
    }
}

fn main() {
    banner(
        "Figure 4 — polar convergence on heavy-tailed (HTMP) matrices",
        "paper Fig. 4 (wall-clock) / Fig. D.2 (iterations); paper uses n=8000, m=4000",
    );
    // Paper: 8000x4000 on an A100; CPU substitute keeps the 2:1 aspect.
    let (n, m) = (256, 128);
    let stop = StopRule::default().with_max_iters(300).with_tol(TOL);
    let pe = PolarExpress::paper_default();
    let mut series = SeriesWriter::create("bench_out/fig4.jsonl");
    let mut rng = Rng::seed_from(42);

    let mut t = Table::new(&[
        "kappa",
        "NS-5 iters",
        "NS-5 ms",
        "PolarExpress iters",
        "PE ms",
        "PRISM-5 iters",
        "PRISM ms",
    ]);
    let mut alpha_rows: Vec<(f64, Vec<f64>)> = Vec::new();
    for kappa in [0.1f64, 0.5, 100.0] {
        let a = randmat::htmp(&mut rng, n, m, kappa);

        let classic = polar_prism(&a, &PolarOpts::classic(2).with_stop(stop), &mut rng);
        let (_, pe_log) = pe.polar(&a, &stop);
        let fast = polar_prism(&a, &PolarOpts::degree5().with_stop(stop), &mut rng);

        row_series(&mut series, kappa, "newton-schulz", &classic.log);
        row_series(&mut series, kappa, "polar-express", &pe_log);
        row_series(&mut series, kappa, "prism", &fast.log);

        let it = |l: &IterationLog| {
            l.iters_to_tol(TOL).map(|k| k.to_string()).unwrap_or_else(|| "—".into())
        };
        let ms = |l: &IterationLog| format!("{:.1}", l.time_to_tol(TOL).unwrap_or(l.wall_s) * 1e3);
        t.row(&[
            format!("{kappa}"),
            it(&classic.log),
            ms(&classic.log),
            it(&pe_log),
            ms(&pe_log),
            it(&fast.log),
            ms(&fast.log),
        ]);
        alpha_rows.push((kappa, fast.log.alphas.clone()));
    }
    println!("\nHTMP A ({n}x{m}), ‖I − XᵀX‖_F < {TOL:.0e}:");
    t.print();

    println!("\nright panel — PRISM α_k per κ (heavier tail ⇒ longer high-α phase):");
    for (kappa, alphas) in &alpha_rows {
        let pts: Vec<String> = alphas.iter().map(|a| format!("{a:.3}")).collect();
        println!("  κ={kappa:<5} [{}]", pts.join(", "));
        for (k, &a) in alphas.iter().enumerate() {
            series.point(&[
                ("kappa", Value::Float(*kappa)),
                ("method", Value::Str("prism-alpha".into())),
                ("iter", Value::Int(k as i64)),
                ("alpha", Value::Float(a)),
            ]);
        }
    }
    println!("\nexpected shape: smaller κ (heavier tail, wider spread of σ) ⇒ more");
    println!("iterations for everyone, biggest PRISM advantage; κ=100 ≈ MP bulk only.");
    println!("series → bench_out/fig4.jsonl");
}
