//! L3 coordinator throughput/latency under load — the service-side view
//! used in EXPERIMENTS.md §Perf.
//!
//! Three sections:
//!
//! * **sweep** — worker count, batching limit, and backend on a fixed
//!   synthetic gradient stream, reporting jobs/s and latency percentiles.
//!   The service must scale with workers until the GEMM work saturates
//!   physical cores, and batching must trade p50 latency for throughput.
//! * **amortization** — a same-shape InvSqrt burst swept over `max_batch`,
//!   counting *sketch fills*: the batched lockstep path draws one sketch
//!   per iteration shared across the whole batch, so fills per batch stay
//!   at O(iters) — roughly the per-job iteration count — independent of
//!   batch size, where per-job solving would pay O(batch · iters).
//! * **zoo** — a round-robin mixed-shape "model zoo" stream, the worst
//!   case for arrival-order batching: adjacent jobs never share a shape.
//!   FIFO cutting (emulated by flushing on every shape change) dispatches
//!   singletons; the shape-bucketed scheduler fills full lockstep batches
//!   per shape, multiplying batch occupancy and dividing fills/solve.
//!
//! All sections land in `bench_out/BENCH_service.json` (uploaded by CI
//! next to `BENCH_gemm.json`/`BENCH_matfn.json`); `--smoke` runs tiny sizes
//! but still writes the full report shape; `--zoo` runs the zoo section
//! alone (it always runs as part of the full and smoke sweeps too).

use prism::benchkit::{banner, JsonReport, SeriesWriter, Table};
use prism::config::{Backend, ServiceConfig};
use prism::configfmt::Value;
use prism::coordinator::service::{JobKind, Service};
use prism::linalg::gemm::syrk_at_a;
use prism::linalg::Mat;
use prism::randmat;
use prism::rng::Rng;
use prism::util::Stopwatch;
use prism::workload::GradientStream;

fn service_cfg(workers: usize, max_batch: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        queue_cap: 256,
        admission: prism::config::Admission::Block,
        max_batch,
        sketch_p: 8,
        max_iters: 60,
        tol: Some(1e-7),
        precision: prism::matfn::Precision::F64,
        solver_cache_cap: 32,
        gemm_threads: 1,
        stream_residuals: false,
        gemm_block: None,
        gemm_kernel: None,
        faults: None,
        linger: None,
        cache_snapshot: None,
    }
}

fn run(
    workers: usize,
    max_batch: usize,
    backend: Backend,
    jobs: usize,
    n: usize,
) -> (f64, f64, f64) {
    let shapes = vec![(n, n), (n, n / 2)];
    let mut stream = GradientStream::new(42, shapes, 0.5);
    let svc =
        Service::start(service_cfg(workers, max_batch), backend, 42).expect("valid bench config");
    let sw = Stopwatch::start();
    for _ in 0..jobs {
        let (layer, g) = stream.next_grad();
        let (r, c) = g.shape();
        if r == c {
            svc.submit(layer, JobKind::InvSqrt { eps: 1e-8 }, syrk_at_a(&g)).unwrap();
        } else {
            svc.submit(layer, JobKind::Polar, g).unwrap();
        }
    }
    let results = svc.drain().unwrap();
    let wall = sw.elapsed_s();
    let mut lat: Vec<f64> = results.iter().map(|r| r.latency_s * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize];
    (jobs as f64 / wall, pct(0.5), pct(0.99))
}

/// Same-shape InvSqrt burst through one worker at a given batch size.
/// Returns (jobs/s, sketch fills, total solver iterations, batches).
fn run_amortization(max_batch: usize, inputs: &[Mat]) -> (f64, u64, u64, usize) {
    let jobs = inputs.len();
    let svc =
        Service::start(service_cfg(1, max_batch), Backend::Prism5, 42).expect("valid bench config");
    let fills0 = prism::sketch::fills_total();
    let sw = Stopwatch::start();
    for (layer, a) in inputs.iter().enumerate() {
        svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
    }
    let results = svc.drain().unwrap();
    let wall = sw.elapsed_s();
    let fills = prism::sketch::fills_total() - fills0;
    let iters: u64 = results.iter().map(|r| r.iters as u64).sum();
    let nbatches = jobs.div_ceil(max_batch);
    (jobs as f64 / wall, fills, iters, nbatches)
}

/// Mixed-shape round-robin burst through one worker. `fifo` emulates the
/// pre-bucket arrival-order cutter by flushing whenever the incoming shape
/// differs from the previous job's (consecutive same-shape jobs still
/// batch; any shape change cuts). Returns (jobs/s, mean batch occupancy,
/// sketch fills).
fn run_zoo(fifo: bool, max_batch: usize, inputs: &[(usize, Mat)]) -> (f64, f64, u64) {
    let svc =
        Service::start(service_cfg(1, max_batch), Backend::Prism5, 42).expect("valid bench config");
    let fills0 = prism::sketch::fills_total();
    let sw = Stopwatch::start();
    let mut prev = None;
    for (layer, a) in inputs {
        if fifo && prev.is_some_and(|p| p != a.shape()) {
            svc.flush().unwrap();
        }
        prev = Some(a.shape());
        svc.submit(*layer, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
    }
    let results = svc.drain().unwrap();
    let wall = sw.elapsed_s();
    let fills = prism::sketch::fills_total() - fills0;
    let occupancy = svc.metrics.histogram("service.batch_size").mean();
    (results.len() as f64 / wall, occupancy, fills)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let zoo_only = std::env::args().any(|a| a == "--zoo");
    banner("perf — preconditioner service throughput/latency", "EXPERIMENTS.md §Perf (L3)");
    let (jobs, n) = if smoke { (12, 24) } else { (64, 96) };
    let mut series = SeriesWriter::create("bench_out/perf_service.jsonl");
    let mut report = JsonReport::create("bench_out/BENCH_service.json", "perf_service");

    if !zoo_only {
        let mut t =
            Table::new(&["workers", "max_batch", "backend", "jobs/s", "p50 ms", "p99 ms"]);
        let mut cases: Vec<(usize, usize, Backend, &str)> = Vec::new();
        let worker_sweep: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
        for &w in worker_sweep {
            cases.push((w, 4, Backend::Prism5, "prism5"));
        }
        let batch_sweep: &[usize] = if smoke { &[1, 8] } else { &[1, 2, 8, 16] };
        for &b in batch_sweep {
            cases.push((4, b, Backend::Prism5, "prism5"));
        }
        let backends: &[(Backend, &str)] = if smoke {
            &[(Backend::Eigen, "eigen")]
        } else {
            &[
                (Backend::Eigen, "eigen"),
                (Backend::PolarExpress, "polar-express"),
                (Backend::Prism3, "prism3"),
                (Backend::NewtonSchulz, "newton-schulz"),
            ]
        };
        for &(bk, nm) in backends {
            cases.push((4, 4, bk, nm));
        }
        for (w, b, bk, nm) in cases {
            let (jps, p50, p99) = run(w, b, bk, jobs, n);
            t.row(&[
                w.to_string(),
                b.to_string(),
                nm.to_string(),
                format!("{jps:.1}"),
                format!("{p50:.1}"),
                format!("{p99:.1}"),
            ]);
            let fields = [
                ("section", Value::Str("sweep".into())),
                ("workers", Value::Int(w as i64)),
                ("max_batch", Value::Int(b as i64)),
                ("backend", Value::Str(nm.into())),
                ("jobs_per_s", Value::Float(jps)),
                ("p50_ms", Value::Float(p50)),
                ("p99_ms", Value::Float(p99)),
            ];
            series.point(&fields[1..]);
            report.entry(&fields);
        }
        println!("\n{jobs} jobs, base shape {n}x{n}, HTMP(κ=0.5):");
        t.print();
        println!("\nexpected: throughput scales with workers to core count; larger batches");
        println!("raise p50 (queueing) without throughput loss; PRISM ≥ eigen at this size.");
    }

    // ── amortization: sketch fills per batch vs batch size ──────────────
    let (burst_jobs, bn) = if smoke { (16, 16) } else { (48, 64) };
    let mut rng = Rng::seed_from(7);
    let w = randmat::logspace(1e-2, 1.0, bn);
    let inputs: Vec<Mat> =
        (0..burst_jobs).map(|_| randmat::sym_with_spectrum(&mut rng, bn, &w)).collect();
    if !zoo_only {
        let mut t2 = Table::new(&[
            "max_batch",
            "jobs/s",
            "batches",
            "sketch fills",
            "fills/batch",
            "iters/job",
        ]);
        for b in [1usize, 2, 4, 8, 16] {
            let (jps, fills, iters, nbatches) = run_amortization(b, &inputs);
            let fills_per_batch = fills as f64 / nbatches as f64;
            let iters_per_job = iters as f64 / burst_jobs as f64;
            t2.row(&[
                b.to_string(),
                format!("{jps:.1}"),
                nbatches.to_string(),
                fills.to_string(),
                format!("{fills_per_batch:.1}"),
                format!("{iters_per_job:.1}"),
            ]);
            report.entry(&[
                ("section", Value::Str("amortization".into())),
                ("max_batch", Value::Int(b as i64)),
                ("jobs", Value::Int(burst_jobs as i64)),
                ("n", Value::Int(bn as i64)),
                ("jobs_per_s", Value::Float(jps)),
                ("batches", Value::Int(nbatches as i64)),
                ("sketch_fills", Value::Int(fills as i64)),
                ("fills_per_batch", Value::Float(fills_per_batch)),
                ("total_iters", Value::Int(iters as i64)),
                ("iters_per_job", Value::Float(iters_per_job)),
            ]);
        }
        println!("\nsame-shape InvSqrt burst: {burst_jobs} jobs of {bn}x{bn}, 1 worker, prism5:");
        t2.print();
        println!("\nexpected: fills/batch stays at O(iters) — about iters/job, the longest");
        println!("member's count — independent of batch size (shared lockstep sketch),");
        println!("where per-job solving would pay batch · iters/job fills per batch.");
    }

    // ── zoo: mixed-shape tenants, arrival-order cuts vs shape buckets ───
    // Round-robin across shapes is the worst case for arrival-order
    // batching: adjacent jobs never share a shape, so the FIFO emulation
    // dispatches singletons while the bucketed scheduler fills full
    // lockstep batches per shape.
    let (per_shape, zoo_shapes): (usize, &[usize]) =
        if smoke { (8, &[12, 16, 20, 24]) } else { (16, &[24, 32, 48, 64]) };
    let mut zrng = Rng::seed_from(11);
    let mut zoo_inputs: Vec<(usize, Mat)> = Vec::new();
    for _ in 0..per_shape {
        for (layer, &zn) in zoo_shapes.iter().enumerate() {
            let zw = randmat::logspace(1e-2, 1.0, zn);
            zoo_inputs.push((layer, randmat::sym_with_spectrum(&mut zrng, zn, &zw)));
        }
    }
    let mut t3 = Table::new(&[
        "scheduler",
        "max_batch",
        "jobs/s",
        "batch occupancy",
        "sketch fills",
        "fills/solve",
    ]);
    for fifo in [true, false] {
        let (jps, occ, fills) = run_zoo(fifo, 4, &zoo_inputs);
        let mode = if fifo { "fifo" } else { "bucketed" };
        let fills_per_solve = fills as f64 / zoo_inputs.len() as f64;
        t3.row(&[
            mode.to_string(),
            "4".to_string(),
            format!("{jps:.1}"),
            format!("{occ:.2}"),
            fills.to_string(),
            format!("{fills_per_solve:.1}"),
        ]);
        report.entry(&[
            ("section", Value::Str("zoo".into())),
            ("scheduler", Value::Str(mode.into())),
            ("max_batch", Value::Int(4)),
            ("jobs", Value::Int(zoo_inputs.len() as i64)),
            ("shapes", Value::Int(zoo_shapes.len() as i64)),
            ("batch_occupancy", Value::Float(occ)),
            ("sketch_fills", Value::Int(fills as i64)),
            ("fills_per_solve", Value::Float(fills_per_solve)),
            ("jobs_per_s", Value::Float(jps)),
        ]);
    }
    println!(
        "\nmodel zoo: {} jobs round-robin over {} shapes, 1 worker, prism5:",
        zoo_inputs.len(),
        zoo_shapes.len()
    );
    t3.print();
    println!("\nexpected: bucketed occupancy reaches max_batch (>2x the fifo emulation's");
    println!("singletons) and fills/solve drops accordingly via the shared lockstep sketch.");

    // ── robustness counters: one tiny burst's full metrics report ───────
    // CI grep-gates `service.worker_panics` and `service.jobs_escalated`
    // in the smoke output: the supervision counters must always appear
    // (explicit zeros on a clean run), or a metrics regression could
    // silently hide real incidents.
    let svc = Service::start(service_cfg(1, 4), Backend::Prism5, 42).expect("valid bench config");
    for (layer, a) in inputs.iter().take(4).enumerate() {
        svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
    }
    let _ = svc.drain().unwrap();
    println!("\nservice metrics (clean run — the fault counters report zero):");
    println!("{}", svc.report());
    match report.finish() {
        Some(path) => println!("report → {path}  (series → bench_out/perf_service.jsonl)"),
        None => println!("report not written (read-only checkout?)"),
    }
}
