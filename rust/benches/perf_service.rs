//! L3 coordinator throughput/latency under load — the service-side view
//! used in EXPERIMENTS.md §Perf.
//!
//! Sweeps worker count, batching limit, and backend on a fixed synthetic
//! gradient stream, reporting jobs/s and latency percentiles. The service
//! must scale with workers until the GEMM work saturates physical cores, and
//! batching must trade p50 latency for throughput — both are asserted
//! qualitatively in the printed notes.

use prism::benchkit::{banner, SeriesWriter, Table};
use prism::config::{Backend, ServiceConfig};
use prism::configfmt::Value;
use prism::coordinator::service::{JobKind, Service};
use prism::linalg::gemm::syrk_at_a;
use prism::util::Stopwatch;
use prism::workload::GradientStream;

fn run(workers: usize, max_batch: usize, backend: Backend, jobs: usize, n: usize) -> (f64, f64, f64) {
    let cfg = ServiceConfig {
        workers,
        queue_capacity: 256,
        max_batch,
        sketch_p: 8,
        max_iters: 60,
        tol: 1e-7,
        gemm_threads: 1,
        stream_residuals: false,
        gemm_block: None,
        gemm_kernel: None,
    };
    let shapes = vec![(n, n), (n, n / 2)];
    let mut stream = GradientStream::new(42, shapes, 0.5);
    let svc = Service::start(cfg, backend, 42);
    let sw = Stopwatch::start();
    for _ in 0..jobs {
        let (layer, g) = stream.next_grad();
        let (r, c) = g.shape();
        if r == c {
            svc.submit(layer, JobKind::InvSqrt { eps: 1e-8 }, syrk_at_a(&g)).unwrap();
        } else {
            svc.submit(layer, JobKind::Polar, g).unwrap();
        }
    }
    let results = svc.drain().unwrap();
    let wall = sw.elapsed_s();
    let mut lat: Vec<f64> = results.iter().map(|r| r.latency_s * 1e3).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize];
    (jobs as f64 / wall, pct(0.5), pct(0.99))
}

fn main() {
    banner("perf — preconditioner service throughput/latency", "EXPERIMENTS.md §Perf (L3)");
    let jobs = 64;
    let n = 96;
    let mut series = SeriesWriter::create("bench_out/perf_service.jsonl");

    let mut t = Table::new(&["workers", "max_batch", "backend", "jobs/s", "p50 ms", "p99 ms"]);
    let mut cases: Vec<(usize, usize, Backend, &str)> = Vec::new();
    for w in [1usize, 2, 4, 8] {
        cases.push((w, 4, Backend::Prism5, "prism5"));
    }
    for b in [1usize, 2, 8, 16] {
        cases.push((4, b, Backend::Prism5, "prism5"));
    }
    for (bk, nm) in [
        (Backend::Eigen, "eigen"),
        (Backend::PolarExpress, "polar-express"),
        (Backend::Prism3, "prism3"),
        (Backend::NewtonSchulz, "newton-schulz"),
    ] {
        cases.push((4, 4, bk, nm));
    }
    for (w, b, bk, nm) in cases {
        let (jps, p50, p99) = run(w, b, bk, jobs, n);
        t.row(&[
            w.to_string(),
            b.to_string(),
            nm.to_string(),
            format!("{jps:.1}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
        ]);
        series.point(&[
            ("workers", Value::Int(w as i64)),
            ("max_batch", Value::Int(b as i64)),
            ("backend", Value::Str(nm.into())),
            ("jobs_per_s", Value::Float(jps)),
            ("p50_ms", Value::Float(p50)),
            ("p99_ms", Value::Float(p99)),
        ]);
    }
    println!("\n{jobs} jobs, base shape {n}x{n}, HTMP(κ=0.5):");
    t.print();
    println!("\nexpected: throughput scales with workers to core count; larger batches");
    println!("raise p50 (queueing) without throughput loss; PRISM ≥ eigen at this size.");
    println!("series → bench_out/perf_service.jsonl");
}
