//! Table 1: every PRISM-accelerated iteration family, classic vs PRISM, on a
//! shared ill-conditioned instance — the "all rows converge, PRISM never
//! slower" summary the paper's Table 1 asserts by construction.
//!
//! | rows | method | target |
//! |---|---|---|
//! | 1–2 | coupled Newton–Schulz d=1 / d=2 | A^{1/2}, A^{-1/2} |
//! | 3–4 | Newton–Schulz d=1 / d=2 | U Vᵀ |
//! | 5  | coupled inverse Newton (p=1,2,3) | A^{-1/p} |
//! | 6  | DB Newton (product form) | A^{1/2}, A^{-1/2} |
//! | 7  | Chebyshev | A^{-1} |

use prism::benchkit::{banner, Table};
use prism::linalg::gemm::syrk_at_a;
use prism::prism::chebyshev::{chebyshev_inverse, ChebyshevOpts};
use prism::prism::db_newton::{db_newton_prism, DbNewtonOpts};
use prism::prism::inverse_newton::{inv_root_prism, InvRootOpts};
use prism::prism::polar::{polar_prism, PolarOpts};
use prism::prism::sign::{sign_prism, SignOpts};
use prism::prism::sqrt::{sqrt_prism, SqrtOpts};
use prism::prism::{AlphaMode, IterationLog, StopRule};
use prism::randmat;
use prism::rng::Rng;

const TOL: f64 = 1e-8;

fn main() {
    banner("Table 1 — all PRISM-accelerated algorithm families", "paper Table 1");
    let stop = StopRule::default().with_max_iters(300).with_tol(TOL);
    let mut rng = Rng::seed_from(42);

    // Shared instances: rectangular A for polar, SPD GᵀG for roots/inverse.
    let (n, m) = (96, 64);
    let s = randmat::logspace(1e-4, 1.0, m);
    let a_rect = randmat::with_spectrum(&mut rng, n, m, &s);
    let a_spd = syrk_at_a(&a_rect);
    let a_sign = {
        let w: Vec<f64> = randmat::logspace(1e-4, 1.0, m)
            .iter()
            .enumerate()
            .map(|(i, &x)| if i % 2 == 0 { x } else { -x })
            .collect();
        randmat::sym_with_spectrum(&mut rng, m, &w)
    };

    let mut t = Table::new(&[
        "method (Table 1 row)",
        "target",
        "classic iters",
        "PRISM iters",
        "speedup",
        "final residual",
    ]);
    let mut push = |name: &str, target: &str, classic: &IterationLog, fast: &IterationLog| {
        let (ic, ip) = (
            classic.iters_to_tol(TOL).unwrap_or(classic.iters()),
            fast.iters_to_tol(TOL).unwrap_or(fast.iters()),
        );
        t.row(&[
            name.to_string(),
            target.to_string(),
            ic.to_string(),
            ip.to_string(),
            format!("{:.2}x", ic as f64 / ip.max(1) as f64),
            format!("{:.1e}", fast.final_residual()),
        ]);
    };

    // Rows 1–2: coupled NS square root, d = 1 and 2.
    for d in [1usize, 2] {
        let c = sqrt_prism(&a_spd, &SqrtOpts::classic(d).with_stop(stop), &mut rng);
        let opts = if d == 1 { SqrtOpts::degree3() } else { SqrtOpts::degree5() }.with_stop(stop);
        let p = sqrt_prism(&a_spd, &opts, &mut rng);
        push(
            &format!("Newton-Schulz {}th-order (row {})", 2 * d + 1, d),
            "A^{1/2}, A^{-1/2}",
            &c.log,
            &p.log,
        );
    }

    // Rows 3–4: NS polar, d = 1 and 2.
    for d in [1usize, 2] {
        let c = polar_prism(&a_rect, &PolarOpts::classic(d).with_stop(stop), &mut rng);
        let opts =
            if d == 1 { PolarOpts::degree3() } else { PolarOpts::degree5() }.with_stop(stop);
        let p = polar_prism(&a_rect, &opts, &mut rng);
        push(
            &format!("Newton-Schulz {}th-order (row {})", 2 * d + 1, d + 2),
            "U Vᵀ",
            &c.log,
            &p.log,
        );
    }

    // Row 5: coupled inverse Newton, p = 1, 2, 3.
    for p_root in [1usize, 2, 3] {
        let c = inv_root_prism(&a_spd, &InvRootOpts::classic(p_root).with_stop(stop), &mut rng);
        let p = inv_root_prism(&a_spd, &InvRootOpts::prism(p_root).with_stop(stop), &mut rng);
        push(
            &format!("Coupled inverse Newton p={p_root} (row 5)"),
            &format!("A^{{-1/{p_root}}}"),
            &c.log,
            &p.log,
        );
    }

    // Row 6: DB Newton.
    {
        let c = db_newton_prism(&a_spd, &DbNewtonOpts::classic().with_stop(stop), &mut rng);
        let p = db_newton_prism(&a_spd, &DbNewtonOpts::prism().with_stop(stop), &mut rng);
        push("DB Newton (row 6)", "A^{1/2}, A^{-1/2}", &c.log, &p.log);
    }

    // Row 7: Chebyshev inverse.
    {
        let sq = randmat::sym_with_spectrum(&mut rng, m, &randmat::logspace(1e-3, 1.0, m));
        let c = chebyshev_inverse(&sq, &ChebyshevOpts::classic().with_stop(stop), &mut rng);
        let p = chebyshev_inverse(&sq, &ChebyshevOpts::prism().with_stop(stop), &mut rng);
        push("Chebyshev (row 7)", "A^{-1}", &c.log, &p.log);
    }

    // Bonus: matrix sign (the §4 derivation everything builds on).
    {
        let c = sign_prism(
            &a_sign,
            &SignOpts { d: 1, alpha: AlphaMode::Classic, stop, normalize: true },
            &mut rng,
        );
        let p = sign_prism(
            &a_sign,
            &SignOpts { d: 1, alpha: AlphaMode::Sketched { p: 8 }, stop, normalize: true },
            &mut rng,
        );
        push("Newton-Schulz sign (§4)", "sign(A)", &c.log, &p.log);
    }

    println!("\ninstances: A {n}x{m} with σ ∈ [1e-4, 1]; SPD = AᵀA; tol {TOL:.0e}\n");
    t.print();
    println!("\nexpected: PRISM speedup ≥ 1.0x on every row (Theorem 1: never slower).");
}
