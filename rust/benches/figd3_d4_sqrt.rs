//! Figures D.3 / D.4: convergence of degree-5 polynomial methods for the
//! square root and inverse square root of A = GᵀG where G is Gaussian
//! (Wishart A; Fig. D.3, γ = n/m ∈ {1,4,50}) or HTMP heavy-tailed
//! (Fig. D.4, κ ∈ {0.1, 0.5, 100}); plus the α_k traces.
//!
//! Error metric is the paper's coupled residual; we also verify
//! ‖I − Y A Y‖ (Y ≈ A^{-1/2}) at the end of each run.

use prism::baselines::polar_express::PolarExpress;
use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::syrk_at_a;
use prism::prism::sqrt::{sqrt_error, sqrt_prism, SqrtOpts};
use prism::prism::{IterationLog, StopRule};
use prism::randmat;
use prism::rng::Rng;

const TOL: f64 = 1e-8;

fn run_family(
    title: &str,
    mats: Vec<(String, prism::linalg::Mat)>,
    stop: StopRule,
    series: &mut SeriesWriter,
    rng: &mut Rng,
) {
    let pe = PolarExpress::paper_default();
    let mut t = Table::new(&[
        "instance",
        "NS-5 iters",
        "PE-coupled iters",
        "PRISM-5 iters",
        "PRISM ‖I−YAY‖",
    ]);
    let mut alphas_out: Vec<(String, Vec<f64>)> = Vec::new();
    println!("\n{title}");
    for (label, a) in mats {
        let classic = sqrt_prism(&a, &SqrtOpts::classic(2).with_stop(stop), rng);
        let (_, _, pe_log) = pe.sqrt_coupled(&a, &stop);
        let fast = sqrt_prism(&a, &SqrtOpts::degree5().with_stop(stop), rng);
        for (m, log) in [
            ("newton-schulz", &classic.log),
            ("polar-express", &pe_log),
            ("prism", &fast.log),
        ] {
            for (k, &r) in log.residuals.iter().enumerate() {
                series.point(&[
                    ("instance", Value::Str(label.clone())),
                    ("method", Value::Str(m.into())),
                    ("iter", Value::Int(k as i64)),
                    ("residual", Value::Float(r)),
                ]);
            }
        }
        let it = |l: &IterationLog| {
            l.iters_to_tol(TOL).map(|k| k.to_string()).unwrap_or_else(|| "—".into())
        };
        t.row(&[
            label.clone(),
            it(&classic.log),
            it(&pe_log),
            it(&fast.log),
            format!("{:.1e}", sqrt_error(&a, &fast.inv_sqrt)),
        ]);
        alphas_out.push((label, fast.log.alphas.clone()));
    }
    t.print();
    println!("PRISM α_k traces:");
    for (label, alphas) in &alphas_out {
        let pts: Vec<String> = alphas.iter().map(|a| format!("{a:.3}")).collect();
        println!("  {label:<12} [{}]", pts.join(", "));
    }
}

fn main() {
    banner(
        "Figures D.3/D.4 — square-root convergence (coupled NS)",
        "paper Figs. D.3 (Wishart) and D.4 (HTMP), error ‖I − X^{-2}A‖",
    );
    let stop = StopRule::default().with_max_iters(300).with_tol(TOL);
    let mut series = SeriesWriter::create("bench_out/figd3_d4.jsonl");
    let mut rng = Rng::seed_from(42);

    let m = 64;
    let wishart: Vec<(String, prism::linalg::Mat)> = [1usize, 4, 50]
        .iter()
        .map(|&g| {
            let gm = randmat::gaussian(&mut rng, m * g, m);
            (format!("wishart γ={g}"), syrk_at_a(&gm))
        })
        .collect();
    run_family("D.3 — Wishart A = GᵀG, Gaussian G:", wishart, stop, &mut series, &mut rng);

    let (n, mm) = (192, 96);
    let htmp: Vec<(String, prism::linalg::Mat)> = [0.1f64, 0.5, 100.0]
        .iter()
        .map(|&k| {
            let gm = randmat::htmp(&mut rng, n, mm, k);
            (format!("htmp κ={k}"), syrk_at_a(&gm))
        })
        .collect();
    run_family("D.4 — A = GᵀG, heavy-tailed G:", htmp, stop, &mut series, &mut rng);

    println!("\nexpected: same ordering as the polar figures; squaring the spectrum makes");
    println!("conditioning worse, so the PRISM gap is larger than in Figs. 3/4.");
    println!("series → bench_out/figd3_d4.jsonl");
}
