//! Ablation: sketch family × sketch size.
//!
//! The paper (§4.2) defaults to Gaussian sketches and claims (a) p ≈ 5
//! suffices and (b) the family choice is not critical. This bench sweeps
//! all four implemented OSE families (Gaussian, Rademacher, CountSketch,
//! SRHT) and p ∈ {2, 5, 8, 16} on the hard polar instance, reporting
//! iterations-to-tolerance and total wall time — both should be flat in the
//! family axis and flat for p ≥ 5.

use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::prism::polar::{polar_prism, PolarOpts};
use prism::prism::{AlphaMode, StopRule};
use prism::randmat;
use prism::rng::Rng;
use prism::sketch::SketchKind;

const TOL: f64 = 1e-8;

fn main() {
    banner("ablation — sketch family × sketch size", "paper §4.2 ('Gaussian suffices', 'p=5')");
    let (n, m) = (192, 96);
    let stop = StopRule::default().with_max_iters(200).with_tol(TOL);
    let mut rng = Rng::seed_from(42);
    let s = randmat::logspace(1e-6, 1.0, m);
    let a = randmat::with_spectrum(&mut rng, n, m, &s);
    let mut series = SeriesWriter::create("bench_out/ablation_sketch.jsonl");

    // Reference rows.
    let exact = polar_prism(
        &a,
        &PolarOpts { d: 2, alpha: AlphaMode::Exact, stop },
        &mut rng,
    );
    let classic = polar_prism(&a, &PolarOpts::classic(2).with_stop(stop), &mut rng);

    let mut t = Table::new(&["family", "p", "iters to tol", "wall ms", "mean |α−α_exact|"]);
    t.row(&[
        "(exact fit)".into(),
        "—".into(),
        exact.log.iters_to_tol(TOL).map(|k| k.to_string()).unwrap_or("—".into()),
        format!("{:.1}", exact.log.wall_s * 1e3),
        "0".into(),
    ]);
    t.row(&[
        "(classic, no fit)".into(),
        "—".into(),
        classic.log.iters_to_tol(TOL).map(|k| k.to_string()).unwrap_or("—".into()),
        format!("{:.1}", classic.log.wall_s * 1e3),
        "—".into(),
    ]);

    for kind in [
        SketchKind::Gaussian,
        SketchKind::Rademacher,
        SketchKind::CountSketch,
        SketchKind::Srht,
    ] {
        for p in [2usize, 5, 8, 16] {
            let out = polar_prism(
                &a,
                &PolarOpts { d: 2, alpha: AlphaMode::SketchedKind { p, kind }, stop },
                &mut rng,
            );
            // α-trace deviation vs the exact run (aligned prefix).
            let dev: f64 = out
                .log
                .alphas
                .iter()
                .zip(&exact.log.alphas)
                .map(|(a, b)| (a - b).abs())
                .sum::<f64>()
                / out.log.alphas.len().min(exact.log.alphas.len()).max(1) as f64;
            let iters = out.log.iters_to_tol(TOL);
            t.row(&[
                kind.name().into(),
                p.to_string(),
                iters.map(|k| k.to_string()).unwrap_or("—".into()),
                format!("{:.1}", out.log.wall_s * 1e3),
                format!("{dev:.3}"),
            ]);
            series.point(&[
                ("family", Value::Str(kind.name().into())),
                ("p", Value::Int(p as i64)),
                ("iters", Value::Int(iters.unwrap_or(0) as i64)),
                ("wall_s", Value::Float(out.log.wall_s)),
                ("alpha_dev", Value::Float(dev)),
            ]);
        }
    }
    println!("\npolar {n}x{m}, σ ∈ [1e-6, 1], tol {TOL:.0e}:");
    t.print();
    println!("\nexpected: every family at p ≥ 5 matches the exact-fit iteration count;");
    println!("p = 2 may wobble (under-determined trace estimates); all beat classic.");
    println!("series → bench_out/ablation_sketch.jsonl");
}
