//! Figures D.1 / D.2: the per-iteration view of Figs. 3 and 4 — residual
//! ‖I − X_kᵀX_k‖_F versus iteration count (hardware-independent, so this is
//! the cleanest reproduction target on a CPU substrate).

use prism::baselines::polar_express::PolarExpress;
use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::prism::polar::{polar_prism, PolarOpts};
use prism::prism::{IterationLog, StopRule};
use prism::randmat;
use prism::rng::Rng;

const TOL: f64 = 1e-8;

fn trajectory(label: &str, log: &IterationLog) -> String {
    let pts: Vec<String> = log
        .residuals
        .iter()
        .enumerate()
        .step_by(2)
        .map(|(k, r)| format!("({k},{r:.1e})"))
        .collect();
    format!("  {label:<14} {}", pts.join(" "))
}

fn run_family(
    title: &str,
    mats: Vec<(String, prism::linalg::Mat)>,
    stop: StopRule,
    series: &mut SeriesWriter,
    rng: &mut Rng,
) {
    let pe = PolarExpress::paper_default();
    let mut t = Table::new(&["instance", "NS-5 iters", "PolarExpress iters", "PRISM-5 iters"]);
    println!("\n{title}");
    for (label, a) in mats {
        let classic = polar_prism(&a, &PolarOpts::classic(2).with_stop(stop), rng);
        let (_, pe_log) = pe.polar(&a, &stop);
        let fast = polar_prism(&a, &PolarOpts::degree5().with_stop(stop), rng);
        for (m, log) in [
            ("newton-schulz", &classic.log),
            ("polar-express", &pe_log),
            ("prism", &fast.log),
        ] {
            for (k, &r) in log.residuals.iter().enumerate() {
                series.point(&[
                    ("instance", Value::Str(label.clone())),
                    ("method", Value::Str(m.into())),
                    ("iter", Value::Int(k as i64)),
                    ("residual", Value::Float(r)),
                ]);
            }
        }
        let it = |l: &IterationLog| {
            l.iters_to_tol(TOL).map(|k| k.to_string()).unwrap_or_else(|| "—".into())
        };
        t.row(&[label.clone(), it(&classic.log), it(&pe_log), it(&fast.log)]);
        println!("{}", trajectory(&format!("{label} PRISM"), &fast.log));
    }
    t.print();
}

fn main() {
    banner(
        "Figures D.1/D.2 — polar convergence vs iterations",
        "paper Figs. D.1 (Gaussian, γ=1,4,50) and D.2 (HTMP, κ=0.1,0.5,100)",
    );
    let stop = StopRule::default().with_max_iters(300).with_tol(TOL);
    let mut series = SeriesWriter::create("bench_out/figd1_d2.jsonl");
    let mut rng = Rng::seed_from(42);

    let m = 64;
    let gaussian: Vec<(String, prism::linalg::Mat)> = [1usize, 4, 50]
        .iter()
        .map(|&g| (format!("gauss γ={g}"), randmat::gaussian(&mut rng, m * g, m)))
        .collect();
    run_family("D.1 — Gaussian, residual < 1e-8:", gaussian, stop, &mut series, &mut rng);

    let (n, mm) = (256, 128);
    let htmp: Vec<(String, prism::linalg::Mat)> = [0.1f64, 0.5, 100.0]
        .iter()
        .map(|&k| (format!("htmp κ={k}"), randmat::htmp(&mut rng, n, mm, k)))
        .collect();
    run_family("D.2 — HTMP heavy tails, residual < 1e-8:", htmp, stop, &mut series, &mut rng);

    println!("\nexpected: PRISM ≤ PolarExpress < classic NS in iterations on every instance;");
    println!("gap widens with heavier tails / worse conditioning. series → bench_out/figd1_d2.jsonl");
}
