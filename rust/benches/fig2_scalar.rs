//! Figure 2: the scalar illustration of why a better polynomial fit gives
//! faster convergence.
//!
//! Left panel (paper): f(ξ) = (1−ξ)^{-1/2} vs its Taylor approximation
//! f₁(ξ) = 1 + ξ/2 vs the alternative g₁(ξ; 1) = 1 + ξ — we print the
//! pointwise errors over [0, 1).
//!
//! Right panel: residual ξ_k = 1 − x_k² for the scalar Newton–Schulz
//! sequence from x₀ = 1e-6 using f₁ versus g₁(·;1): an exponential
//! (×2 per-iteration rate) speedup in the early phase.

use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::prism::sign::scalar_sequence;

fn main() {
    banner("Figure 2 — scalar illustration of polynomial fitting", "paper Fig. 2, §4");
    let mut series = SeriesWriter::create("bench_out/fig2.jsonl");

    // ── Left: approximation error of f₁ vs g₁(·;1) on [0, 1) ─────────────
    let f = |xi: f64| (1.0 - xi).powf(-0.5);
    let f1 = |xi: f64| 1.0 + 0.5 * xi;
    let g1 = |xi: f64| 1.0 + xi;
    let mut t = Table::new(&["xi", "f(xi)", "f1 err (Taylor)", "g1 err (PRISM alpha=1)"]);
    for i in 0..10 {
        let xi = i as f64 / 10.0;
        t.row(&[
            format!("{xi:.1}"),
            format!("{:.4}", f(xi)),
            format!("{:.4}", (f(xi) - f1(xi)).abs()),
            format!("{:.4}", (f(xi) - g1(xi)).abs()),
        ]);
        series.point(&[
            ("panel", Value::Str("approx".into())),
            ("xi", Value::Float(xi)),
            ("taylor_err", Value::Float((f(xi) - f1(xi)).abs())),
            ("g1_err", Value::Float((f(xi) - g1(xi)).abs())),
        ]);
    }
    println!("\napproximating f(ξ)=(1-ξ)^(-1/2):");
    t.print();

    // ── Right: residual trajectories from x₀ = 1e-6 ───────────────────────
    let x0 = 1e-6;
    let iters = 50;
    // `scalar_sequence` returns the residual trajectory ξ_k = 1 − x_k².
    let rc = scalar_sequence(x0, 1, None, iters);
    let rf = scalar_sequence(x0, 1, Some(1.0), iters);

    let mut t = Table::new(&["k", "classic xi_k = 1-x_k^2", "accelerated xi_k"]);
    for k in (0..iters).step_by(4) {
        t.row(&[
            k.to_string(),
            format!("{:.3e}", rc[k.min(rc.len() - 1)]),
            format!("{:.3e}", rf[k.min(rf.len() - 1)]),
        ]);
        series.point(&[
            ("panel", Value::Str("residual".into())),
            ("k", Value::Int(k as i64)),
            ("classic", Value::Float(rc[k.min(rc.len() - 1)])),
            ("accelerated", Value::Float(rf[k.min(rf.len() - 1)])),
        ]);
    }
    println!("\nscalar Newton–Schulz from x0 = {x0:.0e}:");
    t.print();

    // Iterations until residual < 0.5 (end of the "linear-like" phase).
    let until = |r: &[f64]| r.iter().position(|&x| x < 0.5).unwrap_or(r.len());
    let (kc, kf) = (until(&rc), until(&rf));
    println!("\niterations to ξ < 1/2: classic {kc}, accelerated {kf} (ratio {:.2})", kc as f64 / kf as f64);
    println!("expected: early rate 9/4 per iter (classic) vs 4 per iter (α=1) ⇒ ratio ≈ ln4/ln2.25 ≈ 1.71");
    println!("series → bench_out/fig2.jsonl");
}
