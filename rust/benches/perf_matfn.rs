//! Cross-call workspace reuse: cold-constructed vs reused `matfn::Solver`,
//! across the precision axis (`f64` vs `mixed`).
//!
//! The Shampoo/Muon pattern calls the same matrix function on same-shaped
//! matrices every optimizer step. A cold path plans a fresh `Solver` per
//! call (every n×n ping-pong buffer is reallocated); the persistent path
//! plans once and reuses the workspace, so from the second call onward the
//! hot loop performs zero heap allocations. This bench reports wall time
//! and workspace allocation counts for both, runs each size at `f64` and
//! `mixed` precision (f32 iterate under the f64 residual guard — the
//! `matfn::Precision` contract), and emits the machine-readable
//! `bench_out/BENCH_matfn.json` CI uploads as an artifact with a `dtype`
//! key on every row.
//!
//! A second section covers the rectangular-polar subsystem: the Gram route
//! (`prism5-rectpolar` on a tall p·aspect × p operand) against the same
//! solve square-padded to m × m, emitted as rows with `aspect`, `route` and
//! `speedup_vs_square` keys (the `rect` axis CI greps for).
//!
//! Run: `cargo bench --bench perf_matfn [-- --full | -- --smoke]`
//! (`--full`: adds n = 1024; `--smoke`: tiny size for the CI smoke step).

use prism::benchkit::{banner, Bench, JsonReport, Table};
use prism::configfmt::Value;
use prism::linalg::Mat;
use prism::matfn::{registry, Precision};
use prism::prism::StopRule;
use prism::randmat;
use prism::rng::Rng;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let smoke = std::env::args().any(|a| a == "--smoke");
    banner(
        "perf_matfn — persistent Solver vs cold construction",
        "matfn API: workspace reuse across same-shape calls, f64 vs mixed",
    );
    let bench = if full { Bench::default() } else { Bench::quick() };
    // A fixed, small iteration budget: the point is per-call overhead, not
    // convergence, and it keeps n = 1024 tractable.
    let stop = StopRule::default().with_max_iters(8).with_tol(1e-30);
    let sizes: &[usize] = if smoke {
        &[48]
    } else if full {
        &[64, 256, 1024]
    } else {
        &[64, 256]
    };
    let mut report = JsonReport::create("bench_out/BENCH_matfn.json", "perf_matfn");

    let mut t = Table::new(&[
        "solver",
        "dtype",
        "n",
        "cold ms",
        "reused ms",
        "speedup",
        "allocs/call cold",
        "allocs/call reused",
    ]);
    for &n in sizes {
        let mut rng = Rng::seed_from(7);
        let s = randmat::logspace(1e-4, 1.0, n / 2);
        let a = randmat::with_spectrum(&mut rng, n, n / 2, &s);

        for precision in [Precision::F64, Precision::Mixed] {
            // Cold: plan + solve every call, like the old free-function API.
            let cold = bench.run(&format!("cold_{}_{n}", precision.name()), || {
                let mut solver = registry::resolve("prism5-polar").unwrap();
                solver.set_stop(stop);
                solver.spec_mut().precision = precision;
                std::hint::black_box(solver.solve(&a, &mut rng).log.iters());
            });
            let cold_allocs = {
                let mut solver = registry::resolve("prism5-polar").unwrap();
                solver.set_stop(stop);
                solver.spec_mut().precision = precision;
                let _ = solver.solve(&a, &mut rng);
                solver.workspace_allocations()
            };

            // Reused: plan once, warm the workspace, then measure steady
            // state. (At `mixed` the f32 phase can stop earlier than the
            // fixed f64 budget — its 1e-5 target is reachable — so `ms` is
            // the real per-call cost, not a per-iteration comparison.)
            let mut solver = registry::resolve("prism5-polar").unwrap();
            solver.set_stop(stop);
            solver.spec_mut().precision = precision;
            let _ = solver.solve(&a, &mut rng);
            let warm_base = solver.workspace_allocations();
            let reused = bench.run(&format!("reused_{}_{n}", precision.name()), || {
                std::hint::black_box(solver.solve(&a, &mut rng).log.iters());
            });
            let warm_allocs = solver.workspace_allocations() - warm_base;

            t.row(&[
                "prism5-polar".into(),
                precision.name().into(),
                n.to_string(),
                format!("{:.2}", cold.median_s() * 1e3),
                format!("{:.2}", reused.median_s() * 1e3),
                format!("{:.2}x", cold.median_s() / reused.median_s()),
                cold_allocs.to_string(),
                warm_allocs.to_string(),
            ]);
            report.entry(&[
                ("solver", Value::Str("prism5-polar".into())),
                ("dtype", Value::Str(precision.name().into())),
                ("n", Value::Int(n as i64)),
                ("cold_ms", Value::Float(cold.median_s() * 1e3)),
                ("reused_ms", Value::Float(reused.median_s() * 1e3)),
                ("speedup_reused", Value::Float(cold.median_s() / reused.median_s())),
                ("allocs_cold", Value::Int(cold_allocs as i64)),
                ("allocs_reused", Value::Int(warm_allocs as i64)),
            ]);
            assert_eq!(
                warm_allocs,
                0,
                "reused {} solver must not touch the allocator",
                precision.name()
            );
        }
    }
    t.print();
    println!("\nNotes: 'allocs/call' counts workspace-pool misses (heap allocations for");
    println!("iteration buffers). The reused column must be 0 at BOTH precisions — that");
    println!("is the persistent solver contract the optimizer/service hot paths rely on.");
    println!("'mixed' rows run the f32 iterate + f64 guard path (matfn::Precision docs).");

    // --- Rectangular polar: Gram route vs the square-padded baseline -----
    // Same fixed iteration budget; the square baseline embeds the tall
    // operand into an identity-padded m×m matrix (the pre-subsystem way to
    // push a rectangular param through a square-only polar solver).
    let mut rt =
        Table::new(&["solver", "dtype", "aspect", "route", "rect ms", "square ms", "speedup"]);
    let p: usize = if smoke { 12 } else { 48 };
    let aspects: &[usize] = if smoke { &[2] } else { &[2, 4, 8] };
    for &aspect in aspects {
        let m = p * aspect;
        let mut rng = Rng::seed_from(11);
        let s = randmat::logspace(0.1, 1.0, p);
        let a = randmat::with_spectrum(&mut rng, m, p, &s);
        // Identity-padded embedding: B[:, :p] = A, B[j, j] = 1 for j ≥ p.
        let mut b = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..p {
                b[(i, j)] = a[(i, j)];
            }
        }
        for j in p..m {
            b[(j, j)] = 1.0;
        }

        let mut rect = registry::resolve("prism5-rectpolar").unwrap();
        rect.set_stop(stop);
        let _ = rect.solve(&a, &mut rng);
        let warm_base = rect.workspace_allocations();
        let gram = bench.run(&format!("rect_gram_{m}x{p}"), || {
            std::hint::black_box(rect.solve(&a, &mut rng).log.iters());
        });
        assert_eq!(
            rect.workspace_allocations() - warm_base,
            0,
            "warm rectpolar solver must not touch the allocator"
        );

        let mut square = registry::resolve("prism5-polar").unwrap();
        square.set_stop(stop);
        let _ = square.solve(&b, &mut rng);
        let sq = bench.run(&format!("rect_square_{m}"), || {
            std::hint::black_box(square.solve(&b, &mut rng).log.iters());
        });

        rt.row(&[
            "prism5-rectpolar".into(),
            "f64".into(),
            format!("{aspect}"),
            "gram".into(), // aspect ≥ 2 always resolves to the Gram route
            format!("{:.2}", gram.median_s() * 1e3),
            format!("{:.2}", sq.median_s() * 1e3),
            format!("{:.2}x", sq.median_s() / gram.median_s()),
        ]);
        report.entry(&[
            ("solver", Value::Str("prism5-rectpolar".into())),
            ("dtype", Value::Str("f64".into())),
            ("aspect", Value::Int(aspect as i64)),
            ("route", Value::Str("gram".into())),
            ("rect_ms", Value::Float(gram.median_s() * 1e3)),
            ("square_ms", Value::Float(sq.median_s() * 1e3)),
            ("speedup_vs_square", Value::Float(sq.median_s() / gram.median_s())),
        ]);
    }
    rt.print();
    println!("\nNotes: 'square ms' solves the identity-padded m×m embedding with the");
    println!("square polar solver; 'rect ms' takes the Gram route (syrk + p×p solve +");
    println!("one skinny GEMM). perf_rect has the full aspect sweep with flop counts.");

    match report.finish() {
        Some(path) => println!("report → {path}"),
        None => println!("report → (unwritable bench_out/, skipped)"),
    }
}
