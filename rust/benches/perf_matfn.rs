//! Cross-call workspace reuse: cold-constructed vs reused `matfn::Solver`.
//!
//! The Shampoo/Muon pattern calls the same matrix function on same-shaped
//! matrices every optimizer step. A cold path plans a fresh `Solver` per
//! call (every n×n ping-pong buffer is reallocated); the persistent path
//! plans once and reuses the workspace, so from the second call onward the
//! hot loop performs zero heap allocations. This bench reports wall time
//! and workspace allocation counts for both, and emits the machine-readable
//! `bench_out/BENCH_matfn.json` CI uploads as an artifact.
//!
//! Run: `cargo bench --bench perf_matfn [-- --full | -- --smoke]`
//! (`--full`: adds n = 1024; `--smoke`: tiny size for the CI smoke step).

use prism::benchkit::{banner, Bench, JsonReport, Table};
use prism::configfmt::Value;
use prism::matfn::registry;
use prism::prism::StopRule;
use prism::randmat;
use prism::rng::Rng;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let smoke = std::env::args().any(|a| a == "--smoke");
    banner(
        "perf_matfn — persistent Solver vs cold construction",
        "matfn API: workspace reuse across same-shape calls",
    );
    let bench = if full { Bench::default() } else { Bench::quick() };
    // A fixed, small iteration budget: the point is per-call overhead, not
    // convergence, and it keeps n = 1024 tractable.
    let stop = StopRule::default().with_max_iters(8).with_tol(1e-30);
    let sizes: &[usize] = if smoke {
        &[48]
    } else if full {
        &[64, 256, 1024]
    } else {
        &[64, 256]
    };
    let mut report = JsonReport::create("bench_out/BENCH_matfn.json", "perf_matfn");

    let mut t = Table::new(&[
        "solver", "n", "cold ms", "reused ms", "speedup", "allocs/call cold", "allocs/call reused",
    ]);
    for &n in sizes {
        let mut rng = Rng::seed_from(7);
        let s = randmat::logspace(1e-4, 1.0, n / 2);
        let a = randmat::with_spectrum(&mut rng, n, n / 2, &s);

        // Cold: plan + solve every call, like the old free-function API.
        let cold = bench.run(&format!("cold_{n}"), || {
            let mut solver = registry::resolve("prism5-polar").unwrap();
            solver.set_stop(stop);
            std::hint::black_box(solver.solve(&a, &mut rng).log.iters());
        });
        let cold_allocs = {
            let mut solver = registry::resolve("prism5-polar").unwrap();
            solver.set_stop(stop);
            let _ = solver.solve(&a, &mut rng);
            solver.workspace_allocations()
        };

        // Reused: plan once, warm the workspace, then measure steady state.
        let mut solver = registry::resolve("prism5-polar").unwrap();
        solver.set_stop(stop);
        let _ = solver.solve(&a, &mut rng);
        let warm_base = solver.workspace_allocations();
        let reused = bench.run(&format!("reused_{n}"), || {
            std::hint::black_box(solver.solve(&a, &mut rng).log.iters());
        });
        let warm_allocs = solver.workspace_allocations() - warm_base;

        t.row(&[
            "prism5-polar".into(),
            n.to_string(),
            format!("{:.2}", cold.median_s() * 1e3),
            format!("{:.2}", reused.median_s() * 1e3),
            format!("{:.2}x", cold.median_s() / reused.median_s()),
            cold_allocs.to_string(),
            warm_allocs.to_string(),
        ]);
        report.entry(&[
            ("solver", Value::Str("prism5-polar".into())),
            ("n", Value::Int(n as i64)),
            ("cold_ms", Value::Float(cold.median_s() * 1e3)),
            ("reused_ms", Value::Float(reused.median_s() * 1e3)),
            ("speedup_reused", Value::Float(cold.median_s() / reused.median_s())),
            ("allocs_cold", Value::Int(cold_allocs as i64)),
            ("allocs_reused", Value::Int(warm_allocs as i64)),
        ]);
        assert_eq!(warm_allocs, 0, "reused solver must not touch the allocator");
    }
    t.print();
    println!("\nNotes: 'allocs/call' counts workspace-pool misses (heap allocations for");
    println!("iteration buffers). The reused column must be 0 — that is the persistent");
    println!("solver contract the optimizer/service hot paths rely on.");
    match report.finish() {
        Some(path) => println!("report → {path}"),
        None => println!("report → (unwritable bench_out/, skipped)"),
    }
}
