//! Figure D.5: PRISM-accelerated DB Newton (product form, O(n²) exact α fit,
//! Cholesky-based inverse) versus classical DB Newton, with PRISM-based
//! Newton–Schulz for reference — square root + inverse square root.
//!
//! Instances follow the paper: a Wishart matrix with γ = 1 (worst MP
//! conditioning) and an HTMP matrix with κ = 0.1 (heavy tail). Right panel:
//! the α_k trace of PRISM-Newton.

use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::syrk_at_a;
use prism::prism::db_newton::{db_newton_prism, DbNewtonOpts};
use prism::prism::sqrt::{sqrt_error, sqrt_prism, SqrtOpts};
use prism::prism::{IterationLog, StopRule};
use prism::randmat;
use prism::rng::Rng;

const TOL: f64 = 1e-8;

fn main() {
    banner(
        "Figure D.5 — PRISM DB-Newton vs classical DB-Newton vs PRISM-NS",
        "paper Fig. D.5 and §A.2",
    );
    let stop = StopRule::default().with_max_iters(200).with_tol(TOL);
    let mut series = SeriesWriter::create("bench_out/figd5.jsonl");
    let mut rng = Rng::seed_from(42);

    let m = 64;
    let wishart = {
        let g = randmat::gaussian(&mut rng, m, m);
        syrk_at_a(&g).scaled(1.0 / m as f64)
    };
    let htmp = {
        let g = randmat::htmp(&mut rng, 2 * m, m, 0.1);
        syrk_at_a(&g)
    };
    let instances = [("wishart γ=1", wishart), ("htmp κ=0.1", htmp)];

    let mut t = Table::new(&[
        "instance",
        "DB-Newton iters",
        "PRISM-Newton iters",
        "PRISM-NS iters",
        "PRISM-Newton ms",
        "PRISM-NS ms",
        "‖I−YAY‖ (P-Newton)",
    ]);
    let mut alphas_out: Vec<(String, Vec<f64>)> = Vec::new();
    for (label, a) in instances {
        let classic = db_newton_prism(&a, &DbNewtonOpts::classic().with_stop(stop), &mut rng);
        let newton = db_newton_prism(&a, &DbNewtonOpts::prism().with_stop(stop), &mut rng);
        let ns = sqrt_prism(&a, &SqrtOpts::degree5().with_stop(stop), &mut rng);

        for (meth, log) in [
            ("db-newton", &classic.log),
            ("prism-newton", &newton.log),
            ("prism-ns", &ns.log),
        ] {
            for (k, &r) in log.residuals.iter().enumerate() {
                series.point(&[
                    ("instance", Value::Str(label.into())),
                    ("method", Value::Str(meth.into())),
                    ("iter", Value::Int(k as i64)),
                    ("residual", Value::Float(r)),
                ]);
            }
        }
        let it = |l: &IterationLog| {
            l.iters_to_tol(TOL).map(|k| k.to_string()).unwrap_or_else(|| "—".into())
        };
        let ms = |l: &IterationLog| format!("{:.1}", l.time_to_tol(TOL).unwrap_or(l.wall_s) * 1e3);
        t.row(&[
            label.to_string(),
            it(&classic.log),
            it(&newton.log),
            it(&ns.log),
            ms(&newton.log),
            ms(&ns.log),
            format!("{:.1e}", sqrt_error(&a, &newton.inv_sqrt)),
        ]);
        alphas_out.push((label.to_string(), newton.log.alphas.clone()));
    }
    println!();
    t.print();

    println!("\nright panel — PRISM-Newton α_k (starts away from 1/2, relaxes to 1/2):");
    for (label, alphas) in &alphas_out {
        let pts: Vec<String> = alphas.iter().map(|a| format!("{a:.3}")).collect();
        println!("  {label:<12} [{}]", pts.join(", "));
    }
    println!("\nexpected: PRISM-Newton converges in fewer iterations than both classical");
    println!("DB-Newton and PRISM-NS (paper: 'can outperform PRISM-based Newton-Schulz by");
    println!("a good margin'), at the price of one inverse per iteration.");
    println!("series → bench_out/figd5.jsonl");
}
