//! Ablation: the Muon warm-start trick (paper §C).
//!
//! The paper observes that α_k sits at the interval's upper bound for the
//! first few iterations (Figs. 3/4 right panels) and exploits it: pin
//! α = u for the first 3 iterations — skipping the fit entirely — then fit.
//! This bench quantifies what that buys (fit overhead saved) and costs
//! (iterations, if the pinned α was wrong for the instance) across spectra,
//! sweeping warm ∈ {0, 1, 3, 5, all}.

use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::prism::polar::{orthogonality_error, polar_prism, PolarOpts};
use prism::prism::{AlphaMode, StopRule};
use prism::randmat;
use prism::rng::Rng;

fn run_with_warm(
    a: &prism::linalg::Mat,
    warm: usize,
    total: usize,
    rng: &mut Rng,
) -> (f64, f64) {
    // Phase 1: α pinned at the upper bound for `warm` iterations.
    let (_, hi) = prism::coeffs::alpha_interval(2);
    let sw = prism::util::Stopwatch::start();
    let stop1 = StopRule::default().with_max_iters(warm.min(total)).with_tol(1e-12);
    let opts1 = PolarOpts { d: 2, alpha: AlphaMode::Fixed(hi), stop: stop1 };
    let mid = if warm > 0 { polar_prism(a, &opts1, rng).q } else { a.clone() };
    // Phase 2: sketched fit for the remainder.
    let stop2 = StopRule::default().with_max_iters(total - warm.min(total)).with_tol(1e-8);
    let opts2 = PolarOpts { d: 2, alpha: AlphaMode::Sketched { p: 8 }, stop: stop2 };
    let out = polar_prism(&mid, &opts2, rng);
    (orthogonality_error(&out.q), sw.elapsed_s())
}

fn main() {
    banner("ablation — Muon warm-start (α pinned high, then fitted)", "paper §C");
    let mut rng = Rng::seed_from(42);
    let mut series = SeriesWriter::create("bench_out/ablation_warmstart.jsonl");
    let total = 8; // a Muon-style fixed budget

    let instances: Vec<(String, prism::linalg::Mat)> = vec![
        ("gaussian".into(), randmat::gaussian(&mut rng, 128, 64)),
        ("htmp κ=0.1".into(), randmat::htmp(&mut rng, 128, 64, 0.1)),
        (
            "logspace 1e-6".into(),
            randmat::with_spectrum(&mut rng, 128, 64, &randmat::logspace(1e-6, 1.0, 64)),
        ),
        (
            "narrow [0.5,1]".into(),
            randmat::with_spectrum(&mut rng, 128, 64, &randmat::logspace(0.5, 1.0, 64)),
        ),
    ];

    let mut t = Table::new(&["instance", "warm", "‖I−QᵀQ‖ after 8 iters", "wall ms"]);
    for (label, a) in &instances {
        for warm in [0usize, 1, 3, 5, 8] {
            let (err, wall) = run_with_warm(a, warm, total, &mut rng);
            t.row(&[
                label.clone(),
                if warm == 8 { "all".into() } else { warm.to_string() },
                format!("{err:.2e}"),
                format!("{:.1}", wall * 1e3),
            ]);
            series.point(&[
                ("instance", Value::Str(label.clone())),
                ("warm", Value::Int(warm as i64)),
                ("err", Value::Float(err)),
                ("wall_s", Value::Float(wall)),
            ]);
        }
    }
    println!("\nfixed budget of {total} iterations (Muon regime):");
    t.print();
    println!("\nexpected: warm=3 ≈ warm=0 in error (α would have been at the bound anyway)");
    println!("but cheaper; warm=all loses on narrow spectra where pinning α=1.45 overshoots.");
    println!("series → bench_out/ablation_warmstart.jsonl");
}
