//! Figure 6: Muon-vs-AdamW language-model training with different polar
//! backends — the end-to-end three-layer experiment.
//!
//! Loads the AOT-compiled JAX/Pallas `train_step` artifact through PJRT
//! (`make artifacts` must have run) and trains the transformer LM on a
//! synthetic Markov/Zipf corpus with four optimizers: AdamW, and Muon with
//! PolarExpress / PRISM-3 / PRISM-5 polar factors, using the paper's §C
//! iteration budgets (5/5/3 with warm-start α pinned high).
//!
//! Paper final val losses: PolarExpress 5.4523, PRISM-5 5.0251,
//! PRISM-3 4.9886, AdamW 6.8689 — the *ordering* (every Muon ≪ AdamW,
//! PRISM ≤ PolarExpress) is the reproduction target.

use prism::benchkit::{banner, SeriesWriter, Table};
use prism::config::Backend;
use prism::configfmt::Value;
use prism::coordinator::train::TrainDriver;
use prism::optim::adamw::AdamW;
use prism::optim::muon::Muon;
use prism::optim::Optimizer;
use prism::rng::Rng;
use prism::runtime::Runtime;
use prism::workload::MarkovCorpus;

fn main() {
    banner("Figure 6 — Muon polar backends vs AdamW on the AOT LM", "paper Fig. 6, §C");
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIPPED: artifacts not available ({e}); run `make artifacts` first.");
            return;
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let steps = std::env::var("PRISM_FIG6_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60usize);
    let seed = 42u64;
    let mut series = SeriesWriter::create("bench_out/fig6.jsonl");

    let probe = TrainDriver::new(&rt, seed as f32).expect("load train driver");
    let (vocab, batch, seq) = (probe.vocab, probe.batch, probe.seq_len);
    drop(probe);
    let mut crng = Rng::seed_from(seed);
    let corpus = MarkovCorpus::generate(&mut crng, vocab, 200_000);
    println!(
        "LM: vocab {vocab}, batch {batch} x seq {seq}; corpus {} tokens ({:.3} nats unigram); {steps} steps/optimizer\n",
        corpus.tokens.len(),
        corpus.unigram_entropy()
    );

    let opts: Vec<Box<dyn Optimizer>> = vec![
        Box::new(AdamW::paper_default()),
        Box::new(Muon::paper_default(Backend::PolarExpress, seed)),
        Box::new(Muon::paper_default(Backend::Prism3, seed)),
        Box::new(Muon::paper_default(Backend::Prism5, seed)),
    ];

    let mut t = Table::new(&["optimizer", "final train loss", "val loss", "ms/step"]);
    for mut opt in opts {
        let mut driver = TrainDriver::new(&rt, seed as f32).expect("driver");
        let mut rng = Rng::seed_from(seed ^ 0xF16);
        let name = opt.name();
        for step in 0..steps {
            let (xs, ys) = corpus.sample_batch(&mut rng, driver.batch, driver.seq_len);
            let loss = driver.step(&xs, &ys, opt.as_mut()).expect("train step");
            series.point(&[
                ("optimizer", Value::Str(name.clone())),
                ("step", Value::Int(step as i64)),
                ("train_loss", Value::Float(loss)),
            ]);
        }
        let mut vrng = Rng::seed_from(seed ^ 0x7E57);
        let mut val = 0.0;
        for _ in 0..6 {
            let (xs, ys) = corpus.sample_batch(&mut vrng, driver.batch, driver.seq_len);
            val += driver.eval(&xs, &ys).expect("eval");
        }
        val /= 6.0;
        let ms =
            driver.step_times_s.iter().sum::<f64>() / driver.step_times_s.len() as f64 * 1e3;
        series.point(&[
            ("optimizer", Value::Str(name.clone())),
            ("val_loss", Value::Float(val)),
            ("ms_per_step", Value::Float(ms)),
        ]);
        t.row(&[
            name,
            format!("{:.4}", driver.losses.last().copied().unwrap_or(f64::NAN)),
            format!("{val:.4}"),
            format!("{ms:.0}"),
        ]);
    }
    println!();
    t.print();
    println!("\npaper (GPT-2, 200M FineWeb tokens): PE 5.4523, PRISM-5 5.0251,");
    println!("PRISM-3 4.9886, AdamW 6.8689 — expect the same ordering here:");
    println!("all Muon variants well below AdamW; PRISM at or below PolarExpress,");
    println!("with PRISM-5 the cheapest per step (3 iterations vs 5).");
    println!("series → bench_out/fig6.jsonl");
}
