//! Figure 3: convergence of degree-5 polynomial methods for orthogonalizing
//! a Gaussian random matrix A ∈ R^{n×m} with aspect ratios γ = n/m ∈
//! {1, 4, 50}; right panel — the α_k trace per aspect ratio.
//!
//! The Marchenko–Pastur edge moves with γ (σ_min/σ_max = (√γ−1)/(√γ+1) for
//! the normalized Gram spectrum), so each γ exercises a different effective
//! condition number; PRISM adapts its α_k trace to each without being told.

use prism::baselines::polar_express::PolarExpress;
use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::prism::polar::{polar_prism, PolarOpts};
use prism::prism::{IterationLog, StopRule};
use prism::randmat;
use prism::rng::Rng;

const TOL: f64 = 1e-8;

fn row_series(series: &mut SeriesWriter, gamma: f64, method: &str, log: &IterationLog) {
    for (k, &r) in log.residuals.iter().enumerate() {
        series.point(&[
            ("gamma", Value::Float(gamma)),
            ("method", Value::Str(method.into())),
            ("iter", Value::Int(k as i64)),
            (
                "time_s",
                Value::Float(if k == 0 { 0.0 } else { log.times_s[k - 1] }),
            ),
            ("residual", Value::Float(r)),
        ]);
    }
}

fn main() {
    banner(
        "Figure 3 — polar convergence on Gaussian matrices, γ = n/m ∈ {1,4,50}",
        "paper Fig. 3 (wall-clock) / Fig. D.1 (iterations)",
    );
    let m = 64;
    let stop = StopRule::default().with_max_iters(200).with_tol(TOL);
    let pe = PolarExpress::paper_default();
    let mut series = SeriesWriter::create("bench_out/fig3.jsonl");
    let mut rng = Rng::seed_from(42);

    let mut t = Table::new(&[
        "gamma",
        "NS-5 iters",
        "NS-5 ms",
        "PolarExpress iters",
        "PE ms",
        "PRISM-5 iters",
        "PRISM ms",
    ]);
    let mut alpha_rows: Vec<(f64, Vec<f64>)> = Vec::new();
    for gamma in [1usize, 4, 50] {
        let n = m * gamma;
        let a = randmat::gaussian(&mut rng, n, m);

        let classic = polar_prism(&a, &PolarOpts::classic(2).with_stop(stop), &mut rng);
        let (_, pe_log) = pe.polar(&a, &stop);
        let fast = polar_prism(&a, &PolarOpts::degree5().with_stop(stop), &mut rng);

        row_series(&mut series, gamma as f64, "newton-schulz", &classic.log);
        row_series(&mut series, gamma as f64, "polar-express", &pe_log);
        row_series(&mut series, gamma as f64, "prism", &fast.log);

        let it = |l: &IterationLog| {
            l.iters_to_tol(TOL).map(|k| k.to_string()).unwrap_or_else(|| "—".into())
        };
        let ms = |l: &IterationLog| format!("{:.1}", l.time_to_tol(TOL).unwrap_or(l.wall_s) * 1e3);
        t.row(&[
            format!("{gamma}"),
            it(&classic.log),
            ms(&classic.log),
            it(&pe_log),
            ms(&pe_log),
            it(&fast.log),
            ms(&fast.log),
        ]);
        alpha_rows.push((gamma as f64, fast.log.alphas.clone()));
    }
    println!("\nGaussian A (m = {m}), ‖I − XᵀX‖_F < {TOL:.0e}:");
    t.print();

    println!("\nright panel — PRISM α_k per aspect ratio:");
    for (gamma, alphas) in &alpha_rows {
        let pts: Vec<String> = alphas.iter().map(|a| format!("{a:.3}")).collect();
        println!("  γ={gamma:<4} [{}]", pts.join(", "));
        for (k, &a) in alphas.iter().enumerate() {
            series.point(&[
                ("gamma", Value::Float(*gamma)),
                ("method", Value::Str("prism-alpha".into())),
                ("iter", Value::Int(k as i64)),
                ("alpha", Value::Float(a)),
            ]);
        }
    }
    println!("\nexpected shape: PRISM fastest for all γ; larger γ ⇒ better-conditioned");
    println!("Gram spectrum ⇒ fewer iterations; α_k starts at the upper bound and decays");
    println!("to the Taylor coefficient 0.375 as the spectrum contracts to 1.");
    println!("series → bench_out/fig3.jsonl");
}
