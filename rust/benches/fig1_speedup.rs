//! Figure 1: speedup in wall-clock time over classical Newton–Schulz for
//! polar decomposition (left) and square root (right), sweeping
//! σ_min ∈ [1e-12, 1/2] with σ_max = 1.
//!
//! The paper's claim: PolarExpress (optimized for σ_min = 1e-3) *degrades* —
//! even below 1x — when the true σ_min is far from its design point, while
//! PRISM needs no σ_min and keeps a stable speedup across the entire range.
//!
//! We report time-to-tolerance ratios (classic / method), the paper's
//! y-axis, on a CPU substrate; shapes (who wins, where the crossover sits)
//! are the reproduction target, not absolute GPU milliseconds.

use prism::baselines::polar_express::PolarExpress;
use prism::benchkit::{banner, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::syrk_at_a;
use prism::prism::polar::{polar_prism, PolarOpts};
use prism::prism::sqrt::{sqrt_prism, SqrtOpts};
use prism::prism::StopRule;
use prism::randmat;
use prism::rng::Rng;

const TOL: f64 = 1e-6;

fn time_to_tol_or_wall(log: &prism::prism::IterationLog) -> f64 {
    log.time_to_tol(TOL).unwrap_or(log.wall_s)
}

fn main() {
    banner("Figure 1 — speedup over classical Newton–Schulz vs σ_min", "paper Fig. 1");
    let n = 256;
    let m = 128;
    let stop = StopRule::default().with_max_iters(600).with_tol(TOL);
    let pe = PolarExpress::paper_default();
    let mut rng = Rng::seed_from(42);
    let mut series = SeriesWriter::create("bench_out/fig1.jsonl");

    // ── Left panel: polar decomposition ──────────────────────────────────
    let mut t = Table::new(&[
        "sigma_min",
        "classic (ms)",
        "PolarExpress speedup",
        "PRISM-5 speedup",
    ]);
    for e in [-12i32, -10, -8, -6, -4, -3, -2, -1] {
        let smin = if e == -1 { 0.5 } else { 10f64.powi(e) };
        let s = randmat::logspace(smin, 1.0, m);
        let a = randmat::with_spectrum(&mut rng, n, m, &s);

        let classic = polar_prism(&a, &PolarOpts::classic(2).with_stop(stop), &mut rng);
        let (_, pe_log) = pe.polar(&a, &stop);
        let fast = polar_prism(&a, &PolarOpts::degree5().with_stop(stop), &mut rng);

        let tc = time_to_tol_or_wall(&classic.log);
        let tp = time_to_tol_or_wall(&pe_log);
        let tf = time_to_tol_or_wall(&fast.log);
        t.row(&[
            format!("{smin:.0e}"),
            format!("{:.1}", tc * 1e3),
            format!("{:.2}x", tc / tp),
            format!("{:.2}x", tc / tf),
        ]);
        series.point(&[
            ("panel", Value::Str("polar".into())),
            ("sigma_min", Value::Float(smin)),
            ("classic_s", Value::Float(tc)),
            ("polarexpress_speedup", Value::Float(tc / tp)),
            ("prism_speedup", Value::Float(tc / tf)),
        ]);
    }
    println!("\npolar decomposition ({n}x{m}, tol {TOL:.0e}):");
    t.print();

    // ── Right panel: square root (A = GᵀG ⇒ σ_min is squared) ────────────
    let mut t = Table::new(&[
        "sigma_min(G)",
        "classic (ms)",
        "PolarExpress speedup",
        "PRISM-5 speedup",
    ]);
    for e in [-6i32, -5, -4, -3, -2, -1] {
        let smin = 10f64.powi(e);
        let s = randmat::logspace(smin, 1.0, m);
        let g = randmat::with_spectrum(&mut rng, n, m, &s);
        let a = syrk_at_a(&g);

        let classic = sqrt_prism(&a, &SqrtOpts::classic(2).with_stop(stop), &mut rng);
        let (_, _, pe_log) = pe.sqrt_coupled(&a, &stop);
        let fast = sqrt_prism(&a, &SqrtOpts::degree5().with_stop(stop), &mut rng);

        let tc = time_to_tol_or_wall(&classic.log);
        let tp = time_to_tol_or_wall(&pe_log);
        let tf = time_to_tol_or_wall(&fast.log);
        t.row(&[
            format!("{smin:.0e}"),
            format!("{:.1}", tc * 1e3),
            format!("{:.2}x", tc / tp),
            format!("{:.2}x", tc / tf),
        ]);
        series.point(&[
            ("panel", Value::Str("sqrt".into())),
            ("sigma_min", Value::Float(smin)),
            ("classic_s", Value::Float(tc)),
            ("polarexpress_speedup", Value::Float(tc / tp)),
            ("prism_speedup", Value::Float(tc / tf)),
        ]);
    }
    println!("\nsquare root (A = GᵀG, {m}x{m}, tol {TOL:.0e}):");
    t.print();
    println!("\nexpected shape: PRISM speedup stable ≥1x across all σ_min;");
    println!("PolarExpress peaks near its design point (1e-3) and degrades away from it.");
    println!("series → bench_out/fig1.jsonl");
}
