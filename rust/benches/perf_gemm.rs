//! L3 substrate roofline: packed cache-blocked GEMM / SYRK throughput with
//! a **per-microkernel ablation** (scalar vs SIMD vs the seed broadcast
//! kernel) and a **skinny-path ablation** (sketch-shaped p×n · n×n products
//! routed vs forced through the square-blocked path).
//!
//! Everything PRISM does is GEMM-dominated, so the linalg substrate's
//! GFLOP/s sets the scale of every other benchmark. This bench (a) reports
//! single-thread GFLOP/s at n ∈ {256, 512, 1024} for every microkernel the
//! host can run (forced via `GemmEngine::with_kernel`; target: the SIMD
//! kernel ≥ 2× the scalar packed kernel at n = 1024), (b) reports the
//! skinny thin-A path against the square-blocked path on p × n · n × n
//! with p ∈ {8, 32} (p = 8 routes skinny and must win; p = 32 routes
//! blocked and anchors the comparison), (c) verifies the parallel engine's
//! scaling with bit-identical output asserted per kernel, (d) runs the
//! **dtype axis** — the f32 instantiation of the packed matmul per kernel
//! plus the f32 SYRK (target: f32 SIMD ≥ 1.5× f64 SIMD GFLOP/s at the top
//! size — twice the lanes per register), and (e) emits the machine-readable
//! `bench_out/BENCH_gemm.json` CI uploads as an artifact, including the
//! auto-selected kernel name and a `dtype` key on every op row.
//!
//! Run: `cargo bench --bench perf_gemm [-- --smoke]` (`--smoke`: tiny sizes
//! for the CI smoke step).

use prism::benchkit::{banner, Bench, JsonReport, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::{gemm_broadcast, matmul_naive, matmul_naive32, GemmEngine, MicroKernel};
use prism::linalg::{Mat, Mat32};
use prism::randmat;
use prism::rng::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    banner("perf — GEMM/SYRK substrate throughput", "EXPERIMENTS.md §Perf (L3)");
    let bench = if smoke {
        Bench::quick()
    } else {
        Bench { min_time_s: 0.3, max_samples: 15, warmup: 1 }
    };
    let sizes: &[usize] = if smoke { &[32, 64] } else { &[256, 512, 1024] };
    let mut rng = Rng::seed_from(42);
    let mut report = JsonReport::create("bench_out/BENCH_gemm.json", "perf_gemm");

    let kernels = MicroKernel::available();
    let selected = GemmEngine::sequential().kernel();
    println!(
        "kernels available: [{}]; auto-selected: {}\n",
        kernels.iter().map(|k| k.name()).collect::<Vec<_>>().join(", "),
        selected.name()
    );
    report.entry(&[
        ("op", Value::Str("meta".into())),
        ("selected_kernel", Value::Str(selected.name().into())),
        (
            "kernels_available",
            Value::Str(kernels.iter().map(|k| k.name()).collect::<Vec<_>>().join(",")),
        ),
    ]);

    let par = GemmEngine::with_threads(4); // auto kernel — the production path

    // The SIMD side of the scalar-vs-SIMD summary: the first non-scalar
    // kernel the host can run. Deliberately NOT `selected` — under the CI
    // matrix's PALLAS_GEMM_KERNEL=scalar override the selected kernel is
    // scalar, but the SIMD rows are still benchmarked and must still feed
    // the ≥ 2x acceptance check.
    let simd_kernel = kernels.iter().copied().find(|k| *k != MicroKernel::Scalar);

    let mut t = Table::new(&[
        "op",
        "kernel",
        "dtype",
        "n",
        "ms",
        "GFLOP/s",
        "vs broadcast",
        "4T ms",
        "4T speedup",
    ]);
    // GFLOP/s per (kernel, n) for the ablation summary lines below.
    let mut scalar_gflops_last = 0.0f64;
    let mut simd_gflops_last = 0.0f64;
    // The dtype axis: f32 GFLOP/s at the last size for the mixed-precision
    // headline (f32 SIMD vs f64 SIMD — twice the lanes per register).
    let mut simd_gflops32_last = 0.0f64;
    let mut scalar_gflops32_last = 0.0f64;
    let mut speedup_512_4t = 0.0;
    for &n in sizes {
        let a = randmat::gaussian(&mut rng, n, n);
        let b = randmat::gaussian(&mut rng, n, n);
        let a32 = Mat32::from_f64(&a);
        let b32 = Mat32::from_f64(&b);
        let flops = 2.0 * (n as f64).powi(3);

        // The seed broadcast kernel on the same operands (same zero-fill as
        // matmul_into performs, so the comparison is like for like).
        let mut cb = Mat::zeros(n, n);
        let s_bcast = bench.run(&format!("matmul_broadcast_{n}"), || {
            cb.fill_with(0.0);
            gemm_broadcast(a.as_slice(), b.as_slice(), cb.as_mut_slice(), n, n, n);
            std::hint::black_box(&cb);
        });
        report.entry(&[
            ("op", Value::Str("matmul_broadcast".into())),
            ("dtype", Value::Str("f64".into())),
            ("n", Value::Int(n as i64)),
            ("ms", Value::Float(s_bcast.median_s() * 1e3)),
            ("gflops", Value::Float(flops / s_bcast.median_s() / 1e9)),
        ]);

        for &kern in &kernels {
            let seq = GemmEngine::sequential().with_kernel(kern);
            // Correctness guards before timing: every kernel must match the
            // naive reference; the parallel engine must be bit-identical to
            // the sequential one at the same kernel.
            if n <= 256 {
                let err = seq.matmul(&a, &b).sub(&matmul_naive(&a, &b)).max_abs();
                assert!(err < 1e-9, "{} kernel diverges at n={n}: {err}", kern.name());
            }
            let par_k = GemmEngine::with_threads(4).with_kernel(kern);
            assert_eq!(
                seq.matmul(&a, &b).as_slice(),
                par_k.matmul(&a, &b).as_slice(),
                "{} parallel output differs at n={n}",
                kern.name()
            );

            // Sequential packed engine (allocation-free loop, reused buffer).
            let mut c = Mat::zeros(n, n);
            let s_packed = bench.run(&format!("matmul_{}_{n}", kern.name()), || {
                seq.matmul_into(&mut c, &a, &b);
                std::hint::black_box(&c);
            });
            let gflops = flops / s_packed.median_s() / 1e9;
            if n == *sizes.last().unwrap() {
                if kern == MicroKernel::Scalar {
                    scalar_gflops_last = gflops;
                } else if Some(kern) == simd_kernel {
                    simd_gflops_last = gflops;
                }
            }
            let vs_broadcast = s_bcast.median_s() / s_packed.median_s();

            // Row-panel parallel engine, 4 threads — for the selected
            // (production) kernel only.
            let (ms_4t, speedup_4t) = if kern == selected {
                let mut c4 = Mat::zeros(n, n);
                let s_par = bench.run(&format!("matmul_{n}_4t"), || {
                    par.matmul_into(&mut c4, &a, &b);
                    std::hint::black_box(&c4);
                });
                let sp = s_packed.median_s() / s_par.median_s();
                if n == 512 {
                    speedup_512_4t = sp;
                }
                report.entry(&[
                    ("op", Value::Str("matmul_4t".into())),
                    ("kernel", Value::Str(kern.name().into())),
                    ("n", Value::Int(n as i64)),
                    ("ms", Value::Float(s_par.median_s() * 1e3)),
                    ("speedup_4t", Value::Float(sp)),
                ]);
                (format!("{:.2}", s_par.median_s() * 1e3), format!("{sp:.2}x"))
            } else {
                ("-".into(), "-".into())
            };

            t.row(&[
                "C = A·B".into(),
                kern.name().into(),
                "f64".into(),
                n.to_string(),
                format!("{:.2}", s_packed.median_s() * 1e3),
                format!("{gflops:.2}"),
                format!("{vs_broadcast:.2}x"),
                ms_4t,
                speedup_4t,
            ]);
            report.entry(&[
                ("op", Value::Str("matmul".into())),
                ("kernel", Value::Str(kern.name().into())),
                ("dtype", Value::Str("f64".into())),
                ("selected", Value::Bool(kern == selected)),
                ("n", Value::Int(n as i64)),
                ("ms", Value::Float(s_packed.median_s() * 1e3)),
                ("gflops", Value::Float(gflops)),
                ("speedup_vs_broadcast", Value::Float(vs_broadcast)),
            ]);

            // ── dtype axis: the f32 instantiation of the same packed route
            // (identical blocking, twice the SIMD lanes per register — the
            // mixed-precision hot loop's GEMM). Guarded against the f32
            // naive reference and the parallel engine before timing.
            if n <= 256 {
                let err32 = seq
                    .matmul_f32(&a32, &b32)
                    .to_f64()
                    .sub(&matmul_naive32(&a32, &b32).to_f64())
                    .max_abs();
                assert!(err32 < 1e-3, "{} f32 kernel diverges at n={n}: {err32}", kern.name());
            }
            assert_eq!(
                seq.matmul_f32(&a32, &b32).as_slice(),
                par_k.matmul_f32(&a32, &b32).as_slice(),
                "{} f32 parallel output differs at n={n}",
                kern.name()
            );
            let mut c32 = Mat32::zeros(n, n);
            let s_packed32 = bench.run(&format!("matmul_f32_{}_{n}", kern.name()), || {
                seq.matmul_f32_into(&mut c32, &a32, &b32);
                std::hint::black_box(&c32);
            });
            let gflops32 = flops / s_packed32.median_s() / 1e9;
            if n == *sizes.last().unwrap() {
                if kern == MicroKernel::Scalar {
                    scalar_gflops32_last = gflops32;
                } else if Some(kern) == simd_kernel {
                    simd_gflops32_last = gflops32;
                }
            }
            t.row(&[
                "C = A·B".into(),
                kern.name().into(),
                "f32".into(),
                n.to_string(),
                format!("{:.2}", s_packed32.median_s() * 1e3),
                format!("{gflops32:.2}"),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            report.entry(&[
                ("op", Value::Str("matmul".into())),
                ("kernel", Value::Str(kern.name().into())),
                ("dtype", Value::Str("f32".into())),
                ("selected", Value::Bool(kern == selected)),
                ("n", Value::Int(n as i64)),
                ("ms", Value::Float(s_packed32.median_s() * 1e3)),
                ("gflops", Value::Float(gflops32)),
                ("speedup_vs_f64", Value::Float(gflops32 / gflops)),
            ]);
        }

        // SYRK on the selected kernel: half the flops of a general GEMM
        // (upper triangle + mirror), with 4T scaling.
        let seq = GemmEngine::sequential();
        let mut cs = Mat::zeros(n, n);
        let s_syrk = bench.run(&format!("syrk_{n}"), || {
            seq.syrk_at_a_into(&mut cs, &a);
            std::hint::black_box(&cs);
        });
        let mut cs4 = Mat::zeros(n, n);
        let s_syrk_par = bench.run(&format!("syrk_{n}_4t"), || {
            par.syrk_at_a_into(&mut cs4, &a);
            std::hint::black_box(&cs4);
        });
        t.row(&[
            "C = Aᵀ·A".into(),
            selected.name().into(),
            "f64".into(),
            n.to_string(),
            format!("{:.2}", s_syrk.median_s() * 1e3),
            format!("{:.2}", flops / s_syrk.median_s() / 1e9),
            "-".into(),
            format!("{:.2}", s_syrk_par.median_s() * 1e3),
            format!("{:.2}x", s_syrk.median_s() / s_syrk_par.median_s()),
        ]);
        report.entry(&[
            ("op", Value::Str("syrk".into())),
            ("kernel", Value::Str(selected.name().into())),
            ("dtype", Value::Str("f64".into())),
            ("n", Value::Int(n as i64)),
            ("ms", Value::Float(s_syrk.median_s() * 1e3)),
            ("gflops", Value::Float(flops / s_syrk.median_s() / 1e9)),
            ("ms_4t", Value::Float(s_syrk_par.median_s() * 1e3)),
            ("speedup_4t", Value::Float(s_syrk.median_s() / s_syrk_par.median_s())),
        ]);

        // f32 SYRK on the selected kernel — the residual R = I − XᵀX of the
        // mixed polar loop runs through this exact entry point.
        let mut cs32 = Mat32::zeros(n, n);
        let s_syrk32 = bench.run(&format!("syrk_f32_{n}"), || {
            seq.syrk_at_a_f32_into(&mut cs32, &a32);
            std::hint::black_box(&cs32);
        });
        t.row(&[
            "C = Aᵀ·A".into(),
            selected.name().into(),
            "f32".into(),
            n.to_string(),
            format!("{:.2}", s_syrk32.median_s() * 1e3),
            format!("{:.2}", flops / s_syrk32.median_s() / 1e9),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
        report.entry(&[
            ("op", Value::Str("syrk".into())),
            ("kernel", Value::Str(selected.name().into())),
            ("dtype", Value::Str("f32".into())),
            ("n", Value::Int(n as i64)),
            ("ms", Value::Float(s_syrk32.median_s() * 1e3)),
            ("gflops", Value::Float(flops / s_syrk32.median_s() / 1e9)),
            ("speedup_vs_f64", Value::Float(s_syrk.median_s() / s_syrk32.median_s())),
        ]);
    }
    t.print();
    println!("\n(GFLOP/s on the full 2n³ count; syrk computes the upper triangle only, so");
    println!("its effective rate appears ~2x the work it does. 'vs broadcast' is each");
    println!("single-thread packed kernel against the seed broadcast kernel on identical");
    println!("operands; 4T columns are asserted bit-identical to sequential per kernel.)");

    // ── Skinny ablation: sketch-shaped p×n · n×n, routed vs blocked ──────
    let skinny_ps: &[usize] = &[8, 32];
    let skinny_ns: &[usize] = if smoke { &[64] } else { &[256, 1024] };
    let mut ts = Table::new(&["op", "p", "n", "routed ms", "GFLOP/s", "blocked ms", "speedup"]);
    let mut skinny_speedup_p8 = 0.0f64;
    let eng = GemmEngine::sequential();
    for &p in skinny_ps {
        for &n in skinny_ns {
            let s = randmat::gaussian(&mut rng, p, n);
            let r = randmat::gaussian(&mut rng, n, n);
            let flops = 2.0 * (p * n * n) as f64;
            // Guards: BOTH timed paths must match the naive reference (fp
            // tolerance — routed and blocked reduce in different
            // groupings), so the speedup is never computed against a
            // broken baseline.
            let want = matmul_naive(&s, &r);
            let err = eng.matmul(&s, &r).sub(&want).max_abs();
            assert!(err < 1e-9, "skinny p={p} n={n} routed path diverges: {err}");
            let mut blocked_check = Mat::zeros(0, 0);
            eng.matmul_blocked_into(&mut blocked_check, &s, &r);
            let err_b = blocked_check.sub(&want).max_abs();
            assert!(err_b < 1e-9, "skinny p={p} n={n} blocked baseline diverges: {err_b}");

            let mut c = Mat::zeros(p, n);
            let s_routed = bench.run(&format!("skinny_{p}x{n}"), || {
                eng.matmul_into(&mut c, &s, &r);
                std::hint::black_box(&c);
            });
            let mut cb = Mat::zeros(p, n);
            let s_blocked = bench.run(&format!("skinny_blocked_{p}x{n}"), || {
                eng.matmul_blocked_into(&mut cb, &s, &r);
                std::hint::black_box(&cb);
            });
            let speedup = s_blocked.median_s() / s_routed.median_s();
            if p == 8 && n == *skinny_ns.last().unwrap() {
                skinny_speedup_p8 = speedup;
            }
            ts.row(&[
                "S·R (sketch)".into(),
                p.to_string(),
                n.to_string(),
                format!("{:.3}", s_routed.median_s() * 1e3),
                format!("{:.2}", flops / s_routed.median_s() / 1e9),
                format!("{:.3}", s_blocked.median_s() * 1e3),
                format!("{speedup:.2}x"),
            ]);
            report.entry(&[
                ("op", Value::Str("skinny".into())),
                ("dtype", Value::Str("f64".into())),
                ("p", Value::Int(p as i64)),
                ("n", Value::Int(n as i64)),
                ("routed_ms", Value::Float(s_routed.median_s() * 1e3)),
                ("routed_gflops", Value::Float(flops / s_routed.median_s() / 1e9)),
                ("blocked_ms", Value::Float(s_blocked.median_s() * 1e3)),
                ("speedup_vs_blocked", Value::Float(speedup)),
            ]);
        }
    }
    println!();
    ts.print();
    println!("\n(p = 8 routes the thin-A skinny path — S packed once, R streamed with no");
    println!("copy; p = 32 routes the square-blocked path and anchors the comparison.");
    println!("'blocked ms' forces p = 8 through the square-blocked path via");
    println!("matmul_blocked_into, which packs all of R per product.)");

    if !smoke {
        println!("\nn=512 matmul 4-thread speedup: {speedup_512_4t:.2}x (target ≥ 2x)");
        match simd_kernel {
            Some(sk) if scalar_gflops_last > 0.0 => {
                let ratio = simd_gflops_last / scalar_gflops_last;
                println!(
                    "n={} {} vs scalar: {ratio:.2}x ({simd_gflops_last:.2} vs {scalar_gflops_last:.2} GFLOP/s; target ≥ 2x)",
                    sizes.last().unwrap(),
                    sk.name()
                );
            }
            _ => println!("(no SIMD kernel on this host — scalar only; SIMD ablation skipped)"),
        }
        // The dtype headline: f32 should approach 2x the f64 rate on the
        // SIMD kernels (twice the lanes per register; packing overhead and
        // memory traffic keep it below the ideal).
        match simd_kernel {
            Some(sk) if simd_gflops_last > 0.0 => {
                let ratio32 = simd_gflops32_last / simd_gflops_last;
                println!(
                    "n={} {} f32 vs f64: {ratio32:.2}x ({simd_gflops32_last:.2} vs {simd_gflops_last:.2} GFLOP/s; target ≥ 1.5x)",
                    sizes.last().unwrap(),
                    sk.name()
                );
            }
            _ if scalar_gflops_last > 0.0 => {
                let ratio32 = scalar_gflops32_last / scalar_gflops_last;
                println!(
                    "n={} scalar f32 vs f64: {ratio32:.2}x (no SIMD kernel — no ≥ 1.5x target on scalar)",
                    sizes.last().unwrap()
                );
            }
            _ => {}
        }
        println!(
            "skinny p=8 n={} speedup vs square-blocked: {skinny_speedup_p8:.2}x (target > 1x)",
            skinny_ns.last().unwrap()
        );
    }
    match report.finish() {
        Some(path) => println!("report → {path}"),
        None => println!("report → (unwritable bench_out/, skipped)"),
    }
}
