//! L3 substrate roofline: packed cache-blocked GEMM / SYRK throughput vs the
//! seed broadcast kernel, sequential and row-panel parallel.
//!
//! Everything PRISM does is GEMM-dominated, so the linalg substrate's
//! GFLOP/s sets the scale of every other benchmark. This bench (a) reports
//! the single-thread **packed-kernel speedup over the seed broadcast
//! kernel** at n ∈ {256, 512, 1024} — the PR-over-PR trajectory metric —
//! (b) verifies the parallel engine's scaling (target ≥ 2× at n = 512 with
//! 4 threads) with bit-identical output asserted on every shape, and (c)
//! emits the machine-readable `bench_out/BENCH_gemm.json` CI uploads as an
//! artifact.
//!
//! Run: `cargo bench --bench perf_gemm [-- --smoke]` (`--smoke`: tiny sizes
//! for the CI smoke step).

use prism::benchkit::{banner, Bench, JsonReport, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::{gemm_broadcast, matmul_naive, GemmEngine};
use prism::linalg::Mat;
use prism::randmat;
use prism::rng::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    banner("perf — GEMM/SYRK substrate throughput", "EXPERIMENTS.md §Perf (L3)");
    let bench = if smoke {
        Bench::quick()
    } else {
        Bench { min_time_s: 0.3, max_samples: 15, warmup: 1 }
    };
    let sizes: &[usize] = if smoke { &[32, 64] } else { &[256, 512, 1024] };
    let mut rng = Rng::seed_from(42);
    let mut report = JsonReport::create("bench_out/BENCH_gemm.json", "perf_gemm");

    let seq = GemmEngine::sequential();
    let par = GemmEngine::with_threads(4);

    let mut t = Table::new(&[
        "op",
        "n",
        "packed ms",
        "packed GFLOP/s",
        "broadcast ms",
        "vs broadcast",
        "4T ms",
        "4T speedup",
    ]);
    let mut speedup_512_4t = 0.0;
    for &n in sizes {
        let a = randmat::gaussian(&mut rng, n, n);
        let b = randmat::gaussian(&mut rng, n, n);
        let flops = 2.0 * (n as f64).powi(3);

        // Correctness guards before timing: the packed kernel must match the
        // naive reference, and the parallel engine must be bit-identical to
        // the sequential one.
        if n <= 256 {
            let err = seq.matmul(&a, &b).sub(&matmul_naive(&a, &b)).max_abs();
            assert!(err < 1e-9, "packed kernel diverges from naive at n={n}: {err}");
        }
        assert_eq!(
            seq.matmul(&a, &b).as_slice(),
            par.matmul(&a, &b).as_slice(),
            "parallel engine output differs at n={n}"
        );

        // Sequential packed engine (allocation-free loop on a reused buffer).
        let mut c = Mat::zeros(n, n);
        let s_packed = bench.run(&format!("matmul_{n}"), || {
            seq.matmul_into(&mut c, &a, &b);
            std::hint::black_box(&c);
        });
        // The seed broadcast kernel on the same operands (same zero-fill as
        // matmul_into performs, so the comparison is like for like).
        let mut cb = Mat::zeros(n, n);
        let s_bcast = bench.run(&format!("matmul_broadcast_{n}"), || {
            cb.fill_with(0.0);
            gemm_broadcast(a.as_slice(), b.as_slice(), cb.as_mut_slice(), n, n, n);
            std::hint::black_box(&cb);
        });
        // Row-panel parallel packed engine, 4 threads.
        let mut c4 = Mat::zeros(n, n);
        let s_par = bench.run(&format!("matmul_{n}_4t"), || {
            par.matmul_into(&mut c4, &a, &b);
            std::hint::black_box(&c4);
        });
        let vs_broadcast = s_bcast.median_s() / s_packed.median_s();
        let speedup_4t = s_packed.median_s() / s_par.median_s();
        if n == 512 {
            speedup_512_4t = speedup_4t;
        }
        t.row(&[
            "C = A·B".into(),
            n.to_string(),
            format!("{:.2}", s_packed.median_s() * 1e3),
            format!("{:.2}", flops / s_packed.median_s() / 1e9),
            format!("{:.2}", s_bcast.median_s() * 1e3),
            format!("{vs_broadcast:.2}x"),
            format!("{:.2}", s_par.median_s() * 1e3),
            format!("{speedup_4t:.2}x"),
        ]);
        report.entry(&[
            ("op", Value::Str("matmul".into())),
            ("n", Value::Int(n as i64)),
            ("packed_ms", Value::Float(s_packed.median_s() * 1e3)),
            ("packed_gflops", Value::Float(flops / s_packed.median_s() / 1e9)),
            ("broadcast_ms", Value::Float(s_bcast.median_s() * 1e3)),
            ("broadcast_gflops", Value::Float(flops / s_bcast.median_s() / 1e9)),
            ("speedup_packed_vs_broadcast", Value::Float(vs_broadcast)),
            ("ms_4t", Value::Float(s_par.median_s() * 1e3)),
            ("speedup_4t", Value::Float(speedup_4t)),
        ]);

        // SYRK: half the flops of a general GEMM (upper triangle + mirror).
        let mut cs = Mat::zeros(n, n);
        let s_syrk = bench.run(&format!("syrk_{n}"), || {
            seq.syrk_at_a_into(&mut cs, &a);
            std::hint::black_box(&cs);
        });
        let mut cs4 = Mat::zeros(n, n);
        let s_syrk_par = bench.run(&format!("syrk_{n}_4t"), || {
            par.syrk_at_a_into(&mut cs4, &a);
            std::hint::black_box(&cs4);
        });
        t.row(&[
            "C = Aᵀ·A".into(),
            n.to_string(),
            format!("{:.2}", s_syrk.median_s() * 1e3),
            format!("{:.2}", flops / s_syrk.median_s() / 1e9),
            "-".into(),
            "-".into(),
            format!("{:.2}", s_syrk_par.median_s() * 1e3),
            format!("{:.2}x", s_syrk.median_s() / s_syrk_par.median_s()),
        ]);
        report.entry(&[
            ("op", Value::Str("syrk".into())),
            ("n", Value::Int(n as i64)),
            ("packed_ms", Value::Float(s_syrk.median_s() * 1e3)),
            ("packed_gflops", Value::Float(flops / s_syrk.median_s() / 1e9)),
            ("ms_4t", Value::Float(s_syrk_par.median_s() * 1e3)),
            ("speedup_4t", Value::Float(s_syrk.median_s() / s_syrk_par.median_s())),
        ]);
    }
    t.print();
    println!("\n(GFLOP/s on the full 2n³ count; syrk computes the upper triangle only, so");
    println!("its effective rate appears ~2x the work it does. 'vs broadcast' is the");
    println!("single-thread packed kernel against the seed broadcast kernel on identical");
    println!("operands; 4T columns are asserted bit-identical to sequential.)");
    if !smoke {
        println!("n=512 matmul 4-thread speedup: {speedup_512_4t:.2}x (target ≥ 2x)");
    }
    match report.finish() {
        Some(path) => println!("report → {path}"),
        None => println!("report → (unwritable bench_out/, skipped)"),
    }
}
