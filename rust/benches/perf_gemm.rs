//! L3 substrate roofline: blocked GEMM / SYRK throughput across sizes.
//!
//! Everything PRISM does is GEMM-dominated, so the linalg substrate's
//! GFLOP/s sets the scale of every other benchmark. We track it here to (a)
//! catch regressions and (b) anchor the §Perf roofline analysis in
//! EXPERIMENTS.md (single-core f64; target = practical scalar/auto-vec
//! roofline, not BLAS).

use prism::benchkit::{banner, Bench, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::{matmul, matmul_at_b, syrk_at_a};
use prism::randmat;
use prism::rng::Rng;

fn main() {
    banner("perf — GEMM/SYRK substrate throughput", "EXPERIMENTS.md §Perf (L3)");
    let bench = Bench { min_time_s: 0.3, max_samples: 15, warmup: 1 };
    let mut rng = Rng::seed_from(42);
    let mut series = SeriesWriter::create("bench_out/perf_gemm.jsonl");

    let mut t = Table::new(&["op", "n", "median ms", "GFLOP/s"]);
    for n in [64usize, 128, 256, 512] {
        let a = randmat::gaussian(&mut rng, n, n);
        let b = randmat::gaussian(&mut rng, n, n);
        let flops = 2.0 * (n as f64).powi(3);

        let s = bench.run(&format!("matmul_{n}"), || {
            std::hint::black_box(matmul(&a, &b));
        });
        t.row(&[
            "C = A·B".into(),
            n.to_string(),
            format!("{:.2}", s.median_s() * 1e3),
            format!("{:.2}", flops / s.median_s() / 1e9),
        ]);
        series.point(&[
            ("op", Value::Str("matmul".into())),
            ("n", Value::Int(n as i64)),
            ("gflops", Value::Float(flops / s.median_s() / 1e9)),
        ]);

        let s = bench.run(&format!("matmul_at_b_{n}"), || {
            std::hint::black_box(matmul_at_b(&a, &b));
        });
        t.row(&[
            "C = Aᵀ·B".into(),
            n.to_string(),
            format!("{:.2}", s.median_s() * 1e3),
            format!("{:.2}", flops / s.median_s() / 1e9),
        ]);

        // SYRK does half the FLOPs of a full GEMM (symmetric result).
        let s = bench.run(&format!("syrk_{n}"), || {
            std::hint::black_box(syrk_at_a(&a));
        });
        t.row(&[
            "C = Aᵀ·A".into(),
            n.to_string(),
            format!("{:.2}", s.median_s() * 1e3),
            format!("{:.2}", flops / s.median_s() / 1e9),
        ]);
        series.point(&[
            ("op", Value::Str("syrk".into())),
            ("n", Value::Int(n as i64)),
            ("gflops", Value::Float(flops / s.median_s() / 1e9)),
        ]);
    }
    t.print();
    println!("\n(GFLOP/s computed on the full 2n³ count; syrk exploits symmetry so its");
    println!("effective rate appears ~2x the work it actually does.)");
    println!("series → bench_out/perf_gemm.jsonl");
}
