//! L3 substrate roofline: blocked GEMM / SYRK throughput across sizes,
//! sequential vs the row-panel parallel engine.
//!
//! Everything PRISM does is GEMM-dominated, so the linalg substrate's
//! GFLOP/s sets the scale of every other benchmark. We track it here to (a)
//! catch regressions, (b) anchor the §Perf roofline analysis in
//! EXPERIMENTS.md, and (c) verify the parallel engine's scaling — the
//! acceptance bar is ≥ 2× at n = 512 with 4 threads over the sequential
//! kernel, with bit-identical output (asserted below on every shape).

use prism::benchkit::{banner, Bench, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::{matmul_at_b, GemmEngine};
use prism::linalg::Mat;
use prism::randmat;
use prism::rng::Rng;

fn main() {
    banner("perf — GEMM/SYRK substrate throughput", "EXPERIMENTS.md §Perf (L3)");
    let bench = Bench { min_time_s: 0.3, max_samples: 15, warmup: 1 };
    let mut rng = Rng::seed_from(42);
    let mut series = SeriesWriter::create("bench_out/perf_gemm.jsonl");

    let seq = GemmEngine::sequential();
    let par = GemmEngine::with_threads(4);

    let mut t = Table::new(&["op", "n", "median ms", "GFLOP/s", "4T ms", "4T GFLOP/s", "speedup"]);
    let mut speedup_512 = 0.0;
    for n in [64usize, 128, 256, 512] {
        let a = randmat::gaussian(&mut rng, n, n);
        let b = randmat::gaussian(&mut rng, n, n);
        let flops = 2.0 * (n as f64).powi(3);

        // Determinism check before timing: the parallel engine must be
        // bit-identical to the sequential kernel.
        assert_eq!(
            seq.matmul(&a, &b).as_slice(),
            par.matmul(&a, &b).as_slice(),
            "parallel engine output differs at n={n}"
        );

        // Allocation-free timing loop: `matmul_into` on a reused buffer.
        let mut c = Mat::zeros(n, n);
        let s_seq = bench.run(&format!("matmul_{n}"), || {
            seq.matmul_into(&mut c, &a, &b);
            std::hint::black_box(&c);
        });
        let mut c2 = Mat::zeros(n, n);
        let s_par = bench.run(&format!("matmul_{n}_4t"), || {
            par.matmul_into(&mut c2, &a, &b);
            std::hint::black_box(&c2);
        });
        let speedup = s_seq.median_s() / s_par.median_s();
        if n == 512 {
            speedup_512 = speedup;
        }
        t.row(&[
            "C = A·B".into(),
            n.to_string(),
            format!("{:.2}", s_seq.median_s() * 1e3),
            format!("{:.2}", flops / s_seq.median_s() / 1e9),
            format!("{:.2}", s_par.median_s() * 1e3),
            format!("{:.2}", flops / s_par.median_s() / 1e9),
            format!("{:.2}x", speedup),
        ]);
        series.point(&[
            ("op", Value::Str("matmul".into())),
            ("n", Value::Int(n as i64)),
            ("gflops", Value::Float(flops / s_seq.median_s() / 1e9)),
            ("gflops_4t", Value::Float(flops / s_par.median_s() / 1e9)),
            ("speedup_4t", Value::Float(speedup)),
        ]);

        let s = bench.run(&format!("matmul_at_b_{n}"), || {
            std::hint::black_box(matmul_at_b(&a, &b));
        });
        t.row(&[
            "C = Aᵀ·B".into(),
            n.to_string(),
            format!("{:.2}", s.median_s() * 1e3),
            format!("{:.2}", flops / s.median_s() / 1e9),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);

        // SYRK does half the FLOPs of a full GEMM (symmetric result).
        let mut cs = Mat::zeros(n, n);
        let s_syrk = bench.run(&format!("syrk_{n}"), || {
            seq.syrk_at_a_into(&mut cs, &a);
            std::hint::black_box(&cs);
        });
        let mut cs2 = Mat::zeros(n, n);
        let s_syrk_par = bench.run(&format!("syrk_{n}_4t"), || {
            par.syrk_at_a_into(&mut cs2, &a);
            std::hint::black_box(&cs2);
        });
        t.row(&[
            "C = Aᵀ·A".into(),
            n.to_string(),
            format!("{:.2}", s_syrk.median_s() * 1e3),
            format!("{:.2}", flops / s_syrk.median_s() / 1e9),
            format!("{:.2}", s_syrk_par.median_s() * 1e3),
            format!("{:.2}", flops / s_syrk_par.median_s() / 1e9),
            format!("{:.2}x", s_syrk.median_s() / s_syrk_par.median_s()),
        ]);
        series.point(&[
            ("op", Value::Str("syrk".into())),
            ("n", Value::Int(n as i64)),
            ("gflops", Value::Float(flops / s_syrk.median_s() / 1e9)),
            ("gflops_4t", Value::Float(flops / s_syrk_par.median_s() / 1e9)),
            ("speedup_4t", Value::Float(s_syrk.median_s() / s_syrk_par.median_s())),
        ]);
    }
    t.print();
    println!("\n(GFLOP/s computed on the full 2n³ count; syrk exploits symmetry so its");
    println!("effective rate appears ~2x the work it actually does. 4T columns run the");
    println!("same kernel over 4 row panels — output is asserted bit-identical.)");
    println!("n=512 matmul speedup with 4 threads: {speedup_512:.2}x (target ≥ 2x)");
    println!("series → bench_out/perf_gemm.jsonl");
}
