//! Figure 5: Shampoo training speed with three inverse-root backends —
//! eigendecomposition, PolarExpress (coupled form), PRISM-5.
//!
//! Paper setting: ResNet-20/CIFAR-10 (left) and ResNet-32/CIFAR-100 (right),
//! validation accuracy over the first 50 epochs. Offline substitute (see
//! DESIGN.md): MLP classifiers on synthetic blob datasets — one 10-class
//! ("CIFAR-10") and one 100-class ("CIFAR-100") — with matrix-shaped layers
//! large enough that the inverse-root cost matters. We report validation
//! accuracy at equal step counts *and* the wall-clock cost per backend; the
//! paper's claim is that PRISM reaches the same accuracy in less time.

use prism::benchkit::{banner, SeriesWriter, Table};
use prism::config::Backend;
use prism::configfmt::Value;
use prism::nn::mlp::Mlp;
use prism::optim::shampoo::Shampoo;
use prism::optim::Optimizer;
use prism::rng::Rng;
use prism::util::Stopwatch;
use prism::workload::BlobsDataset;

struct Run {
    backend: &'static str,
    wall_s: f64,
    final_acc: f64,
    acc_curve: Vec<(usize, f64)>,
}

fn train(
    data: &BlobsDataset,
    dims: &[usize],
    backend: Backend,
    bname: &'static str,
    steps: usize,
    seed: u64,
    series: &mut SeriesWriter,
    panel: &str,
) -> Run {
    let mut rng = Rng::seed_from(seed);
    let mut model = Mlp::new(&mut rng, dims);
    let mut opt = Shampoo::paper_default(backend, seed);
    opt.precond_interval = 5;
    let (train_idx, val_idx) = data.split(0.2);
    let (val_x, val_y) = data.batch(&val_idx);
    let batch = 64;

    let sw = Stopwatch::start();
    let mut acc_curve = Vec::new();
    for step in 0..steps {
        let start = (step * batch) % train_idx.len().saturating_sub(batch).max(1);
        let idx: Vec<usize> = train_idx[start..(start + batch).min(train_idx.len())].to_vec();
        let (x, y) = data.batch(&idx);
        let _ = model.forward_backward(&x, &y);
        {
            let mut params = model.params_mut();
            opt.step(&mut params);
        }
        model.zero_grads();
        if step % 10 == 0 || step + 1 == steps {
            let acc = model.accuracy(&val_x, &val_y);
            acc_curve.push((step, acc));
            series.point(&[
                ("panel", Value::Str(panel.into())),
                ("backend", Value::Str(bname.into())),
                ("step", Value::Int(step as i64)),
                ("wall_s", Value::Float(sw.elapsed_s())),
                ("val_acc", Value::Float(acc)),
            ]);
        }
    }
    Run {
        backend: bname,
        wall_s: sw.elapsed_s(),
        final_acc: acc_curve.last().map(|&(_, a)| a).unwrap_or(0.0),
        acc_curve,
    }
}

fn panel(
    title: &str,
    panel_id: &str,
    classes: usize,
    dims: &[usize],
    steps: usize,
    series: &mut SeriesWriter,
) {
    let mut rng = Rng::seed_from(7);
    let data = BlobsDataset::generate(&mut rng, 1500, dims[0], classes, 1.5);
    println!("\n{title}: MLP {dims:?}, {classes} classes, {steps} steps");
    let runs = [
        train(&data, dims, Backend::Eigen, "eigen", steps, 42, series, panel_id),
        train(&data, dims, Backend::PolarExpress, "polar-express", steps, 42, series, panel_id),
        train(&data, dims, Backend::Prism5, "PRISM-5", steps, 42, series, panel_id),
    ];
    let mut t = Table::new(&["backend", "wall (s)", "final val acc", "s/100 steps"]);
    for r in &runs {
        t.row(&[
            r.backend.to_string(),
            format!("{:.2}", r.wall_s),
            format!("{:.3}", r.final_acc),
            format!("{:.2}", r.wall_s / steps as f64 * 100.0),
        ]);
    }
    t.print();
    println!("accuracy curves (step,acc):");
    for r in &runs {
        let pts: Vec<String> =
            r.acc_curve.iter().step_by(2).map(|(s, a)| format!("({s},{a:.2})")).collect();
        println!("  {:<14} {}", r.backend, pts.join(" "));
    }
}

fn main() {
    banner(
        "Figure 5 — Shampoo inverse-root backends: eigen vs PolarExpress vs PRISM",
        "paper Fig. 5 (ResNet-20/CIFAR-10 left, ResNet-32/CIFAR-100 right)",
    );
    let mut series = SeriesWriter::create("bench_out/fig5.jsonl");
    // Left panel analog: 10 classes, ResNet-20-ish depth.
    panel("left (CIFAR-10 analog)", "cifar10", 10, &[256, 192, 128, 10], 120, &mut series);
    // Right panel analog: 100 classes, deeper/wider.
    panel(
        "right (CIFAR-100 analog)",
        "cifar100",
        100,
        &[256, 224, 192, 100],
        120,
        &mut series,
    );
    println!("\nexpected: equal-accuracy-per-step across backends (same math), but PRISM");
    println!("cheapest per step ⇒ best accuracy-vs-wall-clock; eigen slowest at these sizes.");
    println!("series → bench_out/fig5.jsonl");
}
