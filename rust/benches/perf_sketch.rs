//! §4.2 measured: the PRISM fitting overhead.
//!
//! Three claims to verify on this substrate:
//!  1. sketched power traces cost O(n²p) — overhead ≤ ~10% of one
//!     Newton–Schulz iteration (which is Θ(n³)) at n = 512, p = 8;
//!  2. the exact O(n³)-per-power alternative is dramatically slower;
//!  3. tiny p (≈5) already matches exact fitting in convergence
//!     (the paper's "p can be as small as 5").

use prism::benchkit::{banner, Bench, SeriesWriter, Table};
use prism::configfmt::Value;
use prism::linalg::gemm::{matmul, syrk_at_a};
use prism::prism::polar::{polar_prism, PolarOpts};
use prism::prism::{AlphaMode, StopRule};
use prism::randmat;
use prism::rng::Rng;
use prism::sketch::{exact_power_traces, GaussianSketch};

fn main() {
    banner("§4.2 — sketched fitting overhead vs iteration cost", "paper §4.2, Theorem 2");
    let bench = Bench::default();
    let mut rng = Rng::seed_from(42);
    let mut series = SeriesWriter::create("bench_out/perf_sketch.jsonl");
    let q = 10; // powers needed for d=2 (4d+2)

    // ── 1+2: trace costs vs one NS iteration ─────────────────────────────
    let mut t = Table::new(&[
        "n",
        "p",
        "sketch traces (ms)",
        "exact traces (ms)",
        "1 NS iter (ms)",
        "overhead/iter",
    ]);
    for n in [128usize, 256, 512] {
        let g = randmat::gaussian(&mut rng, n, n);
        let r = syrk_at_a(&g).scaled(1.0 / n as f64);
        let iter_stats = bench.run(&format!("ns_iter_n{n}"), || {
            // One d=2 NS iteration ~ 3 GEMMs at n.
            let r2 = matmul(&r, &r);
            let x = matmul(&r, &r2);
            std::hint::black_box(x);
        });
        let exact_stats = if n <= 256 {
            Some(bench.run(&format!("exact_n{n}"), || {
                std::hint::black_box(exact_power_traces(&r, q));
            }))
        } else {
            None // O(q·n³) — too slow; the point is made at smaller n.
        };
        for p in [4usize, 8, 16] {
            let s = GaussianSketch::draw(&mut rng, p, n);
            let sk_stats = bench.run(&format!("sketch_n{n}_p{p}"), || {
                std::hint::black_box(s.power_traces(&r, q));
            });
            let overhead = sk_stats.median_s() / iter_stats.median_s();
            t.row(&[
                n.to_string(),
                p.to_string(),
                format!("{:.2}", sk_stats.median_s() * 1e3),
                exact_stats
                    .as_ref()
                    .map(|e| format!("{:.2}", e.median_s() * 1e3))
                    .unwrap_or_else(|| "(skipped)".into()),
                format!("{:.2}", iter_stats.median_s() * 1e3),
                format!("{:.1}%", overhead * 100.0),
            ]);
            series.point(&[
                ("n", Value::Int(n as i64)),
                ("p", Value::Int(p as i64)),
                ("sketch_s", Value::Float(sk_stats.median_s())),
                ("iter_s", Value::Float(iter_stats.median_s())),
                ("overhead", Value::Float(overhead)),
            ]);
        }
    }
    println!("\npower traces tr(S R^i Sᵀ), i ≤ {q}:");
    t.print();

    // ── 3: convergence vs sketch size p (paper: p = 5 suffices) ──────────
    let mut t = Table::new(&["alpha mode", "iters to 1e-8", "final residual"]);
    let (n, m) = (128, 64);
    let s = randmat::logspace(1e-5, 1.0, m);
    let a = randmat::with_spectrum(&mut rng, n, m, &s);
    let stop = StopRule::default().with_max_iters(200).with_tol(1e-8);
    let mut modes = vec![(AlphaMode::Exact, "exact".to_string())];
    for p in [2usize, 5, 8, 16, 32] {
        modes.push((AlphaMode::Sketched { p }, format!("sketched p={p}")));
    }
    modes.push((AlphaMode::Classic, "classic (no fit)".to_string()));
    for (mode, label) in modes {
        let out = polar_prism(&a, &PolarOpts { d: 2, alpha: mode, stop }, &mut rng);
        t.row(&[
            label.clone(),
            out.log
                .iters_to_tol(1e-8)
                .map(|k| k.to_string())
                .unwrap_or_else(|| "—".into()),
            format!("{:.1e}", out.log.final_residual()),
        ]);
        series.point(&[
            ("ablation", Value::Str(label)),
            ("iters", Value::Int(out.log.iters_to_tol(1e-8).unwrap_or(0) as i64)),
        ]);
    }
    println!("\npolar {n}x{m}, σ ∈ [1e-5, 1] — iterations vs sketch size:");
    t.print();
    println!("\nexpected: p ≥ 5 matches exact; overhead ≈ (q·p)/n per iteration → a few");
    println!("percent at n = 512; exact traces cost more than the iteration itself.");
    println!("series → bench_out/perf_sketch.jsonl");
}
