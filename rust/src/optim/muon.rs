//! Muon (Jordan et al. 2024): orthogonalized momentum updates for
//! matrix-shaped parameters, with the polar factor computed by a pluggable
//! backend (classical NS / PolarExpress / PRISM-3 / PRISM-5 — Fig. 6).
//!
//! Vector parameters (biases, gains) fall back to Adam, as in the reference
//! Muon implementation.

use super::matfn::PolarBackend;
use super::Optimizer;
use crate::config::Backend;
use crate::linalg::Mat;
use crate::matfn::RectStrategy;
use crate::nn::{Param, ParamKind};
use crate::rng::Rng;

pub struct Muon {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub polar: PolarBackend,
    rng: Rng,
    bufs: Vec<Mat>,
    // Persistent per-layer polar outputs: `polar_into` writes here, so the
    // hot loop stops minting a fresh Mat per matrix param per step.
    out_bufs: Vec<Mat>,
    // Adam state for vector params.
    adam_m: Vec<Mat>,
    adam_v: Vec<Mat>,
    t: u64,
}

impl Muon {
    pub fn new(lr: f64, momentum: f64, weight_decay: f64, polar: PolarBackend, seed: u64) -> Muon {
        Muon {
            lr,
            momentum,
            weight_decay,
            polar,
            rng: Rng::seed_from(seed ^ 0x4D756F6E), // "Muon"
            bufs: Vec::new(),
            out_bufs: Vec::new(),
            adam_m: Vec::new(),
            adam_v: Vec::new(),
            t: 0,
        }
    }

    /// Paper §C settings: lr 6e-3, momentum 0.95, weight decay 0.01.
    pub fn paper_default(backend: Backend, seed: u64) -> Muon {
        Muon::new(6e-3, 0.95, 0.01, PolarBackend::paper_muon(backend), seed)
    }

    /// Select the route rectangular params take through the polar backend
    /// (the `rect_strategy` config knob; default [`RectStrategy::Auto`]).
    pub fn set_rect_strategy(&mut self, strategy: RectStrategy) {
        self.polar.set_rect_strategy(strategy);
    }
}

impl Optimizer for Muon {
    fn step(&mut self, params: &mut [&mut Param]) {
        if self.bufs.is_empty() {
            self.bufs = params.iter().map(|p| Mat::zeros(p.w.rows(), p.w.cols())).collect();
            self.out_bufs = self.bufs.clone();
            self.adam_m = self.bufs.clone();
            self.adam_v = self.bufs.clone();
        }
        self.t += 1;
        for (i, p) in params.iter_mut().enumerate() {
            // Nesterov-style momentum on the gradient.
            let buf = &mut self.bufs[i];
            buf.scale(self.momentum);
            buf.axpy(1.0, &p.g);
            match p.kind {
                ParamKind::Matrix if p.w.rows() > 1 && p.w.cols() > 1 => {
                    // Orthogonalize the momentum matrix into this layer's
                    // persistent output buffer — rectangular params route
                    // through the cheap Gram/range path inside the backend.
                    let o = &mut self.out_bufs[i];
                    self.polar.polar_into(buf, o, &mut self.rng);
                    // RMS-preserving scale (Muon convention): the polar
                    // factor has unit singular values, so scale by
                    // √(max(m, n)) · 0.2 to match Adam-sized updates.
                    let (m, n) = o.shape();
                    let scale = 0.2 * (m.max(n) as f64).sqrt();
                    if self.weight_decay > 0.0 {
                        // Decoupled decay, W ← (1 − ηλ)W — no clone needed.
                        p.w.scale(1.0 - self.lr * self.weight_decay);
                    }
                    p.w.axpy(-self.lr * scale, o);
                }
                _ => {
                    // Adam path for vectors.
                    let m = &mut self.adam_m[i];
                    let v = &mut self.adam_v[i];
                    let bc1 = 1.0 - 0.9f64.powi(self.t as i32);
                    let bc2 = 1.0 - 0.999f64.powi(self.t as i32);
                    let gs = p.g.as_slice();
                    let ms = m.as_mut_slice();
                    let vs = v.as_mut_slice();
                    let ws = p.w.as_mut_slice();
                    for j in 0..gs.len() {
                        ms[j] = 0.9 * ms[j] + 0.1 * gs[j];
                        vs[j] = 0.999 * vs[j] + 0.001 * gs[j] * gs[j];
                        ws[j] -= self.lr * (ms[j] / bc1) / ((vs[j] / bc2).sqrt() + 1e-8);
                    }
                }
            }
        }
    }

    fn name(&self) -> String {
        format!("muon[{}](lr={})", self.polar.name(), self.lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::BlobsDataset;

    fn train_small(backend: Backend, steps: usize) -> f64 {
        let mut rng = Rng::seed_from(7);
        let ds = BlobsDataset::generate(&mut rng, 256, 16, 4, 3.0);
        let mut mlp = crate::nn::Mlp::new(&mut rng, &[16, 32, 4]);
        let mut opt = Muon::new(0.05, 0.9, 0.0, PolarBackend::new(backend, 8), 1);
        let mut last = f64::INFINITY;
        for s in 0..steps {
            let idx: Vec<usize> = (0..64).map(|k| (s * 64 + k) % ds.len()).collect();
            let (x, y) = ds.batch(&idx);
            mlp.zero_grads();
            let (loss, _) = mlp.forward_backward(&x, &y);
            let mut ps = mlp.params_mut();
            opt.step(&mut ps);
            last = loss;
        }
        last
    }

    #[test]
    fn muon_prism_trains() {
        let final_loss = train_small(Backend::Prism5, 40);
        assert!(final_loss < 0.7, "final loss {final_loss}");
    }

    #[test]
    fn muon_update_is_orthogonal_direction() {
        // Near-square (direct route) plus both rectangular orientations
        // (64×256 and 256×64 resolve to the Gram route under Auto): the
        // update direction must be orthogonal regardless of the route.
        for (m, n) in [(12usize, 8usize), (64, 256), (256, 64)] {
            let mut rng = Rng::seed_from(2);
            let mut p = Param::matrix("w", Mat::zeros(m, n));
            p.g = Mat::gaussian(&mut rng, m, n, 1.0);
            let mut opt = Muon::new(1.0, 0.0, 0.0, PolarBackend::new(Backend::Prism5, 30), 3);
            opt.step(&mut [&mut p]);
            // Update direction = −lr·scale·O with O orthogonal: check
            // singular values of the update are all ≈ lr·scale.
            let d = if m >= n {
                crate::linalg::svd::svd(&p.w)
            } else {
                crate::linalg::svd::svd(&p.w.transpose())
            };
            let ratio = d.s[0] / d.s[m.min(n) - 1];
            assert!(ratio < 1.01, "{m}x{n}: update not orthogonal: cond={ratio}");
        }
    }

    #[test]
    fn muon_steps_are_allocation_free_after_the_first() {
        // One square-ish and one rectangular param: after the first step
        // both the square solver and the rect solver have warm pools, and
        // the per-layer outputs land in persistent buffers via polar_into —
        // further steps must not miss the workspace pool.
        let mut rng = Rng::seed_from(5);
        let mut p1 = Param::matrix("w1", Mat::zeros(12, 8));
        let mut p2 = Param::matrix("w2", Mat::zeros(64, 16));
        let mut opt = Muon::new(0.01, 0.9, 0.0, PolarBackend::new(Backend::Prism5, 10), 6);
        for _ in 0..2 {
            p1.g = Mat::gaussian(&mut rng, 12, 8, 1.0);
            p2.g = Mat::gaussian(&mut rng, 64, 16, 1.0);
            opt.step(&mut [&mut p1, &mut p2]);
        }
        let allocs = opt.polar.workspace_allocations();
        assert!(allocs > 0);
        for _ in 0..3 {
            p1.g = Mat::gaussian(&mut rng, 12, 8, 1.0);
            p2.g = Mat::gaussian(&mut rng, 64, 16, 1.0);
            opt.step(&mut [&mut p1, &mut p2]);
        }
        assert_eq!(
            opt.polar.workspace_allocations(),
            allocs,
            "warm Muon steps must not miss the workspace pool"
        );
    }

    #[test]
    fn vector_params_use_adam() {
        let mut p = Param::vector("b", 4);
        p.g[(0, 0)] = 1.0;
        let mut opt = Muon::new(0.01, 0.9, 0.0, PolarBackend::new(Backend::Prism5, 5), 4);
        opt.step(&mut [&mut p]);
        assert!(p.w[(0, 0)] < 0.0 && p.w[(0, 0)] > -0.02);
        assert_eq!(p.w[(0, 1)], 0.0);
    }
}
