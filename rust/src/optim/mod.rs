//! Optimizers with pluggable matrix-function backends.
//!
//! * [`sgd`] / [`adamw`] — baselines (AdamW is Fig. 6's reference curve).
//! * [`muon`] — momentum + orthogonalized update via a [`matfn::PolarBackend`].
//! * [`shampoo`] — Kronecker-factored preconditioning via a
//!   [`matfn::InvRootBackend`] (Fig. 5's three compared backends).
//! * [`schedule`] — learning-rate schedules.

pub mod matfn;
pub mod sgd;
pub mod adamw;
pub mod muon;
pub mod shampoo;
pub mod schedule;

use crate::nn::Param;

/// A parameter-set optimizer. `step` consumes the accumulated gradients and
/// updates weights in place; callers zero grads afterwards.
pub trait Optimizer {
    fn step(&mut self, params: &mut [&mut Param]);
    fn name(&self) -> String;
}
