//! Shampoo (Gupta et al. 2018; Shi et al. 2023 distributed variant) with
//! p = 2 preconditioning as the paper uses:
//! `W ← W − η · L^{-1/2} G R^{-1/2}` where `L = Σ G Gᵀ`, `R = Σ Gᵀ G`.
//!
//! The inverse roots are computed by a pluggable [`InvRootBackend`]
//! (eigendecomposition / PolarExpress-coupled / PRISM — Fig. 5's three
//! curves), refreshed every `precond_interval` steps, with SGD grafting so
//! the update magnitude tracks the raw gradient's scale.

use super::matfn::InvRootBackend;
use super::Optimizer;
use crate::config::Backend;
use crate::linalg::gemm::{global_engine, Workspace};
use crate::linalg::Mat;
use crate::nn::{Param, ParamKind};
use crate::rng::Rng;

struct LayerState {
    l: Mat,         // m x m accumulator
    r: Mat,         // n x n accumulator
    l_inv: Mat,     // L^{-1/2}
    r_inv: Mat,     // R^{-1/2}
    initialized: bool,
}

pub struct Shampoo {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub damping: f64,
    pub precond_interval: usize,
    pub grafting: bool,
    backend: InvRootBackend,
    rng: Rng,
    states: Vec<Option<LayerState>>,
    bufs: Vec<Mat>,
    /// Reused GEMM temporaries: the per-step accumulator/update products run
    /// allocation-free after the first step.
    scratch: Workspace,
    t: usize,
}

impl Shampoo {
    pub fn new(
        lr: f64,
        damping: f64,
        precond_interval: usize,
        backend: InvRootBackend,
        seed: u64,
    ) -> Shampoo {
        Shampoo {
            lr,
            momentum: 0.9,
            weight_decay: 0.0,
            damping,
            precond_interval: precond_interval.max(1),
            grafting: true,
            backend,
            rng: Rng::seed_from(seed ^ 0x5368616D), // "Sham"
            states: Vec::new(),
            bufs: Vec::new(),
            scratch: Workspace::new(),
            t: 0,
        }
    }

    /// Paper Fig. 5 settings: lr 1e-3, weight decay 5e-4.
    pub fn paper_default(backend: Backend, seed: u64) -> Shampoo {
        let mut s = Shampoo::new(1e-3, 1e-6, 10, InvRootBackend::new(backend, 40), seed);
        s.weight_decay = 5e-4;
        s
    }
}

impl Optimizer for Shampoo {
    fn step(&mut self, params: &mut [&mut Param]) {
        if self.states.is_empty() {
            self.states = params.iter().map(|_| None).collect();
            self.bufs = params.iter().map(|p| Mat::zeros(p.w.rows(), p.w.cols())).collect();
        }
        let eng = global_engine();
        let refresh = self.t % self.precond_interval == 0;
        self.t += 1;
        for (i, p) in params.iter_mut().enumerate() {
            // Momentum on the raw gradient (in place — no clone).
            self.bufs[i].scale(self.momentum);
            self.bufs[i].axpy(1.0, &p.g);
            let is_matrix =
                matches!(p.kind, ParamKind::Matrix) && p.w.rows() > 1 && p.w.cols() > 1;
            if self.weight_decay > 0.0 {
                // Decoupled decay, W ← (1 − ηλ)W — no clone needed.
                p.w.scale(1.0 - self.lr * self.weight_decay);
            }
            if is_matrix {
                let (m, n) = self.bufs[i].shape();
                let st = self.states[i].get_or_insert_with(|| LayerState {
                    l: Mat::zeros(m, m),
                    r: Mat::zeros(n, n),
                    l_inv: Mat::eye(m),
                    r_inv: Mat::eye(n),
                    initialized: false,
                });
                // Accumulate second-moment factors through scratch buffers.
                let mut tmp = self.scratch.take(m, m);
                eng.syrk_a_at_into(&mut tmp, &self.bufs[i]);
                st.l.axpy(1.0, &tmp);
                eng.syrk_at_a_into(&mut tmp, &self.bufs[i]);
                st.r.axpy(1.0, &tmp);
                self.scratch.put(tmp);
                if refresh || !st.initialized {
                    // Normalise accumulators so damping is scale-free (the
                    // refresh path is cold — every `precond_interval` steps —
                    // so the backend's allocations are acceptable). The
                    // validated solve rejects a rank-deficient damped
                    // accumulator (possible in the first steps, when L/R
                    // hold one low-rank gradient's worth of mass) with a
                    // typed error; on rejection we keep the previous
                    // preconditioner — identity before the first successful
                    // refresh — rather than iterate on a singular operand.
                    let lt = st.l.trace().max(1e-30) / m as f64;
                    let rt = st.r.trace().max(1e-30) / n as f64;
                    let ln = st.l.scaled(1.0 / lt);
                    let rn = st.r.scaled(1.0 / rt);
                    let li = self.backend.try_inv_sqrt(&ln, self.damping, &mut self.rng);
                    let ri = self.backend.try_inv_sqrt(&rn, self.damping, &mut self.rng);
                    if let (Ok(li), Ok(ri)) = (li, ri) {
                        st.l_inv = li.scaled(1.0 / lt.sqrt());
                        st.r_inv = ri.scaled(1.0 / rt.sqrt());
                        st.initialized = true;
                    }
                }
                // U = L^{-1/2} G R^{-1/2}.
                let mut lg = self.scratch.take(m, n);
                eng.matmul_into(&mut lg, &st.l_inv, &self.bufs[i]);
                let mut u = self.scratch.take(m, n);
                eng.matmul_into(&mut u, &lg, &st.r_inv);
                self.scratch.put(lg);
                if self.grafting {
                    // SGD grafting: give the preconditioned direction the
                    // raw gradient's Frobenius norm.
                    let un = u.fro_norm().max(1e-30);
                    u.scale(self.bufs[i].fro_norm() / un);
                }
                p.w.axpy(-self.lr, &u);
                self.scratch.put(u);
            } else {
                // Vectors: plain momentum-SGD.
                p.w.axpy(-self.lr, &self.bufs[i]);
            }
        }
    }

    fn name(&self) -> String {
        format!("shampoo[{}](lr={})", self.backend.name(), self.lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::BlobsDataset;

    fn train(backend: Backend, steps: usize, lr: f64) -> (f64, f64) {
        let mut rng = Rng::seed_from(11);
        let ds = BlobsDataset::generate(&mut rng, 256, 16, 4, 3.0);
        let mut mlp = crate::nn::Mlp::new(&mut rng, &[16, 24, 4]);
        let mut opt = Shampoo::new(lr, 1e-6, 5, InvRootBackend::new(backend, 40), 1);
        let mut last = f64::INFINITY;
        for s in 0..steps {
            let idx: Vec<usize> = (0..64).map(|k| (s * 64 + k) % ds.len()).collect();
            let (x, y) = ds.batch(&idx);
            mlp.zero_grads();
            let (loss, _) = mlp.forward_backward(&x, &y);
            let mut ps = mlp.params_mut();
            opt.step(&mut ps);
            last = loss;
        }
        let idx: Vec<usize> = (0..ds.len()).collect();
        let (x, y) = ds.batch(&idx);
        (last, mlp.accuracy(&x, &y))
    }

    #[test]
    fn shampoo_eigen_trains() {
        let (loss, acc) = train(Backend::Eigen, 50, 0.05);
        assert!(loss < 0.8, "loss={loss}");
        assert!(acc > 0.7, "acc={acc}");
    }

    #[test]
    fn shampoo_prism_trains() {
        let (loss, acc) = train(Backend::Prism5, 50, 0.05);
        assert!(loss < 0.8, "loss={loss}");
        assert!(acc > 0.7, "acc={acc}");
    }

    #[test]
    fn preconditioners_refresh_on_interval() {
        let mut rng = Rng::seed_from(5);
        let mut p = Param::matrix("w", Mat::zeros(6, 4));
        let mut opt = Shampoo::new(0.1, 1e-6, 3, InvRootBackend::new(Backend::Eigen, 30), 2);
        for _ in 0..4 {
            p.g = Mat::gaussian(&mut rng, 6, 4, 1.0);
            opt.step(&mut [&mut p]);
        }
        let st = opt.states[0].as_ref().unwrap();
        assert!(st.initialized);
        assert!(st.l.fro_norm() > 0.0 && st.r.fro_norm() > 0.0);
        assert!(!p.w.has_non_finite());
    }

    #[test]
    fn refresh_rejection_keeps_previous_preconditioner() {
        // Rank-2 gradients make L (8×8) singular; with zero damping the
        // validated refresh must reject the solve and keep the identity
        // preconditioner instead of iterating on a rank-deficient operand.
        let mut rng = Rng::seed_from(7);
        let mut p = Param::matrix("w", Mat::zeros(8, 2));
        let mut opt = Shampoo::new(0.1, 0.0, 1, InvRootBackend::new(Backend::Prism5, 30), 4);
        opt.momentum = 0.0;
        for _ in 0..2 {
            p.g = Mat::gaussian(&mut rng, 8, 2, 1.0);
            opt.step(&mut [&mut p]);
        }
        let st = opt.states[0].as_ref().unwrap();
        assert!(!st.initialized, "singular L with zero damping must be rejected");
        assert_eq!(st.l_inv, Mat::eye(8), "previous (identity) preconditioner kept");
        assert!(!p.w.has_non_finite());
    }

    #[test]
    fn grafting_matches_grad_norm() {
        let mut rng = Rng::seed_from(6);
        let mut p = Param::matrix("w", Mat::zeros(8, 8));
        p.g = Mat::gaussian(&mut rng, 8, 8, 1.0);
        let gnorm = p.g.fro_norm();
        let mut opt = Shampoo::new(1.0, 1e-6, 1, InvRootBackend::new(Backend::Eigen, 30), 3);
        opt.momentum = 0.0;
        opt.step(&mut [&mut p]);
        // With lr=1, wd=0, momentum=0: ‖ΔW‖_F == ‖G‖_F under grafting.
        assert!((p.w.fro_norm() - gnorm).abs() / gnorm < 1e-9);
    }
}
