//! SGD with momentum and decoupled weight decay.

use super::Optimizer;
use crate::linalg::Mat;
use crate::nn::Param;

pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    bufs: Vec<Mat>,
}

impl Sgd {
    pub fn new(lr: f64, momentum: f64, weight_decay: f64) -> Sgd {
        Sgd { lr, momentum, weight_decay, bufs: Vec::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [&mut Param]) {
        if self.bufs.is_empty() {
            self.bufs = params.iter().map(|p| Mat::zeros(p.w.rows(), p.w.cols())).collect();
        }
        for (p, buf) in params.iter_mut().zip(self.bufs.iter_mut()) {
            buf.scale(self.momentum);
            buf.axpy(1.0, &p.g);
            if self.weight_decay > 0.0 {
                let w = p.w.clone();
                p.w.axpy(-self.lr * self.weight_decay, &w);
            }
            p.w.axpy(-self.lr, buf);
        }
    }
    fn name(&self) -> String {
        format!("sgd(lr={}, m={})", self.lr, self.momentum)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_moves_against_gradient() {
        let mut p = Param::matrix("w", Mat::zeros(2, 2));
        p.g[(0, 0)] = 1.0;
        let mut opt = Sgd::new(0.1, 0.0, 0.0);
        opt.step(&mut [&mut p]);
        assert!((p.w[(0, 0)] + 0.1).abs() < 1e-12);
    }

    #[test]
    fn momentum_accumulates() {
        let mut p = Param::matrix("w", Mat::zeros(1, 1));
        let mut opt = Sgd::new(1.0, 0.5, 0.0);
        p.g[(0, 0)] = 1.0;
        opt.step(&mut [&mut p]); // buf = 1, w = -1
        opt.step(&mut [&mut p]); // buf = 1.5, w = -2.5
        assert!((p.w[(0, 0)] + 2.5).abs() < 1e-12);
    }

    #[test]
    fn weight_decay_shrinks() {
        let mut p = Param::matrix("w", Mat::eye(2));
        let mut opt = Sgd::new(0.1, 0.0, 0.5);
        opt.step(&mut [&mut p]);
        assert!(p.w[(0, 0)] < 1.0);
    }
}
