//! Optimizer-facing matrix-function backends — thin wrappers over the
//! unified [`crate::matfn`] solver API.
//!
//! Muon needs a **polar** backend (orthogonalize the momentum matrix);
//! Shampoo needs an **inverse-root** backend (precondition with `L^{-1/2}`,
//! `R^{-1/2}`). Each [`crate::config::Backend`] value maps to one algorithm
//! compared in the paper's Figs. 5–6 (exact eigendecomposition,
//! PolarExpress, classical Newton–Schulz, PRISM-3/PRISM-5, PRISM-DB-Newton);
//! the mapping itself now lives in [`Solver::for_backend`], and these
//! wrappers only add the optimizer conventions (damping for Shampoo, the
//! paper's Muon iteration budget and warm-α phase).
//!
//! ```
//! use prism::config::Backend;
//! use prism::optim::matfn::PolarBackend;
//! use prism::{randmat, Rng};
//!
//! let mut rng = Rng::seed_from(1);
//! let g = randmat::gaussian(&mut rng, 32, 16);
//! let mut polar = PolarBackend::paper_muon(Backend::Prism5);
//! let q = polar.polar(&g, &mut rng);        // same-shape calls reuse buffers
//! assert_eq!(q.shape(), (32, 16));
//! ```

use crate::config::Backend;
use crate::linalg::{cholesky, Mat};
use crate::matfn::rect::resolve_route;
use crate::matfn::{MatFnTask, RectStrategy, Solver};
use crate::rng::Rng;
use crate::util::Result;

/// Polar-factor backend (Muon's orthogonalization step). Owns a persistent
/// square [`Solver`] plus a `RectPolar` twin: rectangular momenta whose
/// resolved [`RectStrategy`] route is not Direct go through the cheap
/// Gram/range-finder path, everything else (squares, near-squares) stays on
/// the square solver — bit-identical to the pre-rect behaviour, warm-α
/// phase included. Per-step calls on same-shaped momentum matrices run
/// allocation-free after the first.
pub struct PolarBackend {
    solver: Solver,
    /// `RectPolar` twin; `None` for PolarExpress, whose Remez schedule has
    /// no rect form — substituting PRISM under a "pe" baseline label would
    /// silently change the Fig. 6 comparison, so PE always solves direct.
    rect: Option<Solver>,
    strategy: RectStrategy,
}

impl PolarBackend {
    pub fn new(backend: Backend, iters: usize) -> Self {
        let solver = Solver::for_backend(backend, MatFnTask::Polar, iters)
            .expect("every Backend has a polar form");
        let rect = if backend == Backend::PolarExpress {
            None
        } else {
            Some(
                Solver::for_backend(backend, MatFnTask::RectPolar, iters)
                    .expect("every non-PE Backend has a rectpolar form"),
            )
        };
        PolarBackend { solver, rect, strategy: RectStrategy::Auto }
    }

    /// The paper's Muon configuration: 5 iterations for PolarExpress and
    /// PRISM-3, 3 iterations for PRISM-5; α pinned at the interval's upper
    /// bound for the first 3 (the §C warm-start trick).
    pub fn paper_muon(backend: Backend) -> Self {
        let iters = match backend {
            Backend::Prism5 => 3,
            _ => 5,
        };
        let mut b = Self::new(backend, iters);
        b.solver.spec_mut().warm_iters = 3;
        b
    }

    /// Select the rectangular route (default [`RectStrategy::Auto`]).
    pub fn set_rect_strategy(&mut self, strategy: RectStrategy) {
        self.strategy = strategy;
        if let Some(r) = self.rect.as_mut() {
            r.spec_mut().rect = strategy;
        }
    }

    pub fn name(&self) -> String {
        self.solver.name()
    }

    /// Total workspace misses across both solvers; flat across two
    /// same-shape [`PolarBackend::polar_into`] calls ⇔ the second ran
    /// allocation-free.
    pub fn workspace_allocations(&self) -> usize {
        self.solver.workspace_allocations()
            + self.rect.as_ref().map_or(0, |r| r.workspace_allocations())
    }

    /// Route to the rect solver only when that changes the algorithm: a
    /// Direct-resolved shape on the rect solver would run the same
    /// iteration minus the warm-α phase, so it stays on the square solver.
    fn use_rect(&self, m: usize, n: usize) -> bool {
        self.rect.is_some()
            && m != n
            && resolve_route(self.strategy, m, n) != RectStrategy::Direct
    }

    /// Orthogonalize `g` (any orientation). Allocates the result; the
    /// optimizer hot loop uses [`PolarBackend::polar_into`] instead.
    pub fn polar(&mut self, g: &Mat, rng: &mut Rng) -> Mat {
        let (m, n) = g.shape();
        if self.use_rect(m, n) {
            self.rect.as_mut().expect("use_rect checked").solve(g, rng).primary
        } else {
            self.solver.solve(g, rng).primary
        }
    }

    /// Orthogonalize `g` into a caller-held persistent buffer (resized to
    /// match `g`). With `out` reused across steps, the per-layer polar call
    /// stops minting a fresh `Mat` every optimizer step — the warm-path
    /// contract the Muon tests assert via [`workspace_allocations`].
    ///
    /// [`workspace_allocations`]: PolarBackend::workspace_allocations
    pub fn polar_into(&mut self, g: &Mat, out: &mut Mat, rng: &mut Rng) {
        let q = self.polar(g, rng);
        out.copy_from(&q);
    }
}

/// Inverse-root backend (Shampoo's `A^{-1/2}` with damping). Owns a
/// persistent [`Solver`] plus a damping scratch buffer.
pub struct InvRootBackend {
    solver: Solver,
    damped: Mat,
}

impl InvRootBackend {
    pub fn new(backend: Backend, iters: usize) -> Self {
        let solver = Solver::for_backend(backend, MatFnTask::InvSqrt, iters)
            .expect("every Backend has an inverse-sqrt form");
        InvRootBackend { solver, damped: Mat::zeros(0, 0) }
    }

    pub fn name(&self) -> String {
        self.solver.name()
    }

    /// `(A + εI)^{-1/2}` for symmetric PSD `A`.
    pub fn inv_sqrt(&mut self, a: &Mat, eps: f64, rng: &mut Rng) -> Mat {
        self.damped.copy_from(a);
        self.damped.add_diag(eps);
        self.solver.solve(&self.damped, rng).primary
    }

    /// [`InvRootBackend::inv_sqrt`] with the damping validated against the
    /// spectrum: rejects a non-finite or negative `eps`, and probes
    /// `A + εI` with a Cholesky factorization before iterating — the tiny
    /// p×p Gram matrices of low-rank updates can be exactly singular, and
    /// an inverse-root iteration on a rank-deficient operand spins to
    /// `max_iters` producing garbage that only fails far downstream.
    /// Returns the typed [`crate::util::Error::Numerical`] at the boundary
    /// instead; the probe costs n³/3 flops against the ~10n³ of a typical
    /// converged solve.
    pub fn try_inv_sqrt(&mut self, a: &Mat, eps: f64, rng: &mut Rng) -> Result<Mat> {
        if !eps.is_finite() || eps < 0.0 {
            return Err(crate::numerical_err!(
                "inv_sqrt: damping eps {eps:e} must be finite and >= 0"
            ));
        }
        self.damped.copy_from(a);
        self.damped.add_diag(eps);
        if let Err(e) = cholesky(&self.damped) {
            return Err(crate::numerical_err!(
                "inv_sqrt: damped operand {}x{} is not positive definite at eps={eps:.3e} — \
                 rank-deficient Gram matrix? raise the damping ({e})",
                a.rows(),
                a.cols()
            ));
        }
        self.solver.try_solve(&self.damped, rng).map(|out| out.primary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b};
    use crate::randmat;

    #[test]
    fn all_polar_backends_orthogonalize() {
        let mut rng = Rng::seed_from(1);
        let s = randmat::logspace(1e-2, 1.0, 12);
        let a = randmat::with_spectrum(&mut rng, 20, 12, &s);
        for b in [
            Backend::Eigen,
            Backend::PolarExpress,
            Backend::NewtonSchulz,
            Backend::Prism3,
            Backend::Prism5,
        ] {
            let mut pb = PolarBackend::new(b, 30);
            let q = pb.polar(&a, &mut rng);
            let err = matmul_at_b(&q, &q).sub(&Mat::eye(12)).max_abs();
            assert!(err < 1e-4, "{}: err={err}", pb.name());
        }
    }

    #[test]
    fn truncated_iters_still_improve() {
        // With the paper's few-iteration budget the result need not be fully
        // orthogonal but must be much closer than the raw input.
        let mut rng = Rng::seed_from(2);
        let s = randmat::logspace(1e-3, 1.0, 16);
        let a = randmat::with_spectrum(&mut rng, 24, 16, &s);
        let before = crate::prism::polar::orthogonality_error(&a.scaled(1.0 / a.fro_norm()));
        // Degree-3 with only 5 iterations makes slower progress on a 1e-3
        // spectrum (σ roughly doubles per iteration) — the paper still runs
        // it this way inside Muon; require commensurate improvements.
        for (b, factor) in [
            (Backend::PolarExpress, 0.5),
            (Backend::Prism3, 0.85),
            // PRISM-5 gets just 3 iterations in the paper's Muon setup.
            (Backend::Prism5, 0.85),
        ] {
            let mut pb = PolarBackend::paper_muon(b);
            let q = pb.polar(&a, &mut rng);
            let after = crate::prism::polar::orthogonality_error(&q);
            assert!(after < factor * before, "{}: {before} -> {after}", pb.name());
        }
    }

    #[test]
    fn all_invroot_backends_work() {
        let mut rng = Rng::seed_from(3);
        let w = randmat::logspace(1e-3, 1.0, 10);
        let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
        for b in [
            Backend::Eigen,
            Backend::PolarExpress,
            Backend::NewtonSchulz,
            Backend::Prism5,
            Backend::PrismNewton,
        ] {
            let mut ib = InvRootBackend::new(b, 60);
            let is = ib.inv_sqrt(&a, 0.0, &mut rng);
            let prod = matmul(&matmul(&is, &a), &is);
            let err = prod.sub(&Mat::eye(10)).max_abs();
            assert!(err < 1e-3, "{}: err={err}", ib.name());
        }
    }

    #[test]
    fn damping_keeps_singular_input_finite() {
        let mut rng = Rng::seed_from(4);
        let g = Mat::gaussian(&mut rng, 12, 3, 1.0);
        let a = crate::linalg::gemm::syrk_a_at(&g); // rank 3 of 12
        for b in [Backend::Eigen, Backend::Prism5, Backend::PrismNewton] {
            let mut ib = InvRootBackend::new(b, 60);
            let is = ib.inv_sqrt(&a, 1e-4, &mut rng);
            assert!(!is.has_non_finite(), "{}", ib.name());
        }
    }

    #[test]
    fn repeated_backend_calls_are_allocation_free() {
        let mut rng = Rng::seed_from(5);
        let mut pb = PolarBackend::new(Backend::Prism5, 20);
        let a = randmat::gaussian(&mut rng, 24, 12);
        let _ = pb.polar(&a, &mut rng);
        let allocs = pb.solver.workspace_allocations();
        let _ = pb.polar(&a, &mut rng);
        let _ = pb.polar(&a, &mut rng);
        assert_eq!(pb.solver.workspace_allocations(), allocs);
    }

    #[test]
    fn rect_shapes_orthogonalize_through_every_backend() {
        // Aspect 4 resolves to the Gram route under Auto for the backends
        // that carry a rect solver; PolarExpress solves direct. Either way
        // the result must be (near-)orthogonal in both orientations.
        let mut rng = Rng::seed_from(6);
        let s = randmat::logspace(1e-1, 1.0, 12);
        let tall = randmat::with_spectrum(&mut rng, 48, 12, &s);
        let wide = tall.transpose();
        for b in [
            Backend::Eigen,
            Backend::PolarExpress,
            Backend::NewtonSchulz,
            Backend::Prism3,
            Backend::Prism5,
        ] {
            for a in [&tall, &wide] {
                let mut pb = PolarBackend::new(b, 60);
                let q = pb.polar(a, &mut rng);
                assert_eq!(q.shape(), a.shape());
                let err = crate::prism::polar::orthogonality_error(&q);
                assert!(err < 1e-4, "{} {:?}: err={err}", pb.name(), a.shape());
            }
        }
    }

    #[test]
    fn polar_into_matches_polar_and_reuses_buffers() {
        let s = randmat::logspace(1e-1, 1.0, 10);
        let a = randmat::with_spectrum(&mut Rng::seed_from(7), 40, 10, &s);
        // Same entry RNG state ⇒ identical result through either surface.
        let mut pb = PolarBackend::new(Backend::Prism5, 40);
        let by_value = pb.polar(&a, &mut Rng::seed_from(8));
        let mut pb2 = PolarBackend::new(Backend::Prism5, 40);
        let mut out = Mat::zeros(0, 0);
        pb2.polar_into(&a, &mut out, &mut Rng::seed_from(8));
        assert_eq!(out, by_value);
        // Warm calls into the persistent buffer stay allocation-free.
        let allocs = pb2.workspace_allocations();
        assert!(allocs > 0);
        for _ in 0..3 {
            pb2.polar_into(&a, &mut out, &mut Rng::seed_from(8));
        }
        assert_eq!(pb2.workspace_allocations(), allocs);
    }

    #[test]
    fn forced_direct_strategy_keeps_rect_shapes_on_the_square_solver() {
        let mut rng = Rng::seed_from(9);
        let a = randmat::gaussian(&mut rng, 48, 12);
        let mut forced = PolarBackend::new(Backend::Prism5, 30);
        forced.set_rect_strategy(crate::matfn::RectStrategy::Direct);
        let mut plain = PolarBackend::new(Backend::Prism5, 30);
        let qf = forced.polar(&a, &mut Rng::seed_from(10));
        // Under Direct the rect solver is bypassed entirely, so the result
        // is bit-identical to the square solver's.
        let qp = plain.solver.solve(&a, &mut Rng::seed_from(10)).primary;
        assert_eq!(qf, qp);
    }

    #[test]
    fn try_inv_sqrt_rejects_rank_deficient_gram_and_bad_eps() {
        let mut rng = Rng::seed_from(11);
        let g = Mat::gaussian(&mut rng, 12, 3, 1.0);
        let a = crate::linalg::gemm::syrk_a_at(&g); // rank 3 of 12: singular
        let mut ib = InvRootBackend::new(Backend::Prism5, 60);
        let err = ib.try_inv_sqrt(&a, 0.0, &mut rng).unwrap_err();
        assert!(matches!(err, crate::util::Error::Numerical(_)), "{err}");
        assert!(err.to_string().contains("positive definite"), "{err}");
        for bad_eps in [f64::NAN, f64::INFINITY, -1e-3] {
            assert!(ib.try_inv_sqrt(&a, bad_eps, &mut rng).is_err(), "eps={bad_eps}");
        }
        // Adequate damping restores the SPD contract and the solve runs.
        let is = ib.try_inv_sqrt(&a, 1e-4, &mut rng).unwrap();
        assert!(!is.has_non_finite());
    }
}
