//! Pluggable matrix-function backends for the optimizers.
//!
//! Muon needs a **polar** backend (orthogonalize the momentum matrix);
//! Shampoo needs an **inverse-root** backend (precondition with `L^{-1/2}`,
//! `R^{-1/2}`). Each backend maps to one algorithm compared in the paper's
//! Figs. 5–6: exact eigendecomposition, PolarExpress, classical
//! Newton–Schulz, PRISM-3/PRISM-5, or PRISM-DB-Newton.

use crate::baselines::eigen_fn;
use crate::baselines::polar_express::PolarExpress;
use crate::config::Backend;
use crate::linalg::Mat;
use crate::prism::db_newton::{db_newton_prism, DbNewtonOpts};
use crate::prism::driver::{AlphaMode, StopRule};
use crate::prism::polar::{polar_prism, PolarOpts};
use crate::prism::sqrt::{sqrt_prism, SqrtOpts};
use crate::rng::Rng;

/// Polar-factor backend (Muon's orthogonalization step).
pub struct PolarBackend {
    backend: Backend,
    iters: usize,
    pe: Option<PolarExpress>,
    /// Muon warm-start (paper §C): pin α at the interval's upper bound for
    /// the first `warm_iters` iterations instead of fitting.
    pub warm_iters: usize,
}

impl PolarBackend {
    pub fn new(backend: Backend, iters: usize) -> Self {
        let pe = if backend == Backend::PolarExpress {
            Some(PolarExpress::paper_default())
        } else {
            None
        };
        PolarBackend { backend, iters, pe, warm_iters: 0 }
    }

    /// The paper's Muon configuration: 5 iterations for PolarExpress and
    /// PRISM-3, 3 iterations for PRISM-5; α pinned high for the first 3.
    pub fn paper_muon(backend: Backend) -> Self {
        let iters = match backend {
            Backend::Prism5 => 3,
            _ => 5,
        };
        let mut b = Self::new(backend, iters);
        b.warm_iters = 3;
        b
    }

    pub fn name(&self) -> &'static str {
        self.backend.name()
    }

    /// Orthogonalize `g` (any orientation).
    pub fn polar(&self, g: &Mat, rng: &mut Rng) -> Mat {
        let stop = StopRule {
            max_iters: self.iters,
            tol: 1e-7,
            diverge_above: 1e12,
        };
        match self.backend {
            Backend::Eigen => eigen_fn::polar_eigen(g),
            Backend::PolarExpress => self.pe.as_ref().unwrap().polar(g, &stop).0,
            Backend::NewtonSchulz => {
                polar_prism(g, &PolarOpts::classic(2).with_stop(stop), rng).q
            }
            Backend::Prism3 | Backend::Prism5 => {
                let d = if self.backend == Backend::Prism3 { 1 } else { 2 };
                let (_, hi) = crate::coeffs::alpha_interval(d);
                if self.warm_iters > 0 && self.warm_iters < self.iters {
                    // Warm phase: α pinned at the upper bound (no fit cost),
                    // then fitted for the remaining iterations.
                    let warm_stop = StopRule { max_iters: self.warm_iters, ..stop };
                    let opts =
                        PolarOpts { d, alpha: AlphaMode::Fixed(hi), stop: warm_stop };
                    let warm = polar_prism(g, &opts, rng);
                    let rest = StopRule { max_iters: self.iters - self.warm_iters, ..stop };
                    let opts2 = PolarOpts {
                        d,
                        alpha: AlphaMode::Sketched { p: 8 },
                        stop: rest,
                    };
                    polar_prism(&warm.q, &opts2, rng).q
                } else if self.warm_iters >= self.iters {
                    let opts = PolarOpts { d, alpha: AlphaMode::Fixed(hi), stop };
                    polar_prism(g, &opts, rng).q
                } else {
                    let opts =
                        PolarOpts { d, alpha: AlphaMode::Sketched { p: 8 }, stop };
                    polar_prism(g, &opts, rng).q
                }
            }
            Backend::PrismNewton => {
                // Polar via sign-like Newton is out of scope; fall back to
                // PRISM-5 which shares the orthogonalization role.
                let opts = PolarOpts { d: 2, alpha: AlphaMode::Sketched { p: 8 }, stop };
                polar_prism(g, &opts, rng).q
            }
        }
    }
}

/// Inverse-root backend (Shampoo's `A^{-1/2}` with damping).
pub struct InvRootBackend {
    backend: Backend,
    iters: usize,
    pe: Option<PolarExpress>,
}

impl InvRootBackend {
    pub fn new(backend: Backend, iters: usize) -> Self {
        let pe = if backend == Backend::PolarExpress {
            // Coupled square-root form: the σ_min = 1e-3 polar tuning becomes
            // an eigenvalue-min 1e-6 tuning (paper Fig. 1 caption).
            Some(PolarExpress::paper_default())
        } else {
            None
        };
        InvRootBackend { backend, iters, pe }
    }

    pub fn name(&self) -> &'static str {
        self.backend.name()
    }

    /// `(A + εI)^{-1/2}` for symmetric PSD `A`.
    pub fn inv_sqrt(&self, a: &Mat, eps: f64, rng: &mut Rng) -> Mat {
        let mut ad = a.clone();
        ad.add_diag(eps);
        let stop = StopRule { max_iters: self.iters, tol: 1e-9, diverge_above: 1e12 };
        match self.backend {
            Backend::Eigen => eigen_fn::inv_sqrt_eigen(a, eps),
            Backend::PolarExpress => self.pe.as_ref().unwrap().sqrt_coupled(&ad, &stop).1,
            Backend::NewtonSchulz => {
                sqrt_prism(&ad, &SqrtOpts::classic(2).with_stop(stop), rng).inv_sqrt
            }
            Backend::Prism3 => {
                let opts = SqrtOpts { d: 1, alpha: AlphaMode::Sketched { p: 8 }, stop };
                sqrt_prism(&ad, &opts, rng).inv_sqrt
            }
            Backend::Prism5 => {
                let opts = SqrtOpts { d: 2, alpha: AlphaMode::Sketched { p: 8 }, stop };
                sqrt_prism(&ad, &opts, rng).inv_sqrt
            }
            Backend::PrismNewton => {
                db_newton_prism(&ad, &DbNewtonOpts::prism().with_stop(stop), rng).inv_sqrt
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b};
    use crate::randmat;

    #[test]
    fn all_polar_backends_orthogonalize() {
        let mut rng = Rng::seed_from(1);
        let s = randmat::logspace(1e-2, 1.0, 12);
        let a = randmat::with_spectrum(&mut rng, 20, 12, &s);
        for b in [
            Backend::Eigen,
            Backend::PolarExpress,
            Backend::NewtonSchulz,
            Backend::Prism3,
            Backend::Prism5,
        ] {
            let pb = PolarBackend::new(b, 30);
            let q = pb.polar(&a, &mut rng);
            let err = matmul_at_b(&q, &q).sub(&Mat::eye(12)).max_abs();
            assert!(err < 1e-4, "{}: err={err}", pb.name());
        }
    }

    #[test]
    fn truncated_iters_still_improve() {
        // With the paper's few-iteration budget the result need not be fully
        // orthogonal but must be much closer than the raw input.
        let mut rng = Rng::seed_from(2);
        let s = randmat::logspace(1e-3, 1.0, 16);
        let a = randmat::with_spectrum(&mut rng, 24, 16, &s);
        let before = crate::prism::polar::orthogonality_error(&a.scaled(1.0 / a.fro_norm()));
        // Degree-3 with only 5 iterations makes slower progress on a 1e-3
        // spectrum (σ roughly doubles per iteration) — the paper still runs
        // it this way inside Muon; require commensurate improvements.
        for (b, factor) in [
            (Backend::PolarExpress, 0.5),
            (Backend::Prism3, 0.85),
            // PRISM-5 gets just 3 iterations in the paper's Muon setup.
            (Backend::Prism5, 0.85),
        ] {
            let pb = PolarBackend::paper_muon(b);
            let q = pb.polar(&a, &mut rng);
            let after = crate::prism::polar::orthogonality_error(&q);
            assert!(after < factor * before, "{}: {before} -> {after}", pb.name());
        }
    }

    #[test]
    fn all_invroot_backends_work() {
        let mut rng = Rng::seed_from(3);
        let w = randmat::logspace(1e-3, 1.0, 10);
        let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
        for b in [
            Backend::Eigen,
            Backend::PolarExpress,
            Backend::NewtonSchulz,
            Backend::Prism5,
            Backend::PrismNewton,
        ] {
            let ib = InvRootBackend::new(b, 60);
            let is = ib.inv_sqrt(&a, 0.0, &mut rng);
            let prod = matmul(&matmul(&is, &a), &is);
            let err = prod.sub(&Mat::eye(10)).max_abs();
            assert!(err < 1e-3, "{}: err={err}", ib.name());
        }
    }

    #[test]
    fn damping_keeps_singular_input_finite() {
        let mut rng = Rng::seed_from(4);
        let g = Mat::gaussian(&mut rng, 12, 3, 1.0);
        let a = crate::linalg::gemm::syrk_a_at(&g); // rank 3 of 12
        for b in [Backend::Eigen, Backend::Prism5, Backend::PrismNewton] {
            let ib = InvRootBackend::new(b, 60);
            let is = ib.inv_sqrt(&a, 1e-4, &mut rng);
            assert!(!is.has_non_finite(), "{}", ib.name());
        }
    }
}
