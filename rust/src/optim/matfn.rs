//! Optimizer-facing matrix-function backends — thin wrappers over the
//! unified [`crate::matfn`] solver API.
//!
//! Muon needs a **polar** backend (orthogonalize the momentum matrix);
//! Shampoo needs an **inverse-root** backend (precondition with `L^{-1/2}`,
//! `R^{-1/2}`). Each [`crate::config::Backend`] value maps to one algorithm
//! compared in the paper's Figs. 5–6 (exact eigendecomposition,
//! PolarExpress, classical Newton–Schulz, PRISM-3/PRISM-5, PRISM-DB-Newton);
//! the mapping itself now lives in [`Solver::for_backend`], and these
//! wrappers only add the optimizer conventions (damping for Shampoo, the
//! paper's Muon iteration budget and warm-α phase).
//!
//! ```
//! use prism::config::Backend;
//! use prism::optim::matfn::PolarBackend;
//! use prism::{randmat, Rng};
//!
//! let mut rng = Rng::seed_from(1);
//! let g = randmat::gaussian(&mut rng, 32, 16);
//! let mut polar = PolarBackend::paper_muon(Backend::Prism5);
//! let q = polar.polar(&g, &mut rng);        // same-shape calls reuse buffers
//! assert_eq!(q.shape(), (32, 16));
//! ```

use crate::config::Backend;
use crate::linalg::Mat;
use crate::matfn::{MatFnTask, Solver};
use crate::rng::Rng;

/// Polar-factor backend (Muon's orthogonalization step). Owns a persistent
/// [`Solver`], so the per-step calls on same-shaped momentum matrices run
/// allocation-free after the first.
pub struct PolarBackend {
    solver: Solver,
}

impl PolarBackend {
    pub fn new(backend: Backend, iters: usize) -> Self {
        let solver = Solver::for_backend(backend, MatFnTask::Polar, iters)
            .expect("every Backend has a polar form");
        PolarBackend { solver }
    }

    /// The paper's Muon configuration: 5 iterations for PolarExpress and
    /// PRISM-3, 3 iterations for PRISM-5; α pinned at the interval's upper
    /// bound for the first 3 (the §C warm-start trick).
    pub fn paper_muon(backend: Backend) -> Self {
        let iters = match backend {
            Backend::Prism5 => 3,
            _ => 5,
        };
        let mut b = Self::new(backend, iters);
        b.solver.spec_mut().warm_iters = 3;
        b
    }

    pub fn name(&self) -> String {
        self.solver.name()
    }

    /// Orthogonalize `g` (any orientation).
    pub fn polar(&mut self, g: &Mat, rng: &mut Rng) -> Mat {
        self.solver.solve(g, rng).primary
    }
}

/// Inverse-root backend (Shampoo's `A^{-1/2}` with damping). Owns a
/// persistent [`Solver`] plus a damping scratch buffer.
pub struct InvRootBackend {
    solver: Solver,
    damped: Mat,
}

impl InvRootBackend {
    pub fn new(backend: Backend, iters: usize) -> Self {
        let solver = Solver::for_backend(backend, MatFnTask::InvSqrt, iters)
            .expect("every Backend has an inverse-sqrt form");
        InvRootBackend { solver, damped: Mat::zeros(0, 0) }
    }

    pub fn name(&self) -> String {
        self.solver.name()
    }

    /// `(A + εI)^{-1/2}` for symmetric PSD `A`.
    pub fn inv_sqrt(&mut self, a: &Mat, eps: f64, rng: &mut Rng) -> Mat {
        self.damped.copy_from(a);
        self.damped.add_diag(eps);
        self.solver.solve(&self.damped, rng).primary
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b};
    use crate::randmat;

    #[test]
    fn all_polar_backends_orthogonalize() {
        let mut rng = Rng::seed_from(1);
        let s = randmat::logspace(1e-2, 1.0, 12);
        let a = randmat::with_spectrum(&mut rng, 20, 12, &s);
        for b in [
            Backend::Eigen,
            Backend::PolarExpress,
            Backend::NewtonSchulz,
            Backend::Prism3,
            Backend::Prism5,
        ] {
            let mut pb = PolarBackend::new(b, 30);
            let q = pb.polar(&a, &mut rng);
            let err = matmul_at_b(&q, &q).sub(&Mat::eye(12)).max_abs();
            assert!(err < 1e-4, "{}: err={err}", pb.name());
        }
    }

    #[test]
    fn truncated_iters_still_improve() {
        // With the paper's few-iteration budget the result need not be fully
        // orthogonal but must be much closer than the raw input.
        let mut rng = Rng::seed_from(2);
        let s = randmat::logspace(1e-3, 1.0, 16);
        let a = randmat::with_spectrum(&mut rng, 24, 16, &s);
        let before = crate::prism::polar::orthogonality_error(&a.scaled(1.0 / a.fro_norm()));
        // Degree-3 with only 5 iterations makes slower progress on a 1e-3
        // spectrum (σ roughly doubles per iteration) — the paper still runs
        // it this way inside Muon; require commensurate improvements.
        for (b, factor) in [
            (Backend::PolarExpress, 0.5),
            (Backend::Prism3, 0.85),
            // PRISM-5 gets just 3 iterations in the paper's Muon setup.
            (Backend::Prism5, 0.85),
        ] {
            let mut pb = PolarBackend::paper_muon(b);
            let q = pb.polar(&a, &mut rng);
            let after = crate::prism::polar::orthogonality_error(&q);
            assert!(after < factor * before, "{}: {before} -> {after}", pb.name());
        }
    }

    #[test]
    fn all_invroot_backends_work() {
        let mut rng = Rng::seed_from(3);
        let w = randmat::logspace(1e-3, 1.0, 10);
        let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
        for b in [
            Backend::Eigen,
            Backend::PolarExpress,
            Backend::NewtonSchulz,
            Backend::Prism5,
            Backend::PrismNewton,
        ] {
            let mut ib = InvRootBackend::new(b, 60);
            let is = ib.inv_sqrt(&a, 0.0, &mut rng);
            let prod = matmul(&matmul(&is, &a), &is);
            let err = prod.sub(&Mat::eye(10)).max_abs();
            assert!(err < 1e-3, "{}: err={err}", ib.name());
        }
    }

    #[test]
    fn damping_keeps_singular_input_finite() {
        let mut rng = Rng::seed_from(4);
        let g = Mat::gaussian(&mut rng, 12, 3, 1.0);
        let a = crate::linalg::gemm::syrk_a_at(&g); // rank 3 of 12
        for b in [Backend::Eigen, Backend::Prism5, Backend::PrismNewton] {
            let mut ib = InvRootBackend::new(b, 60);
            let is = ib.inv_sqrt(&a, 1e-4, &mut rng);
            assert!(!is.has_non_finite(), "{}", ib.name());
        }
    }

    #[test]
    fn repeated_backend_calls_are_allocation_free() {
        let mut rng = Rng::seed_from(5);
        let mut pb = PolarBackend::new(Backend::Prism5, 20);
        let a = randmat::gaussian(&mut rng, 24, 12);
        let _ = pb.polar(&a, &mut rng);
        let allocs = pb.solver.workspace_allocations();
        let _ = pb.polar(&a, &mut rng);
        let _ = pb.polar(&a, &mut rng);
        assert_eq!(pb.solver.workspace_allocations(), allocs);
    }
}
