//! Learning-rate schedules.

/// Linear warmup followed by cosine decay to `min_frac · base`.
#[derive(Debug, Clone, Copy)]
pub struct WarmupCosine {
    pub base: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub min_frac: f64,
}

impl WarmupCosine {
    pub fn lr(&self, step: usize) -> f64 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base * (step + 1) as f64 / self.warmup_steps as f64;
        }
        let t = (step - self.warmup_steps) as f64
            / (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let t = t.min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
        self.base * (self.min_frac + (1.0 - self.min_frac) * cos)
    }
}

/// Step decay: multiply by `gamma` every `every` steps.
#[derive(Debug, Clone, Copy)]
pub struct StepDecay {
    pub base: f64,
    pub gamma: f64,
    pub every: usize,
}

impl StepDecay {
    pub fn lr(&self, step: usize) -> f64 {
        self.base * self.gamma.powi((step / self.every.max(1)) as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_then_decays() {
        let s = WarmupCosine { base: 1.0, warmup_steps: 10, total_steps: 110, min_frac: 0.1 };
        assert!(s.lr(0) < s.lr(5));
        assert!((s.lr(9) - 1.0).abs() < 1e-12);
        assert!(s.lr(50) < 1.0);
        assert!((s.lr(1000) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn step_decay_halves() {
        let s = StepDecay { base: 1.0, gamma: 0.5, every: 10 };
        assert_eq!(s.lr(0), 1.0);
        assert_eq!(s.lr(10), 0.5);
        assert_eq!(s.lr(25), 0.25);
    }
}
