//! AdamW (decoupled weight decay) — Fig. 6's baseline optimizer.

use super::Optimizer;
use crate::linalg::Mat;
use crate::nn::Param;

pub struct AdamW {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    t: u64,
    m: Vec<Mat>,
    v: Vec<Mat>,
}

impl AdamW {
    pub fn new(lr: f64, weight_decay: f64) -> AdamW {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    /// The paper's Fig. 6 AdamW hyper-parameters.
    pub fn paper_default() -> AdamW {
        AdamW::new(3e-4, 0.1)
    }
}

impl Optimizer for AdamW {
    fn step(&mut self, params: &mut [&mut Param]) {
        if self.m.is_empty() {
            self.m = params.iter().map(|p| Mat::zeros(p.w.rows(), p.w.cols())).collect();
            self.v = params.iter().map(|p| Mat::zeros(p.w.rows(), p.w.cols())).collect();
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for ((p, m), v) in params.iter_mut().zip(self.m.iter_mut()).zip(self.v.iter_mut()) {
            let gw = p.g.as_slice();
            let ms = m.as_mut_slice();
            let vs = v.as_mut_slice();
            let ws = p.w.as_mut_slice();
            for i in 0..gw.len() {
                ms[i] = self.beta1 * ms[i] + (1.0 - self.beta1) * gw[i];
                vs[i] = self.beta2 * vs[i] + (1.0 - self.beta2) * gw[i] * gw[i];
                let mhat = ms[i] / bc1;
                let vhat = vs[i] / bc2;
                ws[i] -= self.lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * ws[i]);
            }
        }
    }
    fn name(&self) -> String {
        format!("adamw(lr={})", self.lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_first_step_is_lr_sized() {
        let mut p = Param::matrix("w", Mat::zeros(1, 1));
        p.g[(0, 0)] = 0.5;
        let mut opt = AdamW::new(0.01, 0.0);
        opt.step(&mut [&mut p]);
        // First Adam step ≈ −lr · sign(g).
        assert!((p.w[(0, 0)] + 0.01).abs() < 1e-3);
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        // minimise f(w) = ½‖w − 3‖² with grad w − 3.
        let mut p = Param::matrix("w", Mat::zeros(1, 1));
        let mut opt = AdamW::new(0.1, 0.0);
        for _ in 0..500 {
            p.g[(0, 0)] = p.w[(0, 0)] - 3.0;
            opt.step(&mut [&mut p]);
        }
        assert!((p.w[(0, 0)] - 3.0).abs() < 0.05, "w={}", p.w[(0, 0)]);
    }

    #[test]
    fn decoupled_decay_without_grad() {
        let mut p = Param::matrix("w", Mat::eye(1));
        let mut opt = AdamW::new(0.1, 0.5);
        opt.step(&mut [&mut p]); // g = 0 ⇒ pure decay
        assert!(p.w[(0, 0)] < 1.0 && p.w[(0, 0)] > 0.9);
    }
}
