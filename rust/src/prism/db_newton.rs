//! DB-Newton (Denman–Beavers) product-form iteration for the matrix square
//! root (Table 1 row 6; paper §A.2 and Fig. D.5).
//!
//! `M₀ = Ā`, `X₀ = Ā`, `Y₀ = I` (Ā = A/‖A‖_F):
//! `M_{k+1} = 2α(1−α) I + (1−α)² M_k + α² M_k⁻¹`
//! `X_{k+1} = (1−α) X_k + α X_k M_k⁻¹`
//! `Y_{k+1} = (1−α) Y_k + α Y_k M_k⁻¹`
//!
//! with `X → Ā^{1/2}`, `Y → Ā^{-1/2}`. The PRISM coefficients here are
//! **exact and O(n²)** (no sketching needed — the traces involve only `M`,
//! `M²`, `M⁻¹`, `M⁻²` norms and the iteration computes `M⁻¹` anyway, via
//! Cholesky since `M_k` stays SPD). Classical DB-Newton fixes α = 1/2.

use super::driver::{AlphaMode, EngineHooks, IterationLog, RunRecorder, StopRule};
use crate::coeffs::db_newton_coeffs;
use crate::linalg::decomp::cholesky_inverse;
use crate::linalg::gemm::{global_engine, Workspace};
use crate::linalg::Mat;
use crate::polyfit::minimize_quartic;

#[derive(Debug, Clone)]
pub struct DbNewtonOpts {
    pub alpha: AlphaMode,
    pub stop: StopRule,
}

impl DbNewtonOpts {
    pub fn prism() -> Self {
        DbNewtonOpts { alpha: AlphaMode::Exact, stop: StopRule::default() }
    }
    pub fn classic() -> Self {
        DbNewtonOpts { alpha: AlphaMode::Classic, stop: StopRule::default() }
    }
    pub fn with_stop(mut self, stop: StopRule) -> Self {
        self.stop = stop;
        self
    }
}

pub struct DbNewtonResult {
    pub sqrt: Mat,
    pub inv_sqrt: Mat,
    pub log: IterationLog,
}

/// The α search interval. The Newton iteration is globally convergent so the
/// paper imposes no constraint; we use the natural convex-combination range.
const ALPHA_LO: f64 = 0.05;
const ALPHA_HI: f64 = 0.95;

/// Compute `A^{1/2}`, `A^{-1/2}` for SPD `A` with (PRISM-)DB-Newton.
///
/// Thin wrapper over [`db_newton_prism_in`] with a throwaway workspace;
/// persistent callers go through [`crate::matfn::Solver`].
pub fn db_newton_prism(a: &Mat, opts: &DbNewtonOpts, rng_unused: &mut crate::rng::Rng) -> DbNewtonResult {
    db_newton_prism_in(a, opts, rng_unused, &mut Workspace::new(), EngineHooks::none())
}

/// Workspace-pooled core. The product-form Newton iteration cannot resume
/// from `X` alone (the `(M, X, Y)` triple is coupled), so `hooks.x0` is
/// ignored. The per-iteration Cholesky inverse still allocates (it is a
/// decomposition, not a GEMM, and `M` changes every iteration).
pub(crate) fn db_newton_prism_in(
    a: &Mat,
    opts: &DbNewtonOpts,
    rng_unused: &mut crate::rng::Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> DbNewtonResult {
    let _ = rng_unused; // signature symmetry with the other engines
    assert!(a.is_square());
    let eng = global_engine();
    let n = a.rows();
    let c = a.fro_norm().max(1e-300);
    let mut m = ws.take(n, n);
    m.copy_from(a);
    m.scale(1.0 / c);
    m.symmetrize();
    let mut x = ws.take(n, n);
    x.copy_from(&m);
    let mut y = ws.take(n, n);
    y.fill_with(0.0);
    y.add_diag(1.0);

    // Ping-pong buffers from the pool.
    let mut xm = ws.take(n, n);
    let mut ym = ws.take(n, n);
    let mut xn = ws.take(n, n);
    let mut yn = ws.take(n, n);
    let mut mn = ws.take(n, n);

    let mut rec = RunRecorder::start(eye_minus_fro(&m))
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    for _ in 0..opts.stop.max_iters {
        if eye_minus_fro(&m) < opts.stop.tol {
            break;
        }
        // M⁻¹ via Cholesky (M stays SPD along the iteration).
        let m_inv = match cholesky_inverse(&m) {
            Ok(inv) => inv,
            Err(_) => break, // numerical breakdown: stop and report
        };
        let alpha = match opts.alpha {
            AlphaMode::Classic => 0.5,
            AlphaMode::Fixed(a) => a,
            // Exact O(n²) fit — `Sketched` falls back to the same exact path
            // because sketching cannot beat O(n²).
            AlphaMode::Exact
            | AlphaMode::Sketched { .. }
            | AlphaMode::SketchedKind { .. } => {
                let cfs = db_newton_coeffs(&m, &m_inv);
                minimize_quartic(&cfs, ALPHA_LO, ALPHA_HI)
                    .map(|(a, _)| a)
                    .unwrap_or(0.5)
            }
        };
        let one_m = 1.0 - alpha;
        // X ← (1−α)X + α X M⁻¹ ; Y likewise.
        eng.matmul_into(&mut xm, &x, &m_inv);
        eng.matmul_into(&mut ym, &y, &m_inv);
        xn.copy_from(&x);
        xn.scale(one_m);
        xn.axpy(alpha, &xm);
        std::mem::swap(&mut x, &mut xn);
        yn.copy_from(&y);
        yn.scale(one_m);
        yn.axpy(alpha, &ym);
        std::mem::swap(&mut y, &mut yn);
        // M ← 2α(1−α)I + (1−α)²M + α²M⁻¹
        mn.copy_from(&m);
        mn.scale(one_m * one_m);
        mn.axpy(alpha * alpha, &m_inv);
        mn.add_diag(2.0 * alpha * one_m);
        mn.symmetrize();
        std::mem::swap(&mut m, &mut mn);
        if rec.step_guard(&opts.stop, alpha, eye_minus_fro(&m)) {
            break;
        }
    }
    let sc = c.sqrt();
    let out = DbNewtonResult {
        sqrt: x.scaled(sc),
        inv_sqrt: y.scaled(1.0 / sc),
        log: rec.finish(&opts.stop),
    };
    ws.put(m);
    ws.put(x);
    ws.put(y);
    ws.put(xm);
    ws.put(ym);
    ws.put(xn);
    ws.put(yn);
    ws.put(mn);
    out
}

/// `‖I − M‖_F` without materialising the residual (same summation order as
/// `(−M + I).fro_norm()`, so the value is bit-identical to the old path).
fn eye_minus_fro(m: &Mat) -> f64 {
    let n = m.rows();
    let mut acc = 0.0;
    for i in 0..n {
        let row = m.row(i);
        for (j, &v) in row.iter().enumerate() {
            let e = if i == j { 1.0 - v } else { -v };
            acc += e * e;
        }
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::randmat;
    use crate::rng::Rng;

    fn spd(rng: &mut Rng, n: usize, wmin: f64) -> Mat {
        let w = randmat::logspace(wmin, 1.0, n);
        randmat::sym_with_spectrum(rng, n, &w)
    }

    #[test]
    fn classic_db_newton_sqrt() {
        let mut rng = Rng::seed_from(1);
        let a = spd(&mut rng, 10, 0.01);
        let out = db_newton_prism(&a, &DbNewtonOpts::classic(), &mut rng);
        assert!(out.log.converged, "res={}", out.log.final_residual());
        let back = matmul(&out.sqrt, &out.sqrt);
        assert!(back.sub(&a).max_abs() < 1e-6);
    }

    #[test]
    fn prism_db_newton_sqrt_and_invsqrt() {
        let mut rng = Rng::seed_from(2);
        let a = spd(&mut rng, 12, 1e-4);
        let stop = StopRule::default().with_max_iters(100);
        let out = db_newton_prism(&a, &DbNewtonOpts::prism().with_stop(stop), &mut rng);
        assert!(out.log.converged);
        let back = matmul(&out.sqrt, &out.sqrt);
        assert!(back.sub(&a).max_abs() < 1e-6);
        let prod = matmul(&out.sqrt, &out.inv_sqrt);
        assert!(prod.sub(&Mat::eye(12)).max_abs() < 1e-6);
    }

    #[test]
    fn prism_not_slower_than_classic() {
        // Fig. D.5: PRISM-Newton converges at least as fast as DB-Newton.
        let mut rng = Rng::seed_from(3);
        let a = spd(&mut rng, 16, 1e-6);
        let stop = StopRule::default().with_max_iters(200).with_tol(1e-8);
        let classic =
            db_newton_prism(&a, &DbNewtonOpts::classic().with_stop(stop), &mut rng);
        let prism = db_newton_prism(&a, &DbNewtonOpts::prism().with_stop(stop), &mut rng);
        assert!(classic.log.converged && prism.log.converged);
        let ic = classic.log.iters_to_tol(1e-8).unwrap();
        let ip = prism.log.iters_to_tol(1e-8).unwrap();
        assert!(ip <= ic, "prism {ip} vs classic {ic}");
    }

    #[test]
    fn newton_beats_newton_schulz_on_hard_spectrum() {
        // Fig. D.5's observation: Newton (rational) converges in far fewer
        // iterations than Newton–Schulz (polynomial) on hard spectra.
        use crate::prism::sqrt::{sqrt_prism, SqrtOpts};
        let mut rng = Rng::seed_from(4);
        let a = spd(&mut rng, 14, 1e-8);
        let stop = StopRule::default().with_max_iters(400).with_tol(1e-6);
        let ns = sqrt_prism(&a, &SqrtOpts::degree5().with_stop(stop), &mut rng);
        let nt = db_newton_prism(&a, &DbNewtonOpts::prism().with_stop(stop), &mut rng);
        assert!(ns.log.converged && nt.log.converged);
        assert!(
            nt.log.iters_to_tol(1e-6).unwrap() < ns.log.iters_to_tol(1e-6).unwrap(),
            "newton {} vs ns {}",
            nt.log.iters_to_tol(1e-6).unwrap(),
            ns.log.iters_to_tol(1e-6).unwrap()
        );
    }

    #[test]
    fn alphas_in_unit_interval() {
        let mut rng = Rng::seed_from(5);
        let a = spd(&mut rng, 8, 0.05);
        let out = db_newton_prism(&a, &DbNewtonOpts::prism(), &mut rng);
        for &al in &out.log.alphas {
            assert!((ALPHA_LO..=ALPHA_HI).contains(&al));
        }
    }
}
