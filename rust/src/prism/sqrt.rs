//! Coupled Newton–Schulz for the matrix square root and inverse square root
//! (Table 1 rows 1–2; Theorem 3 of the paper / Higham 1997).
//!
//! For SPD `A` (normalised to `Ā = A/‖A‖_F`):
//! `X₀ = Ā`, `Y₀ = I`, `R_k = I − X_k Y_k`,
//! `X_{k+1} = X_k g_d(R_k; α_k)`, `Y_{k+1} = g_d(R_k; α_k) Y_k`,
//! with `X → Ā^{1/2}`, `Y → Ā^{-1/2}`; results are rescaled by `√‖A‖_F`.
//!
//! This is exactly the primitive Shampoo needs for its `L^{-1/2}`, `R^{-1/2}`
//! preconditioner roots.

use super::driver::{AlphaMode, EngineHooks, IterationLog, RunRecorder, StopRule};
use super::fit::{select_alpha_ns, update_poly_into};
use crate::linalg::gemm::{global_engine, matmul, Workspace};
use crate::linalg::Mat;
use crate::rng::Rng;

#[derive(Debug, Clone)]
pub struct SqrtOpts {
    pub d: usize,
    pub alpha: AlphaMode,
    pub stop: StopRule,
}

impl SqrtOpts {
    pub fn degree3() -> Self {
        SqrtOpts { d: 1, alpha: AlphaMode::Sketched { p: 8 }, stop: StopRule::default() }
    }
    pub fn degree5() -> Self {
        SqrtOpts { d: 2, alpha: AlphaMode::Sketched { p: 8 }, stop: StopRule::default() }
    }
    pub fn classic(d: usize) -> Self {
        SqrtOpts { d, alpha: AlphaMode::Classic, stop: StopRule::default() }
    }
    pub fn with_stop(mut self, stop: StopRule) -> Self {
        self.stop = stop;
        self
    }
}

pub struct SqrtResult {
    /// `A^{1/2}`.
    pub sqrt: Mat,
    /// `A^{-1/2}`.
    pub inv_sqrt: Mat,
    pub log: IterationLog,
}

/// Compute `A^{1/2}` and `A^{-1/2}` for symmetric positive-definite `A`.
///
/// Thin wrapper over [`sqrt_prism_in`] with a throwaway workspace; persistent
/// callers go through [`crate::matfn::Solver`], which reuses one
/// [`Workspace`] across same-shape calls.
pub fn sqrt_prism(a: &Mat, opts: &SqrtOpts, rng: &mut Rng) -> SqrtResult {
    sqrt_prism_in(a, opts, rng, &mut Workspace::new(), EngineHooks::none())
}

/// Workspace-pooled core. The coupled iteration cannot warm-start from `X`
/// alone (`Y` must satisfy the coupling invariant), so `hooks.x0` is ignored.
pub(crate) fn sqrt_prism_in(
    a: &Mat,
    opts: &SqrtOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> SqrtResult {
    assert!(a.is_square(), "sqrt: square input required");
    let eng = global_engine();
    let n = a.rows();
    let c = a.fro_norm().max(1e-300);
    let mut x = ws.take(n, n);
    x.copy_from(a);
    x.scale(1.0 / c);
    let mut y = ws.take(n, n);
    y.fill_with(0.0);
    y.add_diag(1.0);

    // Ping-pong buffers from the pool — the loop is allocation-free, and so
    // is the whole call from the second same-shape solve onward.
    let mut xn = ws.take(n, n);
    let mut yn = ws.take(n, n);
    let mut g = ws.take(n, n);
    let mut r = ws.take(n, n);
    let mut r2 = if opts.d == 2 { Some(ws.take(n, n)) } else { None };

    // NOTE: the residual is `I − Y X` (inverse-root times root), NOT
    // `I − X Y`. In exact arithmetic they are equal (X and Y are commuting
    // polynomials in Ā), but the Y-first pairing is the one Higham (1997)
    // proves numerically *stable*; the X-first pairing slowly amplifies
    // rounding errors after convergence (observed: ×40/iteration blow-up).
    eng.matmul_into(&mut r, &y, &x);
    r.scale(-1.0);
    r.add_diag(1.0);
    r.symmetrize();

    let mut rec = RunRecorder::start(r.fro_norm())
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    for _ in 0..opts.stop.max_iters {
        if r.fro_norm() < opts.stop.tol {
            break;
        }
        let alpha = select_alpha_ns(&r, opts.d, opts.alpha, rng, &eng, ws);
        if let Some(r2buf) = r2.as_mut() {
            eng.matmul_into(r2buf, &r, &r);
        }
        update_poly_into(&mut g, &r, r2.as_ref(), opts.d, alpha, &eng, ws);
        eng.matmul_into(&mut xn, &x, &g);
        std::mem::swap(&mut x, &mut xn);
        eng.matmul_into(&mut yn, &g, &y);
        std::mem::swap(&mut y, &mut yn);
        eng.matmul_into(&mut r, &y, &x);
        r.scale(-1.0);
        r.add_diag(1.0);
        r.symmetrize();
        if rec.step_guard(&opts.stop, alpha, r.fro_norm()) {
            break;
        }
    }
    let sc = c.sqrt();
    let out = SqrtResult {
        sqrt: x.scaled(sc),
        inv_sqrt: y.scaled(1.0 / sc),
        log: rec.finish(&opts.stop),
    };
    ws.put(x);
    ws.put(y);
    ws.put(xn);
    ws.put(yn);
    ws.put(g);
    ws.put(r);
    if let Some(b) = r2 {
        ws.put(b);
    }
    out
}

/// The paper's Fig. D.3 error metric: `‖I − X⁻² A‖_F ≈ ‖I − Y² A‖_F`
/// evaluated with the inverse square root (avoids an explicit inverse).
pub fn sqrt_error(a: &Mat, inv_sqrt: &Mat) -> f64 {
    let mut e = matmul(&matmul(inv_sqrt, inv_sqrt), a).scaled(-1.0);
    e.add_diag(1.0);
    e.fro_norm()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::{gens, Prop};
    use crate::randmat;

    fn spd_with_cond(rng: &mut Rng, n: usize, wmin: f64) -> Mat {
        let w = randmat::logspace(wmin, 1.0, n);
        randmat::sym_with_spectrum(rng, n, &w)
    }

    #[test]
    fn sqrt_squares_back() {
        let mut rng = Rng::seed_from(1);
        let a = spd_with_cond(&mut rng, 16, 1e-2);
        for opts in [SqrtOpts::classic(1), SqrtOpts::degree3(), SqrtOpts::degree5()] {
            let out = sqrt_prism(&a, &opts, &mut rng);
            assert!(out.log.converged, "{}: res {}", opts.alpha.name(), out.log.final_residual());
            let back = matmul(&out.sqrt, &out.sqrt);
            assert!(back.sub(&a).max_abs() < 1e-6, "{}", opts.alpha.name());
        }
    }

    #[test]
    fn inv_sqrt_is_inverse_of_sqrt() {
        let mut rng = Rng::seed_from(2);
        let a = spd_with_cond(&mut rng, 12, 1e-3);
        let stop = StopRule::default().with_max_iters(150);
        let out = sqrt_prism(&a, &SqrtOpts::degree5().with_stop(stop), &mut rng);
        assert!(out.log.converged);
        let prod = matmul(&out.sqrt, &out.inv_sqrt);
        assert!(prod.sub(&Mat::eye(12)).max_abs() < 1e-6);
        assert!(sqrt_error(&a, &out.inv_sqrt) < 1e-5);
    }

    #[test]
    fn matches_eigen_sqrt() {
        let mut rng = Rng::seed_from(3);
        let a = spd_with_cond(&mut rng, 10, 0.05);
        let out = sqrt_prism(&a, &SqrtOpts::degree5(), &mut rng);
        let e = crate::linalg::eigen::symmetric_eigen(&a);
        let exact = e.apply_fn(|w| w.max(0.0).sqrt());
        assert!(out.sqrt.sub(&exact).max_abs() < 1e-6);
    }

    #[test]
    fn prism_fewer_iters_on_ill_conditioned() {
        let mut rng = Rng::seed_from(4);
        // eigenvalues spanning 1e-8..1 — singular values of the sign-embed
        // are 1e-4..1.
        let a = spd_with_cond(&mut rng, 20, 1e-8);
        let stop = StopRule::default().with_max_iters(300).with_tol(1e-6);
        let classic = sqrt_prism(&a, &SqrtOpts::classic(2).with_stop(stop), &mut rng);
        let prism = sqrt_prism(&a, &SqrtOpts::degree5().with_stop(stop), &mut rng);
        assert!(classic.log.converged && prism.log.converged);
        let (ic, ip) = (
            classic.log.iters_to_tol(1e-6).unwrap(),
            prism.log.iters_to_tol(1e-6).unwrap(),
        );
        assert!((ip as f64) <= 0.8 * ic as f64, "prism {ip} vs classic {ic}");
    }

    #[test]
    fn property_sqrt_roundtrip() {
        Prop::new("sqrt roundtrip").cases(6).run(|rng| {
            let n = gens::usize_in(rng, 4, 14);
            let wmin = gens::f64_log(rng, 1e-5, 0.5);
            let a = spd_with_cond(rng, n, wmin);
            let stop = StopRule::default().with_max_iters(200).with_tol(1e-8);
            let out = sqrt_prism(&a, &SqrtOpts::degree5().with_stop(stop), rng);
            assert!(out.log.converged, "wmin={wmin} res={}", out.log.final_residual());
            let back = matmul(&out.sqrt, &out.sqrt);
            let rel = back.sub(&a).fro_norm() / a.fro_norm();
            assert!(rel < 1e-5, "rel={rel}");
        });
    }

    #[test]
    fn identity_sqrt_is_identity() {
        let mut rng = Rng::seed_from(5);
        let out = sqrt_prism(&Mat::eye(6), &SqrtOpts::degree3(), &mut rng);
        assert!(out.sqrt.sub(&Mat::eye(6)).max_abs() < 1e-7);
        assert!(out.inv_sqrt.sub(&Mat::eye(6)).max_abs() < 1e-7);
    }
}
