//! Coupled inverse Newton iteration for `A^{-1/p}` (Table 1 row 5; paper
//! §A.3). This is the other inverse-root backend available to Shampoo
//! (`p = 2` gives `A^{-1/2}` directly, `p = 4` the 4th root used by
//! Shampoo's original formulation).
//!
//! `X₀ = (1/c) I`, `M₀ = A/cᵖ` with `c = (2‖A‖_F/(p+1))^{1/p}`;
//! `R_k = I − M_k`, `X_{k+1} = X_k (I + α_k R_k)`,
//! `M_{k+1} = (I + α_k R_k)ᵖ M_k`.
//! PRISM chooses `α_k` by minimising the sketched next-residual (degree-2p
//! polynomial in α); the classical iteration fixes `α = 1/p`.

use super::driver::{AlphaMode, EngineHooks, IterationLog, RunRecorder, StopRule};
use crate::coeffs::inverse_newton_coeffs;
use crate::linalg::gemm::{global_engine, GemmEngine, Workspace};
use crate::linalg::Mat;
use crate::polyfit::minimize_on_interval;
use crate::rng::Rng;
use crate::sketch::{exact_power_traces, with_sketched_traces, SketchKind};

#[derive(Debug, Clone)]
pub struct InvRootOpts {
    /// The root order p ≥ 1 (p=1 is the Newton–Schulz matrix inverse).
    pub p: usize,
    pub alpha: AlphaMode,
    pub stop: StopRule,
}

impl InvRootOpts {
    pub fn prism(p: usize) -> Self {
        InvRootOpts { p, alpha: AlphaMode::Sketched { p: 8 }, stop: StopRule::default() }
    }
    pub fn classic(p: usize) -> Self {
        InvRootOpts { p, alpha: AlphaMode::Classic, stop: StopRule::default() }
    }
    pub fn with_stop(mut self, stop: StopRule) -> Self {
        self.stop = stop;
        self
    }
}

pub struct InvRootResult {
    /// `A^{-1/p}`.
    pub inv_root: Mat,
    pub log: IterationLog,
}

/// α-constraint interval for root order p: the Taylor coefficient is `1/p`;
/// we allow up to 2× like the d=1 sign case ([ℓ, u] = [1/p, 2/p]).
pub fn alpha_interval_p(p: usize) -> (f64, f64) {
    (1.0 / p as f64, 2.0 / p as f64)
}

/// The sketched modes draw the sketch and trace scratch from `ws` and
/// propagate through `eng`'s skinny GEMM path — allocation-free when warm.
fn select_alpha(
    r: &Mat,
    p: usize,
    mode: AlphaMode,
    rng: &mut Rng,
    eng: &GemmEngine,
    ws: &mut Workspace,
) -> f64 {
    let (lo, hi) = alpha_interval_p(p);
    let fit = |t: &[f64]| {
        let c = inverse_newton_coeffs(t, p);
        minimize_on_interval(&c, lo, hi).map(|(a, _)| a).unwrap_or(1.0 / p as f64)
    };
    match mode {
        AlphaMode::Classic => 1.0 / p as f64,
        AlphaMode::Fixed(a) => a,
        AlphaMode::Exact => fit(&exact_power_traces(r, 2 * p + 2)),
        AlphaMode::Sketched { p: sk } => {
            with_sketched_traces(r, sk, SketchKind::Gaussian, 2 * p + 2, rng, eng, ws, fit)
        }
        AlphaMode::SketchedKind { p: sk, kind } => {
            with_sketched_traces(r, sk, kind, 2 * p + 2, rng, eng, ws, fit)
        }
    }
}

/// Compute `A^{-1/p}` for SPD `A`.
///
/// Thin wrapper over [`inv_root_prism_in`] with a throwaway workspace;
/// persistent callers go through [`crate::matfn::Solver`].
pub fn inv_root_prism(a: &Mat, opts: &InvRootOpts, rng: &mut Rng) -> InvRootResult {
    inv_root_prism_in(a, opts, rng, &mut Workspace::new(), EngineHooks::none())
}

/// Workspace-pooled core. `hooks.x0` warm-starts the coupled iteration at
/// `X₀ = x0` with `M₀ = X₀ᵖ A` — valid because every iterate is a commuting
/// polynomial in `A`, so passing the previous step's `A^{-1/p}` estimate for
/// a nearby `A` resumes with `M₀ ≈ I`.
pub(crate) fn inv_root_prism_in(
    a: &Mat,
    opts: &InvRootOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> InvRootResult {
    assert!(a.is_square());
    let p = opts.p;
    assert!(p >= 1);
    let eng = global_engine();
    let n = a.rows();
    let c = (2.0 * a.fro_norm() / (p as f64 + 1.0)).powf(1.0 / p as f64);
    let mut x = ws.take(n, n);
    let mut m = ws.take(n, n);

    // Ping-pong buffers from the pool — the loop is allocation-free, and so
    // is the whole call from the second same-shape solve onward.
    let mut xn = ws.take(n, n);
    let mut mn = ws.take(n, n);
    let mut g = ws.take(n, n);
    let mut r = ws.take(n, n);
    // G-power scratch, only needed for p ≥ 2.
    let (mut gp, mut gpn) = if p > 1 {
        (ws.take(n, n), ws.take(n, n))
    } else {
        (Mat::zeros(0, 0), Mat::zeros(0, 0))
    };

    match hooks.x0 {
        Some(x0) => {
            assert_eq!(x0.shape(), (n, n), "invroot: x0 shape mismatch");
            x.copy_from(x0);
            // M₀ = X₀ᵖ A.
            if p == 1 {
                eng.matmul_into(&mut m, &x, a);
            } else {
                gp.copy_from(&x);
                for _ in 1..p {
                    eng.matmul_into(&mut gpn, &gp, &x);
                    std::mem::swap(&mut gp, &mut gpn);
                }
                eng.matmul_into(&mut m, &gp, a);
            }
            m.symmetrize();
        }
        None => {
            x.fill_with(0.0);
            x.add_diag(1.0 / c);
            m.copy_from(a);
            m.scale(1.0 / c.powi(p as i32));
        }
    }

    r.copy_from(&m);
    r.scale(-1.0);
    r.add_diag(1.0);

    let mut rec = RunRecorder::start(r.fro_norm())
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    for _ in 0..opts.stop.max_iters {
        if r.fro_norm() < opts.stop.tol {
            break;
        }
        let alpha = select_alpha(&r, p, opts.alpha, rng, &eng, ws);
        // G = I + αR
        g.copy_from(&r);
        g.scale(alpha);
        g.add_diag(1.0);
        eng.matmul_into(&mut xn, &x, &g);
        std::mem::swap(&mut x, &mut xn);
        // M ← Gᵖ M  (p-1 extra multiplications; p is tiny)
        if p == 1 {
            eng.matmul_into(&mut mn, &g, &m);
        } else {
            gp.copy_from(&g);
            for _ in 1..p {
                eng.matmul_into(&mut gpn, &gp, &g);
                std::mem::swap(&mut gp, &mut gpn);
            }
            eng.matmul_into(&mut mn, &gp, &m);
        }
        std::mem::swap(&mut m, &mut mn);
        m.symmetrize();
        r.copy_from(&m);
        r.scale(-1.0);
        r.add_diag(1.0);
        if rec.step_guard(&opts.stop, alpha, r.fro_norm()) {
            break;
        }
    }
    let out = InvRootResult { inv_root: x.clone(), log: rec.finish(&opts.stop) };
    ws.put(x);
    ws.put(m);
    ws.put(xn);
    ws.put(mn);
    ws.put(g);
    ws.put(r);
    if p > 1 {
        ws.put(gp);
        ws.put(gpn);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::symmetric_eigen;
    use crate::linalg::gemm::matmul;
    use crate::randmat;

    fn spd(rng: &mut Rng, n: usize, wmin: f64) -> Mat {
        let w = randmat::logspace(wmin, 1.0, n);
        randmat::sym_with_spectrum(rng, n, &w)
    }

    #[test]
    fn p1_is_inverse() {
        let mut rng = Rng::seed_from(1);
        let a = spd(&mut rng, 10, 0.05);
        for opts in [InvRootOpts::classic(1), InvRootOpts::prism(1)] {
            let stop = StopRule::default().with_max_iters(200);
            let out = inv_root_prism(&a, &opts.with_stop(stop), &mut rng);
            assert!(out.log.converged, "res={}", out.log.final_residual());
            let prod = matmul(&a, &out.inv_root);
            assert!(prod.sub(&Mat::eye(10)).max_abs() < 1e-5);
        }
    }

    #[test]
    fn p2_is_inverse_sqrt() {
        let mut rng = Rng::seed_from(2);
        let a = spd(&mut rng, 12, 0.02);
        let stop = StopRule::default().with_max_iters(200);
        let out = inv_root_prism(&a, &InvRootOpts::prism(2).with_stop(stop), &mut rng);
        assert!(out.log.converged);
        let e = symmetric_eigen(&a);
        let exact = e.apply_fn(|w| 1.0 / w.sqrt());
        assert!(out.inv_root.sub(&exact).max_abs() < 1e-5);
    }

    #[test]
    fn p4_fourth_root() {
        let mut rng = Rng::seed_from(3);
        let a = spd(&mut rng, 8, 0.1);
        let stop = StopRule::default().with_max_iters(300);
        let out = inv_root_prism(&a, &InvRootOpts::prism(4).with_stop(stop), &mut rng);
        assert!(out.log.converged, "res={}", out.log.final_residual());
        // (A^{-1/4})⁴ A = I
        let x2 = matmul(&out.inv_root, &out.inv_root);
        let x4 = matmul(&x2, &x2);
        let prod = matmul(&x4, &a);
        assert!(prod.sub(&Mat::eye(8)).max_abs() < 1e-4);
    }

    #[test]
    fn prism_at_least_as_fast_as_classic() {
        let mut rng = Rng::seed_from(4);
        let a = spd(&mut rng, 16, 1e-4);
        let stop = StopRule::default().with_max_iters(400).with_tol(1e-6);
        let classic = inv_root_prism(&a, &InvRootOpts::classic(2).with_stop(stop), &mut rng);
        let prism = inv_root_prism(&a, &InvRootOpts::prism(2).with_stop(stop), &mut rng);
        assert!(classic.log.converged && prism.log.converged);
        let ic = classic.log.iters_to_tol(1e-6).unwrap();
        let ip = prism.log.iters_to_tol(1e-6).unwrap();
        assert!(ip <= ic, "prism {ip} vs classic {ic}");
    }

    #[test]
    fn alphas_in_interval() {
        let mut rng = Rng::seed_from(5);
        let a = spd(&mut rng, 10, 0.01);
        let out = inv_root_prism(&a, &InvRootOpts::prism(2), &mut rng);
        let (lo, hi) = alpha_interval_p(2);
        for &al in &out.log.alphas {
            assert!((lo - 1e-12..=hi + 1e-12).contains(&al), "α={al}");
        }
    }
}
