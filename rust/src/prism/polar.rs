//! Newton–Schulz orthogonalization (polar factor `U Vᵀ`) — Table 1 rows 3–4.
//!
//! For `A = U Σ Vᵀ` (m ≥ n), the iteration
//! `X₀ = A/‖A‖_F`, `R_k = I − X_kᵀX_k`, `X_{k+1} = X_k g_d(R_k; α_k)`
//! converges to the polar factor; PRISM chooses `α_k` by the sketched fit.
//! This is the primitive inside Muon and the subject of Figs. 1, 3, 4,
//! D.1, D.2.

use super::driver::{AlphaMode, EngineHooks, IterationLog, RunRecorder, StopRule};
use super::fit::{select_alpha_ns, update_poly_into};
use crate::linalg::gemm::{global_engine, syrk_at_a, Workspace};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Options for a polar run.
#[derive(Debug, Clone)]
pub struct PolarOpts {
    /// Update degree d: 1 → 3rd-order iteration, 2 → 5th-order.
    pub d: usize,
    pub alpha: AlphaMode,
    pub stop: StopRule,
}

impl PolarOpts {
    /// PRISM degree-3 (paper "PRISM-3"), sketch p = 8.
    pub fn degree3() -> Self {
        PolarOpts { d: 1, alpha: AlphaMode::Sketched { p: 8 }, stop: StopRule::default() }
    }
    /// PRISM degree-5 (paper "PRISM-5"), sketch p = 8.
    pub fn degree5() -> Self {
        PolarOpts { d: 2, alpha: AlphaMode::Sketched { p: 8 }, stop: StopRule::default() }
    }
    /// Classical Newton–Schulz of the same order.
    pub fn classic(d: usize) -> Self {
        PolarOpts { d, alpha: AlphaMode::Classic, stop: StopRule::default() }
    }
    pub fn with_stop(mut self, stop: StopRule) -> Self {
        self.stop = stop;
        self
    }
    pub fn with_alpha(mut self, alpha: AlphaMode) -> Self {
        self.alpha = alpha;
        self
    }
}

/// Result of a polar run.
pub struct PolarResult {
    /// Approximate polar factor (same shape as the input).
    pub q: Mat,
    pub log: IterationLog,
    /// Whether the input was transposed internally (m < n).
    pub transposed: bool,
}

/// Compute the polar factor of `A` with PRISM/classic Newton–Schulz.
///
/// Handles both orientations; tall (m ≥ n) is the native case. Thin wrapper
/// over [`polar_prism_in`] with a throwaway workspace; persistent callers go
/// through [`crate::matfn::Solver`].
pub fn polar_prism(a: &Mat, opts: &PolarOpts, rng: &mut Rng) -> PolarResult {
    polar_prism_in(a, opts, rng, &mut Workspace::new(), EngineHooks::none())
}

/// Workspace-pooled core. `hooks.x0` warm-starts the iteration at `X₀ = x0`
/// (paper §C — pass the previous step's polar factor when orthogonalizing a
/// slowly-drifting matrix); the caller guarantees `‖x0‖₂ ≲ 1`.
pub(crate) fn polar_prism_in(
    a: &Mat,
    opts: &PolarOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> PolarResult {
    let (m, n) = a.shape();
    if m < n {
        let EngineHooks { x0, observer, event_base, job } = hooks;
        let mut at = ws.take(n, m);
        a.transpose_into(&mut at);
        let x0t = x0.map(|x0| {
            assert_eq!(x0.shape(), (m, n), "polar: x0 shape mismatch");
            let mut t = ws.take(n, m);
            x0.transpose_into(&mut t);
            t
        });
        // The `match` re-coerces the observer's trait-object lifetime for
        // the shorter-lived recursive hooks (Option's variance cannot).
        let hooks_t = EngineHooks {
            x0: x0t.as_ref(),
            observer: match observer {
                Some(o) => Some(o),
                None => None,
            },
            event_base,
            job,
        };
        let r = polar_prism_in(&at, opts, rng, ws, hooks_t);
        ws.put(at);
        if let Some(t) = x0t {
            ws.put(t);
        }
        return PolarResult { q: r.q.transpose(), log: r.log, transposed: true };
    }
    let eng = global_engine();
    let mut x = ws.take(m, n);
    match hooks.x0 {
        Some(x0) => {
            assert_eq!(x0.shape(), (m, n), "polar: x0 shape mismatch");
            x.copy_from(x0);
        }
        None => {
            x.copy_from(a);
            x.scale(1.0 / a.fro_norm().max(1e-300));
        }
    }

    // Ping-pong buffers from the pool: the loop below is allocation-free —
    // including the α fit's sketch draw and trace propagation, which ride
    // the same pool — and so is the whole call from the second same-shape
    // solve onward.
    let mut xn = ws.take(m, n);
    let mut g = ws.take(n, n);
    let mut r = ws.take(n, n);
    let mut r2 = if opts.d == 2 { Some(ws.take(n, n)) } else { None };

    // R = I − XᵀX.
    eng.syrk_at_a_into(&mut r, &x);
    r.scale(-1.0);
    r.add_diag(1.0);

    let mut rec = RunRecorder::start(r.fro_norm())
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    for _ in 0..opts.stop.max_iters {
        if r.fro_norm() < opts.stop.tol {
            break;
        }
        let alpha = select_alpha_ns(&r, opts.d, opts.alpha, rng, &eng, ws);
        if let Some(r2buf) = r2.as_mut() {
            eng.matmul_into(r2buf, &r, &r);
        }
        update_poly_into(&mut g, &r, r2.as_ref(), opts.d, alpha, &eng, ws);
        eng.matmul_into(&mut xn, &x, &g);
        std::mem::swap(&mut x, &mut xn);
        eng.syrk_at_a_into(&mut r, &x);
        r.scale(-1.0);
        r.add_diag(1.0);
        if rec.step_guard(&opts.stop, alpha, r.fro_norm()) {
            break;
        }
    }
    let out = PolarResult { q: x.clone(), log: rec.finish(&opts.stop), transposed: false };
    ws.put(x);
    ws.put(xn);
    ws.put(g);
    ws.put(r);
    if let Some(b) = r2 {
        ws.put(b);
    }
    out
}

/// Orthogonality error ‖I − QᵀQ‖_F of a candidate polar factor.
pub fn orthogonality_error(q: &Mat) -> f64 {
    let (m, n) = q.shape();
    let g = if m >= n { syrk_at_a(q) } else { crate::linalg::gemm::syrk_a_at(q) };
    let k = g.rows();
    let mut r = g.scaled(-1.0);
    r.add_diag(1.0);
    let _ = k;
    r.fro_norm()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;
    use crate::ptest::{gens, Prop};
    use crate::randmat;

    fn check_polar(a: &Mat, opts: &PolarOpts, tol: f64, rng: &mut Rng) -> IterationLog {
        let out = polar_prism(a, opts, rng);
        assert!(out.log.converged, "{}: residual {}", opts.alpha.name(), out.log.final_residual());
        // Compare against the exact polar factor from SVD.
        let (m, n) = a.shape();
        let exact = if m >= n {
            svd(a).polar_factor()
        } else {
            svd(&a.transpose()).polar_factor().transpose()
        };
        let err = out.q.sub(&exact).max_abs();
        assert!(err < tol, "{}: polar error {err}", opts.alpha.name());
        out.log
    }

    #[test]
    fn classic_and_prism_converge_gaussian() {
        let mut rng = Rng::seed_from(1);
        let a = randmat::gaussian(&mut rng, 40, 24);
        for opts in [
            PolarOpts::classic(1),
            PolarOpts::classic(2),
            PolarOpts::degree3(),
            PolarOpts::degree5(),
            PolarOpts { d: 2, alpha: AlphaMode::Exact, stop: StopRule::default() },
        ] {
            check_polar(&a, &opts, 1e-5, &mut rng);
        }
    }

    #[test]
    fn prism_faster_than_classic_on_small_sigma_min() {
        // The paper's headline (Figs. 1–4): with tiny σ_min the classic
        // iteration stalls; PRISM reaches tolerance in far fewer iterations.
        let mut rng = Rng::seed_from(2);
        let s = crate::randmat::logspace(1e-6, 1.0, 24);
        let a = randmat::with_spectrum(&mut rng, 32, 24, &s);
        let stop = StopRule::default().with_max_iters(200).with_tol(1e-6);
        let classic = polar_prism(&a, &PolarOpts::classic(2).with_stop(stop), &mut rng);
        let prism = polar_prism(&a, &PolarOpts::degree5().with_stop(stop), &mut rng);
        assert!(prism.log.converged);
        assert!(classic.log.converged);
        let ic = classic.log.iters_to_tol(1e-6).unwrap();
        let ip = prism.log.iters_to_tol(1e-6).unwrap();
        // Early-phase growth per iteration: classic ×1.875, PRISM ×2.95 ⇒
        // expected iteration ratio ≈ ln(1.875)/ln(2.95) ≈ 0.58.
        assert!(
            (ip as f64) <= 0.75 * ic as f64,
            "prism {ip} iters vs classic {ic} — expected ≈0.6x"
        );
    }

    #[test]
    fn wide_matrix_handled_by_transpose() {
        let mut rng = Rng::seed_from(3);
        let a = randmat::gaussian(&mut rng, 10, 30);
        let out = polar_prism(&a, &PolarOpts::degree5(), &mut rng);
        assert!(out.transposed);
        assert_eq!(out.q.shape(), (10, 30));
        assert!(orthogonality_error(&out.q) < 1e-6);
    }

    #[test]
    fn residual_monotone_decreasing_prism() {
        let mut rng = Rng::seed_from(4);
        let s = crate::randmat::logspace(1e-4, 1.0, 16);
        let a = randmat::with_spectrum(&mut rng, 20, 16, &s);
        let out = polar_prism(&a, &PolarOpts::degree3(), &mut rng);
        for w in out.log.residuals.windows(2) {
            assert!(w[1] <= w[0] * 1.05, "residual went up: {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn alphas_within_interval() {
        let mut rng = Rng::seed_from(5);
        let a = randmat::gaussian(&mut rng, 24, 24);
        for (d, opts) in [(1, PolarOpts::degree3()), (2, PolarOpts::degree5())] {
            let out = polar_prism(&a, &opts, &mut rng);
            let (lo, hi) = crate::coeffs::alpha_interval(d);
            for &al in &out.log.alphas {
                assert!((lo..=hi).contains(&al), "d={d} α={al}");
            }
        }
    }

    #[test]
    fn property_polar_orthogonal_many_spectra() {
        Prop::new("prism polar orthogonalizes").cases(8).run(|rng| {
            let n = gens::usize_in(rng, 6, 20);
            let m = n + gens::usize_in(rng, 0, 10);
            let smin = gens::f64_log(rng, 1e-8, 0.5);
            let s = gens::spectrum(rng, n, smin);
            let a = randmat::with_spectrum(rng, m, n, &s);
            let stop = StopRule::default().with_max_iters(150).with_tol(1e-7);
            let out = polar_prism(&a, &PolarOpts::degree5().with_stop(stop), rng);
            assert!(out.log.converged, "smin={smin} n={n} res={}", out.log.final_residual());
            assert!(orthogonality_error(&out.q) < 1e-6);
        });
    }

    #[test]
    fn identity_is_fixed_point() {
        let mut rng = Rng::seed_from(6);
        let a = Mat::eye(8);
        let out = polar_prism(&a, &PolarOpts::degree5(), &mut rng);
        assert!(out.q.sub(&Mat::eye(8)).max_abs() < 1e-8);
    }
}

#[cfg(test)]
mod general_degree_tests {
    use super::*;
    use crate::prism::driver::{AlphaMode, StopRule};
    use crate::randmat;
    use crate::rng::Rng;

    #[test]
    fn degree3_and_4_converge_and_beat_classic() {
        // The paper defines f_d for all d (Part I); our general-d assembly
        // must converge and retain the PRISM advantage beyond d = 2.
        let mut rng = Rng::seed_from(31);
        let s = randmat::logspace(1e-6, 1.0, 48);
        let a = randmat::with_spectrum(&mut rng, 96, 48, &s);
        let stop = StopRule::default().with_max_iters(200).with_tol(1e-7);
        for d in [3usize, 4] {
            let classic =
                polar_prism(&a, &PolarOpts { d, alpha: AlphaMode::Classic, stop }, &mut rng);
            let fast = polar_prism(
                &a,
                &PolarOpts { d, alpha: AlphaMode::Sketched { p: 8 }, stop },
                &mut rng,
            );
            assert!(fast.log.converged, "d={d} residual {}", fast.log.final_residual());
            assert!(classic.log.converged, "classic d={d}");
            let (ic, ip) = (
                classic.log.iters_to_tol(1e-7).unwrap(),
                fast.log.iters_to_tol(1e-7).unwrap(),
            );
            assert!(ip <= ic, "d={d}: prism {ip} vs classic {ic}");
            assert!(orthogonality_error(&fast.q) < 1e-6);
            // α stays inside the generalised interval.
            let (lo, hi) = crate::coeffs::alpha_interval(d);
            for &al in &fast.log.alphas {
                assert!((lo - 1e-12..=hi + 1e-12).contains(&al), "d={d} α={al}");
            }
        }
    }

    #[test]
    fn higher_degree_takes_fewer_iterations() {
        // (2d+1)-order iterations contract faster per iteration; the trade
        // is more GEMMs per iteration — both directions must show up.
        let mut rng = Rng::seed_from(32);
        let s = randmat::logspace(1e-8, 1.0, 40);
        let a = randmat::with_spectrum(&mut rng, 80, 40, &s);
        let stop = StopRule::default().with_max_iters(300).with_tol(1e-7);
        let mut last = usize::MAX;
        for d in [1usize, 2, 3] {
            let out = polar_prism(
                &a,
                &PolarOpts { d, alpha: AlphaMode::Sketched { p: 8 }, stop },
                &mut rng,
            );
            let it = out.log.iters_to_tol(1e-7).unwrap();
            assert!(it <= last, "d={d}: {it} > previous {last}");
            last = it;
        }
    }
}
