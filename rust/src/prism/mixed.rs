//! Mixed-precision Newton–Schulz drivers: **f32 iterate, f64 guard**.
//!
//! Polar Express (PAPERS.md) runs its NS-type sign iterations in bf16 on
//! GPU; the same tolerance-to-low-precision argument applies to PRISM's
//! polar and coupled-sqrt iterations on CPU, where f32 doubles the SIMD
//! lanes per register (see the `linalg::gemm` module docs). These drivers
//! are the `Precision::Mixed` backend behind `matfn::Solver`:
//!
//! * **What runs in f32** — the iterate storage (`X`, and `Y` for the
//!   coupled sqrt), every update GEMM (`X·g_d(R;α)`, `g_d(R;α)·Y`, `R²`),
//!   the update polynomial assembly, and the sketched α-fit's trace
//!   propagation (the sketch itself is *drawn* in f64 so the RNG stream
//!   matches the f64 path draw-for-draw, then downcast; traces accumulate
//!   in f64).
//! * **What stays in f64** — the residual: after every f32 update the
//!   iterate is upcast (exactly) and `R = I − XᵀX` (polar) or `I − Y·X`
//!   (sqrt) is recomputed entirely in f64. Every stopping decision —
//!   convergence (`‖R‖_F < tol`), divergence (`> diverge_above` / NaN via
//!   `RunRecorder::step_guard`), and the f32-floor stall detection — reads
//!   only this f64 residual. The `IterationLog` therefore records f64-grade
//!   residuals; no decision is ever made on f32 arithmetic.
//! * **Cleanup** — f32 storage bounds how orthogonal/coupled the iterate
//!   can get (the defect floor grows like `n · ε_f32`), so once the f32
//!   phase converges to `max(tol, MIXED_F32_TOL)` or stalls at its floor,
//!   one optional full-f64 iteration runs on the upcast iterate. One
//!   NS step contracts the residual roughly quadratically, which carries
//!   the typical f32 floor well below 1e-9 for the sizes the service
//!   handles; the final residual and `converged` flag report whatever was
//!   actually achieved, in f64.
//!
//! Only the NewtonSchulz family with `d ≤ 2` routes here (the degree-1/2
//! update polynomial is assembled inline in f32); other methods and
//! degrees stay on the f64 engines — `matfn::Solver` enforces that.

use super::driver::{AlphaMode, EngineHooks, RunRecorder};
use super::fit::{alpha_from_traces, select_alpha_ns, taylor_alpha, update_poly_into};
use super::polar::{PolarOpts, PolarResult};
use super::sqrt::{SqrtOpts, SqrtResult};
use crate::coeffs::traces_needed;
use crate::linalg::gemm::{global_engine, GemmEngine, Workspace};
use crate::linalg::{Mat, Mat32};
use crate::rng::Rng;
use crate::sketch::SketchKind;

/// The f32 phase's residual target floor. Below ~1e-5 an f32-stored
/// iterate's defect is dominated by storage/GEMM round-off for moderate n,
/// so pushing the f32 loop further wastes iterations — the f64 cleanup
/// step covers the remaining decades. The effective f32-phase target is
/// `max(stop.tol, MIXED_F32_TOL)`.
pub const MIXED_F32_TOL: f64 = 1e-5;

/// Residual level below which NS contraction is safely quadratic, so a
/// stagnating f64 residual can only mean the f32 round-off floor — the
/// stall guard (two consecutive < 2× improvements) engages only here,
/// never in the slow early phase of an ill-conditioned spectrum.
const STALL_ENGAGE_BELOW: f64 = 1e-2;

/// One f32-phase α selection. Classic/Fixed are precision-free; Exact fits
/// against exact f64 power traces of the f64 residual; the sketched modes
/// draw the sketch **in f64 from `rng`** (identical stream consumption to
/// the f64 path: p·n normals per fit), downcast it, and propagate the
/// power traces through the f32 engine with f64 trace accumulation.
#[allow(clippy::too_many_arguments)]
fn select_alpha_mixed(
    r32: &Mat32,
    r64: &Mat,
    d: usize,
    mode: AlphaMode,
    rng: &mut Rng,
    eng: &GemmEngine,
    ws: &mut Workspace,
) -> f64 {
    match mode {
        AlphaMode::Classic => taylor_alpha(d),
        AlphaMode::Fixed(a) => a,
        AlphaMode::Exact => select_alpha_ns(r64, d, mode, rng, eng, ws),
        AlphaMode::Sketched { p } => {
            sketched_alpha_mixed(r32, d, p, SketchKind::Gaussian, rng, eng, ws)
        }
        AlphaMode::SketchedKind { p, kind } => sketched_alpha_mixed(r32, d, p, kind, rng, eng, ws),
    }
}

/// Sketched α on the f32 residual: f64 sketch draw → downcast → f32 trace
/// propagation ([`power_traces32_into`]) → f64 quartic fit.
fn sketched_alpha_mixed(
    r32: &Mat32,
    d: usize,
    p: usize,
    kind: SketchKind,
    rng: &mut Rng,
    eng: &GemmEngine,
    ws: &mut Workspace,
) -> f64 {
    let n = r32.rows();
    let q = traces_needed(d);
    let mut s64 = ws.take(p, n);
    kind.fill(&mut s64, rng);
    let mut s32 = ws.take_f32(p, n);
    s32.copy_from_f64(&s64);
    let mut t = ws.take(1, q);
    power_traces32_into(&s32, r32, t.as_mut_slice(), eng, ws);
    let alpha = alpha_from_traces(t.as_slice(), d);
    ws.put(s64);
    ws.put_f32(s32);
    ws.put(t);
    alpha
}

/// f32 twin of `sketch::power_traces_into`: propagate the p×n sketch
/// through `R` in f32 (`Y_{j+1} = Y_j · R`, the thin-A fast path) and
/// accumulate each trace estimate `Σ_i s_i · y_i` in **f64**, so the
/// quartic fit sees full-precision trace values over f32-round-off
/// iterates.
fn power_traces32_into(
    s: &Mat32,
    r: &Mat32,
    out: &mut [f64],
    eng: &GemmEngine,
    ws: &mut Workspace,
) {
    assert!(r.is_square(), "power traces: square residual required");
    assert_eq!(r.rows(), s.cols(), "power traces: sketch width mismatch");
    let (p, n) = s.shape();
    let mut yt = ws.take_f32(p, n);
    yt.copy_from(s);
    let mut yn = ws.take_f32(p, n);
    for slot in out.iter_mut() {
        eng.matmul_f32_into(&mut yn, &yt, r);
        std::mem::swap(&mut yt, &mut yn);
        *slot = s
            .as_slice()
            .iter()
            .zip(yt.as_slice())
            .map(|(&a, &b)| (a as f64) * (b as f64))
            .sum();
    }
    ws.put_f32(yt);
    ws.put_f32(yn);
}

/// Assemble `g_d(R; α)` in f32 for d ≤ 2 (the inline twin of
/// `fit::update_poly_into`'s elementwise arms; `r2` must be `R²` for d=2).
fn update_poly32(g: &mut Mat32, r: &Mat32, r2: Option<&Mat32>, d: usize, alpha: f64) {
    match d {
        1 => {
            g.copy_from(r);
            g.scale(alpha as f32);
            g.add_diag(1.0);
        }
        2 => {
            let r2 = r2.expect("d=2 needs R²");
            g.copy_from(r);
            g.scale(0.5);
            g.axpy(alpha as f32, r2);
            g.add_diag(1.0);
        }
        _ => unreachable!("mixed precision supports d <= 2"),
    }
}

/// Whether the f32 phase should hand over: converged to its target,
/// or stalled at the f32 round-off floor (two consecutive sub-2×
/// improvements while already in the quadratic regime).
struct F32Phase {
    target: f64,
    prev: f64,
    stall: usize,
}

impl F32Phase {
    fn new(tol: f64) -> F32Phase {
        F32Phase { target: tol.max(MIXED_F32_TOL), prev: f64::INFINITY, stall: 0 }
    }
    /// Called at the top of each f32 iteration with the current f64
    /// residual; `true` ends the f32 phase.
    fn done(&mut self, res: f64) -> bool {
        if res < self.target {
            return true;
        }
        if res < STALL_ENGAGE_BELOW {
            if res > 0.5 * self.prev {
                self.stall += 1;
            } else {
                self.stall = 0;
            }
            if self.stall >= 2 {
                return true;
            }
        }
        self.prev = res;
        false
    }
}

/// Mixed-precision polar factor: the `Precision::Mixed` backend for
/// [`super::polar::polar_prism_in`] — same signature, same result contract,
/// f64-grade stopping decisions (see the module docs).
pub(crate) fn polar_mixed_in(
    a: &Mat,
    opts: &PolarOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> PolarResult {
    assert!(opts.d <= 2, "mixed precision supports d <= 2");
    let (m, n) = a.shape();
    if m < n {
        // Wide input: transpose, recurse, transpose back — identical to the
        // f64 driver's orientation handling.
        let EngineHooks { x0, observer, event_base, job } = hooks;
        let mut at = ws.take(n, m);
        a.transpose_into(&mut at);
        let x0t = x0.map(|x0| {
            assert_eq!(x0.shape(), (m, n), "polar: x0 shape mismatch");
            let mut t = ws.take(n, m);
            x0.transpose_into(&mut t);
            t
        });
        // The `match` re-coerces the observer's trait-object lifetime for
        // the shorter-lived recursive hooks (Option's variance cannot).
        let hooks_t = EngineHooks {
            x0: x0t.as_ref(),
            observer: match observer {
                Some(o) => Some(o),
                None => None,
            },
            event_base,
            job,
        };
        let r = polar_mixed_in(&at, opts, rng, ws, hooks_t);
        ws.put(at);
        if let Some(t) = x0t {
            ws.put(t);
        }
        return PolarResult { q: r.q.transpose(), log: r.log, transposed: true };
    }
    let eng = global_engine();

    // f64 side: the guard's iterate copy and residual.
    let mut x64 = ws.take(m, n);
    match hooks.x0 {
        Some(x0) => {
            assert_eq!(x0.shape(), (m, n), "polar: x0 shape mismatch");
            x64.copy_from(x0);
        }
        None => {
            x64.copy_from(a);
            x64.scale(1.0 / a.fro_norm().max(1e-300));
        }
    }
    let mut r64 = ws.take(n, n);
    eng.syrk_at_a_into(&mut r64, &x64);
    r64.scale(-1.0);
    r64.add_diag(1.0);

    // f32 side: the working iterate and its loop temporaries.
    let mut x32 = ws.take_f32(m, n);
    x32.copy_from_f64(&x64);
    let mut xn32 = ws.take_f32(m, n);
    let mut g32 = ws.take_f32(n, n);
    let mut r32 = ws.take_f32(n, n);
    let mut r232 = if opts.d == 2 { Some(ws.take_f32(n, n)) } else { None };

    let mut rec = RunRecorder::start(r64.fro_norm())
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    let budget = opts.stop.max_iters.saturating_sub(1); // reserve the cleanup step
    let mut phase = F32Phase::new(opts.stop.tol);
    for _ in 0..budget {
        if phase.done(r64.fro_norm()) {
            break;
        }
        // Downcast the *f64* residual each iteration: the α fit and the f32
        // update both see the guard's residual, not an f32-accumulated one.
        r32.copy_from_f64(&r64);
        let alpha = select_alpha_mixed(&r32, &r64, opts.d, opts.alpha, rng, &eng, ws);
        if let Some(r2buf) = r232.as_mut() {
            eng.matmul_f32_into(r2buf, &r32, &r32);
        }
        update_poly32(&mut g32, &r32, r232.as_ref(), opts.d, alpha);
        eng.matmul_f32_into(&mut xn32, &x32, &g32);
        std::mem::swap(&mut x32, &mut xn32);
        // Exact upcast, then a full-f64 residual for every decision below.
        x32.write_f64_into(&mut x64);
        eng.syrk_at_a_into(&mut r64, &x64);
        r64.scale(-1.0);
        r64.add_diag(1.0);
        if rec.step_guard(&opts.stop, alpha, r64.fro_norm()) {
            break;
        }
    }

    // Optional f64 cleanup: one full-precision iteration on the upcast
    // iterate when the f32 phase stopped short of the caller's tolerance.
    let res = r64.fro_norm();
    if res.is_finite() && res <= opts.stop.diverge_above && res >= opts.stop.tol {
        let mut xn64 = ws.take(m, n);
        let mut g64 = ws.take(n, n);
        let mut r264 = if opts.d == 2 { Some(ws.take(n, n)) } else { None };
        let alpha = select_alpha_ns(&r64, opts.d, opts.alpha, rng, &eng, ws);
        if let Some(r2buf) = r264.as_mut() {
            eng.matmul_into(r2buf, &r64, &r64);
        }
        update_poly_into(&mut g64, &r64, r264.as_ref(), opts.d, alpha, &eng, ws);
        eng.matmul_into(&mut xn64, &x64, &g64);
        std::mem::swap(&mut x64, &mut xn64);
        eng.syrk_at_a_into(&mut r64, &x64);
        r64.scale(-1.0);
        r64.add_diag(1.0);
        rec.step_guard(&opts.stop, alpha, r64.fro_norm());
        ws.put(xn64);
        ws.put(g64);
        if let Some(b) = r264 {
            ws.put(b);
        }
    }

    let out = PolarResult { q: x64.clone(), log: rec.finish(&opts.stop), transposed: false };
    ws.put(x64);
    ws.put(r64);
    ws.put_f32(x32);
    ws.put_f32(xn32);
    ws.put_f32(g32);
    ws.put_f32(r32);
    if let Some(b) = r232 {
        ws.put_f32(b);
    }
    out
}

/// Mixed-precision coupled sqrt/inv-sqrt: the `Precision::Mixed` backend
/// for [`super::sqrt::sqrt_prism_in`] — same signature and result contract
/// (including the Y-first Higham residual pairing), f64-grade stopping
/// decisions. Like the f64 core, the coupled iteration ignores `hooks.x0`.
pub(crate) fn sqrt_mixed_in(
    a: &Mat,
    opts: &SqrtOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> SqrtResult {
    assert!(a.is_square(), "sqrt: square input required");
    assert!(opts.d <= 2, "mixed precision supports d <= 2");
    let eng = global_engine();
    let n = a.rows();
    let c = a.fro_norm().max(1e-300);

    // f64 side: guard copies of both coupled iterates plus the residual.
    let mut x64 = ws.take(n, n);
    x64.copy_from(a);
    x64.scale(1.0 / c);
    let mut y64 = ws.take(n, n);
    y64.fill_with(0.0);
    y64.add_diag(1.0);
    let mut r64 = ws.take(n, n);
    // Y-first pairing (I − Y·X): the numerically stable residual — see the
    // f64 driver's note; the guard must measure the same quantity.
    eng.matmul_into(&mut r64, &y64, &x64);
    r64.scale(-1.0);
    r64.add_diag(1.0);
    r64.symmetrize();

    // f32 side.
    let mut x32 = ws.take_f32(n, n);
    x32.copy_from_f64(&x64);
    let mut y32 = ws.take_f32(n, n);
    y32.copy_from_f64(&y64);
    let mut xn32 = ws.take_f32(n, n);
    let mut yn32 = ws.take_f32(n, n);
    let mut g32 = ws.take_f32(n, n);
    let mut r32 = ws.take_f32(n, n);
    let mut r232 = if opts.d == 2 { Some(ws.take_f32(n, n)) } else { None };

    let mut rec = RunRecorder::start(r64.fro_norm())
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    let budget = opts.stop.max_iters.saturating_sub(1);
    let mut phase = F32Phase::new(opts.stop.tol);
    for _ in 0..budget {
        if phase.done(r64.fro_norm()) {
            break;
        }
        r32.copy_from_f64(&r64);
        let alpha = select_alpha_mixed(&r32, &r64, opts.d, opts.alpha, rng, &eng, ws);
        if let Some(r2buf) = r232.as_mut() {
            eng.matmul_f32_into(r2buf, &r32, &r32);
        }
        update_poly32(&mut g32, &r32, r232.as_ref(), opts.d, alpha);
        eng.matmul_f32_into(&mut xn32, &x32, &g32);
        std::mem::swap(&mut x32, &mut xn32);
        eng.matmul_f32_into(&mut yn32, &g32, &y32);
        std::mem::swap(&mut y32, &mut yn32);
        x32.write_f64_into(&mut x64);
        y32.write_f64_into(&mut y64);
        eng.matmul_into(&mut r64, &y64, &x64);
        r64.scale(-1.0);
        r64.add_diag(1.0);
        r64.symmetrize();
        if rec.step_guard(&opts.stop, alpha, r64.fro_norm()) {
            break;
        }
    }

    // Optional f64 cleanup iteration on both coupled iterates.
    let res = r64.fro_norm();
    if res.is_finite() && res <= opts.stop.diverge_above && res >= opts.stop.tol {
        let mut xn64 = ws.take(n, n);
        let mut yn64 = ws.take(n, n);
        let mut g64 = ws.take(n, n);
        let mut r264 = if opts.d == 2 { Some(ws.take(n, n)) } else { None };
        let alpha = select_alpha_ns(&r64, opts.d, opts.alpha, rng, &eng, ws);
        if let Some(r2buf) = r264.as_mut() {
            eng.matmul_into(r2buf, &r64, &r64);
        }
        update_poly_into(&mut g64, &r64, r264.as_ref(), opts.d, alpha, &eng, ws);
        eng.matmul_into(&mut xn64, &x64, &g64);
        std::mem::swap(&mut x64, &mut xn64);
        eng.matmul_into(&mut yn64, &g64, &y64);
        std::mem::swap(&mut y64, &mut yn64);
        eng.matmul_into(&mut r64, &y64, &x64);
        r64.scale(-1.0);
        r64.add_diag(1.0);
        r64.symmetrize();
        rec.step_guard(&opts.stop, alpha, r64.fro_norm());
        ws.put(xn64);
        ws.put(yn64);
        ws.put(g64);
        if let Some(b) = r264 {
            ws.put(b);
        }
    }

    let sc = c.sqrt();
    let out = SqrtResult {
        sqrt: x64.scaled(sc),
        inv_sqrt: y64.scaled(1.0 / sc),
        log: rec.finish(&opts.stop),
    };
    ws.put(x64);
    ws.put(y64);
    ws.put(r64);
    ws.put_f32(x32);
    ws.put_f32(y32);
    ws.put_f32(xn32);
    ws.put_f32(yn32);
    ws.put_f32(g32);
    ws.put_f32(r32);
    if let Some(b) = r232 {
        ws.put_f32(b);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::eigen_fn;
    use crate::linalg::svd::svd;
    use crate::prism::driver::StopRule;
    use crate::prism::polar::{orthogonality_error, polar_prism_in};
    use crate::prism::sqrt::sqrt_prism_in;
    use crate::ptest::gens;
    use crate::randmat;

    fn polar_mixed(a: &Mat, opts: &PolarOpts, rng: &mut Rng) -> PolarResult {
        polar_mixed_in(a, opts, rng, &mut Workspace::new(), EngineHooks::none())
    }

    fn sqrt_mixed(a: &Mat, opts: &SqrtOpts, rng: &mut Rng) -> SqrtResult {
        sqrt_mixed_in(a, opts, rng, &mut Workspace::new(), EngineHooks::none())
    }

    #[test]
    fn mixed_polar_matches_svd_ground_truth() {
        // Conformance vs SVD at the documented mixed tolerance: the f64
        // cleanup step carries the f32 floor below 1e-8 for these sizes.
        let mut rng = Rng::seed_from(41);
        let a = gens::ill_conditioned(&mut rng, 24, 16, 50.0);
        let opts = PolarOpts::degree5()
            .with_stop(StopRule::default().with_max_iters(200).with_tol(1e-8));
        let out = polar_mixed(&a, &opts, &mut rng);
        assert!(out.log.converged, "res={}", out.log.final_residual());
        let exact = svd(&a).polar_factor();
        assert!(out.q.sub(&exact).max_abs() < 1e-5);
        assert!(orthogonality_error(&out.q) < 1e-6);
    }

    #[test]
    fn mixed_polar_close_to_f64_solve() {
        let mut rng = Rng::seed_from(42);
        let a = randmat::gaussian(&mut rng, 32, 20);
        let stop = StopRule::default().with_max_iters(200).with_tol(1e-8);
        let opts = PolarOpts::degree5().with_stop(stop);
        let mixed = polar_mixed(&a, &opts, &mut Rng::seed_from(7));
        let full = polar_prism_in(
            &a,
            &opts,
            &mut Rng::seed_from(7),
            &mut Workspace::new(),
            EngineHooks::none(),
        );
        assert!(mixed.log.converged && full.log.converged);
        assert!(
            mixed.q.sub(&full.q).max_abs() < 1e-5,
            "mixed vs f64 gap {}",
            mixed.q.sub(&full.q).max_abs()
        );
    }

    #[test]
    fn mixed_sqrt_matches_eigen_ground_truth() {
        let mut rng = Rng::seed_from(43);
        let a = gens::spd(&mut rng, 12, 1e-2);
        let opts = SqrtOpts::degree5()
            .with_stop(StopRule::default().with_max_iters(200).with_tol(1e-9));
        let out = sqrt_mixed(&a, &opts, &mut rng);
        assert!(out.log.converged, "res={}", out.log.final_residual());
        assert!(out.sqrt.sub(&eigen_fn::sqrt_eigen(&a)).max_abs() < 1e-5);
        assert!(out.inv_sqrt.sub(&eigen_fn::inv_sqrt_eigen(&a, 0.0)).max_abs() < 1e-4);
    }

    #[test]
    fn mixed_sqrt_close_to_f64_solve() {
        let mut rng = Rng::seed_from(44);
        let a = gens::spd(&mut rng, 16, 1e-3);
        let stop = StopRule::default().with_max_iters(200).with_tol(1e-9);
        let opts = SqrtOpts::degree5().with_stop(stop);
        let mixed = sqrt_mixed(&a, &opts, &mut Rng::seed_from(9));
        let full = sqrt_prism_in(
            &a,
            &opts,
            &mut Rng::seed_from(9),
            &mut Workspace::new(),
            EngineHooks::none(),
        );
        assert!(mixed.log.converged && full.log.converged);
        assert!(mixed.inv_sqrt.sub(&full.inv_sqrt).max_abs() < 1e-4);
    }

    #[test]
    fn mixed_wide_polar_handled_by_transpose() {
        let mut rng = Rng::seed_from(45);
        let a = randmat::gaussian(&mut rng, 10, 30);
        let out = polar_mixed(&a, &PolarOpts::degree5(), &mut rng);
        assert!(out.transposed);
        assert_eq!(out.q.shape(), (10, 30));
        assert!(orthogonality_error(&out.q) < 1e-4);
    }

    #[test]
    fn guard_residuals_are_f64_grade_and_stall_guard_fires() {
        // The log's residual trajectory comes from the f64 guard: it must
        // end below the f32 floor (impossible to *measure* in f32-only
        // arithmetic at this tolerance) and be finite everywhere. Also pin
        // the f32-phase structure: once below MIXED_F32_TOL the loop hands
        // over, so at most one iteration's residual sits in
        // [tol, MIXED_F32_TOL) before the cleanup step ends the log.
        let mut rng = Rng::seed_from(46);
        let a = randmat::gaussian(&mut rng, 24, 24);
        let opts = PolarOpts::degree5()
            .with_stop(StopRule::default().with_max_iters(100).with_tol(1e-9));
        let out = polar_mixed(&a, &opts, &mut rng);
        assert!(out.log.converged);
        assert!(out.log.final_residual() < 1e-9);
        for &r in &out.log.residuals {
            assert!(r.is_finite());
        }
        // The last recorded step is the f64 cleanup: it must jump from the
        // f32-phase plateau (≥ tol) straight below tol in one step.
        let k = out.log.residuals.len();
        assert!(k >= 2);
        assert!(out.log.residuals[k - 2] >= 1e-9, "cleanup ran from above tol");
    }

    #[test]
    fn f32_phase_stall_detector_engages_only_in_quadratic_regime() {
        let mut p = F32Phase::new(1e-12);
        // Slow early-phase decrease far above the engage threshold: never
        // a stall, no matter how slight the improvement.
        assert!(!p.done(1.0));
        assert!(!p.done(0.999));
        assert!(!p.done(0.998));
        // Quadratic regime: two consecutive sub-2× improvements stop it.
        assert!(!p.done(1e-3));
        assert!(!p.done(0.9e-3));
        assert!(p.done(0.89e-3));
        // Converged target always stops immediately.
        let mut q = F32Phase::new(1e-6);
        assert!(q.done(0.5e-6));
    }
}
