//! Chebyshev iteration for the matrix inverse (Table 1 row 7; paper §A.4).
//!
//! `X₀ = Āᵀ` (Ā = A/‖A‖_F), `R_k = I − Ā X_k`,
//! `X_{k+1} = X_k (I + R_k + α_k R_k²)`; classical Chebyshev fixes α = 1,
//! PRISM fits α ∈ [1/2, 2] from the sketched quadratic.
//! The result is rescaled: `A⁻¹ = Ā⁻¹ / ‖A‖_F`.

use super::driver::{AlphaMode, EngineHooks, IterationLog, RunRecorder, StopRule};
use crate::coeffs::chebyshev_coeffs;
use crate::linalg::gemm::{global_engine, GemmEngine, Workspace};
use crate::linalg::Mat;
use crate::polyfit::minimize_on_interval;
use crate::rng::Rng;
use crate::sketch::{exact_power_traces, with_sketched_traces, SketchKind};

#[derive(Debug, Clone)]
pub struct ChebyshevOpts {
    pub alpha: AlphaMode,
    pub stop: StopRule,
}

impl ChebyshevOpts {
    pub fn prism() -> Self {
        ChebyshevOpts { alpha: AlphaMode::Sketched { p: 8 }, stop: StopRule::default() }
    }
    pub fn classic() -> Self {
        ChebyshevOpts { alpha: AlphaMode::Classic, stop: StopRule::default() }
    }
    pub fn with_stop(mut self, stop: StopRule) -> Self {
        self.stop = stop;
        self
    }
}

pub struct ChebyshevResult {
    pub inverse: Mat,
    pub log: IterationLog,
}

const ALPHA_LO: f64 = 0.5;
const ALPHA_HI: f64 = 2.0;

/// The sketched modes draw the sketch and trace scratch from `ws` and
/// propagate through `eng`'s skinny GEMM path — allocation-free when warm.
fn select_alpha(
    r: &Mat,
    mode: AlphaMode,
    rng: &mut Rng,
    eng: &GemmEngine,
    ws: &mut Workspace,
) -> f64 {
    let fit = |t: &[f64]| {
        let c = chebyshev_coeffs(t);
        minimize_on_interval(&c, ALPHA_LO, ALPHA_HI).map(|(a, _)| a).unwrap_or(1.0)
    };
    match mode {
        AlphaMode::Classic => 1.0,
        AlphaMode::Fixed(a) => a,
        AlphaMode::Exact => fit(&exact_power_traces(r, 6)),
        AlphaMode::Sketched { p } => {
            with_sketched_traces(r, p, SketchKind::Gaussian, 6, rng, eng, ws, fit)
        }
        AlphaMode::SketchedKind { p, kind } => {
            with_sketched_traces(r, p, kind, 6, rng, eng, ws, fit)
        }
    }
}

/// Compute `A⁻¹` for a full-rank square `A` (not necessarily symmetric).
///
/// Thin wrapper over [`chebyshev_inverse_in`] with a throwaway workspace;
/// persistent callers go through [`crate::matfn::Solver`].
pub fn chebyshev_inverse(a: &Mat, opts: &ChebyshevOpts, rng: &mut Rng) -> ChebyshevResult {
    chebyshev_inverse_in(a, opts, rng, &mut Workspace::new(), EngineHooks::none())
}

/// Workspace-pooled core. `hooks.x0` warm-starts at `X₀ = ‖A‖_F · x0`
/// (pass the previous *unscaled* inverse estimate; the internal iteration
/// works on `Ā = A/‖A‖_F`, whose inverse is `‖A‖_F · A⁻¹`).
pub(crate) fn chebyshev_inverse_in(
    a: &Mat,
    opts: &ChebyshevOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> ChebyshevResult {
    assert!(a.is_square());
    let eng = global_engine();
    let n = a.rows();
    let c = a.fro_norm().max(1e-300);
    let mut abar = ws.take(n, n);
    abar.copy_from(a);
    abar.scale(1.0 / c);
    let mut x = ws.take(n, n);
    match hooks.x0 {
        Some(x0) => {
            assert_eq!(x0.shape(), (n, n), "inverse: x0 shape mismatch");
            x.copy_from(x0);
            x.scale(c);
        }
        None => abar.transpose_into(&mut x),
    }

    // Ping-pong buffers from the pool — the loop is allocation-free, and so
    // is the whole call from the second same-shape solve onward.
    let mut xn = ws.take(n, n);
    let mut r = ws.take(n, n);
    let mut r_sym = ws.take(n, n);
    let mut r2 = ws.take(n, n);
    let mut g = ws.take(n, n);

    eng.matmul_into(&mut r, &abar, &x);
    r.scale(-1.0);
    r.add_diag(1.0);

    let mut rec = RunRecorder::start(r.fro_norm())
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    for _ in 0..opts.stop.max_iters {
        if r.fro_norm() < opts.stop.tol {
            break;
        }
        // NOTE: R here is symmetric iff A is normal; the α fit uses the
        // symmetric part's traces which is exact for the symmetric inputs
        // the paper covers and a controlled heuristic otherwise.
        r_sym.copy_from(&r);
        r_sym.symmetrize();
        let alpha = select_alpha(&r_sym, opts.alpha, rng, &eng, ws);
        eng.matmul_into(&mut r2, &r, &r);
        // G = I + R + αR²
        g.copy_from(&r);
        g.axpy(alpha, &r2);
        g.add_diag(1.0);
        eng.matmul_into(&mut xn, &x, &g);
        std::mem::swap(&mut x, &mut xn);
        eng.matmul_into(&mut r, &abar, &x);
        r.scale(-1.0);
        r.add_diag(1.0);
        if rec.step_guard(&opts.stop, alpha, r.fro_norm()) {
            break;
        }
    }
    let out = ChebyshevResult { inverse: x.scaled(1.0 / c), log: rec.finish(&opts.stop) };
    ws.put(abar);
    ws.put(x);
    ws.put(xn);
    ws.put(r);
    ws.put(r_sym);
    ws.put(r2);
    ws.put(g);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::randmat;

    #[test]
    fn inverse_of_spd() {
        let mut rng = Rng::seed_from(1);
        let w = randmat::logspace(0.05, 1.0, 10);
        let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
        for opts in [ChebyshevOpts::classic(), ChebyshevOpts::prism()] {
            let stop = StopRule::default().with_max_iters(150);
            let out = chebyshev_inverse(&a, &opts.with_stop(stop), &mut rng);
            assert!(out.log.converged, "res={}", out.log.final_residual());
            let prod = matmul(&a, &out.inverse);
            assert!(prod.sub(&Mat::eye(10)).max_abs() < 1e-6);
        }
    }

    #[test]
    fn inverse_of_nonsymmetric() {
        let mut rng = Rng::seed_from(2);
        // Well-conditioned non-symmetric matrix: I + small noise.
        let mut a = Mat::gaussian(&mut rng, 12, 12, 0.08);
        a.add_diag(1.0);
        let stop = StopRule::default().with_max_iters(200);
        let out = chebyshev_inverse(&a, &ChebyshevOpts::prism().with_stop(stop), &mut rng);
        assert!(out.log.converged);
        let prod = matmul(&a, &out.inverse);
        assert!(prod.sub(&Mat::eye(12)).max_abs() < 1e-6);
    }

    #[test]
    fn prism_not_slower() {
        let mut rng = Rng::seed_from(3);
        let w = randmat::logspace(1e-3, 1.0, 16);
        let a = randmat::sym_with_spectrum(&mut rng, 16, &w);
        let stop = StopRule::default().with_max_iters(500).with_tol(1e-6);
        let classic = chebyshev_inverse(&a, &ChebyshevOpts::classic().with_stop(stop), &mut rng);
        let prism = chebyshev_inverse(&a, &ChebyshevOpts::prism().with_stop(stop), &mut rng);
        assert!(classic.log.converged && prism.log.converged);
        let ic = classic.log.iters_to_tol(1e-6).unwrap();
        let ip = prism.log.iters_to_tol(1e-6).unwrap();
        assert!(ip <= ic + 1, "prism {ip} vs classic {ic}");
    }

    #[test]
    fn matches_lu_inverse() {
        let mut rng = Rng::seed_from(4);
        let w = randmat::logspace(0.1, 1.0, 8);
        let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
        let out = chebyshev_inverse(&a, &ChebyshevOpts::prism(), &mut rng);
        let exact = crate::linalg::decomp::lu_inverse(&a).unwrap();
        assert!(out.inverse.sub(&exact).max_abs() < 1e-6);
    }

    #[test]
    fn alphas_in_interval() {
        let mut rng = Rng::seed_from(5);
        let w = randmat::logspace(0.01, 1.0, 12);
        let a = randmat::sym_with_spectrum(&mut rng, 12, &w);
        let out = chebyshev_inverse(&a, &ChebyshevOpts::prism(), &mut rng);
        for &al in &out.log.alphas {
            assert!((ALPHA_LO..=ALPHA_HI).contains(&al));
        }
    }
}
