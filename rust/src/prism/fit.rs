//! α-selection shared by the Newton–Schulz-family engines (sign, polar,
//! sqrt): build the quartic `m(α)` from (sketched or exact) power traces of
//! the residual and minimise it over the degree's constraint interval.

use super::driver::AlphaMode;
use crate::coeffs::{alpha_interval, ns_d1_coeffs, ns_d2_coeffs, traces_needed};
use crate::linalg::gemm::{GemmEngine, Workspace};
use crate::linalg::Mat;
use crate::polyfit::minimize_quartic;
use crate::rng::Rng;
use crate::sketch::{exact_power_traces, power_traces_into, with_sketched_traces, SketchKind};

/// Taylor coefficient of ξ^d in f_d — the classical Newton–Schulz choice.
/// f(ξ) = (1-ξ)^{-1/2} = 1 + ξ/2 + 3ξ²/8 + 5ξ³/16 + ...
pub fn taylor_alpha(d: usize) -> f64 {
    crate::coeffs::taylor_coeff(d)
}

/// Choose α for one Newton–Schulz iteration with residual `r` (symmetric).
///
/// The sketched modes draw the p×n sketch buffer and the trace row from
/// `ws` and propagate the sketch through `eng`'s skinny thin-A GEMM path —
/// from the second same-shape call onward the fit performs **zero heap
/// allocations** (the matfn allocation tests assert it through the
/// solvers' [`Workspace::allocations`] counters).
pub fn select_alpha_ns(
    r: &Mat,
    d: usize,
    mode: AlphaMode,
    rng: &mut Rng,
    eng: &GemmEngine,
    ws: &mut Workspace,
) -> f64 {
    match mode {
        AlphaMode::Classic => taylor_alpha(d),
        AlphaMode::Fixed(a) => a,
        AlphaMode::Exact => {
            let t = exact_power_traces(r, traces_needed(d));
            alpha_from_traces(&t, d)
        }
        AlphaMode::Sketched { p } => with_sketched_traces(
            r,
            p,
            SketchKind::Gaussian,
            traces_needed(d),
            rng,
            eng,
            ws,
            |t| alpha_from_traces(t, d),
        ),
        AlphaMode::SketchedKind { p, kind } => {
            with_sketched_traces(r, p, kind, traces_needed(d), rng, eng, ws, |t| {
                alpha_from_traces(t, d)
            })
        }
    }
}

/// α for residual `r` from an **already-drawn** sketch `s` — the batched
/// lockstep path's core ([`crate::matfn::Solver::solve_batch`] fills one
/// sketch per iteration and fits every batch member against it). Given the
/// same draw this is operation-identical to the sequential
/// [`crate::sketch::with_sketched_traces`] route above: both run
/// [`power_traces_into`] then [`alpha_from_traces`], so the two fits cannot
/// drift apart numerically. `traces` must have length
/// [`traces_needed`]`(d)`.
pub fn alpha_with_sketch(
    s: &Mat,
    r: &Mat,
    d: usize,
    traces: &mut [f64],
    eng: &GemmEngine,
    ws: &mut Workspace,
) -> f64 {
    power_traces_into(s, r, traces, eng, ws);
    alpha_from_traces(traces, d)
}

/// Minimise the assembled quartic on the recommended interval.
pub fn alpha_from_traces(t: &[f64], d: usize) -> f64 {
    let c = match d {
        1 => ns_d1_coeffs(t),
        2 => ns_d2_coeffs(t),
        // General degree: symbolic assembly (paper §4.2's 4d+2-trace recipe).
        _ => crate::coeffs::ns_general_coeffs(t, d),
    };
    let (lo, hi) = alpha_interval(d);
    match minimize_quartic(&c, lo, hi) {
        Ok((a, _)) => a,
        // On numerical trouble fall back to the safe classical coefficient.
        Err(_) => taylor_alpha(d),
    }
}

/// Evaluate the degree-d update polynomial applied to the iterate:
/// returns `X · g_d(R; α)` where
/// g₁(R;α) = I + αR and g₂(R;α) = I + R/2 + αR².
///
/// `r2` must be `R²` when d = 2 (caller computes/reuses it), unused for d=1.
pub fn apply_update(x: &Mat, r: &Mat, r2: Option<&Mat>, d: usize, alpha: f64) -> Mat {
    let g = update_poly(r, r2, d, alpha);
    crate::linalg::gemm::matmul(x, &g)
}

/// The polynomial coefficient `c_k` of `g_d(R; α) = Σ_{k≤d} c_k R^k`: the
/// Taylor coefficients `a_k` below the top, and the fitted α on top.
#[inline]
fn update_coeff(k: usize, d: usize, alpha: f64) -> f64 {
    if k == d {
        alpha
    } else {
        taylor_alpha(k)
    }
}

/// Write `g_d(R; α)` into a caller-owned buffer (reshaped in place) — the
/// allocation-free form the iteration engines use in their hot loops. For
/// d ≤ 2 this is pure elementwise work (no GEMMs, no allocation); for d ≥ 3
/// the polynomial is evaluated by **Paterson–Stockmeyer** in ≈ 2√d GEMMs
/// with every matrix intermediate drawn from `ws` — from the second
/// same-shape call onward the only heap traffic is an O(√d)-pointer table
/// `Vec`, never a matrix buffer.
pub fn update_poly_into(
    g: &mut Mat,
    r: &Mat,
    r2: Option<&Mat>,
    d: usize,
    alpha: f64,
    eng: &GemmEngine,
    ws: &mut Workspace,
) {
    match d {
        1 => {
            g.copy_from(r);
            g.scale(alpha);
            g.add_diag(1.0);
        }
        2 => {
            let r2 = r2.expect("d=2 needs R²");
            g.copy_from(r);
            g.scale(0.5);
            g.axpy(alpha, r2);
            g.add_diag(1.0);
        }
        _ => paterson_stockmeyer_into(g, r, r2, d, alpha, eng, ws),
    }
}

/// The power `R^j` for `j ≥ 1`, given the precomputed table `pows[i] =
/// R^{i+2}`.
fn power<'a>(r: &'a Mat, pows: &'a [Mat], j: usize) -> &'a Mat {
    if j == 1 {
        r
    } else {
        &pows[j - 2]
    }
}

/// Paterson–Stockmeyer evaluation of `g_d(R; α) = Σ_{k≤d} c_k R^k` into `g`.
///
/// With `s = ⌈√d⌉`, the polynomial splits into base-`R^s` chunks
/// `g = Σ_{i≤v} B_i(R) · (R^s)^i`, `v = ⌊d/s⌋`, where each `B_i` is a
/// degree-< s polynomial assembled by cheap O(n²) axpys from the power
/// table `R², …, R^s`. Building the table costs `s − 1` GEMMs and the
/// Horner recurrence over `R^s` costs `v` more — `s − 1 + v ≈ 2√d` total,
/// versus the `d − 1` explicit-power GEMMs this replaces (e.g. d = 16:
/// 7 instead of 15). Every matrix buffer (the power table and the Horner
/// ping-pong) is drawn from `ws`, preserving the engines'
/// [`Workspace::allocations`] steady-state invariant; the only per-call
/// heap traffic is the `s − 1`-entry `Vec` holding the table's handles
/// (O(√d) pointers, not matrix data).
///
/// `r2`, when provided, seeds the `R²` table entry and saves one GEMM.
fn paterson_stockmeyer_into(
    g: &mut Mat,
    r: &Mat,
    r2: Option<&Mat>,
    d: usize,
    alpha: f64,
    eng: &GemmEngine,
    ws: &mut Workspace,
) {
    debug_assert!(d >= 3);
    let n = r.rows();
    let mut s = 1usize;
    while s * s < d {
        s += 1;
    }
    let v = d / s;

    // Power table R^2..R^s (s − 1 GEMMs, minus one if R² was supplied).
    let mut pows: Vec<Mat> = Vec::with_capacity(s - 1);
    for j in 2..=s {
        let mut p = ws.take(n, n);
        if j == 2 {
            match r2 {
                Some(r2) => p.copy_from(r2),
                None => eng.matmul_into(&mut p, r, r),
            }
        } else {
            eng.matmul_into(&mut p, &pows[j - 3], r);
        }
        pows.push(p);
    }

    // Top chunk B_v (possibly shorter than s terms): degree d − v·s.
    g.reset(n, n);
    g.fill_with(0.0);
    g.add_diag(update_coeff(v * s, d, alpha));
    for j in 1..=(d - v * s) {
        g.axpy(update_coeff(v * s + j, d, alpha), power(r, &pows, j));
    }

    // Horner over R^s: g ← g·R^s + B_i for i = v−1 … 0 (v GEMMs).
    let mut tmp = ws.take(n, n);
    for i in (0..v).rev() {
        eng.matmul_into(&mut tmp, g, power(r, &pows, s));
        std::mem::swap(g, &mut tmp);
        g.add_diag(update_coeff(i * s, d, alpha));
        for j in 1..s {
            g.axpy(update_coeff(i * s + j, d, alpha), power(r, &pows, j));
        }
    }
    ws.put(tmp);
    for p in pows {
        ws.put(p);
    }
}

/// The polynomial matrix `g_d(R; α)` itself (for coupled iterations that
/// also need `g · Y`). Allocating convenience wrapper over
/// [`update_poly_into`] with a throwaway workspace and the global engine.
pub fn update_poly(r: &Mat, r2: Option<&Mat>, d: usize, alpha: f64) -> Mat {
    let mut g = Mat::zeros(0, 0);
    let eng = crate::linalg::gemm::global_engine();
    update_poly_into(&mut g, r, r2, d, alpha, &eng, &mut Workspace::new());
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::randmat;

    #[test]
    fn taylor_values() {
        assert_eq!(taylor_alpha(1), 0.5);
        assert_eq!(taylor_alpha(2), 0.375);
    }

    #[test]
    fn classic_mode_returns_taylor() {
        let mut rng = Rng::seed_from(1);
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = Workspace::new();
        let r = Mat::eye(4);
        assert_eq!(select_alpha_ns(&r, 1, AlphaMode::Classic, &mut rng, &eng, &mut ws), 0.5);
        assert_eq!(select_alpha_ns(&r, 2, AlphaMode::Fixed(1.45), &mut rng, &eng, &mut ws), 1.45);
    }

    #[test]
    fn exact_alpha_in_interval() {
        let mut rng = Rng::seed_from(2);
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = Workspace::new();
        for d in [1usize, 2] {
            let w: Vec<f64> = (0..12).map(|_| rng.uniform_in(0.0, 0.9)).collect();
            let r = randmat::sym_with_spectrum(&mut rng, 12, &w);
            let a = select_alpha_ns(&r, d, AlphaMode::Exact, &mut rng, &eng, &mut ws);
            let (lo, hi) = crate::coeffs::alpha_interval(d);
            assert!((lo..=hi).contains(&a), "d={d} a={a}");
        }
    }

    #[test]
    fn sketched_close_to_exact_alpha() {
        let mut rng = Rng::seed_from(3);
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = Workspace::new();
        let w: Vec<f64> = (0..32).map(|_| rng.uniform_in(0.2, 0.95)).collect();
        let r = randmat::sym_with_spectrum(&mut rng, 32, &w);
        let a_exact = select_alpha_ns(&r, 1, AlphaMode::Exact, &mut rng, &eng, &mut ws);
        // Average of several sketched fits should track the exact fit.
        let reps = 20;
        let mean: f64 = (0..reps)
            .map(|_| select_alpha_ns(&r, 1, AlphaMode::Sketched { p: 8 }, &mut rng, &eng, &mut ws))
            .sum::<f64>()
            / reps as f64;
        assert!((mean - a_exact).abs() < 0.15, "mean={mean} exact={a_exact}");
    }

    #[test]
    fn sketched_alpha_is_allocation_free_when_warm() {
        let mut rng = Rng::seed_from(10);
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = Workspace::new();
        let w: Vec<f64> = (0..24).map(|_| rng.uniform_in(0.2, 0.9)).collect();
        let r = randmat::sym_with_spectrum(&mut rng, 24, &w);
        let _ = select_alpha_ns(&r, 2, AlphaMode::Sketched { p: 8 }, &mut rng, &eng, &mut ws);
        let allocs = ws.allocations();
        assert!(allocs > 0);
        for _ in 0..4 {
            let a = select_alpha_ns(&r, 2, AlphaMode::Sketched { p: 8 }, &mut rng, &eng, &mut ws);
            let (lo, hi) = crate::coeffs::alpha_interval(2);
            assert!((lo..=hi).contains(&a));
        }
        assert_eq!(ws.allocations(), allocs, "warm sketched fit must not allocate");
    }

    #[test]
    fn update_poly_d1_identity_residual() {
        // R = 0 ⇒ g = I ⇒ X unchanged.
        let mut rng = Rng::seed_from(4);
        let x = Mat::gaussian(&mut rng, 5, 5, 1.0);
        let r = Mat::zeros(5, 5);
        let out = apply_update(&x, &r, None, 1, 0.7);
        assert!(out.sub(&x).max_abs() < 1e-12);
    }

    #[test]
    fn update_poly_into_matches_allocating() {
        let mut rng = Rng::seed_from(6);
        let r = {
            let g = Mat::gaussian(&mut rng, 5, 5, 0.3);
            let mut s = g.add(&g.transpose());
            s.scale(0.5);
            s
        };
        let r2 = matmul(&r, &r);
        let mut g = Mat::zeros(0, 0);
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = Workspace::new();
        for (d, r2opt, alpha) in [(1, None, 0.8), (2, Some(&r2), 1.2), (5, None, 0.4)] {
            update_poly_into(&mut g, &r, r2opt, d, alpha, &eng, &mut ws);
            let want = update_poly(&r, r2opt, d, alpha);
            assert!(g.sub(&want).max_abs() < 1e-13, "d={d}");
        }
    }

    /// Explicit-powers reference: `Σ_{k<d} a_k R^k + α R^d`, one GEMM per
    /// power — the pre-Paterson–Stockmeyer evaluation, kept as the oracle.
    fn explicit_powers_ref(r: &Mat, d: usize, alpha: f64) -> Mat {
        let n = r.rows();
        let mut g = Mat::zeros(n, n);
        g.add_diag(1.0);
        let mut pow = r.clone();
        for k in 1..=d {
            let coef = if k == d { alpha } else { taylor_alpha(k) };
            g.axpy(coef, &pow);
            if k < d {
                pow = matmul(&pow, r);
            }
        }
        g
    }

    #[test]
    fn paterson_stockmeyer_matches_explicit_powers() {
        let mut rng = Rng::seed_from(7);
        let r = {
            let g = Mat::gaussian(&mut rng, 8, 8, 0.2);
            let mut s = g.add(&g.transpose());
            s.scale(0.5 / g.fro_norm().max(1.0)); // keep ‖R‖ < 1
            s
        };
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = Workspace::new();
        let mut g = Mat::zeros(0, 0);
        for d in [3usize, 4, 5, 6, 8, 11, 16] {
            update_poly_into(&mut g, &r, None, d, 0.7, &eng, &mut ws);
            let want = explicit_powers_ref(&r, d, 0.7);
            let err = g.sub(&want).max_abs();
            assert!(err < 1e-13, "d={d}: err {err}");
        }
    }

    #[test]
    fn paterson_stockmeyer_gemm_budget() {
        // The satellite contract: a degree-d update costs ≤ ⌈2√d⌉ + 2 GEMMs
        // (it actually costs ⌈√d⌉ − 1 + ⌊d/⌈√d⌉⌋), strictly fewer than the
        // d − 1 explicit powers it replaced. GemmScope is thread-local, so
        // the count is deterministic even under parallel test execution.
        use crate::linalg::gemm::GemmScope;
        let mut rng = Rng::seed_from(8);
        let r = {
            let g = Mat::gaussian(&mut rng, 6, 6, 0.2);
            let mut s = g.add(&g.transpose());
            s.scale(0.25);
            s
        };
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = Workspace::new();
        let mut g = Mat::zeros(0, 0);
        for d in [5usize, 8, 16] {
            let scope = GemmScope::begin();
            update_poly_into(&mut g, &r, None, d, 0.9, &eng, &mut ws);
            let calls = scope.calls();
            let budget = (2.0 * (d as f64).sqrt()).ceil() as u64 + 2;
            assert!(calls <= budget, "d={d}: {calls} GEMMs > budget {budget}");
            assert!(calls < (d as u64) - 1, "d={d}: {calls} not better than explicit powers");
            // Exact count: (s − 1) power GEMMs + ⌊d/s⌋ Horner GEMMs.
            let s = (1usize..).find(|&s| s * s >= d).unwrap();
            assert_eq!(calls, (s - 1 + d / s) as u64, "d={d}");
        }
        // Supplying R² saves exactly one power GEMM.
        let r2 = matmul(&r, &r);
        let scope = GemmScope::begin();
        update_poly_into(&mut g, &r, Some(&r2), 5, 0.9, &eng, &mut ws);
        assert_eq!(scope.calls(), 2);
    }

    #[test]
    fn paterson_stockmeyer_is_allocation_free_when_warm() {
        let mut rng = Rng::seed_from(9);
        let r = Mat::gaussian(&mut rng, 7, 7, 0.1);
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = Workspace::new();
        let mut g = Mat::zeros(0, 0);
        update_poly_into(&mut g, &r, None, 9, 0.5, &eng, &mut ws);
        let allocs = ws.allocations();
        assert!(allocs > 0);
        for _ in 0..3 {
            update_poly_into(&mut g, &r, None, 9, 0.5, &eng, &mut ws);
        }
        assert_eq!(ws.allocations(), allocs, "warm PS must not allocate matrix buffers");
    }

    #[test]
    fn update_poly_d2_matches_direct() {
        let mut rng = Rng::seed_from(5);
        let r = {
            let g = Mat::gaussian(&mut rng, 6, 6, 0.3);
            let mut s = g.add(&g.transpose());
            s.scale(0.5);
            s
        };
        let r2 = matmul(&r, &r);
        let alpha = 1.1;
        let g = update_poly(&r, Some(&r2), 2, alpha);
        // direct: I + R/2 + αR²
        let mut want = Mat::eye(6);
        want.axpy(0.5, &r);
        want.axpy(alpha, &r2);
        assert!(g.sub(&want).max_abs() < 1e-12);
    }
}
