//! α-selection shared by the Newton–Schulz-family engines (sign, polar,
//! sqrt): build the quartic `m(α)` from (sketched or exact) power traces of
//! the residual and minimise it over the degree's constraint interval.

use super::driver::AlphaMode;
use crate::coeffs::{alpha_interval, ns_d1_coeffs, ns_d2_coeffs, traces_needed};
use crate::linalg::Mat;
use crate::polyfit::minimize_quartic;
use crate::rng::Rng;
use crate::sketch::{exact_power_traces, GaussianSketch};

/// Taylor coefficient of ξ^d in f_d — the classical Newton–Schulz choice.
/// f(ξ) = (1-ξ)^{-1/2} = 1 + ξ/2 + 3ξ²/8 + 5ξ³/16 + ...
pub fn taylor_alpha(d: usize) -> f64 {
    crate::coeffs::taylor_coeff(d)
}

/// Choose α for one Newton–Schulz iteration with residual `r` (symmetric).
pub fn select_alpha_ns(r: &Mat, d: usize, mode: AlphaMode, rng: &mut Rng) -> f64 {
    match mode {
        AlphaMode::Classic => taylor_alpha(d),
        AlphaMode::Fixed(a) => a,
        AlphaMode::Exact => {
            let t = exact_power_traces(r, traces_needed(d));
            alpha_from_traces(&t, d)
        }
        AlphaMode::Sketched { p } => {
            let s = GaussianSketch::draw(rng, p, r.rows());
            let t = s.power_traces(r, traces_needed(d));
            alpha_from_traces(&t, d)
        }
        AlphaMode::SketchedKind { p, kind } => {
            let s = kind.draw(rng, p, r.rows());
            let t = s.power_traces(r, traces_needed(d));
            alpha_from_traces(&t, d)
        }
    }
}

/// Minimise the assembled quartic on the recommended interval.
pub fn alpha_from_traces(t: &[f64], d: usize) -> f64 {
    let c = match d {
        1 => ns_d1_coeffs(t),
        2 => ns_d2_coeffs(t),
        // General degree: symbolic assembly (paper §4.2's 4d+2-trace recipe).
        _ => crate::coeffs::ns_general_coeffs(t, d),
    };
    let (lo, hi) = alpha_interval(d);
    match minimize_quartic(&c, lo, hi) {
        Ok((a, _)) => a,
        // On numerical trouble fall back to the safe classical coefficient.
        Err(_) => taylor_alpha(d),
    }
}

/// Evaluate the degree-d update polynomial applied to the iterate:
/// returns `X · g_d(R; α)` where
/// g₁(R;α) = I + αR and g₂(R;α) = I + R/2 + αR².
///
/// `r2` must be `R²` when d = 2 (caller computes/reuses it), unused for d=1.
pub fn apply_update(x: &Mat, r: &Mat, r2: Option<&Mat>, d: usize, alpha: f64) -> Mat {
    let g = update_poly(r, r2, d, alpha);
    crate::linalg::gemm::matmul(x, &g)
}

/// Write `g_d(R; α)` into a caller-owned buffer (reshaped in place) — the
/// allocation-free form the iteration engines use in their hot loops. For
/// d ≤ 2 no heap allocation happens at all; the general-degree path still
/// allocates its explicit R-powers (it is the ablation-only exotic case).
pub fn update_poly_into(g: &mut Mat, r: &Mat, r2: Option<&Mat>, d: usize, alpha: f64) {
    match d {
        1 => {
            g.copy_from(r);
            g.scale(alpha);
            g.add_diag(1.0);
        }
        2 => {
            let r2 = r2.expect("d=2 needs R²");
            g.copy_from(r);
            g.scale(0.5);
            g.axpy(alpha, r2);
            g.add_diag(1.0);
        }
        _ => {
            let full = update_poly(r, r2, d, alpha);
            g.copy_from(&full);
        }
    }
}

/// The polynomial matrix `g_d(R; α)` itself (for coupled iterations that
/// also need `g · Y`).
pub fn update_poly(r: &Mat, r2: Option<&Mat>, d: usize, alpha: f64) -> Mat {
    let n = r.rows();
    match d {
        1 => {
            let mut g = r.scaled(alpha);
            g.add_diag(1.0);
            g
        }
        2 => {
            let r2 = r2.expect("d=2 needs R²");
            let mut g = r.scaled(0.5);
            g.axpy(alpha, r2);
            g.add_diag(1.0);
            debug_assert_eq!(g.rows(), n);
            g
        }
        _ => {
            // General degree: g = Σ_{k<d} a_k R^k + α R^d by Horner-free
            // accumulation over explicit powers (d−1 extra GEMMs — the
            // (2d+1)-order iteration's intrinsic cost).
            let mut g = Mat::zeros(n, n);
            g.add_diag(taylor_alpha(0)); // a₀ = 1
            let mut pow = r.clone();
            for k in 1..=d {
                let coef = if k == d { alpha } else { taylor_alpha(k) };
                g.axpy(coef, &pow);
                if k < d {
                    pow = crate::linalg::gemm::matmul(&pow, r);
                }
            }
            g
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::randmat;

    #[test]
    fn taylor_values() {
        assert_eq!(taylor_alpha(1), 0.5);
        assert_eq!(taylor_alpha(2), 0.375);
    }

    #[test]
    fn classic_mode_returns_taylor() {
        let mut rng = Rng::seed_from(1);
        let r = Mat::eye(4);
        assert_eq!(select_alpha_ns(&r, 1, AlphaMode::Classic, &mut rng), 0.5);
        assert_eq!(select_alpha_ns(&r, 2, AlphaMode::Fixed(1.45), &mut rng), 1.45);
    }

    #[test]
    fn exact_alpha_in_interval() {
        let mut rng = Rng::seed_from(2);
        for d in [1usize, 2] {
            let w: Vec<f64> = (0..12).map(|_| rng.uniform_in(0.0, 0.9)).collect();
            let r = randmat::sym_with_spectrum(&mut rng, 12, &w);
            let a = select_alpha_ns(&r, d, AlphaMode::Exact, &mut rng);
            let (lo, hi) = crate::coeffs::alpha_interval(d);
            assert!((lo..=hi).contains(&a), "d={d} a={a}");
        }
    }

    #[test]
    fn sketched_close_to_exact_alpha() {
        let mut rng = Rng::seed_from(3);
        let w: Vec<f64> = (0..32).map(|_| rng.uniform_in(0.2, 0.95)).collect();
        let r = randmat::sym_with_spectrum(&mut rng, 32, &w);
        let a_exact = select_alpha_ns(&r, 1, AlphaMode::Exact, &mut rng);
        // Average of several sketched fits should track the exact fit.
        let reps = 20;
        let mean: f64 = (0..reps)
            .map(|_| select_alpha_ns(&r, 1, AlphaMode::Sketched { p: 8 }, &mut rng))
            .sum::<f64>()
            / reps as f64;
        assert!((mean - a_exact).abs() < 0.15, "mean={mean} exact={a_exact}");
    }

    #[test]
    fn update_poly_d1_identity_residual() {
        // R = 0 ⇒ g = I ⇒ X unchanged.
        let mut rng = Rng::seed_from(4);
        let x = Mat::gaussian(&mut rng, 5, 5, 1.0);
        let r = Mat::zeros(5, 5);
        let out = apply_update(&x, &r, None, 1, 0.7);
        assert!(out.sub(&x).max_abs() < 1e-12);
    }

    #[test]
    fn update_poly_into_matches_allocating() {
        let mut rng = Rng::seed_from(6);
        let r = {
            let g = Mat::gaussian(&mut rng, 5, 5, 0.3);
            let mut s = g.add(&g.transpose());
            s.scale(0.5);
            s
        };
        let r2 = matmul(&r, &r);
        let mut g = Mat::zeros(0, 0);
        for (d, r2opt, alpha) in [(1, None, 0.8), (2, Some(&r2), 1.2)] {
            update_poly_into(&mut g, &r, r2opt, d, alpha);
            let want = update_poly(&r, r2opt, d, alpha);
            assert!(g.sub(&want).max_abs() < 1e-15, "d={d}");
        }
    }

    #[test]
    fn update_poly_d2_matches_direct() {
        let mut rng = Rng::seed_from(5);
        let r = {
            let g = Mat::gaussian(&mut rng, 6, 6, 0.3);
            let mut s = g.add(&g.transpose());
            s.scale(0.5);
            s
        };
        let r2 = matmul(&r, &r);
        let alpha = 1.1;
        let g = update_poly(&r, Some(&r2), 2, alpha);
        // direct: I + R/2 + αR²
        let mut want = Mat::eye(6);
        want.axpy(0.5, &r);
        want.axpy(alpha, &r2);
        assert!(g.sub(&want).max_abs() < 1e-12);
    }
}
