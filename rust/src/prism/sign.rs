//! Matrix sign iteration (paper §4) and the scalar sequences of Fig. 2.
//!
//! `sign(A) = A (A²)^{-1/2}` for `A` with `A²` symmetric. The Newton–Schulz
//! iteration is `X₀ = A`, `R_k = I − X_k²`, `X_{k+1} = X_k g_d(R_k; α_k)`.

use super::driver::{AlphaMode, EngineHooks, IterationLog, RunRecorder, StopRule};
use super::fit::{select_alpha_ns, taylor_alpha, update_poly_into};
use crate::linalg::gemm::{global_engine, Workspace};
use crate::linalg::Mat;
use crate::rng::Rng;

/// Options for a sign run.
#[derive(Debug, Clone)]
pub struct SignOpts {
    pub d: usize,
    pub alpha: AlphaMode,
    pub stop: StopRule,
    /// Normalise by ‖A‖_F first (paper assumes ‖A‖₂ ≤ 1).
    pub normalize: bool,
}

impl Default for SignOpts {
    fn default() -> Self {
        SignOpts {
            d: 1,
            alpha: AlphaMode::Sketched { p: 8 },
            stop: StopRule::default(),
            normalize: true,
        }
    }
}

pub struct SignResult {
    pub s: Mat,
    pub log: IterationLog,
}

/// Compute `sign(A)` for square `A` with `A²` symmetric.
///
/// Thin wrapper over [`sign_prism_in`] with a throwaway workspace;
/// persistent callers go through [`crate::matfn::Solver`].
pub fn sign_prism(a: &Mat, opts: &SignOpts, rng: &mut Rng) -> SignResult {
    sign_prism_in(a, opts, rng, &mut Workspace::new(), EngineHooks::none())
}

/// Workspace-pooled core. `hooks.x0` warm-starts at `X₀ = x0` (pass a
/// previous sign estimate; it is used as-is, without renormalisation).
pub(crate) fn sign_prism_in(
    a: &Mat,
    opts: &SignOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> SignResult {
    assert!(a.is_square(), "sign: square input required");
    let eng = global_engine();
    let n = a.rows();
    let mut x = ws.take(n, n);
    match hooks.x0 {
        Some(x0) => {
            assert_eq!(x0.shape(), (n, n), "sign: x0 shape mismatch");
            x.copy_from(x0);
        }
        None => {
            x.copy_from(a);
            if opts.normalize {
                x.scale(1.0 / a.fro_norm().max(1e-300));
            }
        }
    }

    // Ping-pong buffers from the pool — the loop is allocation-free, and so
    // is the whole call from the second same-shape solve onward.
    let mut xn = ws.take(n, n);
    let mut g = ws.take(n, n);
    let mut r = ws.take(n, n);
    let mut r2 = if opts.d == 2 { Some(ws.take(n, n)) } else { None };

    // R = I − X²; A² symmetric ⇒ R symmetric; symmetrize removes drift.
    eng.matmul_into(&mut r, &x, &x);
    r.scale(-1.0);
    r.add_diag(1.0);
    r.symmetrize();

    let mut rec = RunRecorder::start(r.fro_norm())
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    for _ in 0..opts.stop.max_iters {
        if r.fro_norm() < opts.stop.tol {
            break;
        }
        let alpha = select_alpha_ns(&r, opts.d, opts.alpha, rng, &eng, ws);
        if let Some(r2buf) = r2.as_mut() {
            eng.matmul_into(r2buf, &r, &r);
        }
        update_poly_into(&mut g, &r, r2.as_ref(), opts.d, alpha, &eng, ws);
        eng.matmul_into(&mut xn, &x, &g);
        std::mem::swap(&mut x, &mut xn);
        eng.matmul_into(&mut r, &x, &x);
        r.scale(-1.0);
        r.add_diag(1.0);
        r.symmetrize();
        if rec.step_guard(&opts.stop, alpha, r.fro_norm()) {
            break;
        }
    }
    let out = SignResult { s: x.clone(), log: rec.finish(&opts.stop) };
    ws.put(x);
    ws.put(xn);
    ws.put(g);
    ws.put(r);
    if let Some(b) = r2 {
        ws.put(b);
    }
    out
}

/// Scalar Newton–Schulz sequence `x_{k+1} = x_k g_d(1 − x_k²; α)` with
/// fixed α — generates Fig. 2's curves. Returns the residuals `1 − x_k²`.
pub fn scalar_sequence(x0: f64, d: usize, alpha: Option<f64>, iters: usize) -> Vec<f64> {
    let mut x = x0;
    let mut out = Vec::with_capacity(iters + 1);
    out.push(1.0 - x * x);
    for _ in 0..iters {
        let xi = 1.0 - x * x;
        let a = alpha.unwrap_or_else(|| taylor_alpha(d));
        let g = match d {
            1 => 1.0 + a * xi,
            2 => 1.0 + 0.5 * xi + a * xi * xi,
            _ => panic!("d must be 1 or 2"),
        };
        x *= g;
        out.push(1.0 - x * x);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::randmat;

    #[test]
    fn sign_of_spd_is_identity() {
        let mut rng = Rng::seed_from(1);
        let w: Vec<f64> = (0..12).map(|_| rng.uniform_in(0.05, 1.0)).collect();
        let a = randmat::sym_with_spectrum(&mut rng, 12, &w);
        let out = sign_prism(&a, &SignOpts::default(), &mut rng);
        assert!(out.log.converged, "res={}", out.log.final_residual());
        assert!(out.s.sub(&Mat::eye(12)).max_abs() < 1e-6);
    }

    #[test]
    fn sign_of_indefinite_diag() {
        // sign of a symmetric matrix with ± eigenvalues: V sign(Λ) Vᵀ.
        let mut rng = Rng::seed_from(2);
        let w = vec![-1.0, -0.4, 0.3, 0.9, 0.05, -0.07];
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        let opts = SignOpts { stop: StopRule::default().with_max_iters(120), ..Default::default() };
        let out = sign_prism(&a, &opts, &mut rng);
        assert!(out.log.converged);
        // sign(A)² = I and sign(A) commutes with A, sign(A) A is PSD.
        let s2 = matmul(&out.s, &out.s);
        assert!(s2.sub(&Mat::eye(6)).max_abs() < 1e-5);
        let sa = matmul(&out.s, &a);
        let e = crate::linalg::eigen::symmetric_eigen(&sa);
        assert!(e.values.iter().all(|&v| v > -1e-6), "sign(A)·A should be PSD");
    }

    #[test]
    fn d2_matches_d1_target() {
        let mut rng = Rng::seed_from(3);
        let w = vec![0.9, 0.5, -0.3, -0.8];
        let a = randmat::sym_with_spectrum(&mut rng, 4, &w);
        let o1 = sign_prism(&a, &SignOpts { d: 1, ..Default::default() }, &mut rng);
        let o2 = sign_prism(&a, &SignOpts { d: 2, ..Default::default() }, &mut rng);
        assert!(o1.s.sub(&o2.s).max_abs() < 1e-5);
    }

    #[test]
    fn scalar_sequence_matches_paper_example() {
        // Paper §4: with d=1, α=1/2 (classic): 1 − x_{k+1}² = ¾(1−x_k²)² + ¼(1−x_k²)³.
        let xs = scalar_sequence(0.6, 1, None, 1);
        let xi0: f64 = 1.0 - 0.36;
        let want = 0.75 * xi0 * xi0 + 0.25 * xi0 * xi0 * xi0;
        assert!((xs[1] - want).abs() < 1e-12);
    }

    #[test]
    fn scalar_alpha1_doubles_rate() {
        // Paper Fig. 2: for x₀ = 1e-6, α=1 reaches ξ < 0.5 in roughly half
        // the iterations of α=1/2.
        let classic = scalar_sequence(1e-6, 1, None, 100);
        let accel = scalar_sequence(1e-6, 1, Some(1.0), 100);
        let hit = |v: &[f64]| v.iter().position(|&x| x < 0.5).unwrap();
        let (ic, ia) = (hit(&classic), hit(&accel));
        assert!(
            (ia as f64) < 0.65 * ic as f64,
            "alpha=1: {ia} iters vs classic {ic}"
        );
    }

    #[test]
    fn scalar_stays_quadratic_near_convergence() {
        // With the classical α = 1/2 the scalar residual map is
        // h(ξ, 1/2) = ¾ξ² + ¼ξ³ ≤ ξ², i.e. exactly quadratic. (The fitted
        // α* also satisfies |h| ≤ 1.71 ξ² by Lemma B.1, but a *fixed* α = 1
        // is linear near 0 — that is why PRISM clamps α via the interval.)
        let xs = scalar_sequence(0.9, 1, None, 8);
        for w in xs.windows(2) {
            if w[0].abs() < 0.25 {
                assert!(
                    w[1].abs() <= w[0] * w[0] + 1e-15,
                    "{} -> {}",
                    w[0],
                    w[1]
                );
            }
        }
    }
}
