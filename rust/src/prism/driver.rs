//! Shared iteration bookkeeping: logs, stopping rules, α-selection modes,
//! and the per-iteration [`Observer`] / warm-start hooks every engine loop
//! threads through its [`RunRecorder`].

use crate::linalg::gemm::GemmScope;
use crate::linalg::Mat;
use crate::util::Stopwatch;

/// How the update coefficient α_k is chosen each iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AlphaMode {
    /// Fixed Taylor coefficient — the classical iteration.
    Classic,
    /// PRISM fit with sketch dimension p (Step 5 of the meta-algorithm).
    Sketched { p: usize },
    /// PRISM fit with a non-Gaussian sketch family (ablation; the paper
    /// defaults to Gaussian and we confirm the choice doesn't matter much).
    SketchedKind { p: usize, kind: crate::sketch::SketchKind },
    /// PRISM fit with exact traces (Step 4; O(n³) — ablation only).
    Exact,
    /// Fixed user-supplied α (used by the Muon warm-start trick, §C).
    Fixed(f64),
}

impl AlphaMode {
    pub fn name(&self) -> String {
        match self {
            AlphaMode::Classic => "classic".into(),
            AlphaMode::Sketched { p } => format!("prism(p={p})"),
            AlphaMode::SketchedKind { p, kind } => format!("prism(p={p},{})", kind.name()),
            AlphaMode::Exact => "prism(exact)".into(),
            AlphaMode::Fixed(a) => format!("fixed({a})"),
        }
    }
}

/// Stopping rule for the iterations.
#[derive(Debug, Clone, Copy)]
pub struct StopRule {
    pub max_iters: usize,
    /// Stop when the residual Frobenius norm falls below this.
    pub tol: f64,
    /// Abort (report divergence) if the residual exceeds this.
    pub diverge_above: f64,
}

impl Default for StopRule {
    fn default() -> Self {
        StopRule { max_iters: 60, tol: 1e-8, diverge_above: 1e12 }
    }
}

impl StopRule {
    pub fn with_max_iters(mut self, k: usize) -> Self {
        self.max_iters = k;
        self
    }
    pub fn with_tol(mut self, t: f64) -> Self {
        self.tol = t;
        self
    }
    pub fn with_diverge_above(mut self, d: f64) -> Self {
        self.diverge_above = d;
        self
    }
}

/// One completed iteration, as seen by an [`Observer`].
#[derive(Debug, Clone, Copy)]
pub struct IterEvent {
    /// 0-based iteration index within the current run.
    pub iter: usize,
    /// The α chosen for this iteration.
    pub alpha: f64,
    /// Residual Frobenius norm *after* the update.
    pub residual: f64,
    /// Wall-clock seconds since the run started.
    pub elapsed_s: f64,
    /// Index of the batch member this event belongs to when the run is part
    /// of a [`crate::matfn::Solver::solve_batch`] call (0 for plain solves).
    /// A solver-level observer serving a batch uses this to attribute
    /// interleaved per-iteration events to the right job.
    pub job: usize,
}

/// Per-iteration callback: streamed residual trajectories for the
/// coordinator service, live plotting, etc. The engine invokes it once per
/// completed iteration, before the divergence check.
pub type Observer<'a> = &'a mut dyn FnMut(&IterEvent);

/// Optional per-run extensions threaded through an engine call: a warm-start
/// iterate `x0` (paper §C — e.g. the previous optimizer step's factor) and a
/// per-iteration [`Observer`]. Engines that cannot exploit a hook simply
/// ignore it; which engines honour `x0` is documented on
/// [`crate::matfn::MatFnSolver::solve_from`].
pub struct EngineHooks<'a> {
    pub x0: Option<&'a Mat>,
    pub observer: Option<Observer<'a>>,
    /// `(iterations, seconds)` added to every observer event — non-zero when
    /// one logical run is executed as chained engine calls (the warm-α
    /// phase), so streamed events stay continuous with the chained log.
    pub event_base: (usize, f64),
    /// Batch-member index stamped on every observer event (see
    /// [`IterEvent::job`]); 0 outside batched solves.
    pub job: usize,
}

impl<'a> EngineHooks<'a> {
    /// No hooks — the plain free-function entry points use this.
    pub fn none() -> EngineHooks<'static> {
        EngineHooks { x0: None, observer: None, event_base: (0, 0.0), job: 0 }
    }
}

/// Per-run record: residual trajectory, chosen α's, GEMM counts, wall time.
#[derive(Debug, Clone, Default)]
pub struct IterationLog {
    /// `residuals[k]` = ‖R_k‖_F *before* iteration k (so index 0 is the
    /// initial residual); one extra trailing entry is the final residual.
    pub residuals: Vec<f64>,
    /// α chosen at iteration k.
    pub alphas: Vec<f64>,
    /// Cumulative wall-clock seconds at the end of iteration k.
    pub times_s: Vec<f64>,
    pub gemm_calls: u64,
    pub wall_s: f64,
    pub converged: bool,
    pub diverged: bool,
}

impl IterationLog {
    pub fn iters(&self) -> usize {
        self.alphas.len()
    }
    pub fn final_residual(&self) -> f64 {
        self.residuals.last().copied().unwrap_or(f64::INFINITY)
    }
    pub fn initial_residual(&self) -> f64 {
        self.residuals.first().copied().unwrap_or(f64::INFINITY)
    }
    /// First iteration index whose *post*-residual is below `tol`
    /// (residuals[k+1] < tol), or None.
    pub fn iters_to_tol(&self, tol: f64) -> Option<usize> {
        self.residuals
            .iter()
            .skip(1)
            .position(|&r| r < tol)
            .map(|i| i + 1)
    }
    /// Wall time until the residual first drops below `tol`.
    pub fn time_to_tol(&self, tol: f64) -> Option<f64> {
        let k = self.iters_to_tol(tol)?;
        self.times_s.get(k - 1).copied()
    }
}

/// Records GEMM-count + time around an iteration loop. GEMMs are counted
/// through a thread-local [`GemmScope`], so runs on concurrent service
/// workers never inflate each other's `gemm_calls`. Optionally forwards each
/// iteration to an [`Observer`].
pub struct RunRecorder<'a> {
    sw: Stopwatch,
    gemm: GemmScope,
    pub log: IterationLog,
    observer: Option<Observer<'a>>,
    event_base: (usize, f64),
    job: usize,
    /// Chaos hook: iteration whose residual is replaced with NaN, if this
    /// run was scripted as a victim by [`crate::runtime::faultinject`].
    /// `None` always in production (the hook is inert unless a fault plan
    /// is installed).
    nan_at: Option<usize>,
}

impl<'a> RunRecorder<'a> {
    pub fn start(initial_residual: f64) -> RunRecorder<'a> {
        let mut log = IterationLog::default();
        log.residuals.push(initial_residual);
        RunRecorder {
            sw: Stopwatch::start(),
            gemm: GemmScope::begin(),
            log,
            observer: None,
            event_base: (0, 0.0),
            job: 0,
            nan_at: crate::runtime::faultinject::begin_solve(),
        }
    }

    /// Attach (or not) a per-iteration observer.
    pub fn with_observer(mut self, observer: Option<Observer<'a>>) -> Self {
        self.observer = observer;
        self
    }

    /// Offset observer events (see [`EngineHooks::event_base`]). Affects
    /// only what observers see, never the log itself.
    pub fn with_event_base(mut self, event_base: (usize, f64)) -> Self {
        self.event_base = event_base;
        self
    }

    /// Stamp observer events with a batch-member index (see
    /// [`IterEvent::job`]). Affects only what observers see.
    pub fn with_job(mut self, job: usize) -> Self {
        self.job = job;
        self
    }

    /// Record one completed iteration and notify the observer.
    pub fn step(&mut self, alpha: f64, post_residual: f64) {
        // Injected NaN takes the same observable path as a real numerical
        // breakdown: it lands in the log (and the observer stream), and
        // `step_guard`/`finish` below see the poisoned value.
        let post_residual =
            if self.nan_at == Some(self.log.alphas.len()) { f64::NAN } else { post_residual };
        self.log.alphas.push(alpha);
        self.log.residuals.push(post_residual);
        let elapsed_s = self.sw.elapsed_s();
        self.log.times_s.push(elapsed_s);
        if let Some(obs) = self.observer.as_mut() {
            let ev = IterEvent {
                iter: self.event_base.0 + self.log.alphas.len() - 1,
                alpha,
                residual: post_residual,
                elapsed_s: self.event_base.1 + elapsed_s,
                job: self.job,
            };
            obs(&ev);
        }
    }

    /// Record one completed iteration and report whether the loop must stop:
    /// `true` on a non-finite or diverging residual. This is the shared
    /// tail-of-loop check every engine used to hand-roll.
    pub fn step_guard(&mut self, stop: &StopRule, alpha: f64, post_residual: f64) -> bool {
        self.step(alpha, post_residual);
        // Guard on the *recorded* residual, which may have been poisoned by
        // an injected fault — the loop must stop exactly when the log says
        // the run broke down.
        let recorded = self.log.final_residual();
        !recorded.is_finite() || recorded > stop.diverge_above
    }

    pub fn finish(mut self, stop: &StopRule) -> IterationLog {
        self.log.wall_s = self.sw.elapsed_s();
        self.log.gemm_calls = self.gemm.calls();
        let fin = self.log.final_residual();
        self.log.converged = fin < stop.tol;
        self.log.diverged = !fin.is_finite() || fin > stop.diverge_above;
        self.log
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_accessors() {
        let mut rec = RunRecorder::start(1.0);
        rec.step(0.5, 0.5);
        rec.step(0.6, 1e-9);
        let log = rec.finish(&StopRule::default());
        assert_eq!(log.iters(), 2);
        assert_eq!(log.initial_residual(), 1.0);
        assert_eq!(log.final_residual(), 1e-9);
        assert!(log.converged);
        assert!(!log.diverged);
        assert_eq!(log.iters_to_tol(0.7), Some(1));
        assert_eq!(log.iters_to_tol(1e-8), Some(2));
        assert_eq!(log.iters_to_tol(1e-12), None);
        assert!(log.time_to_tol(0.7).is_some());
    }

    #[test]
    fn divergence_detected() {
        let mut rec = RunRecorder::start(1.0);
        rec.step(0.5, 1e15);
        let log = rec.finish(&StopRule::default());
        assert!(log.diverged);
        assert!(!log.converged);
    }

    #[test]
    fn alpha_mode_names() {
        assert_eq!(AlphaMode::Classic.name(), "classic");
        assert_eq!(AlphaMode::Sketched { p: 8 }.name(), "prism(p=8)");
        assert!(AlphaMode::Fixed(1.45).name().contains("1.45"));
    }

    #[test]
    fn stop_rule_builders() {
        let s = StopRule::default().with_max_iters(5).with_tol(1e-3).with_diverge_above(1e6);
        assert_eq!(s.max_iters, 5);
        assert_eq!(s.tol, 1e-3);
        assert_eq!(s.diverge_above, 1e6);
    }

    #[test]
    fn step_guard_detects_divergence_and_nan() {
        let stop = StopRule::default().with_diverge_above(10.0);
        let mut rec = RunRecorder::start(1.0);
        assert!(!rec.step_guard(&stop, 0.5, 2.0));
        assert!(rec.step_guard(&stop, 0.5, 11.0));
        let mut rec2 = RunRecorder::start(1.0);
        assert!(rec2.step_guard(&stop, 0.5, f64::NAN));
    }

    #[test]
    fn observer_sees_every_iteration() {
        let mut events: Vec<(usize, f64)> = Vec::new();
        let mut obs = |ev: &IterEvent| events.push((ev.iter, ev.residual));
        let stop = StopRule::default();
        let mut rec = RunRecorder::start(1.0).with_observer(Some(&mut obs));
        rec.step_guard(&stop, 0.5, 0.5);
        rec.step_guard(&stop, 0.6, 0.25);
        let log = rec.finish(&stop);
        assert_eq!(log.iters(), 2);
        assert_eq!(events, vec![(0, 0.5), (1, 0.25)]);
    }
}
