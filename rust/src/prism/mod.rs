//! The PRISM iteration engines — one per row of the paper's Table 1.
//!
//! Every engine comes in a *classic* variant (fixed Taylor coefficients,
//! i.e. the textbook iteration) and a *PRISM* variant (Step 4+5 of the
//! meta-algorithm: the last polynomial coefficient `α_k` is re-fitted each
//! iteration to the sketched spectrum of the residual).
//!
//! | module | target | Table 1 rows |
//! |---|---|---|
//! | [`sign`] | sign(A) | (derivation §4) |
//! | [`polar`] | U Vᵀ | rows 3–4 |
//! | [`sqrt`] | A^{1/2}, A^{-1/2} | rows 1–2 |
//! | [`inverse_newton`] | A^{-1/p} | row 5 |
//! | [`db_newton`] | A^{1/2}, A^{-1/2} | row 6 |
//! | [`chebyshev`] | A⁻¹ | row 7 |

pub mod driver;
pub mod fit;
pub mod sign;
pub mod polar;
pub mod sqrt;
pub mod inverse_newton;
pub mod db_newton;
pub mod chebyshev;

pub use driver::{AlphaMode, IterationLog, StopRule};
