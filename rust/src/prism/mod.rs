//! The PRISM iteration engines — one per row of the paper's Table 1.
//!
//! **Consumers should not call these engines directly.** The supported
//! surface is [`crate::matfn`]: plan a [`crate::matfn::Solver`] (by spec or
//! by registry name) and call `solve` — the solver owns the ping-pong
//! buffers and reuses them across same-shape calls, supports warm starts
//! (paper §C) and streams per-iteration residuals to an observer:
//!
//! ```
//! use prism::matfn::{registry, MatFnSolver};
//! use prism::{randmat, Rng};
//!
//! let mut rng = Rng::seed_from(7);
//! let a = randmat::gaussian(&mut rng, 64, 32);
//! let mut solver = registry::resolve("prism5-polar").unwrap();
//! assert!(solver.solve(&a, &mut rng).log.converged);
//! ```
//!
//! The free functions in these modules (`polar_prism`, `sqrt_prism`, …)
//! remain as thin wrappers that allocate a throwaway workspace per call;
//! the bench harnesses and unit tests use them, new code should not. Each
//! engine's real body is a `pub(crate)` `*_in` core that draws its buffers
//! from a caller-owned [`crate::linalg::gemm::Workspace`] and honours the
//! [`driver::EngineHooks`] (warm start + observer).
//!
//! Every engine comes in a *classic* variant (fixed Taylor coefficients,
//! i.e. the textbook iteration) and a *PRISM* variant (Step 4+5 of the
//! meta-algorithm: the last polynomial coefficient `α_k` is re-fitted each
//! iteration to the sketched spectrum of the residual).
//!
//! | module | target | Table 1 rows | registry keys |
//! |---|---|---|---|
//! | [`sign`] | sign(A) | (derivation §4) | `prism5-sign`, `ns-sign`, … |
//! | [`polar`] | U Vᵀ | rows 3–4 | `prism5-polar`, `pe-polar`, … |
//! | [`sqrt`] | A^{1/2}, A^{-1/2} | rows 1–2 | `prism5-sqrt`, `prism5-invsqrt`, … |
//! | [`inverse_newton`] | A^{-1/p} | row 5 | `invnewton-invroot2`, … |
//! | [`db_newton`] | A^{1/2}, A^{-1/2} | row 6 | `newton-sqrt`, `newton-invsqrt`, … |
//! | [`chebyshev`] | A⁻¹ | row 7 | `cheb-inverse`, … |
//!
//! [`mixed`] holds the f32-iterate / f64-guard twins of the polar and
//! coupled-sqrt engines — the `Precision::Mixed` backend selected through
//! [`crate::matfn::SolverSpec::with_precision`], not a separate engine row
//! (same iterations, different arithmetic; see its module docs for the
//! accuracy contract). [`lowrank`] holds the randomized range-finder used
//! by `MatFnTask::RectPolar`'s `RectStrategy::RangeFinder` route (registry
//! keys `prism5-rectpolar`, `ns-rectpolar`, …).

pub mod driver;
pub mod fit;
pub mod lowrank;
pub mod mixed;
pub mod sign;
pub mod polar;
pub mod sqrt;
pub mod inverse_newton;
pub mod db_newton;
pub mod chebyshev;

pub use driver::{AlphaMode, EngineHooks, IterEvent, IterationLog, Observer, StopRule};
