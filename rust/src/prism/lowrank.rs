//! Randomized range-finder orthogonalization for genuinely low-rank
//! updates — [`RectStrategy::RangeFinder`]'s engine.
//!
//! For a low-rank `A` (think a rank-r gradient accumulated from r
//! microbatches), even the Gram route wastes work: it solves a p×p inverse
//! root when only an r-dimensional subspace is active — and worse, the Gram
//! matrix of a rank-deficient `A` is singular, which the inverse root cannot
//! tolerate. The Halko–Martinsson–Tropp range finder sidesteps both:
//!
//! 1. sketch `Y = A·Ωᵀ` with a Gaussian test matrix `Ω` (k×n, drawn through
//!    [`crate::sketch::SketchKind::fill`] so the RNG-stream contract holds);
//! 2. orthonormalize `Y` in place (modified Gram–Schmidt, rank-revealing) —
//!    `Q₁` spans range(A) almost surely when `k ≥ rank(A)`;
//! 3. project to the small core `C = Q₁ᵀA` (r×n) and polar-solve it with the
//!    ordinary PRISM iteration;
//! 4. expand back: `Q = Q₁·polar(C)`.
//!
//! Since `Q₁ᵀQ₁ = I`, the SVD of `Q₁C` is `(Q₁U_c)ΣVᵀ`, so
//! `polar(Q₁C) = Q₁·polar(C)` exactly. When `rank(A) ≤ k` this equals the
//! polar factor of `A` restricted to its range: a partial isometry `Q` with
//! `QᵀA` symmetric PSD — the natural orthogonalization of a rank-deficient
//! update (a full-rank polar factor would fabricate arbitrary directions in
//! the null space). The core solve runs in f64; the sketch and projection
//! are one skinny GEMM each, so there is no mixed-precision variant.

use super::driver::{AlphaMode, EngineHooks, RunRecorder, StopRule};
use super::polar::{polar_prism_in, PolarOpts, PolarResult};
use crate::linalg::gemm::{global_engine, Workspace};
use crate::linalg::{orthonormalize_columns, Mat};
use crate::rng::Rng;
use crate::sketch::SketchKind;

/// Options for a range-finder polar run. `rank` is the sketch width k —
/// exactness requires `k ≥ rank(A)`; the caller knows the rank, we don't.
pub(crate) struct RangeOpts {
    pub d: usize,
    pub alpha: AlphaMode,
    pub stop: StopRule,
    pub rank: usize,
}

/// Workspace-pooled range-finder polar. Wide inputs recurse through the
/// transpose like [`polar_prism_in`]; `hooks.x0` is ignored (the core lives
/// in the sketched basis, where a previous full-size factor means nothing).
pub(crate) fn range_polar_in(
    a: &Mat,
    opts: &RangeOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> PolarResult {
    let (m, n) = a.shape();
    if m < n {
        let EngineHooks { x0: _, observer, event_base, job } = hooks;
        let mut at = ws.take(n, m);
        a.transpose_into(&mut at);
        // The `match` re-coerces the observer's trait-object lifetime for
        // the shorter-lived recursive hooks (Option's variance cannot).
        let hooks_t = EngineHooks {
            x0: None,
            observer: match observer {
                Some(o) => Some(o),
                None => None,
            },
            event_base,
            job,
        };
        let r = range_polar_in(&at, opts, rng, ws, hooks_t);
        ws.put(at);
        return PolarResult { q: r.q.transpose(), log: r.log, transposed: true };
    }
    let eng = global_engine();
    let k = opts.rank.clamp(1, n);
    let mut omega = ws.take(k, n);
    SketchKind::Gaussian.fill(&mut omega, rng);
    // Range sample Y = A·Ωᵀ (m×k) — one skinny GEMM.
    let mut y = ws.take(m, k);
    eng.matmul_a_bt_into(&mut y, a, &omega);
    let r = orthonormalize_columns(&mut y);
    if r == 0 {
        // A annihilated the whole sketch: A is (numerically) zero on a
        // full-measure subspace, and the zero matrix's partial-isometry
        // polar factor is zero.
        let out = PolarResult {
            q: Mat::zeros(m, n),
            log: RunRecorder::start(0.0).finish(&opts.stop),
            transposed: false,
        };
        ws.put(omega);
        ws.put(y);
        return out;
    }
    // Rank-deficient sketches are compacted left by the orthonormalizer;
    // borrow the full panel when it kept everything (the common, warm,
    // allocation-free path) and carve the kept block otherwise.
    let q1_store;
    let q1: &Mat = if r == k {
        &y
    } else {
        q1_store = y.block(0, 0, m, r);
        &q1_store
    };
    // Core C = Q₁ᵀA (r×n): the whole action of A inside the captured range.
    let mut c = ws.take(r, n);
    eng.matmul_at_b_into(&mut c, q1, a);
    let popts = PolarOpts { d: opts.d, alpha: opts.alpha, stop: opts.stop };
    let EngineHooks { x0: _, observer, event_base, job } = hooks;
    let core_hooks = EngineHooks {
        x0: None,
        observer: match observer {
            Some(o) => Some(o),
            None => None,
        },
        event_base,
        job,
    };
    // The r×n core is wide for r < n; polar_prism_in's own transpose
    // recursion handles that orientation.
    let core = polar_prism_in(&c, &popts, rng, ws, core_hooks);
    // Expand back: Q = Q₁ · polar(C) (m×n).
    let mut q = ws.take(m, n);
    eng.matmul_into(&mut q, q1, &core.q);
    let out = PolarResult { q: q.clone(), log: core.log, transposed: false };
    ws.put(c);
    ws.put(q);
    ws.put(omega);
    ws.put(y);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul;
    use crate::linalg::svd::svd;
    use crate::randmat;

    fn opts(rank: usize) -> RangeOpts {
        RangeOpts {
            d: 2,
            alpha: AlphaMode::Sketched { p: 8 },
            stop: StopRule::default().with_max_iters(200).with_tol(1e-12),
            rank,
        }
    }

    /// Rank-r A with a known clean spectrum: B (m×r) · Cᵀ (r×n).
    fn lowrank(rng: &mut Rng, m: usize, n: usize, r: usize) -> Mat {
        let b = randmat::orthogonal(rng, m, r);
        let s = randmat::logspace(0.2, 1.0, r);
        let c = randmat::with_spectrum(rng, n, r, &s);
        matmul(&b, &c.transpose())
    }

    #[test]
    fn full_rank_sketch_matches_svd_polar() {
        // k = n on a full-rank tall A captures the whole row space, so the
        // range-finder route must agree with the exact polar factor.
        let mut rng = Rng::seed_from(11);
        let s = randmat::logspace(0.1, 1.0, 10);
        let a = randmat::with_spectrum(&mut rng, 30, 10, &s);
        let mut ws = Workspace::new();
        let out = range_polar_in(&a, &opts(10), &mut rng, &mut ws, EngineHooks::none());
        assert!(out.log.converged);
        let err = out.q.sub(&svd(&a).polar_factor()).max_abs();
        assert!(err < 1e-8, "range polar err {err}");
    }

    #[test]
    fn lowrank_polar_is_partial_isometry_with_psd_core() {
        let mut rng = Rng::seed_from(12);
        for (m, n) in [(40usize, 24usize), (24, 40)] {
            let a = lowrank(&mut rng, m, n, 3);
            let mut ws = Workspace::new();
            let out = range_polar_in(&a, &opts(6), &mut rng, &mut ws, EngineHooks::none());
            assert_eq!(out.q.shape(), (m, n));
            // Q is a partial isometry on range(A): (QᵀQ)² = QᵀQ.
            let g = matmul(&out.q.transpose(), &out.q);
            let proj_err = matmul(&g, &g).sub(&g).max_abs();
            assert!(proj_err < 1e-8, "({m},{n}): projector err {proj_err}");
            // Polar property: H = QᵀA is symmetric (and Q·H reconstructs A).
            let h = matmul(&out.q.transpose(), &a);
            assert!(h.sub(&h.transpose()).max_abs() < 1e-8, "({m},{n}): H not symmetric");
            let rec_err = matmul(&out.q, &h).sub(&a).max_abs();
            assert!(rec_err < 1e-8, "({m},{n}): reconstruction err {rec_err}");
        }
    }

    #[test]
    fn zero_input_yields_zero_factor() {
        let mut rng = Rng::seed_from(13);
        let a = Mat::zeros(20, 8);
        let mut ws = Workspace::new();
        let out = range_polar_in(&a, &opts(4), &mut rng, &mut ws, EngineHooks::none());
        assert_eq!(out.q, Mat::zeros(20, 8));
        assert!(out.log.converged);
    }

    #[test]
    fn repeated_calls_are_deterministic() {
        let mut ws = Workspace::new();
        let a = lowrank(&mut Rng::seed_from(14), 32, 16, 4);
        let q1 =
            range_polar_in(&a, &opts(8), &mut Rng::seed_from(7), &mut ws, EngineHooks::none()).q;
        let q2 =
            range_polar_in(&a, &opts(8), &mut Rng::seed_from(7), &mut ws, EngineHooks::none()).q;
        assert_eq!(q1, q2);
    }
}
