//! Baseline algorithms the paper compares against.
//!
//! * [`polar_express`] — PolarExpress (Amsel et al. 2025): per-iteration
//!   minimax-optimal odd degree-5 polynomials on a prescribed singular-value
//!   interval, constructed here by a Remez/equioscillation solver and
//!   precomputed for the paper's σ_min = 10⁻³ tuning. Includes the coupled
//!   form for (inverse) square roots (paper footnote 2).
//! * [`eigen_fn`] — exact matrix functions via eigendecomposition/SVD, the
//!   Shampoo default the paper benchmarks against in Fig. 5.
//! * [`cans`] — a Chebyshev-type accelerated Newton–Schulz in the spirit of
//!   Grishina et al. 2025: first-iteration interval rescaling + classical
//!   updates afterwards.

pub mod polar_express;
pub mod eigen_fn;
pub mod cans;
