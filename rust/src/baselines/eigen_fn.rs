//! Exact matrix functions via eigendecomposition / SVD — the baseline the
//! paper's Shampoo experiment calls "eigen-decomposition" (Fig. 5) and the
//! correctness oracle for every iterative engine.

use crate::linalg::eigen::symmetric_eigen;
use crate::linalg::svd::svd;
use crate::linalg::Mat;
use crate::util::{Error, Result};

/// `A^{1/2}` for symmetric PSD `A` (eigenvalues clamped at 0).
pub fn sqrt_eigen(a: &Mat) -> Mat {
    symmetric_eigen(a).apply_fn(|w| w.max(0.0).sqrt())
}

/// `A^{-1/2}` with damping: `(A + εI)^{-1/2}` — Shampoo's convention.
pub fn inv_sqrt_eigen(a: &Mat, eps: f64) -> Mat {
    symmetric_eigen(a).apply_fn(|w| 1.0 / (w.max(0.0) + eps).sqrt())
}

/// `A^{-1/p}` with damping.
pub fn inv_root_eigen(a: &Mat, p: usize, eps: f64) -> Result<Mat> {
    if p == 0 {
        return Err(Error::Parse("p must be >= 1".into()));
    }
    Ok(symmetric_eigen(a).apply_fn(|w| (w.max(0.0) + eps).powf(-1.0 / p as f64)))
}

/// Exact polar factor via SVD (both orientations).
pub fn polar_eigen(a: &Mat) -> Mat {
    let (m, n) = a.shape();
    if m >= n {
        svd(a).polar_factor()
    } else {
        svd(&a.transpose()).polar_factor().transpose()
    }
}

/// `sign(A)` for symmetric `A`.
pub fn sign_eigen(a: &Mat) -> Mat {
    symmetric_eigen(a).apply_fn(|w| if w >= 0.0 { 1.0 } else { -1.0 })
}

/// `A⁻¹` for symmetric full-rank `A`.
pub fn inverse_eigen(a: &Mat) -> Mat {
    symmetric_eigen(a).apply_fn(|w| 1.0 / w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b};
    use crate::randmat;
    use crate::rng::Rng;

    #[test]
    fn sqrt_and_invsqrt_consistent() {
        let mut rng = Rng::seed_from(1);
        let w = randmat::logspace(0.01, 1.0, 10);
        let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
        let s = sqrt_eigen(&a);
        assert!(matmul(&s, &s).sub(&a).max_abs() < 1e-9);
        let is = inv_sqrt_eigen(&a, 0.0);
        assert!(matmul(&s, &is).sub(&Mat::eye(10)).max_abs() < 1e-8);
    }

    #[test]
    fn damping_regularises() {
        let mut rng = Rng::seed_from(2);
        // Singular matrix: rank deficient.
        let g = Mat::gaussian(&mut rng, 10, 3, 1.0);
        let a = crate::linalg::gemm::syrk_a_at(&g); // 10x10 rank 3
        let is = inv_sqrt_eigen(&a, 1e-4);
        assert!(!is.has_non_finite());
    }

    #[test]
    fn inv_root_p4() {
        let mut rng = Rng::seed_from(3);
        let w = randmat::logspace(0.1, 1.0, 8);
        let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
        let r = inv_root_eigen(&a, 4, 0.0).unwrap();
        let r2 = matmul(&r, &r);
        let r4 = matmul(&r2, &r2);
        assert!(matmul(&r4, &a).sub(&Mat::eye(8)).max_abs() < 1e-8);
        assert!(inv_root_eigen(&a, 0, 0.0).is_err());
    }

    #[test]
    fn polar_orthogonal_both_orientations() {
        let mut rng = Rng::seed_from(4);
        for shape in [(12, 7), (7, 12)] {
            let a = randmat::gaussian(&mut rng, shape.0, shape.1, );
            let q = polar_eigen(&a);
            assert_eq!(q.shape(), shape);
            let k = shape.0.min(shape.1);
            let g = if shape.0 >= shape.1 {
                matmul_at_b(&q, &q)
            } else {
                crate::linalg::gemm::syrk_a_at(&q)
            };
            assert!(g.sub(&Mat::eye(k)).max_abs() < 1e-8);
        }
    }

    #[test]
    fn inverse_eigen_matches_identity() {
        let mut rng = Rng::seed_from(6);
        let w = randmat::logspace(0.05, 1.0, 7);
        let a = randmat::sym_with_spectrum(&mut rng, 7, &w);
        let inv = inverse_eigen(&a);
        assert!(matmul(&a, &inv).sub(&Mat::eye(7)).max_abs() < 1e-8);
    }

    #[test]
    fn sign_eigen_squares_to_identity() {
        let mut rng = Rng::seed_from(5);
        let w = vec![-0.9, -0.1, 0.2, 0.8];
        let a = randmat::sym_with_spectrum(&mut rng, 4, &w);
        let s = sign_eigen(&a);
        assert!(matmul(&s, &s).sub(&Mat::eye(4)).max_abs() < 1e-9);
    }
}
