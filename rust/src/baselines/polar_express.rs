//! PolarExpress (Amsel et al. 2025): composition of per-iteration
//! minimax-optimal **odd degree-5** polynomials for the polar/sign problem.
//!
//! Stage k solves
//! `p_k = argmin_{p odd, deg 5} max_{x ∈ [ℓ_k, u_k]} |p(x) − 1|`
//! by Remez/equioscillation (4 alternation points for 3 free coefficients),
//! then the interval advances to `[ℓ_{k+1}, u_{k+1}] = [1 − E_k, 1 + E_k]`.
//!
//! The paper's experiments use the variant optimised for σ_min = 10⁻³
//! ([`PolarExpress::paper_default`]); because composition bakes the interval
//! in **ahead of time**, a mismatch between the assumed and actual σ_min is
//! exactly what Fig. 1 shows degrading its convergence — the effect this
//! reproduction must (and does) exhibit.

use crate::linalg::decomp::lu_solve;
use crate::linalg::gemm::{global_engine, matmul, syrk_at_a, GemmEngine, Workspace};
use crate::linalg::Mat;
use crate::prism::driver::{EngineHooks, IterationLog, RunRecorder, StopRule};
use crate::util::{Error, Result};

/// One stage's odd polynomial `p(x) = a x + b x³ + c x⁵`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OddPoly5 {
    pub a: f64,
    pub b: f64,
    pub c: f64,
}

impl OddPoly5 {
    pub fn eval(&self, x: f64) -> f64 {
        let x2 = x * x;
        x * (self.a + x2 * (self.b + x2 * self.c))
    }
}

/// Remez solve: minimax odd degree-5 approximation of the constant 1 on
/// `[l, u]`. Returns (polynomial, equioscillation error E).
pub fn remez_odd5(l: f64, u: f64) -> Result<(OddPoly5, f64)> {
    if !(0.0 < l && l < u) {
        return Err(Error::Parse(format!("remez: bad interval [{l}, {u}]")));
    }
    // Initial reference: 4 Chebyshev points.
    let mut pts: Vec<f64> = (0..4)
        .map(|i| {
            let t = ((2 * i + 1) as f64 * std::f64::consts::PI / 8.0).cos();
            0.5 * (l + u) + 0.5 * (u - l) * t
        })
        .collect();
    pts.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut poly = OddPoly5 { a: 0.0, b: 0.0, c: 0.0 };
    let mut err = f64::INFINITY;
    for _iter in 0..60 {
        // Solve p(x_i) + (−1)^i E = 1 for (a, b, c, E). The columns
        // (x, x³, x⁵) become nearly collinear when the interval is tiny, so
        // we equilibrate columns before the LU solve and unscale after.
        let mut m = Mat::zeros(4, 4);
        let rhs = [1.0; 4];
        for (i, &x) in pts.iter().enumerate() {
            m[(i, 0)] = x;
            m[(i, 1)] = x * x * x;
            m[(i, 2)] = x * x * x * x * x;
            m[(i, 3)] = if i % 2 == 0 { 1.0 } else { -1.0 };
        }
        let mut col_scale = [1.0_f64; 4];
        for j in 0..4 {
            let mx = (0..4).map(|i| m[(i, j)].abs()).fold(0.0_f64, f64::max);
            if mx > 0.0 {
                col_scale[j] = mx;
                for i in 0..4 {
                    m[(i, j)] /= mx;
                }
            }
        }
        let mut sol = lu_solve(&m, &rhs)?;
        for j in 0..4 {
            sol[j] /= col_scale[j];
        }
        poly = OddPoly5 { a: sol[0], b: sol[1], c: sol[2] };
        let e_mag = sol[3].abs();

        // Exchange: find extrema of e(x) = p(x) − 1 on a dense grid.
        let grid = 4000;
        let mut best: Vec<(f64, f64)> = Vec::new(); // (x, e) per alternation segment
        let mut cur_sign = 0.0;
        for gi in 0..=grid {
            let x = l + (u - l) * gi as f64 / grid as f64;
            let e = poly.eval(x) - 1.0;
            let s = e.signum();
            if s != cur_sign {
                best.push((x, e));
                cur_sign = s;
            } else if let Some(last) = best.last_mut() {
                if e.abs() > last.1.abs() {
                    *last = (x, e);
                }
            }
        }
        // Keep the 4 consecutive alternating extrema with the largest error.
        if best.len() > 4 {
            let mut best_window = 0;
            let mut best_mag = -1.0;
            for w in 0..=best.len() - 4 {
                let mag = best[w..w + 4].iter().map(|p| p.1.abs()).fold(f64::MAX, f64::min);
                if mag > best_mag {
                    best_mag = mag;
                    best_window = w;
                }
            }
            best = best[best_window..best_window + 4].to_vec();
        }
        if best.len() < 4 {
            // Degenerate (interval already tiny) — accept current solution.
            return Ok((poly, e_mag));
        }
        let new_pts: Vec<f64> = best.iter().map(|p| p.0).collect();
        let max_e = best.iter().map(|p| p.1.abs()).fold(0.0, f64::max);
        let min_e = best.iter().map(|p| p.1.abs()).fold(f64::MAX, f64::min);
        pts = new_pts;
        err = max_e;
        // Equioscillated within tolerance ⇒ done.
        if max_e - min_e <= 1e-12 * max_e.max(1e-300) {
            break;
        }
    }
    Ok((poly, err))
}

/// A precomputed PolarExpress schedule.
#[derive(Debug, Clone)]
pub struct PolarExpress {
    pub stages: Vec<OddPoly5>,
    /// Interval lower edges per stage (diagnostics).
    pub intervals: Vec<(f64, f64)>,
}

impl PolarExpress {
    /// Build a schedule starting from `σ ∈ [l0, 1]`.
    pub fn build(l0: f64, num_stages: usize) -> Result<PolarExpress> {
        let mut stages = Vec::with_capacity(num_stages);
        let mut intervals = Vec::with_capacity(num_stages);
        let (mut l, mut u) = (l0, 1.0);
        for _ in 0..num_stages {
            if u - l < 1e-9 {
                break; // asymptotic regime: classic NS takes over (see stage())
            }
            let (p, e) = match remez_odd5(l, u) {
                Ok(r) => r,
                // Ill-conditioned tiny interval: the table is long enough —
                // remaining iterations use the classic NS asymptotic stage.
                Err(_) => break,
            };
            intervals.push((l, u));
            stages.push(p);
            l = (1.0 - e).max(1e-12);
            u = 1.0 + e;
            if e < 1e-12 {
                break;
            }
        }
        if stages.is_empty() {
            return Err(Error::Numerical(format!(
                "polar-express: no stages built for l0={l0}"
            )));
        }
        Ok(PolarExpress { stages, intervals })
    }

    /// The paper's variant: optimised for σ_min = 10⁻³ (Algorithm 1 of
    /// Amsel et al.), 12 stages — enough to reach f64 convergence on its
    /// design interval.
    pub fn paper_default() -> PolarExpress {
        PolarExpress::build(1e-3, 12).expect("remez build failed")
    }

    /// Stage polynomial for iteration k. Past the precomputed table the
    /// spectrum sits in a tiny interval around 1, where the right update is
    /// the classical 5th-order Newton–Schulz polynomial
    /// `p(x) = (15x − 10x³ + 3x⁵)/8` (fixed point at 1, quadratic
    /// contraction) — this matches PolarExpress' practice of appending NS
    /// iterations after its schedule.
    pub fn stage(&self, k: usize) -> OddPoly5 {
        if k < self.stages.len() {
            self.stages[k]
        } else {
            OddPoly5 { a: 15.0 / 8.0, b: -10.0 / 8.0, c: 3.0 / 8.0 }
        }
    }

    /// Apply one stage to a rectangular iterate:
    /// `X ← X (aI + bG + cG²)`, `G = XᵀX`.
    pub fn apply(&self, x: &Mat, k: usize) -> Mat {
        let p = self.stage(k);
        let g = syrk_at_a(x);
        let g2 = matmul(&g, &g);
        let mut q = g.scaled(p.b);
        q.axpy(p.c, &g2);
        q.add_diag(p.a);
        matmul(x, &q)
    }

    /// Full polar run: `X₀ = A/‖A‖_F`, iterate stages until `stop`. Thin
    /// wrapper over [`PolarExpress::polar_in`] with a throwaway workspace.
    pub fn polar(&self, a: &Mat, stop: &StopRule) -> (Mat, IterationLog) {
        self.polar_in(a, stop, &mut Workspace::new(), EngineHooks::none())
    }

    /// Workspace-pooled polar core; runs allocation-free from the second
    /// same-shape call onward, like the PRISM engines it is benchmarked
    /// against. `hooks.x0` warm-starts at `X₀ = x0`, but note the schedule is
    /// *precomputed* — stage k still assumes the design interval, so warm
    /// starts mainly skip the lift-off phase on near-orthogonal inputs.
    pub(crate) fn polar_in(
        &self,
        a: &Mat,
        stop: &StopRule,
        ws: &mut Workspace,
        hooks: EngineHooks<'_>,
    ) -> (Mat, IterationLog) {
        let (m, n) = a.shape();
        if m < n {
            let EngineHooks { x0, observer, event_base, job } = hooks;
            let mut at = ws.take(n, m);
            a.transpose_into(&mut at);
            let x0t = x0.map(|x0| {
                assert_eq!(x0.shape(), (m, n), "polar-express: x0 shape mismatch");
                let mut t = ws.take(n, m);
                x0.transpose_into(&mut t);
                t
            });
            // The `match` re-coerces the observer's trait-object lifetime
            // for the shorter-lived recursive hooks (Option's variance
            // cannot).
            let hooks_t = EngineHooks {
                x0: x0t.as_ref(),
                observer: match observer {
                    Some(o) => Some(o),
                    None => None,
                },
                event_base,
                job,
            };
            let (q, log) = self.polar_in(&at, stop, ws, hooks_t);
            ws.put(at);
            if let Some(t) = x0t {
                ws.put(t);
            }
            return (q.transpose(), log);
        }
        let eng = global_engine();
        let mut x = ws.take(m, n);
        match hooks.x0 {
            Some(x0) => {
                assert_eq!(x0.shape(), (m, n), "polar-express: x0 shape mismatch");
                x.copy_from(x0);
            }
            None => {
                x.copy_from(a);
                x.scale(1.0 / a.fro_norm().max(1e-300));
            }
        }
        let mut xn = ws.take(m, n);
        let mut g = ws.take(n, n);
        let mut g2 = ws.take(n, n);
        let mut q = ws.take(n, n);
        let mut rbuf = ws.take(n, n);

        let mut rn = polar_res(&eng, &mut rbuf, &x);
        let mut rec = RunRecorder::start(rn)
            .with_observer(hooks.observer)
            .with_event_base(hooks.event_base)
            .with_job(hooks.job);
        for k in 0..stop.max_iters {
            if rn < stop.tol {
                break;
            }
            let p = self.stage(k);
            eng.syrk_at_a_into(&mut g, &x);
            eng.matmul_into(&mut g2, &g, &g);
            q.copy_from(&g);
            q.scale(p.b);
            q.axpy(p.c, &g2);
            q.add_diag(p.a);
            eng.matmul_into(&mut xn, &x, &q);
            std::mem::swap(&mut x, &mut xn);
            rn = polar_res(&eng, &mut rbuf, &x);
            if rec.step_guard(stop, p.a, rn) {
                break;
            }
        }
        let out = (x.clone(), rec.finish(stop));
        ws.put(x);
        ws.put(xn);
        ws.put(g);
        ws.put(g2);
        ws.put(q);
        ws.put(rbuf);
        out
    }

    /// Coupled form for SPD `A` (paper footnote 2, via Theorem 3):
    /// `X₀ = Ā`, `Y₀ = I`, `M = Y X`, `X ← X q(M)`, `Y ← q(M) Y` with
    /// `q(t) = aI + b t + c t²`; `X → Ā^{1/2}`, `Y → Ā^{-1/2}`.
    pub fn sqrt_coupled(&self, a: &Mat, stop: &StopRule) -> (Mat, Mat, IterationLog) {
        self.sqrt_coupled_in(a, stop, &mut Workspace::new(), EngineHooks::none())
    }

    /// Workspace-pooled coupled-sqrt core (`hooks.x0` is ignored — the
    /// coupled pair cannot resume from `X` alone).
    pub(crate) fn sqrt_coupled_in(
        &self,
        a: &Mat,
        stop: &StopRule,
        ws: &mut Workspace,
        hooks: EngineHooks<'_>,
    ) -> (Mat, Mat, IterationLog) {
        let eng = global_engine();
        let n = a.rows();
        let c = a.fro_norm().max(1e-300);
        let mut x = ws.take(n, n);
        x.copy_from(a);
        x.scale(1.0 / c);
        let mut y = ws.take(n, n);
        y.fill_with(0.0);
        y.add_diag(1.0);
        let mut xn = ws.take(n, n);
        let mut yn = ws.take(n, n);
        let mut m = ws.take(n, n);
        let mut m2 = ws.take(n, n);
        let mut q = ws.take(n, n);
        let mut rbuf = ws.take(n, n);

        let mut rn = coupled_res(&eng, &mut rbuf, &x, &y);
        let mut rec = RunRecorder::start(rn)
            .with_observer(hooks.observer)
            .with_event_base(hooks.event_base)
            .with_job(hooks.job);
        for k in 0..stop.max_iters {
            if rn < stop.tol {
                break;
            }
            let p = self.stage(k);
            eng.matmul_into(&mut m, &y, &x);
            eng.matmul_into(&mut m2, &m, &m);
            q.copy_from(&m);
            q.scale(p.b);
            q.axpy(p.c, &m2);
            q.add_diag(p.a);
            eng.matmul_into(&mut xn, &x, &q);
            std::mem::swap(&mut x, &mut xn);
            eng.matmul_into(&mut yn, &q, &y);
            std::mem::swap(&mut y, &mut yn);
            rn = coupled_res(&eng, &mut rbuf, &x, &y);
            if rec.step_guard(stop, p.a, rn) {
                break;
            }
        }
        let sc = c.sqrt();
        let out = (x.scaled(sc), y.scaled(1.0 / sc), rec.finish(stop));
        ws.put(x);
        ws.put(y);
        ws.put(xn);
        ws.put(yn);
        ws.put(m);
        ws.put(m2);
        ws.put(q);
        ws.put(rbuf);
        out
    }
}

/// `‖I − XᵀX‖_F` into a reused residual buffer.
fn polar_res(eng: &GemmEngine, rbuf: &mut Mat, x: &Mat) -> f64 {
    eng.syrk_at_a_into(rbuf, x);
    rbuf.scale(-1.0);
    rbuf.add_diag(1.0);
    rbuf.fro_norm()
}

/// `‖I − X Y‖_F` into a reused residual buffer.
fn coupled_res(eng: &GemmEngine, rbuf: &mut Mat, x: &Mat, y: &Mat) -> f64 {
    eng.matmul_into(rbuf, x, y);
    rbuf.scale(-1.0);
    rbuf.add_diag(1.0);
    rbuf.fro_norm()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prism::polar::orthogonality_error;
    use crate::randmat;
    use crate::rng::Rng;

    #[test]
    fn remez_equioscillates() {
        let (p, e) = remez_odd5(1e-3, 1.0).unwrap();
        assert!(e > 0.0 && e < 1.0, "E={e}");
        // p maps [l, u] into [1−E, 1+E].
        for i in 0..=1000 {
            let x = 1e-3 + (1.0 - 1e-3) * i as f64 / 1000.0;
            let v = p.eval(x);
            assert!(v >= 1.0 - e - 1e-9 && v <= 1.0 + e + 1e-9, "x={x} p={v} E={e}");
        }
    }

    #[test]
    fn remez_beats_taylor_on_interval() {
        // The classical NS degree-5 polynomial x(1 + ξ/2 + 3ξ²/8), ξ = 1−x²,
        // has much larger worst-case error on [1e-2, 1] than the minimax.
        let (_p, e) = remez_odd5(1e-2, 1.0).unwrap();
        let ns_err = {
            let mut worst: f64 = 0.0;
            for i in 0..=1000 {
                let x: f64 = 1e-2 + (1.0 - 1e-2) * i as f64 / 1000.0;
                let xi = 1.0 - x * x;
                let v = x * (1.0 + 0.5 * xi + 0.375 * xi * xi);
                worst = worst.max((v - 1.0_f64).abs());
            }
            worst
        };
        assert!(e < ns_err, "minimax E={e} vs NS worst={ns_err}");
    }

    #[test]
    fn equioscillation_errors_shrink_monotonically() {
        // After the first stage the interval is [1−E_k, 1+E_k]; the E_k
        // (half-widths) must decrease strictly. (The very first width is
        // u₀−ℓ₀ = 1−1e-3 and the first E can exceed it — lifting σ = 1e-3
        // towards 1 with one degree-5 polynomial is nearly hopeless, which
        // is the whole reason the schedule is a composition.)
        let pe = PolarExpress::build(1e-3, 10).unwrap();
        let widths: Vec<f64> = pe.intervals.iter().skip(1).map(|(l, u)| u - l).collect();
        assert!(widths.len() >= 3, "expected several stages, got {widths:?}");
        for w in widths.windows(2) {
            assert!(w[1] < w[0], "widths: {widths:?}");
        }
    }

    #[test]
    fn polar_converges_on_design_interval() {
        let mut rng = Rng::seed_from(1);
        let pe = PolarExpress::paper_default();
        // σ_min = 1e-3 relative to σ_max: the design case.
        let s = randmat::logspace(1e-3, 1.0, 16);
        let a = randmat::with_spectrum(&mut rng, 24, 16, &s);
        let stop = StopRule::default().with_max_iters(40).with_tol(1e-7);
        let (q, log) = pe.polar(&a, &stop);
        assert!(log.converged, "res={}", log.final_residual());
        assert!(orthogonality_error(&q) < 1e-6);
    }

    #[test]
    fn mismatch_degrades_polar_express() {
        // Fig. 1's phenomenon: σ_min far below the tuned 1e-3 (relative to
        // the Frobenius-normalised σ_max) slows PolarExpress below PRISM.
        use crate::prism::polar::{polar_prism, PolarOpts};
        let mut rng = Rng::seed_from(2);
        let s = randmat::logspace(1e-9, 1.0, 24);
        let a = randmat::with_spectrum(&mut rng, 32, 24, &s);
        let stop = StopRule::default().with_max_iters(200).with_tol(1e-6);
        let pe = PolarExpress::paper_default();
        let (_, pe_log) = pe.polar(&a, &stop);
        let prism = polar_prism(&a, &PolarOpts::degree5().with_stop(stop), &mut rng);
        assert!(prism.log.converged);
        let ip = prism.log.iters_to_tol(1e-6).unwrap();
        let ipe = pe_log.iters_to_tol(1e-6).unwrap_or(stop.max_iters + 1);
        assert!(ip < ipe, "prism {ip} vs polar-express {ipe}");
    }

    #[test]
    fn sqrt_coupled_works() {
        let mut rng = Rng::seed_from(3);
        let w = randmat::logspace(1e-4, 1.0, 12);
        let a = randmat::sym_with_spectrum(&mut rng, 12, &w);
        let pe = PolarExpress::paper_default();
        let stop = StopRule::default().with_max_iters(60).with_tol(1e-8);
        let (sq, isq, log) = pe.sqrt_coupled(&a, &stop);
        assert!(log.converged, "res={}", log.final_residual());
        assert!(matmul(&sq, &sq).sub(&a).max_abs() < 1e-6);
        assert!(matmul(&sq, &isq).sub(&Mat::eye(12)).max_abs() < 1e-6);
    }

    #[test]
    fn wide_input_transposed() {
        let mut rng = Rng::seed_from(4);
        let a = randmat::gaussian(&mut rng, 8, 20);
        let pe = PolarExpress::paper_default();
        let stop = StopRule::default().with_max_iters(40);
        let (q, _log) = pe.polar(&a, &stop);
        assert_eq!(q.shape(), (8, 20));
        assert!(orthogonality_error(&q) < 1e-5);
    }
}
