//! CANS-style Chebyshev-accelerated Newton–Schulz (after Grishina et al.
//! 2025): rescale the iterate by an estimate of its top singular value so
//! the spectrum's upper edge sits at 1, then take the classical degree-5
//! step. The rescale plays the role of CANS' Chebyshev-optimal interval
//! mapping for the *upper* edge; unlike PRISM it does nothing for σ_min,
//! which is why it helps less on spectra with tiny singular values.

use crate::linalg::gemm::{global_engine, GemmEngine, Workspace};
use crate::linalg::norms::spectral_norm_est;
use crate::linalg::Mat;
use crate::prism::driver::{EngineHooks, IterationLog, RunRecorder, StopRule};
use crate::rng::Rng;

#[derive(Debug, Clone)]
pub struct CansOpts {
    pub stop: StopRule,
    /// Power-iteration steps for the σ_max estimate per iteration.
    pub norm_iters: usize,
    /// Rescale during the first this-many iterations only (the spectrum
    /// upper edge is ≈1 afterwards).
    pub rescale_iters: usize,
}

impl Default for CansOpts {
    fn default() -> Self {
        CansOpts { stop: StopRule::default(), norm_iters: 12, rescale_iters: 4 }
    }
}

/// Polar factor by rescaled classical degree-5 Newton–Schulz.
///
/// Thin wrapper over [`polar_cans_in`] with a throwaway workspace;
/// persistent callers go through [`crate::matfn::Solver`].
pub fn polar_cans(a: &Mat, opts: &CansOpts, rng: &mut Rng) -> (Mat, IterationLog) {
    polar_cans_in(a, opts, rng, &mut Workspace::new(), EngineHooks::none())
}

/// Workspace-pooled core. `hooks.x0` warm-starts at `X₀ = x0` (the rescale
/// phase still runs, so a near-orthogonal start is mapped onto σ_max ≈ 1 and
/// polished from there).
pub(crate) fn polar_cans_in(
    a: &Mat,
    opts: &CansOpts,
    rng: &mut Rng,
    ws: &mut Workspace,
    hooks: EngineHooks<'_>,
) -> (Mat, IterationLog) {
    let (m, n) = a.shape();
    if m < n {
        let EngineHooks { x0, observer, event_base, job } = hooks;
        let mut at = ws.take(n, m);
        a.transpose_into(&mut at);
        let x0t = x0.map(|x0| {
            assert_eq!(x0.shape(), (m, n), "cans: x0 shape mismatch");
            let mut t = ws.take(n, m);
            x0.transpose_into(&mut t);
            t
        });
        // The `match` re-coerces the observer's trait-object lifetime for
        // the shorter-lived recursive hooks (Option's variance cannot).
        let hooks_t = EngineHooks {
            x0: x0t.as_ref(),
            observer: match observer {
                Some(o) => Some(o),
                None => None,
            },
            event_base,
            job,
        };
        let (q, log) = polar_cans_in(&at, opts, rng, ws, hooks_t);
        ws.put(at);
        if let Some(t) = x0t {
            ws.put(t);
        }
        return (q.transpose(), log);
    }
    let eng = global_engine();
    let mut x = ws.take(m, n);
    match hooks.x0 {
        Some(x0) => {
            assert_eq!(x0.shape(), (m, n), "cans: x0 shape mismatch");
            x.copy_from(x0);
        }
        None => {
            x.copy_from(a);
            x.scale(1.0 / a.fro_norm().max(1e-300));
        }
    }

    // Ping-pong buffers from the pool — allocation-free from the second
    // same-shape call onward.
    let mut xn = ws.take(m, n);
    let mut r = ws.take(n, n);
    let mut r2 = ws.take(n, n);
    let mut g = ws.take(n, n);

    residual_into(&eng, &mut r, &x);
    let mut rec = RunRecorder::start(r.fro_norm())
        .with_observer(hooks.observer)
        .with_event_base(hooks.event_base)
        .with_job(hooks.job);
    for k in 0..opts.stop.max_iters {
        if r.fro_norm() < opts.stop.tol {
            break;
        }
        if k < opts.rescale_iters {
            // Map the top singular value to ~1 (divide by the estimate,
            // slightly inflated to stay below the NS convergence bound).
            let smax = spectral_norm_est(&x, opts.norm_iters, rng).max(1e-300);
            x.scale(1.0 / (smax * 1.0001));
            residual_into(&eng, &mut r, &x);
        }
        // Classical degree-5 step: X ← X(I + R/2 + 3R²/8).
        eng.matmul_into(&mut r2, &r, &r);
        g.copy_from(&r);
        g.scale(0.5);
        g.axpy(0.375, &r2);
        g.add_diag(1.0);
        eng.matmul_into(&mut xn, &x, &g);
        std::mem::swap(&mut x, &mut xn);
        residual_into(&eng, &mut r, &x);
        if rec.step_guard(&opts.stop, 0.375, r.fro_norm()) {
            break;
        }
    }
    let out = (x.clone(), rec.finish(&opts.stop));
    ws.put(x);
    ws.put(xn);
    ws.put(r);
    ws.put(r2);
    ws.put(g);
    out
}

/// `R = I − XᵀX` into a reused buffer.
fn residual_into(eng: &GemmEngine, r: &mut Mat, x: &Mat) {
    eng.syrk_at_a_into(r, x);
    r.scale(-1.0);
    r.add_diag(1.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prism::polar::orthogonality_error;
    use crate::randmat;

    #[test]
    fn cans_converges() {
        let mut rng = Rng::seed_from(1);
        let s = randmat::logspace(1e-3, 1.0, 16);
        let a = randmat::with_spectrum(&mut rng, 24, 16, &s);
        let opts = CansOpts { stop: StopRule::default().with_max_iters(80), ..Default::default() };
        let (q, log) = polar_cans(&a, &opts, &mut rng);
        assert!(log.converged, "res={}", log.final_residual());
        assert!(orthogonality_error(&q) < 1e-6);
    }

    #[test]
    fn rescaling_beats_plain_classic_early() {
        // With σ_max ≪ ‖A‖_F (many comparable singular values), the rescale
        // recovers most of the Frobenius-normalisation slack.
        use crate::prism::polar::{polar_prism, PolarOpts};
        let mut rng = Rng::seed_from(2);
        let a = randmat::gaussian(&mut rng, 64, 48);
        let stop = StopRule::default().with_max_iters(100).with_tol(1e-6);
        let opts = CansOpts { stop, ..Default::default() };
        let (_, cans_log) = polar_cans(&a, &opts, &mut rng);
        let classic = polar_prism(&a, &PolarOpts::classic(2).with_stop(stop), &mut rng);
        let icans = cans_log.iters_to_tol(1e-6).unwrap();
        let iclassic = classic.log.iters_to_tol(1e-6).unwrap();
        assert!(icans <= iclassic, "cans {icans} vs classic {iclassic}");
    }

    #[test]
    fn wide_matrix_ok() {
        let mut rng = Rng::seed_from(3);
        let a = randmat::gaussian(&mut rng, 10, 20);
        let (q, _log) = polar_cans(&a, &CansOpts::default(), &mut rng);
        assert_eq!(q.shape(), (10, 20));
    }
}
