//! Synthetic workloads for the training and service experiments.
//!
//! * `BlobsDataset` — image-like classification data (the Fig. 5 stand-in for
//!   CIFAR: 3072-dim inputs, k Gaussian class clusters with overlapping
//!   covariance, so training accuracy has headroom and preconditioning
//!   matters).
//! * `MarkovCorpus` — byte-level language-modelling corpus with Zipf-ish
//!   unigram statistics and order-1 Markov structure (the Fig. 6 stand-in
//!   for FineWeb at CPU scale).
//! * `GradientStream` — a stream of synthetic gradient matrices with
//!   HTMP-style spectra, driving the preconditioner-service benches.

use crate::linalg::Mat;
use crate::randmat;
use crate::rng::{zipf_cdf, Rng};

/// Gaussian-blob classification dataset.
pub struct BlobsDataset {
    pub dim: usize,
    pub classes: usize,
    pub xs: Vec<Vec<f64>>,
    pub ys: Vec<usize>,
}

impl BlobsDataset {
    /// `n` samples, `dim` features, `classes` clusters. Cluster centers at
    /// distance `sep`; within-cluster anisotropic noise so gradient
    /// covariances are ill-conditioned (this is what makes Shampoo shine).
    pub fn generate(rng: &mut Rng, n: usize, dim: usize, classes: usize, sep: f64) -> Self {
        let centers: Vec<Vec<f64>> = (0..classes)
            .map(|_| rng.normal_vec(dim).iter().map(|x| x * sep).collect())
            .collect();
        // Anisotropic scales shared across clusters: log-spaced 1.0 .. 0.05.
        let scales = randmat::logspace(0.05, 1.0, dim);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            let mut x = centers[c].clone();
            for (j, v) in x.iter_mut().enumerate() {
                *v += rng.normal() * scales[dim - 1 - (j % dim)];
            }
            xs.push(x);
            ys.push(c);
        }
        BlobsDataset { dim, classes, xs, ys }
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Deterministic train/val split (last `frac` goes to val).
    pub fn split(&self, val_frac: f64) -> (Vec<usize>, Vec<usize>) {
        let n = self.len();
        let nval = ((n as f64) * val_frac) as usize;
        let train: Vec<usize> = (0..n - nval).collect();
        let val: Vec<usize> = (n - nval..n).collect();
        (train, val)
    }

    /// Gather a batch as (X [b x dim], labels).
    pub fn batch(&self, idx: &[usize]) -> (Mat, Vec<usize>) {
        let b = idx.len();
        let mut x = Mat::zeros(b, self.dim);
        let mut y = Vec::with_capacity(b);
        for (r, &i) in idx.iter().enumerate() {
            x.row_mut(r).copy_from_slice(&self.xs[i]);
            y.push(self.ys[i]);
        }
        (x, y)
    }
}

/// Byte-level synthetic corpus with Zipf unigram + order-1 Markov structure.
pub struct MarkovCorpus {
    pub vocab: usize,
    pub tokens: Vec<u32>,
}

impl MarkovCorpus {
    pub fn generate(rng: &mut Rng, vocab: usize, len: usize) -> Self {
        // Each state prefers a small random successor set (Markov), weighted
        // by a global Zipf prior — gives LM-like bigram statistics.
        let cdf = zipf_cdf(vocab, 1.1);
        let succ: Vec<[u32; 4]> = (0..vocab)
            .map(|_| {
                [
                    rng.zipf(&cdf) as u32,
                    rng.zipf(&cdf) as u32,
                    rng.zipf(&cdf) as u32,
                    rng.zipf(&cdf) as u32,
                ]
            })
            .collect();
        let mut tokens = Vec::with_capacity(len);
        let mut state = rng.zipf(&cdf) as u32;
        for _ in 0..len {
            tokens.push(state);
            state = if rng.uniform() < 0.75 {
                succ[state as usize][rng.below(4)]
            } else {
                rng.zipf(&cdf) as u32
            };
        }
        MarkovCorpus { vocab, tokens }
    }

    /// Sample a batch of (input, target) windows: inputs `[b][t]`, targets
    /// shifted by one.
    pub fn sample_batch(
        &self,
        rng: &mut Rng,
        batch: usize,
        seq_len: usize,
    ) -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
        let max_start = self.tokens.len() - seq_len - 1;
        let mut xs = Vec::with_capacity(batch);
        let mut ys = Vec::with_capacity(batch);
        for _ in 0..batch {
            let s = rng.below(max_start);
            xs.push(self.tokens[s..s + seq_len].to_vec());
            ys.push(self.tokens[s + 1..s + seq_len + 1].to_vec());
        }
        (xs, ys)
    }

    /// Empirical unigram entropy in nats (lower bound on achievable loss is
    /// the conditional entropy; unigram entropy is an upper reference).
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

/// A stream of synthetic "gradient matrices" with controllable spectra, used
/// to load-test the preconditioner service the way training would.
pub struct GradientStream {
    rng: Rng,
    pub shapes: Vec<(usize, usize)>,
    pub kappa: f64,
    i: usize,
}

impl GradientStream {
    pub fn new(seed: u64, shapes: Vec<(usize, usize)>, kappa: f64) -> Self {
        GradientStream { rng: Rng::seed_from(seed), shapes, kappa, i: 0 }
    }

    /// Next (layer_id, matrix).
    pub fn next_grad(&mut self) -> (usize, Mat) {
        let layer = self.i % self.shapes.len();
        self.i += 1;
        let (n, m) = self.shapes[layer];
        let g = if n >= m {
            randmat::htmp(&mut self.rng, n, m, self.kappa)
        } else {
            randmat::htmp(&mut self.rng, m, n, self.kappa).transpose()
        };
        (layer, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_shapes_and_labels() {
        let mut rng = Rng::seed_from(1);
        let ds = BlobsDataset::generate(&mut rng, 100, 16, 4, 3.0);
        assert_eq!(ds.len(), 100);
        assert!(ds.ys.iter().all(|&y| y < 4));
        let (train, val) = ds.split(0.2);
        assert_eq!(train.len(), 80);
        assert_eq!(val.len(), 20);
        let (x, y) = ds.batch(&[0, 5, 7]);
        assert_eq!(x.shape(), (3, 16));
        assert_eq!(y.len(), 3);
    }

    #[test]
    fn blobs_classes_separable() {
        let mut rng = Rng::seed_from(2);
        let ds = BlobsDataset::generate(&mut rng, 200, 8, 2, 8.0);
        // Nearest-center classifier should beat chance comfortably.
        let mut centers = vec![vec![0.0; 8]; 2];
        let mut counts = [0usize; 2];
        for (x, &y) in ds.xs.iter().zip(&ds.ys) {
            for j in 0..8 {
                centers[y][j] += x[j];
            }
            counts[y] += 1;
        }
        for c in 0..2 {
            for j in 0..8 {
                centers[c][j] /= counts[c] as f64;
            }
        }
        let correct = ds
            .xs
            .iter()
            .zip(&ds.ys)
            .filter(|(x, &y)| {
                let d0: f64 = x.iter().zip(&centers[0]).map(|(a, b)| (a - b) * (a - b)).sum();
                let d1: f64 = x.iter().zip(&centers[1]).map(|(a, b)| (a - b) * (a - b)).sum();
                (if d0 < d1 { 0 } else { 1 }) == y
            })
            .count();
        assert!(correct > 150, "correct={correct}/200");
    }

    #[test]
    fn corpus_tokens_in_vocab() {
        let mut rng = Rng::seed_from(3);
        let c = MarkovCorpus::generate(&mut rng, 64, 5000);
        assert!(c.tokens.iter().all(|&t| (t as usize) < 64));
        let h = c.unigram_entropy();
        assert!(h > 0.5 && h < (64f64).ln(), "H={h}");
    }

    #[test]
    fn corpus_batches_shifted() {
        let mut rng = Rng::seed_from(4);
        let c = MarkovCorpus::generate(&mut rng, 32, 2000);
        let (xs, ys) = c.sample_batch(&mut rng, 4, 16);
        assert_eq!(xs.len(), 4);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(x.len(), 16);
            assert_eq!(y.len(), 16);
            // y is x shifted by one within the original stream:
            assert_eq!(&x[1..], &y[..15]);
        }
    }

    #[test]
    fn gradient_stream_cycles_shapes() {
        let mut gs = GradientStream::new(5, vec![(32, 16), (16, 32)], 1.0);
        let (l0, g0) = gs.next_grad();
        let (l1, g1) = gs.next_grad();
        let (l2, _) = gs.next_grad();
        assert_eq!((l0, l1, l2), (0, 1, 0));
        assert_eq!(g0.shape(), (32, 16));
        assert_eq!(g1.shape(), (16, 32));
    }
}
