//! Constrained minimisation of the PRISM fitting objective `m(α)`.
//!
//! For Newton–Schulz-family iterations `m(α)` is a degree-4 polynomial
//! (quartic); for Chebyshev/inverse-Newton-p=1 it is quadratic; for inverse
//! p-th roots with p ≥ 3 it has degree 2p. We minimise over an interval
//! `[ℓ, u]` by solving `m'(α) = 0` in closed form (Cardano for the cubic
//! derivative) or via companion-matrix eigenvalues for higher degrees, then
//! comparing candidate stationary points and endpoints.

use crate::util::{Error, Result};

/// Evaluate a polynomial with coefficients `c[i]` of `α^i` (ascending).
pub fn poly_eval(c: &[f64], x: f64) -> f64 {
    let mut acc = 0.0;
    for &ci in c.iter().rev() {
        acc = acc * x + ci;
    }
    acc
}

/// Derivative coefficients (ascending order in, ascending out).
pub fn poly_deriv(c: &[f64]) -> Vec<f64> {
    if c.len() <= 1 {
        return vec![0.0];
    }
    c.iter()
        .enumerate()
        .skip(1)
        .map(|(i, &ci)| ci * i as f64)
        .collect()
}

/// All real roots of a quadratic `c0 + c1 x + c2 x²`.
pub fn roots_quadratic(c0: f64, c1: f64, c2: f64) -> Vec<f64> {
    if c2.abs() < 1e-300 {
        if c1.abs() < 1e-300 {
            return vec![];
        }
        return vec![-c0 / c1];
    }
    let disc = c1 * c1 - 4.0 * c2 * c0;
    if disc < 0.0 {
        return vec![];
    }
    let sq = disc.sqrt();
    // Numerically-stable form.
    let q = -0.5 * (c1 + c1.signum() * sq);
    let mut roots = vec![];
    if q.abs() > 1e-300 {
        roots.push(c0 / q);
    }
    roots.push(q / c2);
    roots
}

/// All real roots of the cubic `c0 + c1 x + c2 x² + c3 x³` (Cardano +
/// trigonometric for three-real-root case).
pub fn roots_cubic(c0: f64, c1: f64, c2: f64, c3: f64) -> Vec<f64> {
    if c3.abs() < 1e-300 {
        return roots_quadratic(c0, c1, c2);
    }
    // Depressed cubic t³ + p t + q with x = t - b/(3a).
    let (a, b, c, d) = (c3, c2, c1, c0);
    let shift = b / (3.0 * a);
    let p = c / a - shift * shift * 3.0;
    let q = 2.0 * shift.powi(3) - shift * c / a + d / a;
    let mut roots = Vec::new();
    let half_q = q / 2.0;
    let third_p = p / 3.0;
    let disc = half_q * half_q + third_p.powi(3);
    if disc > 1e-300 {
        // One real root.
        let sq = disc.sqrt();
        let u = cbrt(-half_q + sq);
        let v = cbrt(-half_q - sq);
        roots.push(u + v - shift);
    } else if disc.abs() <= 1e-300 {
        // Repeated roots.
        let u = cbrt(-half_q);
        roots.push(2.0 * u - shift);
        roots.push(-u - shift);
    } else {
        // Three real roots (casus irreducibilis): trigonometric method.
        let r = (-third_p.powi(3)).sqrt();
        let phi = (-half_q / r).clamp(-1.0, 1.0).acos();
        let m = 2.0 * (-third_p).sqrt();
        for k in 0..3 {
            roots.push(m * ((phi + 2.0 * std::f64::consts::PI * k as f64) / 3.0).cos() - shift);
        }
    }
    roots
}

fn cbrt(x: f64) -> f64 {
    x.signum() * x.abs().powf(1.0 / 3.0)
}

/// Real roots of an arbitrary-degree polynomial via companion-matrix
/// eigenvalues. Uses an unshifted QR-like power method on the companion
/// matrix; adequate for the small degrees (≤ 10) we need. Falls back to
/// bisection scanning for robustness.
pub fn roots_general(c: &[f64], lo: f64, hi: f64) -> Vec<f64> {
    // Trim leading zeros.
    let mut coeffs = c.to_vec();
    while coeffs.len() > 1 && coeffs.last().unwrap().abs() < 1e-300 {
        coeffs.pop();
    }
    let deg = coeffs.len() - 1;
    match deg {
        0 => vec![],
        1 => vec![-coeffs[0] / coeffs[1]],
        2 => roots_quadratic(coeffs[0], coeffs[1], coeffs[2]),
        3 => roots_cubic(coeffs[0], coeffs[1], coeffs[2], coeffs[3]),
        _ => {
            // Dense sign-change scan + bisection over [lo, hi]: we only ever
            // need roots inside the constraint interval.
            let grid = 512;
            let mut out = Vec::new();
            let mut prev_x = lo;
            let mut prev_f = poly_eval(&coeffs, lo);
            for i in 1..=grid {
                let x = lo + (hi - lo) * i as f64 / grid as f64;
                let f = poly_eval(&coeffs, x);
                if prev_f == 0.0 {
                    out.push(prev_x);
                } else if prev_f * f < 0.0 {
                    // Bisection.
                    let (mut a, mut b) = (prev_x, x);
                    let (mut fa, _fb) = (prev_f, f);
                    for _ in 0..80 {
                        let m = 0.5 * (a + b);
                        let fm = poly_eval(&coeffs, m);
                        if fa * fm <= 0.0 {
                            b = m;
                        } else {
                            a = m;
                            fa = fm;
                        }
                    }
                    out.push(0.5 * (a + b));
                }
                prev_x = x;
                prev_f = f;
            }
            out
        }
    }
}

/// Minimise `m(α) = Σ c_i α^i` over `α ∈ [lo, hi]`. Returns (α*, m(α*)).
pub fn minimize_on_interval(c: &[f64], lo: f64, hi: f64) -> Result<(f64, f64)> {
    if !(lo < hi) || !lo.is_finite() || !hi.is_finite() {
        return Err(Error::Parse(format!("bad interval [{lo}, {hi}]")));
    }
    if c.iter().any(|x| !x.is_finite()) {
        return Err(Error::Numerical("non-finite polynomial coefficients".into()));
    }
    let d = poly_deriv(c);
    let mut candidates = vec![lo, hi];
    for r in roots_general(&d, lo, hi) {
        if r > lo && r < hi && r.is_finite() {
            candidates.push(r);
        }
    }
    let mut best = (lo, f64::INFINITY);
    for &x in &candidates {
        let v = poly_eval(c, x);
        if v < best.1 {
            best = (x, v);
        }
    }
    Ok(best)
}

/// Convenience for the common quartic case: coefficients `[c0..c4]`.
pub fn minimize_quartic(c: &[f64; 5], lo: f64, hi: f64) -> Result<(f64, f64)> {
    minimize_on_interval(c, lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::{gens, Prop};
    use crate::rng::Rng;

    #[test]
    fn eval_and_deriv() {
        // m(x) = 1 + 2x + 3x²
        let c = [1.0, 2.0, 3.0];
        assert_eq!(poly_eval(&c, 2.0), 1.0 + 4.0 + 12.0);
        assert_eq!(poly_deriv(&c), vec![2.0, 6.0]);
    }

    #[test]
    fn quadratic_roots_known() {
        // (x-1)(x-3) = 3 - 4x + x²
        let mut r = roots_quadratic(3.0, -4.0, 1.0);
        r.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((r[0] - 1.0).abs() < 1e-12);
        assert!((r[1] - 3.0).abs() < 1e-12);
        assert!(roots_quadratic(1.0, 0.0, 1.0).is_empty()); // x²+1
    }

    #[test]
    fn cubic_roots_three_real() {
        // (x+2)(x)(x-1) = x³ + x² - 2x
        let mut r = roots_cubic(0.0, -2.0, 1.0, 1.0);
        r.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(r.len(), 3);
        assert!((r[0] + 2.0).abs() < 1e-9);
        assert!(r[1].abs() < 1e-9);
        assert!((r[2] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cubic_roots_one_real() {
        // x³ - 1 has one real root at 1.
        let r = roots_cubic(-1.0, 0.0, 0.0, 1.0);
        assert_eq!(r.len(), 1);
        assert!((r[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cubic_random_roots_verify() {
        Prop::new("cubic roots satisfy poly").cases(100).run(|rng| {
            let c: Vec<f64> = (0..4).map(|_| gens::f64_in(rng, -3.0, 3.0)).collect();
            if c[3].abs() < 0.1 {
                return;
            }
            for r in roots_cubic(c[0], c[1], c[2], c[3]) {
                let v = poly_eval(&c, r);
                let scale = c.iter().map(|x| x.abs()).fold(1.0, f64::max) * (1.0 + r.abs().powi(3));
                assert!(v.abs() < 1e-7 * scale, "root {r} gives {v}");
            }
        });
    }

    #[test]
    fn general_roots_degree6() {
        // (x-0.2)(x-0.5)(x-0.8) * (x²+1) * (x-2) expanded numerically:
        let factors = [0.2, 0.5, 0.8, 2.0];
        // Build coefficients of Π(x - f) * (x²+1).
        let mut c = vec![1.0];
        for &f in &factors {
            let mut nc = vec![0.0; c.len() + 1];
            for (i, &ci) in c.iter().enumerate() {
                nc[i + 1] += ci;
                nc[i] -= f * ci;
            }
            c = nc;
        }
        let mut nc = vec![0.0; c.len() + 2];
        for (i, &ci) in c.iter().enumerate() {
            nc[i + 2] += ci;
            nc[i] += ci;
        }
        c = nc;
        let roots = roots_general(&c, 0.0, 1.0);
        assert_eq!(roots.len(), 3, "roots in [0,1]: {roots:?}");
        for (got, want) in roots.iter().zip([0.2, 0.5, 0.8]) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn minimize_quartic_interior() {
        // m(α) = (α - 0.7)² (α² + 1): min at 0.7.
        // Expand: (α² - 1.4α + 0.49)(α² + 1)
        let c = [0.49, -1.4, 1.49, -1.4, 1.0];
        let (a, v) = minimize_quartic(&c, 0.0, 2.0).unwrap();
        assert!((a - 0.7).abs() < 1e-6, "a={a}");
        assert!(v.abs() < 1e-10);
    }

    #[test]
    fn minimize_clamps_to_endpoints() {
        // m(α) = α (increasing): min at lo.
        let (a, _) = minimize_on_interval(&[0.0, 1.0], 0.5, 1.0).unwrap();
        assert_eq!(a, 0.5);
        // m(α) = -α: min at hi.
        let (a, _) = minimize_on_interval(&[0.0, -1.0], 0.5, 1.0).unwrap();
        assert_eq!(a, 1.0);
    }

    #[test]
    fn minimize_random_quartics_beats_grid() {
        Prop::new("quartic min <= grid min").cases(200).run(|rng| {
            let c: [f64; 5] = [
                gens::f64_in(rng, -2.0, 2.0),
                gens::f64_in(rng, -2.0, 2.0),
                gens::f64_in(rng, -2.0, 2.0),
                gens::f64_in(rng, -2.0, 2.0),
                gens::f64_in(rng, -2.0, 2.0),
            ];
            let (lo, hi) = (0.5, 1.5);
            let (astar, vstar) = minimize_quartic(&c, lo, hi).unwrap();
            assert!((lo..=hi).contains(&astar));
            for i in 0..=100 {
                let x = lo + (hi - lo) * i as f64 / 100.0;
                assert!(
                    vstar <= poly_eval(&c, x) + 1e-9,
                    "grid point {x} beats {astar}: {} < {vstar}",
                    poly_eval(&c, x)
                );
            }
        });
    }

    #[test]
    fn minimize_rejects_bad_input() {
        assert!(minimize_on_interval(&[1.0, f64::NAN], 0.0, 1.0).is_err());
        assert!(minimize_on_interval(&[1.0], 1.0, 0.0).is_err());
    }

    #[test]
    fn degenerate_poly_is_constant() {
        let (a, v) = minimize_on_interval(&[3.0], 0.0, 1.0).unwrap();
        assert_eq!(v, 3.0);
        assert!((0.0..=1.0).contains(&a));
    }

    #[test]
    fn roots_general_smoke_random() {
        let mut rng = Rng::seed_from(9);
        for _ in 0..20 {
            let c: Vec<f64> = (0..7).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            for r in roots_general(&c, -1.0, 1.0) {
                assert!(poly_eval(&c, r).abs() < 1e-6);
            }
        }
    }
}
