//! Micro-benchmark harness (no `criterion` offline).
//!
//! Provides warmup + adaptive iteration timing with median/IQR reporting, a
//! fixed-width table printer for the paper-figure benches, JSONL series
//! output so plots can be regenerated outside Rust, and [`JsonReport`] —
//! the machine-readable `BENCH_*.json` artifact the perf benches emit so CI
//! can record the performance trajectory PR over PR.

use crate::configfmt::{to_json, Value};
use crate::util::{fmt_duration, median, percentile, Stopwatch};
use std::collections::BTreeMap;
use std::io::Write;

/// Timing statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: Vec<f64>,
}

impl Stats {
    pub fn median_s(&self) -> f64 {
        median(&self.samples)
    }
    pub fn p10_s(&self) -> f64 {
        percentile(&self.samples, 10.0)
    }
    pub fn p90_s(&self) -> f64 {
        percentile(&self.samples, 90.0)
    }
}

/// Benchmark runner.
pub struct Bench {
    /// Minimum total measured time per case.
    pub min_time_s: f64,
    /// Max samples per case (cap for slow cases).
    pub max_samples: usize,
    pub warmup: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { min_time_s: 0.2, max_samples: 25, warmup: 1 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { min_time_s: 0.05, max_samples: 7, warmup: 1 }
    }

    /// Time `f` repeatedly; each sample is one invocation.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let total = Stopwatch::start();
        while samples.len() < 3
            || (total.elapsed_s() < self.min_time_s && samples.len() < self.max_samples)
        {
            let sw = Stopwatch::start();
            f();
            samples.push(sw.elapsed_s());
        }
        Stats { name: name.to_string(), samples }
    }
}

/// Fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", cell, w = widths[c]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str("|");
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// JSONL series writer: each `point` call appends one JSON object. Used by
/// the figure benches to dump (x, y, series) triples for re-plotting.
pub struct SeriesWriter {
    file: Option<std::fs::File>,
}

impl SeriesWriter {
    /// Write to `path`, or a no-op writer if the directory can't be created.
    pub fn create(path: &str) -> SeriesWriter {
        if let Some(parent) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        SeriesWriter { file: std::fs::File::create(path).ok() }
    }

    pub fn noop() -> SeriesWriter {
        SeriesWriter { file: None }
    }

    pub fn point(&mut self, fields: &[(&str, Value)]) {
        if let Some(f) = self.file.as_mut() {
            let mut map = BTreeMap::new();
            for (k, v) in fields {
                map.insert(k.to_string(), v.clone());
            }
            let _ = writeln!(f, "{}", to_json(&Value::Table(map)));
        }
    }
}

/// Machine-readable bench report: one JSON document per bench run, of the
/// shape `{"bench": <name>, "results": [ {...}, ... ]}`. The perf benches
/// (`perf_gemm`, `perf_matfn`) write these as `bench_out/BENCH_<name>.json`
/// and CI uploads them as artifacts, so the perf trajectory is recorded
/// from the first packed-kernel PR onward.
pub struct JsonReport {
    path: String,
    bench: String,
    results: Vec<Value>,
}

impl JsonReport {
    /// Report writing to `path` on [`JsonReport::finish`].
    pub fn create(path: &str, bench: &str) -> JsonReport {
        JsonReport { path: path.to_string(), bench: bench.to_string(), results: Vec::new() }
    }

    /// Append one result object.
    pub fn entry(&mut self, fields: &[(&str, Value)]) {
        let mut map = BTreeMap::new();
        for (k, v) in fields {
            map.insert(k.to_string(), v.clone());
        }
        self.results.push(Value::Table(map));
    }

    /// Number of result objects recorded so far.
    pub fn len(&self) -> usize {
        self.results.len()
    }
    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    /// Write the document; returns the path on success (None when the file
    /// could not be written — benches keep running, matching
    /// [`SeriesWriter`]'s tolerance of read-only checkouts).
    pub fn finish(self) -> Option<String> {
        let mut doc = BTreeMap::new();
        doc.insert("bench".to_string(), Value::Str(self.bench));
        doc.insert("results".to_string(), Value::Array(self.results));
        if let Some(parent) = std::path::Path::new(&self.path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        match std::fs::write(&self.path, to_json(&Value::Table(doc))) {
            Ok(()) => Some(self.path),
            Err(_) => None,
        }
    }
}

/// Convenience: render one bench stat line.
pub fn stat_line(s: &Stats) -> String {
    format!(
        "{:<40} median {:>10}  p10 {:>10}  p90 {:>10}  (n={})",
        s.name,
        fmt_duration(s.median_s()),
        fmt_duration(s.p10_s()),
        fmt_duration(s.p90_s()),
        s.samples.len()
    )
}

/// Standard bench entry banner.
pub fn banner(title: &str, paper_ref: &str) {
    println!("\n=== {title} ===");
    println!("reproduces: {paper_ref}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let b = Bench { min_time_s: 0.0, max_samples: 5, warmup: 0 };
        let s = b.run("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.samples.len() >= 3);
        assert!(s.median_s() >= 0.0);
        assert!(s.p10_s() <= s.p90_s());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["algo", "time"]);
        t.row(&["ns".into(), "1.0ms".into()]);
        t.row(&["prism-long-name".into(), "0.5ms".into()]);
        let r = t.render();
        assert!(r.contains("prism-long-name"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4); // header, sep, 2 rows
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".into()]);
    }

    #[test]
    fn series_writer_writes_jsonl() {
        let path = "/tmp/prism_test_series.jsonl";
        {
            let mut w = SeriesWriter::create(path);
            w.point(&[("x", Value::Int(1)), ("y", Value::Float(0.5))]);
            w.point(&[("x", Value::Int(2)), ("y", Value::Float(0.25))]);
        }
        let content = std::fs::read_to_string(path).unwrap();
        assert_eq!(content.lines().count(), 2);
        assert!(content.contains("\"x\":1"));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn noop_writer_ok() {
        let mut w = SeriesWriter::noop();
        w.point(&[("x", Value::Int(1))]); // must not panic
    }

    #[test]
    fn stat_line_contains_name() {
        let s = Stats { name: "t".into(), samples: vec![0.001, 0.002, 0.003] };
        assert!(stat_line(&s).contains('t'));
    }

    #[test]
    fn json_report_writes_document() {
        let path = "/tmp/prism_test_BENCH_x.json";
        let mut r = JsonReport::create(path, "perf_x");
        assert!(r.is_empty());
        r.entry(&[("n", Value::Int(256)), ("gflops", Value::Float(3.5))]);
        r.entry(&[("n", Value::Int(512)), ("gflops", Value::Float(3.1))]);
        assert_eq!(r.len(), 2);
        let written = r.finish().expect("writable tmp");
        let content = std::fs::read_to_string(&written).unwrap();
        assert!(content.contains("\"bench\":\"perf_x\""));
        assert!(content.contains("\"results\":["));
        assert!(content.contains("\"n\":256"));
        // Round-trips through the crate's own JSON parser.
        let v = crate::configfmt::parse_json(&content).unwrap();
        assert_eq!(v.get_path("bench").and_then(|x| x.as_str()), Some("perf_x"));
        let _ = std::fs::remove_file(written);
    }
}
