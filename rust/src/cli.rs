//! Minimal command-line parsing (no `clap` offline).
//!
//! Supports `prog <subcommand> --flag value --switch positional ...` with
//! typed accessors, defaults, and an auto-generated usage string.

use crate::util::{Error, Result};
use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, `--switch`
/// booleans, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without program name). Flags may be `--k v` or `--k=v`.
    /// The first non-flag token is treated as the subcommand if
    /// `expect_subcommand` is set.
    pub fn parse(argv: &[String], expect_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else if expect_subcommand && out.subcommand.is_none() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse from `std::env::args()`.
    pub fn from_env(expect_subcommand: bool) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv, expect_subcommand)
    }

    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_string(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("--{name}: expected float, got '{v}'"))),
        }
    }

    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| Error::Parse(format!("--{name}: bad float '{s}'")))
                })
                .collect(),
        }
    }
}

/// Declarative usage help.
pub struct Usage {
    pub program: &'static str,
    pub about: &'static str,
    pub subcommands: &'static [(&'static str, &'static str)],
}

impl Usage {
    pub fn render(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <subcommand> [--flags]\n\nSUBCOMMANDS:\n",
            self.program, self.about, self.program);
        for (name, about) in self.subcommands {
            s.push_str(&format!("  {name:<18} {about}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_subcommand_and_flags() {
        // Note: a bare `--flag value` is always treated as an option pair, so
        // boolean switches go last or use `--flag=`: this is documented
        // behaviour of the schema-less parser.
        let a = Args::parse(&sv(&["polar", "--n", "256", "file.txt", "--verbose"]), true);
        assert_eq!(a.subcommand.as_deref(), Some("polar"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 256);
        assert!(a.has_switch("verbose"));
        assert_eq!(a.positional, vec!["file.txt"]);
    }

    #[test]
    fn parse_equals_form() {
        let a = Args::parse(&sv(&["--lr=0.1", "--name=run1"]), false);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.1);
        assert_eq!(a.get_string("name", ""), "run1");
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], false);
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("x", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_string("s", "d"), "d");
    }

    #[test]
    fn bad_int_is_error() {
        let a = Args::parse(&sv(&["--n", "abc"]), false);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn f64_list_parses() {
        let a = Args::parse(&sv(&["--gammas", "1,4,50"]), false);
        assert_eq!(a.get_f64_list("gammas", &[]).unwrap(), vec![1.0, 4.0, 50.0]);
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse(&sv(&["run", "--fast"]), true);
        assert!(a.has_switch("fast"));
        assert_eq!(a.subcommand.as_deref(), Some("run"));
    }

    #[test]
    fn usage_renders() {
        let u = Usage {
            program: "prism",
            about: "matrix functions",
            subcommands: &[("polar", "orthogonalize")],
        };
        let r = u.render();
        assert!(r.contains("polar"));
        assert!(r.contains("USAGE"));
    }
}
