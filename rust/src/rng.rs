//! Deterministic pseudo-random number generation.
//!
//! The offline build environment provides no `rand` crate, so we implement
//! the standard xoshiro256++ generator (Blackman & Vigna) seeded through
//! SplitMix64, plus the samplers the paper's experiments need: uniform,
//! standard normal (Box–Muller with caching), gamma (Marsaglia–Tsang),
//! inverse-gamma, Zipf and categorical draws.
//!
//! Everything is deterministic given a seed, which is what makes the
//! benchmark tables reproducible run-to-run.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator deterministically from a single u64.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (bias < 2^-64 * n).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid u == 0.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang; valid for k > 0.
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^{1/k}
            let g = self.gamma(k + 1.0);
            let u = loop {
                let u = self.uniform();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Inverse-gamma(shape a, scale b): 1 / Gamma(a, 1/b).
    pub fn inverse_gamma(&mut self, a: f64, b: f64) -> f64 {
        b / self.gamma(a)
    }

    /// Zipf-like rank draw over [0, n): P(i) ∝ 1/(i+1)^s, via inverse CDF on a
    /// precomputed table — used by the synthetic LM corpus generator.
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.uniform();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }

    /// Categorical draw from unnormalised weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// Build the CDF table for [`Rng::zipf`].
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Rng::seed_from(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut rng = Rng::seed_from(5);
        for &k in &[0.5, 1.0, 2.5, 8.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| rng.gamma(k)).sum::<f64>() / n as f64;
            assert!((mean - k).abs() / k < 0.08, "k={k} mean={mean}");
        }
    }

    #[test]
    fn inverse_gamma_positive() {
        let mut rng = Rng::seed_from(6);
        for _ in 0..1000 {
            assert!(rng.inverse_gamma(2.0, 1.0) > 0.0);
        }
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::seed_from(8);
        for _ in 0..1000 {
            assert!(rng.below(10) < 10);
        }
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let mut rng = Rng::seed_from(9);
        let cdf = zipf_cdf(100, 1.2);
        let n = 10_000;
        let low = (0..n).filter(|_| rng.zipf(&cdf) < 10).count();
        assert!(low > n / 2, "low-rank draws: {low}/{n}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::seed_from(10);
        let w = [0.0, 1.0, 3.0];
        let n = 30_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(11);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::seed_from(12);
        let idx = rng.sample_indices(20, 10);
        assert_eq!(idx.len(), 10);
        let mut s = idx.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::seed_from(13);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
