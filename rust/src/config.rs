//! Typed experiment / training configuration, read from the TOML subset.
//!
//! Every example binary and bench accepts `--config path.toml`; values not
//! present fall back to defaults so configs stay short.

use crate::configfmt::{parse_toml, Value};
use crate::linalg::gemm::{GemmBlocking, MicroKernel};
use crate::matfn::{Precision, RectStrategy};
use crate::util::{Error, Result};
use std::time::Duration;

/// Which polar/inverse-root backend an optimizer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Classical Newton–Schulz (fixed Taylor coefficients).
    NewtonSchulz,
    /// PolarExpress minimax polynomials (σ_min = 1e-3 tuning).
    PolarExpress,
    /// PRISM with degree-3 update (d = 1).
    Prism3,
    /// PRISM with degree-5 update (d = 2).
    Prism5,
    /// Exact eigendecomposition (baseline).
    Eigen,
    /// PRISM-accelerated DB-Newton.
    PrismNewton,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s.to_ascii_lowercase().as_str() {
            "ns" | "newton-schulz" | "newton_schulz" => Ok(Backend::NewtonSchulz),
            "polar-express" | "polarexpress" | "pe" => Ok(Backend::PolarExpress),
            "prism3" | "prism-3" => Ok(Backend::Prism3),
            "prism5" | "prism-5" | "prism" => Ok(Backend::Prism5),
            "eigen" | "eig" | "svd" => Ok(Backend::Eigen),
            "prism-newton" | "prismnewton" | "newton" => Ok(Backend::PrismNewton),
            other => Err(Error::Parse(format!("unknown backend '{other}'"))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Backend::NewtonSchulz => "newton-schulz",
            Backend::PolarExpress => "polar-express",
            Backend::Prism3 => "prism-3",
            Backend::Prism5 => "prism-5",
            Backend::Eigen => "eigen",
            Backend::PrismNewton => "prism-newton",
        }
    }
}

/// What `Service::submit` does when the admission queue is full
/// (pending + inflight ≥ `queue_cap`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// `submit` waits for capacity (assumes some thread is draining
    /// results). `try_submit` never blocks regardless of this setting.
    Block,
    /// `submit` behaves like `try_submit`: a full queue is a typed
    /// [`crate::util::Error::Backpressure`] the caller must handle.
    Reject,
}

impl Admission {
    pub fn parse(s: &str) -> Result<Admission> {
        match s.to_ascii_lowercase().as_str() {
            "block" | "blocking" => Ok(Admission::Block),
            "reject" | "rejecting" => Ok(Admission::Reject),
            other => Err(Error::Parse(format!(
                "unknown admission policy '{other}' (want block | reject)"
            ))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Admission::Block => "block",
            Admission::Reject => "reject",
        }
    }
}

/// Training configuration shared by the Shampoo/Muon experiments.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub seed: u64,
    pub steps: usize,
    pub batch_size: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub momentum: f64,
    pub backend: Backend,
    /// Matrix-function iterations per optimizer step (paper: 5 for PE/PRISM-3,
    /// 3 for PRISM-5).
    pub matfn_iters: usize,
    /// Shampoo: refresh preconditioners every this many steps.
    pub precond_interval: usize,
    /// Shampoo damping epsilon.
    pub damping: f64,
    /// Route rectangular params take through Muon's polar backend
    /// (`rect_strategy = "auto" | "gram" | "range<K>" | "direct"` in TOML).
    /// See [`crate::matfn::RectStrategy`]; `auto` picks Gram at aspect ≥ 2
    /// and the plain square iteration otherwise.
    pub rect_strategy: RectStrategy,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            seed: 0,
            steps: 200,
            batch_size: 32,
            lr: 6e-3,
            weight_decay: 0.01,
            momentum: 0.95,
            backend: Backend::Prism5,
            matfn_iters: 5,
            precond_interval: 10,
            damping: 1e-6,
            rect_strategy: RectStrategy::Auto,
            log_every: 10,
        }
    }
}

impl TrainConfig {
    /// Load from a TOML file; missing keys keep defaults.
    pub fn from_toml_file(path: &str) -> Result<TrainConfig> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| Error::Runtime(format!("read {path}: {e}")))?;
        let v = parse_toml(&src)?;
        Self::from_value(&v)
    }

    pub fn from_value(v: &Value) -> Result<TrainConfig> {
        let mut c = TrainConfig::default();
        let geti = |p: &str, d: usize| -> usize {
            v.get_path(p).and_then(|x| x.as_int()).map(|x| x as usize).unwrap_or(d)
        };
        let getf = |p: &str, d: f64| -> f64 { v.get_path(p).and_then(|x| x.as_float()).unwrap_or(d) };
        c.seed = v.get_path("seed").and_then(|x| x.as_int()).unwrap_or(0) as u64;
        c.steps = geti("steps", c.steps);
        c.batch_size = geti("batch_size", c.batch_size);
        c.lr = getf("lr", c.lr);
        c.weight_decay = getf("weight_decay", c.weight_decay);
        c.momentum = getf("momentum", c.momentum);
        c.matfn_iters = geti("matfn_iters", c.matfn_iters);
        c.precond_interval = geti("precond_interval", c.precond_interval);
        c.damping = getf("damping", c.damping);
        c.log_every = geti("log_every", c.log_every);
        if let Some(s) = v.get_path("backend").and_then(|x| x.as_str()) {
            c.backend = Backend::parse(s)?;
        }
        if let Some(s) = v.get_path("rect_strategy").and_then(|x| x.as_str()) {
            c.rect_strategy = RectStrategy::parse(s).ok_or_else(|| {
                Error::Parse(format!(
                    "unknown rect_strategy '{s}' (want auto | gram | range<K> | direct)"
                ))
            })?;
        }
        Ok(c)
    }
}

/// Preconditioner-service configuration (the L3 coordinator).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub workers: usize,
    /// Admission cap: the service accepts at most this many jobs in flight
    /// (router-pending + dispatched-but-unfetched) at once. When the cap is
    /// hit, `submit` either blocks or returns a typed
    /// [`crate::util::Error::Backpressure`] per [`ServiceConfig::admission`]
    /// (`service.queue_cap` in TOML; the pre-PR-8 `service.queue_capacity`
    /// spelling is still accepted).
    pub queue_cap: usize,
    /// Full-queue behaviour of `submit` (`service.admission = "block" |
    /// "reject"` in TOML, `--admission` on the CLI). Default `block`.
    pub admission: Admission,
    /// Batch together up to this many same-shape jobs per dispatch.
    pub max_batch: usize,
    /// Sketch size p for the PRISM fits.
    pub sketch_p: usize,
    pub max_iters: usize,
    /// Stopping tolerance override (`service.tol` in TOML, `--tol` on the
    /// CLI). `None` — the default — keeps the **per-task** solver defaults
    /// (1e-7 for polar/sign, 1e-9 for inverse-root tasks; see
    /// [`crate::matfn::Solver::for_backend_tuned`]). A single `Some(t)`
    /// applies `t` to every task the service runs — deliberately one knob:
    /// set it only when you mean to move *all* tasks off their defaults.
    pub tol: Option<f64>,
    /// Per-worker cap on cached persistent solvers (one solver is kept per
    /// (kind, shape) route; `service.solver_cache_cap` in TOML). Least-
    /// recently-used routes are evicted beyond the cap, so a shape-diverse
    /// tenant cannot grow a worker's solver map without bound. Must be ≥ 1
    /// (checked by [`ServiceConfig::validate`] at service start).
    pub solver_cache_cap: usize,
    /// GEMM pool size shared by the engines (`--threads` on the CLI,
    /// `service.gemm_threads` in TOML). Any value produces bit-identical
    /// results, so this is purely a speed knob. Values > 1 are installed
    /// process-globally by [`crate::coordinator::service::Service::start`];
    /// the default 1 means "unspecified" and leaves any pool already
    /// installed (e.g. via `--threads`) untouched — call
    /// [`crate::linalg::gemm::set_global_threads`]`(1)` to force sequential.
    pub gemm_threads: usize,
    /// Stream per-iteration residuals from the workers over the service's
    /// progress channel (`service.stream_residuals` in TOML, `--stream` on
    /// the CLI). Off by default: the channel is unbounded, so someone must
    /// drain [`crate::coordinator::service::Service::try_recv_progress`].
    pub stream_residuals: bool,
    /// GEMM cache-block sizes (`service.gemm_block = "MCxKCxNC"` in TOML,
    /// `--gemm-block` on the CLI). `None` keeps whatever is already
    /// installed (the built-in default or an earlier CLI setting). Applied
    /// process-globally by `Service::start` — a startup-time tuning knob:
    /// changing KC/NC regroups reductions and can change low-order result
    /// bits of later computations.
    pub gemm_block: Option<GemmBlocking>,
    /// GEMM microkernel (`service.gemm_kernel = "auto|scalar|avx2|neon"` in
    /// TOML, `--gemm-kernel` on the CLI). `None`/"auto" keeps whatever is
    /// already installed (auto-detection by default). Applied
    /// process-globally by `Service::start` when the kernel is available on
    /// the host; like `gemm_block`, a startup-time knob — kernels agree to
    /// fp64 round-off but not bit-for-bit (FMA fuses roundings).
    pub gemm_kernel: Option<MicroKernel>,
    /// Hot-loop precision for the worker solvers (`service.precision =
    /// "f64" | "mixed"` in TOML, `--precision` on the CLI). `mixed` runs the
    /// Newton–Schulz iterations in f32 with an f64 residual guard and one
    /// f64 cleanup iteration — see [`crate::matfn::Precision`] for the
    /// accuracy contract. Malformed values degrade to `f64` (same keep-the-
    /// default policy as `gemm_kernel`).
    pub precision: Precision,
    /// Deterministic fault-injection plan (`service.faults` in TOML,
    /// `--faults` on the CLI, `PALLAS_FAULTS` in the environment). The spec
    /// grammar is documented at [`crate::runtime::faultinject::FaultPlan`];
    /// `None` — the default — leaves fault injection inert. This exists for
    /// the chaos suite and for rehearsing failure drills against a live
    /// service; it must never be set in production configs.
    pub faults: Option<String>,
    /// How long a partially-filled batch bucket may hold its oldest job
    /// before the linger flusher dispatches it anyway (`service.linger_ms`
    /// in TOML, `--linger` milliseconds on the CLI). `None` — the default —
    /// disables the flusher and keeps the caller-driven contract: partial
    /// buckets wait for a full cut, an explicit
    /// [`crate::coordinator::service::Service::flush`]/`drain`, or drop.
    /// `Some(d)` bounds the queue time of rare shapes: a bucket that cannot
    /// fill to `max_batch` is dispatched once its oldest member has waited
    /// `d`, so singleton odd-shape jobs never starve behind busy routes.
    pub linger: Option<Duration>,
    /// Warm-state snapshot path (`service.cache_snapshot` in TOML,
    /// `--cache-snapshot` on the CLI). When set, shutdown serializes the
    /// warm solver-cache routes plus the engine tuning to a
    /// `runtime::manifest` JSON artifact at this path, and
    /// [`crate::coordinator::service::Service::start`] restores it if the
    /// file exists: every worker pre-builds the recorded route solvers and
    /// pre-sizes their workspace pools, so the first post-restart tick runs
    /// the warm (allocation-free) path instead of paying cold-start per
    /// route. A missing file means a cold start; an unreadable one is
    /// warned about and ignored (a stale snapshot must never brick a
    /// restart).
    pub cache_snapshot: Option<String>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            queue_cap: 1024,
            admission: Admission::Block,
            max_batch: 8,
            sketch_p: 8,
            max_iters: 30,
            tol: None,
            solver_cache_cap: 32,
            gemm_threads: 1,
            stream_residuals: false,
            gemm_block: None,
            gemm_kernel: None,
            precision: Precision::F64,
            faults: None,
            linger: None,
            cache_snapshot: None,
        }
    }
}

impl ServiceConfig {
    pub fn from_value(v: &Value) -> ServiceConfig {
        let mut c = ServiceConfig::default();
        let geti = |p: &str, d: usize| -> usize {
            v.get_path(p).and_then(|x| x.as_int()).map(|x| x as usize).unwrap_or(d)
        };
        c.workers = geti("service.workers", c.workers);
        // `queue_capacity` is the pre-PR-8 spelling; `queue_cap` wins if both
        // are present.
        c.queue_cap = geti("service.queue_capacity", c.queue_cap);
        c.queue_cap = geti("service.queue_cap", c.queue_cap);
        if let Some(s) = v.get_path("service.admission").and_then(|x| x.as_str()) {
            // Malformed values keep the blocking default (same keep-the-
            // default policy as gemm_kernel / precision below).
            c.admission = Admission::parse(s).unwrap_or(c.admission);
        }
        c.max_batch = geti("service.max_batch", c.max_batch);
        c.sketch_p = geti("service.sketch_p", c.sketch_p);
        c.max_iters = geti("service.max_iters", c.max_iters);
        c.tol = v.get_path("service.tol").and_then(|x| x.as_float()).or(c.tol);
        c.solver_cache_cap = geti("service.solver_cache_cap", c.solver_cache_cap);
        c.gemm_threads = geti("service.gemm_threads", c.gemm_threads);
        c.stream_residuals = v
            .get_path("service.stream_residuals")
            .and_then(|x| x.as_bool())
            .unwrap_or(c.stream_residuals);
        if let Some(s) = v.get_path("service.gemm_block").and_then(|x| x.as_str()) {
            // Config parsing is infallible-by-default elsewhere in this
            // struct; a malformed blocking spec falls back to None (keep the
            // installed default) rather than aborting service start.
            c.gemm_block = GemmBlocking::parse(s).ok();
        }
        if let Some(s) = v.get_path("service.gemm_kernel").and_then(|x| x.as_str()) {
            // "auto" parses to None; malformed specs likewise degrade to
            // "keep the installed default" (same policy as gemm_block).
            c.gemm_kernel = MicroKernel::parse(s).ok().flatten();
        }
        if let Some(s) = v.get_path("service.precision").and_then(|x| x.as_str()) {
            // Malformed values keep the f64 default (same policy as above).
            c.precision = Precision::parse(s).unwrap_or(c.precision);
        }
        if let Some(s) = v.get_path("service.faults").and_then(|x| x.as_str()) {
            // The spec is validated (hard error) at Service::start, where a
            // typo must abort rather than silently run fault-free.
            c.faults = Some(s.to_string());
        }
        if let Some(ms) = v.get_path("service.linger_ms").and_then(|x| x.as_int()) {
            // Negative values clamp to 0 ("dispatch partials at the next
            // flusher sweep") rather than erroring — same lenient policy as
            // the other service knobs.
            c.linger = Some(Duration::from_millis(ms.max(0) as u64));
        }
        if let Some(s) = v.get_path("service.cache_snapshot").and_then(|x| x.as_str()) {
            c.cache_snapshot = Some(s.to_string());
        }
        c
    }

    /// Range-check the knobs that the service would otherwise have to
    /// clamp or panic on at runtime. Called by `Service::start`; callers
    /// building configs by hand can invoke it early for a nicer error site.
    pub fn validate(&self) -> Result<()> {
        if self.workers < 1 {
            return Err(Error::Config("service.workers must be >= 1".into()));
        }
        if self.queue_cap < 1 {
            return Err(Error::Config("service.queue_cap must be >= 1".into()));
        }
        if self.max_batch < 1 {
            return Err(Error::Config("service.max_batch must be >= 1".into()));
        }
        if self.solver_cache_cap < 1 {
            return Err(Error::Config("service.solver_cache_cap must be >= 1".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_roundtrip() {
        for b in [
            Backend::NewtonSchulz,
            Backend::PolarExpress,
            Backend::Prism3,
            Backend::Prism5,
            Backend::Eigen,
            Backend::PrismNewton,
        ] {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
        }
        assert!(Backend::parse("nope").is_err());
    }

    #[test]
    fn train_config_from_toml() {
        let v = parse_toml(
            r#"
steps = 50
lr = 0.01
backend = "prism3"
"#,
        )
        .unwrap();
        let c = TrainConfig::from_value(&v).unwrap();
        assert_eq!(c.steps, 50);
        assert_eq!(c.lr, 0.01);
        assert_eq!(c.backend, Backend::Prism3);
        // defaults survive
        assert_eq!(c.momentum, 0.95);
        assert_eq!(c.rect_strategy, RectStrategy::Auto);
    }

    #[test]
    fn train_config_rect_strategy_parses() {
        for (tok, want) in [
            ("auto", RectStrategy::Auto),
            ("gram", RectStrategy::Gram),
            ("range16", RectStrategy::RangeFinder { rank: 16 }),
            ("direct", RectStrategy::Direct),
        ] {
            let v = parse_toml(&format!("rect_strategy = \"{tok}\"\n")).unwrap();
            assert_eq!(TrainConfig::from_value(&v).unwrap().rect_strategy, want);
        }
        // Malformed values are a hard parse error, like `backend`.
        let v = parse_toml("rect_strategy = \"blorp\"\n").unwrap();
        assert!(TrainConfig::from_value(&v).is_err());
    }

    #[test]
    fn service_config_defaults() {
        let v = parse_toml("[service]\nworkers = 3\n").unwrap();
        let c = ServiceConfig::from_value(&v);
        assert_eq!(c.workers, 3);
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.gemm_threads, 1);
    }

    #[test]
    fn service_config_solver_cache_cap_parses() {
        let v = parse_toml("[service]\nsolver_cache_cap = 4\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).solver_cache_cap, 4);
        assert_eq!(ServiceConfig::default().solver_cache_cap, 32);
    }

    #[test]
    fn service_config_queue_cap_parses_both_spellings() {
        assert_eq!(ServiceConfig::default().queue_cap, 1024);
        let v = parse_toml("[service]\nqueue_cap = 64\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).queue_cap, 64);
        // The pre-PR-8 spelling still works...
        let v = parse_toml("[service]\nqueue_capacity = 32\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).queue_cap, 32);
        // ...and the new key wins when both are present.
        let v = parse_toml("[service]\nqueue_capacity = 32\nqueue_cap = 8\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).queue_cap, 8);
    }

    #[test]
    fn service_config_admission_parses() {
        assert_eq!(ServiceConfig::default().admission, Admission::Block);
        let v = parse_toml("[service]\nadmission = \"reject\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).admission, Admission::Reject);
        let v = parse_toml("[service]\nadmission = \"block\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).admission, Admission::Block);
        // Malformed values keep the blocking default.
        let v = parse_toml("[service]\nadmission = \"drop\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).admission, Admission::Block);
        for a in [Admission::Block, Admission::Reject] {
            assert_eq!(Admission::parse(a.name()).unwrap(), a);
        }
    }

    #[test]
    fn service_config_faults_parses() {
        assert_eq!(ServiceConfig::default().faults, None);
        let v = parse_toml("[service]\nfaults = \"nan:solve=0,iter=1\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).faults.as_deref(), Some("nan:solve=0,iter=1"));
    }

    #[test]
    fn service_config_validate_rejects_zero_caps() {
        assert!(ServiceConfig::default().validate().is_ok());
        for field in ["workers", "queue_cap", "max_batch", "solver_cache_cap"] {
            let mut c = ServiceConfig::default();
            match field {
                "workers" => c.workers = 0,
                "queue_cap" => c.queue_cap = 0,
                "max_batch" => c.max_batch = 0,
                _ => c.solver_cache_cap = 0,
            }
            match c.validate() {
                Err(Error::Config(m)) => assert!(m.contains(field), "{m} should name {field}"),
                other => panic!("{field} = 0 must be Error::Config, got {other:?}"),
            }
        }
    }

    #[test]
    fn service_config_tol_defaults_to_per_task_none() {
        // PR 5 regression: a blanket `tol` default of 1e-7 silently loosened
        // the InvSqrt solvers from their 1e-9 per-task default. The default
        // must be "no override".
        assert_eq!(ServiceConfig::default().tol, None);
        let v = parse_toml("[service]\nworkers = 2\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).tol, None);
        let v = parse_toml("[service]\ntol = 1e-6\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).tol, Some(1e-6));
    }

    #[test]
    fn service_config_precision_parses() {
        assert_eq!(ServiceConfig::default().precision, Precision::F64);
        let v = parse_toml("[service]\nprecision = \"mixed\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).precision, Precision::Mixed);
        let v = parse_toml("[service]\nprecision = \"f64\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).precision, Precision::F64);
        // Malformed values keep the f64 default.
        let v = parse_toml("[service]\nprecision = \"f16\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).precision, Precision::F64);
    }

    #[test]
    fn service_config_gemm_threads_parses() {
        let v = parse_toml("[service]\ngemm_threads = 4\n").unwrap();
        let c = ServiceConfig::from_value(&v);
        assert_eq!(c.gemm_threads, 4);
    }

    #[test]
    fn service_config_stream_residuals_parses() {
        let v = parse_toml("[service]\nstream_residuals = true\n").unwrap();
        let c = ServiceConfig::from_value(&v);
        assert!(c.stream_residuals);
        assert!(!ServiceConfig::default().stream_residuals);
    }

    #[test]
    fn service_config_gemm_block_parses() {
        let v = parse_toml("[service]\ngemm_block = \"64x128x256\"\n").unwrap();
        let c = ServiceConfig::from_value(&v);
        assert_eq!(c.gemm_block, Some(GemmBlocking { mc: 64, kc: 128, nc: 256 }));
        // Malformed specs degrade to "keep the installed default".
        let v = parse_toml("[service]\ngemm_block = \"banana\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).gemm_block, None);
        assert_eq!(ServiceConfig::default().gemm_block, None);
    }

    #[test]
    fn service_config_linger_parses() {
        // Default: no linger flusher — partial buckets are caller-driven,
        // exactly the pre-bucketing dispatch contract.
        assert_eq!(ServiceConfig::default().linger, None);
        let v = parse_toml("[service]\nlinger_ms = 5\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).linger, Some(Duration::from_millis(5)));
        let v = parse_toml("[service]\nlinger_ms = 0\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).linger, Some(Duration::ZERO));
        // Negative values clamp to zero instead of erroring.
        let v = parse_toml("[service]\nlinger_ms = -3\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).linger, Some(Duration::ZERO));
    }

    #[test]
    fn service_config_cache_snapshot_parses() {
        assert_eq!(ServiceConfig::default().cache_snapshot, None);
        let v = parse_toml("[service]\ncache_snapshot = \"warm.json\"\n").unwrap();
        assert_eq!(
            ServiceConfig::from_value(&v).cache_snapshot.as_deref(),
            Some("warm.json")
        );
    }

    #[test]
    fn service_config_gemm_kernel_parses() {
        let v = parse_toml("[service]\ngemm_kernel = \"scalar\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).gemm_kernel, Some(MicroKernel::Scalar));
        let v = parse_toml("[service]\ngemm_kernel = \"avx2\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).gemm_kernel, Some(MicroKernel::Avx2));
        // "auto" and malformed specs keep the installed default.
        let v = parse_toml("[service]\ngemm_kernel = \"auto\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).gemm_kernel, None);
        let v = parse_toml("[service]\ngemm_kernel = \"sse9\"\n").unwrap();
        assert_eq!(ServiceConfig::from_value(&v).gemm_kernel, None);
        assert_eq!(ServiceConfig::default().gemm_kernel, None);
    }
}

#[cfg(test)]
mod file_tests {
    use super::*;

    #[test]
    fn shipped_config_files_parse() {
        let root = env!("CARGO_MANIFEST_DIR");
        let muon = TrainConfig::from_toml_file(&format!("{root}/configs/muon_fig6.toml"))
            .expect("muon config");
        assert_eq!(muon.steps, 200);
        assert_eq!(muon.backend, Backend::Prism5);
        assert_eq!(muon.matfn_iters, 3);
        assert!((muon.lr - 0.006).abs() < 1e-12);
        assert_eq!(muon.rect_strategy, RectStrategy::Auto);

        let sham =
            TrainConfig::from_toml_file(&format!("{root}/configs/shampoo_fig5.toml"))
                .expect("shampoo config");
        assert_eq!(sham.precond_interval, 10);
        assert!((sham.weight_decay - 5e-4).abs() < 1e-12);
        // Its [service] section feeds ServiceConfig.
        let src =
            std::fs::read_to_string(format!("{root}/configs/shampoo_fig5.toml")).unwrap();
        let v = parse_toml(&src).unwrap();
        let svc = ServiceConfig::from_value(&v);
        assert_eq!(svc.workers, 4);
        assert_eq!(svc.max_batch, 4);
        // The shipped config leaves `tol` unset: per-task solver defaults
        // (InvSqrt at 1e-9) must survive, not a blanket override.
        assert_eq!(svc.tol, None);
        assert_eq!(svc.precision, Precision::F64);
        assert_eq!(svc.sketch_p, 8);
        assert_eq!(svc.solver_cache_cap, 32);
        // Admission-control knobs documented in the shipped config; the
        // fault-injection knob must ship commented out (inert).
        assert_eq!(svc.queue_cap, 256);
        assert_eq!(svc.admission, Admission::Block);
        assert_eq!(svc.faults, None);
        // Bucket-scheduler knobs documented in the shipped config: a 5 ms
        // linger, with the warm-state snapshot shipped commented out.
        assert_eq!(svc.linger, Some(Duration::from_millis(5)));
        assert_eq!(svc.cache_snapshot, None);
        svc.validate().expect("shipped service config must validate");

        // Muon's config opts into the mixed-precision polar path and keeps
        // a shorter linger for its per-width orthogonalization buckets.
        let src = std::fs::read_to_string(format!("{root}/configs/muon_fig6.toml")).unwrap();
        let v = parse_toml(&src).unwrap();
        let msvc = ServiceConfig::from_value(&v);
        assert_eq!(msvc.precision, Precision::Mixed);
        assert_eq!(msvc.linger, Some(Duration::from_millis(2)));
    }

    #[test]
    fn missing_config_file_is_error() {
        assert!(TrainConfig::from_toml_file("/nonexistent/x.toml").is_err());
    }
}
