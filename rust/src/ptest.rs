//! Property-based testing mini-framework.
//!
//! The offline environment has no `proptest`, so we provide the 20% that
//! covers our needs: seeded generators, a case runner that reports the
//! failing seed, and simple halving shrink for numeric scalars.
//!
//! ```
//! use prism::ptest::{Prop, gens};
//! Prop::new("abs is nonneg")
//!     .cases(100)
//!     .run(|rng| {
//!         let x = gens::f64_in(rng, -10.0, 10.0);
//!         assert!(x.abs() >= 0.0);
//!     });
//! ```

use crate::rng::Rng;

/// A property runner.
pub struct Prop {
    name: String,
    cases: usize,
    seed: u64,
}

impl Prop {
    pub fn new(name: &str) -> Self {
        Prop { name: name.to_string(), cases: 64, seed: 0x5EED }
    }
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Run `f` for each case with an independent RNG; panics with the case
    /// seed on failure so the case can be replayed deterministically.
    pub fn run(self, f: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let result = std::panic::catch_unwind(|| {
                let mut rng = Rng::seed_from(case_seed);
                f(&mut rng);
            });
            if let Err(panic) = result {
                let msg = panic
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property '{}' failed at case {} (replay seed {:#x}): {}",
                    self.name, case, case_seed, msg
                );
            }
        }
    }

    /// Like [`run`] but the property returns `Result<(), String>` instead of
    /// panicking; useful when asserting numeric bounds with context.
    pub fn check(self, f: impl Fn(&mut Rng) -> Result<(), String>) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let mut rng = Rng::seed_from(case_seed);
            if let Err(msg) = f(&mut rng) {
                panic!(
                    "property '{}' failed at case {} (replay seed {:#x}): {}",
                    self.name, case, case_seed, msg
                );
            }
        }
    }

    /// Like [`run`], but the property takes a matrix dimension drawn
    /// uniformly from `[lo, hi]`. On failure, smaller dimensions are retried
    /// with the *same* case seed and the smallest still-failing dimension is
    /// reported — per-case shrink, so matrix counterexamples arrive at
    /// debuggable size.
    pub fn run_dim(
        self,
        lo: usize,
        hi: usize,
        f: impl Fn(&mut Rng, usize) + std::panic::RefUnwindSafe,
    ) {
        assert!(1 <= lo && lo <= hi, "run_dim: bad range [{lo}, {hi}]");
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
            let dim = {
                let mut rng = Rng::seed_from(case_seed);
                lo + rng.below(hi - lo + 1)
            };
            let try_dim = |d: usize| -> Result<(), String> {
                let result = std::panic::catch_unwind(|| {
                    let mut rng = Rng::seed_from(case_seed);
                    // Burn the dimension draw so the entry stream matches
                    // what the original case saw.
                    let _ = rng.below(hi - lo + 1);
                    f(&mut rng, d);
                });
                match result {
                    Ok(()) => Ok(()),
                    Err(panic) => Err(panic
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<non-string panic>".to_string())),
                }
            };
            if let Err(first_msg) = try_dim(dim) {
                // Shrink: smallest dimension (same seed) that still fails.
                let mut shrunk = (dim, first_msg);
                for d in lo..dim {
                    if let Err(msg) = try_dim(d) {
                        shrunk = (d, msg);
                        break;
                    }
                }
                panic!(
                    "property '{}' failed at case {} (replay seed {:#x}, dim {} shrunk to {}): {}",
                    self.name, case, case_seed, dim, shrunk.0, shrunk.1
                );
            }
        }
    }
}

/// Generator helpers.
pub mod gens {
    use crate::rng::Rng;

    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.uniform_in(lo, hi)
    }

    /// Log-uniform over [lo, hi], lo > 0 — for σ_min-style magnitudes.
    pub fn f64_log(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (rng.uniform_in(lo.ln(), hi.ln())).exp()
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// Random descending spectrum in (0, 1] with σ_max = 1.
    pub fn spectrum(rng: &mut Rng, n: usize, sigma_min: f64) -> Vec<f64> {
        let mut s: Vec<f64> = (0..n).map(|_| f64_log(rng, sigma_min, 1.0)).collect();
        s.sort_by(|a, b| b.partial_cmp(a).unwrap());
        s[0] = 1.0;
        if n > 1 {
            s[n - 1] = sigma_min;
        }
        s
    }

    /// One of the listed items.
    pub fn choice<'a, T>(rng: &mut Rng, items: &'a [T]) -> &'a T {
        &items[rng.below(items.len())]
    }

    /// Random rows×cols matrix with iid N(0, 1/cols) entries (σ_max ≈
    /// 1 + √(cols/rows)) — the generic rectangular test input.
    pub fn gaussian_mat(rng: &mut Rng, rows: usize, cols: usize) -> crate::linalg::Mat {
        crate::randmat::gaussian(rng, rows, cols)
    }

    /// Random n×n SPD matrix with eigenvalues log-spaced in [wmin, 1]
    /// (condition number exactly 1/wmin), random eigenbasis.
    pub fn spd(rng: &mut Rng, n: usize, wmin: f64) -> crate::linalg::Mat {
        assert!(wmin > 0.0 && wmin <= 1.0);
        let w = crate::randmat::logspace(wmin, 1.0, n);
        crate::randmat::sym_with_spectrum(rng, n, &w)
    }

    /// Random m×n (m ≥ n) matrix with singular values log-spaced in
    /// [1/κ, 1] — condition number exactly `kappa`.
    pub fn ill_conditioned(rng: &mut Rng, m: usize, n: usize, kappa: f64) -> crate::linalg::Mat {
        assert!(kappa >= 1.0 && n <= m);
        let s = crate::randmat::logspace(1.0 / kappa, 1.0, n);
        crate::randmat::with_spectrum(rng, m, n, &s)
    }
}

/// Halving shrink search: find a smaller `x` in [lo, x0] that still fails
/// `fails`, assuming monotone failure. Returns the smallest failing value
/// found within `steps` bisections.
pub fn shrink_f64(x0: f64, lo: f64, steps: usize, fails: impl Fn(f64) -> bool) -> f64 {
    debug_assert!(fails(x0));
    let mut hi = x0;
    let mut lo = lo;
    for _ in 0..steps {
        let mid = 0.5 * (lo + hi);
        if fails(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    hi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_passes() {
        Prop::new("square nonneg").cases(50).run(|rng| {
            let x = gens::f64_in(rng, -5.0, 5.0);
            assert!(x * x >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn prop_reports_seed_on_failure() {
        Prop::new("always fails").cases(3).run(|_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn check_variant_works() {
        Prop::new("sum comm").cases(20).check(|rng| {
            let a = gens::f64_in(rng, -1.0, 1.0);
            let b = gens::f64_in(rng, -1.0, 1.0);
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err(format!("{a} {b}"))
            }
        });
    }

    #[test]
    fn spectrum_gen_shape() {
        let mut rng = Rng::seed_from(1);
        let s = gens::spectrum(&mut rng, 10, 1e-4);
        assert_eq!(s[0], 1.0);
        assert_eq!(s[9], 1e-4);
        assert!(s.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn shrink_finds_boundary() {
        // fails for x >= 2.0
        let x = shrink_f64(10.0, 0.0, 40, |x| x >= 2.0);
        assert!((x - 2.0).abs() < 1e-9);
    }

    #[test]
    fn usize_in_bounds() {
        let mut rng = Rng::seed_from(2);
        for _ in 0..100 {
            let v = gens::usize_in(&mut rng, 3, 7);
            assert!((3..=7).contains(&v));
        }
    }

    #[test]
    fn spd_gen_is_spd_with_requested_condition() {
        let mut rng = Rng::seed_from(3);
        let a = gens::spd(&mut rng, 8, 1e-2);
        assert_eq!(a.shape(), (8, 8));
        assert_eq!(a.symmetry_defect(), 0.0);
        let e = crate::linalg::eigen::symmetric_eigen(&a);
        let (mut wmin, mut wmax) = (f64::MAX, f64::MIN);
        for &w in &e.values {
            assert!(w > 0.0, "non-positive eigenvalue {w}");
            wmin = wmin.min(w);
            wmax = wmax.max(w);
        }
        assert!((wmax - 1.0).abs() < 1e-8, "wmax={wmax}");
        assert!((wmin - 1e-2).abs() < 1e-8, "wmin={wmin}");
    }

    #[test]
    fn ill_conditioned_gen_hits_kappa() {
        let mut rng = Rng::seed_from(4);
        let a = gens::ill_conditioned(&mut rng, 12, 7, 1e3);
        assert_eq!(a.shape(), (12, 7));
        let d = crate::linalg::svd::svd(&a);
        let cond = d.s[0] / d.s[d.s.len() - 1];
        assert!((cond - 1e3).abs() / 1e3 < 1e-6, "cond={cond}");
    }

    #[test]
    fn gaussian_mat_gen_shape_and_finite() {
        let mut rng = Rng::seed_from(5);
        let a = gens::gaussian_mat(&mut rng, 6, 9);
        assert_eq!(a.shape(), (6, 9));
        assert!(!a.has_non_finite());
        assert!(a.fro_norm() > 0.0);
    }

    #[test]
    fn run_dim_passes_dims_in_range() {
        Prop::new("dims in range").cases(20).run_dim(3, 9, |_rng, n| {
            assert!((3..=9).contains(&n));
        });
    }

    #[test]
    #[should_panic(expected = "shrunk to 5")]
    fn run_dim_shrinks_to_smallest_failing() {
        // Fails for every dim ≥ 5 ⇒ the shrink must land exactly on 5.
        Prop::new("fails at >=5").cases(40).run_dim(2, 12, |_rng, n| {
            assert!(n < 5, "dim {n} too big");
        });
    }
}
