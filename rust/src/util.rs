//! Small shared utilities: errors, timing, float comparison, lightweight logging.

use std::fmt;
use std::time::Instant;

/// Crate-wide error type. We deliberately keep a single enum rather than
/// per-module error types: almost every failure in this library is either a
/// shape/argument problem, a numerical breakdown, or an I/O / runtime issue.
#[derive(Debug)]
pub enum Error {
    /// Dimension or argument mismatch (programmer error surfaced politely).
    Shape(String),
    /// Numerical failure (non-convergence, non-SPD input to Cholesky, ...).
    Numerical(String),
    /// Config/CLI parse problems.
    Parse(String),
    /// Filesystem or PJRT runtime problems.
    Runtime(String),
    /// Admission control refused the job: the service queue is at capacity.
    Backpressure(String),
    /// A configuration value is out of its valid range.
    Config(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Backpressure(m) => write!(f, "backpressure: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[macro_export]
macro_rules! shape_err {
    ($($arg:tt)*) => { $crate::util::Error::Shape(format!($($arg)*)) };
}

#[macro_export]
macro_rules! numerical_err {
    ($($arg:tt)*) => { $crate::util::Error::Numerical(format!($($arg)*)) };
}

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// A poisoned mutex means some thread panicked while holding it — for the
/// coordinator that thread's damage is already converted into typed error
/// results by the supervisor, so the data behind the lock is still
/// consistent and the right move is to keep serving rather than cascade
/// the panic into every later `submit`/`recv`/`inflight` call.
///
/// The mutex type is [`crate::runtime::sync::Mutex`] — identical to
/// `std::sync::Mutex` on normal builds, and the model checker's mutex under
/// `--cfg loom` — so poison recovery is exercised by the loom suite too.
/// Callers therefore import `Mutex` from `crate::runtime::sync`, not
/// `std::sync` (lint rules R1/R4).
pub fn lock_or_recover<T>(
    m: &crate::runtime::sync::Mutex<T>,
) -> crate::runtime::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Wall-clock stopwatch in seconds.
#[derive(Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Relative closeness check used across the numerical tests.
pub fn approx_eq(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let d = (a - b).abs();
    d <= abs || d <= rel * a.abs().max(b.abs())
}

/// `assert!(approx_eq(..))` with a useful message.
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr, $rel:expr, $abs:expr) => {{
        let (a, b) = ($a, $b);
        assert!(
            $crate::util::approx_eq(a, b, $rel, $abs),
            "assert_close failed: {} vs {} (rel={}, abs={})",
            a,
            b,
            $rel,
            $abs
        );
    }};
    ($a:expr, $b:expr) => {
        $crate::assert_close!($a, $b, 1e-9, 1e-12)
    };
}

/// Verbosity-gated logging to stderr. Level 0 = silent, 1 = info, 2 = debug.
/// The level is process-global; set once from the CLI.
use std::sync::atomic::{AtomicU8, Ordering};
static LOG_LEVEL: AtomicU8 = AtomicU8::new(1);

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_level() -> u8 {
    LOG_LEVEL.load(Ordering::Relaxed)
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 1 { eprintln!("[info] {}", format!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 2 { eprintln!("[debug] {}", format!($($arg)*)); }
    };
}

/// Pretty-print seconds with an adaptive unit.
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

/// Median of a slice (copies + sorts; fine for bench-sized inputs).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile (0..=100) with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * ((v.len() - 1) as f64);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_basic() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(!approx_eq(1.0, 1.1, 1e-3, 1e-3));
        assert!(approx_eq(0.0, 1e-15, 0.0, 1e-12));
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!((percentile(&xs, 25.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2.0).ends_with('s'));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2e-6).ends_with("us"));
        assert!(fmt_duration(2e-9).ends_with("ns"));
    }

    #[test]
    fn error_display() {
        let e = Error::Shape("2x3 vs 4x5".into());
        assert!(format!("{e}").contains("shape"));
        assert!(format!("{}", Error::Backpressure("queue full".into())).contains("backpressure"));
        assert!(format!("{}", Error::Config("queue_cap = 0".into())).contains("config"));
    }

    #[test]
    fn lock_or_recover_survives_poison() {
        use crate::runtime::sync::{Arc, Mutex};
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_or_recover(&m), 7);
        *lock_or_recover(&m) = 8;
        assert_eq!(*lock_or_recover(&m), 8);
    }

    #[test]
    fn lock_or_recover_returns_pre_panic_state_after_catch_unwind() {
        use crate::runtime::sync::Mutex;
        // Poison on the *same* thread via catch_unwind: the holder mutates
        // the state, then panics with the guard alive. Recovery must hand
        // back exactly the pre-panic state — mutation included — instead of
        // propagating the poison.
        let m = Mutex::new(vec![1u32, 2]);
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = m.lock().unwrap();
            g.push(3);
            panic!("poison with the guard alive");
        }))
        .is_err();
        assert!(panicked, "the closure must have panicked");
        assert!(m.lock().is_err(), "mutex should be poisoned");
        assert_eq!(*lock_or_recover(&m), vec![1, 2, 3]);
        lock_or_recover(&m).push(4);
        assert_eq!(*lock_or_recover(&m), vec![1, 2, 3, 4]);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_s();
        let b = sw.elapsed_s();
        assert!(b >= a);
        assert!(sw.elapsed_ms() >= 0.0);
        assert!(sw.elapsed_us() >= 0.0);
    }
}
