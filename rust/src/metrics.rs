//! Metrics: counters, gauges and log-bucketed histograms with a process-wide
//! registry. The coordinator reports queue depths, batch sizes and per-stage
//! latencies through this module; benches print the same tables.

use crate::runtime::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use crate::runtime::sync::{Arc, Mutex, OnceLock};
use crate::util::lock_or_recover;
use std::collections::BTreeMap;

/// Monotone counter.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Point-in-time gauge.
#[derive(Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    pub fn set(&self, x: i64) {
        self.v.store(x, Ordering::Relaxed);
    }
    pub fn add(&self, x: i64) {
        self.v.fetch_add(x, Ordering::Relaxed);
    }
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Histogram with logarithmic buckets covering ~[1ns, 1000s] when values are
/// seconds (or any positive quantity). 8 buckets per decade.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_micro: AtomicU64, // sum in 1e-6 units for mean
}

const DECADES: f64 = 12.0; // 1e-9 .. 1e3
const PER_DECADE: usize = 8;
const NBUCKETS: usize = (DECADES as usize) * PER_DECADE;
const LOG_MIN: f64 = -9.0;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_micro: AtomicU64::new(0),
        }
    }

    fn bucket_of(x: f64) -> usize {
        if x <= 1e-9 {
            return 0;
        }
        let idx = ((x.log10() - LOG_MIN) * PER_DECADE as f64).floor() as isize;
        idx.clamp(0, NBUCKETS as isize - 1) as usize
    }

    fn bucket_upper(i: usize) -> f64 {
        10f64.powf(LOG_MIN + (i + 1) as f64 / PER_DECADE as f64)
    }

    pub fn observe(&self, x: f64) {
        self.buckets[Self::bucket_of(x)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micro
            .fetch_add((x * 1e6).max(0.0) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_micro.load(Ordering::Relaxed) as f64 * 1e-6 / c as f64
    }

    /// Approximate quantile from the bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_upper(i);
            }
        }
        Self::bucket_upper(NBUCKETS - 1)
    }
}

/// Process-wide registry keyed by name.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histos: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::default)
}

impl Registry {
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        lock_or_recover(&self.counters)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::default()))
            .clone()
    }
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        lock_or_recover(&self.gauges)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::default()))
            .clone()
    }
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        lock_or_recover(&self.histos)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Render a plain-text report of everything registered.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, c) in lock_or_recover(&self.counters).iter() {
            out.push_str(&format!("counter {k} = {}\n", c.get()));
        }
        for (k, g) in lock_or_recover(&self.gauges).iter() {
            out.push_str(&format!("gauge   {k} = {}\n", g.get()));
        }
        for (k, h) in lock_or_recover(&self.histos).iter() {
            out.push_str(&format!(
                "histo   {k}: n={} mean={:.3e} p50={:.3e} p90={:.3e} p99={:.3e}\n",
                h.count(),
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.9),
                h.quantile(0.99),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::default();
        let c = r.counter("jobs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = r.gauge("depth");
        g.set(10);
        g.add(-3);
        assert_eq!(g.get(), 7);
        // Same name returns same instance.
        assert_eq!(r.counter("jobs").get(), 5);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-6); // 1us .. 1ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p90 && p90 <= p99);
        // p50 should be near 0.5ms
        assert!(p50 > 1e-4 && p50 < 1.5e-3, "p50={p50}");
        assert!((h.mean() - 5.0e-4).abs() < 1e-4, "mean={}", h.mean());
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.9), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn report_contains_names() {
        let r = Registry::default();
        r.counter("a").inc();
        r.gauge("b").set(1);
        r.histogram("c").observe(0.5);
        let rep = r.report();
        assert!(rep.contains("counter a"));
        assert!(rep.contains("gauge   b"));
        assert!(rep.contains("histo   c"));
    }

    #[test]
    fn global_registry_singleton() {
        global().counter("x").inc();
        assert!(global().counter("x").get() >= 1);
    }
}
