//! Randomized sketching (Step 5 of the PRISM meta-algorithm).
//!
//! A Gaussian sketch `S ∈ R^{p×n}` is an oblivious subspace embedding; the
//! quantities PRISM needs are the *sketched power traces*
//! `T_i = tr(S R^i Sᵀ)`, i = 1..q, computed by applying `R` repeatedly to the
//! p sketched rows — `O(n² p)` total, never forming `R^i`.
//!
//! The module also provides exact traces (for tests and the ablation bench)
//! and a Hutchinson estimator for comparison.

use crate::linalg::gemm::{global_engine, GemmEngine, Workspace};
use crate::linalg::Mat;
use crate::rng::Rng;
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of sketch draws ([`SketchKind::fill`] calls, which
/// [`SketchKind::draw`] goes through too). The service bench reads deltas of
/// this to show that batched solves share **one** sketch fill per iteration
/// across the whole batch — O(iters) fills per batch instead of
/// O(batch · iters) — since worker threads fill on their own threads where a
/// thread-local scope would be invisible to the measuring thread.
static FILLS_TOTAL: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static FILLS_LOCAL: Cell<u64> = const { Cell::new(0) };
}

/// Total sketch fills performed by this process so far.
pub fn fills_total() -> u64 {
    FILLS_TOTAL.load(Ordering::Relaxed)
}

/// Counts sketch fills on the *current thread* between `begin` and `fills`
/// — race-free under parallel test execution (same pattern as
/// [`crate::linalg::gemm::GemmScope`]).
pub struct SketchScope {
    start: u64,
}

impl SketchScope {
    pub fn begin() -> SketchScope {
        SketchScope { start: FILLS_LOCAL.with(|c| c.get()) }
    }
    /// Fills on this thread since `begin`.
    pub fn fills(&self) -> u64 {
        FILLS_LOCAL.with(|c| c.get()) - self.start
    }
}

fn record_fill() {
    FILLS_TOTAL.fetch_add(1, Ordering::Relaxed);
    FILLS_LOCAL.with(|c| c.set(c.get() + 1));
}

/// Gaussian sketch matrix `S` with iid N(0, 1/p) entries (scaling keeps
/// `E[tr(S M Sᵀ)] = tr(M)`).
pub struct GaussianSketch {
    pub s: Mat, // p x n
}

impl GaussianSketch {
    pub fn draw(rng: &mut Rng, p: usize, n: usize) -> Self {
        SketchKind::Gaussian.draw(rng, p, n)
    }

    pub fn p(&self) -> usize {
        self.s.rows()
    }
    pub fn n(&self) -> usize {
        self.s.cols()
    }

    /// Sketched power traces `[tr(S R¹ Sᵀ), ..., tr(S R^q Sᵀ)]`. Allocating
    /// convenience wrapper over [`power_traces_into`] (throwaway workspace,
    /// global engine); hot-loop callers — the α fits in `prism::fit` and
    /// friends — use the `_into` form with their solver's pooled
    /// [`Workspace`] so the steady state allocates nothing.
    pub fn power_traces(&self, r: &Mat, q: usize) -> Vec<f64> {
        let mut out = vec![0.0; q];
        power_traces_into(&self.s, r, &mut out, &global_engine(), &mut Workspace::new());
        out
    }

    /// Workspace-pooled form of [`GaussianSketch::power_traces`]: fills
    /// `out` (length q) drawing every panel from `ws`.
    pub fn power_traces_in(
        &self,
        r: &Mat,
        out: &mut [f64],
        eng: &GemmEngine,
        ws: &mut Workspace,
    ) {
        power_traces_into(&self.s, r, out, eng, ws);
    }
}

/// Sketched power traces `out[i-1] = tr(S R^i Sᵀ)`, i = 1..=out.len(), for
/// symmetric `R` and a p×n sketch `s`, computed right-to-left: `Y_0 = Sᵀ`,
/// `Y_i = R Y_{i-1}`, and `tr(S R^i Sᵀ) = Σ_{j,k} S[j,k] · Y_i[k,j]`.
///
/// Cost: q products of (p × n)·(n × n) = O(q n² p). The panel is kept
/// TRANSPOSED (p × n): because R is symmetric, `Yᵀ_i = Yᵀ_{i-1} · R`, and
/// the skinny (p × n)·(n × n) shape routes through the GEMM engine's
/// thin-A fast path (p ≤ MR) — S is packed once per product and R streams
/// unpacked, instead of the square-blocked path packing all of R per power
/// (§Perf change 7 measured 2.7x for the transposed layout at n = 512,
/// p = 8; the thin-A routing compounds it). Both ping-pong panels come from
/// `ws`, so from the second same-shape call onward the computation performs
/// **zero heap allocations** (asserted by the matfn allocation tests via
/// [`Workspace::allocations`]).
pub fn power_traces_into(
    s: &Mat,
    r: &Mat,
    out: &mut [f64],
    eng: &GemmEngine,
    ws: &mut Workspace,
) {
    assert!(r.is_square());
    assert_eq!(r.rows(), s.cols(), "sketch width mismatch");
    let (p, n) = s.shape();
    let mut yt = ws.take(p, n);
    yt.copy_from(s);
    let mut yn = ws.take(p, n);
    for slot in out.iter_mut() {
        eng.matmul_into(&mut yn, &yt, r);
        std::mem::swap(&mut yt, &mut yn);
        // tr(S R^i Sᵀ) = Σ_{j,k} S[j,k] · Yᵀ[j,k] — an elementwise dot.
        *slot = s
            .as_slice()
            .iter()
            .zip(yt.as_slice())
            .map(|(a, b)| a * b)
            .sum();
    }
    ws.put(yt);
    ws.put(yn);
}

/// Draw a fresh p×n sketch of `kind` into pooled scratch, compute the first
/// `q` sketched power traces of symmetric `r` through the skinny GEMM path,
/// and hand the trace row to `f` — the shared primitive behind every
/// PRISM α fit (`prism::fit`, inverse Newton, Chebyshev). All scratch (the
/// sketch, the 1×q trace row, the propagation panels) comes from `ws`, so a
/// warm same-shape steady state performs zero heap allocations.
#[allow(clippy::too_many_arguments)]
pub fn with_sketched_traces<T>(
    r: &Mat,
    p: usize,
    kind: SketchKind,
    q: usize,
    rng: &mut Rng,
    eng: &GemmEngine,
    ws: &mut Workspace,
    f: impl FnOnce(&[f64]) -> T,
) -> T {
    let mut s = ws.take(p, r.rows());
    kind.fill(&mut s, rng);
    let mut t = ws.take(1, q);
    power_traces_into(&s, r, t.as_mut_slice(), eng, ws);
    let out = f(t.as_slice());
    ws.put(s);
    ws.put(t);
    out
}

/// Alternative sketch families — the paper notes "there are many plausible
/// choices for the sketch matrix S, and here simple random Gaussian matrices
/// appear to be sufficient"; these let us verify that claim empirically
/// (ablation bench `ablation_sketch`) and give users cheaper options.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchKind {
    /// iid N(0, 1/p) — the paper's default.
    Gaussian,
    /// iid ±1/√p — same first two moments, no Box–Muller cost.
    Rademacher,
    /// Sparse embedding (Clarkson–Woodruff): one ±1 per column, hashed to a
    /// random row. Stored dense here (the R·Y sweep dominates cost anyway);
    /// the statistical behaviour is what the ablation compares.
    CountSketch,
    /// Subsampled randomized Hadamard transform: rows of `√(1/p)·H D` with D
    /// a random sign flip and H the ±1 Walsh–Hadamard pattern of size padded
    /// to a power of two (truncated back to n columns).
    Srht,
}

impl SketchKind {
    pub fn name(&self) -> &'static str {
        match self {
            SketchKind::Gaussian => "gaussian",
            SketchKind::Rademacher => "rademacher",
            SketchKind::CountSketch => "countsketch",
            SketchKind::Srht => "srht",
        }
    }

    /// Fill an existing p×n buffer with a fresh sketch of this kind — the
    /// allocation-free primitive the α-fit hot loops use (the buffer comes
    /// from the solver's [`Workspace`] and is reused every iteration).
    /// Every entry of `s` is overwritten; the RNG consumption is identical
    /// to [`SketchKind::draw`] for the same kind and shape, so pooled and
    /// allocating callers see bit-identical sketches from equal seeds.
    pub fn fill(&self, s: &mut Mat, rng: &mut Rng) {
        record_fill();
        let (p, n) = s.shape();
        match self {
            SketchKind::Gaussian => {
                let v = 1.0 / (p as f64).sqrt();
                for x in s.as_mut_slice() {
                    *x = rng.normal() * v;
                }
            }
            SketchKind::Rademacher => {
                let v = 1.0 / (p as f64).sqrt();
                for i in 0..p {
                    for j in 0..n {
                        s[(i, j)] = if rng.uniform() < 0.5 { -v } else { v };
                    }
                }
            }
            SketchKind::CountSketch => {
                // One ±1 per column in a uniformly random row: E[SᵀS] = I,
                // so tr(S M Sᵀ) is unbiased for tr(M).
                s.fill_with(0.0);
                for j in 0..n {
                    let row = rng.below(p);
                    s[(row, j)] = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
                }
            }
            SketchKind::Srht => srht_fill(rng, s),
        }
    }

    /// Draw a p×n sketch of this kind (dense representation, shared
    /// [`GaussianSketch`] container so `power_traces` works unchanged).
    /// Allocating wrapper over [`SketchKind::fill`].
    pub fn draw(&self, rng: &mut Rng, p: usize, n: usize) -> GaussianSketch {
        let mut s = Mat::zeros(p, n);
        self.fill(&mut s, rng);
        GaussianSketch { s }
    }
}

/// Dense SRHT rows, written into `s` (p×n). Row i is `H[r_i, ·] ⊙ signs/√p`
/// where `r_i` is a sampled row index of the n2×n2 Walsh–Hadamard pattern
/// `H[i,j] = (−1)^{popcount(i & j)}`, n2 = next power of two ≥ n. The
/// 1/√n2 Hadamard normalization and the √(n2/p) subsampling correction
/// combine to 1/√p, keeping `E[tr(S M Sᵀ)] = tr(M)`.
///
/// Allocation-free like the other families: the sign vector is stashed in
/// `s`'s last row (which is transformed last, element-wise read-before-
/// write), and the RNG draw order — n sign draws, then p row samples —
/// matches the natural two-pass formulation exactly.
fn srht_fill(rng: &mut Rng, s: &mut Mat) {
    let (p, n) = s.shape();
    let n2 = n.next_power_of_two();
    let scale = 1.0 / (p as f64).sqrt();
    for j in 0..n {
        s[(p - 1, j)] = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
    }
    for i in 0..p {
        let ri = rng.below(n2);
        for j in 0..n {
            let h = if (ri & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
            // signs[j] lives at s[(p-1, j)] until that row's own transform
            // (i == p-1) consumes each entry exactly once.
            s[(i, j)] = h * s[(p - 1, j)] * scale;
        }
    }
}

/// Exact power traces `tr(R^i)` for i = 1..q — O(q n³); test/ablation only.
pub fn exact_power_traces(r: &Mat, q: usize) -> Vec<f64> {
    assert!(r.is_square());
    let eng = crate::linalg::gemm::global_engine();
    let mut acc = r.clone();
    let mut nxt = Mat::zeros(r.rows(), r.cols());
    let mut out = Vec::with_capacity(q);
    out.push(acc.trace());
    for _ in 1..q {
        eng.matmul_into(&mut nxt, &acc, r);
        std::mem::swap(&mut acc, &mut nxt);
        out.push(acc.trace());
    }
    out
}

/// Hutchinson trace estimates `tr(R^i)` via `z ~ Rademacher`, for reference.
pub fn hutchinson_power_traces(rng: &mut Rng, r: &Mat, q: usize, probes: usize) -> Vec<f64> {
    let n = r.rows();
    let mut out = vec![0.0; q];
    for _ in 0..probes {
        let z: Vec<f64> = (0..n)
            .map(|_| if rng.uniform() < 0.5 { -1.0 } else { 1.0 })
            .collect();
        let mut y = z.clone();
        for t in out.iter_mut().take(q) {
            y = r.matvec(&y);
            let dot: f64 = z.iter().zip(&y).map(|(a, b)| a * b).sum();
            *t += dot / probes as f64;
        }
    }
    out
}

/// Sketched squared Frobenius norm `‖S M‖_F²` (used by tests to validate the
/// OSE property on our Gaussian sketches). The skinny (p × n)·(n × m)
/// product routes through the engine's thin-A path — S packed once, M
/// streamed, no transpose materialised (this used to go through
/// `matmul_a_bt` on an explicitly transposed M).
pub fn sketched_fro_sq(s: &GaussianSketch, m: &Mat) -> f64 {
    let sm = global_engine().matmul(&s.s, m);
    sm.fro_norm_sq()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::syrk_at_a;
    use crate::ptest::Prop;

    fn sym(rng: &mut Rng, n: usize) -> Mat {
        let g = Mat::gaussian(rng, n + 2, n, 1.0 / (n as f64).sqrt());
        syrk_at_a(&g)
    }

    #[test]
    fn sketched_traces_close_to_exact() {
        let mut rng = Rng::seed_from(1);
        let n = 48;
        let r = sym(&mut rng, n);
        let exact = exact_power_traces(&r, 6);
        // Average several sketches: unbiasedness check.
        let reps = 40;
        let mut mean = vec![0.0; 6];
        for _ in 0..reps {
            let s = GaussianSketch::draw(&mut rng, 8, n);
            let t = s.power_traces(&r, 6);
            for i in 0..6 {
                mean[i] += t[i] / reps as f64;
            }
        }
        for i in 0..6 {
            let rel = (mean[i] - exact[i]).abs() / exact[i].abs().max(1e-12);
            assert!(rel < 0.25, "i={i} mean={} exact={} rel={rel}", mean[i], exact[i]);
        }
    }

    #[test]
    fn single_sketch_concentrates_reasonably() {
        // The paper uses p as small as 5; verify a single draw with p=8 is
        // within a factor useful for the α fit (coefficients are ratios of
        // traces, so moderate error is tolerated).
        let mut rng = Rng::seed_from(2);
        let n = 64;
        let r = sym(&mut rng, n);
        let exact = exact_power_traces(&r, 6);
        let s = GaussianSketch::draw(&mut rng, 8, n);
        let t = s.power_traces(&r, 6);
        for i in 0..6 {
            let rel = (t[i] - exact[i]).abs() / exact[i].abs().max(1e-12);
            // Variance grows with the power i (T₆ is dominated by the top
            // eigenvalues); a single p=8 draw stays within a small constant
            // factor, which is all the α fit needs (tested end-to-end in
            // prism::fit::sketched_close_to_exact_alpha).
            let tol = if i < 3 { 0.6 } else { 1.5 };
            assert!(rel < tol, "i={i} rel={rel}");
        }
    }

    #[test]
    fn power_traces_match_definition_small() {
        // Verify tr(S R^i Sᵀ) literally on a tiny case.
        let mut rng = Rng::seed_from(3);
        let n = 6;
        let r = sym(&mut rng, n);
        let s = GaussianSketch::draw(&mut rng, 3, n);
        let t = s.power_traces(&r, 3);
        // Direct: S R^i Sᵀ.
        let mut ri = r.clone();
        for i in 0..3 {
            let srs = crate::linalg::gemm::matmul(
                &crate::linalg::gemm::matmul(&s.s, &ri),
                &s.s.transpose(),
            );
            assert!((srs.trace() - t[i]).abs() < 1e-9, "i={i}");
            ri = crate::linalg::gemm::matmul(&ri, &r);
        }
    }

    #[test]
    fn power_traces_into_is_allocation_free_when_warm() {
        // The satellite contract: steady-state sketch power traces draw
        // every panel from the caller's Workspace — zero heap allocations
        // from the second same-shape call onward — and agree exactly with
        // the allocating wrapper (same engine ⇒ same path ⇒ bitwise equal).
        let mut rng = Rng::seed_from(10);
        let n = 32;
        let r = sym(&mut rng, n);
        let s = GaussianSketch::draw(&mut rng, 8, n);
        let eng = crate::linalg::gemm::GemmEngine::sequential();
        let mut ws = crate::linalg::gemm::Workspace::new();
        let mut out = [0.0; 6];
        s.power_traces_in(&r, &mut out, &eng, &mut ws);
        let allocs = ws.allocations();
        assert!(allocs > 0, "cold call populates the pool");
        for _ in 0..3 {
            s.power_traces_in(&r, &mut out, &eng, &mut ws);
        }
        assert_eq!(ws.allocations(), allocs, "warm power traces must not allocate");
        assert_eq!(out.to_vec(), s.power_traces(&r, 6), "pooled and allocating paths agree");
    }

    #[test]
    fn fill_counters_count_draws() {
        // Thread-local scope is exact even with other tests filling
        // concurrently on their own threads; the global total is monotone.
        let scope = SketchScope::begin();
        let before = fills_total();
        let mut rng = Rng::seed_from(11);
        let _ = GaussianSketch::draw(&mut rng, 4, 8);
        let mut buf = Mat::zeros(4, 8);
        SketchKind::Rademacher.fill(&mut buf, &mut rng);
        assert_eq!(scope.fills(), 2);
        assert!(fills_total() >= before + 2);
    }

    #[test]
    fn fill_matches_draw_rng_stream() {
        // fill() into a recycled buffer must produce the same sketch as a
        // fresh draw() from an equally-seeded RNG — the engines rely on
        // this to keep their α sequences identical to the allocating path.
        for kind in [
            SketchKind::Gaussian,
            SketchKind::Rademacher,
            SketchKind::CountSketch,
            SketchKind::Srht,
        ] {
            let mut r1 = Rng::seed_from(77);
            let mut r2 = Rng::seed_from(77);
            let drawn = kind.draw(&mut r1, 5, 12);
            let mut buf = Mat::gaussian(&mut Rng::seed_from(0), 5, 12, 1.0); // dirty buffer
            kind.fill(&mut buf, &mut r2);
            assert_eq!(buf, drawn.s, "{}", kind.name());
        }
    }

    #[test]
    fn hutchinson_unbiased() {
        let mut rng = Rng::seed_from(4);
        let n = 32;
        let r = sym(&mut rng, n);
        let exact = exact_power_traces(&r, 3);
        let est = hutchinson_power_traces(&mut rng, &r, 3, 300);
        for i in 0..3 {
            let rel = (est[i] - exact[i]).abs() / exact[i].abs().max(1e-12);
            assert!(rel < 0.25, "i={i} rel={rel}");
        }
    }

    #[test]
    fn ose_preserves_column_norms() {
        // Johnson–Lindenstrauss flavour: ‖S M‖_F² ≈ ‖M‖_F² on average.
        Prop::new("ose frobenius").cases(10).run(|rng| {
            let n = 40;
            let m = Mat::gaussian(rng, n, 5, 1.0);
            let reps = 30;
            let mut mean = 0.0;
            for _ in 0..reps {
                let s = GaussianSketch::draw(rng, 10, n);
                mean += sketched_fro_sq(&s, &m) / reps as f64;
            }
            let rel = (mean - m.fro_norm_sq()).abs() / m.fro_norm_sq();
            assert!(rel < 0.35, "rel={rel}");
        });
    }

    #[test]
    fn all_sketch_kinds_unbiased() {
        // E[tr(S R^i Sᵀ)] = tr(R^i) for every family.
        let mut rng = Rng::seed_from(6);
        let n = 40;
        let r = sym(&mut rng, n);
        let exact = exact_power_traces(&r, 4);
        for kind in [
            SketchKind::Gaussian,
            SketchKind::Rademacher,
            SketchKind::CountSketch,
            SketchKind::Srht,
        ] {
            let reps = 60;
            let mut mean = vec![0.0; 4];
            for _ in 0..reps {
                let s = kind.draw(&mut rng, 8, n);
                let t = s.power_traces(&r, 4);
                for i in 0..4 {
                    mean[i] += t[i] / reps as f64;
                }
            }
            for i in 0..4 {
                let rel = (mean[i] - exact[i]).abs() / exact[i].abs().max(1e-12);
                assert!(rel < 0.35, "{} i={i} rel={rel}", kind.name());
            }
        }
    }

    #[test]
    fn countsketch_is_one_nonzero_per_column() {
        let mut rng = Rng::seed_from(7);
        let s = SketchKind::CountSketch.draw(&mut rng, 6, 30);
        for j in 0..30 {
            let nz: Vec<f64> =
                (0..6).map(|i| s.s[(i, j)]).filter(|v| *v != 0.0).collect();
            assert_eq!(nz.len(), 1, "column {j}");
            assert!(nz[0] == 1.0 || nz[0] == -1.0);
        }
    }

    #[test]
    fn srht_rows_have_unit_scaled_entries() {
        let mut rng = Rng::seed_from(8);
        let p = 5;
        let s = SketchKind::Srht.draw(&mut rng, p, 24);
        let v = 1.0 / (p as f64).sqrt();
        for i in 0..p {
            for j in 0..24 {
                assert!((s.s[(i, j)].abs() - v).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn rademacher_entries_pm_inv_sqrt_p() {
        let mut rng = Rng::seed_from(9);
        let p = 4;
        let s = SketchKind::Rademacher.draw(&mut rng, p, 16);
        let v = 1.0 / (p as f64).sqrt();
        let mut plus = 0;
        for i in 0..p {
            for j in 0..16 {
                assert!((s.s[(i, j)].abs() - v).abs() < 1e-12);
                if s.s[(i, j)] > 0.0 {
                    plus += 1;
                }
            }
        }
        // roughly balanced signs
        assert!(plus > 16 && plus < 48, "plus={plus}");
    }

    #[test]
    fn traces_of_identity() {
        let mut rng = Rng::seed_from(5);
        let n = 24;
        let r = Mat::eye(n);
        let s = GaussianSketch::draw(&mut rng, 64, n);
        let t = s.power_traces(&r, 4);
        // tr(S I^i Sᵀ) = ‖S‖_F² ≈ n for all i.
        for i in 0..4 {
            assert!((t[i] - n as f64).abs() / (n as f64) < 0.4, "i={i} t={}", t[i]);
            assert!((t[i] - t[0]).abs() < 1e-9);
        }
    }
}
