//! Closed-form assembly of the PRISM fitting objective `m(α)` from
//! (sketched) power traces `T_i ≈ tr(R^i)`.
//!
//! The derivations follow Appendix A of the paper. Writing the
//! per-eigenvalue next-residual as `h(λ, α)` and `m(α) = Σ_i h(λ_i, α)²`,
//! each family below gives polynomial coefficients of `m` (ascending in α,
//! constant term set to 0 — it does not affect the argmin):
//!
//! * Newton–Schulz d=1 (`g₁(ξ;α) = 1 + αξ`, 3rd-order iteration),
//! * Newton–Schulz d=2 (`g₂(ξ;α) = 1 + ξ/2 + αξ²`, 5th-order iteration),
//! * coupled inverse Newton for `A^{-1/p}` (general p via binomial sums),
//! * Chebyshev inverse iteration, and
//! * DB-Newton, whose coefficients need only `tr(M)`, `tr(M²) = ‖M‖_F²`,
//!   `tr(M⁻¹)`, `tr(M⁻²)` — all O(n²) given the inverse the iteration
//!   already computes, so **no sketching is needed** (paper §A.2).

use crate::linalg::Mat;

/// Recommended α-constraint interval per degree (paper Thm. 1 / §4.1):
/// d=1 → [1/2, 1]; d=2 → [3/8, 29/20].
///
/// For d ≥ 3 (which the paper's Part I defines but never tunes) we
/// generalise the pattern behind the published intervals: the lower bound
/// is the Taylor coefficient `a_d` (so the fit can always fall back to the
/// classical iteration — the "never slower" guarantee), and the upper bound
/// caps the small-σ growth factor `g_d(1; α) = Σ_{k<d} a_k + α` at `d + 1`,
/// i.e. `u_d = (d+1) − Σ_{k<d} a_k`. This reproduces the paper's u₁ = 1
/// exactly and gives u₂ = 1.5 (paper's empirical choice: 1.45, which we
/// keep verbatim for d = 2).
pub fn alpha_interval(d: usize) -> (f64, f64) {
    match d {
        1 => (0.5, 1.0),
        2 => (3.0 / 8.0, 29.0 / 20.0),
        _ => {
            let partial: f64 = (0..d).map(taylor_coeff).sum();
            (taylor_coeff(d), (d as f64 + 1.0) - partial)
        }
    }
}

/// Taylor coefficient `a_k` of `f(ξ) = (1−ξ)^{-1/2} = Σ_k a_k ξ^k`:
/// `a_k = C(2k, k) / 4^k` (a₀ = 1, a₁ = 1/2, a₂ = 3/8, a₃ = 5/16, ...).
pub fn taylor_coeff(k: usize) -> f64 {
    binom(2 * k, k) / 4f64.powi(k as i32)
}

/// Multiply two polynomials given by ascending coefficient vectors.
fn poly_mul(a: &[f64], b: &[f64]) -> Vec<f64> {
    let mut out = vec![0.0; a.len() + b.len() - 1];
    for (i, &av) in a.iter().enumerate() {
        if av == 0.0 {
            continue;
        }
        for (j, &bv) in b.iter().enumerate() {
            out[i + j] += av * bv;
        }
    }
    out
}

/// `T[P] = Σ_j p_j · tr(S R^j Sᵀ)` for a polynomial `P(ξ) = Σ p_j ξ^j`
/// with **zero constant term** (all fitting polynomials here vanish at 0,
/// so the unavailable power-0 trace is never needed).
fn trace_of_poly(p: &[f64], t: &[f64]) -> f64 {
    assert!(p.is_empty() || p[0].abs() < 1e-300, "non-zero constant term");
    p.iter()
        .enumerate()
        .skip(1)
        .map(|(j, &c)| c * t[j - 1])
        .sum()
}

/// Quartic coefficients of `m(α)` for **general-degree** Newton–Schulz
/// (`g_d(ξ; α) = f_{d−1}(ξ) + αξ^d`), assembled symbolically:
///
/// with `F = f_{d−1}` and `1 − ξ·X²-substitution` (X² = I − R), the sketched
/// residual is `M(α) = M₀ + αM₁ + α²M₂` where
/// `M₀ = 1 − (1−ξ)F²`, `M₁ = −2(1−ξ)F ξ^d`, `M₂ = −(1−ξ)ξ^{2d}`,
/// so `m(α) = T[M₀²] + 2αT[M₀M₁] + α²(T[M₁²] + 2T[M₀M₂]) + 2α³T[M₁M₂]
/// + α⁴T[M₂²]` — every term a trace of a power of R up to `4d + 2`
/// (exactly the paper's §4.2 count). Reduces to [`ns_d1_coeffs`] /
/// [`ns_d2_coeffs`] for d = 1, 2.
pub fn ns_general_coeffs(t: &[f64], d: usize) -> [f64; 5] {
    assert!(d >= 1);
    assert!(t.len() >= 4 * d + 2, "need T1..T{}", 4 * d + 2);
    // F = f_{d-1}(ξ), one_minus = (1 − ξ), xi_d = ξ^d.
    let f: Vec<f64> = (0..d).map(taylor_coeff).collect();
    let one_minus = vec![1.0, -1.0];
    let mut xi_d = vec![0.0; d + 1];
    xi_d[d] = 1.0;

    // M0 = 1 − (1−ξ)F² (constant terms cancel: F(0) = 1).
    let mut m0: Vec<f64> = poly_mul(&one_minus, &poly_mul(&f, &f))
        .iter()
        .map(|c| -c)
        .collect();
    m0[0] += 1.0;
    // M1 = −2(1−ξ)F·ξ^d ; M2 = −(1−ξ)·ξ^{2d}.
    let m1: Vec<f64> = poly_mul(&poly_mul(&one_minus, &f), &xi_d)
        .iter()
        .map(|c| -2.0 * c)
        .collect();
    let m2: Vec<f64> = poly_mul(&one_minus, &poly_mul(&xi_d, &xi_d))
        .iter()
        .map(|c| -c)
        .collect();

    let tp = |a: &[f64], b: &[f64]| trace_of_poly(&poly_mul(a, b), t);
    [
        0.0, // constant term unused by the argmin
        2.0 * tp(&m0, &m1),
        tp(&m1, &m1) + 2.0 * tp(&m0, &m2),
        2.0 * tp(&m1, &m2),
        tp(&m2, &m2),
    ]
}

/// Quartic coefficients for Newton–Schulz d=1 from traces `t[i] = T_{i+1}`
/// (so `t` must hold T₁..T₆, length ≥ 6).
///
/// c₁ = 4T₃ − 4T₂;  c₂ = 6T₄ − 10T₃ + 4T₂;
/// c₃ = 4T₅ − 8T₄ + 4T₃;  c₄ = T₆ − 2T₅ + T₄.
pub fn ns_d1_coeffs(t: &[f64]) -> [f64; 5] {
    assert!(t.len() >= 6, "need T1..T6");
    let tr = |i: usize| t[i - 1];
    [
        0.0,
        4.0 * tr(3) - 4.0 * tr(2),
        6.0 * tr(4) - 10.0 * tr(3) + 4.0 * tr(2),
        4.0 * tr(5) - 8.0 * tr(4) + 4.0 * tr(3),
        tr(6) - 2.0 * tr(5) + tr(4),
    ]
}

/// Quartic coefficients for Newton–Schulz d=2; needs T₁..T₁₀.
///
/// c₁ = ½T₇ + 2T₆ + ½T₅ − 3T₄;
/// c₂ = 3/2·T₈ + 3T₇ − 9/2·T₆ − 4T₅ + 4T₄;
/// c₃ = 2T₉ − 6T₇ + 4T₆;  c₄ = T₁₀ − 2T₉ + T₈.
pub fn ns_d2_coeffs(t: &[f64]) -> [f64; 5] {
    assert!(t.len() >= 10, "need T1..T10");
    let tr = |i: usize| t[i - 1];
    [
        0.0,
        0.5 * tr(7) + 2.0 * tr(6) + 0.5 * tr(5) - 3.0 * tr(4),
        1.5 * tr(8) + 3.0 * tr(7) - 4.5 * tr(6) - 4.0 * tr(5) + 4.0 * tr(4),
        2.0 * tr(9) - 6.0 * tr(7) + 4.0 * tr(6),
        tr(10) - 2.0 * tr(9) + tr(8),
    ]
}

/// How many traces each family needs.
pub fn traces_needed(d: usize) -> usize {
    match d {
        1 => 6,
        2 => 10,
        _ => 4 * d + 2,
    }
}

fn binom(n: usize, k: usize) -> f64 {
    if k > n {
        return 0.0;
    }
    let mut r = 1.0;
    for i in 0..k {
        r = r * (n - i) as f64 / (i + 1) as f64;
    }
    r
}

/// Degree-2p coefficients for the coupled inverse Newton iteration for
/// `A^{-1/p}` (paper §A.3). Needs T₁..T_{2p+2}.
///
/// Per-eigenvalue residual of the next iterate:
/// `λ + Σ_{i=1}^p C(p,i) α^i (λ^{i+1} − λ^i)`, so
/// `c_k = 2·C(p,k)(T_{k+2} − T_{k+1}) [k ≤ p]
///        + (Σ_{i+j=k, 1≤i,j≤p} C(p,i)C(p,j)) (T_{k+2} − 2T_{k+1} + T_k)`.
pub fn inverse_newton_coeffs(t: &[f64], p: usize) -> Vec<f64> {
    assert!(p >= 1);
    assert!(t.len() >= 2 * p + 2, "need T1..T{}", 2 * p + 2);
    let tr = |i: usize| t[i - 1];
    let mut c = vec![0.0; 2 * p + 1];
    for k in 1..=2 * p {
        let mut ck = 0.0;
        if k <= p {
            ck += 2.0 * binom(p, k) * (tr(k + 2) - tr(k + 1));
        }
        let mut pair_sum = 0.0;
        for i in 1..k {
            let j = k - i;
            if i <= p && j <= p {
                pair_sum += binom(p, i) * binom(p, j);
            }
        }
        if pair_sum > 0.0 {
            ck += pair_sum * (tr(k + 2) - 2.0 * tr(k + 1) + tr(k));
        }
        c[k] = ck;
    }
    c
}

/// Quadratic coefficients for the Chebyshev inverse iteration (paper §A.4):
/// c₁ = 2T₅ − 2T₄;  c₂ = T₄ − 2T₅ + T₆. Needs T₁..T₆.
/// Recommended interval [1/2, 2].
pub fn chebyshev_coeffs(t: &[f64]) -> [f64; 3] {
    assert!(t.len() >= 6, "need T1..T6");
    let tr = |i: usize| t[i - 1];
    [0.0, 2.0 * tr(5) - 2.0 * tr(4), tr(4) - 2.0 * tr(5) + tr(6)]
}

/// Exact DB-Newton quartic coefficients in O(n²) (paper §A.2):
///
/// c₁ = tr(−4I + 8M − 4M²)
/// c₂ = tr(10I − 14M + 6M² − 2M⁻¹)
/// c₃ = tr(−12I + 12M − 4M² + 4M⁻¹)
/// c₄ = tr(6I − 4M + M² − 4M⁻¹ + M⁻²)
///
/// using `tr(M²) = Σ_ij M_ij²` for symmetric M.
pub fn db_newton_coeffs(m: &Mat, m_inv: &Mat) -> [f64; 5] {
    assert!(m.is_square() && m_inv.is_square());
    let n = m.rows() as f64;
    let tr_m = m.trace();
    let tr_m2 = m.fro_norm_sq(); // symmetric M
    let tr_minv = m_inv.trace();
    let tr_minv2 = m_inv.fro_norm_sq();
    [
        0.0,
        -4.0 * n + 8.0 * tr_m - 4.0 * tr_m2,
        10.0 * n - 14.0 * tr_m + 6.0 * tr_m2 - 2.0 * tr_minv,
        -12.0 * n + 12.0 * tr_m - 4.0 * tr_m2 + 4.0 * tr_minv,
        6.0 * n - 4.0 * tr_m + tr_m2 - 4.0 * tr_minv + tr_minv2,
    ]
}

/// Scalar next-residual for the NS family: `h(x, α) = 1 − (1−x)·g_d(x;α)²`.
/// Used by tests and the scalar Fig. 2 bench to validate the coefficient
/// assembly against direct evaluation.
pub fn h_next_residual(d: usize, x: f64, alpha: f64) -> f64 {
    let g = match d {
        1 => 1.0 + alpha * x,
        2 => 1.0 + 0.5 * x + alpha * x * x,
        _ => panic!("d must be 1 or 2"),
    };
    1.0 - (1.0 - x) * g * g
}

/// Direct evaluation of `m(α) = Σ h(λ_i, α)²` from eigenvalues — the test
/// oracle for the trace-based assembly.
pub fn m_direct(d: usize, eigs: &[f64], alpha: f64) -> f64 {
    eigs.iter().map(|&x| h_next_residual(d, x, alpha).powi(2)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyfit::poly_eval;
    use crate::ptest::{gens, Prop};

    /// Exact traces T_i = Σ λ^i from eigenvalues.
    fn traces_from_eigs(eigs: &[f64], q: usize) -> Vec<f64> {
        (1..=q)
            .map(|i| eigs.iter().map(|&l| l.powi(i as i32)).sum())
            .collect()
    }

    #[test]
    fn d1_coeffs_match_direct() {
        Prop::new("ns d1 m(α) matches direct").cases(100).run(|rng| {
            let n = gens::usize_in(rng, 2, 12);
            let eigs: Vec<f64> = (0..n).map(|_| gens::f64_in(rng, 0.0, 1.0)).collect();
            let t = traces_from_eigs(&eigs, 6);
            let c = ns_d1_coeffs(&t);
            for &alpha in &[0.5, 0.7, 1.0] {
                let via_coeffs = poly_eval(&c, alpha);
                let direct = m_direct(1, &eigs, alpha) - m_direct(1, &eigs, 0.0)
                    + poly_eval(&c, 0.0);
                // both drop the constant term: compare differences
                let want = m_direct(1, &eigs, alpha) - m_direct(1, &eigs, 0.0);
                let got = via_coeffs - poly_eval(&c, 0.0);
                assert!(
                    (want - got).abs() < 1e-8 * (1.0 + want.abs()),
                    "α={alpha}: want {want} got {got} (direct={direct})"
                );
            }
        });
    }

    #[test]
    fn d2_coeffs_match_direct() {
        Prop::new("ns d2 m(α) matches direct").cases(100).run(|rng| {
            let n = gens::usize_in(rng, 2, 12);
            let eigs: Vec<f64> = (0..n).map(|_| gens::f64_in(rng, 0.0, 1.0)).collect();
            let t = traces_from_eigs(&eigs, 10);
            let c = ns_d2_coeffs(&t);
            for &alpha in &[0.375, 0.8, 1.45] {
                let want = m_direct(2, &eigs, alpha) - m_direct(2, &eigs, 0.0);
                let got = poly_eval(&c, alpha) - c[0];
                assert!(
                    (want - got).abs() < 1e-8 * (1.0 + want.abs()),
                    "α={alpha}: want {want} got {got}"
                );
            }
        });
    }

    #[test]
    fn inverse_newton_matches_direct_p123() {
        // Direct residual: r_next(λ, α) = λ + Σ C(p,i) α^i (λ^{i+1} − λ^i),
        // m(α) = Σ r_next².
        let direct = |p: usize, eigs: &[f64], a: f64| -> f64 {
            eigs.iter()
                .map(|&l| {
                    let mut r = l;
                    for i in 1..=p {
                        r += binom(p, i) * a.powi(i as i32) * (l.powi(i as i32 + 1) - l.powi(i as i32));
                    }
                    r * r
                })
                .sum()
        };
        Prop::new("inverse newton coeffs").cases(60).run(|rng| {
            for p in 1..=3 {
                let n = gens::usize_in(rng, 2, 8);
                let eigs: Vec<f64> = (0..n).map(|_| gens::f64_in(rng, 0.0, 1.0)).collect();
                let t = traces_from_eigs(&eigs, 2 * p + 2);
                let c = inverse_newton_coeffs(&t, p);
                for &alpha in &[0.3, 1.0, 1.7] {
                    let want = direct(p, &eigs, alpha) - direct(p, &eigs, 0.0);
                    let got = poly_eval(&c, alpha) - c[0];
                    assert!(
                        (want - got).abs() < 1e-8 * (1.0 + want.abs()),
                        "p={p} α={alpha}: want {want} got {got}"
                    );
                }
            }
        });
    }

    #[test]
    fn chebyshev_matches_direct() {
        // r_next(λ, α) = λ² − α(λ² − λ³); m(α) = Σ r_next².
        Prop::new("chebyshev coeffs").cases(60).run(|rng| {
            let n = gens::usize_in(rng, 2, 10);
            let eigs: Vec<f64> = (0..n).map(|_| gens::f64_in(rng, 0.0, 1.0)).collect();
            let t = traces_from_eigs(&eigs, 6);
            let c = chebyshev_coeffs(&t);
            for &alpha in &[0.5, 1.0, 2.0] {
                let direct: f64 = eigs
                    .iter()
                    .map(|&l| {
                        let r = l * l - alpha * (l * l - l * l * l);
                        r * r
                    })
                    .sum();
                let d0: f64 = eigs.iter().map(|&l| (l * l) * (l * l)).sum();
                let want = direct - d0;
                let got = poly_eval(&c, alpha) - c[0];
                assert!(
                    (want - got).abs() < 1e-9 * (1.0 + want.abs()),
                    "α={alpha}: want {want} got {got}"
                );
            }
        });
    }

    #[test]
    fn db_newton_matches_direct() {
        // m(α) = ‖I − M_{k+1}‖_F² with
        // M_{k+1} = 2α(1−α)I + (1−α)²M + α²M⁻¹, evaluated spectrally.
        use crate::linalg::eigen::symmetric_eigen;
        use crate::randmat;
        let mut rng = crate::rng::Rng::seed_from(11);
        let n = 10;
        let w: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.3, 2.0)).collect();
        let m = randmat::sym_with_spectrum(&mut rng, n, &w);
        let e = symmetric_eigen(&m);
        let m_inv = e.apply_fn(|x| 1.0 / x);
        let c = db_newton_coeffs(&m, &m_inv);
        for &alpha in &[0.2, 0.5, 0.9] {
            let direct: f64 = e
                .values
                .iter()
                .map(|&mu| {
                    let next = 2.0 * alpha * (1.0 - alpha)
                        + (1.0 - alpha) * (1.0 - alpha) * mu
                        + alpha * alpha / mu;
                    (1.0 - next).powi(2)
                })
                .sum();
            let d0: f64 = e.values.iter().map(|&mu| (1.0 - mu).powi(2)).sum();
            let want = direct - d0;
            let got = poly_eval(&c, alpha) - c[0];
            assert!(
                (want - got).abs() < 1e-7 * (1.0 + want.abs()),
                "α={alpha}: want {want} got {got}"
            );
        }
    }

    #[test]
    fn alpha_intervals() {
        assert_eq!(alpha_interval(1), (0.5, 1.0));
        assert_eq!(alpha_interval(2), (0.375, 1.45));
    }

    #[test]
    fn traces_needed_counts() {
        assert_eq!(traces_needed(1), 6);
        assert_eq!(traces_needed(2), 10);
    }

    #[test]
    fn h_taylor_alpha_recovers_classic() {
        // α = 1/2 in d=1 is the classical Newton–Schulz: h(x, 1/2) must
        // equal the classical residual map 1 − (1−x)(1+x/2)².
        for x in [0.1, 0.5, 0.9] {
            let classic = 1.0 - (1.0 - x) * (1.0 + 0.5 * x) * (1.0 + 0.5 * x);
            assert!((h_next_residual(1, x, 0.5) - classic).abs() < 1e-14);
        }
    }

    fn binom(n: usize, k: usize) -> f64 {
        super::binom(n, k)
    }

    #[test]
    fn binom_values() {
        assert_eq!(binom(2, 1), 2.0);
        assert_eq!(binom(4, 2), 6.0);
        assert_eq!(binom(3, 0), 1.0);
        assert_eq!(binom(2, 3), 0.0);
    }
}

#[cfg(test)]
mod general_d_tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn taylor_coeffs_of_inverse_sqrt() {
        assert_eq!(taylor_coeff(0), 1.0);
        assert_eq!(taylor_coeff(1), 0.5);
        assert_eq!(taylor_coeff(2), 3.0 / 8.0);
        assert_eq!(taylor_coeff(3), 5.0 / 16.0);
        assert_eq!(taylor_coeff(4), 35.0 / 128.0);
    }

    #[test]
    fn general_matches_d1_closed_form() {
        let mut rng = Rng::seed_from(1);
        for _ in 0..20 {
            let t: Vec<f64> = (0..6).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let a = ns_d1_coeffs(&t);
            let b = ns_general_coeffs(&t, 1);
            for i in 1..5 {
                assert!((a[i] - b[i]).abs() < 1e-12, "c{i}: {} vs {}", a[i], b[i]);
            }
        }
    }

    #[test]
    fn general_matches_d2_closed_form() {
        let mut rng = Rng::seed_from(2);
        for _ in 0..20 {
            let t: Vec<f64> = (0..10).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            let a = ns_d2_coeffs(&t);
            let b = ns_general_coeffs(&t, 2);
            for i in 1..5 {
                assert!((a[i] - b[i]).abs() < 1e-12, "c{i}: {} vs {}", a[i], b[i]);
            }
        }
    }

    #[test]
    fn general_interval_extends_published_pattern() {
        // d=1 reproduces the paper's [1/2, 1]; d≥3 follows the growth-cap rule.
        assert_eq!(alpha_interval(1), (0.5, 1.0));
        let (lo3, hi3) = alpha_interval(3);
        assert!((lo3 - 5.0 / 16.0).abs() < 1e-12);
        assert!((hi3 - (4.0 - 1.875)).abs() < 1e-12); // 4 − (1 + 1/2 + 3/8)
        let (lo4, hi4) = alpha_interval(4);
        assert!(lo4 < lo3 && hi4 > hi3); // coefficients shrink, caps grow
    }

    #[test]
    fn general_d3_coeffs_match_eigen_evaluation() {
        // Build a small symmetric R, compute exact traces, and check that
        // m(α) assembled from ns_general_coeffs equals the direct
        // per-eigenvalue objective Σ_i h(λ_i, α)² up to the constant term.
        let mut rng = Rng::seed_from(3);
        let n = 8;
        let w: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.05, 0.95)).collect();
        let r = crate::randmat::sym_with_spectrum(&mut rng, n, &w);
        let d = 3;
        let t = crate::sketch::exact_power_traces(&r, 4 * d + 2);
        let c = ns_general_coeffs(&t, d);
        let f: Vec<f64> = (0..d).map(taylor_coeff).collect();
        let direct = |a: f64| -> f64 {
            w.iter()
                .map(|&lam| {
                    let g: f64 = f
                        .iter()
                        .enumerate()
                        .map(|(k, &fk)| fk * lam.powi(k as i32))
                        .sum::<f64>()
                        + a * lam.powi(d as i32);
                    let h = 1.0 - (1.0 - lam) * g * g;
                    h * h
                })
                .sum()
        };
        let m0 = direct(0.0);
        for a in [0.3, 0.5, 1.0, 1.8] {
            let want = direct(a) - m0;
            let got = c[1] * a + c[2] * a * a + c[3] * a.powi(3) + c[4] * a.powi(4);
            assert!(
                (got - want).abs() < 1e-8 * want.abs().max(1.0),
                "α={a}: {got} vs {want}"
            );
        }
    }
}
