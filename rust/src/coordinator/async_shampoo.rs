//! Staleness-tolerant Shampoo: preconditioner refreshes run *asynchronously*
//! through the preconditioner [`Service`](super::service::Service) while
//! training keeps stepping on slightly-stale inverse roots — the pattern of
//! Distributed Shampoo (Shi et al. 2023) and DION (Ahn et al. 2025), with
//! PRISM (or any backend) doing the matrix functions on the worker pool.
//!
//! Protocol per layer with matrix-shaped parameters:
//!  * every step: accumulate `L += G Gᵀ`, `R += Gᵀ G` and apply the update
//!    `L̂^{-1/2} G R̂^{-1/2}` with whatever `L̂,R̂` roots are installed;
//!  * every `refresh_interval` steps: snapshot the normalised accumulators
//!    and *submit* two `InvSqrt` jobs — no waiting;
//!  * every step: poll `try_recv` and install any finished roots, tagging
//!    them with the submission step so staleness is observable.
//!
//! Refresh jobs ride the service's shape-bucketed scheduler: a tick's
//! same-shape Gram matrices (e.g. every 24×24 `L`/`R` across a stack of
//! equal-width layers) fill shared lockstep batches — one sketch fill per
//! iteration per *batch* instead of per job — with the service's `linger`
//! timer (or the end-of-step flush) bounding how long a partial bucket
//! waits. This is why the optimizer no longer forces `max_batch: 1`.
//!
//! The first update per layer blocks until its roots arrive (identity
//! preconditioning would distort the first steps — the wait is preceded by
//! a flush so it never sleeps on the linger timer); afterwards the train
//! loop never waits on the service.

use super::service::{JobKind, JobResult, Service};
use crate::linalg::gemm::{matmul, syrk_a_at, syrk_at_a};
use crate::linalg::Mat;
use crate::nn::{Param, ParamKind};
use crate::optim::Optimizer;
use std::collections::HashMap;

#[derive(Clone, Copy, PartialEq, Eq, Hash)]
enum Side {
    Left,
    Right,
}

struct LayerState {
    l: Mat,
    r: Mat,
    l_inv: Mat,
    r_inv: Mat,
    /// Scale factors applied after the normalised inverse roots come back.
    l_scale: f64,
    r_scale: f64,
    /// Step at which the currently installed roots were *submitted*.
    installed_at: (usize, usize),
    ready: bool,
}

/// Shampoo with service-backed asynchronous preconditioner refreshes.
pub struct AsyncShampoo<'s> {
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    pub damping: f64,
    pub refresh_interval: usize,
    pub grafting: bool,
    service: &'s Service,
    /// job id → (param index, side, submit step, trace scale)
    pending: HashMap<u64, (usize, Side, usize, f64)>,
    states: Vec<Option<LayerState>>,
    bufs: Vec<Mat>,
    t: usize,
    /// Histogram source: staleness (steps) of the roots used at each step.
    pub staleness_log: Vec<usize>,
}

impl<'s> AsyncShampoo<'s> {
    pub fn new(lr: f64, damping: f64, refresh_interval: usize, service: &'s Service) -> Self {
        AsyncShampoo {
            lr,
            momentum: 0.9,
            weight_decay: 0.0,
            damping,
            refresh_interval: refresh_interval.max(1),
            grafting: true,
            service,
            pending: HashMap::new(),
            states: Vec::new(),
            bufs: Vec::new(),
            t: 0,
            staleness_log: Vec::new(),
        }
    }

    /// Install a finished inverse root.
    fn install(&mut self, res: JobResult, meta: (usize, Side, usize, f64)) {
        let (idx, side, step, scale) = meta;
        if let Some(st) = self.states[idx].as_mut() {
            match side {
                Side::Left => {
                    st.l_inv = res.result.scaled(1.0 / scale.sqrt());
                    st.installed_at.0 = step;
                }
                Side::Right => {
                    st.r_inv = res.result.scaled(1.0 / scale.sqrt());
                    st.installed_at.1 = step;
                }
            }
            st.ready = true;
        }
    }

    /// Drain every finished refresh without blocking.
    fn poll(&mut self) {
        while let Some(res) = self.service.try_recv() {
            if let Some(meta) = self.pending.remove(&res.id) {
                self.install(res, meta);
            }
        }
    }

    /// Block until at least one pending job finishes (used only before a
    /// layer's very first preconditioned step).
    fn wait_one(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        if let Ok(res) = self.service.recv() {
            if let Some(meta) = self.pending.remove(&res.id) {
                self.install(res, meta);
            }
        }
    }

    /// Block until every in-flight refresh has been installed. Call this to
    /// bound staleness explicitly (e.g. at evaluation points); the train
    /// loop itself never needs it.
    pub fn sync(&mut self) {
        let _ = self.service.flush();
        while !self.pending.is_empty() {
            self.wait_one();
        }
    }

    /// Whether a refresh for `idx` is already in flight (either side) —
    /// used to avoid queue build-up when steps outpace the service.
    fn refresh_in_flight(&self, idx: usize) -> bool {
        self.pending.values().any(|&(i, _, _, _)| i == idx)
    }

    /// Submit L/R refresh jobs for layer `idx` from the current accumulators.
    fn submit_refresh(&mut self, idx: usize) {
        let (lt, rt, ln, rn) = {
            let st = self.states[idx].as_ref().unwrap();
            let (m, n) = (st.l.rows(), st.r.rows());
            let lt = st.l.trace().max(1e-30) / m as f64;
            let rt = st.r.trace().max(1e-30) / n as f64;
            (lt, rt, st.l.scaled(1.0 / lt), st.r.scaled(1.0 / rt))
        };
        let eps = self.damping;
        if let Ok(id) = self.service.submit(idx, JobKind::InvSqrt { eps }, ln) {
            self.pending.insert(id, (idx, Side::Left, self.t, lt));
        }
        if let Ok(id) = self.service.submit(idx, JobKind::InvSqrt { eps }, rn) {
            self.pending.insert(id, (idx, Side::Right, self.t, rt));
        }
        // No flush here: the jobs sit in their shape bucket so that refreshes
        // from *other* layers this tick can join the same lockstep batch. The
        // end-of-step flush (and the service's linger timer) bound the wait.
    }

    /// Average staleness (in steps) of installed roots, for reporting.
    pub fn mean_staleness(&self) -> f64 {
        if self.staleness_log.is_empty() {
            return 0.0;
        }
        self.staleness_log.iter().sum::<usize>() as f64 / self.staleness_log.len() as f64
    }

    pub fn pending_jobs(&self) -> usize {
        self.pending.len()
    }
}

impl Optimizer for AsyncShampoo<'_> {
    fn step(&mut self, params: &mut [&mut Param]) {
        if self.states.is_empty() {
            self.states = params.iter().map(|_| None).collect();
            self.bufs =
                params.iter().map(|p| Mat::zeros(p.w.rows(), p.w.cols())).collect();
        }
        let refresh = self.t % self.refresh_interval == 0;
        self.poll();
        for (i, p) in params.iter_mut().enumerate() {
            let buf = &mut self.bufs[i];
            buf.scale(self.momentum);
            buf.axpy(1.0, &p.g);
            let g = buf.clone();
            let update = match p.kind {
                ParamKind::Matrix if p.w.rows() > 1 && p.w.cols() > 1 => {
                    let (m, n) = g.shape();
                    if self.states[i].is_none() {
                        self.states[i] = Some(LayerState {
                            l: Mat::zeros(m, m),
                            r: Mat::zeros(n, n),
                            l_inv: Mat::eye(m),
                            r_inv: Mat::eye(n),
                            l_scale: 1.0,
                            r_scale: 1.0,
                            installed_at: (0, 0),
                            ready: false,
                        });
                    }
                    {
                        let st = self.states[i].as_mut().unwrap();
                        st.l.axpy(1.0, &syrk_a_at(&g));
                        st.r.axpy(1.0, &syrk_at_a(&g));
                        let _ = (st.l_scale, st.r_scale);
                    }
                    // Refresh on schedule, but never queue a second refresh
                    // behind one still in flight: if the service is slower
                    // than the train loop, work on the freshest snapshot
                    // rather than a backlog of stale ones.
                    if (refresh || !self.states[i].as_ref().unwrap().ready)
                        && !self.refresh_in_flight(i)
                    {
                        self.submit_refresh(i);
                    }
                    // First use must have real roots; afterwards stay async.
                    // Flush before blocking: the refresh may still be parked
                    // in a partial bucket, and `wait_one` blocks on `recv`,
                    // which would never see it until the linger timer fired
                    // (or ever, if no linger is configured).
                    if !self.states[i].as_ref().unwrap().ready {
                        let _ = self.service.flush();
                        while !self.states[i].as_ref().unwrap().ready {
                            self.wait_one();
                        }
                    }
                    let st = self.states[i].as_ref().unwrap();
                    let stale =
                        self.t.saturating_sub(st.installed_at.0.min(st.installed_at.1));
                    self.staleness_log.push(stale);
                    let mut u = matmul(&matmul(&st.l_inv, &g), &st.r_inv);
                    if self.grafting {
                        let un = u.fro_norm().max(1e-30);
                        u.scale(g.fro_norm() / un);
                    }
                    u
                }
                _ => g,
            };
            if self.weight_decay > 0.0 {
                let w = p.w.clone();
                p.w.axpy(-self.lr * self.weight_decay, &w);
            }
            p.w.axpy(-self.lr, &update);
        }
        // Cut whatever partial buckets this tick's refreshes left behind:
        // within the step same-shape jobs had every chance to coalesce, and
        // past it they would only age (until the linger timer, or forever
        // without one). Cheap no-op on steps that submitted nothing.
        let _ = self.service.flush();
        self.t += 1;
    }

    fn name(&self) -> String {
        format!("async-shampoo(lr={},interval={})", self.lr, self.refresh_interval)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, ServiceConfig};
    use crate::nn::mlp::Mlp;
    use crate::rng::Rng;
    use crate::workload::BlobsDataset;

    fn service(workers: usize) -> Service {
        let cfg = ServiceConfig {
            workers,
            queue_cap: 64,
            admission: crate::config::Admission::Block,
            // Same-shape refreshes from one tick share lockstep batches; the
            // linger deadline keeps odd-shape singletons from waiting on a
            // batch that will never fill.
            max_batch: 4,
            sketch_p: 8,
            max_iters: 40,
            tol: Some(1e-7),
            precision: crate::matfn::Precision::F64,
            solver_cache_cap: 32,
            gemm_threads: 1,
            stream_residuals: false,
            gemm_block: None,
            gemm_kernel: None,
            faults: None,
            linger: Some(std::time::Duration::from_millis(2)),
            cache_snapshot: None,
        };
        Service::start(cfg, Backend::Prism5, 9).expect("valid service config")
    }

    fn train_loss_curve_with(
        opt: &mut dyn Optimizer,
        steps: usize,
        mut after_step: impl FnMut(&mut dyn Optimizer),
    ) -> Vec<f64> {
        let mut rng = Rng::seed_from(3);
        let data = BlobsDataset::generate(&mut rng, 400, 32, 4, 2.0);
        let mut model = Mlp::new(&mut rng, &[32, 24, 4]);
        let (train_idx, _) = data.split(0.1);
        let mut losses = Vec::new();
        for step in 0..steps {
            let idx: Vec<usize> =
                train_idx.iter().cycle().skip(step * 32).take(32).copied().collect();
            let (x, y) = data.batch(&idx);
            let (loss, _) = model.forward_backward(&x, &y);
            {
                let mut params = model.params_mut();
                opt.step(&mut params);
            }
            model.zero_grads();
            losses.push(loss);
            after_step(opt);
        }
        losses
    }

    fn train_loss_curve(opt: &mut dyn Optimizer, steps: usize) -> Vec<f64> {
        train_loss_curve_with(opt, steps, |_| {})
    }

    #[test]
    fn async_shampoo_reduces_loss() {
        let svc = service(2);
        let mut opt = AsyncShampoo::new(0.05, 1e-6, 4, &svc);
        let losses = train_loss_curve(&mut opt, 30);
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "{} -> {}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn staleness_bounded_when_service_keeps_up() {
        // `sync` after each step models a training step that is slower than
        // a refresh (the realistic regime — train steps run GEMMs on the
        // whole model, a refresh handles one layer pair). Staleness is then
        // bounded by the refresh interval.
        let svc = service(2);
        let interval = 5;
        let mut opt = AsyncShampoo::new(0.05, 1e-6, interval, &svc);
        {
            let o: &mut AsyncShampoo = &mut opt;
            let mut rng = Rng::seed_from(3);
            let data = BlobsDataset::generate(&mut rng, 400, 32, 4, 2.0);
            let mut model = Mlp::new(&mut rng, &[32, 24, 4]);
            let (train_idx, _) = data.split(0.1);
            for step in 0..25 {
                let idx: Vec<usize> =
                    train_idx.iter().cycle().skip(step * 32).take(32).copied().collect();
                let (x, y) = data.batch(&idx);
                let _ = model.forward_backward(&x, &y);
                {
                    let mut params = model.params_mut();
                    o.step(&mut params);
                }
                model.zero_grads();
                o.sync();
            }
        }
        assert!(!opt.staleness_log.is_empty());
        let max_stale = *opt.staleness_log.iter().max().unwrap();
        assert!(max_stale <= interval + 1, "max staleness {max_stale}");
    }

    #[test]
    fn fast_loop_does_not_build_backlog() {
        // When the train loop outpaces the service we must NOT queue
        // refreshes behind each other: at most one refresh (two jobs) in
        // flight per layer at any time.
        let svc = service(1);
        let mut opt = AsyncShampoo::new(0.05, 1e-6, 1, &svc); // refresh every step
        let _ = train_loss_curve(&mut opt, 20);
        // MLP [32,24,4] has 2 matrix layers ⇒ ≤ 4 jobs in flight.
        assert!(opt.pending_jobs() <= 4, "pending {}", opt.pending_jobs());
        opt.sync();
        assert_eq!(opt.pending_jobs(), 0);
    }

    #[test]
    fn first_step_waits_for_real_roots() {
        let svc = service(1);
        let mut opt = AsyncShampoo::new(0.05, 1e-6, 50, &svc);
        let losses = train_loss_curve(&mut opt, 3);
        // If identity roots had been used the staleness log would be empty;
        // instead every matrix step records an installed-root use.
        assert!(opt.staleness_log.len() >= 3);
        assert!(losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn matches_sync_shampoo_loss_within_tolerance() {
        // Async with interval k, synced each step (service keeps up), should
        // track sync Shampoo with the same k.
        let svc = service(2);
        let mut async_opt = AsyncShampoo::new(0.05, 1e-6, 4, &svc);
        let async_losses = {
            let mut rng = Rng::seed_from(3);
            let data = BlobsDataset::generate(&mut rng, 400, 32, 4, 2.0);
            let mut model = Mlp::new(&mut rng, &[32, 24, 4]);
            let (train_idx, _) = data.split(0.1);
            let mut losses = Vec::new();
            for step in 0..30 {
                let idx: Vec<usize> =
                    train_idx.iter().cycle().skip(step * 32).take(32).copied().collect();
                let (x, y) = data.batch(&idx);
                let (loss, _) = model.forward_backward(&x, &y);
                {
                    let mut params = model.params_mut();
                    async_opt.step(&mut params);
                }
                model.zero_grads();
                async_opt.sync();
                losses.push(loss);
            }
            losses
        };
        let mut sync_opt = crate::optim::shampoo::Shampoo::new(
            0.05,
            1e-6,
            4,
            crate::optim::matfn::InvRootBackend::new(Backend::Prism5, 40),
            9,
        );
        let sync_losses = train_loss_curve(&mut sync_opt, 30);
        let (a, s) = (async_losses.last().unwrap(), sync_losses.last().unwrap());
        // Both drive this separable task to (near-)zero loss; staleness < k
        // steps must not change the qualitative optimisation behaviour.
        assert!(*a < 1e-4, "async failed to converge: {a}");
        assert!(*s < 1e-4, "sync failed to converge: {s}");
    }

    #[test]
    fn bucketed_refreshes_amortize_sketch_fills_across_layers() {
        // A [32,24,24,24,4] MLP refreshes six same-shape 24×24 Gram matrices
        // (plus one 32×32 and one 4×4) per tick. Bucketed with `max_batch: 4`
        // the 24×24 jobs ride shared lockstep batches — one sketch fill per
        // iteration per *batch* — while `max_batch: 1` pays fills per job.
        //
        // `sketch::fills_total` is process-global, so tests running in
        // parallel add noise to both measurements; each configuration is
        // therefore measured twice and the minimum delta taken, and the
        // expected contrast (~half the fills, hundreds over ten ticks)
        // dwarfs what a quiet window leaks. Occupancy comes from the
        // service's own registry and is exact.
        let run = |max_batch: usize| -> (u64, f64) {
            let cfg = ServiceConfig {
                workers: 1,
                queue_cap: 64,
                admission: crate::config::Admission::Block,
                max_batch,
                sketch_p: 8,
                max_iters: 40,
                tol: Some(1e-7),
                precision: crate::matfn::Precision::F64,
                solver_cache_cap: 32,
                gemm_threads: 1,
                stream_residuals: false,
                gemm_block: None,
                gemm_kernel: None,
                faults: None,
                // Long linger: `sync` flushes explicitly every step, and a
                // mid-step timer cut would make batch composition (and the
                // occupancy assertion below) timing-dependent.
                linger: Some(std::time::Duration::from_millis(200)),
                cache_snapshot: None,
            };
            let svc = Service::start(cfg, Backend::Prism5, 9).expect("valid service config");
            let mut opt = AsyncShampoo::new(0.05, 1e-6, 1, &svc);
            let before = crate::sketch::fills_total();
            let mut rng = Rng::seed_from(3);
            let data = BlobsDataset::generate(&mut rng, 400, 32, 4, 2.0);
            let mut model = Mlp::new(&mut rng, &[32, 24, 24, 24, 4]);
            let (train_idx, _) = data.split(0.1);
            for step in 0..10 {
                let idx: Vec<usize> =
                    train_idx.iter().cycle().skip(step * 32).take(32).copied().collect();
                let (x, y) = data.batch(&idx);
                let _ = model.forward_backward(&x, &y);
                {
                    let mut params = model.params_mut();
                    opt.step(&mut params);
                }
                model.zero_grads();
                opt.sync();
            }
            let fills = crate::sketch::fills_total() - before;
            let occupancy = svc.metrics.histogram("service.batch_size").mean();
            (fills, occupancy)
        };
        let (single_a, single_occ) = run(1);
        let (batched_a, batched_occ) = run(4);
        let (single_b, _) = run(1);
        let (batched_b, _) = run(4);
        assert!(
            (single_occ - 1.0).abs() < 1e-9,
            "max_batch 1 must mean singleton batches, got occupancy {single_occ}"
        );
        assert!(batched_occ > 1.5, "bucketed occupancy {batched_occ} should exceed 1.5");
        let (single, batched) = (single_a.min(single_b), batched_a.min(batched_b));
        assert!(
            batched < single,
            "bucketed refreshes must amortize sketch fills: {batched} (bucketed) \
             vs {single} (singleton)"
        );
    }
}
