//! The L3 coordinator.
//!
//! * [`service`] — the **preconditioner service**: a routed, batched,
//!   multi-worker queue of matrix-function jobs (Shampoo inverse roots, Muon
//!   orthogonalizations) with staleness-aware scheduling, backpressure and
//!   metrics. This is how a distributed-Shampoo-style trainer offloads its
//!   matrix functions (cf. Shi et al. 2023; DION).
//! * [`train`] — the **training driver**: owns flattened model parameters,
//!   executes the AOT-compiled JAX `train_step` artifact via PJRT for
//!   loss+gradients, and applies the Rust optimizers (Muon/AdamW) — Python
//!   never runs on this path.

//! * [`async_shampoo`] — **staleness-tolerant Shampoo**: preconditioner
//!   refreshes submitted to the service asynchronously; the train loop never
//!   blocks on a matrix function after warmup.
//! * [`schedule`] — **shape-bucketed batch scheduling**: per-(task, shape,
//!   precision) pending buckets with `max_batch` cuts and a linger deadline,
//!   so mixed-shape tenants still fill lockstep batches.
//! * [`gate`] — **admission-control primitives** (the inflight ledger and
//!   the blocking-submitter condvar gate), extracted so the loom suite
//!   (`rust/tests/loom_coordinator.rs`) model-checks the production state
//!   machines rather than test doubles.

pub mod async_shampoo;
pub mod gate;
pub mod schedule;
pub mod service;
pub mod supervise;
pub mod train;

pub use async_shampoo::AsyncShampoo;
pub use service::{Job, JobKind, JobResult, Service};
pub use train::TrainDriver;
