//! The preconditioner service: route → batch → execute matrix-function jobs
//! on a worker pool, with bounded queues (backpressure) and full metrics.
//!
//! Training integrations submit gradient/covariance matrices tagged by layer
//! and function kind; the router groups same-shape, same-kind jobs into
//! batches (shared sketch draws amortise PRISM's fitting overhead within a
//! batch), workers run the jobs through the unified [`crate::matfn`] solver
//! API, and results flow back over a completion channel. Each worker keeps
//! one persistent [`Solver`] per (kind, shape) route, so a steady stream of
//! same-shaped preconditioner jobs runs allocation-free — the Shampoo/Muon
//! hot path. With `stream_residuals` set, workers attach a per-iteration
//! observer and stream [`ResidualEvent`]s over a progress channel while jobs
//! are still running, instead of making clients wait for the final
//! `IterationLog`. Staleness scheduling lets Shampoo keep training on
//! slightly-old preconditioners while refreshes are in flight — the pattern
//! of Distributed Shampoo/DION.

use crate::config::{Backend, ServiceConfig};
use crate::linalg::Mat;
use crate::matfn::{MatFnTask, Solver};
use crate::metrics::Registry;
use crate::rng::Rng;
use crate::util::{Error, Result, Stopwatch};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// What function to apply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobKind {
    /// `(A + εI)^{-1/2}` for symmetric PSD input.
    InvSqrt { eps: f64 },
    /// Polar factor (orthogonalization).
    Polar,
}

impl JobKind {
    fn route_key(&self, shape: (usize, usize)) -> (u8, usize, usize) {
        let tag = match self {
            JobKind::InvSqrt { .. } => 0,
            JobKind::Polar => 1,
        };
        (tag, shape.0, shape.1)
    }
}

/// A matrix-function request.
pub struct Job {
    pub id: u64,
    pub layer: usize,
    pub kind: JobKind,
    pub matrix: Mat,
    pub submitted: Instant,
}

/// A completed job.
pub struct JobResult {
    pub id: u64,
    pub layer: usize,
    pub result: Mat,
    /// Queue wait + service time, seconds.
    pub latency_s: f64,
    pub batch_size: usize,
    /// Iterations the solver ran.
    pub iters: usize,
    /// Final residual Frobenius norm.
    pub final_residual: f64,
}

/// One per-iteration progress report, streamed while a job is running
/// (only when [`ServiceConfig::stream_residuals`] is set).
#[derive(Debug, Clone, Copy)]
pub struct ResidualEvent {
    pub id: u64,
    pub layer: usize,
    pub iter: usize,
    pub residual: f64,
}

enum WorkerMsg {
    Batch(Vec<Job>),
    Shutdown,
}

/// Service handle. Dropping it shuts the workers down.
pub struct Service {
    tx: SyncSender<WorkerMsg>,
    results_rx: Mutex<Receiver<JobResult>>,
    progress_rx: Mutex<Receiver<ResidualEvent>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<Mutex<BTreeMap<(u8, usize, usize), Vec<Job>>>>,
    cfg: ServiceConfig,
    next_id: Mutex<u64>,
    pub metrics: Arc<Registry>,
    /// Jobs handed to workers / results taken off the completion channel.
    /// Both counters are only touched by service-handle callers (never by
    /// workers), so `dispatched − received` is an exact count of results
    /// still owed and the drain loop can block on it race-free: every
    /// dispatched job sends exactly one result.
    dispatched: AtomicU64,
    received: AtomicU64,
}

impl Service {
    /// Start the service with `cfg.workers` threads using `backend` for the
    /// matrix functions. When `cfg.gemm_threads > 1` this also installs the
    /// process-global GEMM pool the engines run their panels on (results are
    /// bit-identical at any pool size, so this only changes speed). The
    /// default value 1 means "unspecified" and deliberately does NOT tear
    /// down a pool installed earlier (e.g. by the CLI's `--threads`).
    /// `cfg.gemm_block`, when set, likewise installs the process-global
    /// GEMM cache-block sizes (a startup-time tuning knob — see
    /// [`crate::linalg::gemm::set_global_blocking`]), and `cfg.gemm_kernel`
    /// the process-global microkernel (skipped with a warning when the
    /// host lacks the ISA, so a shared config stays portable).
    pub fn start(cfg: ServiceConfig, backend: Backend, seed: u64) -> Service {
        if cfg.gemm_threads > 1 {
            crate::linalg::gemm::set_global_threads(cfg.gemm_threads);
        }
        if let Some(blk) = cfg.gemm_block {
            crate::linalg::gemm::set_global_blocking(blk);
        }
        if let Some(kern) = cfg.gemm_kernel {
            if kern.is_available() {
                crate::linalg::gemm::set_global_kernel(Some(kern));
            } else {
                eprintln!(
                    "service: gemm kernel '{}' not available on this host; keeping auto-detection",
                    kern.name()
                );
            }
        }
        let (tx, rx) = sync_channel::<WorkerMsg>(cfg.queue_capacity);
        let rx = Arc::new(Mutex::new(rx));
        let (res_tx, res_rx): (Sender<JobResult>, Receiver<JobResult>) =
            std::sync::mpsc::channel();
        let (prog_tx, prog_rx): (Sender<ResidualEvent>, Receiver<ResidualEvent>) = channel();
        let metrics = Arc::new(Registry::default());
        let mut workers = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let res_tx = res_tx.clone();
            let prog_tx = prog_tx.clone();
            let metrics = Arc::clone(&metrics);
            let iters = cfg.max_iters;
            let stream = cfg.stream_residuals;
            workers.push(std::thread::spawn(move || {
                let mut rng = Rng::seed_from(seed ^ (w as u64 + 1));
                // One persistent solver per (kind, shape) route: same-shape
                // jobs reuse the solver's workspace, so the steady-state
                // preconditioner stream runs allocation-free.
                let mut solvers: BTreeMap<(u8, usize, usize), Solver> = BTreeMap::new();
                let mut damped = Mat::zeros(0, 0);
                let service_time = metrics.histogram("service.exec_s");
                let done = metrics.counter("service.jobs_done");
                loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok(WorkerMsg::Batch(jobs)) => {
                            let bsize = jobs.len();
                            for job in jobs {
                                let key = job.kind.route_key(job.matrix.shape());
                                let solver = solvers.entry(key).or_insert_with(|| {
                                    let task = match job.kind {
                                        JobKind::InvSqrt { .. } => MatFnTask::InvSqrt,
                                        JobKind::Polar => MatFnTask::Polar,
                                    };
                                    Solver::for_backend(backend, task, iters)
                                        .expect("service backends always have polar/invsqrt forms")
                                });
                                if stream {
                                    let ptx = prog_tx.clone();
                                    let (id, layer) = (job.id, job.layer);
                                    solver.set_observer(Some(Box::new(move |ev| {
                                        let _ = ptx.send(ResidualEvent {
                                            id,
                                            layer,
                                            iter: ev.iter,
                                            residual: ev.residual,
                                        });
                                    })));
                                }
                                let sw = Stopwatch::start();
                                let out = match job.kind {
                                    JobKind::InvSqrt { eps } => {
                                        damped.copy_from(&job.matrix);
                                        damped.add_diag(eps);
                                        solver.solve(&damped, &mut rng)
                                    }
                                    JobKind::Polar => solver.solve(&job.matrix, &mut rng),
                                };
                                if stream {
                                    solver.set_observer(None);
                                }
                                service_time.observe(sw.elapsed_s());
                                done.inc();
                                let latency_s = job.submitted.elapsed().as_secs_f64();
                                let _ = res_tx.send(JobResult {
                                    id: job.id,
                                    layer: job.layer,
                                    result: out.primary,
                                    latency_s,
                                    batch_size: bsize,
                                    iters: out.log.iters(),
                                    final_residual: out.log.final_residual(),
                                });
                            }
                        }
                        Ok(WorkerMsg::Shutdown) | Err(_) => break,
                    }
                }
            }));
        }
        Service {
            tx,
            results_rx: Mutex::new(res_rx),
            progress_rx: Mutex::new(prog_rx),
            workers,
            pending: Arc::new(Mutex::new(BTreeMap::new())),
            cfg,
            next_id: Mutex::new(0),
            metrics,
            dispatched: AtomicU64::new(0),
            received: AtomicU64::new(0),
        }
    }

    /// Submit a job; same-shape jobs are held back briefly to form batches
    /// of up to `max_batch` (call [`flush`] to force dispatch).
    pub fn submit(&self, layer: usize, kind: JobKind, matrix: Mat) -> Result<u64> {
        let id = {
            let mut n = self.next_id.lock().unwrap();
            *n += 1;
            *n
        };
        self.metrics.counter("service.jobs_submitted").inc();
        let key = kind.route_key(matrix.shape());
        let job = Job { id, layer, kind, matrix, submitted: Instant::now() };
        let ready = {
            let mut pend = self.pending.lock().unwrap();
            let q = pend.entry(key).or_default();
            q.push(job);
            if q.len() >= self.cfg.max_batch {
                Some(std::mem::take(q))
            } else {
                None
            }
        };
        if let Some(batch) = ready {
            self.dispatch(batch)?;
        }
        Ok(id)
    }

    fn dispatch(&self, batch: Vec<Job>) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        self.dispatched.fetch_add(batch.len() as u64, Ordering::SeqCst);
        self.metrics
            .histogram("service.batch_size")
            .observe(batch.len() as f64);
        self.tx
            .send(WorkerMsg::Batch(batch))
            .map_err(|_| Error::Runtime("service: workers gone".into()))
    }

    /// Dispatch all partially-filled batches.
    pub fn flush(&self) -> Result<()> {
        let batches: Vec<Vec<Job>> = {
            let mut pend = self.pending.lock().unwrap();
            pend.values_mut().map(std::mem::take).collect()
        };
        for b in batches {
            self.dispatch(b)?;
        }
        Ok(())
    }

    /// Number of results still owed (dispatched − received). Results of
    /// partially-filled batches still held back by the router are *not*
    /// counted — call [`Self::flush`] first.
    pub fn inflight(&self) -> usize {
        let d = self.dispatched.load(Ordering::SeqCst);
        let r = self.received.load(Ordering::SeqCst);
        (d - r) as usize
    }

    /// Blocking receive of the next completed job.
    pub fn recv(&self) -> Result<JobResult> {
        let rx = self.results_rx.lock().unwrap();
        let r = rx
            .recv()
            .map_err(|_| Error::Runtime("service: result channel closed".into()))?;
        self.received.fetch_add(1, Ordering::SeqCst);
        self.metrics.histogram("service.latency_s").observe(r.latency_s);
        Ok(r)
    }

    /// Non-blocking receive of the next streamed per-iteration residual.
    /// Only produces events when [`ServiceConfig::stream_residuals`] is set;
    /// clients poll this to watch convergence while jobs are in flight
    /// instead of waiting for the final `IterationLog`.
    pub fn try_recv_progress(&self) -> Option<ResidualEvent> {
        self.progress_rx.lock().unwrap().try_recv().ok()
    }

    /// Non-blocking receive: returns `None` when no result is ready yet.
    /// Used by staleness-tolerant callers (e.g. [`super::async_shampoo`])
    /// that keep working with old results while refreshes are in flight.
    pub fn try_recv(&self) -> Option<JobResult> {
        let rx = self.results_rx.lock().unwrap();
        match rx.try_recv() {
            Ok(r) => {
                self.received.fetch_add(1, Ordering::SeqCst);
                self.metrics.histogram("service.latency_s").observe(r.latency_s);
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Flush, then collect every outstanding result. Blocks until all
    /// dispatched jobs have reported back; race-free because `dispatched`
    /// is fixed once `flush` returns and each job sends exactly one result.
    pub fn drain(&self) -> Result<Vec<JobResult>> {
        self.flush()?;
        let mut out = Vec::new();
        while self.inflight() > 0 {
            out.push(self.recv()?);
        }
        Ok(out)
    }

    pub fn report(&self) -> String {
        self.metrics.report()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(WorkerMsg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b};
    use crate::randmat;

    fn cfg(workers: usize, max_batch: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            queue_capacity: 64,
            max_batch,
            sketch_p: 8,
            max_iters: 40,
            tol: 1e-7,
            gemm_threads: 1,
            stream_residuals: false,
            gemm_block: None,
            gemm_kernel: None,
        }
    }

    #[test]
    fn invsqrt_jobs_round_trip() {
        let mut rng = Rng::seed_from(1);
        let svc = Service::start(cfg(2, 2), Backend::Prism5, 42);
        let mut inputs = Vec::new();
        for layer in 0..4 {
            let w = randmat::logspace(1e-2, 1.0, 8);
            let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
            inputs.push(a.clone());
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 4);
        for r in &results {
            let a = &inputs[r.layer];
            let prod = matmul(&matmul(&r.result, a), &r.result);
            assert!(
                prod.sub(&Mat::eye(8)).max_abs() < 1e-3,
                "layer {}: err {}",
                r.layer,
                prod.sub(&Mat::eye(8)).max_abs()
            );
            assert!(r.latency_s >= 0.0);
        }
    }

    #[test]
    fn polar_jobs_round_trip() {
        let mut rng = Rng::seed_from(2);
        let svc = Service::start(cfg(1, 4), Backend::Prism3, 7);
        let a = randmat::gaussian(&mut rng, 16, 8);
        svc.submit(0, JobKind::Polar, a).unwrap();
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 1);
        let q = &results[0].result;
        assert!(matmul_at_b(q, q).sub(&Mat::eye(8)).max_abs() < 1e-3);
    }

    #[test]
    fn batching_groups_same_shape() {
        let mut rng = Rng::seed_from(3);
        let svc = Service::start(cfg(1, 3), Backend::Eigen, 1);
        // 3 same-shape jobs = exactly one full batch.
        for layer in 0..3 {
            let w = randmat::logspace(0.1, 1.0, 6);
            let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| r.batch_size == 3), "batch sizes: {:?}",
            results.iter().map(|r| r.batch_size).collect::<Vec<_>>());
    }

    #[test]
    fn mixed_shapes_split_batches() {
        let mut rng = Rng::seed_from(4);
        let svc = Service::start(cfg(2, 8), Backend::Eigen, 2);
        for layer in 0..4 {
            let n = if layer % 2 == 0 { 5 } else { 7 };
            let w = randmat::logspace(0.1, 1.0, n);
            let a = randmat::sym_with_spectrum(&mut rng, n, &w);
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 4);
        // Shapes must be preserved per layer.
        for r in &results {
            let n = if r.layer % 2 == 0 { 5 } else { 7 };
            assert_eq!(r.result.shape(), (n, n));
        }
    }

    #[test]
    fn streams_residual_trajectory_when_enabled() {
        let mut rng = Rng::seed_from(6);
        let mut c = cfg(1, 1);
        c.stream_residuals = true;
        let svc = Service::start(c, Backend::Prism5, 9);
        let w = randmat::logspace(1e-2, 1.0, 8);
        let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 1);
        // Once the job is done, its full trajectory has been streamed.
        let mut events = Vec::new();
        while let Some(ev) = svc.try_recv_progress() {
            events.push(ev);
        }
        assert_eq!(events.len(), results[0].iters, "one event per iteration");
        assert!(events.iter().all(|e| e.layer == 0));
        for (k, ev) in events.iter().enumerate() {
            assert_eq!(ev.iter, k);
        }
        let last = events.last().expect("at least one iteration");
        assert!(
            (last.residual - results[0].final_residual).abs() <= 1e-12,
            "stream tail must match the final residual"
        );
    }

    #[test]
    fn no_progress_events_by_default() {
        let mut rng = Rng::seed_from(7);
        let svc = Service::start(cfg(1, 1), Backend::Prism5, 11);
        let w = randmat::logspace(0.1, 1.0, 6);
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        let _ = svc.drain().unwrap();
        assert!(svc.try_recv_progress().is_none());
    }

    #[test]
    fn metrics_populated() {
        let mut rng = Rng::seed_from(5);
        let svc = Service::start(cfg(1, 1), Backend::Prism5, 3);
        let w = randmat::logspace(0.1, 1.0, 6);
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        let _ = svc.drain().unwrap();
        let rep = svc.report();
        assert!(rep.contains("service.jobs_done"));
        assert!(rep.contains("service.latency_s"));
    }
}
