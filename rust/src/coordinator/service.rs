//! The preconditioner service: bucket → batch → execute matrix-function
//! jobs on a worker pool, with bounded queues (backpressure), warm-state
//! snapshots and full metrics.
//!
//! ## Shape-bucketed scheduling
//!
//! Training integrations submit gradient/covariance matrices tagged by
//! layer and function kind; the scheduler (`super::schedule`) routes each
//! job into a per-(task, shape, precision) **bucket**. A bucket is cut
//! into one dispatched batch when
//!
//! 1. it reaches `max_batch` — the hot path, cut synchronously inside the
//!    submit call, so a full batch never waits on a timer;
//! 2. its *oldest* member has waited past [`ServiceConfig::linger`] — a
//!    background flusher cuts ripe buckets, so a rare-shape singleton is
//!    delayed by at most ~`linger` while busy routes churn (with
//!    `linger: None`, the default, partial buckets wait for the caller:
//!    `flush`/`drain`/drop);
//! 3. the caller forces dispatch ([`Service::flush`], [`Service::drain`],
//!    or dropping the handle).
//!
//! Unlike a FIFO cut, bucketing keeps mixed-shape tenants batchable: a
//! Shampoo tick interleaving many layer shapes still fills same-shape
//! lockstep batches instead of collapsing to batch size 1.
//!
//! A worker executes each batch as **one**
//! [`crate::matfn::Solver::solve_batch`] call. Newton–Schulz-family
//! backends (PRISM-3/5, classical NS) run the batch in lockstep, sharing
//! one sketch fill per iteration across every member — O(iters) sketch
//! draws per batch instead of O(batch · iters), which is what amortises
//! PRISM's fitting overhead at service scale. Only input-independent
//! scratch is shared; each job keeps its own iterate, residual, α sequence
//! and iteration log. Direct/minimax backends (eigen, PolarExpress,
//! DB-Newton) execute batch members back to back through the same
//! per-route workspace.
//!
//! ## RNG stream guarantee
//!
//! Every batch reads the RNG stream seeded by [`batch_stream_seed`] — a
//! pure function of the service seed and the batch's lowest job id, never
//! of worker identity or scheduling. Batch composition is a pure function
//! of the submission sequence and `max_batch`: buckets keep submission
//! order, linger cuts only dispatch a prefix *earlier* (never reorder),
//! and a cancelled or expired job is removed from its bucket immediately —
//! so the survivors' lowest id equals what a worker-side prune would have
//! left. Results are therefore **bit-identical across worker counts and
//! linger settings**, and each job's result equals a sequential
//! [`crate::matfn::Solver::solve`] run from a clone of its batch's stream
//! (pinned by the service conformance tests).
//!
//! Each worker keeps an LRU cache of persistent [`crate::matfn::Solver`]s per
//! (kind, shape) route, capped at `solver_cache_cap` entries, so a steady
//! stream of same-shaped preconditioner jobs runs allocation-free — the
//! Shampoo/Muon hot path — while shape-diverse traffic cannot grow a
//! worker's solver map without bound. The `sketch_p`/`tol`/`max_iters`
//! knobs are threaded into every constructed solver. With
//! `stream_residuals` set, each cached solver carries **one persistent
//! observer** whose per-batch job tags are swapped through a shared cell,
//! streaming [`ResidualEvent`]s over a progress channel while jobs are
//! still running.
//!
//! ## Warm-state snapshot / restore
//!
//! With [`ServiceConfig::cache_snapshot`] set, dropping the handle writes
//! the warm state through [`crate::runtime::manifest`]: one artifact entry
//! per recently-dispatched solver route (task, shape, solver tuning) plus
//! an `engine` entry recording the GEMM tuning (threads, blocking,
//! microkernel). `Service::start` restores a snapshot found at that path:
//! engine tuning fills the gaps the config left unset (explicit config
//! always wins), and every worker **prewarms** the restored routes at
//! spawn — building each solver through the normal path and growing its
//! batch workspace with one throwaway full-width solve — so a restarted
//! service's first tick runs the warm path with zero allocations
//! (`service.workspace_allocs` stays 0). A missing snapshot is a cold
//! start; an unreadable one warns and starts cold — the snapshot is a
//! performance hint, never a correctness input.
//!
//! ## Supervision, fault tolerance, admission
//!
//! Worker execution is supervised (see [`super::supervise`]): a panicking
//! batch is converted into per-job typed error results and the worker
//! respawns in place (re-prewarming restored routes) — no submitted job is
//! ever lost, and [`Service::drain`] always returns exactly one result per
//! admitted job. Failed solves are retried through a deterministic
//! escalation ladder (mixed→f64, then damping, then eigen); the traversed
//! path is recorded in [`JobResult::fallback`].
//!
//! The service accepts at most [`ServiceConfig::queue_cap`] jobs in flight
//! (bucket-pending + dispatched-but-unfetched). At the cap,
//! [`Service::submit`] blocks until a result is fetched
//! ([`Admission::Block`], the default) or returns a typed
//! [`Error::Backpressure`] ([`Admission::Reject`]); [`Service::try_submit`]
//! never blocks. Jobs may carry a deadline
//! ([`Service::submit_with_deadline`]); one that expires while still in
//! its bucket is removed immediately — it can neither hold the bucket's
//! linger clock open nor perturb the survivors' stream seed — as is a
//! bucket-pending job hit by [`Service::cancel`]. In every case each
//! admitted id yields exactly one [`JobResult`].
//!
//! ## Metrics
//!
//! Counters: `service.jobs_submitted`, `jobs_done`, `jobs_rejected`,
//! `jobs_failed`, `jobs_escalated`, `jobs_expired`, `jobs_cancelled`,
//! `jobs_backpressured`, `worker_panics`, `worker_restarts`,
//! `solver_cache_evictions`, `bucket_flush_full` / `bucket_flush_linger`
//! (why batches left the scheduler) and `workspace_allocs` (workspace
//! growth on the solve path — 0 on a warm service) — all registered
//! eagerly at start, so a clean run reports explicit zeros. Histograms:
//! `batch_size`, `batch_occupancy` (same observations, the scheduler-level
//! name the perf harness reads), `batch_exec_s`, `exec_s`, `latency_s`;
//! gauges: `solver_cache_size`, `batch_occupancy` (last dispatched size).
//!
//! Dropping the [`Service`] handle stops the linger flusher, dispatches
//! still-pending partial batches and waits for the workers to finish them —
//! submitted work is executed (and counted in the metrics), never silently
//! discarded — then writes the warm-state snapshot, if configured.

use super::gate::{AdmissionGate, InflightLedger};
use super::schedule::BucketScheduler;
use super::supervise;
use crate::config::{Admission, Backend, ServiceConfig};
use crate::configfmt::Value;
use crate::linalg::gemm::{GemmBlocking, MicroKernel};
use crate::linalg::Mat;
use crate::matfn::{validate_input, Precision};
use crate::metrics::Registry;
use crate::runtime::faultinject::{self, FaultPlan};
use crate::runtime::manifest::{ArtifactEntry, Manifest, TensorSpec};
use crate::runtime::sync::atomic::{AtomicBool, Ordering};
use crate::runtime::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender,
};
use crate::runtime::sync::{Arc, Mutex};
use crate::util::{lock_or_recover, Error, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What function to apply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobKind {
    /// `(A + εI)^{-1/2}` for symmetric PSD input.
    InvSqrt { eps: f64 },
    /// Polar factor (orthogonalization).
    Polar,
    /// Polar factor through the rectangular routes (`matfn::rect`): Gram /
    /// range-finder / direct, chosen per shape by the solver's
    /// `RectStrategy::Auto`. The route key carries (rows, cols), so a
    /// 256×64 layer and a 64×256 layer batch separately — each gets a
    /// warm solver for its own orientation.
    RectPolar,
}

impl JobKind {
    pub(super) fn route_key(&self, shape: (usize, usize)) -> (u8, usize, usize) {
        let tag = match self {
            JobKind::InvSqrt { .. } => 0,
            JobKind::Polar => 1,
            JobKind::RectPolar => 2,
        };
        (tag, shape.0, shape.1)
    }
}

/// A matrix-function request.
pub struct Job {
    pub id: u64,
    pub layer: usize,
    pub kind: JobKind,
    pub matrix: Mat,
    pub submitted: Instant,
    /// Absolute deadline (see [`Service::submit_with_deadline`]): a worker
    /// that picks the job up past this instant short-circuits it to a typed
    /// error result instead of solving. `None` — plain [`Service::submit`]
    /// — never expires.
    pub deadline: Option<Instant>,
}

/// A completed job.
pub struct JobResult {
    pub id: u64,
    pub layer: usize,
    pub result: Mat,
    /// Queue wait + service time, seconds.
    pub latency_s: f64,
    pub batch_size: usize,
    /// Iterations the solver ran.
    pub iters: usize,
    /// Final residual Frobenius norm.
    pub final_residual: f64,
    /// `Some(path)` when the primary solve failed and the escalation ladder
    /// ran (see [`super::supervise`]): the `"→"`-joined rungs traversed,
    /// e.g. `"f64→damp(1.2e-6)"` or `"eigen"`. `None` for jobs whose first
    /// solve succeeded. A populated `fallback` with `error: None` means a
    /// rung rescued the job; with `error: Some(_)` every rung failed too.
    pub fallback: Option<String>,
    /// `Some(reason)` when the job failed instead of being solved — a
    /// non-finite matrix reached a worker (a NaN/∞ `eps` poisoning the
    /// damping is the one route past [`Service::submit`]'s input gate), its
    /// deadline expired, it was cancelled, its worker panicked, or its
    /// solve diverged beyond every escalation rung. A failed job still
    /// yields exactly one `JobResult` (the one-result-per-job accounting
    /// holds), with `result` all zeros, `iters == 0` (boundary failures)
    /// and a NaN `final_residual`; each failure class has its own counter
    /// (`service.jobs_rejected` / `jobs_expired` / `jobs_cancelled` /
    /// `jobs_failed`) and none count in `service.jobs_done`.
    pub error: Option<String>,
}

/// One per-iteration progress report, streamed while a job is running
/// (only when [`ServiceConfig::stream_residuals`] is set).
#[derive(Debug, Clone, Copy)]
pub struct ResidualEvent {
    pub id: u64,
    pub layer: usize,
    pub iter: usize,
    pub residual: f64,
}

pub(super) enum WorkerMsg {
    Batch(Vec<Job>),
    Shutdown,
}

/// Seed of the RNG stream a batch's solves read: a pure function of the
/// service seed and the batch's first (lowest) job id — independent of
/// worker identity and execution order, which is what makes service results
/// reproducible at any worker count. Exposed so tests and clients can
/// re-run a job's exact solve out of band (see the module docs).
pub fn batch_stream_seed(service_seed: u64, first_job_id: u64) -> u64 {
    service_seed ^ first_job_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Why a batch left the scheduler — drives the `service.bucket_flush_*`
/// counters so occupancy regressions are attributable to a cut path.
#[derive(Clone, Copy)]
enum FlushReason {
    /// The bucket reached `max_batch` (cut synchronously inside `admit`).
    Full,
    /// The linger flusher cut a bucket whose oldest member waited past
    /// [`ServiceConfig::linger`].
    Linger,
    /// Caller-driven: `flush`/`drain`/drop.
    Manual,
}

/// Service handle. Dropping it shuts the workers down.
pub struct Service {
    tx: SyncSender<WorkerMsg>,
    results_rx: Mutex<Receiver<JobResult>>,
    /// Clone of the workers' result sender: the service itself synthesizes
    /// the one-and-only result for jobs removed from their bucket before
    /// dispatch (cancellation, queue-expired deadlines).
    res_tx: Sender<JobResult>,
    progress_rx: Mutex<Receiver<ResidualEvent>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<Mutex<BucketScheduler>>,
    /// Ids marked by [`Service::cancel`] *after* dispatch, shared with the
    /// workers (which honour a mark before solving) and pruned when a
    /// result is fetched. Bucket-pending cancels never land here — they
    /// remove the job from its bucket directly.
    cancelled: Arc<Mutex<BTreeSet<u64>>>,
    cfg: ServiceConfig,
    backend: Backend,
    next_id: Mutex<u64>,
    pub metrics: Arc<Registry>,
    /// Jobs handed to workers / results taken off the completion channel
    /// (see [`InflightLedger`]): `dispatched − received` is an exact count
    /// of results still owed, so the drain loop can block on it race-free.
    /// Shared with the linger flusher, which counts its own dispatches and
    /// synthesized expiry results.
    ledger: Arc<InflightLedger>,
    /// Blocking submitters park here when the admission cap is hit. The
    /// gate's condvar waits on the pending-scheduler mutex — the same lock
    /// the capacity check reads under — and every capacity-freeing path
    /// (result fetch, bucket-pending cancel, queue-expiry prune) notifies
    /// while holding that lock, so a wakeup can never be lost (the monitor
    /// discipline `rust/tests/loom_coordinator.rs` model-checks). The 5 ms
    /// timeout in the wait loop is an operational backstop only.
    admission: Arc<AdmissionGate>,
    /// Most-recently dispatched route keys, LRU-capped at
    /// `solver_cache_cap` — the warm state the shutdown snapshot records.
    warm_routes: Arc<Mutex<Vec<(u8, usize, usize)>>>,
    /// The linger flusher (spawned only with `cfg.linger` set) and its stop
    /// flag; stopped and joined in `Drop` before the final flush, so
    /// shutdown has exactly one dispatcher.
    flusher: Option<JoinHandle<()>>,
    flusher_stop: Arc<AtomicBool>,
}

impl Service {
    /// Start the service with `cfg.workers` threads using `backend` for the
    /// matrix functions; `cfg.sketch_p`, `cfg.tol` and `cfg.max_iters` are
    /// threaded into every solver the workers construct (via
    /// [`crate::matfn::Solver::for_backend_tuned`]), and
    /// `cfg.solver_cache_cap` bounds each worker's per-route solver cache.
    ///
    /// Fails with a typed [`Error::Config`] when the config is out of range
    /// ([`ServiceConfig::validate`]) or `cfg.faults` holds a malformed
    /// fault spec; a well-formed spec is installed process-globally before
    /// any worker starts (see [`crate::runtime::faultinject`]).
    ///
    /// When `cfg.gemm_threads > 1` this also installs the
    /// process-global GEMM pool the engines run their panels on (results are
    /// bit-identical at any pool size, so this only changes speed). The
    /// default value 1 means "unspecified" and deliberately does NOT tear
    /// down a pool installed earlier (e.g. by the CLI's `--threads`).
    /// `cfg.gemm_block`, when set, likewise installs the process-global
    /// GEMM cache-block sizes (a startup-time tuning knob — see
    /// [`crate::linalg::gemm::set_global_blocking`]), and `cfg.gemm_kernel`
    /// the process-global microkernel (skipped with a warning when the
    /// host lacks the ISA, so a shared config stays portable).
    pub fn start(cfg: ServiceConfig, backend: Backend, seed: u64) -> Result<Service> {
        cfg.validate()?;
        if let Some(spec) = cfg.faults.as_deref() {
            // Installed before any worker runs, so a scripted plan sees a
            // deterministic event order from the very first solve. `None`
            // deliberately leaves the process-global state alone.
            faultinject::install(FaultPlan::parse(spec)?);
        }
        if cfg.gemm_threads > 1 {
            crate::linalg::gemm::set_global_threads(cfg.gemm_threads);
        }
        if let Some(blk) = cfg.gemm_block {
            crate::linalg::gemm::set_global_blocking(blk);
        }
        if let Some(kern) = cfg.gemm_kernel {
            if kern.is_available() {
                crate::linalg::gemm::set_global_kernel(Some(kern));
            } else {
                eprintln!(
                    "service: gemm kernel '{}' not available on this host; keeping auto-detection",
                    kern.name()
                );
            }
        }
        // Warm-state restore (the snapshot leg): decode the previous run's
        // snapshot into routes the workers prewarm at spawn, and let its
        // engine entry fill any GEMM-tuning gap the config left unset. A
        // missing file is a cold start; an unreadable one warns and starts
        // cold — a stale snapshot must never block the service.
        let mut prewarm_routes: Vec<(u8, usize, usize)> = Vec::new();
        if let Some(path) = cfg.cache_snapshot.as_deref() {
            let p = Path::new(path);
            if p.exists() {
                match Manifest::load(p) {
                    Ok(m) => prewarm_routes = restore_snapshot(&m, &cfg, backend),
                    Err(e) => {
                        eprintln!("service: cache snapshot {path}: {e}; starting cold")
                    }
                }
            }
        }
        let prewarm = Arc::new(prewarm_routes);
        // The channel bound is queue_cap message slots plus one per worker:
        // admission (not the channel) is the limiter — at most `queue_cap`
        // jobs are in flight and a batch message carries ≥ 1 job — so
        // `dispatch` never blocks on a full channel.
        let (tx, rx) = sync_channel::<WorkerMsg>(cfg.queue_cap + cfg.workers);
        let rx = Arc::new(Mutex::new(rx));
        let (res_tx, res_rx): (Sender<JobResult>, Receiver<JobResult>) = channel();
        let (prog_tx, prog_rx): (Sender<ResidualEvent>, Receiver<ResidualEvent>) = channel();
        let metrics = Arc::new(Registry::default());
        // Register every counter the scheduling/supervision/admission layers
        // can touch before any job runs: a clean run's report() prints
        // explicit zeros (the CI grep-gates depend on the names always
        // appearing).
        for name in [
            "service.jobs_submitted",
            "service.jobs_done",
            "service.jobs_rejected",
            "service.jobs_failed",
            "service.jobs_escalated",
            "service.jobs_expired",
            "service.jobs_cancelled",
            "service.jobs_backpressured",
            "service.worker_panics",
            "service.worker_restarts",
            "service.solver_cache_evictions",
            "service.bucket_flush_full",
            "service.bucket_flush_linger",
            "service.workspace_allocs",
        ] {
            let _ = metrics.counter(name);
        }
        let _ = metrics.gauge("service.solver_cache_size");
        let _ = metrics.histogram("service.batch_occupancy");
        let _ = metrics.gauge("service.batch_occupancy");
        let cancelled: Arc<Mutex<BTreeSet<u64>>> = Arc::new(Mutex::new(BTreeSet::new()));
        let mut workers = Vec::new();
        for index in 0..cfg.workers {
            workers.push(supervise::spawn_worker(
                supervise::WorkerSpec {
                    index,
                    backend,
                    seed,
                    rx: Arc::clone(&rx),
                    res_tx: res_tx.clone(),
                    prog_tx: prog_tx.clone(),
                    metrics: Arc::clone(&metrics),
                    cancelled: Arc::clone(&cancelled),
                    prewarm: Arc::clone(&prewarm),
                },
                &cfg,
            ));
        }
        let pending = Arc::new(Mutex::new(BucketScheduler::new(cfg.max_batch, cfg.precision)));
        let ledger = Arc::new(InflightLedger::new());
        let admission = Arc::new(AdmissionGate::new());
        let warm_routes: Arc<Mutex<Vec<(u8, usize, usize)>>> =
            Arc::new(Mutex::new(Vec::new()));
        let flusher_stop = Arc::new(AtomicBool::new(false));
        let flusher = cfg.linger.map(|linger| {
            spawn_flusher(FlusherShared {
                pending: Arc::clone(&pending),
                tx: tx.clone(),
                res_tx: res_tx.clone(),
                metrics: Arc::clone(&metrics),
                ledger: Arc::clone(&ledger),
                admission: Arc::clone(&admission),
                warm_routes: Arc::clone(&warm_routes),
                warm_cap: cfg.solver_cache_cap,
                stop: Arc::clone(&flusher_stop),
                linger,
            })
        });
        Ok(Service {
            tx,
            results_rx: Mutex::new(res_rx),
            res_tx,
            progress_rx: Mutex::new(prog_rx),
            workers,
            pending,
            cancelled,
            cfg,
            backend,
            next_id: Mutex::new(0),
            metrics,
            ledger,
            admission,
            warm_routes,
            flusher,
            flusher_stop,
        })
    }

    /// Submit a job; same-shape jobs are held back briefly to form batches
    /// of up to `max_batch` (call [`flush`] to force dispatch).
    ///
    /// Non-finite matrices (any NaN/∞ entry) are rejected here at the
    /// boundary with a typed [`Error::Numerical`] — **before** an id is
    /// assigned (accepted ids stay dense, so batch composition and every
    /// accepted job's RNG stream are exactly what they would have been had
    /// the poisoned submission never happened) and before the job can
    /// reach a batch, where its NaNs would burn `max_iters` of work
    /// producing garbage. Rejections count in `service.jobs_rejected`, not
    /// `service.jobs_submitted`. (A non-finite InvSqrt `eps` is the one
    /// poisoning this gate cannot see — the workers catch it after damping
    /// and return a [`JobResult::error`] instead.)
    ///
    /// When the admission cap is hit (module docs), the call blocks until
    /// capacity frees up or — with `admission = reject` — returns a typed
    /// [`Error::Backpressure`] immediately.
    pub fn submit(&self, layer: usize, kind: JobKind, matrix: Mat) -> Result<u64> {
        self.admit(layer, kind, matrix, None, self.cfg.admission == Admission::Block)
    }

    /// [`Service::submit`] that never blocks: a full queue is always a typed
    /// [`Error::Backpressure`], regardless of [`ServiceConfig::admission`].
    pub fn try_submit(&self, layer: usize, kind: JobKind, matrix: Mat) -> Result<u64> {
        self.admit(layer, kind, matrix, None, false)
    }

    /// [`Service::submit`] with a time-to-live: if the job is still waiting
    /// for a worker `ttl` from now, it is short-circuited to a typed error
    /// result (`service.jobs_expired`) instead of being solved. The
    /// deadline bounds *queue* time, not solve time — a job picked up in
    /// time runs to completion.
    pub fn submit_with_deadline(
        &self,
        layer: usize,
        kind: JobKind,
        matrix: Mat,
        ttl: Duration,
    ) -> Result<u64> {
        // A `ttl` too large to represent simply never expires.
        let deadline = Instant::now().checked_add(ttl);
        self.admit(layer, kind, matrix, deadline, self.cfg.admission == Admission::Block)
    }

    /// Best-effort cancellation. A job still pending in its bucket is
    /// removed **immediately** and its typed error result
    /// (`service.jobs_cancelled`) synthesized on the spot — it can neither
    /// hold the bucket open past `linger` nor ride into a batch and perturb
    /// the surviving members' [`batch_stream_seed`]. A job already
    /// dispatched is marked instead, so a worker that picks it up *before
    /// solving* short-circuits it; one already solving — or already done —
    /// is not interrupted: its normal result is still delivered and the
    /// mark is discarded when that result is fetched. Returns `false` for
    /// ids the service never assigned.
    pub fn cancel(&self, id: u64) -> bool {
        if id == 0 || id > *lock_or_recover(&self.next_id) {
            return false;
        }
        let held = {
            let mut pend = lock_or_recover(&self.pending);
            let held = pend.remove(id);
            if held.is_some() {
                // A bucket-pending removal frees admission capacity: wake
                // parked submitters under the pending lock (the gate's
                // no-lost-wakeup discipline).
                self.admission.notify();
            }
            held
        };
        if let Some(job) = held {
            self.metrics.counter("service.jobs_cancelled").inc();
            // Count the synthesized result as one dispatch *before* sending
            // it, so `inflight` never undercounts what is owed.
            self.ledger.note_dispatched(1);
            let why = format!("job {id}: cancelled while pending in its bucket");
            let _ = self.res_tx.send(bucket_removal_result(&job, why));
            return true;
        }
        lock_or_recover(&self.cancelled).insert(id);
        true
    }

    /// Admission + routing. The capacity check, id assignment and queue
    /// push all happen under the pending lock, so concurrent submitters
    /// serialize and the cap is never overshot (`inflight` can only shrink
    /// concurrently — results being fetched — which is the safe direction).
    ///
    /// A blocking submitter parks on the *pending* mutex itself (through
    /// [`AdmissionGate`]): the wait releases exactly the lock the capacity
    /// check read under, and every capacity-freeing path notifies while
    /// holding it, so a wakeup cannot land in the check-to-park window and
    /// be lost. The loom suite checks this over every bounded interleaving;
    /// the 5 ms backstop bounds the cost of anything the model does not
    /// cover (e.g. a future capacity-freeing path that forgets to notify).
    fn admit(
        &self,
        layer: usize,
        kind: JobKind,
        matrix: Mat,
        deadline: Option<Instant>,
        block: bool,
    ) -> Result<u64> {
        if let Err(e) = validate_input(&matrix) {
            self.metrics.counter("service.jobs_rejected").inc();
            return Err(e);
        }
        let mut job =
            Some(Job { id: 0, layer, kind, matrix, submitted: Instant::now(), deadline });
        loop {
            let mut pend = lock_or_recover(&self.pending);
            let used = pend.pending() + self.inflight();
            if used < self.cfg.queue_cap {
                let id = {
                    let mut n = lock_or_recover(&self.next_id);
                    *n += 1;
                    *n
                };
                let mut j = job.take().expect("job is present until admitted");
                j.id = id;
                j.submitted = Instant::now();
                self.metrics.counter("service.jobs_submitted").inc();
                let batch = pend.push(j);
                drop(pend);
                // A full-bucket cut dispatches synchronously with the
                // admitting submit (outside the pending lock) — batch
                // latency is part of the admission path's contract.
                if let Some(b) = batch {
                    self.dispatch(b, FlushReason::Full)?;
                }
                return Ok(id);
            }
            if !block {
                drop(pend);
                self.metrics.counter("service.jobs_backpressured").inc();
                return Err(Error::Backpressure(format!(
                    "service: {used} jobs in flight ≥ queue_cap {} \
                     (fetch results or raise service.queue_cap)",
                    self.cfg.queue_cap
                )));
            }
            // Park until capacity frees up; the loop re-checks under the
            // re-acquired lock (both against spurious wakeups and because
            // another submitter may have taken the freed slot first).
            let _pend = self.admission.park(pend, Duration::from_millis(5));
        }
    }

    fn dispatch(&self, batch: Vec<Job>, reason: FlushReason) -> Result<()> {
        dispatch_batch(
            &self.tx,
            &self.ledger,
            &self.metrics,
            &self.warm_routes,
            self.cfg.solver_cache_cap,
            batch,
            reason,
        )
    }

    /// Dispatch all partially-filled buckets.
    pub fn flush(&self) -> Result<()> {
        let batches = lock_or_recover(&self.pending).take_all();
        for b in batches {
            self.dispatch(b, FlushReason::Manual)?;
        }
        Ok(())
    }

    /// Number of results still owed (dispatched − received). Results of
    /// partially-filled batches still held back by the router are *not*
    /// counted — call [`Self::flush`] first. The exactness argument (load
    /// order, no underflow clamp) lives on [`InflightLedger::inflight`].
    pub fn inflight(&self) -> usize {
        self.ledger.inflight()
    }

    /// Shared bookkeeping for every fetched result: advance `received`,
    /// record latency, discard a stale cancel mark, and wake the admission
    /// waiters (capacity just freed up). The notify happens under the
    /// pending lock — the gate's no-lost-wakeup discipline — acquired after
    /// the ledger update, so a woken submitter's capacity re-check already
    /// sees the freed slot.
    fn note_received(&self, r: &JobResult) {
        self.ledger.note_received();
        self.metrics.histogram("service.latency_s").observe(r.latency_s);
        lock_or_recover(&self.cancelled).remove(&r.id);
        let _pend = lock_or_recover(&self.pending);
        self.admission.notify();
    }

    /// Blocking receive of the next completed job.
    pub fn recv(&self) -> Result<JobResult> {
        let r = {
            let rx = lock_or_recover(&self.results_rx);
            rx.recv().map_err(|_| Error::Runtime("service: result channel closed".into()))?
        };
        self.note_received(&r);
        Ok(r)
    }

    /// [`Service::recv`] with a timeout: `Ok(None)` when no result arrived
    /// within `timeout`, `Err` only when the workers are gone. The bounded
    /// wait is what lets callers supervise a service that might have
    /// stalled instead of blocking forever on it.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Option<JobResult>> {
        let got = {
            let rx = lock_or_recover(&self.results_rx);
            rx.recv_timeout(timeout)
        };
        match got {
            Ok(r) => {
                self.note_received(&r);
                Ok(Some(r))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(Error::Runtime("service: result channel closed".into()))
            }
        }
    }

    /// Non-blocking receive of the next streamed per-iteration residual.
    /// Only produces events when [`ServiceConfig::stream_residuals`] is set;
    /// clients poll this to watch convergence while jobs are in flight
    /// instead of waiting for the final `IterationLog`.
    pub fn try_recv_progress(&self) -> Option<ResidualEvent> {
        lock_or_recover(&self.progress_rx).try_recv().ok()
    }

    /// Non-blocking receive: returns `None` when no result is ready yet.
    /// Used by staleness-tolerant callers (e.g. [`super::async_shampoo`])
    /// that keep working with old results while refreshes are in flight.
    pub fn try_recv(&self) -> Option<JobResult> {
        let r = {
            let rx = lock_or_recover(&self.results_rx);
            rx.try_recv().ok()?
        };
        self.note_received(&r);
        Some(r)
    }

    /// Flush, then collect every outstanding result. Blocks until all
    /// dispatched jobs have reported back; race-free because `dispatched`
    /// is fixed once `flush` returns and each job sends exactly one result.
    pub fn drain(&self) -> Result<Vec<JobResult>> {
        self.flush()?;
        let mut out = Vec::new();
        while self.inflight() > 0 {
            out.push(self.recv()?);
        }
        Ok(out)
    }

    /// [`Service::drain`] with a wall-clock budget for the *whole* drain:
    /// flushes, then collects results until none are owed or the budget is
    /// spent — in which case it fails with a typed error naming how many
    /// results are still missing, instead of hanging on a stalled service.
    pub fn drain_timeout(&self, budget: Duration) -> Result<Vec<JobResult>> {
        self.flush()?;
        let deadline = Instant::now() + budget;
        let mut out = Vec::new();
        while self.inflight() > 0 {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::Runtime(format!(
                    "service: drain timed out after {:.1}s with {} results still owed",
                    budget.as_secs_f64(),
                    self.inflight()
                )));
            }
            if let Some(r) = self.recv_timeout(left)? {
                out.push(r);
            }
        }
        Ok(out)
    }

    pub fn report(&self) -> String {
        self.metrics.report()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        // Stop the linger flusher first, so the final flush below is the
        // only dispatcher left (no timer cuts racing shutdown).
        self.flusher_stop.store(true, Ordering::SeqCst);
        if let Some(f) = self.flusher.take() {
            let _ = f.join();
        }
        // Dispatch still-pending partial batches so submitted work is
        // executed (and counted) rather than silently discarded; the FIFO
        // worker channel guarantees they run before the shutdown messages
        // queued behind them.
        let _ = self.flush();
        for _ in &self.workers {
            let _ = self.tx.send(WorkerMsg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Snapshot the warm state only after the workers are done: the
        // recorded routes are exactly the ones whose solvers finished warm.
        if let Some(path) = self.cfg.cache_snapshot.as_deref() {
            let routes = lock_or_recover(&self.warm_routes).clone();
            let m = snapshot_manifest(&routes, &self.cfg, self.backend);
            if let Err(e) = m.save(Path::new(path)) {
                eprintln!("service: cache snapshot {path}: {e}");
            }
        }
    }
}

/// The shared dispatch path — used by the service handle (full-bucket cuts,
/// manual flushes) and the linger flusher thread. Advances `dispatched`,
/// records the occupancy metrics and the warm-route LRU, then hands the
/// batch to the worker channel.
fn dispatch_batch(
    tx: &SyncSender<WorkerMsg>,
    ledger: &InflightLedger,
    metrics: &Registry,
    warm_routes: &Mutex<Vec<(u8, usize, usize)>>,
    warm_cap: usize,
    batch: Vec<Job>,
    reason: FlushReason,
) -> Result<()> {
    if batch.is_empty() {
        return Ok(());
    }
    // Chaos hook: a scripted dispatch delay widens race windows (e.g.
    // deadlines expiring in the queue) deterministically. Inert — one
    // relaxed atomic load — unless a fault plan is installed.
    if let Some(ms) = faultinject::dispatch_delay_ms() {
        std::thread::sleep(Duration::from_millis(ms));
    }
    match reason {
        FlushReason::Full => metrics.counter("service.bucket_flush_full").inc(),
        FlushReason::Linger => metrics.counter("service.bucket_flush_linger").inc(),
        FlushReason::Manual => {}
    }
    ledger.note_dispatched(batch.len() as u64);
    metrics.histogram("service.batch_size").observe(batch.len() as f64);
    metrics.histogram("service.batch_occupancy").observe(batch.len() as f64);
    metrics.gauge("service.batch_occupancy").set(batch.len() as i64);
    {
        // Warm-route LRU for the shutdown snapshot: most-recently
        // dispatched first out, capped like the worker solver caches.
        let key = batch[0].kind.route_key(batch[0].matrix.shape());
        let mut warm = lock_or_recover(warm_routes);
        if let Some(i) = warm.iter().position(|k| *k == key) {
            warm.remove(i);
        }
        warm.push(key);
        if warm.len() > warm_cap {
            warm.remove(0);
        }
    }
    tx.send(WorkerMsg::Batch(batch))
        .map_err(|_| Error::Runtime("service: workers gone".into()))
}

/// The one-and-only typed error result for a job removed from its bucket
/// before dispatch (cancellation, queue-expired deadline). Mirrors the
/// worker-side failure shape: zero matrix, 0 iters, NaN residual.
fn bucket_removal_result(job: &Job, why: String) -> JobResult {
    JobResult {
        id: job.id,
        layer: job.layer,
        result: Mat::zeros(job.matrix.rows(), job.matrix.cols()),
        latency_s: job.submitted.elapsed().as_secs_f64(),
        batch_size: 1,
        iters: 0,
        final_residual: f64::NAN,
        fallback: None,
        error: Some(why),
    }
}

/// Everything the linger flusher thread owns: clones of the dispatch path's
/// shared state plus its own stop flag.
struct FlusherShared {
    pending: Arc<Mutex<BucketScheduler>>,
    tx: SyncSender<WorkerMsg>,
    res_tx: Sender<JobResult>,
    metrics: Arc<Registry>,
    ledger: Arc<InflightLedger>,
    admission: Arc<AdmissionGate>,
    warm_routes: Arc<Mutex<Vec<(u8, usize, usize)>>>,
    warm_cap: usize,
    stop: Arc<AtomicBool>,
    linger: Duration,
}

/// The linger flusher: periodically sweeps the bucket scheduler, removing
/// queue-expired jobs (synthesizing their typed error results) and cutting
/// every bucket whose oldest member has waited past `linger`. Spawned only
/// when [`ServiceConfig::linger`] is set.
fn spawn_flusher(sh: FlusherShared) -> JoinHandle<()> {
    std::thread::spawn(move || {
        // Poll at a fraction of the linger so a ripe bucket is cut within
        // ~linger/4 of its deadline; clamped so tiny lingers don't spin and
        // large ones still notice the stop flag promptly.
        let poll = (sh.linger / 4)
            .clamp(Duration::from_micros(500), Duration::from_millis(5));
        while !sh.stop.load(Ordering::SeqCst) {
            std::thread::sleep(poll);
            let now = Instant::now();
            let (dead, ripe) = {
                let mut pend = lock_or_recover(&sh.pending);
                let swept =
                    (pend.prune_deadlines(now), pend.take_over_linger(now, sh.linger));
                if !swept.0.is_empty() {
                    // Queue-expiry pruning frees admission capacity without
                    // a result fetch: notify under the pending lock (the
                    // gate's no-lost-wakeup discipline). Linger cuts only
                    // move jobs from pending to in-flight — no capacity
                    // change — so they don't notify.
                    sh.admission.notify();
                }
                swept
            };
            for job in dead {
                // Expiry is detected while the job still sits in its bucket,
                // so it cannot pin the bucket's linger clock nor perturb the
                // survivors' stream seed. One synthesized result per removed
                // job keeps the one-result-per-job accounting exact; count
                // it dispatched first so `inflight` never undercounts.
                sh.metrics.counter("service.jobs_expired").inc();
                let why =
                    format!("job {}: deadline expired in its bucket before dispatch", job.id);
                sh.ledger.note_dispatched(1);
                let _ = sh.res_tx.send(bucket_removal_result(&job, why));
            }
            for batch in ripe {
                let sent = dispatch_batch(
                    &sh.tx,
                    &sh.ledger,
                    &sh.metrics,
                    &sh.warm_routes,
                    sh.warm_cap,
                    batch,
                    FlushReason::Linger,
                );
                if sent.is_err() {
                    return; // workers gone — the service is shutting down
                }
            }
        }
    })
}

/// Encode the warm state as a [`Manifest`]: one artifact entry per
/// recently-dispatched solver route (its solver tuning in `meta`, its input
/// shape as a [`TensorSpec`]) plus an `engine` entry carrying the GEMM
/// tuning. The same artifact contract `python/compile/aot.py` writes, so
/// the snapshot round-trips through [`Manifest::parse`].
fn snapshot_manifest(
    routes: &[(u8, usize, usize)],
    cfg: &ServiceConfig,
    backend: Backend,
) -> Manifest {
    let precision = match cfg.precision {
        Precision::F64 => "f64",
        Precision::Mixed => "mixed",
    };
    let mut entries = Vec::with_capacity(routes.len() + 1);
    for &(task, rows, cols) in routes {
        let mut meta = BTreeMap::new();
        meta.insert("task".to_string(), Value::Int(task as i64));
        meta.insert("backend".to_string(), Value::Str(backend.name().to_string()));
        meta.insert("max_iters".to_string(), Value::Int(cfg.max_iters as i64));
        meta.insert("sketch_p".to_string(), Value::Int(cfg.sketch_p as i64));
        meta.insert("tol".to_string(), cfg.tol.map_or(Value::Null, Value::Float));
        meta.insert("precision".to_string(), Value::Str(precision.to_string()));
        let spec = |name: &str| TensorSpec {
            name: name.to_string(),
            shape: vec![rows as i64, cols as i64],
            dtype: "f64".to_string(),
        };
        entries.push(ArtifactEntry {
            name: format!("route_{task}_{rows}x{cols}"),
            file: "solver-cache".to_string(),
            inputs: vec![spec("a")],
            outputs: vec![spec("f_a")],
            meta,
        });
    }
    let mut meta = BTreeMap::new();
    meta.insert("threads".to_string(), Value::Int(cfg.gemm_threads as i64));
    if let Some(b) = cfg.gemm_block {
        meta.insert("block".to_string(), Value::Str(b.display()));
    }
    if let Some(k) = cfg.gemm_kernel {
        meta.insert("kernel".to_string(), Value::Str(k.name().to_string()));
    }
    entries.push(ArtifactEntry {
        name: "engine".to_string(),
        file: "gemm-tuning".to_string(),
        inputs: Vec::new(),
        outputs: Vec::new(),
        meta,
    });
    Manifest { version: 1, entries }
}

/// Decode a snapshot back into prewarmable route keys, and apply its engine
/// entry as a gap-filler for GEMM tuning the config left unset (explicit
/// config always wins). Only routes whose recorded solver tuning matches
/// the *current* config are kept — a solver prewarmed under stale tuning
/// would shadow the correctly-tuned one in the worker caches.
fn restore_snapshot(
    m: &Manifest,
    cfg: &ServiceConfig,
    backend: Backend,
) -> Vec<(u8, usize, usize)> {
    let want_precision = match cfg.precision {
        Precision::F64 => "f64",
        Precision::Mixed => "mixed",
    };
    let want_tol = cfg.tol.map_or(Value::Null, Value::Float);
    let mut routes = Vec::new();
    for e in &m.entries {
        if e.file == "gemm-tuning" {
            if cfg.gemm_threads <= 1 {
                if let Some(t) = e.meta.get("threads").and_then(|v| v.as_int()) {
                    if t > 1 {
                        crate::linalg::gemm::set_global_threads(t as usize);
                    }
                }
            }
            if cfg.gemm_block.is_none() {
                if let Some(b) = e.meta.get("block").and_then(|v| v.as_str()) {
                    if let Ok(blk) = GemmBlocking::parse(b) {
                        crate::linalg::gemm::set_global_blocking(blk);
                    }
                }
            }
            if cfg.gemm_kernel.is_none() {
                if let Some(k) = e.meta.get("kernel").and_then(|v| v.as_str()) {
                    if let Ok(Some(kern)) = MicroKernel::parse(k) {
                        if kern.is_available() {
                            crate::linalg::gemm::set_global_kernel(Some(kern));
                        }
                    }
                }
            }
            continue;
        }
        if e.file != "solver-cache" {
            continue;
        }
        let tuned_for_this_config = e.meta.get("backend").and_then(|v| v.as_str())
            == Some(backend.name())
            && e.meta.get("sketch_p").and_then(|v| v.as_int()) == Some(cfg.sketch_p as i64)
            && e.meta.get("max_iters").and_then(|v| v.as_int()) == Some(cfg.max_iters as i64)
            && e.meta.get("precision").and_then(|v| v.as_str()) == Some(want_precision)
            && *e.meta.get("tol").unwrap_or(&Value::Null) == want_tol;
        if !tuned_for_this_config {
            continue;
        }
        let task = e.meta.get("task").and_then(|v| v.as_int());
        let (rows, cols) = match e.inputs.first() {
            Some(t) if t.shape.len() == 2 => (t.shape[0], t.shape[1]),
            _ => continue,
        };
        if let Some(task) = task {
            if (0..=2).contains(&task) && rows > 0 && cols > 0 {
                routes.push((task as u8, rows as usize, cols as usize));
            }
        }
    }
    routes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_at_b};
    use crate::matfn::{MatFnTask, Precision, Solver};
    use crate::randmat;
    use crate::rng::Rng;

    fn cfg(workers: usize, max_batch: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            queue_cap: 64,
            admission: Admission::Block,
            max_batch,
            sketch_p: 8,
            max_iters: 40,
            tol: None,
            solver_cache_cap: 32,
            gemm_threads: 1,
            stream_residuals: false,
            gemm_block: None,
            gemm_kernel: None,
            precision: Precision::F64,
            faults: None,
            linger: None,
            cache_snapshot: None,
        }
    }

    /// Test-side `Service::start` that unwraps the config validation.
    fn start(cfg: ServiceConfig, backend: Backend, seed: u64) -> Service {
        Service::start(cfg, backend, seed).expect("test service config is valid")
    }

    #[test]
    fn invsqrt_jobs_round_trip() {
        let mut rng = Rng::seed_from(1);
        let svc = start(cfg(2, 2), Backend::Prism5, 42);
        let mut inputs = Vec::new();
        for layer in 0..4 {
            let w = randmat::logspace(1e-2, 1.0, 8);
            let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
            inputs.push(a.clone());
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 4);
        for r in &results {
            let a = &inputs[r.layer];
            let prod = matmul(&matmul(&r.result, a), &r.result);
            assert!(
                prod.sub(&Mat::eye(8)).max_abs() < 1e-3,
                "layer {}: err {}",
                r.layer,
                prod.sub(&Mat::eye(8)).max_abs()
            );
            assert!(r.latency_s >= 0.0);
        }
    }

    #[test]
    fn polar_jobs_round_trip() {
        let mut rng = Rng::seed_from(2);
        let svc = start(cfg(1, 4), Backend::Prism3, 7);
        let a = randmat::gaussian(&mut rng, 16, 8);
        svc.submit(0, JobKind::Polar, a).unwrap();
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 1);
        let q = &results[0].result;
        assert!(matmul_at_b(q, q).sub(&Mat::eye(8)).max_abs() < 1e-3);
    }

    #[test]
    fn rectpolar_jobs_round_trip_both_orientations() {
        // Tall and wide layers route separately — (rows, cols) is in the
        // route key — and each solves through the Gram route (aspect 4
        // under Auto), landing within the service polar tolerance of the
        // SVD polar factor.
        let mut rng = Rng::seed_from(21);
        let svc = start(cfg(2, 2), Backend::Prism5, 17);
        let s = randmat::logspace(0.1, 1.0, 12);
        let tall = randmat::with_spectrum(&mut rng, 48, 12, &s);
        let wide = tall.transpose();
        let inputs = [tall, wide];
        for (layer, a) in inputs.iter().enumerate() {
            svc.submit(layer, JobKind::RectPolar, a.clone()).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 2);
        for r in &results {
            let a = &inputs[r.layer];
            assert_eq!(r.result.shape(), a.shape());
            assert!(r.error.is_none(), "{:?}", r.error);
            let exact = crate::baselines::eigen_fn::polar_eigen(a);
            let err = r.result.sub(&exact).max_abs();
            assert!(err < 1e-3, "layer {}: err {err}", r.layer);
        }
    }

    #[test]
    fn batching_groups_same_shape() {
        let mut rng = Rng::seed_from(3);
        let svc = start(cfg(1, 3), Backend::Eigen, 1);
        // 3 same-shape jobs = exactly one full batch.
        for layer in 0..3 {
            let w = randmat::logspace(0.1, 1.0, 6);
            let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 3);
        assert!(results.iter().all(|r| r.batch_size == 3), "batch sizes: {:?}",
            results.iter().map(|r| r.batch_size).collect::<Vec<_>>());
    }

    #[test]
    fn mixed_shapes_split_batches() {
        let mut rng = Rng::seed_from(4);
        let svc = start(cfg(2, 8), Backend::Eigen, 2);
        for layer in 0..4 {
            let n = if layer % 2 == 0 { 5 } else { 7 };
            let w = randmat::logspace(0.1, 1.0, n);
            let a = randmat::sym_with_spectrum(&mut rng, n, &w);
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 4);
        // Shapes must be preserved per layer.
        for r in &results {
            let n = if r.layer % 2 == 0 { 5 } else { 7 };
            assert_eq!(r.result.shape(), (n, n));
        }
    }

    #[test]
    fn streams_residual_trajectory_when_enabled() {
        let mut rng = Rng::seed_from(6);
        let mut c = cfg(1, 1);
        c.stream_residuals = true;
        let svc = start(c, Backend::Prism5, 9);
        let w = randmat::logspace(1e-2, 1.0, 8);
        let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 1);
        // Once the job is done, its full trajectory has been streamed.
        let mut events = Vec::new();
        while let Some(ev) = svc.try_recv_progress() {
            events.push(ev);
        }
        assert_eq!(events.len(), results[0].iters, "one event per iteration");
        assert!(events.iter().all(|e| e.layer == 0));
        for (k, ev) in events.iter().enumerate() {
            assert_eq!(ev.iter, k);
        }
        let last = events.last().expect("at least one iteration");
        assert!(
            (last.residual - results[0].final_residual).abs() <= 1e-12,
            "stream tail must match the final residual"
        );
    }

    #[test]
    fn no_progress_events_by_default() {
        let mut rng = Rng::seed_from(7);
        let svc = start(cfg(1, 1), Backend::Prism5, 11);
        let w = randmat::logspace(0.1, 1.0, 6);
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        let _ = svc.drain().unwrap();
        assert!(svc.try_recv_progress().is_none());
    }

    fn burst_results(workers: usize, max_batch: usize, seed: u64, inputs: &[Mat]) -> Vec<Mat> {
        let svc = start(cfg(workers, max_batch), Backend::Prism5, seed);
        for (layer, a) in inputs.iter().enumerate() {
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
        }
        let mut rs = svc.drain().unwrap();
        rs.sort_by_key(|r| r.layer);
        rs.into_iter().map(|r| r.result).collect()
    }

    #[test]
    fn batched_burst_bit_identical_to_per_job_solves() {
        // The tentpole contract: a 16-job same-shape burst drained through
        // one or many workers is bitwise identical to solving each job
        // sequentially from a clone of its batch's RNG stream.
        let mut rng = Rng::seed_from(10);
        let inputs: Vec<Mat> = (0..16)
            .map(|_| {
                let w = randmat::logspace(1e-2, 1.0, 8);
                randmat::sym_with_spectrum(&mut rng, 8, &w)
            })
            .collect();
        let seed = 42;
        let single = burst_results(1, 4, seed, &inputs);
        let multi = burst_results(4, 4, seed, &inputs);
        assert_eq!(single.len(), 16);
        for j in 0..16 {
            assert_eq!(single[j], multi[j], "job {j}: worker count changed result bits");
        }
        // Per-job sequential reference: ids are 1-based in submission order
        // and max_batch = 4, so job j rides the batch whose first id is
        // 4·⌊j/4⌋ + 1, and its solve reads a clone of that batch's stream.
        for (j, a) in inputs.iter().enumerate() {
            let first_id = (j / 4 * 4 + 1) as u64;
            let mut r = Rng::seed_from(batch_stream_seed(seed, first_id));
            let mut s = Solver::for_backend_tuned(
                Backend::Prism5,
                MatFnTask::InvSqrt,
                40,
                None, // per-task default, same as the service's tol: None
                Some(8),
            )
            .unwrap();
            let out = s.solve(a, &mut r);
            assert_eq!(single[j], out.primary, "job {j}: batched != sequential solve");
        }
    }

    #[test]
    fn tol_knob_reaches_the_solvers() {
        // Regression for the silently-dropped config knobs: a looser
        // service.tol must stop the iteration earlier.
        let mut rng = Rng::seed_from(8);
        let w = randmat::logspace(1e-3, 1.0, 10);
        let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
        let run = |tol: f64| {
            let mut c = cfg(1, 1);
            c.max_iters = 60;
            c.tol = Some(tol);
            let svc = start(c, Backend::Prism5, 42);
            svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
            svc.drain().unwrap()[0].iters
        };
        let (loose, tight) = (run(1e-2), run(1e-10));
        assert!(loose < tight, "tol must change observed iters: loose {loose} vs tight {tight}");
    }

    #[test]
    fn sketch_p_knob_reaches_the_solvers() {
        // A different service.sketch_p draws different sketches, so the
        // fitted α sequence — and hence the result bits — must change.
        let mut rng = Rng::seed_from(9);
        let w = randmat::logspace(1e-3, 1.0, 12);
        let a = randmat::sym_with_spectrum(&mut rng, 12, &w);
        let run = |p: usize| {
            let mut c = cfg(1, 1);
            c.sketch_p = p;
            let svc = start(c, Backend::Prism5, 42);
            svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
            svc.drain().unwrap().remove(0).result
        };
        assert_ne!(run(2), run(32), "sketch_p must reach the α fits");
    }

    #[test]
    fn solver_cache_evicts_lru_under_shape_diverse_stream() {
        let mut rng = Rng::seed_from(11);
        let mut c = cfg(1, 1);
        c.solver_cache_cap = 8;
        c.max_iters = 3; // cheap: eviction behaviour, not convergence
        let svc = start(c, Backend::Prism3, 5);
        for k in 0..100usize {
            // 100 distinct route keys: polar panels of width 5..=104.
            let a = randmat::gaussian(&mut rng, 4, 5 + k);
            svc.submit(k, JobKind::Polar, a).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 100);
        let size = svc.metrics.gauge("service.solver_cache_size").get();
        assert!((1..=8).contains(&size), "cache size {size} must stay within the cap");
        let ev = svc.metrics.counter("service.solver_cache_evictions").get();
        assert!(ev >= 92, "expected >= 92 LRU evictions under 100 shapes, saw {ev}");
    }

    #[test]
    fn drop_flushes_pending_jobs() {
        // Partial batches still held by the router must be executed (and
        // counted) when the handle drops, not silently discarded.
        let mut rng = Rng::seed_from(12);
        let svc = start(cfg(1, 8), Backend::Prism5, 6);
        let w = randmat::logspace(0.1, 1.0, 6);
        for layer in 0..3 {
            let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let metrics = Arc::clone(&svc.metrics);
        drop(svc);
        assert_eq!(
            metrics.counter("service.jobs_done").get(),
            3,
            "drop must flush and execute pending jobs"
        );
    }

    #[test]
    fn streams_per_job_trajectories_for_batches() {
        // Batched execution interleaves members' iterations; the persistent
        // observers must still attribute every event to the right job.
        let mut rng = Rng::seed_from(13);
        let mut c = cfg(1, 4);
        c.stream_residuals = true;
        let svc = start(c, Backend::Prism5, 9);
        let w = randmat::logspace(1e-2, 1.0, 8);
        for layer in 0..4 {
            let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 4);
        let mut per_job: BTreeMap<u64, Vec<ResidualEvent>> = BTreeMap::new();
        while let Some(ev) = svc.try_recv_progress() {
            per_job.entry(ev.id).or_default().push(ev);
        }
        for r in &results {
            let evs = &per_job[&r.id];
            assert_eq!(evs.len(), r.iters, "job {}: one event per iteration", r.id);
            for (k, ev) in evs.iter().enumerate() {
                assert_eq!(ev.iter, k, "job {}: events in iteration order", r.id);
                assert_eq!(ev.layer, r.layer);
            }
            let last = evs.last().expect("at least one iteration");
            assert!(
                (last.residual - r.final_residual).abs() <= 1e-12,
                "job {}: stream tail must match the final residual",
                r.id
            );
        }
    }

    #[test]
    fn submit_rejects_non_finite_matrix_before_assigning_an_id() {
        let mut rng = Rng::seed_from(20);
        let svc = start(cfg(1, 2), Backend::Prism5, 21);
        let mut bad = randmat::gaussian(&mut rng, 6, 6);
        bad[(2, 4)] = f64::NAN;
        let err = svc.submit(0, JobKind::Polar, bad).unwrap_err();
        assert!(matches!(err, Error::Numerical(_)), "{err}");
        assert!(err.to_string().contains("non-finite"), "{err}");
        let mut inf = randmat::gaussian(&mut rng, 6, 6);
        inf[(0, 0)] = f64::NEG_INFINITY;
        assert!(svc.submit(0, JobKind::Polar, inf).is_err());
        assert_eq!(svc.metrics.counter("service.jobs_rejected").get(), 2);
        assert_eq!(svc.metrics.counter("service.jobs_submitted").get(), 0);
        let w = randmat::logspace(0.1, 1.0, 6);
        let spd = randmat::sym_with_spectrum(&mut rng, 6, &w);
        // Rejection happened before id assignment: the first accepted job
        // still gets id 1, so batch streams are unperturbed.
        let id = svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, spd).unwrap();
        assert_eq!(id, 1, "rejected submissions must not consume ids");
        let _ = svc.drain().unwrap();
    }

    #[test]
    fn poisoned_burst_member_fails_cleanly_others_bit_identical() {
        // Regression: one poisoned submission inside a same-shape burst must
        // fail at the boundary while every accepted member's result stays
        // bit-identical to its solo solve — same ids, same batch
        // composition, same RNG stream as a burst where the poisoned submit
        // never happened.
        let mut rng = Rng::seed_from(22);
        let inputs: Vec<Mat> = (0..4)
            .map(|_| {
                let w = randmat::logspace(1e-2, 1.0, 8);
                randmat::sym_with_spectrum(&mut rng, 8, &w)
            })
            .collect();
        let mut poison = inputs[0].clone();
        poison[(1, 1)] = f64::NAN;
        let seed = 33;
        let svc = start(cfg(1, 4), Backend::Prism5, seed);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, inputs[0].clone()).unwrap();
        svc.submit(1, JobKind::InvSqrt { eps: 0.0 }, inputs[1].clone()).unwrap();
        assert!(svc.submit(9, JobKind::InvSqrt { eps: 0.0 }, poison).is_err());
        svc.submit(2, JobKind::InvSqrt { eps: 0.0 }, inputs[2].clone()).unwrap();
        svc.submit(3, JobKind::InvSqrt { eps: 0.0 }, inputs[3].clone()).unwrap();
        let mut results = svc.drain().unwrap();
        results.sort_by_key(|r| r.layer);
        assert_eq!(results.len(), 4);
        // All four accepted jobs formed one batch (ids 1..=4, stream seeded
        // by id 1); each must equal its solo solve from that stream.
        for (j, r) in results.iter().enumerate() {
            assert!(r.error.is_none());
            assert_eq!(r.batch_size, 4);
            let mut stream = Rng::seed_from(batch_stream_seed(seed, 1));
            let mut s = Solver::for_backend_tuned(
                Backend::Prism5,
                MatFnTask::InvSqrt,
                40,
                None,
                Some(8),
            )
            .unwrap();
            let out = s.solve(&inputs[j], &mut stream);
            assert_eq!(r.result, out.primary, "job {j}: poisoned peer changed result bits");
        }
    }

    #[test]
    fn non_finite_eps_reaching_a_worker_yields_an_error_result() {
        // eps = NaN slips past the matrix gate (the matrix itself is
        // finite) and poisons the worker-side damping: the job must come
        // back as exactly one error result — zero matrix, 0 iters, counted
        // as rejected not done — without corrupting its batch peer.
        let mut rng = Rng::seed_from(23);
        let w = randmat::logspace(1e-2, 1.0, 8);
        let good = randmat::sym_with_spectrum(&mut rng, 8, &w);
        let seed = 44;
        let svc = start(cfg(1, 2), Backend::Prism5, seed);
        let poisoned_id =
            svc.submit(0, JobKind::InvSqrt { eps: f64::NAN }, good.clone()).unwrap();
        let good_id = svc.submit(1, JobKind::InvSqrt { eps: 0.0 }, good.clone()).unwrap();
        let mut results = svc.drain().unwrap();
        assert_eq!(results.len(), 2, "one result per job, failed or not");
        results.sort_by_key(|r| r.id);
        let (bad_r, good_r) = (&results[0], &results[1]);
        assert_eq!(bad_r.id, poisoned_id);
        assert!(bad_r.error.as_deref().unwrap().contains("non-finite"));
        assert_eq!(bad_r.iters, 0);
        assert!(bad_r.final_residual.is_nan());
        assert_eq!(bad_r.result, Mat::zeros(8, 8));
        // The surviving member solves alone: its stream is seeded by the
        // lowest *surviving* id, and its result matches that solo solve.
        assert_eq!(good_r.id, good_id);
        assert!(good_r.error.is_none());
        let mut stream = Rng::seed_from(batch_stream_seed(seed, good_id));
        let mut s =
            Solver::for_backend_tuned(Backend::Prism5, MatFnTask::InvSqrt, 40, None, Some(8))
                .unwrap();
        let out = s.solve(&good, &mut stream);
        assert_eq!(good_r.result, out.primary);
        assert_eq!(svc.metrics.counter("service.jobs_rejected").get(), 1);
        assert_eq!(svc.metrics.counter("service.jobs_done").get(), 1);
    }

    #[test]
    fn invsqrt_service_tol_defaults_to_tight_per_task_value() {
        // Regression for PR 5: ServiceConfig's old blanket tol = 1e-7
        // silently loosened InvSqrt from its 1e-9 per-task default. With
        // tol: None the solvers must get 1e-9 back.
        let mut rng = Rng::seed_from(24);
        let w = randmat::logspace(1e-2, 1.0, 10);
        let a = randmat::sym_with_spectrum(&mut rng, 10, &w);
        let mut c = cfg(1, 1);
        c.max_iters = 100;
        let svc = start(c, Backend::Prism5, 42);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        let r = svc.drain().unwrap().remove(0);
        assert!(
            r.final_residual < 1e-9,
            "InvSqrt default must be the tight 1e-9, stopped at {}",
            r.final_residual
        );
    }

    #[test]
    fn mixed_eps_members_batch_together_and_match_solo_solves() {
        // eps is per-job (the route key fixes only kind and shape): members
        // with different damping must share one batch and still match their
        // solo solves on the damped matrices.
        let mut rng = Rng::seed_from(25);
        let w = randmat::logspace(1e-2, 1.0, 8);
        let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
        let epss = [0.0, 1e-3, 1e-2, 0.1];
        let seed = 55;
        let svc = start(cfg(1, 4), Backend::Prism5, seed);
        for (layer, &eps) in epss.iter().enumerate() {
            svc.submit(layer, JobKind::InvSqrt { eps }, a.clone()).unwrap();
        }
        let mut results = svc.drain().unwrap();
        results.sort_by_key(|r| r.layer);
        assert_eq!(results.len(), 4);
        assert!(results.iter().all(|r| r.batch_size == 4));
        for (j, r) in results.iter().enumerate() {
            let mut damped = a.clone();
            if epss[j] != 0.0 {
                damped.add_diag(epss[j]);
            }
            let mut stream = Rng::seed_from(batch_stream_seed(seed, 1));
            let mut s = Solver::for_backend_tuned(
                Backend::Prism5,
                MatFnTask::InvSqrt,
                40,
                None,
                Some(8),
            )
            .unwrap();
            let out = s.solve(&damped, &mut stream);
            assert_eq!(r.result, out.primary, "eps={} member diverged from solo", epss[j]);
        }
    }

    #[test]
    fn inflight_counts_exactly_across_dispatch_and_recv() {
        let mut rng = Rng::seed_from(26);
        let svc = start(cfg(1, 1), Backend::Eigen, 1);
        assert_eq!(svc.inflight(), 0);
        let w = randmat::logspace(0.1, 1.0, 6);
        for layer in 0..3 {
            let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        // max_batch = 1 dispatches each submission immediately.
        assert_eq!(svc.inflight(), 3);
        let _ = svc.recv().unwrap();
        assert_eq!(svc.inflight(), 2);
        let _ = svc.recv().unwrap();
        let _ = svc.recv().unwrap();
        assert_eq!(svc.inflight(), 0);
    }

    #[test]
    fn mixed_precision_service_solves_accurately() {
        // service.precision = mixed reaches the worker solvers: results
        // differ bit-wise from f64 (different arithmetic) but meet the same
        // per-task tolerance thanks to the f64 guard + cleanup iteration.
        let mut rng = Rng::seed_from(27);
        let w = randmat::logspace(1e-2, 1.0, 8);
        let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
        let run = |precision: Precision| {
            let mut c = cfg(1, 1);
            c.max_iters = 100;
            c.precision = precision;
            let svc = start(c, Backend::Prism5, 42);
            svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
            svc.drain().unwrap().remove(0)
        };
        let full = run(Precision::F64);
        let mixed = run(Precision::Mixed);
        assert!(full.final_residual < 1e-9);
        assert!(
            mixed.final_residual < 1e-9,
            "mixed InvSqrt must still reach the 1e-9 default, got {}",
            mixed.final_residual
        );
        assert_ne!(
            full.result, mixed.result,
            "mixed precision should change low-order bits"
        );
        assert!(full.result.sub(&mixed.result).max_abs() < 1e-6);
    }

    #[test]
    fn metrics_populated() {
        let mut rng = Rng::seed_from(5);
        let svc = start(cfg(1, 1), Backend::Prism5, 3);
        let w = randmat::logspace(0.1, 1.0, 6);
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        let _ = svc.drain().unwrap();
        let rep = svc.report();
        assert!(rep.contains("service.jobs_done"));
        assert!(rep.contains("service.latency_s"));
    }

    #[test]
    fn robustness_counters_registered_eagerly() {
        // A clean run must still *print* the fault-path counters (as
        // explicit zeros) — the CI grep-gates depend on the names always
        // appearing in report() output.
        let svc = start(cfg(1, 1), Backend::Prism5, 3);
        let rep = svc.report();
        for name in [
            "service.worker_panics",
            "service.worker_restarts",
            "service.jobs_escalated",
            "service.jobs_expired",
            "service.jobs_cancelled",
            "service.jobs_backpressured",
            "service.jobs_failed",
            "service.bucket_flush_full",
            "service.bucket_flush_linger",
            "service.workspace_allocs",
            "service.batch_occupancy",
        ] {
            assert!(rep.contains(name), "report() must always show {name}:\n{rep}");
        }
    }

    #[test]
    fn start_rejects_out_of_range_config_with_typed_error() {
        let mut c = cfg(1, 1);
        c.queue_cap = 0;
        match Service::start(c, Backend::Prism5, 1) {
            Err(Error::Config(m)) => assert!(m.contains("queue_cap"), "{m}"),
            Err(other) => panic!("queue_cap = 0 must be Error::Config, got {other:?}"),
            Ok(_) => panic!("queue_cap = 0 must be rejected"),
        }
        let mut c = cfg(1, 1);
        c.solver_cache_cap = 0;
        match Service::start(c, Backend::Prism5, 1) {
            Err(Error::Config(m)) => assert!(m.contains("solver_cache_cap"), "{m}"),
            Err(other) => panic!("solver_cache_cap = 0 must be Error::Config, got {other:?}"),
            Ok(_) => panic!("solver_cache_cap = 0 must be rejected"),
        }
        let mut c = cfg(1, 1);
        c.faults = Some("explode:now=1".into());
        match Service::start(c, Backend::Prism5, 1) {
            Err(Error::Config(m)) => assert!(m.contains("explode"), "{m}"),
            Err(other) => panic!("malformed fault spec must be Error::Config, got {other:?}"),
            Ok(_) => panic!("a malformed fault spec must be rejected"),
        }
    }

    #[test]
    fn try_submit_backpressure_is_typed_and_recoverable() {
        let mut rng = Rng::seed_from(30);
        let mut c = cfg(1, 8);
        // max_batch 8 > cap keeps everything router-pending: the capacity
        // check sees a deterministic `used` with no worker races.
        c.queue_cap = 2;
        c.admission = Admission::Reject;
        let svc = start(c, Backend::Prism5, 42);
        let w = randmat::logspace(0.1, 1.0, 6);
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        svc.try_submit(0, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
        svc.try_submit(1, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
        let err = svc.try_submit(2, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap_err();
        assert!(matches!(err, Error::Backpressure(_)), "{err}");
        assert!(err.to_string().contains("queue_cap"), "{err}");
        // `submit` honours cfg.admission = Reject the same way.
        assert!(svc.submit(2, JobKind::InvSqrt { eps: 0.0 }, a.clone()).is_err());
        assert_eq!(svc.metrics.counter("service.jobs_backpressured").get(), 2);
        // Refused submissions consumed no ids and queued nothing.
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 2);
        // Draining freed capacity: admission accepts again.
        svc.try_submit(3, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        assert_eq!(svc.drain().unwrap().len(), 1);
    }

    #[test]
    fn blocking_submit_waits_for_capacity_instead_of_failing() {
        let mut rng = Rng::seed_from(31);
        let mut c = cfg(1, 1);
        c.queue_cap = 1;
        let svc = Arc::new(start(c, Backend::Prism5, 42));
        let w = randmat::logspace(0.1, 1.0, 8);
        let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
        // The queue is at cap until the first result is fetched, so this
        // submit must block — then succeed once the receiver below drains.
        let submitter = {
            let svc = Arc::clone(&svc);
            let a = a.clone();
            std::thread::spawn(move || svc.submit(1, JobKind::InvSqrt { eps: 0.0 }, a))
        };
        let mut got = Vec::new();
        got.push(svc.recv().unwrap());
        submitter.join().expect("submitter thread").unwrap();
        got.push(svc.recv().unwrap());
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|r| r.error.is_none()));
    }

    #[test]
    fn expired_deadline_yields_typed_error_result() {
        let mut rng = Rng::seed_from(32);
        let svc = start(cfg(1, 1), Backend::Prism5, 42);
        let w = randmat::logspace(0.1, 1.0, 6);
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        let id = svc
            .submit_with_deadline(0, JobKind::InvSqrt { eps: 0.0 }, a, Duration::ZERO)
            .unwrap();
        let results = svc.drain().unwrap();
        assert_eq!(results.len(), 1, "an expired job still yields its one result");
        let r = &results[0];
        assert_eq!(r.id, id);
        assert!(r.error.as_deref().unwrap().contains("deadline"), "{:?}", r.error);
        assert_eq!(r.iters, 0);
        assert!(r.final_residual.is_nan());
        assert_eq!(svc.metrics.counter("service.jobs_expired").get(), 1);
        assert_eq!(svc.metrics.counter("service.jobs_done").get(), 0);
    }

    #[test]
    fn cancel_marks_pending_job_and_prunes_on_fetch() {
        let mut rng = Rng::seed_from(33);
        // max_batch 8: submissions stay router-pending until drain flushes,
        // so the cancel provably lands before a worker sees the job.
        let svc = start(cfg(1, 8), Backend::Prism5, 42);
        let w = randmat::logspace(0.1, 1.0, 6);
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        let keep = svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
        let dead = svc.submit(1, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        assert!(svc.cancel(dead));
        assert!(!svc.cancel(99), "unknown ids are not cancellable");
        let mut results = svc.drain().unwrap();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 2);
        assert!(results[0].error.is_none());
        assert_eq!(results[0].id, keep);
        assert!(
            results[1].error.as_deref().unwrap().contains("cancelled"),
            "{:?}",
            results[1].error
        );
        assert_eq!(svc.metrics.counter("service.jobs_cancelled").get(), 1);
        // The mark was consumed with the result: nothing lingers to kill a
        // future job that happens to reuse the id space.
        assert!(lock_or_recover(&svc.cancelled).is_empty());
    }

    #[test]
    fn recv_timeout_times_out_cleanly_when_idle() {
        let svc = start(cfg(1, 1), Backend::Prism5, 42);
        let sw = crate::util::Stopwatch::start();
        let got = svc.recv_timeout(Duration::from_millis(20)).unwrap();
        assert!(got.is_none(), "no job was submitted — nothing to receive");
        assert!(sw.elapsed_s() < 5.0, "recv_timeout must come back promptly");
    }

    #[test]
    fn drain_timeout_returns_everything_when_workers_are_healthy() {
        let mut rng = Rng::seed_from(34);
        let svc = start(cfg(2, 2), Backend::Prism5, 42);
        let w = randmat::logspace(0.1, 1.0, 8);
        for layer in 0..4 {
            let a = randmat::sym_with_spectrum(&mut rng, 8, &w);
            svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        }
        let results = svc.drain_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(results.len(), 4);
    }

    #[test]
    fn mixed_shape_burst_bit_identical_across_workers_and_linger() {
        // Tentpole contract under the bucketed scheduler: a 32-job
        // mixed-shape burst is bit-identical across worker counts and
        // linger settings, and every job matches a sequential solve from a
        // clone of its bucket-chunk's RNG stream.
        let mut rng = Rng::seed_from(40);
        let sizes = [5usize, 6, 7, 8];
        let inputs: Vec<Mat> = (0..32)
            .map(|j| {
                let n = sizes[j % sizes.len()];
                let w = randmat::logspace(1e-2, 1.0, n);
                randmat::sym_with_spectrum(&mut rng, n, &w)
            })
            .collect();
        let seed = 91;
        let run = |workers: usize, linger: Option<Duration>| -> Vec<Mat> {
            let mut c = cfg(workers, 4);
            c.linger = linger;
            let svc = start(c, Backend::Prism5, seed);
            for (layer, a) in inputs.iter().enumerate() {
                svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
            }
            let mut rs = svc.drain().unwrap();
            assert!(rs.iter().all(|r| r.error.is_none()));
            rs.sort_by_key(|r| r.layer);
            rs.into_iter().map(|r| r.result).collect()
        };
        let base = run(1, None);
        assert_eq!(base.len(), 32);
        let slow = Some(Duration::from_secs(30)); // never ripens mid-burst
        for (what, other) in [
            ("4 workers", run(4, None)),
            ("linger on", run(1, slow)),
            ("4 workers + linger", run(4, slow)),
        ] {
            for j in 0..32 {
                assert_eq!(base[j], other[j], "job {j}: {what} changed result bits");
            }
        }
        // Sequential reference. Submission round-robins the 4 shapes, so
        // shape bucket g holds ids {g+1, g+5, ...}; with max_batch = 4 the
        // bucket's k-th cut is seeded by its (4k)-th member — id g+16k+1.
        for (j, a) in inputs.iter().enumerate() {
            let (g, p) = (j % 4, j / 4);
            let first_id = (g + 16 * (p / 4) + 1) as u64;
            let mut stream = Rng::seed_from(batch_stream_seed(seed, first_id));
            let mut s = Solver::for_backend_tuned(
                Backend::Prism5,
                MatFnTask::InvSqrt,
                40,
                None,
                Some(8),
            )
            .unwrap();
            let out = s.solve(a, &mut stream);
            assert_eq!(base[j], out.primary, "job {j}: bucketed batch != sequential solve");
        }
    }

    #[test]
    fn lingering_singleton_dispatches_without_flush() {
        // Starvation regression: a rare-shape singleton must dispatch once
        // its linger deadline passes — no max_batch peers, no explicit
        // flush — and be attributed to the linger cut path.
        let mut rng = Rng::seed_from(41);
        let mut c = cfg(1, 8);
        c.linger = Some(Duration::from_millis(50));
        let svc = start(c, Backend::Prism5, 42);
        let w = randmat::logspace(0.1, 1.0, 6);
        let a = randmat::sym_with_spectrum(&mut rng, 6, &w);
        let t0 = Instant::now();
        svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, a).unwrap();
        let r = svc
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .expect("the linger cut must dispatch the singleton");
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.batch_size, 1);
        let waited = t0.elapsed();
        assert!(
            waited >= Duration::from_millis(50),
            "a bucket must not be cut before its linger deadline (waited {waited:?})"
        );
        assert_eq!(svc.metrics.counter("service.bucket_flush_linger").get(), 1);
        assert_eq!(svc.metrics.counter("service.bucket_flush_full").get(), 0);
    }

    #[test]
    fn cancelled_job_neither_holds_bucket_nor_perturbs_stream_seed() {
        // Satellite contract: cancelling a bucket-pending job removes it
        // immediately — its result is synthesized on the spot, and the
        // survivors batch exactly as if it had never been admitted past id
        // assignment: their stream seed is the lowest *surviving* id.
        let mut rng = Rng::seed_from(43);
        let w = randmat::logspace(1e-2, 1.0, 8);
        let inputs: Vec<Mat> =
            (0..3).map(|_| randmat::sym_with_spectrum(&mut rng, 8, &w)).collect();
        let seed = 77;
        let svc = start(cfg(1, 2), Backend::Prism5, seed);
        let dead = svc.submit(0, JobKind::InvSqrt { eps: 0.0 }, inputs[0].clone()).unwrap();
        assert!(svc.cancel(dead));
        // The synthesized result is available without any flush: the
        // cancelled job cannot hold its bucket open.
        let r = svc.recv_timeout(Duration::from_secs(10)).unwrap().expect("synthesized");
        assert_eq!(r.id, dead);
        assert!(r.error.as_deref().unwrap().contains("cancelled"), "{:?}", r.error);
        assert_eq!(svc.metrics.counter("service.jobs_cancelled").get(), 1);
        // Survivors fill the next full cut: ids 2 and 3, seeded by id 2.
        let id2 = svc.submit(1, JobKind::InvSqrt { eps: 0.0 }, inputs[1].clone()).unwrap();
        let _id3 = svc.submit(2, JobKind::InvSqrt { eps: 0.0 }, inputs[2].clone()).unwrap();
        let mut results = svc.drain().unwrap();
        results.sort_by_key(|r| r.id);
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|r| r.error.is_none() && r.batch_size == 2));
        for (r, a) in results.iter().zip(&inputs[1..]) {
            let mut stream = Rng::seed_from(batch_stream_seed(seed, id2));
            let mut s = Solver::for_backend_tuned(
                Backend::Prism5,
                MatFnTask::InvSqrt,
                40,
                None,
                Some(8),
            )
            .unwrap();
            let out = s.solve(a, &mut stream);
            assert_eq!(r.result, out.primary, "job {}: cancel perturbed the stream", r.id);
        }
    }

    #[test]
    fn snapshot_restore_round_trip_prewarms_solver_caches() {
        // Tentpole leg 2: shutdown writes the warm routes through
        // runtime::manifest; a restarted service prewarms them at worker
        // spawn, so the first post-restore batch performs zero workspace
        // allocations and the results stay bit-identical to the cold run.
        let mut rng = Rng::seed_from(42);
        let inputs: Vec<Mat> = (0..2)
            .map(|_| {
                let w = randmat::logspace(1e-2, 1.0, 8);
                randmat::sym_with_spectrum(&mut rng, 8, &w)
            })
            .collect();
        let path = std::env::temp_dir()
            .join(format!("prism_service_snap_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let snap = path.to_string_lossy().into_owned();
        let run = || {
            let mut c = cfg(1, 2);
            c.cache_snapshot = Some(snap.clone());
            let svc = start(c, Backend::Prism5, 42);
            for (layer, a) in inputs.iter().enumerate() {
                svc.submit(layer, JobKind::InvSqrt { eps: 0.0 }, a.clone()).unwrap();
            }
            let mut rs = svc.drain().unwrap();
            assert!(rs.iter().all(|r| r.error.is_none()));
            rs.sort_by_key(|r| r.layer);
            let allocs = svc.metrics.counter("service.workspace_allocs").get();
            (rs.into_iter().map(|r| r.result).collect::<Vec<_>>(), allocs)
        };
        let (cold, cold_allocs) = run();
        assert!(cold_allocs > 0, "a cold route must grow its workspace");
        assert!(path.exists(), "drop must write the snapshot");
        let manifest = Manifest::load(&path).unwrap();
        assert!(
            manifest.get("route_0_8x8").is_some(),
            "the 8x8 InvSqrt route must be recorded"
        );
        assert!(manifest.get("engine").is_some(), "engine tuning rides along");
        let (warm, warm_allocs) = run();
        assert_eq!(
            warm_allocs, 0,
            "the first post-restore batch must run the warm path allocation-free"
        );
        for j in 0..2 {
            assert_eq!(cold[j], warm[j], "job {j}: restore changed result bits");
        }
        let _ = std::fs::remove_file(&path);
    }
}
