//! Admission-control primitives extracted from the service so the loom
//! suite can model-check the *production* state machines, not test
//! doubles: [`InflightLedger`] is the dispatched/received accounting behind
//! [`super::service::Service::inflight`], and [`AdmissionGate`] is the
//! condvar blocking submitters park on when the queue cap is hit.
//!
//! Both are deliberately tiny. The correctness arguments they carry are
//! easy to state and exactly the kind a test can only sample but a model
//! checker can exhaust:
//!
//! * **Ledger exactness** — `inflight()` loads `received` *before*
//!   `dispatched`, so the difference never underflows and never reports
//!   zero while a result is still owed (the drain loop blocks on it).
//! * **No lost wakeup** — the gate's condvar waits on the *same* mutex the
//!   capacity check reads under (the service's pending-scheduler lock), and
//!   every capacity-freeing path notifies while holding that mutex. A
//!   notify therefore cannot land inside a submitter's check-to-park
//!   window: either it happens before the submitter locks and the re-check
//!   sees the freed capacity, or it happens after the wait has released the
//!   lock and the wakeup is delivered. `rust/tests/loom_coordinator.rs`
//!   checks this over every (bounded) interleaving; the 5 ms timeout the
//!   production wait keeps is an operational backstop, not a correctness
//!   crutch, and the model deliberately treats it as an untimed wait.

use crate::runtime::sync::atomic::{AtomicU64, Ordering};
use crate::runtime::sync::{Condvar, MutexGuard, PoisonError};
use std::time::Duration;

/// Exact dispatched/received accounting. `dispatched` is only advanced by
/// the service handle and its linger flusher (each synthesized
/// cancellation/expiry result counts as one dispatch), never by workers, so
/// `dispatched − received` is precisely the number of results still owed
/// and a drain loop can block on it race-free: every dispatched job sends
/// exactly one result.
#[derive(Debug, Default)]
pub struct InflightLedger {
    dispatched: AtomicU64,
    received: AtomicU64,
}

impl InflightLedger {
    pub const fn new() -> InflightLedger {
        InflightLedger { dispatched: AtomicU64::new(0), received: AtomicU64::new(0) }
    }

    /// Count `n` jobs handed to the workers (or synthesized on their
    /// behalf). Always advanced *before* the jobs/results are sent, so
    /// [`InflightLedger::inflight`] never undercounts what is owed.
    pub fn note_dispatched(&self, n: u64) {
        self.dispatched.fetch_add(n, Ordering::SeqCst);
    }

    /// Count one result taken off the completion channel.
    pub fn note_received(&self) {
        self.received.fetch_add(1, Ordering::SeqCst);
    }

    /// Results still owed (dispatched − received).
    ///
    /// Load order is what makes this exact with no underflow clamp:
    /// `received` is read FIRST. A result can only be received after its
    /// job was dispatched, so `received ≤ dispatched` holds at the moment
    /// of the first load, and `dispatched` only grows between the two loads
    /// — hence `d ≥ r` always. (Reading `dispatched` first admitted a race:
    /// a dispatch + recv on other threads between the loads made `r` exceed
    /// the stale `d`, and a `saturating_sub` silently reported 0 in-flight
    /// while a result was still owed.)
    pub fn inflight(&self) -> usize {
        let r = self.received.load(Ordering::SeqCst);
        let d = self.dispatched.load(Ordering::SeqCst);
        debug_assert!(
            d >= r,
            "service: {r} results received for {d} dispatched jobs — \
             the one-result-per-job invariant is broken"
        );
        (d - r) as usize
    }
}

/// The condvar blocking submitters park on when the admission cap is hit.
///
/// The gate owns no lock: callers park on the guard of the mutex their
/// capacity check read under, and capacity-freeing paths notify while
/// holding that same mutex — the monitor discipline whose no-lost-wakeup
/// property the module docs spell out.
#[derive(Debug, Default)]
pub struct AdmissionGate {
    cv: Condvar,
}

impl AdmissionGate {
    pub const fn new() -> AdmissionGate {
        AdmissionGate { cv: Condvar::new() }
    }

    /// Atomically release `guard` and wait for a [`AdmissionGate::notify`]
    /// (or the backstop timeout), then re-acquire and return the guard.
    /// Poisoning is recovered, not propagated, matching
    /// [`crate::util::lock_or_recover`].
    pub fn park<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        backstop: Duration,
    ) -> MutexGuard<'a, T> {
        self.cv
            .wait_timeout(guard, backstop)
            .unwrap_or_else(PoisonError::into_inner)
            .0
    }

    /// Wake every parked submitter. Callers hold the mutex the waiters'
    /// capacity check reads under (see the module docs); waking all of them
    /// is deliberate — each re-checks capacity under that lock, so spurious
    /// wakeups cost a re-check, while `notify_one` to a waiter that loses
    /// the race would strand the rest.
    pub fn notify(&self) {
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sync::{Arc, Mutex};
    use crate::util::lock_or_recover;

    #[test]
    fn ledger_counts_are_exact() {
        let l = InflightLedger::new();
        assert_eq!(l.inflight(), 0);
        l.note_dispatched(3);
        assert_eq!(l.inflight(), 3);
        l.note_received();
        l.note_received();
        assert_eq!(l.inflight(), 1);
        l.note_dispatched(1);
        l.note_received();
        l.note_received();
        assert_eq!(l.inflight(), 0);
    }

    #[test]
    fn gate_park_returns_on_notify() {
        let shared: Arc<(Mutex<bool>, AdmissionGate)> =
            Arc::new((Mutex::new(false), AdmissionGate::new()));
        let waiter = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let (m, gate) = &*shared;
                let mut freed = lock_or_recover(m);
                while !*freed {
                    freed = gate.park(freed, Duration::from_millis(5));
                }
            })
        };
        {
            let (m, gate) = &*shared;
            let mut freed = lock_or_recover(m);
            *freed = true;
            gate.notify();
        }
        waiter.join().expect("waiter exits once capacity frees");
    }

    #[test]
    fn gate_park_backstop_times_out_without_a_notify() {
        let shared: (Mutex<()>, AdmissionGate) = (Mutex::new(()), AdmissionGate::new());
        let (m, gate) = &shared;
        // No notifier exists: the backstop alone must return the guard.
        let g = lock_or_recover(m);
        let _g = gate.park(g, Duration::from_millis(1));
    }
}
