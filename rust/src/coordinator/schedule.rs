//! Shape-bucketed batch scheduling for the preconditioner service.
//!
//! Pending jobs are routed into per-(task, shape, precision) buckets. A
//! bucket is cut into a batch when it reaches `max_batch` (the hot full-cut
//! path, still performed synchronously inside `submit`), when the service's
//! linger flusher finds its oldest member has waited past
//! [`crate::config::ServiceConfig::linger`] (so rare shapes never starve
//! behind busy routes), or when the caller forces dispatch (`flush`/`drain`/
//! drop). Jobs keep submission order inside their bucket, which is what
//! pins the batch-composition half of the service's bit-identity contract:
//! the batch a job rides — and hence the RNG stream seeded by the batch's
//! lowest id — is a pure function of the submission sequence and `max_batch`
//! (plus wall-clock linger cuts, which only ever *split* a bucket earlier,
//! never reorder members).
//!
//! The scheduler also supports surgical removal ([`BucketScheduler::remove`]
//! and [`BucketScheduler::prune_deadlines`]): a cancelled or expired job is
//! taken out of its bucket *immediately*, so it can neither hold a bucket
//! open past `linger` nor ride into a batch and perturb the surviving
//! members' stream seed — the survivors' lowest id after an early removal
//! is exactly the lowest id a worker-side prune would have produced.
//!
//! This is a plain data structure: no locks, no channels. The service owns
//! one behind its pending mutex and the linger flusher thread sweeps it.

use super::service::Job;
use crate::matfn::Precision;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Bucket identity: one batchable class of jobs. `task`/`rows`/`cols` come
/// from `JobKind::route_key`; `precision` is the service's (currently
/// service-wide) solver precision, carried explicitly so the batching
/// contract — only same-precision jobs share a lockstep solve — stays
/// visible in the key even if precision ever becomes per-job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketKey {
    pub task: u8,
    pub rows: usize,
    pub cols: usize,
    pub precision: u8,
}

// Bucket (and hence flush/drain dispatch) order: task, then shape, then
// precision.
impl Ord for BucketKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.task, self.rows, self.cols, self.precision).cmp(&(
            other.task,
            other.rows,
            other.cols,
            other.precision,
        ))
    }
}

impl PartialOrd for BucketKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

fn precision_tag(p: Precision) -> u8 {
    match p {
        Precision::F64 => 0,
        Precision::Mixed => 1,
    }
}

/// Per-(task, shape, precision) pending-job buckets with `max_batch` cuts.
/// Public (not just `pub(super)`) so the loom suite can drive the *real*
/// scheduler through its linger-cut and cancel races.
pub struct BucketScheduler {
    max_batch: usize,
    precision: u8,
    buckets: BTreeMap<BucketKey, Vec<Job>>,
}

impl BucketScheduler {
    pub fn new(max_batch: usize, precision: Precision) -> BucketScheduler {
        BucketScheduler {
            max_batch: max_batch.max(1),
            precision: precision_tag(precision),
            buckets: BTreeMap::new(),
        }
    }

    fn key_of(&self, job: &Job) -> BucketKey {
        let (task, rows, cols) = job.kind.route_key(job.matrix.shape());
        BucketKey { task, rows, cols, precision: self.precision }
    }

    /// Route `job` into its bucket. Returns the full batch when the push
    /// brings the bucket to `max_batch` — the caller dispatches it outside
    /// the pending lock, synchronously with the submission (full-bucket
    /// dispatch latency is part of the admission path's contract).
    pub fn push(&mut self, job: Job) -> Option<Vec<Job>> {
        let key = self.key_of(&job);
        let bucket = self.buckets.entry(key).or_default();
        bucket.push(job);
        if bucket.len() >= self.max_batch {
            Some(std::mem::take(bucket))
        } else {
            None
        }
    }

    /// Jobs currently held back (all buckets).
    pub fn pending(&self) -> usize {
        self.buckets.values().map(Vec::len).sum()
    }

    /// Cut every non-empty bucket, in key order (deterministic dispatch
    /// sequence for `flush`/`drain`/drop).
    pub fn take_all(&mut self) -> Vec<Vec<Job>> {
        std::mem::take(&mut self.buckets)
            .into_values()
            .filter(|b| !b.is_empty())
            .collect()
    }

    /// Cut the buckets whose *oldest* member has waited at least `linger`.
    /// Members keep submission order, so the oldest is always the front.
    pub fn take_over_linger(&mut self, now: Instant, linger: Duration) -> Vec<Vec<Job>> {
        let ripe: Vec<BucketKey> = self
            .buckets
            .iter()
            .filter(|(_, b)| {
                b.first()
                    .is_some_and(|j| now.saturating_duration_since(j.submitted) >= linger)
            })
            .map(|(k, _)| *k)
            .collect();
        ripe.iter().filter_map(|k| self.buckets.remove(k)).collect()
    }

    /// Remove the pending job with this id, preserving the order of the
    /// remaining members (the survivors' lowest id — the batch stream seed —
    /// must equal what a worker-side prune would have left). `None` when the
    /// id is not held back here (already dispatched, or never admitted).
    pub fn remove(&mut self, id: u64) -> Option<Job> {
        for bucket in self.buckets.values_mut() {
            if let Some(pos) = bucket.iter().position(|j| j.id == id) {
                return Some(bucket.remove(pos));
            }
        }
        None
    }

    /// Remove every pending job whose deadline has already passed. Expiry
    /// is detected here — while the job still sits in a bucket — instead of
    /// at dispatch time, so a dead job cannot keep a bucket's linger clock
    /// pinned to its own (stale) submission instant.
    pub fn prune_deadlines(&mut self, now: Instant) -> Vec<Job> {
        let mut dead = Vec::new();
        for bucket in self.buckets.values_mut() {
            let mut i = 0;
            while i < bucket.len() {
                if bucket[i].deadline.is_some_and(|d| d <= now) {
                    dead.push(bucket.remove(i));
                } else {
                    i += 1;
                }
            }
        }
        dead
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::JobKind;
    use crate::linalg::Mat;

    fn job(id: u64, n: usize, deadline: Option<Instant>) -> Job {
        Job {
            id,
            layer: id as usize,
            kind: JobKind::InvSqrt { eps: 0.0 },
            matrix: Mat::eye(n),
            submitted: Instant::now(),
            deadline,
        }
    }

    #[test]
    fn full_bucket_cuts_at_max_batch_in_submission_order() {
        let mut s = BucketScheduler::new(2, Precision::F64);
        assert!(s.push(job(1, 4, None)).is_none());
        assert!(s.push(job(2, 6, None)).is_none(), "different shape, different bucket");
        let batch = s.push(job(3, 4, None)).expect("4x4 bucket reached max_batch");
        assert_eq!(batch.iter().map(|j| j.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(s.pending(), 1, "the 6x6 singleton is still held");
    }

    #[test]
    fn take_all_drains_every_bucket_deterministically() {
        let mut s = BucketScheduler::new(8, Precision::F64);
        for (id, n) in [(1, 4), (2, 6), (3, 4), (4, 8)] {
            assert!(s.push(job(id, n, None)).is_none());
        }
        let batches = s.take_all();
        assert_eq!(batches.len(), 3);
        // Key order: 4x4 before 6x6 before 8x8.
        assert_eq!(batches[0].iter().map(|j| j.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(batches[1][0].id, 2);
        assert_eq!(batches[2][0].id, 4);
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn linger_cut_fires_only_past_the_deadline() {
        let mut s = BucketScheduler::new(8, Precision::F64);
        let t0 = Instant::now();
        assert!(s.push(job(1, 4, None)).is_none());
        // Not ripe yet at a 1-hour linger...
        assert!(s.take_over_linger(t0, Duration::from_secs(3600)).is_empty());
        assert_eq!(s.pending(), 1);
        // ...ripe once "now" is past submitted + linger.
        let later = t0 + Duration::from_secs(7200);
        let cut = s.take_over_linger(later, Duration::from_secs(3600));
        assert_eq!(cut.len(), 1);
        assert_eq!(cut[0][0].id, 1);
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn remove_preserves_survivor_order() {
        let mut s = BucketScheduler::new(8, Precision::F64);
        for id in 1..=4 {
            assert!(s.push(job(id, 4, None)).is_none());
        }
        let gone = s.remove(2).expect("id 2 is pending");
        assert_eq!(gone.id, 2);
        assert!(s.remove(2).is_none(), "a removed id is no longer pending");
        assert!(s.remove(99).is_none());
        let batches = s.take_all();
        assert_eq!(batches.len(), 1);
        // Survivors keep submission order; the lowest id (the stream seed)
        // is exactly what a worker-side prune would have left.
        assert_eq!(batches[0].iter().map(|j| j.id).collect::<Vec<_>>(), vec![1, 3, 4]);
    }

    #[test]
    fn prune_deadlines_removes_only_expired_jobs() {
        let mut s = BucketScheduler::new(8, Precision::F64);
        let past = Instant::now();
        assert!(s.push(job(1, 4, Some(past))).is_none());
        assert!(s.push(job(2, 4, None)).is_none());
        assert!(s.push(job(3, 4, Some(past + Duration::from_secs(3600)))).is_none());
        let dead = s.prune_deadlines(past + Duration::from_millis(1));
        assert_eq!(dead.iter().map(|j| j.id).collect::<Vec<_>>(), vec![1]);
        let batches = s.take_all();
        assert_eq!(batches[0].iter().map(|j| j.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn mixed_precision_buckets_carry_the_precision_tag() {
        let f64s = BucketScheduler::new(2, Precision::F64);
        let mixed = BucketScheduler::new(2, Precision::Mixed);
        let j = job(1, 4, None);
        let (kf, km) = (f64s.key_of(&j), mixed.key_of(&j));
        assert_eq!((kf.task, kf.rows, kf.cols), (km.task, km.rows, km.cols));
        assert_ne!(kf.precision, km.precision, "precision is part of the bucket identity");
    }
}
