//! Worker supervision for the preconditioner service: panic containment,
//! in-thread respawn, snapshot prewarming, pre-solve admission checks
//! (deadline / cancellation / poisoned input) and the retry-with-escalation
//! ladder.
//!
//! ## Supervision contract
//!
//! Each worker runs every batch inside [`std::panic::catch_unwind`]. A panic
//! — whether a library bug or a scripted
//! [`crate::runtime::faultinject::Fault::WorkerPanic`] — is converted into
//! one typed error [`JobResult`] per batch member that had not yet reported
//! (counted in `service.jobs_failed`, with `service.worker_panics`
//! incremented once per incident), and the worker then **respawns in
//! place**: it rebuilds a fresh [`SolverCache`] and observer tag cell
//! (`service.worker_restarts`) and keeps serving the same channels on the
//! same thread. No submitted job is ever lost and the service's
//! one-result-per-job accounting survives arbitrary panics.
//!
//! ## Escalation ladder
//!
//! A batch member whose solve fails ([`MatFnOutput::is_failure`]: divergence
//! or a non-finite iterate) is retried solo through a deterministic ladder —
//! each rung a fresh cold solver reading a clone of the batch's RNG stream:
//!
//! 1. **`f64`** — when the service runs `precision = mixed`, retry the same
//!    method in full f64 (the cheapest fix when the f32 iterate left the
//!    method's basin of attraction).
//! 2. **`damp(δ)`** — InvSqrt only: bump the diagonal by a deterministic
//!    δ = 1e-6·‖A‖_F/√n and retry at f64. This changes the problem to
//!    (A + δI)^{-1/2}, which the recorded fallback string makes explicit.
//! 3. **`eigen`** — the O(n³) eigendecomposition baseline: slow, but free
//!    of iteration-divergence failure modes.
//!
//! The traversed path is recorded in [`JobResult::fallback`] (e.g.
//! `"f64→damp(1.2e-6)→eigen"`) and `service.jobs_escalated` counts jobs
//! that entered the ladder. A job whose every rung fails still yields
//! exactly one result — zero matrix, typed error, `service.jobs_failed`.

use super::service::{batch_stream_seed, Job, JobKind, JobResult, ResidualEvent, WorkerMsg};
use crate::config::{Backend, ServiceConfig};
use crate::linalg::Mat;
use crate::matfn::{MatFnOutput, MatFnTask, Precision, Solver};
use crate::metrics::{Counter, Gauge, Histogram, Registry};
use crate::rng::Rng;
use crate::runtime::faultinject;
use crate::runtime::sync::mpsc::{Receiver, Sender};
use crate::runtime::sync::{Arc, Mutex};
use crate::util::{lock_or_recover, Stopwatch};
use std::collections::BTreeSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::thread::JoinHandle;
use std::time::Instant;

/// Per-worker LRU cache of persistent solvers keyed by (kind, shape) route.
/// A cached solver's workspace holds the grown batch panels — the cache is
/// what makes the steady state allocation-free — and the cap bounds memory
/// under shape-diverse traffic. Reported through the metrics registry:
/// counter `service.solver_cache_evictions`, gauge
/// `service.solver_cache_size` (last-touching worker wins).
struct SolverCache {
    cap: usize,
    tick: u64,
    /// (route key, solver, last-used tick); linear scans — caps are small.
    entries: Vec<((u8, usize, usize), Solver, u64)>,
    evictions: Arc<Counter>,
    size: Arc<Gauge>,
}

impl SolverCache {
    fn new(cap: usize, metrics: &Registry) -> SolverCache {
        SolverCache {
            cap,
            tick: 0,
            entries: Vec::new(),
            evictions: metrics.counter("service.solver_cache_evictions"),
            size: metrics.gauge("service.solver_cache_size"),
        }
    }

    fn get_or_insert(
        &mut self,
        key: (u8, usize, usize),
        make: impl FnOnce() -> Solver,
    ) -> &mut Solver {
        self.tick += 1;
        let tick = self.tick;
        if let Some(i) = self.entries.iter().position(|(k, _, _)| *k == key) {
            self.entries[i].2 = tick;
            return &mut self.entries[i].1;
        }
        if self.entries.len() >= self.cap {
            // cap >= 1 is enforced by `ServiceConfig::validate` at service
            // start, so a full cache is non-empty; stay defensive anyway —
            // a missing victim must not panic a worker.
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, _, used))| *used)
                .map(|(i, _)| i);
            if let Some(lru) = lru {
                self.entries.swap_remove(lru);
                self.evictions.inc();
            }
        }
        self.entries.push((key, make(), tick));
        self.size.set(self.entries.len() as i64);
        &mut self.entries.last_mut().expect("just pushed").1
    }
}

/// Everything a worker thread is born with: identity, channels, shared
/// state. Bundled so [`spawn_worker`]'s signature survives growth.
pub(super) struct WorkerSpec {
    /// Stable worker index (0-based), used by the panic-injection hook.
    pub index: usize,
    pub backend: Backend,
    pub seed: u64,
    pub rx: Arc<Mutex<Receiver<WorkerMsg>>>,
    pub res_tx: Sender<JobResult>,
    pub prog_tx: Sender<ResidualEvent>,
    pub metrics: Arc<Registry>,
    /// Ids marked by [`super::service::Service::cancel`]; a worker that
    /// finds a batch member here short-circuits it before solving.
    pub cancelled: Arc<Mutex<BTreeSet<u64>>>,
    /// Route keys restored from a warm-state snapshot: the worker
    /// pre-builds these solvers (and pre-sizes their batch workspaces) at
    /// spawn and after every panic-respawn, so a restored route's first
    /// real batch runs allocation-free. Empty on a cold start.
    pub prewarm: Arc<Vec<(u8, usize, usize)>>,
}

/// The solver-tuning subset of [`ServiceConfig`] a worker needs per batch.
#[derive(Clone, Copy)]
struct WorkerCfg {
    iters: usize,
    tol: Option<f64>,
    sketch_p: usize,
    cache_cap: usize,
    stream: bool,
    precision: Precision,
    /// Service batch width — how many members a prewarmed workspace must
    /// already hold for the first real full batch to allocate nothing.
    max_batch: usize,
}

/// Construct one route's persistent solver exactly as a live batch would:
/// tuning knobs threaded through, per-task default tolerances preserved
/// (`tol: None`), and — with streaming on — the one persistent observer
/// reading the worker's shared tag cell. Shared by the batch path and the
/// snapshot-prewarm path, so a restored solver streams exactly like a
/// cold-built one.
fn build_solver(
    backend: Backend,
    cfg: WorkerCfg,
    tags: &Arc<Mutex<Vec<(u64, usize)>>>,
    prog_tx: &Sender<ResidualEvent>,
    task: MatFnTask,
) -> Solver {
    // `tol` passes through as-is: `None` keeps the per-task defaults
    // (InvSqrt at 1e-9, polar at 1e-7) instead of flattening every task
    // onto one blanket tolerance.
    let mut s =
        Solver::for_backend_tuned(backend, task, cfg.iters, cfg.tol, Some(cfg.sketch_p))
            .expect("service backends always have polar/invsqrt forms");
    s.spec_mut().precision = cfg.precision;
    if cfg.stream {
        let tags = Arc::clone(tags);
        let prog_tx = prog_tx.clone();
        s.set_observer(Some(Box::new(move |ev| {
            let tag = lock_or_recover(&tags).get(ev.job).copied();
            if let Some((id, layer)) = tag {
                let _ = prog_tx.send(ResidualEvent {
                    id,
                    layer,
                    iter: ev.iter,
                    residual: ev.residual,
                });
            }
        })));
    }
    s
}

/// Spawn one supervised worker thread serving the shared job channel.
pub(super) fn spawn_worker(spec: WorkerSpec, cfg: &ServiceConfig) -> JoinHandle<()> {
    let wcfg = WorkerCfg {
        iters: cfg.max_iters,
        tol: cfg.tol,
        sketch_p: cfg.sketch_p,
        cache_cap: cfg.solver_cache_cap,
        stream: cfg.stream_residuals,
        precision: cfg.precision,
        max_batch: cfg.max_batch,
    };
    std::thread::spawn(move || {
        let mut worker = Worker::new(spec, wcfg);
        worker.prewarm();
        loop {
            let msg = { lock_or_recover(&worker.spec.rx).recv() };
            match msg {
                Ok(WorkerMsg::Batch(jobs)) => {
                    if !jobs.is_empty() {
                        worker.run_supervised(jobs);
                    }
                }
                Ok(WorkerMsg::Shutdown) | Err(_) => break,
            }
        }
    })
}

struct Worker {
    spec: WorkerSpec,
    cfg: WorkerCfg,
    /// Persistent solvers per (kind, shape) route, LRU-capped: same-route
    /// batches reuse the solver's workspace, so the steady-state
    /// preconditioner stream runs allocation-free.
    cache: SolverCache,
    /// (id, layer) of the current batch's members, read by the persistent
    /// streaming observers (refreshed per batch; the Vec's capacity is
    /// reused, so the warm path stays allocation-free with streaming on).
    tags: Arc<Mutex<Vec<(u64, usize)>>>,
    /// Jobs this worker has accepted for solving (1-based, survives
    /// restarts); drives the deterministic panic-injection hook.
    jobs_accepted: u64,
    done: Arc<Counter>,
    failed: Arc<Counter>,
    rejected: Arc<Counter>,
    escalated: Arc<Counter>,
    expired: Arc<Counter>,
    cancelled: Arc<Counter>,
    panics: Arc<Counter>,
    restarts: Arc<Counter>,
    /// Workspace growth observed across the cached solvers' batch solves —
    /// 0 on a warm (steady-state or snapshot-prewarmed) service.
    workspace_allocs: Arc<Counter>,
    batch_time: Arc<Histogram>,
    job_time: Arc<Histogram>,
}

impl Worker {
    fn new(spec: WorkerSpec, cfg: WorkerCfg) -> Worker {
        let m = Arc::clone(&spec.metrics);
        Worker {
            cache: SolverCache::new(cfg.cache_cap, &m),
            tags: Arc::new(Mutex::new(Vec::new())),
            jobs_accepted: 0,
            done: m.counter("service.jobs_done"),
            failed: m.counter("service.jobs_failed"),
            rejected: m.counter("service.jobs_rejected"),
            escalated: m.counter("service.jobs_escalated"),
            expired: m.counter("service.jobs_expired"),
            cancelled: m.counter("service.jobs_cancelled"),
            panics: m.counter("service.worker_panics"),
            restarts: m.counter("service.worker_restarts"),
            workspace_allocs: m.counter("service.workspace_allocs"),
            // Execution time is recorded twice since batches became one
            // solve call: `service.batch_exec_s` is the wall time of a whole
            // batch, `service.exec_s` keeps its historical per-job meaning
            // as the amortised share (batch wall / members) — comparable
            // against `service.latency_s` at any max_batch.
            batch_time: m.histogram("service.batch_exec_s"),
            job_time: m.histogram("service.exec_s"),
            spec,
            cfg,
        }
    }

    /// Run one batch under a panic boundary. On unwind, synthesize a typed
    /// error result for every member that had not reported yet, then
    /// respawn in place: fresh solver cache and tag cell, same thread.
    fn run_supervised(&mut self, jobs: Vec<Job>) {
        // Metadata snapshot: enough to synthesize an error result for any
        // member the batch panicked on before reporting it.
        let meta: Vec<(u64, usize, usize, usize, Instant)> = jobs
            .iter()
            .map(|j| (j.id, j.layer, j.matrix.rows(), j.matrix.cols(), j.submitted))
            .collect();
        // Ids whose (success or failure) result has been sent. Behind a
        // Mutex so a panic mid-insert cannot leave it unreadable.
        let reported: Mutex<BTreeSet<u64>> = Mutex::new(BTreeSet::new());
        let panicked =
            catch_unwind(AssertUnwindSafe(|| self.execute_batch(jobs, &reported))).is_err();
        if !panicked {
            return;
        }
        self.panics.inc();
        let reported = lock_or_recover(&reported);
        for (id, layer, rows, cols, submitted) in meta {
            if reported.contains(&id) {
                continue;
            }
            self.failed.inc();
            let _ = self.spec.res_tx.send(JobResult {
                id,
                layer,
                result: Mat::zeros(rows, cols),
                latency_s: submitted.elapsed().as_secs_f64(),
                batch_size: 1,
                iters: 0,
                final_residual: f64::NAN,
                fallback: None,
                error: Some(format!(
                    "job {id}: worker {} panicked mid-batch; worker restarted",
                    self.spec.index
                )),
            });
        }
        // Respawn in place: the unwound solver cache and tag cell may hold
        // arbitrary partial state, so both are rebuilt from scratch (and
        // the snapshot-restored routes prewarmed again — the respawned
        // worker should be as warm as the one that died).
        self.cache = SolverCache::new(self.cfg.cache_cap, &self.spec.metrics);
        self.tags = Arc::new(Mutex::new(Vec::new()));
        self.restarts.inc();
        self.prewarm();
    }

    /// Pre-build the snapshot-restored routes: construct each solver
    /// through the same path a live batch would (observer wiring included)
    /// and run one throwaway full-width batch of benign diagonal matrices
    /// through it, so the workspace panels are grown before the first real
    /// job arrives. The dummy solve reads a throwaway RNG stream; solver
    /// reuse is deterministic, so later results are bit-identical to a
    /// cold start's. Runs under its own unwind boundary — a stale snapshot
    /// is a performance hint, never something that may kill a worker.
    fn prewarm(&mut self) {
        if self.spec.prewarm.is_empty() {
            return;
        }
        let routes = Arc::clone(&self.spec.prewarm);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            for &(tag, rows, cols) in routes.iter() {
                let task = match tag {
                    0 => MatFnTask::InvSqrt,
                    1 => MatFnTask::Polar,
                    _ => MatFnTask::RectPolar,
                };
                let cfg = self.cfg;
                let backend = self.spec.backend;
                let prog_tx = self.spec.prog_tx.clone();
                let tags = Arc::clone(&self.tags);
                let solver = self
                    .cache
                    .get_or_insert((tag, rows, cols), || {
                        build_solver(backend, cfg, &tags, &prog_tx, task)
                    });
                // Identity-like inputs converge immediately for every task,
                // while still exercising the full batch-width workspace.
                let dummy: Vec<Mat> = (0..cfg.max_batch.max(1))
                    .map(|_| {
                        let mut m = Mat::zeros(rows, cols);
                        for i in 0..rows.min(cols) {
                            m[(i, i)] = 1.0;
                        }
                        m
                    })
                    .collect();
                let refs: Vec<&Mat> = dummy.iter().collect();
                let mut rng = Rng::seed_from(0);
                let _ = solver.solve_batch(&refs, &mut rng);
            }
        }));
    }

    /// Send the one-and-only error result for `job` and mark it reported.
    fn fail_job(&self, job: &Job, reported: &Mutex<BTreeSet<u64>>, why: String) {
        let _ = self.spec.res_tx.send(JobResult {
            id: job.id,
            layer: job.layer,
            result: Mat::zeros(job.matrix.rows(), job.matrix.cols()),
            latency_s: job.submitted.elapsed().as_secs_f64(),
            batch_size: 1,
            iters: 0,
            final_residual: f64::NAN,
            fallback: None,
            error: Some(why),
        });
        lock_or_recover(reported).insert(job.id);
    }

    fn execute_batch(&mut self, mut jobs: Vec<Job>, reported: &Mutex<BTreeSet<u64>>) {
        // Damp InvSqrt inputs in place (ε may differ per job; the route key
        // only fixes kind and shape).
        for job in jobs.iter_mut() {
            if let JobKind::InvSqrt { eps } = job.kind {
                if eps != 0.0 {
                    job.matrix.add_diag(eps);
                }
            }
        }
        // Pre-solve short-circuits. submit() refuses non-finite matrices,
        // but a non-finite eps poisons the damping above; deadlines may
        // have expired in the queue; ids may have been cancelled. Each
        // dead member sends exactly one typed error result — so the
        // one-result-per-job accounting holds — and the rest solve: a
        // dead member must never corrupt its batch peers. (When a dropped
        // job was the batch's first, the executed batch's RNG stream is
        // seeded by the lowest *surviving* id.)
        let now = Instant::now();
        let mut live = Vec::with_capacity(jobs.len());
        for job in jobs {
            if job.matrix.has_non_finite() {
                self.rejected.inc();
                let why = format!(
                    "job {}: non-finite matrix after damping ({:?})",
                    job.id, job.kind
                );
                self.fail_job(&job, reported, why);
            } else if job.deadline.is_some_and(|d| d <= now) {
                self.expired.inc();
                let why = format!("job {}: deadline expired before solving", job.id);
                self.fail_job(&job, reported, why);
            } else if lock_or_recover(&self.spec.cancelled).remove(&job.id) {
                self.cancelled.inc();
                let why = format!("job {}: cancelled before solving", job.id);
                self.fail_job(&job, reported, why);
            } else {
                live.push(job);
            }
        }
        if live.is_empty() {
            return;
        }
        let jobs = live;
        // Deterministic panic injection: count the jobs this worker accepts
        // for solving and unwind *before* any member reports, so the
        // supervisor's whole-batch recovery path is exercised.
        for _ in &jobs {
            self.jobs_accepted += 1;
            if faultinject::should_panic(self.spec.index, self.jobs_accepted) {
                panic!(
                    "faultinject: worker {} scripted to panic on its job #{}",
                    self.spec.index, self.jobs_accepted
                );
            }
        }
        let bsize = jobs.len();
        // The router groups by route key, so the whole batch shares one
        // (kind, shape) — one solver.
        let key = jobs[0].kind.route_key(jobs[0].matrix.shape());
        let first_id = jobs[0].id;
        let task = task_of(jobs[0].kind);
        let cfg = self.cfg;
        let backend = self.spec.backend;
        let prog_tx = self.spec.prog_tx.clone();
        let tags = Arc::clone(&self.tags);
        let solver = self
            .cache
            .get_or_insert(key, || build_solver(backend, cfg, &tags, &prog_tx, task));
        if cfg.stream {
            let mut t = lock_or_recover(&self.tags);
            t.clear();
            t.extend(jobs.iter().map(|j| (j.id, j.layer)));
        }
        let mut rng = Rng::seed_from(batch_stream_seed(self.spec.seed, first_id));
        let sw = Stopwatch::start();
        let allocs_before = solver.workspace_allocations();
        let outs = {
            let refs: Vec<&Mat> = jobs.iter().map(|j| &j.matrix).collect();
            solver.solve_batch(&refs, &mut rng)
        };
        let exec_s = sw.elapsed_s();
        // Workspace growth on the solve path: non-zero only while a route
        // warms up — the snapshot/prewarm round-trip pins this to 0 for a
        // restored service's first batch.
        let grown = solver.workspace_allocations().saturating_sub(allocs_before);
        self.workspace_allocs.add(grown as u64);
        self.batch_time.observe(exec_s);
        self.job_time.observe(exec_s / bsize as f64);
        for (job, out) in jobs.into_iter().zip(outs) {
            let latency_s = job.submitted.elapsed().as_secs_f64();
            if !out.is_failure() {
                self.done.inc();
                let _ = self.spec.res_tx.send(JobResult {
                    id: job.id,
                    layer: job.layer,
                    result: out.primary,
                    latency_s,
                    batch_size: bsize,
                    iters: out.log.iters(),
                    final_residual: out.log.final_residual(),
                    fallback: None,
                    error: None,
                });
            } else {
                self.escalated.inc();
                let (rescue, path) = self.escalate(&job, first_id);
                match rescue {
                    Some(ok) => {
                        self.done.inc();
                        let _ = self.spec.res_tx.send(JobResult {
                            id: job.id,
                            layer: job.layer,
                            result: ok.primary,
                            latency_s: job.submitted.elapsed().as_secs_f64(),
                            batch_size: bsize,
                            iters: ok.log.iters(),
                            final_residual: ok.log.final_residual(),
                            fallback: Some(path),
                            error: None,
                        });
                    }
                    None => {
                        self.failed.inc();
                        let _ = self.spec.res_tx.send(JobResult {
                            id: job.id,
                            layer: job.layer,
                            result: Mat::zeros(job.matrix.rows(), job.matrix.cols()),
                            latency_s: job.submitted.elapsed().as_secs_f64(),
                            batch_size: bsize,
                            iters: out.log.iters(),
                            final_residual: out.log.final_residual(),
                            fallback: Some(path),
                            error: Some(format!(
                                "job {}: solve diverged and every escalation failed",
                                job.id
                            )),
                        });
                    }
                }
            }
            lock_or_recover(reported).insert(job.id);
        }
    }

    /// The escalation ladder for one failed batch member (module docs).
    /// Returns the rescuing output (if any rung succeeded) and the
    /// traversed path, `"→"`-joined.
    fn escalate(&self, job: &Job, first_id: u64) -> (Option<MatFnOutput>, String) {
        let task = task_of(job.kind);
        let mut path: Vec<String> = Vec::new();
        if self.cfg.precision == Precision::Mixed {
            path.push("f64".to_string());
            if let Some(out) = self.retry(task, &job.matrix, first_id, self.spec.backend) {
                return (Some(out), path.join("→"));
            }
        }
        if matches!(job.kind, JobKind::InvSqrt { .. }) {
            let n = job.matrix.rows().max(1);
            let bump = 1e-6 * job.matrix.fro_norm() / (n as f64).sqrt();
            if bump.is_finite() && bump > 0.0 {
                path.push(format!("damp({bump:.1e})"));
                let mut damped = job.matrix.clone();
                damped.add_diag(bump);
                if let Some(out) = self.retry(task, &damped, first_id, self.spec.backend) {
                    return (Some(out), path.join("→"));
                }
            }
        }
        path.push("eigen".to_string());
        let out = self.retry(task, &job.matrix, first_id, Backend::Eigen);
        (out, path.join("→"))
    }

    /// One escalation rung: a fresh cold solver at full f64, reading a
    /// clone of the failed batch's RNG stream. `None` when the rung itself
    /// fails (unsupported form, divergence, non-finite output).
    fn retry(
        &self,
        task: MatFnTask,
        a: &Mat,
        first_id: u64,
        backend: Backend,
    ) -> Option<MatFnOutput> {
        let mut s = Solver::for_backend_tuned(
            backend,
            task,
            self.cfg.iters,
            self.cfg.tol,
            Some(self.cfg.sketch_p),
        )
        .ok()?;
        s.spec_mut().precision = Precision::F64;
        let mut rng = Rng::seed_from(batch_stream_seed(self.spec.seed, first_id));
        s.solve_checked(a, &mut rng).ok()
    }
}

fn task_of(kind: JobKind) -> MatFnTask {
    match kind {
        JobKind::InvSqrt { .. } => MatFnTask::InvSqrt,
        JobKind::Polar => MatFnTask::Polar,
        JobKind::RectPolar => MatFnTask::RectPolar,
    }
}
