//! The training driver: executes the AOT-compiled JAX `train_step` artifact
//! (loss + gradients) through PJRT and applies a Rust optimizer — the
//! end-to-end path of the Fig. 6 experiment with Python fully out of the
//! request loop.
//!
//! Artifact contract (written by `python/compile/aot.py`):
//! * `init_params`: `(seed: f32[]) → (param_0, ..., param_{P-1})`
//! * `train_step`: `(param_0..param_{P-1}, tokens_x: f32[B,T],
//!   tokens_y: f32[B,T]) → (loss: f32[], grad_0, ..., grad_{P-1})`
//!
//! Parameter tensors are at most rank-2 (the model reshapes heads
//! internally), so each maps onto one optimizer [`Param`].

use crate::nn::{Param, ParamKind};
use crate::optim::Optimizer;
use crate::runtime::{f32_to_mat, mat_to_f32, Executable, Runtime};
use crate::util::{Error, Result, Stopwatch};
use std::sync::Arc;

pub struct TrainDriver {
    step_exe: Arc<Executable>,
    pub params: Vec<Param>,
    /// (rows, cols) per param as fed to PJRT.
    shapes: Vec<(usize, usize)>,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub losses: Vec<f64>,
    pub step_times_s: Vec<f64>,
}

fn dims_of(shape: &[i64]) -> Result<(usize, usize)> {
    match shape.len() {
        0 => Ok((1, 1)),
        1 => Ok((1, shape[0] as usize)),
        2 => Ok((shape[0] as usize, shape[1] as usize)),
        _ => Err(Error::Runtime(format!(
            "param of rank {} unsupported (model must flatten)",
            shape.len()
        ))),
    }
}

impl TrainDriver {
    /// Load artifacts and initialise parameters on-device.
    pub fn new(rt: &Runtime, seed: f32) -> Result<TrainDriver> {
        let init_exe = rt.load("init_params")?;
        let step_exe = rt.load("train_step")?;
        let meta = &step_exe.entry.meta;
        let geti = |k: &str| -> Result<usize> {
            meta.get(k)
                .and_then(|v| v.as_int())
                .map(|x| x as usize)
                .ok_or_else(|| Error::Runtime(format!("train_step meta missing '{k}'")))
        };
        let (batch, seq_len, vocab) = (geti("batch")?, geti("seq_len")?, geti("vocab")?);

        // Initialise parameters by running the init artifact.
        let raw = init_exe.run_f32(&[&[seed]])?;
        let nparams = step_exe.entry.inputs.len() - 2; // minus tokens_x/y
        if raw.len() != nparams {
            return Err(Error::Runtime(format!(
                "init_params returned {} tensors, train_step expects {nparams}",
                raw.len()
            )));
        }
        let mut params = Vec::with_capacity(nparams);
        let mut shapes = Vec::with_capacity(nparams);
        for (i, buf) in raw.iter().enumerate() {
            let spec = &step_exe.entry.inputs[i];
            let (r, c) = dims_of(&spec.shape)?;
            let w = f32_to_mat(r, c, buf)?;
            let kind = if r > 1 && c > 1 { ParamKind::Matrix } else { ParamKind::Vector };
            let mut p = Param::matrix(&spec.name, w);
            p.kind = kind;
            params.push(p);
            shapes.push((r, c));
        }
        Ok(TrainDriver {
            step_exe,
            params,
            shapes,
            batch,
            seq_len,
            vocab,
            losses: Vec::new(),
            step_times_s: Vec::new(),
        })
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// One optimizer step on a token batch. `xs`/`ys` are `[batch][seq_len]`.
    pub fn step(
        &mut self,
        xs: &[Vec<u32>],
        ys: &[Vec<u32>],
        opt: &mut dyn Optimizer,
    ) -> Result<f64> {
        let sw = Stopwatch::start();
        if xs.len() != self.batch || ys.len() != self.batch {
            return Err(Error::Runtime(format!(
                "batch size {} != artifact batch {}",
                xs.len(),
                self.batch
            )));
        }
        // Flatten inputs.
        let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(self.params.len() + 2);
        for p in &self.params {
            bufs.push(mat_to_f32(&p.w));
        }
        let flat = |rows: &[Vec<u32>]| -> Vec<f32> {
            rows.iter().flat_map(|r| r.iter().map(|&t| t as f32)).collect()
        };
        bufs.push(flat(xs));
        bufs.push(flat(ys));
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let outs = self.step_exe.run_f32(&refs)?;
        if outs.len() != self.params.len() + 1 {
            return Err(Error::Runtime(format!(
                "train_step returned {} outputs, expected {}",
                outs.len(),
                self.params.len() + 1
            )));
        }
        let loss = outs[0][0] as f64;
        if !loss.is_finite() {
            return Err(Error::Numerical(format!("loss diverged: {loss}")));
        }
        // Write grads into the params and step the optimizer.
        for (i, g) in outs[1..].iter().enumerate() {
            let (r, c) = self.shapes[i];
            self.params[i].g = f32_to_mat(r, c, g)?;
        }
        {
            let mut refs: Vec<&mut Param> = self.params.iter_mut().collect();
            opt.step(&mut refs);
        }
        for p in self.params.iter_mut() {
            p.zero_grad();
        }
        self.losses.push(loss);
        self.step_times_s.push(sw.elapsed_s());
        Ok(loss)
    }

    /// Save a checkpoint of the current parameters (atomic write).
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        crate::nn::checkpoint::save(path, &self.params, self.losses.len() as u64)
    }

    /// Restore parameters from a checkpoint; returns the step it was taken
    /// at. Names and shapes must match the loaded artifact's parameters.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<u64> {
        let (saved, step) = crate::nn::checkpoint::load(path)?;
        crate::nn::checkpoint::restore_into(&mut self.params, &saved)?;
        Ok(step)
    }

    /// Loss on a batch without updating parameters.
    pub fn eval(&self, xs: &[Vec<u32>], ys: &[Vec<u32>]) -> Result<f64> {
        let mut bufs: Vec<Vec<f32>> = Vec::with_capacity(self.params.len() + 2);
        for p in &self.params {
            bufs.push(mat_to_f32(&p.w));
        }
        let flat = |rows: &[Vec<u32>]| -> Vec<f32> {
            rows.iter().flat_map(|r| r.iter().map(|&t| t as f32)).collect()
        };
        bufs.push(flat(xs));
        bufs.push(flat(ys));
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let outs = self.step_exe.run_f32(&refs)?;
        Ok(outs[0][0] as f64)
    }
}

// Integration tests live in rust/tests/train_integration.rs (they require
// `make artifacts`).
