//! Artifact manifest: the JSON contract between `python/compile/aot.py`
//! (writer) and the Rust runtime (reader).
//!
//! ```json
//! {
//!   "version": 1,
//!   "artifacts": [
//!     {"name": "polar_step_d2", "file": "polar_step_d2.hlo.txt",
//!      "inputs":  [{"name": "x", "shape": [256, 128], "dtype": "f32"}],
//!      "outputs": [{"name": "x_next", "shape": [256, 128], "dtype": "f32"}],
//!      "meta": {"alpha_lo": 0.375, "alpha_hi": 1.45}}
//!   ]
//! }
//! ```

use crate::configfmt::{parse_json, Value};
use crate::util::{Error, Result};
use std::path::Path;

/// One named tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: String,
}

/// One compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (hyper-parameters baked at lowering time).
    pub meta: std::collections::BTreeMap<String, Value>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: i64,
    pub entries: Vec<ArtifactEntry>,
}

fn tensor_specs(v: &Value, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = v
        .as_array()
        .ok_or_else(|| Error::Parse(format!("manifest: {what} must be an array")))?;
    arr.iter()
        .map(|t| {
            let name = t
                .get_path("name")
                .and_then(|x| x.as_str())
                .unwrap_or("unnamed")
                .to_string();
            let shape = t
                .get_path("shape")
                .and_then(|x| x.as_array())
                .ok_or_else(|| Error::Parse(format!("manifest: {what}.{name}: no shape")))?
                .iter()
                .map(|d| d.as_int().unwrap_or(0))
                .collect();
            let dtype = t
                .get_path("dtype")
                .and_then(|x| x.as_str())
                .unwrap_or("f32")
                .to_string();
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let v = parse_json(src)?;
        let version = v.get_path("version").and_then(|x| x.as_int()).unwrap_or(1);
        let arts = v
            .get_path("artifacts")
            .and_then(|x| x.as_array())
            .ok_or_else(|| Error::Parse("manifest: missing 'artifacts'".into()))?;
        let mut entries = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get_path("name")
                .and_then(|x| x.as_str())
                .ok_or_else(|| Error::Parse("manifest: artifact without name".into()))?
                .to_string();
            let file = a
                .get_path("file")
                .and_then(|x| x.as_str())
                .ok_or_else(|| Error::Parse(format!("manifest: {name}: no file")))?
                .to_string();
            let inputs = tensor_specs(
                a.get_path("inputs").unwrap_or(&Value::Array(vec![])),
                "inputs",
            )?;
            let outputs = tensor_specs(
                a.get_path("outputs").unwrap_or(&Value::Array(vec![])),
                "outputs",
            )?;
            let meta = a
                .get_path("meta")
                .and_then(|x| x.as_table())
                .cloned()
                .unwrap_or_default();
            entries.push(ArtifactEntry { name, file, inputs, outputs, meta });
        }
        Ok(Manifest { version, entries })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        Manifest::parse(&src)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "polar_step_d2", "file": "polar_step_d2.hlo.txt",
         "inputs":  [{"name": "x", "shape": [16, 8], "dtype": "f32"},
                     {"name": "alpha", "shape": [], "dtype": "f32"}],
         "outputs": [{"name": "x_next", "shape": [16, 8], "dtype": "f32"}],
         "meta": {"alpha_lo": 0.375, "alpha_hi": 1.45}},
        {"name": "train_step", "file": "train_step.hlo.txt",
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.entries.len(), 2);
        let e = m.get("polar_step_d2").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![16, 8]);
        assert_eq!(e.inputs[1].shape, Vec::<i64>::new());
        assert_eq!(e.outputs[0].name, "x_next");
        assert_eq!(e.meta.get("alpha_hi").unwrap().as_float(), Some(1.45));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"file": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }
}
