//! Artifact manifest: the JSON contract between `python/compile/aot.py`
//! (writer) and the Rust runtime (reader) — and, since the service grew
//! warm-state snapshots, a Rust-side **writer** too:
//! [`Manifest::save`]/[`Manifest::to_json`] serialize a manifest back into
//! the exact JSON shape [`Manifest::parse`] accepts, so the coordinator can
//! persist its warm solver-cache routes on shutdown and restore them at the
//! next start through the same artifact contract.
//!
//! ```json
//! {
//!   "version": 1,
//!   "artifacts": [
//!     {"name": "polar_step_d2", "file": "polar_step_d2.hlo.txt",
//!      "inputs":  [{"name": "x", "shape": [256, 128], "dtype": "f32"}],
//!      "outputs": [{"name": "x_next", "shape": [256, 128], "dtype": "f32"}],
//!      "meta": {"alpha_lo": 0.375, "alpha_hi": 1.45}}
//!   ]
//! }
//! ```

use crate::configfmt::{parse_json, Value};
use crate::util::{Error, Result};
use std::path::Path;

/// One named tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: String,
}

/// One compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (hyper-parameters baked at lowering time).
    pub meta: std::collections::BTreeMap<String, Value>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: i64,
    pub entries: Vec<ArtifactEntry>,
}

fn tensor_specs(v: &Value, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = v
        .as_array()
        .ok_or_else(|| Error::Parse(format!("manifest: {what} must be an array")))?;
    arr.iter()
        .map(|t| {
            let name = t
                .get_path("name")
                .and_then(|x| x.as_str())
                .unwrap_or("unnamed")
                .to_string();
            let shape = t
                .get_path("shape")
                .and_then(|x| x.as_array())
                .ok_or_else(|| Error::Parse(format!("manifest: {what}.{name}: no shape")))?
                .iter()
                .map(|d| d.as_int().unwrap_or(0))
                .collect();
            let dtype = t
                .get_path("dtype")
                .and_then(|x| x.as_str())
                .unwrap_or("f32")
                .to_string();
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let v = parse_json(src)?;
        let version = v.get_path("version").and_then(|x| x.as_int()).unwrap_or(1);
        let arts = v
            .get_path("artifacts")
            .and_then(|x| x.as_array())
            .ok_or_else(|| Error::Parse("manifest: missing 'artifacts'".into()))?;
        let mut entries = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get_path("name")
                .and_then(|x| x.as_str())
                .ok_or_else(|| Error::Parse("manifest: artifact without name".into()))?
                .to_string();
            let file = a
                .get_path("file")
                .and_then(|x| x.as_str())
                .ok_or_else(|| Error::Parse(format!("manifest: {name}: no file")))?
                .to_string();
            let inputs = tensor_specs(
                a.get_path("inputs").unwrap_or(&Value::Array(vec![])),
                "inputs",
            )?;
            let outputs = tensor_specs(
                a.get_path("outputs").unwrap_or(&Value::Array(vec![])),
                "outputs",
            )?;
            let meta = a
                .get_path("meta")
                .and_then(|x| x.as_table())
                .cloned()
                .unwrap_or_default();
            entries.push(ArtifactEntry { name, file, inputs, outputs, meta });
        }
        Ok(Manifest { version, entries })
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        Manifest::parse(&src)
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Build the [`Value`] tree `parse` reads — the writer half of the
    /// round-trip contract (`parse(to_json(m))` reproduces `m`).
    pub fn to_value(&self) -> Value {
        let tensor = |t: &TensorSpec| {
            let mut tv = std::collections::BTreeMap::new();
            tv.insert("name".to_string(), Value::Str(t.name.clone()));
            tv.insert(
                "shape".to_string(),
                Value::Array(t.shape.iter().map(|&d| Value::Int(d)).collect()),
            );
            tv.insert("dtype".to_string(), Value::Str(t.dtype.clone()));
            Value::Table(tv)
        };
        let arts: Vec<Value> = self
            .entries
            .iter()
            .map(|e| {
                let mut ev = std::collections::BTreeMap::new();
                ev.insert("name".to_string(), Value::Str(e.name.clone()));
                ev.insert("file".to_string(), Value::Str(e.file.clone()));
                ev.insert(
                    "inputs".to_string(),
                    Value::Array(e.inputs.iter().map(tensor).collect()),
                );
                ev.insert(
                    "outputs".to_string(),
                    Value::Array(e.outputs.iter().map(tensor).collect()),
                );
                ev.insert("meta".to_string(), Value::Table(e.meta.clone()));
                Value::Table(ev)
            })
            .collect();
        let mut root = std::collections::BTreeMap::new();
        root.insert("version".to_string(), Value::Int(self.version));
        root.insert("artifacts".to_string(), Value::Array(arts));
        Value::Table(root)
    }

    /// Serialize to the JSON `parse` accepts.
    pub fn to_json(&self) -> String {
        crate::configfmt::to_json(&self.to_value())
    }

    /// Write the manifest to `path` (atomic enough for single-writer use:
    /// one `fs::write`, no partial-update protocol).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| Error::Runtime(format!("write {}: {e}", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "polar_step_d2", "file": "polar_step_d2.hlo.txt",
         "inputs":  [{"name": "x", "shape": [16, 8], "dtype": "f32"},
                     {"name": "alpha", "shape": [], "dtype": "f32"}],
         "outputs": [{"name": "x_next", "shape": [16, 8], "dtype": "f32"}],
         "meta": {"alpha_lo": 0.375, "alpha_hi": 1.45}},
        {"name": "train_step", "file": "train_step.hlo.txt",
         "inputs": [], "outputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.entries.len(), 2);
        let e = m.get("polar_step_d2").unwrap();
        assert_eq!(e.inputs.len(), 2);
        assert_eq!(e.inputs[0].shape, vec![16, 8]);
        assert_eq!(e.inputs[1].shape, Vec::<i64>::new());
        assert_eq!(e.outputs[0].name, "x_next");
        assert_eq!(e.meta.get("alpha_hi").unwrap().as_float(), Some(1.45));
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"file": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }

    #[test]
    fn writer_round_trips_through_parse() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let back = Manifest::parse(&m.to_json()).expect("writer output must re-parse");
        assert_eq!(back.version, m.version);
        assert_eq!(back.entries.len(), m.entries.len());
        let (e0, b0) = (&m.entries[0], &back.entries[0]);
        assert_eq!(b0.name, e0.name);
        assert_eq!(b0.file, e0.file);
        assert_eq!(b0.inputs.len(), e0.inputs.len());
        assert_eq!(b0.inputs[0].shape, e0.inputs[0].shape);
        assert_eq!(b0.inputs[0].dtype, e0.inputs[0].dtype);
        assert_eq!(b0.meta, e0.meta);
    }

    #[test]
    fn save_and_load_round_trip() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let path = std::env::temp_dir()
            .join(format!("prism_manifest_rt_{}.json", std::process::id()));
        m.save(&path).expect("save");
        let back = Manifest::load(&path).expect("load");
        assert_eq!(back.entries.len(), m.entries.len());
        assert_eq!(back.get("train_step").unwrap().file, "train_step.hlo.txt");
        let _ = std::fs::remove_file(&path);
    }
}
