//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them from Rust. Python never runs on
//! this path — the artifacts are produced once by `make artifacts`.
//!
//! The interchange format is HLO **text**, not serialized `HloModuleProto`:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the bundled
//! xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly.
//!
//! The PJRT execution path needs a vendored `xla` crate that not every
//! build environment carries, so it is gated behind the off-by-default
//! `pjrt` cargo feature. Without the feature, [`Runtime`] and
//! [`Executable`] keep their full API surface — manifests load, shapes and
//! metadata are inspectable — but [`Runtime::load`] and
//! [`Executable::run_f32`] return a typed [`Error::Runtime`] explaining
//! that execution requires `--features pjrt`. The integration tests in
//! `rust/tests/runtime_integration.rs` self-skip when no artifacts are
//! present, so both build flavours stay green.

pub mod faultinject;
pub mod manifest;
pub mod sync;

use crate::linalg::Mat;
use crate::util::Result;

pub use manifest::{ArtifactEntry, Manifest};

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::{ArtifactEntry, Manifest};
    use crate::runtime::sync::Mutex;
    use crate::util::{lock_or_recover, Error, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A loaded, compiled artifact plus its metadata.
    pub struct Executable {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
        pub entry: ArtifactEntry,
    }

    /// PJRT client + executable cache keyed by artifact name.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, usize>>,
        loaded: Mutex<Vec<std::sync::Arc<Executable>>>,
    }

    impl Runtime {
        /// Open the artifacts directory (expects `manifest.json` inside).
        pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = Manifest::load(&dir.join("manifest.json"))?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("pjrt cpu client: {e}")))?;
            Ok(Runtime {
                client,
                dir,
                manifest,
                cache: Mutex::new(HashMap::new()),
                loaded: Mutex::new(Vec::new()),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load (or fetch cached) an executable by manifest name.
        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            {
                let cache = lock_or_recover(&self.cache);
                if let Some(&idx) = cache.get(name) {
                    return Ok(lock_or_recover(&self.loaded)[idx].clone());
                }
            }
            let entry = self
                .manifest
                .entries
                .iter()
                .find(|e| e.name == name)
                .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not in manifest")))?
                .clone();
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
            let arc = std::sync::Arc::new(Executable { name: name.to_string(), exe, entry });
            let mut loaded = lock_or_recover(&self.loaded);
            loaded.push(arc.clone());
            lock_or_recover(&self.cache).insert(name.to_string(), loaded.len() - 1);
            Ok(arc)
        }
    }

    impl Executable {
        /// Execute with f32 buffers; `inputs[i]` must match the manifest's
        /// i-th input shape. Returns the tuple elements as flat f32 vectors.
        pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            if inputs.len() != self.entry.inputs.len() {
                return Err(Error::Runtime(format!(
                    "{}: expected {} inputs, got {}",
                    self.name,
                    self.entry.inputs.len(),
                    inputs.len()
                )));
            }
            let mut lits = Vec::with_capacity(inputs.len());
            for (buf, spec) in inputs.iter().zip(&self.entry.inputs) {
                let expect: usize = spec.shape.iter().product::<i64>() as usize;
                if buf.len() != expect {
                    return Err(Error::Runtime(format!(
                        "{}: input '{}' expects {} elems, got {}",
                        self.name,
                        spec.name,
                        expect,
                        buf.len()
                    )));
                }
                let lit = xla::Literal::vec1(buf)
                    .reshape(&spec.shape)
                    .map_err(|e| Error::Runtime(format!("reshape input '{}': {e}", spec.name)))?;
                lits.push(lit);
            }
            let mut result = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| Error::Runtime(format!("execute {}: {e}", self.name)))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch {}: {e}", self.name)))?;
            // aot.py lowers with return_tuple=True.
            let elems = result
                .decompose_tuple()
                .map_err(|e| Error::Runtime(format!("untuple {}: {e}", self.name)))?;
            let mut out = Vec::with_capacity(elems.len());
            for (i, el) in elems.into_iter().enumerate() {
                out.push(
                    el.to_vec::<f32>()
                        .map_err(|e| Error::Runtime(format!("output {i} of {}: {e}", self.name)))?,
                );
            }
            Ok(out)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::{ArtifactEntry, Manifest};
    use crate::util::{Error, Result};
    use std::path::Path;

    fn no_pjrt(what: &str) -> Error {
        Error::Runtime(format!(
            "{what}: this build lacks the PJRT execution backend — rebuild with \
             `--features pjrt` (requires the vendored xla crate)"
        ))
    }

    /// Manifest-only stand-in for the PJRT executable: metadata is real,
    /// execution reports a typed error.
    pub struct Executable {
        pub name: String,
        pub entry: ArtifactEntry,
    }

    /// Manifest-only stand-in for the PJRT runtime: `open` still validates
    /// and loads `manifest.json` so `prism info` and artifact tooling work;
    /// only `load`/execution require the `pjrt` feature.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        /// Open the artifacts directory (expects `manifest.json` inside).
        pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
            let manifest = Manifest::load(&dir.as_ref().join("manifest.json"))?;
            Ok(Runtime { manifest })
        }

        pub fn platform(&self) -> String {
            "unavailable (built without the pjrt feature)".to_string()
        }

        /// Load an executable by manifest name: always a typed error here.
        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            // Check the manifest first so "unknown artifact" and "no
            // backend" stay distinguishable, matching the real runtime.
            self.manifest
                .entries
                .iter()
                .find(|e| e.name == name)
                .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not in manifest")))?;
            Err(no_pjrt(&format!("artifact '{name}'")))
        }
    }

    impl Executable {
        /// Execute with f32 buffers: always a typed error here.
        pub fn run_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
            Err(no_pjrt(&self.name))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, Runtime};

/// f64 `Mat` → f32 buffer (row-major).
pub fn mat_to_f32(m: &Mat) -> Vec<f32> {
    m.as_slice().iter().map(|&x| x as f32).collect()
}

/// f32 buffer → f64 `Mat`.
pub fn f32_to_mat(rows: usize, cols: usize, buf: &[f32]) -> Result<Mat> {
    Mat::from_vec(rows, cols, buf.iter().map(|&x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_f32_roundtrip() {
        let mut rng = crate::rng::Rng::seed_from(1);
        let m = Mat::gaussian(&mut rng, 3, 4, 1.0);
        let buf = mat_to_f32(&m);
        let back = f32_to_mat(3, 4, &buf).unwrap();
        assert!(m.sub(&back).max_abs() < 1e-6);
        assert!(f32_to_mat(2, 2, &buf).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_are_typed() {
        // Without artifacts on disk `open` is a Runtime error, not a panic.
        assert!(Runtime::open("/nonexistent/artifacts").is_err());
        let entry = ArtifactEntry {
            name: "train_step".into(),
            file: "train_step.hlo.txt".into(),
            inputs: Vec::new(),
            outputs: Vec::new(),
            meta: Default::default(),
        };
        let exe = Executable { name: "train_step".into(), entry };
        match exe.run_f32(&[]) {
            Err(crate::util::Error::Runtime(m)) => assert!(m.contains("pjrt")),
            other => panic!("want Runtime error, got {other:?}"),
        }
    }

    // PJRT-backed tests live in rust/tests/runtime_integration.rs — they
    // need `make artifacts` to have run first.
}
