//! Deterministic fault injection for the chaos suite.
//!
//! A [`FaultPlan`] is a small, seeded script of failures — "poison solve #4
//! with a NaN iterate", "panic worker 0 on its 9th job", "delay every
//! dispatch by 10 ms" — that the coordinator and the iteration recorder
//! consult at well-defined points. The plan is keyed off configuration
//! (`service.faults` in TOML, `--faults` on the CLI, `PALLAS_FAULTS` in the
//! environment) and is **inert by default**: with nothing installed, every
//! hook is a single relaxed atomic load and no counter advances, so the
//! production hot path pays essentially nothing for being injectable.
//!
//! Determinism contract: faults address *logical* event indices, not wall
//! clock. `nan` counts engine runs process-wide from [`install`] (every
//! [`crate::prism::driver::RunRecorder::start`] — including escalation
//! retries and eigen fallbacks — advances the count by one); `panic` counts
//! the jobs a given worker has accepted for solving (1-based); `delay` is a
//! fixed sleep before each dispatch. Under a single worker the event order
//! is the submission order, so a chaos test that pins `workers = 1` can
//! name the exact victim job.
//!
//! The state is process-global (the engines have no channel back to a
//! specific service), so concurrent tests that install plans must
//! serialize; `rust/tests/tier_chaos.rs` holds a suite-wide lock for this.

use crate::runtime::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::runtime::sync::Mutex;
use crate::util::{lock_or_recover, Error, Result};
use std::collections::BTreeMap;

/// One scripted failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Replace the residual of engine run number `solve` (0-based since
    /// [`install`]) at in-run iteration `iter` (0-based) with NaN, so the
    /// run takes the real divergence path: the engine breaks out and the
    /// iteration log reports `diverged`.
    NanIterate { solve: u64, iter: usize },
    /// Panic worker `worker` when it is about to solve the `job`-th job it
    /// has ever accepted (1-based per-worker count).
    WorkerPanic { worker: usize, job: u64 },
    /// Sleep this many milliseconds before every batch dispatch.
    DelayDispatch { ms: u64 },
}

/// A parsed fault script: `;`-separated clauses, each `kind:key=val,...`.
///
/// Grammar (whitespace around tokens is ignored):
///
/// ```text
/// nan:solve=<N>,iter=<K>    poison engine run N at iteration K
/// panic:worker=<W>,job=<J>  panic worker W on its J-th job (1-based)
/// delay:ms=<M>              sleep M ms before each dispatch
/// ```
///
/// Example: `nan:solve=4,iter=1;panic:worker=0,job=9;delay:ms=10`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

fn clause_err(clause: &str, why: &str) -> Error {
    Error::Config(format!("fault clause '{clause}': {why}"))
}

impl FaultPlan {
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (kind, rest) =
                clause.split_once(':').ok_or_else(|| clause_err(clause, "missing ':'"))?;
            let mut kv: BTreeMap<String, u64> = BTreeMap::new();
            for pair in rest.split(',').map(str::trim).filter(|p| !p.is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| clause_err(clause, "expected key=value pairs"))?;
                let n: u64 = v.trim().parse().map_err(|_| {
                    clause_err(clause, &format!("'{}' is not a non-negative integer", v.trim()))
                })?;
                kv.insert(k.trim().to_string(), n);
            }
            let mut take = |key: &str| -> Result<u64> {
                kv.remove(key).ok_or_else(|| clause_err(clause, &format!("missing '{key}='")))
            };
            let fault = match kind.trim() {
                "nan" => Fault::NanIterate { solve: take("solve")?, iter: take("iter")? as usize },
                "panic" => {
                    Fault::WorkerPanic { worker: take("worker")? as usize, job: take("job")? }
                }
                "delay" => Fault::DelayDispatch { ms: take("ms")? },
                other => {
                    return Err(clause_err(
                        clause,
                        &format!("unknown fault kind '{other}' (want nan | panic | delay)"),
                    ))
                }
            };
            if let Some(extra) = kv.keys().next() {
                return Err(clause_err(clause, &format!("unexpected key '{extra}='")));
            }
            faults.push(fault);
        }
        if faults.is_empty() {
            return Err(Error::Config(format!("fault spec '{spec}': no clauses")));
        }
        Ok(FaultPlan { faults })
    }
}

/// Fast-path gate: one relaxed load on every hook when nothing is installed.
static ACTIVE: AtomicBool = AtomicBool::new(false);
/// Engine runs observed since the last [`install`].
static SOLVES: AtomicU64 = AtomicU64::new(0);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

/// Install a plan and reset the solve counter. Replaces any previous plan.
pub fn install(plan: FaultPlan) {
    *lock_or_recover(&PLAN) = Some(plan);
    SOLVES.store(0, Ordering::SeqCst);
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Deactivate fault injection and drop the installed plan.
pub fn clear() {
    ACTIVE.store(false, Ordering::SeqCst);
    *lock_or_recover(&PLAN) = None;
}

/// Is a plan currently installed?
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// Hook for `RunRecorder::start`: count this engine run and return the
/// iteration index to poison with NaN, if this run is a scripted victim.
pub fn begin_solve() -> Option<usize> {
    if !active() {
        return None;
    }
    let idx = SOLVES.fetch_add(1, Ordering::SeqCst);
    let plan = lock_or_recover(&PLAN);
    plan.as_ref()?.faults.iter().find_map(|f| match f {
        Fault::NanIterate { solve, iter } if *solve == idx => Some(*iter),
        _ => None,
    })
}

/// Hook for the worker loop: should worker `worker` panic instead of
/// solving its `job_seq`-th accepted job (1-based)?
pub fn should_panic(worker: usize, job_seq: u64) -> bool {
    if !active() {
        return false;
    }
    match lock_or_recover(&PLAN).as_ref() {
        Some(p) => p.faults.iter().any(|f| match f {
            Fault::WorkerPanic { worker: w, job } => *w == worker && *job == job_seq,
            _ => false,
        }),
        None => false,
    }
}

/// Hook for `Service::dispatch`: how long to stall before sending, if at all.
pub fn dispatch_delay_ms() -> Option<u64> {
    if !active() {
        return None;
    }
    let plan = lock_or_recover(&PLAN);
    plan.as_ref()?.faults.iter().find_map(|f| match f {
        Fault::DelayDispatch { ms } => Some(*ms),
        _ => None,
    })
}

/// Parse a plan from the `PALLAS_FAULTS` environment variable, if set and
/// non-empty. Used by the `serve` CLI when no `--faults`/TOML spec is given.
pub fn plan_from_env() -> Result<Option<FaultPlan>> {
    match std::env::var("PALLAS_FAULTS") {
        Ok(s) if !s.trim().is_empty() => FaultPlan::parse(&s).map(Some),
        _ => Ok(None),
    }
}

// The install/hook behaviour mutates process-global state, so it is tested
// in `rust/tests/tier_chaos.rs` (its own process, suite-serialized); the
// tests here stay pure so they cannot perturb concurrently running lib
// tests that execute engines.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let plan = FaultPlan::parse("nan:solve=4,iter=1; panic:worker=0,job=9; delay:ms=10")
            .expect("spec should parse");
        assert_eq!(
            plan.faults,
            vec![
                Fault::NanIterate { solve: 4, iter: 1 },
                Fault::WorkerPanic { worker: 0, job: 9 },
                Fault::DelayDispatch { ms: 10 },
            ]
        );
    }

    #[test]
    fn parse_tolerates_whitespace_and_trailing_separator() {
        let plan = FaultPlan::parse(" delay: ms = 3 ;").unwrap();
        assert_eq!(plan.faults, vec![Fault::DelayDispatch { ms: 3 }]);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        let bad_specs = [
            "",
            "  ;  ",
            "nan",
            "nan:solve=1",
            "nan:solve=1,iter=2,x=3",
            "panic:worker=a,job=1",
            "panic:worker=-1,job=1",
            "explode:now=1",
            "delay:ms",
        ];
        for bad in bad_specs {
            let got = FaultPlan::parse(bad);
            assert!(
                matches!(got, Err(Error::Config(_))),
                "'{bad}' must be Error::Config, got {got:?}"
            );
        }
    }

    #[test]
    fn inert_by_default() {
        // No install has happened in this test binary unless a chaos test
        // ran first — and those live in a different binary. Every hook must
        // be a no-op.
        if !active() {
            assert_eq!(begin_solve(), None);
            assert!(!should_panic(0, 1));
            assert_eq!(dispatch_delay_ms(), None);
        }
    }
}
