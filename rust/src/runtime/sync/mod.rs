//! The crate's one gateway to `std::sync` for concurrency-reviewed modules.
//!
//! Every module that participates in the coordinator's determinism contract
//! (`coordinator/{service,gate,schedule,supervise}.rs`, `threads.rs`,
//! `metrics.rs`, `runtime/faultinject.rs`) imports its sync primitives from
//! here instead of `std::sync` — enforced by `cargo xtask lint` rule R4.
//!
//! * **Normal builds** (`cfg(not(loom))`): pure re-exports of `std::sync`.
//!   Zero cost, zero behavior change — the shim compiles away entirely.
//! * **Model-checking builds** (`RUSTFLAGS="--cfg loom"`): the same names
//!   resolve to the [`model`] module's scheduler-aware types, so the state
//!   machines behind the service's races (admission, linger cuts,
//!   cancel-vs-dispatch, panic-respawn) can be explored exhaustively by
//!   `rust/tests/loom_coordinator.rs`.
//!
//! The `cfg` name is `loom` after the crate that popularized the technique,
//! but the model checker itself is in-tree ([`model`]): this repository
//! builds fully offline with an empty `[dependencies]` table, so vendoring
//! the real `loom` (or `syn`, for the linter) is not an option. The in-tree
//! checker is a bounded-preemption DFS over sequentially-consistent
//! interleavings — see the [`model`] docs for exactly what it does and does
//! not cover.
//!
//! Discipline for new code (also in `CONTRIBUTING.md`):
//!
//! * Import `Mutex`/`Condvar`/atomics from `crate::runtime::sync`, never
//!   from `std::sync`, in any module listed above (or any module you add to
//!   the R4 list).
//! * Lock through [`crate::util::lock_or_recover`] rather than
//!   `.lock().unwrap()` (lint rule R1) so a panicking holder cannot cascade
//!   poison panics through the service.
//! * `mpsc`, `Arc` and `OnceLock` pass through to `std` in both builds: the
//!   model checker does not interpose on them, so loom scenarios model
//!   channels as `Mutex`-guarded queues instead.

#[cfg(any(loom, test))]
pub mod model;

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{
    mpsc, Arc, Condvar, LockResult, Mutex, MutexGuard, OnceLock, PoisonError, WaitTimeoutResult,
};

#[cfg(loom)]
pub use model::atomic;
#[cfg(loom)]
pub use model::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
#[cfg(loom)]
pub use std::sync::{mpsc, Arc, LockResult, OnceLock, PoisonError};
