//! In-tree bounded model checker behind the `cfg(loom)` face of
//! [`crate::runtime::sync`].
//!
//! [`model`] (and [`Builder::check`]) runs a closure — the *scenario* — many
//! times, exploring a different thread interleaving on each run, and panics
//! on the first schedule under which the scenario panics (a failed assert, a
//! poisoned invariant) or deadlocks. Scenarios spawn threads with
//! [`thread::spawn`] and synchronize through this module's [`Mutex`],
//! [`Condvar`] and [`atomic`] types; those are the *only* interleaving
//! points — code between two sync operations executes atomically, which is
//! the standard reduction for data-race-free programs.
//!
//! How it works: scenario threads are real OS threads, but a turn-taking
//! scheduler serializes them so exactly one is ever runnable. Every sync
//! operation is a *choice point*: the running thread records which threads
//! could run next and picks one; the driver then backtracks depth-first
//! over those recorded choices (increment the last choice with an untried
//! alternative, truncate, replay) until the space is exhausted. Replay is
//! what makes this sound: a scenario must therefore be deterministic apart
//! from scheduling — no wall-clock branching, no OS randomness.
//!
//! What is modeled, and what is not:
//!
//! * Interleavings are **sequentially consistent**. Relaxed-memory
//!   reorderings are out of scope — the coordinator's contracts all use
//!   `SeqCst` on the counters this matters for.
//! * The search is **preemption-bounded** (default 3): schedules with more
//!   than N involuntary context switches are not explored. Almost all real
//!   concurrency bugs manifest within 2 preemptions (CHESS's observation),
//!   and the bound is configurable via [`Builder`].
//! * [`Condvar::wait_timeout`] is modeled as an *untimed* wait. The 5 ms
//!   production backstop exists to mask rare missed wakeups operationally;
//!   modeling it as always-firable would both mask lost-wakeup bugs (the
//!   model's whole point: a lost wakeup must surface as a modeled deadlock)
//!   and make every park loop an unbounded schedule space.
//! * `mpsc`, `Arc`, and `OnceLock` are not interposed on (see
//!   [`crate::runtime::sync`]); scenarios model channels as `Mutex`-guarded
//!   queues.
//!
//! Outside a model run (no active execution on this thread) every type
//! here degrades to plain `std::sync` behavior, so lib code compiled with
//! `--cfg loom` still works when called from ordinary tests.

use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, LockResult, PoisonError, TryLockError};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Execution state: one scheduler shared by every thread of one model run.
// ---------------------------------------------------------------------------

/// Status of one scenario thread, as the scheduler sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Status {
    /// Can be scheduled.
    Ready,
    /// Parked in `Mutex::lock` on the mutex at this address.
    BlockedMutex(usize),
    /// Parked in `Condvar::wait` (or modeled `wait_timeout`) on the condvar
    /// at this address.
    BlockedCond(usize),
    /// Parked in `JoinHandle::join` on this thread id.
    BlockedJoin(usize),
    /// Closure returned (or unwound); never scheduled again.
    Finished,
}

/// One recorded scheduling decision: which threads were runnable, which ran.
#[derive(Debug, Clone)]
struct Choice {
    /// Runnable thread ids at this point, current-thread-first.
    alts: Vec<usize>,
    /// Index into `alts` actually taken on this run.
    chosen: usize,
}

struct ExecState {
    status: Vec<Status>,
    /// Thread id whose turn it is.
    current: usize,
    /// The schedule being replayed, then extended, on this run.
    schedule: Vec<Choice>,
    /// Next position in `schedule` to replay; past the end means "record".
    cursor: usize,
    preemptions: usize,
    preemption_bound: usize,
    /// First real failure (assert/deadlock/divergence) observed this run.
    failure: Option<String>,
    /// Set on failure: parked threads must unwind instead of waiting.
    abort: bool,
    /// All threads finished.
    done: bool,
}

struct Execution {
    m: std::sync::Mutex<ExecState>,
    cv: std::sync::Condvar,
}

/// Sentinel payload used to unwind parked threads after a failure. Raised
/// via `resume_unwind`, so it never reaches the panic hook.
struct ModelAbort;

/// Panic payload for scenarios that *intend* to panic (e.g. the
/// panic-respawn race): the quiet hook suppresses the per-run "thread
/// panicked" stderr spam a deliberately-panicking scenario would otherwise
/// produce on every explored schedule.
pub struct Quiet(pub &'static str);

fn install_quiet_hook() {
    use std::sync::Once;
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if info.payload().is::<Quiet>() {
                return;
            }
            prev(info);
        }));
    });
}

thread_local! {
    /// The execution this OS thread belongs to, plus its thread id.
    static CURRENT: std::cell::RefCell<Option<(Arc<Execution>, usize)>> =
        const { std::cell::RefCell::new(None) };
}

fn current_execution() -> Option<(Arc<Execution>, usize)> {
    CURRENT.with(|c| c.borrow().clone())
}

impl Execution {
    fn new(schedule: Vec<Choice>, preemption_bound: usize) -> Execution {
        Execution {
            m: std::sync::Mutex::new(ExecState {
                status: Vec::new(),
                current: 0,
                schedule,
                cursor: 0,
                preemptions: 0,
                preemption_bound,
                failure: None,
                abort: false,
                done: false,
            }),
            cv: std::sync::Condvar::new(),
        }
    }

    fn state(&self) -> std::sync::MutexGuard<'_, ExecState> {
        // The scheduler mutex is only poisoned if the checker itself has a
        // bug; recover so every parked thread still sees `abort` and exits.
        self.m.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record a failure (first one wins), wake everyone, and mark abort.
    fn fail(&self, msg: String) -> ! {
        {
            let mut st = self.state();
            if st.failure.is_none() {
                let trace = format_schedule(&st.schedule);
                st.failure = Some(format!("{msg}\n  schedule: {trace}"));
            }
            st.abort = true;
        }
        self.cv.notify_all();
        panic::resume_unwind(Box::new(ModelAbort));
    }

    /// Core scheduling step. `me` sets its own status, a successor is chosen
    /// (replayed or recorded), and the call returns once it is `me`'s turn
    /// again. A thread that marks itself `Finished` returns immediately
    /// after handing off.
    fn yield_turn(self: &Arc<Self>, me: usize, my_status: Status) {
        let mut st = self.state();
        if st.abort {
            drop(st);
            panic::resume_unwind(Box::new(ModelAbort));
        }
        st.status[me] = my_status;

        // Runnable set, current-thread-first so `chosen == 0` always means
        // "keep running the same thread" (no preemption).
        let mut alts: Vec<usize> = Vec::new();
        if st.status[me] == Status::Ready {
            alts.push(me);
        }
        for (tid, s) in st.status.iter().enumerate() {
            if tid != me && *s == Status::Ready {
                alts.push(tid);
            }
        }

        if alts.is_empty() {
            if st.status.iter().all(|s| *s == Status::Finished) {
                st.done = true;
                drop(st);
                self.cv.notify_all();
                return;
            }
            let dump = st
                .status
                .iter()
                .enumerate()
                .map(|(t, s)| format!("t{t}:{s:?}"))
                .collect::<Vec<_>>()
                .join(" ");
            drop(st);
            self.fail(format!("model deadlock: no runnable thread ({dump})"));
        }

        // Preemption bound: once spent, a runnable current thread may not be
        // switched away from, so the choice collapses to it. The same
        // constraint must be recomputed on replay (the preemption counter
        // evolves identically along a replayed prefix) or replay validation
        // would diverge from what was recorded.
        let constrained = if st.status[me] == Status::Ready
            && st.preemptions >= st.preemption_bound
        {
            vec![me]
        } else {
            alts
        };

        let next = if st.cursor < st.schedule.len() {
            let c = &st.schedule[st.cursor];
            if c.alts != constrained {
                let (want, got) = (c.alts.clone(), constrained.clone());
                drop(st);
                self.fail(format!(
                    "nondeterministic scenario: replay expected runnable set \
                     {want:?} but found {got:?} (scenarios must not branch on \
                     wall-clock time or other non-modeled state)"
                ));
            }
            let next = c.alts[c.chosen];
            st.cursor += 1;
            next
        } else {
            let next = constrained[0];
            st.schedule.push(Choice { alts: constrained, chosen: 0 });
            st.cursor = st.schedule.len();
            next
        };

        if next != me && st.status[me] == Status::Ready {
            st.preemptions += 1;
        }
        st.current = next;
        drop(st);
        self.cv.notify_all();

        if my_status == Status::Finished {
            return;
        }

        // Wait for our turn. Another thread's action (unlock, notify,
        // finish) may flip our status back to Ready and schedule us.
        let mut st = self.state();
        loop {
            if st.abort {
                drop(st);
                panic::resume_unwind(Box::new(ModelAbort));
            }
            if st.current == me && st.status[me] == Status::Ready {
                return;
            }
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// A plain interleaving point: anyone runnable may go next.
    fn yield_now(self: &Arc<Self>, me: usize) {
        self.yield_turn(me, Status::Ready);
    }

    /// Mark threads blocked on the mutex at `addr` runnable again.
    fn wake_mutex_waiters(&self, addr: usize) {
        let mut st = self.state();
        for s in st.status.iter_mut() {
            if *s == Status::BlockedMutex(addr) {
                *s = Status::Ready;
            }
        }
    }

    /// Wake condvar waiters: all of them, or just the first.
    fn wake_cond_waiters(&self, addr: usize, all: bool) {
        let mut st = self.state();
        for s in st.status.iter_mut() {
            if *s == Status::BlockedCond(addr) {
                *s = Status::Ready;
                if !all {
                    break;
                }
            }
        }
    }

    /// Register a new scenario thread; returns its tid.
    fn register(&self) -> usize {
        let mut st = self.state();
        st.status.push(Status::Ready);
        st.status.len() - 1
    }

    fn finish(self: &Arc<Self>, me: usize) {
        // Wake joiners first, then hand the turn off.
        {
            let mut st = self.state();
            for s in st.status.iter_mut() {
                if *s == Status::BlockedJoin(me) {
                    *s = Status::Ready;
                }
            }
        }
        self.yield_turn(me, Status::Finished);
    }
}

fn format_schedule(schedule: &[Choice]) -> String {
    let picks: Vec<String> = schedule
        .iter()
        .map(|c| format!("t{}", c.alts[c.chosen]))
        .collect();
    format!("[{}] ({} choice points)", picks.join(" "), schedule.len())
}

// ---------------------------------------------------------------------------
// Driver: DFS over schedules.
// ---------------------------------------------------------------------------

/// Configures and runs an exhaustive (bounded) interleaving search.
pub struct Builder {
    /// Max involuntary context switches per schedule (default 3).
    pub preemption_bound: usize,
    /// Hard cap on explored schedules; exceeding it is a loud failure, not a
    /// silent truncation (default 200 000).
    pub max_schedules: usize,
}

impl Default for Builder {
    fn default() -> Builder {
        Builder { preemption_bound: 3, max_schedules: 200_000 }
    }
}

impl Builder {
    pub fn new() -> Builder {
        Builder::default()
    }

    /// Run `f` under every schedule within the bound. Panics — on the test
    /// thread, with the offending schedule — if any run fails or deadlocks.
    pub fn check<F>(&self, f: F)
    where
        F: Fn() + Send + Sync + 'static,
    {
        install_quiet_hook();
        let f = Arc::new(f);
        let mut schedule: Vec<Choice> = Vec::new();
        let mut runs = 0usize;
        loop {
            runs += 1;
            if runs > self.max_schedules {
                panic!(
                    "model exceeded max_schedules ({}): the scenario's state \
                     space is too large — shrink it or raise the cap via \
                     Builder (refusing to silently truncate the search)",
                    self.max_schedules
                );
            }
            let exec = Arc::new(Execution::new(schedule, self.preemption_bound));
            let root_tid = exec.register();
            debug_assert_eq!(root_tid, 0);
            let root = {
                let exec = Arc::clone(&exec);
                let f = Arc::clone(&f);
                std::thread::spawn(move || {
                    CURRENT.with(|c| *c.borrow_mut() = Some((Arc::clone(&exec), 0)));
                    let r = panic::catch_unwind(AssertUnwindSafe(|| (*f)()));
                    if let Err(e) = r {
                        if !e.is::<ModelAbort>() {
                            let msg = panic_message(&e);
                            let mut st = exec.state();
                            if st.failure.is_none() {
                                let trace = format_schedule(&st.schedule);
                                st.failure = Some(format!(
                                    "scenario panicked on t0: {msg}\n  schedule: {trace}"
                                ));
                            }
                            st.abort = true;
                            drop(st);
                            exec.cv.notify_all();
                        }
                    }
                    exec.finish(0);
                })
            };

            // Wait for the run to finish or fail.
            {
                let mut st = exec.state();
                while !st.done && st.failure.is_none() {
                    st = exec.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                }
            }
            let _ = root.join();

            let (failure, mut sched) = {
                let mut st = exec.state();
                (st.failure.take(), std::mem::take(&mut st.schedule))
            };
            if let Some(msg) = failure {
                panic!("model check failed after {runs} schedule(s):\n  {msg}");
            }

            // Depth-first advance: bump the deepest choice with an untried
            // alternative; drop everything after it.
            loop {
                match sched.last_mut() {
                    None => return, // space exhausted, all runs passed
                    Some(c) if c.chosen + 1 < c.alts.len() => {
                        c.chosen += 1;
                        break;
                    }
                    Some(_) => {
                        sched.pop();
                    }
                }
            }
            schedule = sched;
        }
    }
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else if let Some(q) = e.downcast_ref::<Quiet>() {
        format!("Quiet({})", q.0)
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Model-check `f` with default bounds. The `cfg(loom)` equivalent of
/// `loom::model`.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    Builder::new().check(f)
}

// ---------------------------------------------------------------------------
// Modeled thread spawn/join.
// ---------------------------------------------------------------------------

pub mod thread {
    use super::*;

    pub struct JoinHandle<T> {
        tid: Option<usize>,
        os: std::thread::JoinHandle<T>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            if let Some((exec, me)) = current_execution() {
                let target = self.tid.expect("model JoinHandle always has a tid");
                loop {
                    let st = exec.state();
                    if st.abort {
                        drop(st);
                        panic::resume_unwind(Box::new(ModelAbort));
                    }
                    let finished = st.status[target] == Status::Finished;
                    drop(st);
                    if finished {
                        break;
                    }
                    exec.yield_turn(me, Status::BlockedJoin(target));
                }
                // The target has executed `finish`; its OS thread is exiting
                // (or already gone), so this join is a bounded real wait, not
                // a modeled one.
                self.os.join()
            } else {
                self.os.join()
            }
        }
    }

    /// Spawn a scenario thread. Inside a model run the child participates in
    /// the turn-taking scheduler; outside one this is `std::thread::spawn`.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        if let Some((exec, me)) = current_execution() {
            let tid = exec.register();
            let child_exec = Arc::clone(&exec);
            let os = std::thread::spawn(move || {
                CURRENT.with(|c| {
                    *c.borrow_mut() = Some((Arc::clone(&child_exec), tid))
                });
                // Wait to be scheduled for the first time.
                {
                    let mut st = child_exec.state();
                    loop {
                        if st.abort {
                            drop(st);
                            panic::resume_unwind(Box::new(ModelAbort));
                        }
                        if st.current == tid && st.status[tid] == Status::Ready {
                            break;
                        }
                        st = child_exec
                            .cv
                            .wait(st)
                            .unwrap_or_else(PoisonError::into_inner);
                    }
                }
                let r = panic::catch_unwind(AssertUnwindSafe(f));
                match r {
                    Ok(v) => {
                        child_exec.finish(tid);
                        v
                    }
                    Err(e) => {
                        if !e.is::<ModelAbort>() {
                            let msg = panic_message(&e);
                            let mut st = child_exec.state();
                            if st.failure.is_none() {
                                let trace = format_schedule(&st.schedule);
                                st.failure = Some(format!(
                                    "scenario panicked on t{tid}: {msg}\n  schedule: {trace}"
                                ));
                            }
                            st.abort = true;
                            drop(st);
                            child_exec.cv.notify_all();
                            child_exec.finish(tid);
                        }
                        panic::resume_unwind(e);
                    }
                }
            });
            // Spawning is itself a visible event: give the scheduler the
            // option of running the child right away.
            exec.yield_now(me);
            JoinHandle { tid: Some(tid), os }
        } else {
            JoinHandle { tid: None, os: std::thread::spawn(f) }
        }
    }
}

// ---------------------------------------------------------------------------
// Mutex / Condvar.
// ---------------------------------------------------------------------------

/// Scheduler-aware mutex. `const`-constructible (statics in `faultinject`
/// and `gemm` depend on it); all scheduler bookkeeping is keyed by the inner
/// mutex's address, so the type adds no fields over `std`.
pub struct Mutex<T: ?Sized> {
    inner: std::sync::Mutex<T>,
}

pub struct MutexGuard<'a, T: ?Sized + 'a> {
    lock: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T> Mutex<T> {
    pub const fn new(t: T) -> Mutex<T> {
        Mutex { inner: std::sync::Mutex::new(t) }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

impl<T: ?Sized> Mutex<T> {
    fn addr(&self) -> usize {
        &self.inner as *const _ as *const () as usize
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if let Some((exec, me)) = current_execution() {
            loop {
                // Acquiring is a choice point *before* the attempt, so a
                // competitor can slip in between any two of our sync ops.
                exec.yield_now(me);
                match self.inner.try_lock() {
                    Ok(g) => {
                        return Ok(MutexGuard { lock: self, inner: Some(g) })
                    }
                    Err(TryLockError::Poisoned(p)) => {
                        return Err(PoisonError::new(MutexGuard {
                            lock: self,
                            inner: Some(p.into_inner()),
                        }))
                    }
                    Err(TryLockError::WouldBlock) => {
                        // Serialized execution means the holder cannot be
                        // mid-release: park until its guard drop wakes us.
                        exec.yield_turn(me, Status::BlockedMutex(self.addr()));
                    }
                }
            }
        } else {
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g) }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                })),
            }
        }
    }

    pub fn get_mut(&mut self) -> LockResult<&mut T> {
        self.inner.get_mut()
    }
}

impl<T: ?Sized + std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard accessed after release")
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard accessed after release")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        let addr = self.lock.addr();
        // Release the real lock first (possibly poisoning it if we are
        // unwinding), then tell the scheduler; waiters only retry when
        // scheduled, so the order cannot race.
        self.inner = None;
        if let Some((exec, me)) = current_execution() {
            exec.wake_mutex_waiters(addr);
            if !std::thread::panicking() {
                exec.yield_now(me);
            }
        }
    }
}

/// Result of a modeled [`Condvar::wait_timeout`]. `timed_out` is always
/// `false` under the model (see the module docs: the production timeout is
/// a backstop deliberately excluded so lost wakeups surface as deadlocks).
#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Scheduler-aware condvar; `const`-constructible like [`Mutex`].
pub struct Condvar {
    inner: std::sync::Condvar,
}

impl Condvar {
    pub const fn new() -> Condvar {
        Condvar { inner: std::sync::Condvar::new() }
    }

    fn addr(&self) -> usize {
        &self.inner as *const _ as usize
    }

    fn wait_model<'a, T: ?Sized>(
        &self,
        mut guard: MutexGuard<'a, T>,
        exec: &Arc<Execution>,
        me: usize,
    ) -> LockResult<MutexGuard<'a, T>> {
        let lock = guard.lock;
        // Atomically (w.r.t. the model: no yield in between) release the
        // mutex and park on the condvar — the no-lost-wakeup guarantee a
        // real condvar provides. Guard teardown is done by hand so its Drop
        // yield does not open a wakeup window.
        guard.inner = None;
        exec.wake_mutex_waiters(lock.addr());
        std::mem::forget(guard);
        exec.yield_turn(me, Status::BlockedCond(self.addr()));
        // Notified (we only run again once a notify flipped us to Ready).
        lock.lock()
    }

    pub fn wait<'a, T: ?Sized>(
        &self,
        guard: MutexGuard<'a, T>,
    ) -> LockResult<MutexGuard<'a, T>> {
        if let Some((exec, me)) = current_execution() {
            self.wait_model(guard, &exec, me)
        } else {
            let lock = guard.lock;
            let mut guard = guard;
            let std_guard = guard.inner.take().expect("guard accessed after release");
            std::mem::forget(guard);
            match self.inner.wait(std_guard) {
                Ok(g) => Ok(MutexGuard { lock, inner: Some(g) }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock,
                    inner: Some(p.into_inner()),
                })),
            }
        }
    }

    pub fn wait_timeout<'a, T: ?Sized>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        if let Some((exec, me)) = current_execution() {
            match self.wait_model(guard, &exec, me) {
                Ok(g) => Ok((g, WaitTimeoutResult(false))),
                Err(p) => Err(PoisonError::new((
                    p.into_inner(),
                    WaitTimeoutResult(false),
                ))),
            }
        } else {
            let lock = guard.lock;
            let mut guard = guard;
            let std_guard = guard.inner.take().expect("guard accessed after release");
            std::mem::forget(guard);
            match self.inner.wait_timeout(std_guard, dur) {
                Ok((g, t)) => Ok((
                    MutexGuard { lock, inner: Some(g) },
                    WaitTimeoutResult(t.timed_out()),
                )),
                Err(p) => {
                    let (g, t) = p.into_inner();
                    Err(PoisonError::new((
                        MutexGuard { lock, inner: Some(g) },
                        WaitTimeoutResult(t.timed_out()),
                    )))
                }
            }
        }
    }

    pub fn notify_one(&self) {
        if let Some((exec, me)) = current_execution() {
            exec.wake_cond_waiters(self.addr(), false);
            exec.yield_now(me);
        } else {
            self.inner.notify_one();
        }
    }

    pub fn notify_all(&self) {
        if let Some((exec, me)) = current_execution() {
            exec.wake_cond_waiters(self.addr(), true);
            exec.yield_now(me);
        } else {
            self.inner.notify_all();
        }
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

// ---------------------------------------------------------------------------
// Atomics: std semantics, plus a choice point before every operation.
// ---------------------------------------------------------------------------

pub mod atomic {
    use super::current_execution;
    pub use std::sync::atomic::Ordering;

    fn interleave() {
        if let Some((exec, me)) = current_execution() {
            exec.yield_now(me);
        }
    }

    macro_rules! model_atomic {
        ($name:ident, $std:ty, $prim:ty) => {
            #[derive(Debug, Default)]
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $prim) -> $name {
                    $name { inner: <$std>::new(v) }
                }
                pub fn load(&self, o: Ordering) -> $prim {
                    interleave();
                    self.inner.load(o)
                }
                pub fn store(&self, v: $prim, o: Ordering) {
                    interleave();
                    self.inner.store(v, o)
                }
                pub fn swap(&self, v: $prim, o: Ordering) -> $prim {
                    interleave();
                    self.inner.swap(v, o)
                }
                pub fn compare_exchange(
                    &self,
                    cur: $prim,
                    new: $prim,
                    ok: Ordering,
                    err: Ordering,
                ) -> Result<$prim, $prim> {
                    interleave();
                    self.inner.compare_exchange(cur, new, ok, err)
                }
            }
        };
    }

    macro_rules! model_atomic_int {
        ($name:ident, $std:ty, $prim:ty) => {
            model_atomic!($name, $std, $prim);

            impl $name {
                pub fn fetch_add(&self, v: $prim, o: Ordering) -> $prim {
                    interleave();
                    self.inner.fetch_add(v, o)
                }
                pub fn fetch_sub(&self, v: $prim, o: Ordering) -> $prim {
                    interleave();
                    self.inner.fetch_sub(v, o)
                }
                pub fn fetch_max(&self, v: $prim, o: Ordering) -> $prim {
                    interleave();
                    self.inner.fetch_max(v, o)
                }
                pub fn fetch_min(&self, v: $prim, o: Ordering) -> $prim {
                    interleave();
                    self.inner.fetch_min(v, o)
                }
            }
        };
    }

    model_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
    model_atomic_int!(AtomicU8, std::sync::atomic::AtomicU8, u8);
    model_atomic_int!(AtomicU32, std::sync::atomic::AtomicU32, u32);
    model_atomic_int!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    model_atomic_int!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
    model_atomic_int!(AtomicI64, std::sync::atomic::AtomicI64, i64);
}

// ---------------------------------------------------------------------------
// Self-tests: run under plain `cargo test` (tier-1), no `--cfg loom` needed.
// They both pin that the checker accepts correct synchronization and that it
// actually *finds* the bug classes the loom suite exists for.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::atomic::{AtomicU64, Ordering};
    use super::*;
    use std::collections::VecDeque;

    /// Two unsynchronized increments through load+store lose updates; the
    /// checker must find the interleaving where one write clobbers the
    /// other. (This is the checker's own smoke test: if it cannot find this
    /// textbook race, every green loom scenario is meaningless.)
    #[test]
    fn finds_a_lost_update() {
        let failed = panic::catch_unwind(|| {
            model(|| {
                let n = Arc::new(AtomicU64::new(0));
                let mut hs = Vec::new();
                for _ in 0..2 {
                    let n = Arc::clone(&n);
                    hs.push(thread::spawn(move || {
                        let v = n.load(Ordering::SeqCst);
                        n.store(v + 1, Ordering::SeqCst);
                    }));
                }
                for h in hs {
                    h.join().unwrap();
                }
                assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
            });
        })
        .is_err();
        assert!(failed, "the checker must catch a load/store lost update");
    }

    /// The same counter incremented with fetch_add is race-free; the checker
    /// must pass every interleaving.
    #[test]
    fn passes_atomic_increments() {
        model(|| {
            let n = Arc::new(AtomicU64::new(0));
            let mut hs = Vec::new();
            for _ in 0..2 {
                let n = Arc::clone(&n);
                hs.push(thread::spawn(move || {
                    n.fetch_add(1, Ordering::SeqCst);
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(n.load(Ordering::SeqCst), 2);
        });
    }

    /// Mutex-guarded read-modify-write never loses updates.
    #[test]
    fn passes_mutex_counter() {
        model(|| {
            let n = Arc::new(Mutex::new(0u64));
            let mut hs = Vec::new();
            for _ in 0..2 {
                let n = Arc::clone(&n);
                hs.push(thread::spawn(move || {
                    let mut g = n.lock().unwrap();
                    *g += 1;
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(*n.lock().unwrap(), 2);
        });
    }

    /// Correct monitor discipline: the waiter re-checks the predicate under
    /// the same mutex the condvar parks on, and the producer flips the
    /// predicate under that mutex before notifying. No interleaving may
    /// deadlock. This is exactly the shape the service's admission gate uses
    /// after this PR (check + park on the pending mutex).
    #[test]
    fn passes_monitor_handshake() {
        model(|| {
            let slot: Arc<(Mutex<bool>, Condvar)> =
                Arc::new((Mutex::new(false), Condvar::new()));
            let waiter = {
                let slot = Arc::clone(&slot);
                thread::spawn(move || {
                    let (m, cv) = &*slot;
                    let mut ready = m.lock().unwrap();
                    while !*ready {
                        ready = cv.wait(ready).unwrap();
                    }
                })
            };
            let (m, cv) = &*slot;
            {
                let mut ready = m.lock().unwrap();
                *ready = true;
                cv.notify_all();
            }
            waiter.join().unwrap();
        });
    }

    /// Broken discipline — predicate guarded by one mutex, condvar parked on
    /// another, no recheck between them — has a lost-wakeup interleaving:
    /// producer sets the flag and notifies inside the waiter's check-to-park
    /// window. The checker must report it as a deadlock.
    #[test]
    fn finds_a_lost_wakeup() {
        let failed = panic::catch_unwind(|| {
            model(|| {
                let flag = Arc::new(Mutex::new(false));
                let park: Arc<(Mutex<()>, Condvar)> =
                    Arc::new((Mutex::new(()), Condvar::new()));
                let waiter = {
                    let (flag, park) = (Arc::clone(&flag), Arc::clone(&park));
                    thread::spawn(move || {
                        let set = *flag.lock().unwrap();
                        if !set {
                            // Lost-wakeup window: the notify can land here.
                            let (m, cv) = &*park;
                            let g = m.lock().unwrap();
                            let _g = cv.wait(g).unwrap();
                        }
                    })
                };
                *flag.lock().unwrap() = true;
                let (_m, cv) = &*park;
                cv.notify_all();
                waiter.join().unwrap();
            });
        })
        .is_err();
        assert!(failed, "the checker must catch the two-lock lost wakeup");
    }

    /// A panic while holding a model mutex poisons it, and the recovered
    /// state is the pre-panic state — the property `util::lock_or_recover`
    /// is built on, now pinned against the *model* mutex too.
    #[test]
    fn poison_recovers_pre_panic_state() {
        model(|| {
            let log: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
            let h = {
                let log = Arc::clone(&log);
                thread::spawn(move || {
                    let mut g = log.lock().unwrap();
                    g.push(7);
                    std::panic::panic_any(Quiet("poison the log"));
                })
            };
            assert!(h.join().is_err(), "worker must have panicked");
            let g = log
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            assert_eq!(*g, vec![7], "recovered state is the pre-panic state");
        });
    }

    /// wait_timeout is modeled as untimed wait and reports !timed_out.
    #[test]
    fn wait_timeout_is_a_wait_under_the_model() {
        model(|| {
            let slot: Arc<(Mutex<bool>, Condvar)> =
                Arc::new((Mutex::new(false), Condvar::new()));
            let waiter = {
                let slot = Arc::clone(&slot);
                thread::spawn(move || {
                    let (m, cv) = &*slot;
                    let mut ready = m.lock().unwrap();
                    while !*ready {
                        let (g, t) = cv
                            .wait_timeout(ready, Duration::from_millis(5))
                            .unwrap();
                        assert!(!t.timed_out());
                        ready = g;
                    }
                })
            };
            let (m, cv) = &*slot;
            {
                let mut ready = m.lock().unwrap();
                *ready = true;
                cv.notify_all();
            }
            waiter.join().unwrap();
        });
    }

    /// Pass-through: outside a model run the types behave like std's, so
    /// `--cfg loom` builds still work when lib code runs under plain tests.
    #[test]
    fn passthrough_outside_a_model() {
        let m = Mutex::new(1u32);
        *m.lock().unwrap() += 1;
        assert_eq!(*m.lock().unwrap(), 2);
        let q: Mutex<VecDeque<u32>> = Mutex::new(VecDeque::new());
        q.lock().unwrap().push_back(3);
        assert_eq!(q.lock().unwrap().pop_front(), Some(3));
        let a = AtomicU64::new(5);
        assert_eq!(a.fetch_add(2, Ordering::SeqCst), 5);
        assert_eq!(a.load(Ordering::SeqCst), 7);
    }
}
