//! Minimal TOML and JSON parsing (no `serde`/`toml` offline).
//!
//! * TOML subset: tables (`[a.b]`), key = value with strings, ints, floats,
//!   booleans and flat arrays — enough for experiment configs.
//! * JSON: full parser + writer — used for the artifact `manifest.json`
//!   interchange with `python/compile/aot.py` and the bench JSONL output.

use crate::util::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A dynamically-typed config/JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }
    /// Dotted-path lookup: `get_path("optim.lr")`.
    pub fn get_path(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }
}

// ---------------------------------------------------------------- TOML ----

/// Parse the TOML subset. Keys at top level go into the root table; `[a.b]`
/// opens nested tables.
pub fn parse_toml(src: &str) -> Result<Value> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            let inner = &line[1..line.len() - 1];
            current_path = inner.split('.').map(|s| s.trim().to_string()).collect();
            ensure_table(&mut root, &current_path)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| Error::Parse(format!("toml line {}: missing '='", lineno + 1)))?;
        let key = line[..eq].trim().to_string();
        let val = parse_toml_value(line[eq + 1..].trim())
            .map_err(|e| Error::Parse(format!("toml line {}: {e}", lineno + 1)))?;
        insert_at(&mut root, &current_path, key, val)?;
    }
    Ok(Value::Table(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table(root: &mut BTreeMap<String, Value>, path: &[String]) -> Result<()> {
    let mut cur = root;
    for p in path {
        let entry = cur
            .entry(p.clone())
            .or_insert_with(|| Value::Table(BTreeMap::new()));
        cur = match entry {
            Value::Table(t) => t,
            _ => return Err(Error::Parse(format!("toml: '{p}' is not a table"))),
        };
    }
    Ok(())
}

fn insert_at(
    root: &mut BTreeMap<String, Value>,
    path: &[String],
    key: String,
    val: Value,
) -> Result<()> {
    let mut cur = root;
    for p in path {
        cur = match cur.get_mut(p) {
            Some(Value::Table(t)) => t,
            _ => return Err(Error::Parse(format!("toml: missing table '{p}'"))),
        };
    }
    cur.insert(key, val);
    Ok(())
}

fn parse_toml_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_toml_value(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(Error::Parse(format!("unrecognised value '{s}'")))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

// ---------------------------------------------------------------- JSON ----

/// Parse a JSON document.
pub fn parse_json(src: &str) -> Result<Value> {
    let mut p = JsonParser { src: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.src.len() {
        return Err(Error::Parse(format!("json: trailing data at byte {}", p.pos)));
    }
    Ok(v)
}

struct JsonParser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(Error::Parse(format!(
                "json: expected '{}' at byte {}",
                c as char,
                self.pos.saturating_sub(1)
            )))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Parse(format!("json: unexpected {other:?} at {}", self.pos))),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Parse(format!("json: bad literal at {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Table(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Table(map)),
                _ => return Err(Error::Parse(format!("json: bad object at {}", self.pos))),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(Error::Parse(format!("json: bad array at {}", self.pos))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| {
                                Error::Parse("json: truncated \\u".to_string())
                            })?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| Error::Parse("json: bad \\u".to_string()))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(Error::Parse("json: bad escape".to_string())),
                },
                Some(c) => out.push(c as char),
                None => return Err(Error::Parse("json: unterminated string".to_string())),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Value::Float)
                .map_err(|_| Error::Parse(format!("json: bad number '{text}'")))
        } else {
            text.parse::<i64>()
                .map(Value::Int)
                .map_err(|_| Error::Parse(format!("json: bad number '{text}'")))
        }
    }
}

/// Serialise a `Value` to compact JSON.
pub fn to_json(v: &Value) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => {
            let _ = write!(out, "{i}");
        }
        Value::Float(f) => {
            if f.is_finite() {
                let _ = write!(out, "{f}");
            } else {
                out.push_str("null");
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Array(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Value::Table(t) => {
            out.push('{');
            for (i, (k, val)) in t.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Value::Str(k.clone()), out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toml_basic() {
        let src = r#"
# experiment config
name = "fig3"
n = 256
lr = 0.02
verbose = true
gammas = [1.0, 4.0, 50.0]

[optim]
kind = "muon"
momentum = 0.95

[optim.polar]
degree = 5
"#;
        let v = parse_toml(src).unwrap();
        assert_eq!(v.get_path("name").unwrap().as_str(), Some("fig3"));
        assert_eq!(v.get_path("n").unwrap().as_int(), Some(256));
        assert_eq!(v.get_path("lr").unwrap().as_float(), Some(0.02));
        assert_eq!(v.get_path("verbose").unwrap().as_bool(), Some(true));
        assert_eq!(v.get_path("optim.kind").unwrap().as_str(), Some("muon"));
        assert_eq!(v.get_path("optim.polar.degree").unwrap().as_int(), Some(5));
        let arr = v.get_path("gammas").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_float(), Some(50.0));
    }

    #[test]
    fn toml_rejects_garbage() {
        assert!(parse_toml("key value").is_err());
        assert!(parse_toml("k = @@").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let src = r#"{"a": 1, "b": [1.5, "x", true, null], "c": {"d": -2}}"#;
        let v = parse_json(src).unwrap();
        assert_eq!(v.get_path("a").unwrap().as_int(), Some(1));
        assert_eq!(v.get_path("c.d").unwrap().as_int(), Some(-2));
        let re = parse_json(&to_json(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn json_string_escapes() {
        let v = parse_json(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
        let out = to_json(&v);
        let back = parse_json(&out).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn json_rejects_trailing() {
        assert!(parse_json("{} x").is_err());
        assert!(parse_json("[1,]").is_err());
    }

    #[test]
    fn json_nested_arrays() {
        let v = parse_json("[[1,2],[3,4]]").unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a[1].as_array().unwrap()[0].as_int(), Some(3));
    }

    #[test]
    fn json_empty_containers() {
        assert_eq!(parse_json("{}").unwrap(), Value::Table(BTreeMap::new()));
        assert_eq!(parse_json("[]").unwrap(), Value::Array(vec![]));
    }

    #[test]
    fn json_floats() {
        let v = parse_json("[1e-3, -2.5E2, 0.0]").unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a[0].as_float(), Some(1e-3));
        assert_eq!(a[1].as_float(), Some(-250.0));
    }
}
