//! `prism` — the leader binary: CLI over the PRISM matrix-function engines,
//! the preconditioner service, and the AOT training driver.
//!
//! Subcommands:
//!
//! * `polar`   — orthogonalize a random test matrix, compare backends.
//! * `sqrt`    — coupled Newton–Schulz square root / inverse square root.
//! * `invroot` — coupled inverse Newton for `A^{-1/p}`.
//! * `inverse` — Chebyshev iteration for `A^{-1}`.
//! * `sign`    — matrix sign (the §4 case study).
//! * `serve`   — run the preconditioner service on a synthetic gradient
//!   stream and report throughput/latency percentiles.
//! * `train`   — end-to-end: load the AOT-compiled JAX/Pallas `train_step`
//!   artifact via PJRT and train the transformer LM with Muon/AdamW/Shampoo.
//! * `info`    — show the artifact manifest and PJRT platform.
//!
//! Run with no args for usage.

use prism::cli::Args;
use prism::config::{Backend, ServiceConfig, TrainConfig};
use prism::coordinator::service::{JobKind, Service};
use prism::coordinator::train::TrainDriver;
use prism::linalg::Mat;
use prism::matfn::{registry, MatFnOutput, MatFnTask};
use prism::optim::adamw::AdamW;
use prism::optim::muon::Muon;
use prism::optim::shampoo::Shampoo;
use prism::optim::Optimizer;
use prism::prism::polar::orthogonality_error;
use prism::prism::sqrt::sqrt_error;
use prism::prism::{IterationLog, StopRule};
use prism::randmat;
use prism::rng::Rng;
use prism::runtime::Runtime;
use prism::util::Stopwatch;
use prism::workload::{GradientStream, MarkovCorpus};

const USAGE: &str = "\
prism — distribution-free adaptive matrix functions (PRISM reproduction)

USAGE:
  prism <subcommand> [--flag value ...]

SUBCOMMANDS:
  polar     orthogonalization U Vᵀ          (Figs. 1, 3, 4)
  sqrt      A^{1/2} and A^{-1/2}            (Figs. D.3, D.4)
  invroot   A^{-1/p} via inverse Newton     (Table 1 row 5)
  inverse   A^{-1} via Chebyshev            (Table 1 row 7)
  sign      matrix sign                     (§4 case study)
  serve     preconditioner service demo     (L3 coordinator)
  train     AOT LM training via PJRT        (Fig. 6 end-to-end)
  info      artifact manifest + PJRT platform

COMMON FLAGS:
  --threads T      GEMM pool size (default 1 = sequential kernels;
                   any T gives bit-identical results — speed knob only)
  --gemm-block B   GEMM cache-block sizes as MCxKCxNC (default 128x256x512;
                   startup-time tuning knob — changing KC/NC regroups the
                   reduction and can change low-order result bits)
  --gemm-kernel K  GEMM microkernel: auto|scalar|avx2|neon (default auto =
                   widest kernel the host supports, also overridable via
                   the PALLAS_GEMM_KERNEL env var; kernels agree to fp64
                   round-off but not bit-for-bit — FMA fuses roundings)
  --n / --m        matrix shape             (default 256 / 128)
  --spectrum S     gaussian|logspace|htmp|wishart|mp (default gaussian)
  --smin X         smallest singular value for logspace (default 1e-6)
  --kappa K        HTMP tail parameter      (default 0.5)
  --seed N         RNG seed                 (default 42)
  --iters K        max iterations           (default 100)
  --tol T          residual tolerance       (default 1e-7; serve: unset
                   keeps per-task defaults — 1e-7 polar/sign, 1e-9
                   inverse-root)
  --precision P    serve: f64|mixed (default f64; mixed runs the hot
                   Newton–Schulz loop in f32 under an f64 residual guard
                   plus one f64 cleanup iteration — see matfn::Precision)
  --d D            polynomial degree 1|2    (default 2)
  --sketch P       sketch rows p            (default 8)
  --backends LIST  comma list of matfn methods: classic,prism,exact,
                   polarexpress,cans,newton,eigen (per-command defaults)
  --stream         serve: stream per-iteration residuals from the workers
  --cache-cap C    serve: per-worker LRU cap on cached per-shape solvers
                   (default 32)
  --queue-cap Q    serve: max jobs admitted but not yet fetched (default 128)
  --admission P    serve: block|reject — what a full queue does to submit
                   (default block; reject returns a typed Backpressure error)
  --faults SPEC    serve: deterministic fault injection, e.g.
                   "nan:solve=4,iter=1;panic:worker=0,job=9;delay:ms=5"
                   (default none; PALLAS_FAULTS env var is the fallback)
  --linger MS      serve: shape-bucket linger in milliseconds — how long a
                   partial batch may wait for same-shape peers before the
                   flusher cuts it (default unset: only full buckets and
                   explicit flushes dispatch)
  --cache-snapshot F  serve: warm-state manifest path — restored at start
                   when the file exists (pre-building per-shape solver
                   caches), rewritten at shutdown
  --artifacts DIR  artifact directory       (default artifacts)

All subcommands dispatch through the matfn solver registry; any
`<method>-<task>` name from `prism::matfn::registry::names()` (e.g.
prism5-polar, newton-sqrt, cheb-inverse) is also accepted in --backends.
";

fn main() {
    let args = Args::from_env(true);
    // Install the global GEMM pool before any engine runs. Results are
    // bit-identical at every pool size, so this only changes wall time.
    match args.get_usize("threads", 1) {
        Ok(t) => {
            if t > 1 {
                prism::linalg::gemm::set_global_threads(t);
            }
        }
        Err(e) => {
            eprintln!("prism: error: {e}");
            std::process::exit(1);
        }
    }
    // Install the GEMM cache-block sizes before any engine runs (tuning
    // knob; see USAGE for the bit-level caveat on changing it mid-run).
    if let Some(spec) = args.get("gemm-block") {
        match prism::linalg::gemm::GemmBlocking::parse(spec) {
            Ok(b) => prism::linalg::gemm::set_global_blocking(b),
            Err(e) => {
                eprintln!("prism: error: {e}");
                std::process::exit(1);
            }
        }
    }
    // Force a GEMM microkernel before any engine runs ("auto" keeps the
    // detected default). Unavailable kernels are a hard error here — a
    // forced ablation run must not silently fall back.
    if let Some(spec) = args.get("gemm-kernel") {
        match prism::linalg::gemm::MicroKernel::parse(spec) {
            Ok(None) => {}
            Ok(Some(k)) if k.is_available() => {
                prism::linalg::gemm::set_global_kernel(Some(k))
            }
            Ok(Some(k)) => {
                let avail: Vec<&str> = prism::linalg::gemm::MicroKernel::available()
                    .iter()
                    .map(|k| k.name())
                    .collect();
                eprintln!(
                    "prism: error: gemm kernel '{}' is not available on this host (available: {})",
                    k.name(),
                    avail.join(", ")
                );
                std::process::exit(1);
            }
            Err(e) => {
                eprintln!("prism: error: {e}");
                std::process::exit(1);
            }
        }
    }
    let code = match args.subcommand.as_deref() {
        Some("polar") => cmd_polar(&args),
        Some("sqrt") => cmd_sqrt(&args),
        Some("invroot") => cmd_invroot(&args),
        Some("inverse") => cmd_inverse(&args),
        Some("sign") => cmd_sign(&args),
        Some("serve") => cmd_serve(&args),
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = code {
        eprintln!("prism: error: {e}");
        std::process::exit(1);
    }
}

/// Build the test matrix requested by `--spectrum`.
fn test_matrix(args: &Args, rng: &mut Rng, square: bool) -> prism::util::Result<Mat> {
    let n = args.get_usize("n", 256)?;
    let m = if square { n } else { args.get_usize("m", (n / 2).max(1))? };
    let smin = args.get_f64("smin", 1e-6)?;
    let kappa = args.get_f64("kappa", 0.5)?;
    let kind = args.get_string("spectrum", "gaussian");
    let k = n.min(m);
    Ok(match kind.as_str() {
        "gaussian" => randmat::gaussian(rng, n, m),
        "logspace" => {
            let s = randmat::logspace(smin, 1.0, k);
            randmat::with_spectrum(rng, n, m, &s)
        }
        "htmp" => randmat::htmp(rng, n, m, kappa),
        "wishart" => randmat::wishart(rng, n, m),
        "mp" => {
            let w = randmat::marchenko_pastur_eigs(rng, k, m as f64 / n as f64);
            let s: Vec<f64> = w.iter().map(|x| x.sqrt()).collect();
            randmat::with_spectrum(rng, n, m, &s)
        }
        other => {
            return Err(prism::util::Error::Parse(format!(
                "--spectrum '{other}' (want gaussian|logspace|htmp|wishart|mp)"
            )))
        }
    })
}

fn stop_rule(args: &Args) -> prism::util::Result<StopRule> {
    Ok(StopRule::default()
        .with_max_iters(args.get_usize("iters", 100)?)
        .with_tol(args.get_f64("tol", 1e-7)?))
}

/// Resolve a registry name into a solver with the CLI's stop rule and sketch
/// size applied, then run it. Every subcommand dispatches through here — the
/// engines are never called directly.
fn solve_named(
    name: &str,
    stop: StopRule,
    d: usize,
    sketch_p: usize,
    a: &Mat,
    rng: &mut Rng,
) -> prism::util::Result<(String, MatFnOutput)> {
    let mut solver = registry::resolve(name)?;
    solver.set_stop(stop);
    // `--d` applies only to Newton–Schulz solvers whose name does NOT encode
    // an order (ns-*, prism-exact-*); an explicit `prismN-*` name keeps its
    // own degree — otherwise `--backends prism3-polar` would silently run
    // (and be labelled as) a different order.
    let sketched = matches!(
        solver.spec().alpha,
        prism::prism::AlphaMode::Sketched { .. } | prism::prism::AlphaMode::SketchedKind { .. }
    );
    if solver.spec().method == prism::matfn::Method::NewtonSchulz && !sketched {
        solver.spec_mut().d = d;
    }
    if sketch_p != 8 {
        if let prism::prism::AlphaMode::Sketched { .. } = solver.spec().alpha {
            solver.spec_mut().alpha = prism::prism::AlphaMode::Sketched { p: sketch_p };
        }
    }
    let label = solver.name();
    let out = solver.solve(a, rng);
    Ok((label, out))
}

/// Map a CLI `--backends` token to a registry name for `task`: the short
/// tokens keep their historical meaning, anything containing `-` is taken as
/// a full registry name, and any other bare method token is paired with the
/// task (`eigen` → `eigen-polar`).
fn registry_name(token: &str, task: MatFnTask, d: usize) -> String {
    match token {
        "classic" | "ns" => match task {
            MatFnTask::InvRoot { .. } => format!("invnewton-classic-{}", task.name()),
            MatFnTask::Inverse => format!("cheb-classic-{}", task.name()),
            _ => format!("ns-{}", task.name()),
        },
        "prism" => match task {
            MatFnTask::InvRoot { .. } => format!("invnewton-{}", task.name()),
            MatFnTask::Inverse => format!("cheb-{}", task.name()),
            _ => format!("prism{}-{}", 2 * d + 1, task.name()),
        },
        "exact" => format!("prism-exact-{}", task.name()),
        "polarexpress" | "pe" => format!("pe-{}", task.name()),
        full if full.contains('-') => full.to_string(),
        method => format!("{method}-{}", task.name()),
    }
}

fn print_log(name: &str, log: &IterationLog, extra: &str) {
    println!(
        "  {name:<14} iters={:<4} residual={:<12.3e} time={:>8.2}ms {}",
        log.iters(),
        log.final_residual(),
        log.wall_s * 1e3,
        extra
    );
    if !log.alphas.is_empty() {
        let alphas: Vec<String> = log.alphas.iter().take(10).map(|a| format!("{a:.3}")).collect();
        println!(
            "  {:<14} α_k = [{}{}]",
            "",
            alphas.join(", "),
            if log.alphas.len() > 10 { ", …" } else { "" }
        );
    }
}

fn cmd_polar(args: &Args) -> prism::util::Result<()> {
    let mut rng = Rng::seed_from(args.get_u64("seed", 42)?);
    let a = test_matrix(args, &mut rng, false)?;
    let stop = stop_rule(args)?;
    let d = args.get_usize("d", 2)?;
    let p = args.get_usize("sketch", 8)?;
    let backends = args.get_string("backends", "classic,prism,polarexpress");
    println!(
        "polar: A is {}x{}, spectrum={}",
        a.rows(),
        a.cols(),
        args.get_string("spectrum", "gaussian")
    );
    for tok in backends.split(',') {
        let name = registry_name(tok.trim(), MatFnTask::Polar, d);
        match solve_named(&name, stop, d, p, &a, &mut rng) {
            Ok((label, out)) => print_log(
                &label,
                &out.log,
                &format!("orth-err={:.2e}", orthogonality_error(&out.primary)),
            ),
            Err(e) => eprintln!("  (skipping '{}': {e})", tok.trim()),
        }
    }
    Ok(())
}

fn cmd_sqrt(args: &Args) -> prism::util::Result<()> {
    let mut rng = Rng::seed_from(args.get_u64("seed", 42)?);
    let g = test_matrix(args, &mut rng, false)?;
    // Square roots want a symmetric PSD input: use GᵀG (Wishart-like).
    let a = prism::linalg::gemm::syrk_at_a(&g);
    let stop = stop_rule(args)?;
    let d = args.get_usize("d", 2)?;
    let p = args.get_usize("sketch", 8)?;
    let backends = args.get_string("backends", "classic,prism");
    println!("sqrt: A = GᵀG is {}x{}", a.rows(), a.cols());
    for tok in backends.split(',') {
        let name = registry_name(tok.trim(), MatFnTask::Sqrt, d);
        match solve_named(&name, stop, d, p, &a, &mut rng) {
            Ok((label, out)) => {
                // The coupled methods return A^{-1/2} as the secondary
                // output; use it for the paper's Fig. D.3 error metric.
                let extra = out
                    .secondary
                    .as_ref()
                    .map(|y| format!("‖I−YAY‖={:.2e}", sqrt_error(&a, y)))
                    .unwrap_or_default();
                print_log(&label, &out.log, &extra);
            }
            Err(e) => eprintln!("  (skipping '{}': {e})", tok.trim()),
        }
    }
    Ok(())
}

fn cmd_invroot(args: &Args) -> prism::util::Result<()> {
    let mut rng = Rng::seed_from(args.get_u64("seed", 42)?);
    let g = test_matrix(args, &mut rng, false)?;
    let a = prism::linalg::gemm::syrk_at_a(&g);
    let stop = stop_rule(args)?;
    let p = args.get_usize("p", 2)?;
    let sketch = args.get_usize("sketch", 8)?;
    let d = args.get_usize("d", 2)?;
    let backends = args.get_string("backends", "classic,prism");
    println!("invroot: A^(-1/{p}), A is {}x{}", a.rows(), a.cols());
    for tok in backends.split(',') {
        let name = registry_name(tok.trim(), MatFnTask::InvRoot { p }, d);
        match solve_named(&name, stop, d, sketch, &a, &mut rng) {
            Ok((label, out)) => print_log(&label, &out.log, ""),
            Err(e) => eprintln!("  (skipping '{}': {e})", tok.trim()),
        }
    }
    Ok(())
}

fn cmd_inverse(args: &Args) -> prism::util::Result<()> {
    let mut rng = Rng::seed_from(args.get_u64("seed", 42)?);
    let a = test_matrix(args, &mut rng, true)?;
    let stop = stop_rule(args)?;
    let sketch = args.get_usize("sketch", 8)?;
    let d = args.get_usize("d", 2)?;
    let backends = args.get_string("backends", "classic,prism");
    println!("inverse: A is {}x{}", a.rows(), a.cols());
    for tok in backends.split(',') {
        let name = registry_name(tok.trim(), MatFnTask::Inverse, d);
        match solve_named(&name, stop, d, sketch, &a, &mut rng) {
            Ok((label, out)) => print_log(&label, &out.log, ""),
            Err(e) => eprintln!("  (skipping '{}': {e})", tok.trim()),
        }
    }
    Ok(())
}

fn cmd_sign(args: &Args) -> prism::util::Result<()> {
    let mut rng = Rng::seed_from(args.get_u64("seed", 42)?);
    let n = args.get_usize("n", 128)?;
    let smin = args.get_f64("smin", 1e-6)?;
    // A with A² symmetric and eigenvalues of both signs.
    let w: Vec<f64> = randmat::logspace(smin, 1.0, n)
        .iter()
        .enumerate()
        .map(|(i, &x)| if i % 2 == 0 { x } else { -x })
        .collect();
    let a = randmat::sym_with_spectrum(&mut rng, n, &w);
    let stop = stop_rule(args)?;
    let d = args.get_usize("d", 1)?;
    let sketch = args.get_usize("sketch", 8)?;
    let backends = args.get_string("backends", "classic,prism,exact");
    println!("sign: A is {n}x{n}, eigenvalues in ±[{smin:.1e}, 1]");
    for tok in backends.split(',') {
        let name = registry_name(tok.trim(), MatFnTask::Sign, d);
        match solve_named(&name, stop, d, sketch, &a, &mut rng) {
            Ok((label, out)) => print_log(&label, &out.log, ""),
            Err(e) => eprintln!("  (skipping '{}': {e})", tok.trim()),
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> prism::util::Result<()> {
    let seed = args.get_u64("seed", 42)?;
    let jobs = args.get_usize("jobs", 64)?;
    let stream_res = args.has_switch("stream");
    let cfg = ServiceConfig {
        workers: args.get_usize("workers", 4)?,
        queue_cap: args.get_usize("queue-cap", 128)?,
        admission: match args.get("admission") {
            Some(s) => prism::config::Admission::parse(s)?,
            None => prism::config::Admission::Block,
        },
        max_batch: args.get_usize("batch", 4)?,
        sketch_p: args.get_usize("sketch", 8)?,
        max_iters: args.get_usize("iters", 60)?,
        // No --tol keeps the per-task solver defaults (1e-7 polar/sign,
        // 1e-9 inverse-root); an explicit flag forces one tolerance for
        // every task kind.
        tol: match args.get("tol") {
            Some(_) => Some(args.get_f64("tol", 1e-7)?),
            None => None,
        },
        precision: match args.get("precision") {
            Some(s) => prism::matfn::Precision::parse(s).ok_or_else(|| {
                prism::util::Error::Parse(format!("--precision '{s}' (want f64|mixed)"))
            })?,
            None => prism::matfn::Precision::F64,
        },
        solver_cache_cap: args.get_usize("cache-cap", 32)?,
        gemm_threads: args.get_usize("threads", 1)?,
        stream_residuals: stream_res,
        gemm_block: match args.get("gemm-block") {
            Some(spec) => Some(prism::linalg::gemm::GemmBlocking::parse(spec)?),
            None => None,
        },
        gemm_kernel: match args.get("gemm-kernel") {
            Some(spec) => prism::linalg::gemm::MicroKernel::parse(spec)?,
            None => None,
        },
        // --faults wins; otherwise the PALLAS_FAULTS env var (if set) feeds
        // the same validated path in Service::start. Absent both, the fault
        // hooks stay compiled out of the hot path (one relaxed load).
        faults: args
            .get("faults")
            .map(str::to_string)
            .or_else(|| std::env::var("PALLAS_FAULTS").ok()),
        linger: match args.get("linger") {
            Some(_) => {
                Some(std::time::Duration::from_millis(args.get_u64("linger", 0)?))
            }
            None => None,
        },
        cache_snapshot: args.get("cache-snapshot").map(str::to_string),
    };
    let backend = Backend::parse(&args.get_string("backend", "prism5"))?;
    let kappa = args.get_f64("kappa", 0.5)?;
    let n = args.get_usize("n", 128)?;
    println!(
        "serve: {} workers, batch≤{}, backend={}, {jobs} jobs of {n}x{n} HTMP(κ={kappa})",
        cfg.workers,
        cfg.max_batch,
        backend.name()
    );
    let shapes = vec![(n, n), (n, n / 2)];
    let mut stream = GradientStream::new(seed, shapes, kappa);
    let svc = Service::start(cfg, backend, seed)?;
    let sw = Stopwatch::start();
    for _ in 0..jobs {
        let (layer, g) = stream.next_grad();
        let (r, c) = g.shape();
        if r == c {
            let a = prism::linalg::gemm::syrk_at_a(&g);
            svc.submit(layer, JobKind::InvSqrt { eps: 1e-8 }, a)?;
        } else {
            svc.submit(layer, JobKind::Polar, g)?;
        }
    }
    let results = svc.drain()?;
    let wall = sw.elapsed_s();
    println!(
        "  {} results in {:.2}s — {:.1} jobs/s",
        results.len(),
        wall,
        results.len() as f64 / wall
    );
    if stream_res {
        // Drain the per-iteration residual stream the workers emitted while
        // the jobs were running (the Observer hook through the matfn API).
        let mut events = 0usize;
        let mut last: Option<prism::coordinator::service::ResidualEvent> = None;
        while let Some(ev) = svc.try_recv_progress() {
            events += 1;
            last = Some(ev);
        }
        if let Some(ev) = last {
            println!(
                "  streamed {events} residual points (last: job {} iter {} residual {:.2e})",
                ev.id, ev.iter, ev.residual
            );
        }
    }
    println!("{}", svc.report());
    Ok(())
}

fn cmd_train(args: &Args) -> prism::util::Result<()> {
    let cfg = match args.get("config") {
        Some(path) => TrainConfig::from_toml_file(path)?,
        None => TrainConfig::default(),
    };
    let steps = args.get_usize("steps", cfg.steps)?;
    let opt_name = args.get_string("optimizer", "muon");
    let backend = Backend::parse(&args.get_string("backend", cfg.backend.name()))?;
    let dir = args.get_string("artifacts", "artifacts");
    let rt = Runtime::open(&dir)?;
    println!("train: PJRT platform = {}", rt.platform());
    let mut driver = TrainDriver::new(&rt, cfg.seed as f32)?;
    println!(
        "  model: {} params across {} tensors, batch={} seq={} vocab={}",
        driver.num_params(),
        driver.params.len(),
        driver.batch,
        driver.seq_len,
        driver.vocab
    );
    let mut opt: Box<dyn Optimizer> = match opt_name.as_str() {
        "muon" => {
            let mut m = Muon::paper_default(backend, cfg.seed);
            m.set_rect_strategy(cfg.rect_strategy);
            Box::new(m)
        }
        "adamw" => Box::new(AdamW::paper_default()),
        "shampoo" => Box::new(Shampoo::paper_default(backend, cfg.seed)),
        other => {
            return Err(prism::util::Error::Parse(format!(
                "--optimizer '{other}' (want muon|adamw|shampoo)"
            )))
        }
    };
    let mut rng = Rng::seed_from(cfg.seed);
    let corpus = MarkovCorpus::generate(&mut rng, driver.vocab, 200_000);
    println!(
        "  corpus: {} tokens, unigram entropy {:.3} nats; optimizer = {}",
        corpus.tokens.len(),
        corpus.unigram_entropy(),
        opt.name()
    );
    let log_every = args.get_usize("log-every", cfg.log_every.max(1))?;
    for step in 0..steps {
        let (xs, ys) = corpus.sample_batch(&mut rng, driver.batch, driver.seq_len);
        let loss = driver.step(&xs, &ys, opt.as_mut())?;
        if step % log_every == 0 || step + 1 == steps {
            let t = driver.step_times_s.last().copied().unwrap_or(0.0);
            println!("  step {step:>5}  loss {loss:.4}  ({:.0} ms/step)", t * 1e3);
        }
    }
    let first = driver.losses.first().copied().unwrap_or(f64::NAN);
    let last = driver.losses.last().copied().unwrap_or(f64::NAN);
    println!("  done: loss {first:.4} → {last:.4} over {steps} steps");
    Ok(())
}

fn cmd_info(args: &Args) -> prism::util::Result<()> {
    let dir = args.get_string("artifacts", "artifacts");
    let rt = Runtime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts in {dir}:");
    for e in &rt.manifest.entries {
        let ins: Vec<String> =
            e.inputs.iter().map(|t| format!("{}{:?}", t.name, t.shape)).collect();
        println!("  {:<24} {} inputs: {}", e.name, e.file, ins.join(", "));
    }
    Ok(())
}
