//! Manual-backprop neural networks for the optimizer experiments.
//!
//! The Fig. 5 stand-in (Shampoo on image-like classification) trains an
//! [`Mlp`] on [`crate::workload::BlobsDataset`]; the Fig. 6 native-Rust
//! fallback (Muon on language modelling) trains an [`MlpLm`] — a windowed
//! embedding-MLP language model whose parameters are matrix-shaped, exactly
//! the case Muon/Shampoo preconditioning targets. (The full transformer runs
//! through the JAX/PJRT path in `coordinator::train`.)
//!
//! Everything uses explicit reverse-mode gradients; no autodiff framework.

pub mod checkpoint;
pub mod layers;
pub mod mlp;
pub mod lm;

pub use layers::{Param, ParamKind};
pub use lm::MlpLm;
pub use mlp::Mlp;
